"""Configuration system for the repro framework.

Every model/run is described by three dataclasses:

  * :class:`ModelConfig`    — architecture hyper-parameters (one per assigned arch).
  * :class:`ParallelConfig` — mesh + strategy (hecaton 2D-TP / megatron 1D-TP), ZeRO,
                              remat, microbatching.
  * :class:`RunConfig`      — shape (seq/batch), mode (train / prefill / decode),
                              optimizer settings.

Configs are plain frozen dataclasses so they hash (usable as jit static args) and
serialize to JSON for checkpoint metadata.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

# Block kinds understood by models/blocks.py
ATTN = "attn"        # self-attention + MLP transformer block
MAMBA = "mamba"      # mamba2 SSD block
SHARED_ATTN = "shared_attn"  # zamba2-style block whose attention params are shared


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # router jitter / z-loss coefficients
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek/MiniCPM3 style)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) mixer hyper-parameters."""
    state_dim: int = 128        # N (ssm_state)
    head_dim: int = 64          # P
    expand: int = 2             # d_inner = expand * d_model
    n_groups: int = 1           # B/C groups
    conv_kernel: int = 4
    chunk_size: int = 128       # SSD chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // num_heads
    mlp_kind: str = "swiglu"                # swiglu | relu2 | gelu | geglu
    norm_kind: str = "rmsnorm"              # rmsnorm | layernorm
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # Block layout: default all-ATTN. For ssm/hybrid archs this is a pattern.
    # block_pattern is a tuple of block kinds of length num_layers (derived in
    # __post_init__ helpers for hybrids), or None => all "attn".
    block_pattern: Optional[Tuple[str, ...]] = None
    # zamba2-style: how many distinct shared-attention parameter sets exist.
    num_shared_attn_sets: int = 0
    shared_attn_every: int = 0               # insert shared attn after every k blocks
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # enc-dec (whisper): encoder depth; decoder depth = num_layers.
    encoder_layers: int = 0
    encoder_is_causal: bool = False
    # modality frontend stub: number of prefix embeddings supplied by input_specs()
    # (audio frames for whisper encoder, image patches for paligemma).
    frontend_stub_len: int = 0
    # dropout applied to the embedding output (computed on the local token
    # shard of the sequence-sharded residual stream; needs a "dropout_rng"
    # batch entry to be active — omitted rng means deterministic eval).
    embed_dropout: float = 0.0
    max_seq_len: int = 1_048_576
    dtype_note: str = "bf16 compute / fp32 master"

    # ---- derived helpers -------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 (Megatron-style padding) so the
        embedding/vocab dim tiles evenly over any mesh factorization."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        pat = self.pattern()
        return all(k == MAMBA for k in pat)

    def pattern(self) -> Tuple[str, ...]:
        if self.block_pattern is not None:
            return self.block_pattern
        return tuple([ATTN] * self.num_layers)

    def param_count(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS = 6*N*D)."""
        from repro.models.lm import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        """Active params per token (MoE counts only routed experts)."""
        from repro.models.lm import count_params_analytic
        return count_params_analytic(self, active_only=True)

    def scaled(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)


# ---------------------------------------------------------------------------
# Parallelism configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParallelConfig:
    strategy: str = "hecaton"        # hecaton | megatron
    # mesh shape; axis names derived from strategy + multi_pod.
    data: int = 16
    model: int = 16                  # for hecaton this splits into mx*my
    mx: int = 4                      # hecaton grid rows  (token axis)
    my: int = 4                      # hecaton grid cols  (hidden axis)
    pods: int = 1
    # What the pod axis does when pods > 1 (multi-package systems):
    #   "data"     — extra data parallelism (batch sharded over the pod axis
    #                alongside "data"; the pre-PR-5 behaviour).
    #   "pipeline" — each pod owns one contiguous STAGE of the block stack
    #                and microbatches stream through a 1F1B schedule
    #                (parallel/pipeline.py, docs/DESIGN.md §5).  The
    #                off-package links then only carry one boundary
    #                activation per microbatch per stage boundary — the
    #                right tier for the slow inter-package links (§V-B).
    #                Requires pods > 1 (validated below).
    pod_axis_role: str = "data"      # data | pipeline
    # ZeRO-1: shard optimizer states over the data axis.
    zero1: bool = True
    # FSDP (ZeRO-3-lite): shard parameter *storage* over the data axis too;
    # per-layer all-gathers happen inside the layer scan (grads reduce-scatter
    # back).  Enabled for models whose model-sharded params exceed HBM budget.
    fsdp: bool = False
    # gradient all-reduce precision: fp32 | bf16 | int8 (error feedback)
    grad_reduce_dtype: str = "bf16"
    # remat policy name (see core/schedule.py)
    remat: str = "fusion"            # none | fusion | full
    # fused chunked lm-head+loss (Perf iteration 2): never materializes
    # [tokens, V] logits; vocab sharded over h_ax only.
    fused_loss: bool = True
    # NoP communication/compute overlap for the hecaton collectives
    # (core/overlap.py): "none" = bulk-synchronous AG/RS (paper Alg. 1 as
    # written), "ring" = ppermute-decomposed collective matmuls (AG-matmul /
    # matmul-RS), "bidir" = half-sized shards circulating both ring
    # directions, "fused" = the whole ring inside one Pallas kernel with
    # double-buffered remote DMA (kernels/ring_matmul.py; falls back to
    # "ring" per collective on non-tile-aligned shapes).
    overlap: str = "none"
    # NoP ring-collective wire dtype (core/quant.py): "bf16" ships shards
    # as-is (bit-identical to the pre-quantization rings), "int8" quantizes
    # every hop's shard with per-row symmetric scales — (int8 payload, fp32
    # scale) crosses the link, dequantized into the fp32 accumulator on
    # receipt; hops whose shard cannot carry scales (integer ids, trailing
    # extents < quant.MIN_QUANT_DIM) degrade per hop to full width, mirroring
    # the fused→ring→bulk overlap lattice (docs/DESIGN.md §11).
    comm_dtype: str = "bf16"
    # Canonical inter-block residual-stream layout (parallel/sharding.py
    # RESIDUAL_LAYOUTS): "seq" keeps activations token-sharded over the model
    # axes between blocks — hecaton's Alg. 1 tiling natively, and the
    # Korthikanti-style sequence-parallel layout for the megatron baseline
    # (column-parallel gathers the sequence at entry, row-parallel
    # reduce-scatters it at exit; both ride the ``overlap`` ring lattice).
    # "replicated" restores the classic 1D-TP model-replicated residual
    # (per-die activation memory does NOT shrink with N — the property the
    # paper criticizes in §V-A(b)).  Decode and non-dividing sequence extents
    # fall back to "replicated" per call site.
    residual: str = "seq"
    # microbatches for grad accumulation (paper's mini-batches)
    microbatches: int = 8
    # attention layout preference (see parallel/sharding.py solver)
    attn_layout: str = "auto"        # auto | heads | batch

    def __post_init__(self):
        if self.strategy == "hecaton":
            assert self.mx * self.my == self.model, (
                f"hecaton grid {self.mx}x{self.my} != model={self.model}")
        assert self.overlap in ("none", "ring", "bidir", "fused"), (
            f"overlap={self.overlap!r} not in "
            f"('none', 'ring', 'bidir', 'fused')")
        assert self.residual in ("seq", "replicated"), (
            f"residual={self.residual!r} not in ('seq', 'replicated')")
        assert self.comm_dtype in ("bf16", "int8"), (
            f"comm_dtype={self.comm_dtype!r} not in ('bf16', 'int8')")
        if self.pod_axis_role not in ("data", "pipeline"):
            raise ValueError(
                f"pod_axis_role={self.pod_axis_role!r} not in "
                f"('data', 'pipeline')")
        if self.pods < 1:
            raise ValueError(f"pods={self.pods} must be >= 1")
        if self.microbatches < 1:
            raise ValueError(
                f"microbatches={self.microbatches} must be >= 1")
        if self.pod_axis_role == "pipeline" and self.pods < 2:
            # The old silent no-op: "pipeline" used to be accepted and run
            # as extra data parallelism.  A 1-pod pipeline is degenerate —
            # reject it rather than silently doing something else.
            raise ValueError(
                "pod_axis_role='pipeline' requires pods > 1 "
                f"(got pods={self.pods}); use pod_axis_role='data' for "
                "single-pod meshes")

    @property
    def pipeline_enabled(self) -> bool:
        """True when the pod axis runs 1F1B stages (parallel/pipeline.py)."""
        return self.pod_axis_role == "pipeline" and self.pods > 1

    @property
    def pipeline_stages(self) -> int:
        return self.pods if self.pipeline_enabled else 1

    @property
    def total_devices(self) -> int:
        return self.pods * self.data * self.model

    def with_(self, **overrides) -> "ParallelConfig":
        return dataclasses.replace(self, **overrides)


# ---------------------------------------------------------------------------
# Checkpoint configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CheckpointConfig:
    """Persistence policy for the train loop (checkpoint/manager.py).

    ``async_`` selects the AsyncCheckpointManager: the step boundary only
    snapshots param+optimizer shards into a reusable host staging arena and a
    background writer thread serializes + atomically publishes, so the
    compute pipeline never stalls on persistence (ISSUE 4 / the paper's
    DRAM-traffic-hiding argument).  ``staging`` degrades the async manager to
    the blocking path ("sync") without changing the manager type — useful for
    A/B-ing the stall.  ``max_inflight`` bounds the arena (and therefore host
    memory): acquiring a slot blocks when that many snapshots are unwritten.

    ``writers`` fans each save out over a writer group (ISSUE 6): N logical
    writers persist disjoint shard sets into per-writer subdirectories with
    per-shard checksums, and a coordinator publishes the step's global
    manifest only after ``quorum`` partial manifests verified AND every
    shard is covered (two-phase quorum publish, docs/DESIGN.md §7).  On
    pipeline meshes the natural choice is one writer per stage/pod
    (``parallel/pipeline.stage_writer_map``); otherwise shards are
    byte-balanced across the group.  ``quorum=None`` means all writers;
    ``quorum < writers`` only lets a save survive dead writers that owned
    zero shards.  ``verify`` re-checks every shard's byte length + crc32 on
    restore, failing loudly (naming the file) on corruption.

    ``writer_procs`` (ISSUE 8) runs each logical writer as its own OS
    process (runtime/procs.py, docs/DESIGN.md §9): the snapshot is handed
    over through a shared-memory arena (spill-file fallback), each child
    writes the same ``writer_NN/`` tree, and a heartbeat-lease layer
    detects crashed / hung / slow writers — a dead writer's shard range is
    reassigned to a surviving writer (up to ``reassign`` times per save)
    before the quorum gate, so a ``kill -9`` mid-save degrades the save
    instead of tearing it.  ``writer_timeout`` is both the lease deadline
    (a writer whose heartbeat token stalls longer is SIGKILL-fenced) and
    the slow-writer reporting threshold.
    """
    every: int = 50                  # save cadence in steps
    keep: int = 3                    # published checkpoints retained by GC
    async_: bool = True              # background writer vs blocking save
    staging: str = "host"            # "host" (staged async) | "sync"
    max_inflight: int = 2            # double-buffered staging arena slots
    durable: bool = False            # fsync data + dirs around the publish
    writers: int = 1                 # logical writer-group size
    quorum: Optional[int] = None     # partial manifests required (None: all)
    verify: bool = True              # checksum-verify shards on restore
    writer_procs: bool = False       # writers as OS processes (fleet)
    writer_timeout: float = 5.0      # heartbeat-lease deadline, seconds
    reassign: int = 1                # orphan-range reassignments per save

    def __post_init__(self):
        assert self.every >= 1, f"ckpt every={self.every} must be >= 1"
        assert self.keep >= 1, f"ckpt keep={self.keep} must be >= 1"
        assert self.max_inflight >= 1, self.max_inflight
        assert self.staging in ("host", "sync"), (
            f"staging={self.staging!r} not in ('host', 'sync')")
        assert self.writers >= 1, f"writers={self.writers} must be >= 1"
        if self.quorum is not None:
            assert 1 <= self.quorum <= self.writers, (
                f"quorum={self.quorum} must be in [1, writers="
                f"{self.writers}]")
        assert self.writer_timeout > 0, (
            f"writer_timeout={self.writer_timeout} must be > 0")
        assert self.reassign >= 0, (
            f"reassign={self.reassign} must be >= 0")


# ---------------------------------------------------------------------------
# Training-guard configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GuardConfig:
    """Self-healing training-runtime policy (runtime/guard.py, docs/DESIGN.md
    §8) — three escalating defenses against the failure class checkpointing
    alone cannot fix: numerical blow-ups and hung steps.

    **In-graph skip-update guard** (``grad_spike_factor``,
    ``grad_ewma_alpha``): the jitted optimizer step computes one scalar
    predicate — all grads finite (read off the global-norm reduction the
    clip already does) AND the norm within ``grad_spike_factor``x the EWMA
    of previously accepted norms (``AdamState.gnorm_ewma``) — and applies
    the update under a ``jax.lax.cond`` (both branches trace once).  A
    poison microbatch costs a no-op step, never a crash or a retrace.

    **Loss-spike rollback** (``loss_spike_factor``, ``loss_ewma_alpha``,
    ``patience``, ``skip_cap``, ``rollback``): the loop-side
    ``TrainingGuard`` tracks a loss EWMA; ``patience`` consecutive spiking
    losses (> ``loss_spike_factor``x EWMA, or non-finite), or ``skip_cap``
    consecutive in-graph skips, raise ``DivergenceError``.  With
    ``rollback`` the supervisor then retires checkpoints newer than the
    first poisoned step, blocklists the poison window
    (``blocklist.json``), and restarts on the filtered data stream.

    **Hang watchdog** (``hang_timeout``): a daemon thread armed per step;
    a step exceeding the timeout raises ``HangError`` (supervised,
    retryable).  0 disables the watchdog.
    """
    grad_spike_factor: float = 10.0   # in-graph skip when gnorm > f * EWMA
    grad_ewma_alpha: float = 0.1      # EWMA decay for accepted grad norms
    loss_spike_factor: float = 2.0    # loop-side spike when loss > f * EWMA
    loss_ewma_alpha: float = 0.1      # EWMA decay for non-spiking losses
    patience: int = 3                 # consecutive loss spikes -> rollback
    skip_cap: int = 3                 # consecutive skipped updates -> rollback
    hang_timeout: float = 0.0         # seconds per step; 0 = no watchdog
    rollback: bool = True             # blocklist + rollback vs plain raise

    def __post_init__(self):
        assert self.grad_spike_factor > 1.0, (
            f"grad_spike_factor={self.grad_spike_factor} must be > 1")
        assert 0.0 < self.grad_ewma_alpha <= 1.0, self.grad_ewma_alpha
        assert self.loss_spike_factor > 1.0, (
            f"loss_spike_factor={self.loss_spike_factor} must be > 1")
        assert 0.0 < self.loss_ewma_alpha <= 1.0, self.loss_ewma_alpha
        assert self.patience >= 1, f"patience={self.patience} must be >= 1"
        assert self.skip_cap >= 1, f"skip_cap={self.skip_cap} must be >= 1"
        assert self.hang_timeout >= 0.0, (
            f"hang_timeout={self.hang_timeout} must be >= 0")


# ---------------------------------------------------------------------------
# Run configuration (shape cells)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RunConfig:
    shape_name: str                  # train_4k | prefill_32k | decode_32k | long_500k
    mode: str                        # train | prefill | decode
    seq_len: int
    global_batch: int
    # optimizer
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    warmup_steps: int = 100


# The four assigned LM shape cells.
SHAPES: Dict[str, RunConfig] = {
    "train_4k":    RunConfig("train_4k",    "train",  4_096,   256),
    "prefill_32k": RunConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  RunConfig("decode_32k",  "decode", 32_768,  128),
    "long_500k":   RunConfig("long_500k",   "decode", 524_288, 1),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}
_SMOKE_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig, smoke: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    _SMOKE_REGISTRY[cfg.name] = smoke
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def get_smoke_config(name: str) -> ModelConfig:
    _ensure_loaded()
    return _SMOKE_REGISTRY[name]


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    if not _REGISTRY:
        import repro.configs  # noqa: F401  (registers everything)


def shape_cells_for(cfg: ModelConfig):
    """The (shape -> RunConfig) cells assigned to an arch, honoring skips.

    ``long_500k`` runs only for sub-quadratic archs (ssm / hybrid); pure
    full-attention archs skip it (recorded as an explicit skip, per docs/DESIGN.md §4).
    """
    cells = {}
    for name, rc in SHAPES.items():
        if name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
            continue
        cells[name] = rc
    return cells


def config_to_json(cfg) -> str:
    return json.dumps(dataclasses.asdict(cfg), default=str, indent=2)
