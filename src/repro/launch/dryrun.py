import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device count
# on first init).  REPRO_DRYRUN_DEVICES overrides for scaled-down testing.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run driver.

For every (arch x shape x mesh x strategy) cell:
  * builds the real train/prefill/decode step,
  * ``jax.jit(...).lower(**ShapeDtypeStructs).compile()`` on the production mesh
    (16x16 single pod / 2x16x16 multi-pod; hecaton refactors model=16 -> 4x4),
  * prints ``memory_analysis()`` (proves it fits) and ``cost_analysis()``,
  * extracts loop-scaled per-chip FLOPs / HBM bytes / collective bytes
    (roofline/hlo.py) and writes one JSON per cell for EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import config as C
from repro.config import ParallelConfig, get_config, shape_cells_for
from repro.core import schedule
from repro.launch import inputs as I
from repro.launch import mesh as M
from repro.models import lm
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.parallel import specs as SP
from repro.roofline import analysis as RA
from repro.serve import step as serve_step
from repro.train import step as train_step
from jax.sharding import NamedSharding, PartitionSpec as P

FSDP_THRESHOLD_BYTES = 2e9      # params(fp32)/model-shard above this => FSDP


def make_pcfg(cfg, rc, strategy: str, multi_pod: bool) -> ParallelConfig:
    params_bytes = cfg.param_count() * 4
    fsdp = params_bytes / 16 > FSDP_THRESHOLD_BYTES
    micro, remat = 1, "none"
    n_data = 32 if multi_pod else 16      # pod axis is data-parallel
    if rc.mode == "train":
        micro, remat = schedule.choose_microbatches(
            rc.global_batch, rc.seq_len, cfg.d_model, n_data_shards=n_data,
            n_token_shards=16, num_layers=cfg.num_layers + cfg.encoder_layers,
            vocab=cfg.padded_vocab, act_budget_bytes=2e9)
    if os.environ.get("REPRO_MICRO_OVERRIDE"):
        micro = int(os.environ["REPRO_MICRO_OVERRIDE"])
    return ParallelConfig(strategy=strategy, data=16, model=16, mx=4, my=4,
                          pods=2 if multi_pod else 1, fsdp=fsdp,
                          microbatches=micro, remat=remat,
                          attn_layout=os.environ.get("REPRO_ATTN_LAYOUT",
                                                     "auto"))


def _batch_sharding(mesh, pcfg, batch_structs, *, global_batch):
    ax = shd.axis_info(mesh, pcfg.strategy)
    d = shd._one(ax.data_axes)
    if global_batch % ax.n_data:
        d = None                      # e.g. long_500k batch=1: data axis idle
    if pcfg.strategy == "hecaton":
        seq_ax = ax.t_ax
    elif pcfg.residual == "seq":
        # megatron seq-sharded residual: inputs arrive token-sharded over the
        # model axis so the embedding scatter lands in the canonical layout
        seq_ax = shd._one(ax.model_axes)
    else:
        seq_ax = None

    def s_ok(extent):
        # shard a sequence-like dim only when it divides the token ring
        # (e.g. whisper's 1500 frames do NOT divide a 16-way model ring)
        return (seq_ax is not None and extent > 1
                and extent % ax.size(seq_ax) == 0)

    out = {}
    for k, v in batch_structs.items():
        rank = len(v.shape)
        if k == "dropout_rng":
            spec = P()                # PRNG key: replicated, never sharded
        elif k in ("patches", "frames"):
            spec = P(d, seq_ax if s_ok(v.shape[1]) else None, None)
        elif rank == 2:
            spec = P(d, seq_ax if s_ok(v.shape[1]) else None)
        else:
            spec = P(d)
        out[k] = NamedSharding(mesh, spec)
    return out


def lower_cell(arch: str, shape: str, strategy: str, multi_pod: bool):
    cfg = get_config(arch)
    rc = C.SHAPES[shape]
    pcfg = make_pcfg(cfg, rc, strategy, multi_pod)
    mesh = M.make_mesh_for(strategy, multi_pod=multi_pod)
    chips = int(mesh.devices.size)
    mesh_name = "multi" if multi_pod else "single"

    pshape = I.params_shape(cfg)
    pspecs = SP.param_specs(pshape, mesh, pcfg)
    pshard = SP.sharding_tree(pspecs, mesh)

    if rc.mode == "train":
        ts = train_step.build_train_step(cfg, pcfg, rc, mesh)
        oshape = jax.eval_shape(adamw.init, pshape)
        ospecs = SP.opt_state_specs(pspecs, pshape, mesh, pcfg)
        oshard = SP.sharding_tree(ospecs, mesh)
        bstructs = I.train_input_specs(cfg, rc)
        bshard = _batch_sharding(mesh, pcfg, bstructs,
                                 global_batch=rc.global_batch)
        fn = jax.jit(ts, in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
        lowered = fn.lower(pshape, oshape, bstructs)
    elif rc.mode == "prefill":
        pf = serve_step.build_prefill(cfg, pcfg, rc, mesh)
        bstructs = I.prefill_input_specs(cfg, rc)
        bshard = _batch_sharding(mesh, pcfg, bstructs,
                                 global_batch=rc.global_batch)
        fn = jax.jit(pf, in_shardings=(pshard, bshard))
        lowered = fn.lower(pshape, bstructs)
    else:
        ds = serve_step.build_decode_step(cfg, pcfg, rc, mesh)
        cstructs = I.decode_cache_specs(cfg, rc)
        cspecs = serve_step.cache_specs(cfg, pcfg, mesh, rc.global_batch)
        cshard = SP.sharding_tree(cspecs, mesh)
        bstructs = I.decode_input_specs(cfg, rc)
        bshard = _batch_sharding(mesh, pcfg, bstructs,
                                 global_batch=rc.global_batch)
        fn = jax.jit(ds, in_shardings=(pshard, cshard, bshard["tokens"],
                                       bshard["positions"]),
                     donate_argnums=(1,))
        lowered = fn.lower(pshape, cstructs, bstructs["tokens"],
                           bstructs["positions"])
    return lowered, dict(cfg=cfg, rc=rc, pcfg=pcfg, chips=chips,
                         mesh_name=mesh_name)


def run_cell(arch, shape, strategy, multi_pod, out_dir):
    t0 = time.time()
    tag = f"{arch}.{shape}.{strategy}.{'multi' if multi_pod else 'single'}"
    try:
        lowered, meta = lower_cell(arch, shape, strategy, multi_pod)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        from repro.compat import cost_analysis_dict
        ca = cost_analysis_dict(compiled)
        res = RA.from_compiled(
            compiled, arch=arch, shape=shape, mesh_name=meta["mesh_name"],
            strategy=strategy, chips=meta["chips"], cfg=meta["cfg"],
            rc=meta["rc"], note=f"fsdp={meta['pcfg'].fsdp} "
            f"micro={meta['pcfg'].microbatches}")
        d = res.to_dict()
        d["lower_s"] = round(t_lower, 1)
        d["compile_s"] = round(t_compile, 1)
        d["xla_cost_analysis"] = {k: ca.get(k) for k in
                                  ("flops", "bytes accessed") if k in ca}
        d["memory_analysis"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "peak_bytes_per_chip": ma.argument_size_in_bytes
            + ma.temp_size_in_bytes + ma.output_size_in_bytes,
        }
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(d, f, indent=1, default=str)
        print(f"[OK] {tag}: compute={res.compute_s*1e3:.1f}ms "
              f"mem={res.memory_s*1e3:.1f}ms coll={res.collective_s*1e3:.1f}ms "
              f"bottleneck={res.bottleneck} "
              f"args/chip={ma.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp/chip={ma.temp_size_in_bytes/2**30:.2f}GiB "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)", flush=True)
        return True
    except Exception as e:
        print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
        traceback.print_exc()
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, tag + ".FAIL.txt"), "w") as f:
            f.write(traceback.format_exc())
        return False


ASSIGNED = ["mamba2-130m", "qwen3-0.6b", "nemotron-4-340b", "granite-34b",
            "minicpm3-4b", "paligemma-3b", "whisper-small",
            "granite-moe-3b-a800m", "grok-1-314b", "zamba2-1.2b"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--strategy", default="hecaton",
                    choices=["hecaton", "megatron"])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or args.arch is None) else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    ok = fail = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([args.shape] if args.shape
                  else list(shape_cells_for(cfg)))
        for shape in shapes:
            if shape not in shape_cells_for(cfg):
                print(f"[SKIP] {arch}.{shape}: long_500k skipped for "
                      f"full-attention arch (see docs/DESIGN.md §4)", flush=True)
                continue
            for mp in meshes:
                if run_cell(arch, shape, args.strategy, mp, args.out):
                    ok += 1
                else:
                    fail += 1
    print(f"dryrun done: {ok} ok, {fail} failed")
    sys.exit(1 if fail else 0)


if __name__ == "__main__":
    main()
