"""Serving launcher: continuous-batching decode over the paged cache pool.

Feeds the engine a synthetic arrival trace (more requests than slots,
mixed prompt lengths) and reports prefill latency and decode tok/s
SEPARATELY — both jitted functions are warmed up first so compile time
never pollutes the throughput number.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --slots 4 --requests 8 --gen 16 --sample top_p --eos-id 7
"""

from __future__ import annotations

import argparse
import time


def build_trace(rng, n_requests, vocab, prompt_lens, gen, arrival_every):
    """Deterministic synthetic arrival trace with mixed prompt lengths."""
    import numpy as np
    from repro.serve.engine import Request
    reqs = []
    for i in range(n_requests):
        plen = prompt_lens[i % len(prompt_lens)]
        prompt = rng.integers(0, vocab, size=plen).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new=gen,
                            arrival=i // max(1, arrival_every)))
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots (fixed jit batch)")
    ap.add_argument("--block", type=int, default=16,
                    help="tokens per KV pool block")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="pool blocks incl. the null block (0 = auto)")
    ap.add_argument("--max-seq", type=int, default=0,
                    help="per-sequence prompt+gen cap (0 = auto)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--arrival-every", type=int, default=2,
                    help="new arrivals per engine tick")
    ap.add_argument("--prompt-lens", default="8,24,16",
                    help="comma list cycled over the trace")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--eos-id", type=int, default=-1,
                    help="stop generation at this token id (-1 = off)")
    ap.add_argument("--sample", default="greedy",
                    choices=["greedy", "temperature", "top_p"])
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-p", type=float, default=0.9)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quant-kv", action="store_true",
                    help="store paged K/V as int8 + per-row fp32 scales "
                         "(docs/DESIGN.md §11)")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.config import ParallelConfig, RunConfig, get_config, \
        get_smoke_config
    from repro.models import lm
    from repro.serve.cache import PoolConfig, blocks_for, dense_cache_bytes
    from repro.serve.engine import DecodeEngine

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    prompt_lens = [int(x) for x in args.prompt_lens.split(",") if x]
    max_seq = args.max_seq or max(prompt_lens) + args.gen
    num_blocks = args.num_blocks or \
        args.slots * blocks_for(max_seq, args.block) + 1
    pool = PoolConfig(slots=args.slots, block=args.block,
                      num_blocks=num_blocks, max_seq=max_seq)
    rc = RunConfig("serve", "decode", max_seq, args.slots)
    pcfg = ParallelConfig(strategy="hecaton", data=1, model=1, mx=1, my=1)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    eng = DecodeEngine(cfg, pcfg, rc, params, pool, compute_dtype=jnp.float32,
                       eos_id=None if args.eos_id < 0 else args.eos_id,
                       method=args.sample, temperature=args.temperature,
                       top_p=args.top_p, seed=args.seed,
                       quant_kv=args.quant_kv)
    t0 = time.perf_counter()
    eng.warmup(prompt_lens=prompt_lens)  # compile BEFORE the clock starts
    print(f"warmup (jit) {time.perf_counter() - t0:.2f}s")

    rng = np.random.default_rng(args.seed)
    reqs = build_trace(rng, args.requests, cfg.vocab_size, prompt_lens,
                       args.gen, args.arrival_every)
    fin = eng.run(reqs)

    pf = eng.stats["prefill_s"]
    dec_s = max(eng.stats["decode_s"], 1e-9)
    print(f"{len(fin)} sequences  ticks={eng.stats['decode_ticks']}  "
          f"preemptions={eng.stats['preemptions']}")
    print(f"prefill latency  mean {1e3 * sum(pf) / max(1, len(pf)):.1f} ms  "
          f"max {1e3 * max(pf):.1f} ms")
    print(f"decode           {eng.stats['decode_tokens']} tokens in "
          f"{dec_s:.2f}s  ({eng.stats['decode_tokens'] / dec_s:.1f} tok/s)")
    print(f"pool             peak {eng.pool.peak_blocks_in_use}/"
          f"{pool.leasable_blocks} blocks  "
          f"(dense arena equiv {pool.dense_equiv_blocks} blocks / "
          f"{dense_cache_bytes(cfg, args.slots, max_seq, jnp.float32)} B)")
    for rid in sorted(fin)[:4]:
        f = fin[rid]
        print(f"  rid={rid} plen={f.prompt_len} {f.reason:7s} "
              f"tokens={f.tokens[:10]}")


if __name__ == "__main__":
    main()
