"""Serving launcher: prefill a batch of prompts, then batched greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.config import ParallelConfig, RunConfig, get_config, \
        get_smoke_config
    from repro.data.synthetic import SyntheticLM
    from repro.models import lm
    from repro.serve import step as SS

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    s_max = args.prompt_len + args.gen
    rc = RunConfig("serve", "decode", s_max, args.batch)
    pcfg = ParallelConfig(strategy="hecaton", data=1, model=1, mx=1, my=1)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    prefill = jax.jit(SS.build_prefill(cfg, pcfg, rc, None,
                                       compute_dtype=jnp.float32))
    decode = jax.jit(SS.build_decode_step(cfg, pcfg, rc, None,
                                          compute_dtype=jnp.float32))

    ds = SyntheticLM(cfg.vocab_size, args.prompt_len, args.batch,
                     extras={"patches": (cfg.frontend_stub_len, cfg.d_model)}
                     if cfg.family == "vlm" else
                     ({"frames": (cfg.frontend_stub_len, cfg.d_model)}
                      if cfg.family == "audio" else None))
    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()
             if k != "labels"}

    t0 = time.time()
    logits, caches = prefill(params, batch)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(args.gen - 1):
        pos = jnp.full((args.batch, 1), args.prompt_len + i, jnp.int32)
        logits, caches = decode(params, caches, tok, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    jax.block_until_ready(gen)
    dt = time.time() - t0
    tps = args.batch * args.gen / dt
    print(f"generated {gen.shape} tokens in {dt:.2f}s ({tps:.1f} tok/s)")
    print("sample:", np.asarray(gen[0, :12]))


if __name__ == "__main__":
    main()
