"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --steps 200 --batch 8 --seq 256 [--mesh-devices 8 --strategy hecaton]

On this CPU container it runs single-device (or a small fake-device mesh via
--mesh-devices, spawned through XLA_FLAGS); on a real pod the same entry point
picks up all devices.  Enables checkpointing + fault supervision.
"""

from __future__ import annotations

import argparse
import os
import sys


def _maybe_respawn(n: int):
    if n > 1 and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={n}"
        os.execv(sys.executable, [sys.executable] + sys.argv)


def _guard_cfg(args):
    """GuardConfig from flags, or None when --guard is off (docs/DESIGN.md
    §8).  Passed to the step builder (arms the in-graph skip-update select)
    and to the TrainingGuard (loss-spike / skip-cap escalation)."""
    if not args.guard:
        return None
    from repro.config import GuardConfig
    return GuardConfig(grad_spike_factor=args.guard_spike_factor,
                       loss_spike_factor=args.guard_loss_spike,
                       patience=args.guard_patience,
                       skip_cap=args.guard_skip_cap,
                       hang_timeout=args.hang_timeout,
                       rollback=not args.no_rollback)


def _guard_runtime(args, gcfg, ckpt_dir, start, batch_at):
    """Loop-side guard surface: (TrainingGuard, Watchdog, data_index_fn,
    data stream).  The stream seeks to ``batch_at(data_index(start,
    blocklist))`` — a restored run consumes exactly the batches an
    uninterrupted (blocklist-filtered) run would have, instead of
    restarting the data at index 0."""
    from repro.runtime import guard as G
    tguard = G.TrainingGuard(gcfg) if gcfg is not None else None
    wd = G.Watchdog(args.hang_timeout) if args.hang_timeout > 0 else None
    bl = G.load_blocklist(ckpt_dir)
    if bl:
        print(f"blocklist: skipping poisoned data indices {bl}")
    stream = G.blocklisted_stream(batch_at, start, bl)
    return tguard, wd, (lambda s: G.data_index(s, bl)), stream


def _train_pipeline(cfg, pcfg, rc, mesh, args):
    """1F1B pipeline path: per-pod stage state, host-side schedule executor.

    The step function is NOT jitted (the per-stage closures inside the
    runner are); train/loop.py drives it unchanged because the state leaves
    (lists of per-stage trees) are ordinary pytrees.
    """
    import jax
    import jax.numpy as jnp
    from repro.checkpoint.manager import make_manager
    from repro.config import CheckpointConfig
    from repro.data.synthetic import Prefetcher, SyntheticLM
    from repro.models import lm
    from repro.parallel import pipeline as PP
    from repro.runtime.fault import StepTimer
    from repro.train import loop as train_loop

    gcfg = _guard_cfg(args)
    runner, step = PP.build_pipeline_train_step(
        cfg, pcfg, rc, mesh, total_steps=args.steps,
        compute_dtype=jnp.bfloat16, guard=gcfg)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    sparams = runner.place_params(params)
    sopt = runner.init_opt(sparams)
    del params

    # one checkpoint writer per pipeline stage/pod — each pod persists the
    # stage it already holds — unless --ckpt-writers overrides
    writers = args.ckpt_writers or pcfg.pipeline_stages
    ccfg = CheckpointConfig(every=args.ckpt_every, keep=args.ckpt_keep,
                            async_=not args.ckpt_sync, writers=writers,
                            quorum=args.ckpt_quorum or None,
                            verify=not args.ckpt_no_verify,
                            writer_procs=args.ckpt_procs,
                            writer_timeout=args.ckpt_writer_timeout)
    ckpt = (make_manager(args.ckpt_dir, ccfg,
                         writer_map=PP.stage_writer_map(writers))
            if args.ckpt_dir else None)
    start = 0
    if ckpt is not None and ckpt.latest_step() is not None:
        # per-stage state is an ordinary pytree (lists of stage trees), so
        # the manager restores it shard-for-shard onto the sub-meshes
        restored, start = ckpt.restore(
            {"params": sparams, "opt_state": sopt})
        sparams, sopt = restored["params"], restored["opt_state"]
        print(f"restored pipeline checkpoint at step {start}")

    ds = SyntheticLM(cfg.vocab_size, args.seq, args.batch)
    tguard, wd, dix, stream = _guard_runtime(args, gcfg, args.ckpt_dir,
                                             start, ds.batch_at)
    it = Prefetcher(stream)
    state = {"params": sparams, "opt_state": sopt}
    try:
        state = train_loop.train(step, state, it, start_step=start,
                                 num_steps=args.steps, ckpt=ckpt,
                                 ckpt_every=ccfg.every, timer=StepTimer(),
                                 guard=tguard, watchdog=wd,
                                 data_index_fn=dix)
    finally:
        if wd is not None:
            wd.close()
        it.close()
    if ckpt is not None:
        ckpt.close()                 # train() already drained in-flight saves
    h = state["history"]
    print(f"pipeline[{pcfg.pods} stages x ({pcfg.mx}x{pcfg.my})] "
          f"final loss {h[-1][1]:.4f} (first {h[0][1]:.4f})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--strategy", default="hecaton")
    ap.add_argument("--comm-dtype", default="bf16", choices=["bf16", "int8"],
                    help="ring-collective wire dtype: int8 quantizes each "
                         "hop's shard (docs/DESIGN.md §11)")
    ap.add_argument("--mesh-devices", type=int, default=1)
    ap.add_argument("--data", type=int, default=2)
    ap.add_argument("--mx", type=int, default=2)
    ap.add_argument("--my", type=int, default=2)
    ap.add_argument("--pods", type=int, default=1,
                    help="number of packages; with --pod-role pipeline each "
                         "pod runs one 1F1B stage of the block stack")
    ap.add_argument("--pod-role", default="data",
                    choices=("data", "pipeline"))
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-keep", type=int, default=3)
    ap.add_argument("--ckpt-sync", action="store_true",
                    help="blocking saves (default: async double-buffered "
                         "writer that hides the persistence stall)")
    ap.add_argument("--ckpt-writers", type=int, default=0,
                    help="logical checkpoint writers (0 = auto: one per "
                         "pipeline stage, else 1)")
    ap.add_argument("--ckpt-quorum", type=int, default=0,
                    help="partial manifests required before a step "
                         "publishes (0 = all writers)")
    ap.add_argument("--ckpt-no-verify", action="store_true",
                    help="skip per-shard checksum verification on restore")
    ap.add_argument("--ckpt-procs", action="store_true",
                    help="run each logical checkpoint writer as its own OS "
                         "process (heartbeat leases + orphan-shard "
                         "reassignment; runtime/procs.py, docs/DESIGN.md §9)")
    ap.add_argument("--ckpt-writer-timeout", type=float, default=5.0,
                    help="heartbeat-lease deadline in seconds: a writer "
                         "process whose heartbeat stalls longer is SIGKILL-"
                         "fenced and its shard range reassigned")
    ap.add_argument("--guard", action="store_true",
                    help="arm the self-healing guard: in-graph NaN/spike "
                         "skip-update + loss-spike divergence detection "
                         "(docs/DESIGN.md §8)")
    ap.add_argument("--guard-spike-factor", type=float, default=10.0,
                    help="skip the update when grad norm exceeds this "
                         "multiple of its EWMA")
    ap.add_argument("--guard-loss-spike", type=float, default=2.0,
                    help="a step whose loss exceeds this multiple of the "
                         "loss EWMA counts toward divergence patience")
    ap.add_argument("--guard-patience", type=int, default=3,
                    help="consecutive spiking losses before DivergenceError")
    ap.add_argument("--guard-skip-cap", type=int, default=3,
                    help="consecutive in-graph skipped updates before "
                         "DivergenceError")
    ap.add_argument("--hang-timeout", type=float, default=0.0,
                    help="seconds before an armed step counts as hung "
                         "(0 = watchdog off)")
    ap.add_argument("--no-rollback", action="store_true",
                    help="on divergence, restart WITHOUT retiring poisoned "
                         "checkpoints / blocklisting the poison window")
    args = ap.parse_args()
    _maybe_respawn(max(args.mesh_devices,
                       args.pods * args.data * args.mx * args.my
                       if args.pods > 1 else args.mesh_devices))

    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.checkpoint.manager import make_manager
    from repro.config import (CheckpointConfig, ParallelConfig, RunConfig,
                              get_config, get_smoke_config)
    from repro.data.synthetic import Prefetcher, SyntheticLM
    from repro.launch.mesh import make_small_mesh
    from repro.optim import adamw
    from repro.parallel import specs as SP
    from repro.runtime.fault import StepTimer
    from repro.train import loop as train_loop
    from repro.train import step as TS
    from repro.models import lm

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rc = RunConfig("custom", "train", args.seq, args.batch, lr=args.lr)
    mesh = None
    pcfg = ParallelConfig(strategy=args.strategy, data=args.data,
                          model=args.mx * args.my, mx=args.mx, my=args.my,
                          pods=args.pods, pod_axis_role=args.pod_role,
                          microbatches=args.microbatches, zero1=True,
                          comm_dtype=args.comm_dtype)
    if args.mesh_devices > 1 or args.pods > 1:
        mesh = make_small_mesh(args.strategy, args.data, args.mx, args.my,
                               pods=args.pods)

    if pcfg.pipeline_enabled:
        _train_pipeline(cfg, pcfg, rc, mesh, args)
        return

    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw.init(params)
    if mesh is not None:
        pspecs = SP.param_specs(params, mesh, pcfg)
        pshard = SP.sharding_tree(pspecs, mesh)
        params = jax.device_put(params, pshard)
        ospecs = SP.opt_state_specs(pspecs, params, mesh, pcfg)
        opt_state = jax.device_put(opt_state, SP.sharding_tree(ospecs, mesh))

    gcfg = _guard_cfg(args)
    ts = TS.build_train_step(cfg, pcfg, rc, mesh,
                             compute_dtype=jnp.float32 if mesh is None
                             else jnp.bfloat16, guard=gcfg)
    ts = jax.jit(ts, donate_argnums=(0, 1))

    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = (cfg.frontend_stub_len, cfg.d_model)
    if cfg.family == "audio":
        extras["frames"] = (cfg.frontend_stub_len, cfg.d_model)
    ds = SyntheticLM(cfg.vocab_size, args.seq, args.batch, extras=extras)

    ccfg = CheckpointConfig(every=args.ckpt_every, keep=args.ckpt_keep,
                            async_=not args.ckpt_sync,
                            writers=args.ckpt_writers or 1,
                            quorum=args.ckpt_quorum or None,
                            verify=not args.ckpt_no_verify,
                            writer_procs=args.ckpt_procs,
                            writer_timeout=args.ckpt_writer_timeout)
    ckpt = make_manager(args.ckpt_dir, ccfg) if args.ckpt_dir else None
    start = 0
    if ckpt is not None and ckpt.latest_step() is not None:
        restored, start = ckpt.restore(
            {"params": params, "opt_state": opt_state})
        params, opt_state = restored["params"], restored["opt_state"]
        print(f"restored checkpoint at step {start}")

    tguard, wd, dix, stream = _guard_runtime(args, gcfg, args.ckpt_dir,
                                             start, ds.batch_at)
    it = Prefetcher(stream)
    state = {"params": params, "opt_state": opt_state}
    try:
        state = train_loop.train(ts, state, it, start_step=start,
                                 num_steps=args.steps, ckpt=ckpt,
                                 ckpt_every=ccfg.every,
                                 timer=StepTimer(),
                                 guard=tguard, watchdog=wd,
                                 data_index_fn=dix)
    finally:
        if wd is not None:
            wd.close()
        it.close()
    if ckpt is not None:
        ckpt.close()                 # train() already drained in-flight saves
    h = state["history"]
    print(f"final loss {h[-1][1]:.4f} (first {h[0][1]:.4f})")


if __name__ == "__main__":
    main()
