"""``input_specs``: ShapeDtypeStruct stand-ins for every model input per
(arch x shape) cell — weak-type-correct, shardable, no device allocation.

Modality frontends are STUBS per the assignment: paligemma gets precomputed
patch embeddings, whisper gets precomputed frame embeddings.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ParallelConfig, RunConfig
from repro.models import lm


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def train_input_specs(cfg: ModelConfig, rc: RunConfig) -> Dict[str, Any]:
    B, S = rc.global_batch, rc.seq_len
    batch = {"tokens": sds((B, S), jnp.int32), "labels": sds((B, S), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = sds((B, cfg.frontend_stub_len, cfg.d_model),
                               jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = sds((B, cfg.frontend_stub_len, cfg.d_model),
                              jnp.bfloat16)
    return batch


def prefill_input_specs(cfg: ModelConfig, rc: RunConfig) -> Dict[str, Any]:
    spec = train_input_specs(cfg, rc)
    spec.pop("labels")
    return spec


def decode_input_specs(cfg: ModelConfig, rc: RunConfig) -> Dict[str, Any]:
    B = rc.global_batch
    return {"tokens": sds((B, 1), jnp.int32),
            "positions": sds((B, 1), jnp.int32)}


def decode_cache_specs(cfg: ModelConfig, rc: RunConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStructs for a cache filled to rc.seq_len."""
    return jax.eval_shape(
        lambda: lm.init_caches(cfg, rc.global_batch, rc.seq_len, dtype))


def params_shape(cfg: ModelConfig):
    return jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
