"""Mesh construction.  ``make_production_mesh`` is the spec-mandated entry point;
``make_hecaton_mesh`` refactors the same devices into the paper's 2D grid
(model axis 16 -> 4x4), and ``make_mesh_for`` dispatches on strategy.

Everything is a function — importing this module never touches jax device state.
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_hecaton_mesh(*, multi_pod: bool = False, data: int = 16, mx: int = 4,
                      my: int = 4, pods: int = 2, devices=None):
    """Same chips as the production mesh; model axis factored into (mx, my).

    The (mx, my) grid is the paper's sqrt(N) x sqrt(N) die array; on a TPU v5e
    pod the ICI torus gives every row/column the ring the paper builds from
    bypass links.
    """
    devices = np.asarray(devices if devices is not None else jax.devices())
    if multi_pod:
        shape = (pods, data, mx, my)
        axes = ("pod", "data", "mx", "my")
    else:
        shape = (data, mx, my)
        axes = ("data", "mx", "my")
    need = int(np.prod(shape))
    assert devices.size >= need, f"need {need} devices, have {devices.size}"
    return Mesh(devices[:need].reshape(shape), axes)


def make_mesh_for(strategy: str, *, multi_pod: bool = False, data: int = 16,
                  model: int = 16, mx: int = 4, my: int = 4, devices=None):
    if strategy == "hecaton":
        return make_hecaton_mesh(multi_pod=multi_pod, data=data, mx=mx, my=my,
                                 devices=devices)
    if devices is None:
        return make_production_mesh(multi_pod=multi_pod)
    devices = np.asarray(devices)
    if multi_pod:
        return Mesh(devices[:2 * data * model].reshape(2, data, model),
                    ("pod", "data", "model"))
    return Mesh(devices[:data * model].reshape(data, model), ("data", "model"))


def make_small_mesh(strategy: str, data: int, mx: int, my: int,
                    pods: int = 1):
    """Scaled-down mesh for tests / weak-scaling studies on host devices.

    ``pods > 1`` prepends a leading ``"pod"`` axis — the inter-package tier.
    Whether that axis is extra data parallelism or 1F1B pipeline stages is
    the *config's* call (``ParallelConfig.pod_axis_role``); the mesh only
    fixes the placement: pods are contiguous device blocks, so every
    intra-pod ring stays within a package and only stage-boundary (or
    batch-gradient) traffic crosses the slow tier.
    """
    n = pods * data * mx * my
    devs = np.asarray(jax.devices()[:n])
    if pods > 1:
        if strategy == "hecaton":
            return Mesh(devs.reshape(pods, data, mx, my),
                        ("pod", "data", "mx", "my"))
        return Mesh(devs.reshape(pods, data, mx * my),
                    ("pod", "data", "model"))
    if strategy == "hecaton":
        return Mesh(devs.reshape(data, mx, my), ("data", "mx", "my"))
    return Mesh(devs.reshape(data, mx * my), ("data", "model"))


def pod_submeshes(mesh: Mesh):
    """Split a multi-pod mesh into one single-pod Mesh per pod-axis index.

    Pipeline stages (parallel/pipeline.py) run each stage on its pod's
    sub-mesh: inside a stage the world looks exactly like a single-pod
    mesh, so the hecaton/megatron collectives, the overlap lattice and the
    seq residual compose unchanged.  The pod order of this list defines the
    stage order (stage ``s`` sends its boundary activation to ``s+1``).
    """
    if "pod" not in mesh.axis_names:
        raise ValueError(f"mesh has no 'pod' axis: {mesh.axis_names}")
    i = mesh.axis_names.index("pod")
    names = tuple(a for a in mesh.axis_names if a != "pod")
    return [Mesh(np.take(mesh.devices, k, axis=i), names)
            for k in range(mesh.devices.shape[i])]
