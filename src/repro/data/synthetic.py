"""Deterministic synthetic LM data pipeline.

Production-shaped: per-host sharded generation (each host materializes only its
slice of the global batch), double-buffered host->device prefetch (the paper's
on/off-package overlap, §III-B a), and a learnable synthetic distribution — a
Markov-ish token stream with arch-consistent vocab so that a real model's loss
demonstrably decreases (used by the e2e convergence tests and examples).
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticLM:
    """Deterministic, seekable synthetic token stream.

    Tokens follow t[i+1] = (a * t[i] + noise) % vocab with a few "motifs" so
    next-token prediction is learnable but not trivial.
    """

    def __init__(self, vocab: int, seq_len: int, global_batch: int, *,
                 seed: int = 0, host_id: int = 0, num_hosts: int = 1,
                 extras: Optional[Dict] = None):
        assert global_batch % num_hosts == 0
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.local_batch = global_batch // num_hosts
        self.seed, self.host_id, self.num_hosts = seed, host_id, num_hosts
        self.extras = extras or {}

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step, host) — restart-safe (fault tolerance:
        resuming at step k regenerates the identical batch)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        B, S, V = self.local_batch, self.seq_len, self.vocab
        base = rng.integers(0, V, size=(B, 1), dtype=np.int64)
        mult = 1 + (rng.integers(1, 7, size=(B, 1), dtype=np.int64) * 2)
        idx = np.arange(S + 1, dtype=np.int64)[None, :]
        toks = (base + mult * idx) % V
        # inject motif repeats (content-based predictability)
        motif_len = min(8, S // 4) or 1
        motif = rng.integers(0, V, size=(B, motif_len), dtype=np.int64)
        pos = rng.integers(0, max(1, S - 2 * motif_len), size=(B,))
        for b in range(B):
            toks[b, pos[b]:pos[b] + motif_len] = motif[b]
            toks[b, pos[b] + motif_len:pos[b] + 2 * motif_len] = motif[b]
        out = {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
        for k, shape in self.extras.items():
            out[k] = rng.standard_normal((B, *shape)).astype(np.float32) * 0.02
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread device prefetch with a bounded queue — overlaps host
    data generation/transfer with device compute (paper Fig. 6 overlap)."""

    def __init__(self, it: Iterator, sharding=None, depth: int = 2):
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.sharding = sharding
        self._stop = threading.Event()

        def work():
            for batch in it:
                if self._stop.is_set():
                    return
                if sharding is not None:
                    batch = {k: jax.device_put(v, sharding.get(k))
                             for k, v in batch.items()}
                else:
                    batch = {k: jnp.asarray(v) for k, v in batch.items()}
                self.q.put(batch)

        self.t = threading.Thread(target=work, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
