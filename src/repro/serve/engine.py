"""Continuous-batching decode engine over the paged cache pool
(docs/DESIGN.md §10).

The engine owns a fixed set of decode **slots** (the jit batch dimension)
and a :class:`repro.serve.cache.CachePool`.  Each tick it

1. **admits** queued requests whose arrival time has passed, one slot
   each, while the pool's admission gate says their prompt blocks fit —
   an admission runs a single-sequence prefill through the slot's block
   table and samples the first token;
2. runs one **decode step** over ALL slots at once — inactive slots
   carry token 0 / length 0, their K/V writes land in the reserved null
   block and their logits are ignored, so admission and completion never
   change the jitted shapes (**slot padding**: the decode function is
   traced once for ``[slots, 1]`` and never again);
3. **finishes** sequences on EOS or their per-request token budget,
   freeing their blocks so the next queued prompt can be admitted.

Out-of-blocks mid-decode triggers the **eviction protocol**: the
youngest running sequence is preempted — its blocks are freed and its
request is requeued to restart from the prompt.  Greedy decode is
deterministic, so a preempted sequence's final tokens are identical to
an uninterrupted run; for stochastic sampling the per-request PRNG is
folded from (seed, request id, step index), which restores the same
draws on re-run.

Prefill shapes: attention-family prompts are right-padded to the next
multiple of the pool block size (padded positions write into the leased
tail or the null block and stay masked — bounded retraces, one per
distinct block count).  SSM and hybrid prompts run at their exact length
because padding a recurrence would corrupt the carried conv/SSD state
(one retrace per distinct prompt length in the trace).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ParallelConfig, RunConfig
from repro.serve import step as SRV
from repro.serve.cache import CachePool, PoolConfig, blocks_for


@dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [plen] int32 token ids
    max_new: int                # generation budget (includes the EOS token)
    arrival: int = 0            # tick at which the request becomes visible


@dataclass
class Finished:
    rid: int
    prompt_len: int
    tokens: List[int]           # generated ids (EOS included when hit)
    reason: str                 # "eos" | "max_new"
    preemptions: int = 0


@dataclass
class _Running:
    req: Request
    slot: int
    admit_seq: int              # monotone admission counter (eviction order)
    pending: int                # next input token id
    generated: List[int] = field(default_factory=list)
    preemptions: int = 0


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, pcfg: ParallelConfig, rc: RunConfig,
                 params, pool: PoolConfig, *, mesh=None,
                 compute_dtype=jnp.float32, eos_id: Optional[int] = None,
                 method: str = "greedy", temperature: float = 1.0,
                 top_p: float = 0.9, seed: int = 0,
                 prompt_pad: Optional[int] = None, quant_kv: bool = False):
        self.cfg, self.pcfg, self.rc = cfg, pcfg, rc
        self.params = params
        self.pool = CachePool(cfg, pool, dtype=compute_dtype,
                              quant_kv=quant_kv)
        self.eos_id = eos_id
        self.method, self.temperature, self.top_p = method, temperature, top_p
        self.base_key = jax.random.PRNGKey(seed)
        # fixed prefill width; None -> pad to the next block multiple
        self.prompt_pad = prompt_pad
        self.exact_prefill = cfg.family in ("ssm", "hybrid")
        self._prefill = jax.jit(SRV.build_prefill_paged(
            cfg, pcfg, mesh, compute_dtype=compute_dtype))
        self._decode = jax.jit(SRV.build_decode_step(
            cfg, pcfg, rc, mesh, compute_dtype=compute_dtype))
        self.queue: deque = deque()
        self.running: Dict[int, _Running] = {}      # slot -> state
        self.finished: Dict[int, Finished] = {}
        self.tick = 0
        self._admit_seq = 0
        self._preempt_counts: Dict[int, int] = {}
        self.stats = {"prefill_s": [], "decode_ticks": 0, "decode_tokens": 0,
                      "decode_s": 0.0, "preemptions": 0}

    # -- submission ------------------------------------------------------
    def submit(self, req: Request) -> None:
        total = len(req.prompt) + req.max_new
        if total > self.pool.pool.max_seq:
            raise ValueError(f"request {req.rid}: prompt+max_new={total} "
                             f"exceeds max_seq={self.pool.pool.max_seq}")
        if blocks_for(total, self.pool.pool.block) > self.pool.pool.leasable_blocks:
            raise ValueError(f"request {req.rid}: needs more blocks than the "
                             "pool owns — it could never finish")
        self.queue.append(req)

    def warmup(self, prompt_lens=(1,)) -> None:
        """Trace both jitted functions before timing starts.

        ``prompt_lens``: prompt lengths expected in the trace — each
        distinct padded prefill width compiles once here instead of
        inside the first timed admission.  Safe against the live pool:
        warmup leases a slot, prefills, and frees it — block reuse is
        safe because reads are masked by each slot's committed length."""
        for plen_i in sorted(set(int(p) for p in prompt_lens)):
            slot = self.pool.admit(plen_i)
            assert slot is not None, "warmup needs an idle pool"
            tokens, plen = self._pad_prompt(np.zeros(plen_i, np.int32))
            last, tree = self._prefill(self.params,
                                       self.pool.prefill_tree(slot),
                                       tokens, plen)
            self.pool.absorb_prefill(slot, tree)
            self.pool.free_slot(slot)
        logits, tree = self._decode(self.params, self.pool.decode_tree(),
                                    jnp.zeros((self.pool.pool.slots, 1), jnp.int32),
                                    jnp.zeros((self.pool.pool.slots, 1), jnp.int32))
        self.pool.absorb_decode(tree)
        jax.block_until_ready(logits)
        self.pool.peak_blocks_in_use = 0            # warmup doesn't count

    # -- internals -------------------------------------------------------
    def _pad_prompt(self, prompt: np.ndarray):
        plen = len(prompt)
        if self.exact_prefill:
            pad = plen
        elif self.prompt_pad is not None:
            pad = self.prompt_pad
        else:
            bs = self.pool.pool.block
            pad = blocks_for(plen, bs) * bs
        assert pad >= plen, (pad, plen)
        buf = np.zeros(pad, np.int32)
        buf[:plen] = prompt
        return jnp.asarray(buf)[None, :], jnp.int32(plen)

    def _sample_key(self, rid: int, step: int):
        if self.method == "greedy":
            return None
        return jax.random.fold_in(jax.random.fold_in(self.base_key, rid), step)

    def _sample_one(self, logits_row, rid: int, step: int) -> int:
        tok = SRV.sample(logits_row, method=self.method,
                         key=self._sample_key(rid, step),
                         temperature=self.temperature, top_p=self.top_p)
        return int(np.asarray(tok).reshape(-1)[0])

    def _finish(self, slot: int, reason: str) -> None:
        st = self.running.pop(slot)
        self.pool.free_slot(slot)
        self.finished[st.req.rid] = Finished(
            st.req.rid, len(st.req.prompt), list(st.generated), reason,
            self._preempt_counts.get(st.req.rid, 0))

    def _record_token(self, st: _Running, tok: int) -> bool:
        """Append a sampled token; True if the sequence is done."""
        st.generated.append(tok)
        if self.eos_id is not None and tok == self.eos_id:
            self._finish(st.slot, "eos")
            return True
        if len(st.generated) >= st.req.max_new:
            self._finish(st.slot, "max_new")
            return True
        st.pending = tok
        return False

    def _admit_ready(self) -> None:
        while self.queue and self.queue[0].arrival <= self.tick:
            req = self.queue[0]
            slot = self.pool.admit(len(req.prompt))
            if slot is None:
                return
            self.queue.popleft()
            t0 = time.perf_counter()
            tokens, plen = self._pad_prompt(np.asarray(req.prompt, np.int32))
            last, tree = self._prefill(self.params,
                                       self.pool.prefill_tree(slot),
                                       tokens, plen)
            last = jax.block_until_ready(last)
            self.stats["prefill_s"].append(time.perf_counter() - t0)
            self.pool.absorb_prefill(slot, tree)
            self.pool.commit_prefill(slot, len(req.prompt))
            st = _Running(req, slot, self._admit_seq, pending=-1)
            self._admit_seq += 1
            self.running[slot] = st
            self._record_token(st, self._sample_one(last[0, 0], req.rid, 0))

    def _evict_youngest(self) -> None:
        slot = max(self.running, key=lambda s: self.running[s].admit_seq)
        st = self.running.pop(slot)
        self.pool.free_slot(slot)
        st.req.arrival = self.tick          # requeue: restart from the prompt
        self.queue.appendleft(st.req)
        self.stats["preemptions"] += 1
        self._preempt_counts[st.req.rid] = \
            self._preempt_counts.get(st.req.rid, 0) + 1

    def _ensure_appends(self) -> None:
        for slot in sorted(self.running, key=lambda s: self.running[s].admit_seq):
            while slot in self.running and not self.pool.ensure_append(slot):
                if len(self.running) == 1:
                    raise RuntimeError("pool exhausted with one sequence "
                                       "running — submit() sizing bug")
                self._evict_youngest()

    def _decode_tick(self) -> None:
        self._ensure_appends()
        if not self.running:
            return
        S = self.pool.pool.slots
        tokens = np.zeros((S, 1), np.int32)
        for slot, st in self.running.items():
            tokens[slot, 0] = st.pending
        positions = np.asarray(self.pool.lengths, np.int32)[:, None]
        t0 = time.perf_counter()
        logits, tree = self._decode(self.params, self.pool.decode_tree(),
                                    jnp.asarray(tokens), jnp.asarray(positions))
        logits = jax.block_until_ready(logits)
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["decode_ticks"] += 1
        self.pool.absorb_decode(tree)
        logits_h = np.asarray(logits)
        for slot in list(self.running):
            st = self.running[slot]
            self.pool.advance(slot)
            self.stats["decode_tokens"] += 1
            tok = self._sample_one(logits_h[slot, 0], st.req.rid,
                                   len(st.generated))
            self._record_token(st, tok)

    # -- driving ---------------------------------------------------------
    def step(self) -> None:
        """One engine tick: admit what fits, then decode every slot once."""
        self._admit_ready()
        self._decode_tick()
        self.tick += 1

    def run(self, requests: List[Request]) -> Dict[int, Finished]:
        """Drive a whole arrival trace to completion."""
        for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            self.submit(r)
        while self.queue or self.running:
            self.step()
        return self.finished
