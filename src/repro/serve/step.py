"""Serving step builders: prefill (build KV/SSM caches from a prompt batch) and
decode (one token against a filled cache).

Decode runs the 1D-TP layout over the combined model axes (docs/DESIGN.md §4
— the paper's Alg. 1 token-scatter needs >= sqrt(N) tokens/step and targets
training); prefill reuses the full Hecaton dataflow since it is
forward-pass-shaped.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig, ParallelConfig, RunConfig
from repro.models import attention as ATT
from repro.models import lm
from repro.parallel import sharding as shd
from repro.parallel.context import PCtx


def build_prefill(cfg: ModelConfig, pcfg: ParallelConfig, rc: RunConfig, mesh,
                  *, compute_dtype=jnp.bfloat16):
    pctx = PCtx(mesh, pcfg, "prefill")

    def prefill(params, batch):
        B = batch["tokens"].shape[0]
        caches = lm.init_caches(cfg, B, rc.seq_len, compute_dtype)
        if cfg.is_encdec:
            # encode once; cache per-layer cross K/V for decode
            enc_pctx = pctx
            frames = batch["frames"].astype(compute_dtype)
            Fl = frames.shape[1]
            fpos = jnp.broadcast_to(jnp.arange(Fl, dtype=jnp.int32)[None],
                                    (B, Fl))
            mem = enc_pctx.canon(frames)
            layout = enc_pctx.attn_layout(cfg.num_heads, B)
            mem, _, _ = lm._scan_attn_stack(
                enc_pctx, cfg, params["encoder"], mem, positions=fpos,
                layout=layout, causal=cfg.encoder_is_causal, caches=None,
                memory=None, remat="none")
            mem = enc_pctx.norm(cfg.norm_kind, params["enc_norm"], mem)

            def per_layer_kv(p_l):
                return ATT.cross_kv(enc_pctx, cfg, p_l["xattn"], mem)

            caches["cross"] = jax.lax.map(
                lambda p_l: per_layer_kv(p_l), params["blocks"])
        mb = dict(batch)
        mb["_dtype"] = compute_dtype
        out = lm.forward(pctx, cfg, params, mb, caches=caches)
        return out.logits[:, -1:], out.caches

    return prefill


def build_prefill_paged(cfg: ModelConfig, pcfg: ParallelConfig, mesh, *,
                        compute_dtype=jnp.bfloat16):
    """Prefill one admitted sequence into a paged cache tree.

    Unlike :func:`build_prefill`, the caches come in as an argument (the
    pool's ``prefill_tree``) so the new tokens are written through the
    slot's block table (docs/DESIGN.md §10).  ``tokens`` is ``[1, P]``
    where P may exceed the true prompt length (fixed-shape padding for
    attention-family archs); ``length`` is the true prompt length and
    selects the logits row — padded tail positions write into the leased
    tail / null block and are masked by the per-slot lengths until real
    decode tokens overwrite them.
    """
    pctx = PCtx(mesh, pcfg, "prefill")

    def prefill(params, caches, tokens, length):
        B, S = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        mb = {"tokens": tokens, "positions": pos, "_dtype": compute_dtype}
        out = lm.forward(pctx, cfg, params, mb, caches=caches)
        last = jax.lax.dynamic_slice_in_dim(
            out.logits, jnp.maximum(length - 1, 0), 1, axis=1)
        return last, out.caches

    return prefill


def build_decode_step(cfg: ModelConfig, pcfg: ParallelConfig, rc: RunConfig,
                      mesh, *, compute_dtype=jnp.bfloat16):
    """One-token decode against a filled cache tree.

    The cache tree decides the layout: dense ``KVCache``/``MLACache``
    leaves take the classic dynamic-update path, ``PagedKVCache``/
    ``PagedMLACache`` leaves write/gather through their block tables —
    the step function itself is layout-agnostic.
    """
    pctx = PCtx(mesh, pcfg, "decode")

    def decode_step(params, caches, tokens, positions):
        """tokens [B,1]; positions [B,1] absolute positions of the new token."""
        mb = {"tokens": tokens, "positions": positions, "_dtype": compute_dtype}
        out = lm.forward(pctx, cfg, params, mb, caches=caches)
        return out.logits, out.caches

    return decode_step


# ---------------------------------------------------------------------------
# sampling — the single serve-path entry point
# ---------------------------------------------------------------------------

def greedy_sample(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(logits, key, temperature: float = 1.0):
    """Categorical sample from temperature-scaled logits (fp32 softmax)."""
    lf = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    return jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)


def top_p_sample(logits, key, top_p: float = 0.9, temperature: float = 1.0):
    """Nucleus sampling: keep the smallest prefix of the descending-sorted
    distribution whose cumulative mass reaches ``top_p``, renormalize,
    sample, and map back through the sort permutation."""
    lf = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    sort_idx = jnp.argsort(-lf, axis=-1)
    sorted_lf = jnp.take_along_axis(lf, sort_idx, axis=-1)
    probs = jax.nn.softmax(sorted_lf, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep tokens whose preceding cumulative mass is < top_p (always >= 1 kept)
    keep = (cum - probs) < top_p
    masked = jnp.where(keep, sorted_lf, -jnp.inf)
    choice = jax.random.categorical(key, masked, axis=-1)
    return jnp.take_along_axis(
        sort_idx, choice[..., None], axis=-1)[..., 0].astype(jnp.int32)


def sample(logits, *, method: str = "greedy", key=None, temperature: float = 1.0,
           top_p: float = 0.9):
    """Unified sampling entry point for every serve path (greedy /
    temperature / top-p).  ``logits`` is ``[..., V]``; returns int32 ids
    with the leading shape."""
    if method == "greedy":
        return greedy_sample(logits)
    if key is None:
        raise ValueError(f"sampling method {method!r} needs a PRNG key")
    if method == "temperature":
        return temperature_sample(logits, key, temperature)
    if method == "top_p":
        return top_p_sample(logits, key, top_p, temperature)
    raise ValueError(f"unknown sampling method {method!r}")


# ---------------------------------------------------------------------------
# cache sharding specs
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, pcfg: ParallelConfig, mesh, batch: int):
    """Spec tree for stacked decode caches.

    KV: [L, B, S, nkv, dh] — batch over data axes, kv-heads over a model axis
    where divisible (solver), else batch absorbs the model axes.
    SSM states: [L, B, nh, dh, state] similarly.
    """
    if mesh is None:
        return None
    ax = shd.axis_info(mesh, pcfg.strategy)
    caches = jax.eval_shape(lambda: lm.init_caches(cfg, batch, 8, jnp.bfloat16))

    def kv_layout(n_heads):
        return shd.solve_attn_layout(ax, n_heads, max(1, batch // ax.n_data))

    def bspec(lay):
        # batch=1 cells (long_500k): the data axis is idle; don't shard B.
        if batch % ax.n_data:
            return None
        return shd._one(lay.batch_axes)

    def data_b():
        # no-head-axis leaves (MLA latents, conv states): shard B over the
        # data axes only — never absorb model axes a head leaf can't match
        if batch % ax.n_data:
            return None
        return shd._one(ax.data_axes)

    def f(kp, leaf):
        names = [getattr(k, "key", getattr(k, "name", None)) for k in kp]
        rank = len(leaf.shape)
        if "attn" in names or "cross" in names:
            if rank == 5:     # [L,B,S,nkv,dh]
                # head count from the leaf ITSELF, not cfg: the solver must
                # see exactly the nkv axis init_kv_cache built (GQA/MQA), or
                # the spec tree silently mis-shards the cache
                lay = kv_layout(leaf.shape[3])
                return P(None, bspec(lay), None, shd._one(lay.head_axes), None)
            if rank == 4:     # MLA c_kv [L,B,S,lora]
                return P(None, data_b(), None, None)
            if rank == 3:     # MLA k_rope [L,B,S] collapsed or lengths
                return P(None, data_b(), None)
            return P()
        if "mamba" in names:
            if rank == 5:     # ssm state [L,B,nh,dh,state]
                lay = kv_layout(leaf.shape[2])
                return P(None, bspec(lay), shd._one(lay.head_axes), None, None)
            if rank == 4:     # conv state [L,B,K-1,C]
                return P(None, data_b(), None, None)
            return P()
        return P()

    return jax.tree_util.tree_map_with_path(f, caches)
