"""Serving step builders: prefill (build KV/SSM caches from a prompt batch) and
decode (one token against a filled cache).

Decode runs the 1D-TP layout over the combined model axes (docs/DESIGN.md §4
— the paper's Alg. 1 token-scatter needs >= sqrt(N) tokens/step and targets
training); prefill reuses the full Hecaton dataflow since it is
forward-pass-shaped.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig, ParallelConfig, RunConfig
from repro.models import attention as ATT
from repro.models import lm
from repro.parallel import sharding as shd
from repro.parallel.context import PCtx


def build_prefill(cfg: ModelConfig, pcfg: ParallelConfig, rc: RunConfig, mesh,
                  *, compute_dtype=jnp.bfloat16):
    pctx = PCtx(mesh, pcfg, "prefill")

    def prefill(params, batch):
        B = batch["tokens"].shape[0]
        caches = lm.init_caches(cfg, B, rc.seq_len, compute_dtype)
        if cfg.is_encdec:
            # encode once; cache per-layer cross K/V for decode
            enc_pctx = pctx
            frames = batch["frames"].astype(compute_dtype)
            Fl = frames.shape[1]
            fpos = jnp.broadcast_to(jnp.arange(Fl, dtype=jnp.int32)[None],
                                    (B, Fl))
            mem = enc_pctx.canon(frames)
            layout = enc_pctx.attn_layout(cfg.num_heads, B)
            mem, _, _ = lm._scan_attn_stack(
                enc_pctx, cfg, params["encoder"], mem, positions=fpos,
                layout=layout, causal=cfg.encoder_is_causal, caches=None,
                memory=None, remat="none")
            mem = enc_pctx.norm(cfg.norm_kind, params["enc_norm"], mem)

            def per_layer_kv(p_l):
                return ATT.cross_kv(enc_pctx, cfg, p_l["xattn"], mem)

            caches["cross"] = jax.lax.map(
                lambda p_l: per_layer_kv(p_l), params["blocks"])
        mb = dict(batch)
        mb["_dtype"] = compute_dtype
        out = lm.forward(pctx, cfg, params, mb, caches=caches)
        return out.logits[:, -1:], out.caches

    return prefill


def build_decode_step(cfg: ModelConfig, pcfg: ParallelConfig, rc: RunConfig,
                      mesh, *, compute_dtype=jnp.bfloat16):
    pctx = PCtx(mesh, pcfg, "decode")

    def decode_step(params, caches, tokens, positions):
        """tokens [B,1]; positions [B,1] absolute positions of the new token."""
        mb = {"tokens": tokens, "positions": positions, "_dtype": compute_dtype}
        out = lm.forward(pctx, cfg, params, mb, caches=caches)
        return out.logits, out.caches

    return decode_step


def greedy_sample(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# cache sharding specs
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, pcfg: ParallelConfig, mesh, batch: int):
    """Spec tree for stacked decode caches.

    KV: [L, B, S, nkv, dh] — batch over data axes, kv-heads over a model axis
    where divisible (solver), else batch absorbs the model axes.
    SSM states: [L, B, nh, dh, state] similarly.
    """
    if mesh is None:
        return None
    ax = shd.axis_info(mesh, pcfg.strategy)
    caches = jax.eval_shape(lambda: lm.init_caches(cfg, batch, 8, jnp.bfloat16))

    def kv_layout(n_heads):
        return shd.solve_attn_layout(ax, n_heads, max(1, batch // ax.n_data))

    def bspec(lay):
        # batch=1 cells (long_500k): the data axis is idle; don't shard B.
        if batch % ax.n_data:
            return None
        return shd._one(lay.batch_axes)

    def f(kp, leaf):
        names = [getattr(k, "key", getattr(k, "name", None)) for k in kp]
        rank = len(leaf.shape)
        if "attn" in names or "cross" in names:
            lay = kv_layout(cfg.num_kv_heads if cfg.num_kv_heads else 1)
            b = bspec(lay)
            h = shd._one(lay.head_axes)
            if rank == 5:     # [L,B,S,nkv,dh]
                return P(None, b, None, h, None)
            if rank == 4:     # MLA [L,B,S,lora]
                return P(None, b, None, None)
            if rank == 3:     # MLA k_rope [L,B,S,dr] collapsed or lengths
                return P(None, b, None)
            return P()
        if "mamba" in names:
            from repro.models import ssm as SSM
            lay = kv_layout(SSM.n_heads(cfg))
            b = bspec(lay)
            h = shd._one(lay.head_axes)
            if rank == 5:     # ssm state [L,B,nh,dh,state]
                return P(None, b, h, None, None)
            if rank == 4:     # conv state [L,B,K-1,C]
                return P(None, b, None, None)
            return P()
        return P()

    return jax.tree_util.tree_map_with_path(f, caches)
