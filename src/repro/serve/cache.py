"""Cache manager for the decode service: dense layout factory + the paged
block-pool (docs/DESIGN.md §10).

Cache *layout* lives here, factored out of ``lm.init_caches`` (which now
delegates to :func:`init_dense` so the training-side dense path is
unchanged).  Two layouts exist:

* **dense** — the classic per-sequence arena: every cache leaf is
  ``[L, B, S_max, ...]``, so each sequence pays ``S_max`` tokens of KV
  memory up front regardless of its actual length.  Training/eval tests
  and the multi-device ``cache_specs`` sharding path keep using this.

* **paged** — one shared arena of fixed-size blocks
  (``[L, num_blocks, block, ...]``) that sequences of different lengths
  lease on demand through a per-slot **block table**
  (``[slots, max_blocks]`` int32).  Block id 0 is the reserved *null
  block*: it backs every unleased table entry, so writes from padded
  prompt positions or inactive decode slots land in trash instead of a
  neighbour's lease, and gathered reads past a slot's length are masked
  to exact-zero softmax weight by the per-slot ``lengths``
  (models/attention.py ``paged_write`` / ``paged_gather``).  With
  ``quant_kv=True`` the payload arenas hold per-row symmetric int8 plus a
  trailing-1 fp32 scale arena (docs/DESIGN.md §11) — written through
  ``quant_paged_write`` and dequantized at gather time; the dense-dtype
  arena path is byte-identical to before.

:class:`CachePool` is the host-side manager: it owns the device arenas,
the free-block list, and the per-slot accounting, and exposes the
allocate / append / free protocol the engine drives:

* ``admit(prompt_len)`` — the **admission gate**: a prompt is admitted
  only when a slot is free AND the free list covers its prompt blocks
  (``ceil(prompt_len / block)``); otherwise the request stays queued.
  Admission leases prompt blocks only — generated tokens lease lazily.
* ``ensure_append(slot)`` — before a decode tick, lease the block that
  will hold position ``lengths[slot]`` if the slot's current lease does
  not cover it.  Returns False when the pool is exhausted — the engine's
  **eviction protocol** then preempts the youngest running sequence
  (frees its lease, requeues its request for a deterministic greedy
  re-run) until the append fits.
* ``free_slot(slot)`` — return the lease to the free list (EOS/max-len).

SSM recurrent states (mamba / hybrid) are O(1) per sequence, so they are
pooled per-slot rather than block-paged: the pool holds ``[L, slots, ...]``
state arenas and re-zeroes a slot's row on admission via the prefill
scatter.  Peak ``blocks_in_use`` is tracked so benchmarks can compare the
paged pool against the dense ``slots * ceil(max_seq/block)`` arena
equivalent.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import attention as ATT
from repro.models import ssm as SSM


# ---------------------------------------------------------------------------
# dense layout (factored out of lm.init_caches)
# ---------------------------------------------------------------------------

def init_dense(cfg: ModelConfig, batch: int, s_max: int, dtype):
    """Stacked per-layer dense decode caches ([L, B, S_max, ...] leaves).

    The pre-pool ``lm.init_caches`` layout, verbatim — training-side tests
    and multi-device serving keep this path."""
    fam = cfg.family
    if fam == "ssm":
        st = SSM.init_ssm_state(cfg, batch, dtype)
        return {"mamba": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)), st)}
    if fam == "hybrid":
        st = SSM.init_ssm_state(cfg, batch, dtype)
        n_apps = cfg.num_layers // max(1, cfg.shared_attn_every)
        kv = ATT.init_kv_cache(cfg, batch, s_max, dtype)
        return {
            "mamba": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)), st),
            "attn": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_apps, *a.shape)), kv),
        }
    mk = (lambda: ATT.init_mla_cache(cfg, batch, s_max, dtype)) if cfg.mla \
        else (lambda: ATT.init_kv_cache(cfg, batch, s_max, dtype))
    c = mk()
    out = {"attn": jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)), c)}
    if cfg.is_encdec:
        dh = cfg.resolved_head_dim
        F = cfg.frontend_stub_len
        out["cross"] = (jnp.zeros((cfg.num_layers, batch, F,
                                   cfg.num_kv_heads, dh), dtype),
                        jnp.zeros((cfg.num_layers, batch, F,
                                   cfg.num_kv_heads, dh), dtype))
    return out


def dense_cache_bytes(cfg: ModelConfig, batch: int, s_max: int, dtype) -> int:
    """Total bytes of the dense [L,B,S_max,...] cache arena."""
    tree = jax.eval_shape(lambda: init_dense(cfg, batch, s_max, dtype))
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# paged pool
# ---------------------------------------------------------------------------

NULL_BLOCK = 0           # reserved trash block backing unleased table entries


@dataclass(frozen=True)
class PoolConfig:
    """Shape of the paged pool.

    ``slots`` is the fixed decode batch (jit shape — admission pads into
    it, never resizes it); ``block`` is tokens per block; ``num_blocks``
    is the arena capacity INCLUDING the reserved null block; ``max_seq``
    caps prompt + generated tokens per sequence and sizes the block
    table's width."""
    slots: int
    block: int
    num_blocks: int
    max_seq: int

    def __post_init__(self):
        assert self.slots >= 1, self.slots
        assert self.block >= 1, self.block
        assert self.max_seq >= 1, self.max_seq
        assert self.num_blocks >= 2, (
            f"num_blocks={self.num_blocks}: need the null block + >= 1 "
            "leasable block")

    @property
    def max_blocks_per_slot(self) -> int:
        return -(-self.max_seq // self.block)

    @property
    def leasable_blocks(self) -> int:
        return self.num_blocks - 1          # block 0 is never leased

    @property
    def dense_equiv_blocks(self) -> int:
        """Blocks a dense [slots, max_seq] arena would pin up front."""
        return self.slots * self.max_blocks_per_slot


def blocks_for(tokens: int, block: int) -> int:
    return max(1, -(-tokens // block))


class CachePool:
    """Host-side paged cache manager: device arenas + block accounting.

    The device side is a dict of layer-stacked arena leaves (attention
    K/V or MLA latents paged over blocks; SSM states per-slot).  The
    pytrees handed to the jitted prefill/decode steps are assembled per
    call from the arenas plus the CURRENT host block table / lengths
    (``decode_tree`` / ``prefill_tree``), and the updated arenas are
    absorbed back afterwards — the host copy of table/lengths is always
    authoritative."""

    def __init__(self, cfg: ModelConfig, pool: PoolConfig, dtype=jnp.float32,
                 quant_kv: bool = False):
        if cfg.is_encdec:
            raise NotImplementedError(
                "paged pool: enc-dec cross caches are per-prompt dense; "
                "use the dense serving path for audio archs")
        self.cfg, self.pool, self.dtype = cfg, pool, dtype
        self.quant_kv = bool(quant_kv)
        fam = cfg.family
        mb = pool.max_blocks_per_slot
        self.arenas: Dict[str, Any] = {}
        self.states: Dict[str, Any] = {}
        if fam in ("ssm", "hybrid"):
            st = SSM.init_ssm_state(cfg, pool.slots, dtype)
            self.states["mamba"] = jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (cfg.num_layers, *a.shape)).copy(), st)
        if fam != "ssm":
            n_app = (cfg.num_layers // max(1, cfg.shared_attn_every)
                     if fam == "hybrid" else cfg.num_layers)
            if self.quant_kv:
                # int8 payload + fp32 per-row scale arenas (DESIGN §11);
                # the dense-dtype path below is untouched
                mk = (ATT.init_paged_mla_quant if cfg.mla
                      else ATT.init_paged_kv_quant)
            else:
                mk = ATT.init_paged_mla if cfg.mla else ATT.init_paged_kv
            paged = mk(cfg, pool.num_blocks, pool.block, pool.slots, mb, dtype)
            # arenas only — table/lengths leaves are rebuilt per call
            self.arenas["attn"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_app, *a.shape)).copy(),
                self._arena_leaves(paged))
        # host accounting
        self.table = np.zeros((pool.slots, mb), np.int32)
        self.lengths = np.zeros(pool.slots, np.int32)
        self.active = np.zeros(pool.slots, bool)
        self.free: List[int] = list(range(1, pool.num_blocks))
        self.owned: List[List[int]] = [[] for _ in range(pool.slots)]
        self.peak_blocks_in_use = 0

    # -- accounting ------------------------------------------------------
    @property
    def blocks_in_use(self) -> int:
        return self.pool.leasable_blocks - len(self.free)

    @property
    def free_slots(self) -> List[int]:
        return [s for s in range(self.pool.slots) if not self.active[s]]

    def _lease(self, slot: int) -> bool:
        if not self.free:
            return False
        b = self.free.pop()
        self.owned[slot].append(b)
        self.table[slot, len(self.owned[slot]) - 1] = b
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.blocks_in_use)
        return True

    def can_admit(self, prompt_len: int) -> bool:
        return (prompt_len <= self.pool.max_seq
                and bool(self.free_slots)
                and len(self.free) >= blocks_for(prompt_len, self.pool.block))

    def admit(self, prompt_len: int) -> Optional[int]:
        """Admission gate: lease prompt blocks into a free slot, or None."""
        if not self.can_admit(prompt_len):
            return None
        slot = self.free_slots[0]
        for _ in range(blocks_for(prompt_len, self.pool.block)):
            ok = self._lease(slot)
            assert ok, "can_admit checked the free list"
        self.active[slot] = True
        self.lengths[slot] = 0              # prefill commits the real length
        return slot

    def commit_prefill(self, slot: int, prompt_len: int) -> None:
        assert self.active[slot]
        self.lengths[slot] = prompt_len

    def ensure_append(self, slot: int) -> bool:
        """Lease the block holding position ``lengths[slot]`` if missing.

        False = out of blocks (caller runs the eviction protocol) or the
        slot hit ``max_seq`` (caller must have finished it already)."""
        need = self.lengths[slot] // self.pool.block + 1
        if need > self.pool.max_blocks_per_slot:
            return False
        while len(self.owned[slot]) < need:
            if not self._lease(slot):
                return False
        return True

    def advance(self, slot: int) -> None:
        self.lengths[slot] += 1

    def free_slot(self, slot: int) -> None:
        for b in self.owned[slot]:
            self.free.append(b)
        self.owned[slot] = []
        self.table[slot] = NULL_BLOCK
        self.lengths[slot] = 0
        self.active[slot] = False

    # -- device tree assembly -------------------------------------------
    def _arena_leaves(self, cache) -> tuple:
        """The arena leaves of a paged cache NamedTuple, in the positional
        order its constructor expects (table/lengths excluded)."""
        if self.quant_kv:
            return ((cache.c_kv, cache.c_scale, cache.k_rope, cache.r_scale)
                    if self.cfg.mla
                    else (cache.k, cache.k_scale, cache.v, cache.v_scale))
        return ((cache.c_kv, cache.k_rope) if self.cfg.mla
                else (cache.k, cache.v))

    def _paged(self, arenas, table_rows, lengths_rows):
        """Assemble the paged cache NamedTuple with table/lengths broadcast
        over the layer axis (scan xs need a leading layer dim)."""
        n_app = jax.tree.leaves(arenas)[0].shape[0]
        B = table_rows.shape[0]
        bt = jnp.broadcast_to(jnp.asarray(table_rows, jnp.int32),
                              (n_app, B, table_rows.shape[1]))
        ln = jnp.broadcast_to(jnp.asarray(lengths_rows, jnp.int32), (n_app, B))
        if self.quant_kv:
            klass = (ATT.QuantPagedMLACache if self.cfg.mla
                     else ATT.QuantPagedKVCache)
        else:
            klass = ATT.PagedMLACache if self.cfg.mla else ATT.PagedKVCache
        return klass(*arenas, bt, ln)

    def decode_tree(self):
        """Cache pytree for one decode tick over all ``slots`` rows."""
        out: Dict[str, Any] = {}
        if "attn" in self.arenas:
            out["attn"] = self._paged(self.arenas["attn"], self.table,
                                      self.lengths)
        if "mamba" in self.states:
            out["mamba"] = self.states["mamba"]
        return out

    def prefill_tree(self, slot: int):
        """Cache pytree for a single-slot prefill (batch 1, length 0)."""
        out: Dict[str, Any] = {}
        if "attn" in self.arenas:
            out["attn"] = self._paged(self.arenas["attn"],
                                      self.table[slot:slot + 1],
                                      np.zeros(1, np.int32))
        if "mamba" in self.states:
            out["mamba"] = jax.tree.map(
                lambda a: jnp.zeros((a.shape[0], 1, *a.shape[2:]), a.dtype),
                self.states["mamba"])
        return out

    def absorb_prefill(self, slot: int, new_tree) -> None:
        """Store a prefill's updated arenas; scatter its SSM state row."""
        if "attn" in self.arenas:
            self.arenas["attn"] = self._arena_leaves(new_tree["attn"])
        if "mamba" in self.states:
            self.states["mamba"] = jax.tree.map(
                lambda full, one: full.at[:, slot].set(one[:, 0]),
                self.states["mamba"], new_tree["mamba"])

    def absorb_decode(self, new_tree) -> None:
        if "attn" in self.arenas:
            self.arenas["attn"] = self._arena_leaves(new_tree["attn"])
        if "mamba" in self.states:
            self.states["mamba"] = new_tree["mamba"]

    # -- reporting -------------------------------------------------------
    @property
    def block_bytes(self) -> int:
        """Bytes one leased block pins across all layers' paged arenas."""
        # arena leaf shape: [n_app, num_blocks, block, ...]
        per_block = 0
        for leaf in jax.tree.leaves(self.arenas):
            n_app, _, block = leaf.shape[0], leaf.shape[1], leaf.shape[2]
            per_block += (n_app * block * int(np.prod(leaf.shape[3:]))
                          * leaf.dtype.itemsize)
        return per_block

    def paged_bytes_in_use(self) -> int:
        """Bytes of currently leased (non-null) blocks."""
        return self.block_bytes * self.blocks_in_use

    def paged_bytes_peak(self) -> int:
        """Bytes leased at the pool's high-water mark."""
        return self.block_bytes * self.peak_blocks_in_use
