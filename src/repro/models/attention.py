"""Attention mixers: GQA/MQA (qwen/nemotron/granite/grok/...), MLA (minicpm3),
cross-attention (whisper).  All projections route through PCtx so the Hecaton
§IV-C dataflow (sequence gathered, heads sharded, AG/RS only) applies uniformly.

Long sequences use a q-block-chunked softmax (``lax.scan``) so the [S,S] score
matrix is never materialized — the jnp analogue of kernels/flash_attention.py.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.core import quant as QU
from repro.models import layers as L

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_attn(cfg: ModelConfig, key, cross: bool = False):
    dh = cfg.resolved_head_dim
    nh, nkv, H = cfg.num_heads, cfg.num_kv_heads, cfg.d_model
    ks = jax.random.split(key, 8)
    p = {
        "wq": L.normal_init(ks[0], (H, nh * dh)),
        "wk": L.normal_init(ks[1], (H, nkv * dh)),
        "wv": L.normal_init(ks[2], (H, nkv * dh)),
        "wo": L.normal_init(ks[3], (nh * dh, H), scale=1.0 / (nh * dh) ** 0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), jnp.float32)
        p["k_norm"] = jnp.ones((dh,), jnp.float32)
    return p


def init_mla(cfg: ModelConfig, key):
    m = cfg.mla
    H, nh = cfg.d_model, cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": L.normal_init(ks[0], (H, m.q_lora_rank)),
        "q_norm": jnp.ones((m.q_lora_rank,), jnp.float32),
        "wq_b": L.normal_init(ks[1], (m.q_lora_rank, nh * (dn + dr))),
        "wkv_a": L.normal_init(ks[2], (H, m.kv_lora_rank + dr)),
        "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
        "wkv_b": L.normal_init(ks[3], (m.kv_lora_rank, nh * (dn + dv))),
        "wo": L.normal_init(ks[4], (nh * dv, H), scale=1.0 / (nh * dv) ** 0.5),
    }


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array          # [B, S_max, nkv, dh]
    v: jax.Array
    length: jax.Array     # [] int32 — tokens filled


class MLACache(NamedTuple):
    c_kv: jax.Array       # [B, S_max, kv_lora]
    k_rope: jax.Array     # [B, S_max, dr]
    length: jax.Array


class PagedKVCache(NamedTuple):
    """Block-paged KV cache (serving tier, docs/DESIGN.md §10).

    The arena is ONE pool of fixed-size blocks shared by every decode slot;
    slot b owns the blocks listed in ``block_table[b]`` (0 = the reserved
    null block that absorbs writes from padded/inactive slots and backs
    table entries beyond a slot's leased range).  ``lengths`` is per-slot —
    continuous batching means every row sits at a different position.
    """
    k: jax.Array            # [n_blocks, block, nkv, dh] shared arena
    v: jax.Array
    block_table: jax.Array  # [B, max_blocks] int32 block ids (0 = null)
    lengths: jax.Array      # [B] int32 tokens already written per slot


class PagedMLACache(NamedTuple):
    """Paged variant of :class:`MLACache` (same block-table protocol)."""
    c_kv: jax.Array         # [n_blocks, block, kv_lora]
    k_rope: jax.Array       # [n_blocks, block, dr]
    block_table: jax.Array  # [B, max_blocks] int32
    lengths: jax.Array      # [B] int32


class QuantPagedKVCache(NamedTuple):
    """Int8 block-paged KV arena (docs/DESIGN.md §11).

    Same block-table protocol as :class:`PagedKVCache`, but the payload
    arenas hold per-token-per-head symmetric int8 with a trailing-1 fp32
    scale arena alongside (scale = max|row| / 127 over the head dim, 1.0
    for all-zero rows so untouched blocks dequantize to exact zeros).
    Attention dequantizes into the compute dtype at gather time; the
    fp paged path is untouched when the arena is dense.
    """
    k: jax.Array            # int8 [n_blocks, block, nkv, dh]
    k_scale: jax.Array      # f32  [n_blocks, block, nkv, 1]
    v: jax.Array
    v_scale: jax.Array
    block_table: jax.Array  # [B, max_blocks] int32
    lengths: jax.Array      # [B] int32


class QuantPagedMLACache(NamedTuple):
    """Int8 paged variant of :class:`PagedMLACache` (docs/DESIGN.md §11)."""
    c_kv: jax.Array         # int8 [n_blocks, block, kv_lora]
    c_scale: jax.Array      # f32  [n_blocks, block, 1]
    k_rope: jax.Array       # int8 [n_blocks, block, dr]
    r_scale: jax.Array      # f32  [n_blocks, block, 1]
    block_table: jax.Array  # [B, max_blocks] int32
    lengths: jax.Array      # [B] int32


def init_kv_cache(cfg: ModelConfig, batch: int, s_max: int, dtype):
    dh = cfg.resolved_head_dim
    return KVCache(jnp.zeros((batch, s_max, cfg.num_kv_heads, dh), dtype),
                   jnp.zeros((batch, s_max, cfg.num_kv_heads, dh), dtype),
                   jnp.zeros((), jnp.int32))


def init_mla_cache(cfg: ModelConfig, batch: int, s_max: int, dtype):
    m = cfg.mla
    return MLACache(jnp.zeros((batch, s_max, m.kv_lora_rank), dtype),
                    jnp.zeros((batch, s_max, m.qk_rope_head_dim), dtype),
                    jnp.zeros((), jnp.int32))


def init_paged_kv(cfg: ModelConfig, num_blocks: int, block: int, batch: int,
                  max_blocks: int, dtype):
    dh = cfg.resolved_head_dim
    return PagedKVCache(
        jnp.zeros((num_blocks, block, cfg.num_kv_heads, dh), dtype),
        jnp.zeros((num_blocks, block, cfg.num_kv_heads, dh), dtype),
        jnp.zeros((batch, max_blocks), jnp.int32),
        jnp.zeros((batch,), jnp.int32))


def init_paged_mla(cfg: ModelConfig, num_blocks: int, block: int, batch: int,
                   max_blocks: int, dtype):
    m = cfg.mla
    return PagedMLACache(
        jnp.zeros((num_blocks, block, m.kv_lora_rank), dtype),
        jnp.zeros((num_blocks, block, m.qk_rope_head_dim), dtype),
        jnp.zeros((batch, max_blocks), jnp.int32),
        jnp.zeros((batch,), jnp.int32))


def _quant_arena_dtype(row_dim: int, dtype):
    """Degrade rule for arenas, mirroring the wire-side ``quant_ok`` gate:
    rows narrower than MIN_QUANT_DIM keep the dense dtype (a per-row scale
    would eat the byte win and the coarse scale hurts accuracy — DESIGN
    §11); the scale arena still exists but stays at its init value of 1.0
    and the write/gather dispatch on the arena dtype skips it."""
    return jnp.int8 if row_dim >= QU.MIN_QUANT_DIM else dtype


def init_paged_kv_quant(cfg: ModelConfig, num_blocks: int, block: int,
                        batch: int, max_blocks: int, dtype=jnp.float32):
    dh = cfg.resolved_head_dim
    nkv = cfg.num_kv_heads
    dt = _quant_arena_dtype(dh, dtype)
    return QuantPagedKVCache(
        jnp.zeros((num_blocks, block, nkv, dh), dt),
        jnp.ones((num_blocks, block, nkv, 1), jnp.float32),
        jnp.zeros((num_blocks, block, nkv, dh), dt),
        jnp.ones((num_blocks, block, nkv, 1), jnp.float32),
        jnp.zeros((batch, max_blocks), jnp.int32),
        jnp.zeros((batch,), jnp.int32))


def init_paged_mla_quant(cfg: ModelConfig, num_blocks: int, block: int,
                         batch: int, max_blocks: int, dtype=jnp.float32):
    m = cfg.mla
    return QuantPagedMLACache(
        jnp.zeros((num_blocks, block, m.kv_lora_rank),
                  _quant_arena_dtype(m.kv_lora_rank, dtype)),
        jnp.ones((num_blocks, block, 1), jnp.float32),
        jnp.zeros((num_blocks, block, m.qk_rope_head_dim),
                  _quant_arena_dtype(m.qk_rope_head_dim, dtype)),
        jnp.ones((num_blocks, block, 1), jnp.float32),
        jnp.zeros((batch, max_blocks), jnp.int32),
        jnp.zeros((batch,), jnp.int32))


def paged_write(arena, vals, block_table, lengths):
    """Scatter ``vals`` [B, S, ...] into the block arena.

    Token s of row b lands at absolute position ``lengths[b] + s``, i.e.
    block ``block_table[b, pos // block]`` offset ``pos % block``.  Positions
    past the table's leased range resolve to the null block (entry 0), so
    prompt padding and inactive decode slots write trash into block 0
    instead of corrupting a neighbour's lease; duplicate null-block indices
    scatter in unspecified order, which is fine — null-block contents are
    never read unmasked."""
    B, S = vals.shape[:2]
    block = arena.shape[1]
    pos = lengths[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    blk_slot = jnp.minimum(pos // block, block_table.shape[1] - 1)
    blk = jnp.take_along_axis(block_table, blk_slot, axis=1)       # [B,S]
    return arena.at[blk, pos % block].set(vals.astype(arena.dtype))


def paged_gather(arena, block_table):
    """Gather a slot-contiguous [B, max_blocks*block, ...] view of the pages.

    Positions beyond a slot's length read null-block / stale-lease garbage;
    every consumer masks with the per-slot ``lengths`` (exact-zero softmax
    weights — see the bit-exactness argument in docs/DESIGN.md §10)."""
    B, nblk = block_table.shape
    g = arena[block_table]                     # [B, nblk, block, ...]
    return g.reshape(B, nblk * arena.shape[1], *arena.shape[2:])


def quant_paged_write(arena, scales, vals, block_table, lengths):
    """Quantize ``vals`` [B, S, ...] per trailing-axis row and scatter the
    int8 payload and its fp32 scales at identical arena indices (same
    null-block semantics as :func:`paged_write`).  Degraded components
    (dense-dtype arena, MIN_QUANT_DIM rule) bypass quantization and leave
    the scale arena untouched."""
    if arena.dtype != jnp.int8:
        return paged_write(arena, vals, block_table, lengths), scales
    q, s = QU.quant_int8(vals)
    B, S = vals.shape[:2]
    block = arena.shape[1]
    pos = lengths[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    blk_slot = jnp.minimum(pos // block, block_table.shape[1] - 1)
    blk = jnp.take_along_axis(block_table, blk_slot, axis=1)       # [B,S]
    off = pos % block
    return arena.at[blk, off].set(q), scales.at[blk, off].set(s)


def quant_paged_gather(arena, scales, block_table, dtype):
    """Gather + dequantize the paged int8 view into ``dtype``.  The same
    lengths-masking argument as :func:`paged_gather` applies — garbage past
    a slot's length is finite (scale arenas init to 1.0) and masked out.
    Degraded (dense-dtype) components gather without dequantization."""
    if arena.dtype != jnp.int8:
        return paged_gather(arena, block_table).astype(dtype)
    B, nblk = block_table.shape
    g = QU.dequant_int8(arena[block_table], scales[block_table], dtype)
    return g.reshape(B, nblk * arena.shape[1], *arena.shape[2:])


# ---------------------------------------------------------------------------
# core attention math (chunked over q blocks)
# ---------------------------------------------------------------------------

def _sdpa(q, k, v, *, causal: bool, q_offset, kv_len=None, q_block: int = 1024):
    """q [B,Sq,nh,dh]; k,v [B,Sk,nh,dh] (kv already repeated to nh).

    Chunked over Sq: scores per block are [B,nh,q_block,Sk] — never [Sq,Sk].
    ``q_offset`` is the absolute position of q[0] (decode / prefill-continue).
    ``kv_len`` masks the unfilled cache tail.
    """
    B, Sq, nh, dh = q.shape
    Sk = k.shape[1]
    scale = dh ** -0.5
    kt = k.transpose(0, 2, 3, 1)         # [B,nh,dh,Sk]
    vt = v.transpose(0, 2, 1, 3)         # [B,nh,Sk,dh]
    kv_pos = jnp.arange(Sk)

    def block(qb, qpos):
        # qb [B,nh,bq,dh]
        s = jnp.einsum("bhqd,bhdk->bhqk", qb.astype(jnp.float32),
                       kt.astype(jnp.float32)) * scale
        mask = jnp.ones((qpos.shape[0], Sk), bool)
        if causal:
            mask &= kv_pos[None, :] <= qpos[:, None]
        if kv_len is not None:
            mask &= kv_pos[None, :] < kv_len
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", p, vt.astype(jnp.float32))

    qh = q.transpose(0, 2, 1, 3)         # [B,nh,Sq,dh]
    if Sq % q_block:                     # non-divisible (e.g. 1500 frames): direct
        q_block = Sq
    if Sq <= q_block:
        o = block(qh, q_offset + jnp.arange(Sq))
    else:
        nb = Sq // q_block
        qb = qh.reshape(B, nh, nb, q_block, dh).transpose(2, 0, 1, 3, 4)
        pos = (q_offset + jnp.arange(Sq)).reshape(nb, q_block)
        o = lax.map(lambda args: block(*args), (qb, pos))
        o = o.transpose(1, 2, 0, 3, 4).reshape(B, nh, Sq, -1)   # -1: v dh may differ
    return o.transpose(0, 2, 1, 3).astype(q.dtype)     # [B,Sq,nh,dh]


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    B, S, nkv, dh = k.shape
    return jnp.repeat(k, n_rep, axis=2)


def _sdpa_grouped_decode(q, k, v, *, kv_len):
    """Decode-step attention WITHOUT repeating KV (GQA grouped einsum).

    q [B,1,nkv,g,dh]; k,v [B,S,nkv,dh].  Keeps the KV cache sharded by kv-head
    — repeating to q-heads at decode would force XLA to materialize/all-gather
    the multi-GB cache across the grid.
    """
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqcgd,bscd->bcgqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.arange(k.shape[1])[None, :] < kv_len
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bcgqs,bscd->bqcgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def apply_attn(pctx, cfg: ModelConfig, p, x, *, positions, causal: bool = True,
               cache: Optional[KVCache] = None, layout=None,
               q_block: int = 1024) -> Tuple[jax.Array, Optional[KVCache]]:
    """x [B,S,H] canonical -> (y [B,S,H] canonical, updated cache)."""
    dh = cfg.resolved_head_dim
    nh, nkv = cfg.num_heads, cfg.num_kv_heads
    B, S, _ = x.shape

    # one shared entry gather for the q/k/v trio (megatron seq layout
    # ring-gathers the token shard once; hecaton/replicated fall back)
    qp, kp, vp = pctx.mixer_in_many(x, p["wq"], p["wk"], p["wv"])
    q = qp.reshape(B, S, nh, dh)
    k = kp.reshape(B, S, nkv, dh)
    v = vp.reshape(B, S, nkv, dh)

    hspec = pctx.heads_spec(layout) if layout is not None else None
    q = pctx.constraint(q, hspec)

    if cfg.qk_norm:
        q = L.rms_head_norm(p["q_norm"], q)
        k = L.rms_head_norm(p["k_norm"], k)
    cos, sin = L.rope_cos_sin(positions, dh, cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)

    new_cache, kv_len, q_off = None, None, jnp.zeros((), jnp.int32)
    if isinstance(cache, (PagedKVCache, QuantPagedKVCache)):
        # paged serving path: write the new tokens through the block table,
        # then attend over the gathered page view (per-slot lengths mask the
        # unwritten tail exactly — docs/DESIGN.md §10).  The int8 arena
        # variant quantizes at write time and dequantizes at gather time
        # (docs/DESIGN.md §11); attention math downstream is identical.
        if isinstance(cache, QuantPagedKVCache):
            kc, ksc = quant_paged_write(cache.k, cache.k_scale, k,
                                        cache.block_table, cache.lengths)
            vc, vsc = quant_paged_write(cache.v, cache.v_scale, v,
                                        cache.block_table, cache.lengths)
            new_cache = QuantPagedKVCache(kc, ksc, vc, vsc,
                                          cache.block_table,
                                          cache.lengths + S)
            k = quant_paged_gather(kc, ksc, cache.block_table, x.dtype)
            v = quant_paged_gather(vc, vsc, cache.block_table, x.dtype)
        else:
            kc = paged_write(cache.k, k, cache.block_table, cache.lengths)
            vc = paged_write(cache.v, v, cache.block_table, cache.lengths)
            new_cache = PagedKVCache(kc, vc, cache.block_table,
                                     cache.lengths + S)
            k = paged_gather(kc, cache.block_table)
            v = paged_gather(vc, cache.block_table)
        if S == 1:
            kv_len = (cache.lengths + S)[:, None]          # [B,1] per-slot
        else:
            # paged prefill is per-admission (one sequence): scalar offsets
            assert B == 1, "paged prefill runs one sequence at a time"
            kv_len, q_off = cache.lengths[0] + S, cache.lengths[0]
    elif cache is not None:
        kc = lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                      (0, cache.length, 0, 0))
        vc = lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                      (0, cache.length, 0, 0))
        new_cache = KVCache(kc, vc, cache.length + S)
        k, v = kc, vc
        kv_len, q_off = new_cache.length, cache.length

    if cache is not None and S == 1:
        # decode: grouped GQA, KV cache stays kv-head-sharded
        kv_lay = pctx.attn_layout(nkv, B)
        ba = None
        if pctx.mesh is not None and B % pctx.ax.n_data == 0:
            ba = kv_lay.batch_axes
        kvh = kv_lay.head_axes or None
        import jax.sharding as _js
        qspec = (None if pctx.mesh is None else
                 _js.PartitionSpec(ba if not ba or len(ba) > 1 else ba[0], None,
                                   kvh if not kvh or len(kvh) > 1 else kvh[0],
                                   None, None))
        kspec = (None if pctx.mesh is None else
                 _js.PartitionSpec(ba if not ba or len(ba) > 1 else ba[0], None,
                                   kvh if not kvh or len(kvh) > 1 else kvh[0],
                                   None))
        g = nh // nkv
        q5 = pctx.constraint(q.reshape(B, S, nkv, g, dh), qspec)
        k = pctx.constraint(k.astype(q.dtype), kspec)
        v = pctx.constraint(v.astype(q.dtype), kspec)
        o = _sdpa_grouped_decode(q5, k, v, kv_len=kv_len)
        o = o.reshape(B, S, nh, dh)
    else:
        k = pctx.constraint(_repeat_kv(k.astype(q.dtype), nh // nkv), hspec)
        v = pctx.constraint(_repeat_kv(v.astype(q.dtype), nh // nkv), hspec)
        o = _sdpa(q, k, v, causal=causal, q_offset=q_off, kv_len=kv_len,
                  q_block=q_block)
        o = pctx.constraint(o, hspec)
    y = pctx.mixer_out(o.reshape(B, S, nh * dh), p["wo"])
    return y, new_cache


def apply_cross_attn(pctx, cfg: ModelConfig, p, x, memory_kv, *, layout=None):
    """Whisper cross-attention: q from decoder x, k/v precomputed from encoder."""
    dh = cfg.resolved_head_dim
    nh, nkv = cfg.num_heads, cfg.num_kv_heads
    B, S, _ = x.shape
    q = pctx.mixer_in(x, p["wq"]).reshape(B, S, nh, dh)
    hspec = pctx.heads_spec(layout) if layout is not None else None
    q = pctx.constraint(q, hspec)
    k, v = memory_kv
    k = pctx.constraint(_repeat_kv(k.astype(q.dtype), nh // nkv), hspec)
    v = pctx.constraint(_repeat_kv(v.astype(q.dtype), nh // nkv), hspec)
    o = _sdpa(q, k, v, causal=False, q_offset=jnp.zeros((), jnp.int32))
    return pctx.mixer_out(o.reshape(B, S, nh * dh), p["wo"])


def cross_kv(pctx, cfg: ModelConfig, p, memory):
    """Precompute cross-attention K/V from encoder output (cached for decode)."""
    B, Sm, _ = memory.shape
    dh, nkv = cfg.resolved_head_dim, cfg.num_kv_heads
    kp, vp = pctx.mixer_in_many(memory, p["wk"], p["wv"])
    return kp.reshape(B, Sm, nkv, dh), vp.reshape(B, Sm, nkv, dh)


# ---------------------------------------------------------------------------
# MLA (minicpm3 / deepseek style)
# ---------------------------------------------------------------------------

def apply_mla(pctx, cfg: ModelConfig, p, x, *, positions,
              cache: Optional[MLACache] = None, layout=None, q_block: int = 1024):
    m = cfg.mla
    nh, H = cfg.num_heads, cfg.d_model
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    B, S, _ = x.shape
    hspec = pctx.heads_spec(layout) if layout is not None else None

    ql, kv = pctx.mixer_in_many(x, p["wq_a"], p["wkv_a"])
    ql = L.apply_norm("rmsnorm", {"scale": p["q_norm"]}, ql)
    # ql is mixer-interior (full sequence already gathered): interior=True
    # keeps the megatron seq-sharded path from re-gathering a non-entry
    q = pctx.mixer_in(ql, p["wq_b"], interior=True).reshape(B, S, nh, dn + dr)
    q = pctx.constraint(q, hspec)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    c_kv, k_rope = kv[..., :m.kv_lora_rank], kv[..., m.kv_lora_rank:]
    c_kv = L.apply_norm("rmsnorm", {"scale": p["kv_norm"]}, c_kv)

    cos, sin = L.rope_cos_sin(positions, dr, cfg.rope_theta)
    q_rope = L.apply_rope(q_rope, cos, sin)
    k_rope = L.apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]

    new_cache, kv_len, q_off = None, None, jnp.zeros((), jnp.int32)
    if isinstance(cache, (PagedMLACache, QuantPagedMLACache)):
        if isinstance(cache, QuantPagedMLACache):
            cc, csc = quant_paged_write(cache.c_kv, cache.c_scale, c_kv,
                                        cache.block_table, cache.lengths)
            kr, rsc = quant_paged_write(cache.k_rope, cache.r_scale, k_rope,
                                        cache.block_table, cache.lengths)
            new_cache = QuantPagedMLACache(cc, csc, kr, rsc,
                                           cache.block_table,
                                           cache.lengths + S)
            c_kv = quant_paged_gather(cc, csc, cache.block_table, x.dtype)
            k_rope = quant_paged_gather(kr, rsc, cache.block_table, x.dtype)
        else:
            cc = paged_write(cache.c_kv, c_kv, cache.block_table,
                             cache.lengths)
            kr = paged_write(cache.k_rope, k_rope, cache.block_table,
                             cache.lengths)
            new_cache = PagedMLACache(cc, kr, cache.block_table,
                                      cache.lengths + S)
            c_kv = paged_gather(cc, cache.block_table).astype(x.dtype)
            k_rope = paged_gather(kr, cache.block_table).astype(x.dtype)
        if S == 1:
            kv_len = (cache.lengths + S)[:, None]          # [B,1] per-slot
        else:
            assert B == 1, "paged prefill runs one sequence at a time"
            kv_len, q_off = cache.lengths[0] + S, cache.lengths[0]
    elif cache is not None:
        cc = lax.dynamic_update_slice(cache.c_kv, c_kv.astype(cache.c_kv.dtype),
                                      (0, cache.length, 0))
        kr = lax.dynamic_update_slice(cache.k_rope, k_rope.astype(cache.k_rope.dtype),
                                      (0, cache.length, 0))
        new_cache = MLACache(cc, kr, cache.length + S)
        c_kv, k_rope = cc.astype(x.dtype), kr.astype(x.dtype)
        kv_len, q_off = new_cache.length, cache.length

    if cache is not None and S == 1:
        # ---- absorbed decode (DeepSeek trick): never materialize per-head K/V.
        wkv = p["wkv_b"].reshape(m.kv_lora_rank, nh, dn + dv)
        wk_b, wv_b = wkv[..., :dn], wkv[..., dn:]
        q_abs = jnp.einsum("bshd,lhd->bshl", q_nope, wk_b)         # [B,1,nh,lora]
        s = (jnp.einsum("bshl,btl->bhst", q_abs.astype(jnp.float32),
                        c_kv.astype(jnp.float32))
             + jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                          k_rope.astype(jnp.float32))) * ((dn + dr) ** -0.5)
        mask = jnp.arange(c_kv.shape[1])[None, :] < kv_len
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        prob = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhst,btl->bshl", prob, c_kv.astype(jnp.float32))
        o = jnp.einsum("bshl,lhd->bshd", o_lat, wv_b).astype(x.dtype)
    else:
        kv_up = jnp.einsum("btl,lo->bto", c_kv, p["wkv_b"].astype(c_kv.dtype),
                           preferred_element_type=jnp.float32).astype(x.dtype)
        kv_up = kv_up.reshape(B, -1, nh, dn + dv)
        k_nope, vv = kv_up[..., :dn], kv_up[..., dn:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (*k_nope.shape[:3], dr))], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        qq = pctx.constraint(qq, hspec)
        k = pctx.constraint(k, hspec)
        # Perf iteration 3b tried passing v at its native 64-dim head (saves
        # 2.5x SV flops) but GSPMD then relaid the whole SV chain with
        # per-layer collective-permutes (+678GB/chip, 40x the compute win) —
        # measured and REVERTED; see EXPERIMENTS.md. The padded-v form keeps
        # the qkv chain in one layout.
        vpad = jnp.pad(vv, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
        o = _sdpa(qq, k, pctx.constraint(vpad, hspec), causal=True,
                  q_offset=q_off, kv_len=kv_len, q_block=q_block)[..., :dv]
    y = pctx.mixer_out(o.reshape(B, S, nh * dv), p["wo"])
    return y, new_cache
