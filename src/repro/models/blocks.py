"""Block assembly: pre-norm residual wiring for attention / MLA / MoE / mamba
blocks, plus stacked init helpers for scan-over-layers."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as ATT
from repro.models import layers as L
from repro.models import mlp as MLP
from repro.models import ssm as SSM


def init_attn_block(cfg: ModelConfig, key, cross: bool = False):
    ks = jax.random.split(key, 5)
    p: Dict[str, Any] = {
        "norm1": L.init_norm(cfg.norm_kind, cfg.d_model),
        "norm2": L.init_norm(cfg.norm_kind, cfg.d_model),
    }
    p["attn"] = ATT.init_mla(cfg, ks[0]) if cfg.mla else ATT.init_attn(cfg, ks[0])
    p["mlp"] = MLP.init_moe(cfg, ks[1]) if cfg.moe else MLP.init_mlp(cfg, ks[1])
    if cross:
        p["norm_x"] = L.init_norm(cfg.norm_kind, cfg.d_model)
        p["xattn"] = ATT.init_attn(cfg, ks[2])
    return p


def init_mamba_block(cfg: ModelConfig, key):
    return {"norm1": L.init_norm(cfg.norm_kind, cfg.d_model),
            "mixer": SSM.init_mamba(cfg, key)}


def init_stacked(init_fn, n: int, key):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def apply_attn_block(pctx, cfg: ModelConfig, p, x, *, positions, layout,
                     causal=True, cache=None, memory_kv=None,
                     ) -> Tuple[jax.Array, Any, jax.Array]:
    """Returns (x, new_cache, aux_loss).

    Pre-norms and residual adds run on the canonical (seq-sharded) residual
    layout via the PCtx entry points — the whole block boundary is shard-local
    work; the mixers gather/scatter the sequence internally."""
    aux = jnp.zeros((), jnp.float32)
    h = pctx.norm(cfg.norm_kind, p["norm1"], x)
    if cfg.mla:
        a, new_cache = ATT.apply_mla(pctx, cfg, p["attn"], h, positions=positions,
                                     cache=cache, layout=layout)
    else:
        a, new_cache = ATT.apply_attn(pctx, cfg, p["attn"], h, positions=positions,
                                      causal=causal, cache=cache, layout=layout)
    x = pctx.canon(x + a)
    if memory_kv is not None:
        h = pctx.norm(cfg.norm_kind, p["norm_x"], x)
        a = ATT.apply_cross_attn(pctx, cfg, p["xattn"], h, memory_kv, layout=layout)
        x = pctx.canon(x + a)
    h = pctx.norm(cfg.norm_kind, p["norm2"], x)
    if cfg.moe:
        m, aux = MLP.apply_moe(pctx, cfg, p["mlp"], h)
    else:
        m = MLP.apply_mlp(pctx, cfg, p["mlp"], h)
    x = pctx.canon(x + m.astype(x.dtype))
    return x, new_cache, aux


def apply_mamba_block(pctx, cfg: ModelConfig, p, x, *, layout, state=None,
                      ) -> Tuple[jax.Array, Any]:
    h = pctx.norm(cfg.norm_kind, p["norm1"], x)
    m, new_state = SSM.apply_mamba(pctx, cfg, p["mixer"], h, state=state,
                                   layout=layout)
    return pctx.canon(x + m.astype(x.dtype)), new_state
