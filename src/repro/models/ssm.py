"""Mamba2 (SSD — state-space duality) mixer.  [arXiv:2405.21060]

The SSD forward is the chunked dual form: intra-chunk attention-like matmuls +
an inter-chunk state recurrence (``lax.scan`` over chunks).  This file is the
pure-jnp semantics; kernels/ssd.py is the Pallas TPU version of the same math and
kernels/ref.py re-exports ``ssd_chunked`` as its oracle.

Projections route through PCtx: the Hecaton mixer pattern gathers the sequence and
shards d_inner/heads over the grid — the SSD scan itself is then comm-free, exactly
like multi-head attention in the paper's §IV-C ("intrinsic parallelism provided by
multiple heads").
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models import layers as L


class SSMState(NamedTuple):
    conv: jax.Array     # [B, K-1, conv_channels]
    ssm: jax.Array      # [B, nheads, head_dim, state]


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


def n_heads(cfg: ModelConfig) -> int:
    return d_inner(cfg) // cfg.ssm.head_dim


def conv_channels(cfg: ModelConfig) -> int:
    s = cfg.ssm
    return d_inner(cfg) + 2 * s.n_groups * s.state_dim


def init_mamba(cfg: ModelConfig, key):
    s = cfg.ssm
    H, Di, nh = cfg.d_model, d_inner(cfg), n_heads(cfg)
    gs = s.n_groups * s.state_dim
    ks = jax.random.split(key, 8)
    dt = jnp.exp(jax.random.uniform(ks[5], (nh,)) * (jnp.log(0.1) - jnp.log(0.001))
                 + jnp.log(0.001))
    return {
        "wz": L.normal_init(ks[0], (H, Di)),
        "wx": L.normal_init(ks[1], (H, Di)),
        "wB": L.normal_init(ks[2], (H, gs)),
        "wC": L.normal_init(ks[3], (H, gs)),
        "wdt": L.normal_init(ks[4], (H, nh)),
        "dt_bias": dt + jnp.log(-jnp.expm1(-dt)),     # softplus^-1(dt)
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "conv_w": L.normal_init(ks[6], (s.conv_kernel, conv_channels(cfg)),
                                scale=0.5),
        "norm": jnp.ones((Di,), jnp.float32),
        "wo": L.normal_init(ks[7], (Di, H), scale=1.0 / Di ** 0.5),
    }


# ---------------------------------------------------------------------------
# SSD chunked scan (reference semantics; Pallas version in kernels/ssd.py)
# ---------------------------------------------------------------------------

def _segsum(x):
    """x [..., Q] -> lower-triangular pairwise cumulative sums [..., Q, Q]."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, *, chunk: int, init_state=None):
    """SSD dual-form forward.

    x  [b, S, nh, dh]      inputs
    dt [b, S, nh]          post-softplus step sizes
    A  [nh]                negative decay rates
    B  [b, S, g, dstate]   input projections  (g groups broadcast over heads)
    C  [b, S, g, dstate]   output projections
    Returns (y [b,S,nh,dh], final_state [b,nh,dh,dstate]).
    """
    b, S, nh, dh = x.shape
    g = B.shape[2]
    if S % chunk:
        # pad with dt=0 steps: decay exp(0)=1 and zero input — exact no-ops
        # for both outputs (sliced off) and the carried state.
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, fin = ssd_chunked(x, dt, A, B, C, chunk=chunk,
                             init_state=init_state)
        return y[:, :S], fin
    nc = S // chunk
    hpg = nh // g
    f32 = jnp.float32

    xc = x.reshape(b, nc, chunk, nh, dh).astype(f32)
    dtc = dt.reshape(b, nc, chunk, nh).astype(f32)
    Bc = B.reshape(b, nc, chunk, g, -1).astype(f32)
    Cc = C.reshape(b, nc, chunk, g, -1).astype(f32)
    Bh = jnp.repeat(Bc, hpg, axis=3)            # [b,nc,Q,nh,ds]
    Ch = jnp.repeat(Cc, hpg, axis=3)

    dA = dtc * A.astype(f32)                    # [b,nc,Q,nh] (negative)
    dAcum = jnp.cumsum(dA, axis=2)              # within-chunk cumulative

    # --- intra-chunk (diagonal blocks): attention-like masked matmul
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))          # [b,nc,nh,Q,Q]
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh) * Lmat.transpose(0, 1, 2, 3, 4)
    xdt = xc * dtc[..., None]
    y_diag = jnp.einsum("bchqk,bckhd->bcqhd", scores, xdt)

    # --- chunk summaries: state contributed by each chunk
    decay_to_end = jnp.exp(dAcum[:, :, -1:, :] - dAcum)        # [b,nc,Q,nh]
    states = jnp.einsum("bcqhn,bcqh,bcqhd->bchdn", Bh, decay_to_end * dtc, xc)

    # --- inter-chunk recurrence
    chunk_decay = jnp.exp(dAcum[:, :, -1, :])                  # [b,nc,nh]
    s0 = (jnp.zeros((b, nh, dh, Bh.shape[-1]), f32) if init_state is None
          else init_state.astype(f32))

    def step(carry, inp):
        st_c, dec_c = inp                                       # [b,nh,dh,ds],[b,nh]
        prev = carry
        new = prev * dec_c[..., None, None] + st_c
        return new, prev

    final, prevs = lax.scan(step,
                            s0,
                            (states.transpose(1, 0, 2, 3, 4),
                             chunk_decay.transpose(1, 0, 2)))
    prevs = prevs.transpose(1, 0, 2, 3, 4)                      # [b,nc,nh,dh,ds]

    # --- off-diagonal contribution from carried state
    in_decay = jnp.exp(dAcum)                                   # [b,nc,Q,nh]
    y_off = jnp.einsum("bcqhn,bchdn,bcqh->bcqhd", Ch, prevs, in_decay)

    y = (y_diag + y_off).reshape(b, S, nh, dh)
    return y.astype(x.dtype), final


def ssd_decode_step(state, x, dt, A, B, C):
    """Single-token recurrence.  x [b,nh,dh], dt [b,nh], B/C [b,g,ds]."""
    g = B.shape[1]
    hpg = x.shape[1] // g
    Bh = jnp.repeat(B, hpg, axis=1).astype(jnp.float32)     # [b,nh,ds]
    Ch = jnp.repeat(C, hpg, axis=1).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A.astype(jnp.float32))               # [b,nh]
    xdt = x.astype(jnp.float32) * dtf[..., None]            # [b,nh,dh]
    new = state * dA[..., None, None] + jnp.einsum("bhd,bhn->bhdn", xdt, Bh)
    y = jnp.einsum("bhdn,bhn->bhd", new, Ch)
    return y.astype(x.dtype), new


# ---------------------------------------------------------------------------
# causal depthwise conv
# ---------------------------------------------------------------------------

def causal_conv(x, w):
    """x [B,S,C], w [K,C] depthwise causal conv."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i]
    return out.astype(x.dtype)


def conv_step(conv_state, xt, w):
    """conv_state [B,K-1,C], xt [B,C] -> (y [B,C], new_state)."""
    K = w.shape[0]
    window = jnp.concatenate([conv_state, xt[:, None, :]], axis=1)  # [B,K,C]
    y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w).astype(xt.dtype)
    return y, window[:, 1:, :]


# ---------------------------------------------------------------------------
# full mamba block
# ---------------------------------------------------------------------------

def apply_mamba(pctx, cfg: ModelConfig, p, x, *, state: Optional[SSMState] = None,
                layout=None) -> Tuple[jax.Array, Optional[SSMState]]:
    """x [B,S,H] canonical -> (y canonical, updated recurrent state)."""
    s = cfg.ssm
    B_, S, H = x.shape
    Di, nh = d_inner(cfg), n_heads(cfg)
    hspec = pctx.heads_spec(layout) if layout is not None else None

    z, xs = pctx.mixer_in_many(x, p["wz"], p["wx"])     # [B,S,Di] full seq,
    # sharing one entry gather of the token shard (megatron seq layout)
    Bp = pctx.small_proj(x, p["wB"])                    # [B,S,g*ds] (tiny)
    Cp = pctx.small_proj(x, p["wC"])
    dt = pctx.small_proj(x, p["wdt"])                   # [B,S,nh]

    conv_in = jnp.concatenate([xs, Bp, Cp], axis=-1)
    new_conv = None
    if state is not None and S == 1:
        cy, new_conv = conv_step(state.conv, conv_in[:, 0, :], p["conv_w"])
        conv_out = cy[:, None, :]
    else:
        conv_out = causal_conv(conv_in, p["conv_w"])
        if state is not None:
            K = s.conv_kernel
            new_conv = conv_in[:, -(K - 1):, :]
    conv_out = jax.nn.silu(conv_out)

    xs = conv_out[..., :Di]
    Bp = conv_out[..., Di:Di + s.n_groups * s.state_dim]
    Cp = conv_out[..., Di + s.n_groups * s.state_dim:]

    xh = pctx.constraint(xs.reshape(B_, S, nh, s.head_dim), hspec)
    Bh = Bp.reshape(B_, S, s.n_groups, s.state_dim)
    Ch = Cp.reshape(B_, S, s.n_groups, s.state_dim)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    new_ssm = None
    if state is not None and S == 1:
        y, new_ssm = ssd_decode_step(state.ssm, xh[:, 0], dtv[:, 0], A,
                                     Bh[:, 0], Ch[:, 0])
        y = y[:, None]
    else:
        init = state.ssm if state is not None else None
        y, fin = ssd_chunked(xh, dtv, A, Bh, Ch, chunk=min(s.chunk_size, S),
                             init_state=init)
        if state is not None:
            new_ssm = fin

    y = y + xh * p["D"][None, None, :, None]            # skip connection
    y = pctx.constraint(y, hspec)
    y = y.reshape(B_, S, Di)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = L.apply_norm("rmsnorm", {"scale": p["norm"]}, y * jax.nn.silu(z))
    out = pctx.mixer_out(y, p["wo"])
    new_state = SSMState(new_conv, new_ssm) if state is not None else None
    return out, new_state


def init_ssm_state(cfg: ModelConfig, batch: int, dtype) -> SSMState:
    s = cfg.ssm
    return SSMState(
        jnp.zeros((batch, s.conv_kernel - 1, conv_channels(cfg)), dtype),
        jnp.zeros((batch, n_heads(cfg), s.head_dim, s.state_dim), jnp.float32))
