"""Shared model layers: initializers, norms, RoPE, embeddings, activations."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def normal_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (LLM standard)."""
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32)
            * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg_kind: str, dim: int):
    p = {"scale": jnp.ones((dim,), jnp.float32)}
    if cfg_kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), jnp.float32)
    return p


def apply_norm(kind: str, params, x, eps: float = 1e-6):
    """RMSNorm / LayerNorm over the last dim, fp32 statistics."""
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * params["scale"]).astype(x.dtype)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * params["scale"] + params["bias"]).astype(x.dtype)
    raise KeyError(kind)


def dropout(x, rate: float, rng=None):
    """Inverted dropout; identity when rate is 0 or no rng is supplied.

    Called on the canonical (seq-sharded) residual via ``PCtx.dropout`` so the
    mask is drawn shard-local under GSPMD — no replicated [B,S,H] mask."""
    if rate <= 0.0 or rng is None:
        return x
    keep = 1.0 - rate
    mask = jax.random.bernoulli(rng, keep, x.shape)
    return jnp.where(mask, x / keep, 0).astype(x.dtype)


def rms_head_norm(scale, x, eps: float = 1e-6):
    """qk-norm: RMSNorm over head_dim of [..., head_dim]."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def relu2(x):
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS = {
    "swiglu": jax.nn.silu,   # gated (w1b present)
    "geglu": jax.nn.gelu,    # gated
    "relu2": relu2,          # non-gated (nemotron squared-ReLU)
    "gelu": jax.nn.gelu,     # non-gated
}

GATED = {"swiglu": True, "geglu": True, "relu2": False, "gelu": False}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions [B,S] -> cos,sin [B,S,head_dim//2] in fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs   # [B,S,half]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [B,S,H,D] (D even, split-half convention)."""
    d = x.shape[-1] // 2
    x1, x2 = x[..., :d], x[..., d:]
    c, s = cos[:, :, None, :], sin[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s],
                           axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, dim: int, dtype=jnp.float32):
    return {"table": embed_init(key, (vocab, dim), dtype)}


def apply_embed(params, ids, compute_dtype):
    return jnp.take(params["table"], ids, axis=0).astype(compute_dtype)
