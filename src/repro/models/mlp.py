"""MLP / MoE blocks.

Dense MLPs run the Hecaton fused-FFN dataflow (core/hecaton.ffn_block).

MoE uses an EP×TP hybrid (docs/DESIGN.md §4): experts sharded over the grid's ``mx``
axis, each expert's FFN width sharded over ``my``; tokens are dispatched locally by
an argsort-based capacity router (gather/scatter-add, fully differentiable).  The
only collectives are an all-gather of the (hidden-sharded) input and a
reduce-scatter of the combined output — the same AG/RS-only property as the paper's
dense method, so MoE inherits the complexity bound.

With ``ParallelConfig.overlap`` != "none" those EP/TP gathers and scatters run
as ``lax.ppermute`` rings (core/overlap.py): the input gathers become ring
all-gathers and the two output reduce-scatters become circulating-accumulator
rings, so the MoE path has zero bulk AG/RS in its HLO just like the dense hot
path.  (The expert compute between them is gather/scatter-add dispatch, not a
single matmul, so the ``fused`` single-kernel mode contributes its ring
decomposition here rather than a fused matmul; extents a ring cannot chunk
fall back to the bulk collective per collective, as everywhere else.)
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.config import ModelConfig
from repro.core import overlap as OV
from repro.models import layers as L


def init_mlp(cfg: ModelConfig, key):
    H, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w1": L.normal_init(ks[0], (H, F)),
         "w2": L.normal_init(ks[1], (F, H), scale=1.0 / F ** 0.5)}
    if L.GATED[cfg.mlp_kind]:
        p["w1b"] = L.normal_init(ks[2], (H, F))
    return p


def apply_mlp(pctx, cfg: ModelConfig, p, x):
    act = L.ACTIVATIONS[cfg.mlp_kind]
    return pctx.ffn(x, p["w1"], p["w2"], act, p.get("w1b"))


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def init_moe(cfg: ModelConfig, key):
    H, F, E = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    ks = jax.random.split(key, 4)
    p = {"router": L.normal_init(ks[0], (H, E), scale=0.02),
         "we1": L.normal_init(ks[1], (E, H, F)),
         "we2": L.normal_init(ks[2], (E, F, H), scale=1.0 / F ** 0.5)}
    if L.GATED[cfg.mlp_kind]:
        p["we1b"] = L.normal_init(ks[3], (E, H, F))
    return p


def _dispatch_indices(expert_of, n_local_experts: int, e_offset, capacity: int):
    """Argsort-based capacity dispatch for flattened (token,slot) assignments.

    expert_of: [A] global expert id per assignment (A = T * top_k).
    Returns (slot_token [E_loc, C] source assignment index, slot_valid [E_loc, C]).
    """
    A = expert_of.shape[0]
    local_e = expert_of - e_offset
    in_range = (local_e >= 0) & (local_e < n_local_experts)
    sort_key = jnp.where(in_range, local_e, n_local_experts)      # invalid last
    order = jnp.argsort(sort_key)                                 # stable
    sorted_e = sort_key[order]
    # position within its expert group
    pos = jnp.arange(A) - jnp.searchsorted(sorted_e, sorted_e, side="left")
    valid = (sorted_e < n_local_experts) & (pos < capacity)
    slot = jnp.where(valid, sorted_e * capacity + pos, n_local_experts * capacity)
    slot_token = jnp.full((n_local_experts * capacity + 1,), A, jnp.int32)
    slot_token = slot_token.at[slot].set(order.astype(jnp.int32), mode="drop")
    return slot_token[:-1].reshape(n_local_experts, capacity)


def _moe_local(p, x, *, cfg: ModelConfig, n_local_experts: int, e_offset,
               compute_dtype):
    """MoE over local tokens x [T, H] with experts [e_offset, e_offset+n_local).

    Returns (y [T,H] partial over expert shards, router_probs [T,E]).
    """
    mc = cfg.moe
    T, H = x.shape
    E, k = mc.num_experts, mc.top_k
    logits = jnp.einsum("th,he->te", x, p["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = lax.top_k(probs, k)                              # [T,k]
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    expert_of = idx.reshape(-1)                                   # [T*k]
    gates_flat = gate.reshape(-1)
    cap = max(1, int(k * T * mc.capacity_factor / E))
    slot_token = _dispatch_indices(expert_of, n_local_experts, e_offset, cap)
    tok_of_slot = jnp.minimum(slot_token // k, T - 1)
    slot_valid = slot_token < T * k

    xd = x[tok_of_slot] * slot_valid[..., None].astype(x.dtype)   # [E_loc,C,H]
    w1 = lax.dynamic_slice_in_dim(p["we1"], e_offset, n_local_experts, 0) \
        if p["we1"].shape[0] != n_local_experts else p["we1"]
    w2 = lax.dynamic_slice_in_dim(p["we2"], e_offset, n_local_experts, 0) \
        if p["we2"].shape[0] != n_local_experts else p["we2"]
    h = jnp.einsum("ech,ehf->ecf", xd, w1.astype(xd.dtype),
                   preferred_element_type=jnp.float32).astype(compute_dtype)
    act = L.ACTIVATIONS[cfg.mlp_kind]
    if "we1b" in p:
        w1b = lax.dynamic_slice_in_dim(p["we1b"], e_offset, n_local_experts, 0) \
            if p["we1b"].shape[0] != n_local_experts else p["we1b"]
        h = act(h) * jnp.einsum("ech,ehf->ecf", xd, w1b.astype(xd.dtype),
                                preferred_element_type=jnp.float32
                                ).astype(compute_dtype)
    else:
        h = act(h)
    yd = jnp.einsum("ecf,efh->ech", h, w2.astype(h.dtype),
                    preferred_element_type=jnp.float32).astype(compute_dtype)
    gd = gates_flat[slot_token.reshape(-1)] * slot_valid.reshape(-1)
    yd = yd.reshape(-1, H) * gd[:, None].astype(yd.dtype)
    y = jnp.zeros((T + 1, H), yd.dtype).at[
        jnp.minimum(tok_of_slot.reshape(-1), T)].add(
            yd, mode="drop")[:T]
    return y, probs


def moe_aux_losses(probs, idx_onehot_mean=None):
    """Load-balance + z-style losses from router probabilities [T,E]."""
    E = probs.shape[-1]
    me = jnp.mean(probs, axis=0)
    # fraction routed (approximated by prob mass argmax-free, Switch-style)
    return E * jnp.sum(me * me)


def apply_moe(pctx, cfg: ModelConfig, p, x):
    """x [B,S,H] canonical -> y canonical (+ aux loss scalar)."""
    mc = cfg.moe
    B, S, H = x.shape
    mesh = pctx.mesh
    if mesh is None or not pctx.use_hecaton:
        # single-device / megatron fallback: experts unsharded (megatron shards
        # handled by GSPMD through the einsums via constraints)
        y, probs = _moe_local(p, x.reshape(-1, H), cfg=cfg,
                              n_local_experts=mc.num_experts, e_offset=0,
                              compute_dtype=x.dtype)
        return y.reshape(B, S, H), moe_aux_losses(probs)

    a = pctx.ax
    ep_ax, tp_ax = a.t_ax, a.h_ax           # experts over mx, ffn width over my
    n_ep, n_tp = a.size(ep_ax), a.size(tp_ax)
    n_loc = mc.num_experts // n_ep
    dspec = a.data_axes if len(a.data_axes) > 1 else a.data_axes[0]
    all_axes = a.data_axes + (ep_ax, tp_ax)
    ov = pctx.overlap
    bidir = ov == "bidir"

    def f(xl, router, w1, w2, *rest):
        # xl [b, s_loc, H/my].  Gather hidden (full H for routing) AND sequence
        # (every expert shard must see every token of its data shard) — the
        # mixer-pattern gathers, after which expert compute is comm-free.
        # With overlap enabled both gathers (and the reduce-scatters below)
        # run as ppermute rings instead of bulk collectives.
        if ov != "none":
            xg = OV.ring_all_gather(xl, tp_ax, dim=2, n=n_tp, bidir=bidir)
            xg = OV.ring_all_gather(xg, ep_ax, dim=1, n=n_ep, bidir=bidir)
        else:
            xg = lax.all_gather(xl, tp_ax, axis=2, tiled=True)   # [b,s_loc,H]
            xg = lax.all_gather(xg, ep_ax, axis=1, tiled=True)   # [b,S,H]
        b, S, H = xg.shape
        e_off = lax.axis_index(ep_ax) * n_loc
        pl = {"router": router, "we1": w1, "we2": w2}
        if rest:
            pl["we1b"] = rest[0]
        y, probs = _moe_local(pl, xg.reshape(b * S, H), cfg=cfg,
                              n_local_experts=n_loc, e_offset=e_off,
                              compute_dtype=xl.dtype)
        # y [T,H] is partial over ep_ax (expert subsets) and tp_ax (F-contraction
        # partials): two reduce-scatters complete the sums and restore the
        # canonical tiling (tokens over mx, hidden over my).  The token scatter
        # must split the SEQUENCE dim per batch row — not the flattened (b*S)
        # dim, which would hand whole batch rows to different shards.
        y = y.reshape(b, S, H)
        if ov != "none" and OV.rs_ok(S, n_ep):
            y = OV.ring_reduce_scatter(y, ep_ax, dim=1, n=n_ep, bidir=bidir)
        else:
            y = lax.psum_scatter(y, ep_ax, scatter_dimension=1, tiled=True)
        if ov != "none" and OV.rs_ok(H, n_tp):
            y = OV.ring_reduce_scatter(y, tp_ax, dim=2, n=n_tp, bidir=bidir)
        else:
            y = lax.psum_scatter(y, tp_ax, scatter_dimension=2, tiled=True)
        aux = lax.pmean(moe_aux_losses(probs), all_axes)
        return y, aux

    in_specs = [P(dspec, a.t_ax, a.h_ax), P(),
                P(ep_ax, None, tp_ax), P(ep_ax, tp_ax, None)]
    # cast expert weights to activation dtype BEFORE the shard_map boundary so
    # any FSDP gather moves bf16, not fp32 (Perf iteration 1)
    args = [x, p["router"], p["we1"].astype(x.dtype), p["we2"].astype(x.dtype)]
    if "we1b" in p:
        in_specs.append(P(ep_ax, None, tp_ax))
        args.append(p["we1b"].astype(x.dtype))
    y, aux = compat.shard_map(
        f, mesh, tuple(in_specs),
        (P(dspec, a.t_ax, a.h_ax), P()))(*args)
    return y, aux
