"""LM assembly: decoder-only / SSM / hybrid / encoder-decoder language models.

Layers are stacked ([L, ...] param arrays) and applied with ``lax.scan`` so the
compiled HLO is depth-independent — essential for dry-running 96-layer models.
Remat (core/schedule.py policies) wraps the scan body.

Sharding: all projections route through PCtx (Hecaton Alg. 1 or the Megatron
baseline); embeddings / norms / loss are jit-level ops under GSPMD constraints.
The residual stream stays in the canonical seq-sharded layout
(``ParallelConfig.residual``) across the whole layer scan: embedding output,
dropout, pre-norms, residual adds and the final norm all run on the local
token shard, so no block boundary carries a bulk collective.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.core import schedule
from repro.models import attention as ATT
from repro.models import blocks as BLK
from repro.models import layers as L
from repro.models import ssm as SSM


# ---------------------------------------------------------------------------
# parameter counting (MODEL_FLOPS = 6*N*D uses these)
# ---------------------------------------------------------------------------

def _attn_params(cfg: ModelConfig) -> int:
    H, dh = cfg.d_model, cfg.resolved_head_dim
    if cfg.mla:
        m = cfg.mla
        dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
        return (H * m.q_lora_rank + m.q_lora_rank * cfg.num_heads * (dn + dr)
                + H * (m.kv_lora_rank + dr)
                + m.kv_lora_rank * cfg.num_heads * (dn + dv)
                + cfg.num_heads * dv * H)
    return (H * cfg.num_heads * dh + 2 * H * cfg.num_kv_heads * dh
            + cfg.num_heads * dh * H)


def _mlp_params(cfg: ModelConfig, active_only: bool) -> int:
    H, F = cfg.d_model, cfg.d_ff
    per = (3 if L.GATED[cfg.mlp_kind] else 2) * H * F
    if cfg.moe:
        E = cfg.moe.num_experts
        n = cfg.moe.top_k if active_only else E
        return per * n + H * E
    return per


def _mamba_params(cfg: ModelConfig) -> int:
    H, Di = cfg.d_model, SSM.d_inner(cfg)
    gs = cfg.ssm.n_groups * cfg.ssm.state_dim
    return (2 * H * Di + 2 * H * gs + H * SSM.n_heads(cfg)
            + cfg.ssm.conv_kernel * SSM.conv_channels(cfg) + Di + Di * H)


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    H = cfg.d_model
    emb = cfg.vocab_size * H * (1 if cfg.tie_embeddings else 2)
    total = emb
    if cfg.family == "hybrid":
        Lm = cfg.num_layers
        total += Lm * (_mamba_params(cfg) + 2 * H)
        per_attn = _attn_params(cfg) + _mlp_params(cfg, active_only) + 4 * H
        every = max(1, cfg.shared_attn_every)
        n_apps = Lm // every
        n_sets = max(1, cfg.num_shared_attn_sets)
        total += (n_apps if active_only else n_sets) * per_attn
        return total
    if cfg.family == "ssm":
        return total + cfg.num_layers * (_mamba_params(cfg) + 2 * H)
    per_block = _attn_params(cfg) + _mlp_params(cfg, active_only) + 4 * H
    n_layers = cfg.num_layers + cfg.encoder_layers
    if cfg.is_encdec:   # decoder blocks also carry cross-attention
        per_cross = _attn_params(cfg) + 2 * H
        return total + cfg.encoder_layers * per_block + \
            cfg.num_layers * (per_block + per_cross)
    return total + n_layers * per_block


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": L.init_embed(ks[0], cfg.padded_vocab, cfg.d_model),
        "final_norm": L.init_norm(cfg.norm_kind, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": L.normal_init(ks[1], (cfg.d_model, cfg.padded_vocab),
                                                scale=0.02)}
    fam = cfg.family
    if fam == "ssm":
        params["blocks"] = BLK.init_stacked(
            lambda k: BLK.init_mamba_block(cfg, k), cfg.num_layers, ks[2])
    elif fam == "hybrid":
        params["blocks"] = {
            "mamba": BLK.init_stacked(
                lambda k: BLK.init_mamba_block(cfg, k), cfg.num_layers, ks[2]),
            "shared": BLK.init_stacked(
                lambda k: BLK.init_attn_block(cfg, k),
                max(1, cfg.num_shared_attn_sets), ks[3]),
        }
    elif cfg.is_encdec:
        params["encoder"] = BLK.init_stacked(
            lambda k: BLK.init_attn_block(cfg, k), cfg.encoder_layers, ks[2])
        params["blocks"] = BLK.init_stacked(
            lambda k: BLK.init_attn_block(cfg, k, cross=True), cfg.num_layers, ks[3])
        params["enc_norm"] = L.init_norm(cfg.norm_kind, cfg.d_model)
    else:
        params["blocks"] = BLK.init_stacked(
            lambda k: BLK.init_attn_block(cfg, k), cfg.num_layers, ks[2])
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, s_max: int, dtype):
    """Stacked per-layer decode caches (dense layout).

    Cache layout now lives in ``repro.serve.cache`` (docs/DESIGN.md §10);
    this delegates to the dense factory there so training-side callers are
    unchanged.  Lazy import: serve.cache imports the model modules."""
    from repro.serve import cache as CM
    return CM.init_dense(cfg, batch, s_max, dtype)


def cache_length(caches) -> jax.Array:
    if "attn" in caches:
        return jax.tree.leaves(caches["attn"])[-1].reshape(-1)[0]
    return jax.tree.leaves(caches)[0].shape[0] * 0   # ssm: caller tracks position


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

class LMOut(NamedTuple):
    logits: Any
    aux: jax.Array
    caches: Any
    hidden: Any = None


def _scan_attn_stack(pctx, cfg, stacked, x, *, positions, layout, causal,
                     caches, memory, remat: str):
    """Uniform attention stack via scan; caches may be None."""

    def body(carry, xs):
        x, aux = carry
        if caches is None and memory is None:
            p_l = xs
            cache_l, mem_kv = None, None
        elif memory is not None and caches is None:
            p_l = xs
            mem_kv = ATT.cross_kv(pctx, cfg, p_l["xattn"], memory)
            cache_l = None
        elif memory is None:
            p_l, cache_l = xs
            mem_kv = None
        else:
            p_l, cache_l, mem_kv = xs
        x, new_cache, aux_l = BLK.apply_attn_block(
            pctx, cfg, p_l, x, positions=positions, layout=layout,
            causal=causal, cache=cache_l, memory_kv=mem_kv)
        out = new_cache if new_cache is not None else 0
        return (x, aux + aux_l), out

    body = schedule.apply_remat(body, remat)
    if caches is None and memory is None:
        xs = stacked
    elif memory is not None and caches is None:
        xs = stacked
    elif memory is None:
        xs = (stacked, caches)
    else:
        xs = (stacked, caches, memory)
    (x, aux), new_caches = lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, aux, (new_caches if caches is not None else None)


def _scan_mamba_stack(pctx, cfg, stacked, x, *, layout, states, remat: str):
    def body(carry, xs):
        x = carry
        if states is None:
            p_l, st_l = xs, None
        else:
            p_l, st_l = xs
        x, new_st = BLK.apply_mamba_block(pctx, cfg, p_l, x, layout=layout,
                                          state=st_l)
        return x, (new_st if new_st is not None else 0)

    body = schedule.apply_remat(body, remat)
    xs = stacked if states is None else (stacked, states)
    x, new_states = lax.scan(body, x, xs)
    return x, (new_states if states is not None else None)


def _hybrid_forward(pctx, cfg, params, x, *, positions, layouts, caches, remat):
    """zamba2: groups of `every` mamba blocks + a shared-params attention block."""
    every = max(1, cfg.shared_attn_every)
    Lm = cfg.num_layers
    G = Lm // every
    tail = Lm % every
    n_sets = max(1, cfg.num_shared_attn_sets)
    mparams = params["blocks"]["mamba"]
    shared = params["blocks"]["shared"]
    m_lay, a_lay = layouts

    main = jax.tree.map(lambda a: a[:G * every].reshape(G, every, *a.shape[1:]),
                        mparams)
    m_states = None if caches is None else caches["mamba"]
    main_states = None if m_states is None else jax.tree.map(
        lambda a: a[:G * every].reshape(G, every, *a.shape[1:]), m_states)
    a_caches = None if caches is None else caches["attn"]
    aux0 = jnp.zeros((), jnp.float32)

    def group_body(carry, xs):
        x, aux = carry
        if caches is None:
            p_g, gi = xs
            st_g, kv_g = None, None
        else:
            p_g, st_g, kv_g, gi = xs
        x, new_st = _scan_mamba_stack(pctx, cfg, p_g, x, layout=m_lay,
                                      states=st_g, remat="none")
        sel = jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, gi % n_sets, 0, keepdims=False),
            shared)
        x, new_kv, aux_l = BLK.apply_attn_block(
            pctx, cfg, sel, x, positions=positions, layout=a_lay, causal=True,
            cache=kv_g)
        outs = (new_st if new_st is not None else 0,
                new_kv if new_kv is not None else 0)
        return (x, aux + aux_l), outs

    group_body = schedule.apply_remat(group_body, remat)
    gi = jnp.arange(G)
    xs = (main, gi) if caches is None else (main, main_states, a_caches, gi)
    (x, aux), (new_m, new_kv) = lax.scan(group_body, (x, aux0), xs)

    new_caches = None
    tail_states = None if m_states is None else jax.tree.map(
        lambda a: a[G * every:], m_states)
    if tail:
        tail_p = jax.tree.map(lambda a: a[G * every:], mparams)
        x, new_tail = _scan_mamba_stack(pctx, cfg, tail_p, x, layout=m_lay,
                                        states=tail_states, remat=remat)
    else:
        new_tail = tail_states
    if caches is not None:
        flat_m = jax.tree.map(lambda a: a.reshape(-1, *a.shape[2:]), new_m)
        if tail:
            merged = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                                  flat_m, new_tail)
        else:
            merged = flat_m
        new_caches = {"mamba": merged, "attn": new_kv}
    return x, aux, new_caches


def forward(pctx, cfg: ModelConfig, params, batch: Dict[str, jax.Array], *,
            caches=None, remat: str = "none", skip_head: bool = False) -> LMOut:
    """batch: tokens [B,S] (+ patches/frames for vlm/audio, positions optional)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    compute_dtype = batch.get("_dtype", jnp.bfloat16)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    x = pctx.embed(params["embed"]["table"], tokens, compute_dtype)
    if cfg.family == "vlm" and "patches" in batch:
        P_len = batch["patches"].shape[1]
        pad = jnp.zeros((B, S - P_len, cfg.d_model), compute_dtype)
        patches_full = jnp.concatenate(
            [batch["patches"].astype(compute_dtype), pad], axis=1)
        is_prefix = (positions < P_len)[..., None]
        x = jnp.where(is_prefix, patches_full, x)
    x = pctx.canon(x)
    if cfg.embed_dropout and pctx.mode == "train":
        # shard-local: the mask is drawn on the canonical (seq-sharded)
        # residual, so no replicated [B,S,H] ever materializes
        x = pctx.dropout(x, cfg.embed_dropout, batch.get("dropout_rng"))

    layout = pctx.attn_layout(cfg.num_heads, B)   # B here is the global batch
    aux = jnp.zeros((), jnp.float32)
    new_caches = None

    if cfg.family == "ssm":
        states = None if caches is None else caches["mamba"]
        x, new_states = _scan_mamba_stack(pctx, cfg, params["blocks"], x,
                                          layout=layout, states=states,
                                          remat=remat)
        if caches is not None:
            new_caches = {"mamba": new_states}
    elif cfg.family == "hybrid":
        m_layout = pctx.attn_layout(SSM.n_heads(cfg), B)
        x, aux, new_caches = _hybrid_forward(
            pctx, cfg, params, x, positions=positions,
            layouts=(m_layout, layout), caches=caches, remat=remat)
    elif cfg.is_encdec:
        if caches is None:
            frames = batch["frames"].astype(compute_dtype)
            Bf, Fl, _ = frames.shape
            fpos = jnp.broadcast_to(jnp.arange(Fl, dtype=jnp.int32)[None],
                                    (Bf, Fl))
            mem = pctx.canon(frames)
            mem, _, _ = _scan_attn_stack(pctx, cfg, params["encoder"], mem,
                                         positions=fpos, layout=layout,
                                         causal=cfg.encoder_is_causal, caches=None,
                                         memory=None, remat=remat)
            mem = pctx.norm(cfg.norm_kind, params["enc_norm"], mem)
            x, aux, _ = _scan_attn_stack(pctx, cfg, params["blocks"], x,
                                         positions=positions, layout=layout,
                                         causal=True, caches=None, memory=mem,
                                         remat=remat)
        else:
            x, aux, attn_c = _scan_attn_stack(
                pctx, cfg, params["blocks"], x, positions=positions,
                layout=layout, causal=True, caches=caches["attn"],
                memory=caches["cross"], remat="none")
            new_caches = {"attn": attn_c, "cross": caches["cross"]}
    else:
        x, aux, attn_c = _scan_attn_stack(pctx, cfg, params["blocks"], x,
                                          positions=positions, layout=layout,
                                          causal=True, caches=caches and
                                          caches["attn"], memory=None,
                                          remat=remat)
        if caches is not None:
            new_caches = {"attn": attn_c}

    x = pctx.norm(cfg.norm_kind, params["final_norm"], x)
    if skip_head:
        return LMOut(None, aux, new_caches, hidden=x)
    head_w = (params["embed"]["table"].T.astype(compute_dtype)
              if cfg.tie_embeddings else
              params["lm_head"]["w"].astype(compute_dtype))
    logits = pctx.lm_head(x.astype(compute_dtype), head_w)
    logits = pctx.constraint(logits, pctx.logits_spec())
    return LMOut(logits, aux, new_caches)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def xent_loss(pctx, logits, labels, loss_mask=None):
    """Stable softmax cross-entropy over (possibly vocab-sharded) logits.

    Uses the one-hot-contraction form so vocab-dim reductions lower to psum over
    vocab shards under GSPMD (no gather from a sharded axis).
    """
    lf = logits.astype(jnp.float32)
    m = lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    lse = jnp.squeeze(m, -1) + jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1))
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    gold = jnp.sum(lf * onehot, axis=-1)
    nll = lse - gold
    if loss_mask is None:
        return jnp.mean(nll)
    w = loss_mask.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


def _loss_mask(cfg, batch):
    mask = batch.get("loss_mask")
    if mask is None and cfg.family == "vlm" and "patches" in batch:
        B, S = batch["tokens"].shape
        P_len = batch["patches"].shape[1]
        mask = jnp.broadcast_to(
            (jnp.arange(S) >= P_len)[None].astype(jnp.float32), (B, S))
    return mask


def head_loss(pctx, cfg: ModelConfig, params, hidden, labels, *, mask=None,
              compute_dtype=jnp.bfloat16):
    """Post-final-norm hidden states -> mean masked NLL.

    The LM-head + cross-entropy tail of :func:`train_loss`, factored out so
    a pipeline's LAST stage (parallel/pipeline.py) can run it on its own
    sub-mesh.  Routes through the fused chunked losses where the layout
    allows (hecaton's ``fused_lm_loss``; megatron seq layout's
    ``fused_lm_loss_seq`` with sharded labels) and otherwise materializes
    (sharded) logits and runs :func:`xent_loss` — exactly what the
    pre-refactor ``train_loss`` inlined.  ``params`` needs only the head
    leaves (``lm_head`` or the tied ``embed`` table)."""
    from repro.parallel import megatron as meg
    use_fused = (pctx.mesh is None or pctx.use_hecaton) and \
        pctx.pcfg.fused_loss
    use_meg_fused = (not use_fused and pctx.mesh is not None
                     and pctx.pcfg.fused_loss
                     and meg.seq_loss_ok(pctx, hidden.shape[1],
                                         cfg.padded_vocab))
    head_w = (params["embed"]["table"].T.astype(compute_dtype)
              if cfg.tie_embeddings else
              params["lm_head"]["w"].astype(compute_dtype))
    hidden = hidden.astype(compute_dtype)
    if use_meg_fused:
        # megatron seq layout: labels stay sharded; the head's vocab
        # chunks ring over the model axis (fused_lm_loss_seq)
        nll, cnt = meg.fused_lm_loss_seq(pctx, hidden, head_w, labels, mask)
    elif use_fused:
        from repro.core import hecaton as hec
        a = pctx.ax
        nll, cnt = hec.fused_lm_loss(
            hidden, head_w, labels, mask,
            mesh=pctx.mesh, t_ax=a.t_ax if a else "mx",
            h_ax=a.h_ax if a else "my",
            data_axes=a.data_axes if a else ("data",),
            overlap=pctx.overlap, comm_dtype=pctx.comm_dtype)
    else:
        logits = pctx.lm_head(hidden, head_w)
        logits = pctx.constraint(logits, pctx.logits_spec())
        return xent_loss(pctx, logits, labels, mask)
    return nll / jnp.maximum(cnt, 1.0)


def train_loss(pctx, cfg: ModelConfig, params, batch, *, remat: str = "fusion"):
    mask = _loss_mask(cfg, batch)
    out = forward(pctx, cfg, params, batch, remat=remat, skip_head=True)
    loss = head_loss(pctx, cfg, params, out.hidden, batch["labels"],
                     mask=mask, compute_dtype=batch.get("_dtype",
                                                        jnp.bfloat16))
    aux_coef = cfg.moe.aux_loss if cfg.moe else 0.0
    total = loss + aux_coef * out.aux
    return total, {"loss": loss, "aux": out.aux}
