"""AdamW with warmup-cosine schedule, global-norm clipping, and ZeRO-1-ready
state layout (parallel/zero.py shards these states over the data axis).

Implemented from scratch (no optax in this environment): functional
(init, update) pair operating on pytrees.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import RunConfig


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init(params) -> AdamState:
    zeros = lambda p: jax.tree.map(lambda a: jnp.zeros_like(a, jnp.float32), p)
    return AdamState(jnp.zeros((), jnp.int32), zeros(params), zeros(params))


def lr_schedule(rc: RunConfig, step, total_steps: int = 10_000):
    warm = jnp.minimum(1.0, (step + 1) / max(1, rc.warmup_steps))
    prog = jnp.clip((step - rc.warmup_steps) /
                    max(1, total_steps - rc.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return rc.lr * warm * (0.1 + 0.9 * cos)


def global_norm_sq(tree) -> jax.Array:
    """Sum of squared leaf elements (fp32).  Exposed separately so pipeline
    stages (parallel/pipeline.py) can combine per-stage partial sums into
    ONE global norm before clipping — the clip couples all stages."""
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sum(jnp.stack(leaves))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(global_norm_sq(tree))


def clip_by_global_norm(grads, max_norm: float, norm=None):
    """Clip by global norm; ``norm`` substitutes a precomputed norm (the
    pipeline's cross-stage combined norm) for the local tree norm."""
    g = global_norm(grads) if norm is None else norm
    scale = jnp.minimum(1.0, max_norm / (g + 1e-6))
    return jax.tree.map(lambda a: (a * scale).astype(a.dtype), grads), g


def update(params, grads, state: AdamState, rc: RunConfig,
           total_steps: int = 10_000, *,
           grad_norm=None) -> Tuple[Any, AdamState, Dict]:
    grads, gnorm = clip_by_global_norm(grads, rc.grad_clip, norm=grad_norm)
    step = state.step + 1
    lr = lr_schedule(rc, state.step, total_steps)
    b1, b2, eps = rc.beta1, rc.beta2, 1e-8

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mh = m2 / (1 - b1 ** step)
        vh = v2 / (1 - b2 ** step)
        delta = mh / (jnp.sqrt(vh) + eps) + rc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
