"""AdamW with warmup-cosine schedule, global-norm clipping, and ZeRO-1-ready
state layout (parallel/zero.py shards these states over the data axis).

Implemented from scratch (no optax in this environment): functional
(init, update) pair operating on pytrees.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import RunConfig


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any
    # EWMA of ACCEPTED (finite, non-spiking) gradient norms, consumed by the
    # in-graph skip-update guard (runtime/guard.py, docs/DESIGN.md §8).  It
    # lives in the optimizer state — not the guard object — so it
    # checkpoints, restores and re-shards with the rest of the state: a
    # restarted incarnation resumes with the same spike baseline it crashed
    # with.  0.0 means "unseeded" (norms are positive, so 0 is unambiguous).
    gnorm_ewma: jax.Array


def init(params) -> AdamState:
    zeros = lambda p: jax.tree.map(lambda a: jnp.zeros_like(a, jnp.float32), p)
    return AdamState(jnp.zeros((), jnp.int32), zeros(params), zeros(params),
                     jnp.zeros((), jnp.float32))


def lr_schedule(rc: RunConfig, step, total_steps: int = 10_000):
    warm = jnp.minimum(1.0, (step + 1) / max(1, rc.warmup_steps))
    prog = jnp.clip((step - rc.warmup_steps) /
                    max(1, total_steps - rc.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return rc.lr * warm * (0.1 + 0.9 * cos)


def global_norm_sq(tree) -> jax.Array:
    """Sum of squared leaf elements (fp32).  Exposed separately so pipeline
    stages (parallel/pipeline.py) can combine per-stage partial sums into
    ONE global norm before clipping — the clip couples all stages."""
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sum(jnp.stack(leaves))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(global_norm_sq(tree))


def clip_by_global_norm(grads, max_norm: float, norm=None):
    """Clip by global norm; ``norm`` substitutes a precomputed norm (the
    pipeline's cross-stage combined norm) for the local tree norm."""
    g = global_norm(grads) if norm is None else norm
    scale = jnp.minimum(1.0, max_norm / (g + 1e-6))
    return jax.tree.map(lambda a: (a * scale).astype(a.dtype), grads), g


def guard_predicate(gnorm, ewma, guard):
    """The in-graph skip-update predicate (runtime/guard.py tentpole,
    docs/DESIGN.md §8): ``ok = finite AND NOT spike``.

    Finiteness of EVERY grad leaf is read off ONE scalar — the global norm
    already computed for clipping.  ``global_norm_sq`` sums squares of all
    leaves in fp32: a NaN anywhere propagates through the sum; ±Inf squares
    to +Inf; squares are non-negative so no cancellation can hide either.
    The spike test compares against the EWMA of previously ACCEPTED norms
    (``AdamState.gnorm_ewma``); an unseeded EWMA (0.0) never flags a spike,
    and NaN compares false so a non-finite norm cannot double-fire.

    Returns ``(ok, finite)`` scalar bool arrays."""
    finite = jnp.isfinite(gnorm)
    spike = (ewma > 0.0) & (gnorm > guard.grad_spike_factor * ewma)
    return finite & ~spike, finite


def update(params, grads, state: AdamState, rc: RunConfig,
           total_steps: int = 10_000, *,
           grad_norm=None, guard=None) -> Tuple[Any, AdamState, Dict]:
    """One AdamW step; with ``guard`` (a :class:`repro.config.GuardConfig`)
    the update is applied under a ``jax.lax.cond`` on the
    :func:`guard_predicate` — a bad microbatch costs a no-op step (params
    and every optimizer leaf pass through BIT-UNCHANGED, the step counter
    does not advance) instead of a crash or a retrace: both branches trace
    once, the predicate picks one at run time.  ``cond`` rather than
    per-leaf ``jnp.where`` selects because accepted steps (all of training)
    must not pay for the guard: XLA-CPU materializes the selects as extra
    full-state passes (~10% step time), while the cond's taken branch is
    exactly the unguarded update.  (Multiply-masking is not an option at
    all: NaN * 0 is NaN; the skipped path must be bit-clean.)
    ``guard=None`` reproduces the unguarded numerics exactly."""
    grads, gnorm = clip_by_global_norm(grads, rc.grad_clip, norm=grad_norm)
    ok = None
    if guard is not None:
        ok, finite = guard_predicate(gnorm, state.gnorm_ewma, guard)
    lr = lr_schedule(rc, state.step, total_steps)
    b1, b2, eps = rc.beta1, rc.beta2, 1e-8
    # the EWMA folds in the (unclipped) norm only on ACCEPTED steps — a
    # skipped spike must not drag its own baseline up (cf. StepTimer's
    # freeze-while-slow); first accepted norm seeds it
    a = jnp.float32(guard.grad_ewma_alpha if guard is not None else 0.1)

    def applied(_):
        step = state.step + 1

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * gf
            v2 = b2 * v + (1 - b2) * gf * gf
            mh = m2 / (1 - b1 ** step)
            vh = v2 / (1 - b2 ** step)
            delta = (mh / (jnp.sqrt(vh) + eps)
                     + rc.weight_decay * p.astype(jnp.float32))
            p2 = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return p2, m2, v2

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        out = [upd(p, g, m, v)
               for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        seeded = state.gnorm_ewma > 0.0
        folded = jnp.where(seeded,
                           (1.0 - a) * state.gnorm_ewma + a * gnorm, gnorm)
        return new_p, AdamState(step, new_m, new_v, folded)

    if ok is None:
        new_p, new_state = applied(None)
        return new_p, new_state, {"grad_norm": gnorm, "lr": lr}

    new_p, new_state = jax.lax.cond(ok, applied,
                                    lambda _: (params, state), None)
    metrics = {"grad_norm": gnorm, "lr": lr, "update_ok": ok,
               "update_skipped": 1.0 - ok.astype(jnp.float32),
               "nonfinite": 1.0 - finite.astype(jnp.float32)}
    return new_p, new_state, metrics
