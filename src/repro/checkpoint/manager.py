"""Fault-tolerant sharded checkpointing.

Design (no orbax in this environment — built from scratch):

  * **Atomic**: writes go to ``step_K.tmp/`` then ``os.replace`` to ``step_K/``;
    a crash mid-write never corrupts the latest checkpoint.
  * **Sharded**: each leaf is saved as one ``.npy`` per *data-axis shard owner*
    — on a real multi-host pod each host writes only its addressable shards
    (here: single host writes all, layout identical).
  * **Elastic restore**: leaves are saved UNSHARDED logically (global arrays),
    so a checkpoint written on a (16,16) mesh restores onto (2,16,16), a
    different microbatch count, or a rescaled data axis — re-sharding happens
    at ``device_put`` with the *target* sharding (elastic scaling / node-failure
    recovery path used by runtime/fault.py).
  * **Self-describing**: ``meta.json`` records step, config hash, tree structure.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        out[name] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any],
             extra_meta: Optional[Dict] = None) -> str:
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = _leaf_paths(state)
        manifest = {}
        for name, leaf in leaves.items():
            arr = np.asarray(jax.device_get(leaf))
            fn = name.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest[name] = {"file": fn, "shape": list(arr.shape),
                              "dtype": str(arr.dtype)}
        meta = {"step": step, "manifest": manifest, **(extra_meta or {})}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        os.replace(tmp, final)                      # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, int]:
        """Restore into the structure of ``template`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings`` (optional matching tree) re-shards
        for the *current* mesh — the elastic-scaling path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        leaves = _leaf_paths(template)
        shard_leaves = _leaf_paths(shardings) if shardings is not None else {}
        out = {}
        for name, leaf in leaves.items():
            info = meta["manifest"][name]
            arr = np.load(os.path.join(d, info["file"]))
            assert list(arr.shape) == list(leaf.shape), \
                f"{name}: ckpt {arr.shape} vs template {leaf.shape}"
            sh = shard_leaves.get(name)
            out[name] = (jax.device_put(arr, sh) if sh is not None
                         else jax.numpy.asarray(arr))
        # rebuild the tree
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        rebuilt = []
        for kp, _ in flat:
            name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in kp)
            rebuilt.append(out[name])
        return jax.tree_util.tree_unflatten(treedef, rebuilt), step
