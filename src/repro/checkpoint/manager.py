"""Fault-tolerant sharded checkpointing — synchronous and asynchronous.

Design (no orbax in this environment — built from scratch, mirroring its
``save / wait_until_finished / check_error`` surface):

  * **Atomic**: writes go to ``step_K.tmp/`` then ``os.replace`` to ``step_K/``;
    a crash or kill mid-write never corrupts the latest checkpoint.  Stale
    ``.tmp`` directories left by a dead incarnation are invisible to
    :meth:`CheckpointManager.all_steps` and are swept on manager construction
    (and by :meth:`AsyncCheckpointManager.abort`), so a restart can never
    resume from a half-published step.
  * **Sharded**: each leaf is saved as one ``.npy`` per *data-axis shard owner*
    — on a real multi-host pod each host writes only its addressable shards
    (here: single host writes all, layout identical).
  * **Elastic restore**: leaves are saved UNSHARDED logically (global arrays),
    so a checkpoint written on a (16,16) mesh restores onto (2,16,16), a
    different microbatch count, or a rescaled data axis — re-sharding happens
    at ``device_put`` with the *target* sharding (elastic scaling / node-failure
    recovery path used by runtime/fault.py).
  * **Self-describing**: ``meta.json`` records step, tree structure, and the
    logical dtype of every leaf.  Leaf files are numbered (``leaf_00000.npy``)
    and mapped through the manifest, so pytree key names can contain any
    character (``__``, ``/``, ``%``) without filename collisions; path
    segments are %-escaped in the manifest so ``{"a/b": x}`` and
    ``{"a": {"b": x}}`` stay distinct.  Dtypes ``.npy`` cannot round-trip
    (``bfloat16`` and the other ml_dtypes extension types load back as raw
    void) are stored as raw bytes with the logical dtype in the manifest.

Asynchronous path (:class:`AsyncCheckpointManager`, the ISSUE 4 tentpole):
``save_async`` runs only the device→host snapshot on the caller (train-loop)
thread — a ``jax.device_get`` into a *reusable host staging arena* — and hands
serialization + the atomic publish to a background writer thread.  The arena
copy is required for correctness, not just speed: on the CPU backend
``device_get`` can alias the device buffer, and with ``donate_argnums`` the
next train step reuses that memory; the arena gives the writer stable storage
while the step ahead runs.  The arena is double-buffered (``max_inflight``
slots): acquiring a slot blocks only when every slot still has an unwritten
snapshot, which bounds host memory and applies natural backpressure instead
of dropping checkpoints.  Writer failures are sticky and surface on the next
``save_async`` / ``check_error`` / ``wait_until_finished``; ``abort`` (called
by ``runtime/fault.run_supervised`` when an incarnation dies) discards queued
snapshots, interrupts a mid-write publish between leaves, and sweeps ``.tmp``
debris so the restart sees only fully-published steps.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_COPY_POOL: Optional[ThreadPoolExecutor] = None
_COPY_POOL_LOCK = threading.Lock()


def _copy_pool() -> ThreadPoolExecutor:
    """Shared pool for the staging-arena memcpy: ``np.copyto`` releases the
    GIL but is single-threaded, and the boundary snapshot is exactly the
    stall the async path is supposed to minimize — copying the leaves
    concurrently overlaps page faults and uses the full memory bandwidth."""
    global _COPY_POOL
    with _COPY_POOL_LOCK:
        if _COPY_POOL is None:
            _COPY_POOL = ThreadPoolExecutor(
                max_workers=min(8, 2 * (os.cpu_count() or 2)),
                thread_name_prefix="ckpt-stage")
        return _COPY_POOL


def _fsync_path(path: str):
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _escape(segment: str) -> str:
    """%-escape a pytree path segment so joined names are collision-free
    (a dict key containing "/" must not alias a nested dict path)."""
    return segment.replace("%", "%25").replace("/", "%2F")


def _leaf_paths(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in flat:
        name = "/".join(_escape(str(getattr(k, "key", getattr(k, "idx", k))))
                        for k in kp)
        out[name] = leaf
    return out


def _npy_safe(dtype: np.dtype) -> bool:
    """Can the ``.npy`` format round-trip this dtype?  ml_dtypes extension
    types (bfloat16, float8_*) save fine but LOAD back as raw void."""
    return np.dtype(dtype).isbuiltin == 1


class _Aborted(Exception):
    """Internal: a mid-write save was interrupted by :meth:`abort`."""


class CheckpointManager:
    """Synchronous atomic checkpointing (the blocking baseline path).

    ``durable=True`` fsyncs every leaf file, the metadata and the directory
    before the atomic publish (and the parent after), so a published step
    survives power loss, not just process death.  Off by default — on
    network/9p filesystems fsync costs seconds, and the tests/examples only
    need crash-consistency against process kills."""

    def __init__(self, directory: str, keep: int = 3, *,
                 durable: bool = False):
        self.dir = directory
        self.keep = keep
        self.durable = durable
        os.makedirs(directory, exist_ok=True)
        self._clean_stale_tmp()

    def _clean_stale_tmp(self):
        """Sweep half-written ``step_K.tmp/`` debris from a dead incarnation.
        Safe only when no writer is active against this directory (true at
        construction and after an abort drain)."""
        for d in os.listdir(self.dir):
            if d.startswith("step_") and d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any],
             extra_meta: Optional[Dict] = None) -> str:
        """Blocking save: snapshot, serialize and publish on this thread."""
        return self._write(step, self._snapshot_host(state), extra_meta)

    def _snapshot_host(self, state, slot: Optional[Dict] = None):
        """Device→host snapshot of every leaf, as a flat {name: np.ndarray}.

        With a ``slot`` (the async staging arena), host bytes are copied into
        the slot's reusable buffers so the result owns stable storage even
        when ``device_get`` aliases a soon-to-be-donated device buffer."""
        leaves = _leaf_paths(state)
        host = jax.device_get(leaves)            # one batched transfer
        if slot is None:
            return {k: np.asarray(v) for k, v in host.items()}
        snap = {}
        jobs = []
        for name, arr in host.items():
            arr = np.asarray(arr)
            buf = slot.get(name)
            if (buf is None or buf.shape != arr.shape
                    or buf.dtype != arr.dtype):
                slot[name] = buf = np.empty(arr.shape, arr.dtype)
            jobs.append((buf, arr))
            snap[name] = buf
        # parallel memcpy into the arena (np.copyto releases the GIL)
        list(_copy_pool().map(lambda ba: np.copyto(ba[0], ba[1]), jobs))
        return snap

    def _write(self, step: int, snap: Dict[str, np.ndarray],
               extra_meta: Optional[Dict] = None, abort_check=None) -> str:
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {}
        for i, name in enumerate(sorted(snap)):
            if abort_check is not None and abort_check():
                raise _Aborted(step)
            arr = snap[name]
            fn = f"leaf_{i:05d}.npy"
            info = {"file": fn, "shape": list(arr.shape),
                    "dtype": str(arr.dtype)}
            if _npy_safe(arr.dtype):
                np.save(os.path.join(tmp, fn), arr)
            else:                      # bf16 etc: raw bytes + logical dtype
                info["raw"] = True
                np.save(os.path.join(tmp, fn),
                        np.frombuffer(arr.tobytes(), np.uint8))
            manifest[name] = info
        meta = {"step": step, "manifest": manifest, **(extra_meta or {})}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            if self.durable:
                f.flush()
                os.fsync(f.fileno())
        if self.durable:                 # data durable BEFORE the publish
            for info in manifest.values():
                _fsync_path(os.path.join(tmp, info["file"]))
            _fsync_path(tmp)
        os.replace(tmp, final)                      # atomic publish
        if self.durable:
            _fsync_path(self.dir)        # the rename itself
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def all_steps(self):
        """Published steps only — ``.tmp`` (in-flight or crashed) never listed."""
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    # orbax-like surface, trivially satisfied on the sync path (so the train
    # loop / supervisor can treat both managers uniformly)
    # ------------------------------------------------------------------
    def save_async(self, step: int, state: Dict[str, Any],
                   extra_meta: Optional[Dict] = None) -> None:
        """On the sync manager this is just a blocking :meth:`save`."""
        self.save(step, state, extra_meta)

    def wait_until_finished(self):
        pass

    def check_error(self):
        pass

    def abort(self):
        self._clean_stale_tmp()

    def close(self):
        pass

    # ------------------------------------------------------------------
    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, int]:
        """Restore into the structure of ``template`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings`` (optional matching tree) re-shards
        for the *current* mesh — the elastic-scaling path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        leaves = _leaf_paths(template)
        shard_leaves = _leaf_paths(shardings) if shardings is not None else {}
        out = {}
        for name, leaf in leaves.items():
            info = meta["manifest"][name]
            arr = np.load(os.path.join(d, info["file"]))
            if info.get("raw"):
                arr = np.frombuffer(arr.tobytes(),
                                    dtype=np.dtype(info["dtype"])
                                    ).reshape(info["shape"])
            assert list(arr.shape) == list(leaf.shape), \
                f"{name}: ckpt {arr.shape} vs template {leaf.shape}"
            sh = shard_leaves.get(name)
            out[name] = (jax.device_put(arr, sh) if sh is not None
                         else jax.numpy.asarray(arr))
        # rebuild the tree
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        rebuilt = []
        for kp, _ in flat:
            name = "/".join(_escape(str(getattr(k, "key",
                                                getattr(k, "idx", k))))
                            for k in kp)
            rebuilt.append(out[name])
        return jax.tree_util.tree_unflatten(treedef, rebuilt), step


class AsyncCheckpointManager(CheckpointManager):
    """Non-blocking checkpointing: snapshot on the step boundary, serialize +
    atomically publish on a background writer thread (module docstring)."""

    def __init__(self, directory: str, keep: int = 3, *,
                 max_inflight: int = 2, staging: str = "host",
                 durable: bool = False):
        super().__init__(directory, keep, durable=durable)
        assert staging in ("host", "sync"), staging
        assert max_inflight >= 1, max_inflight
        self.staging = staging
        self._free: "queue.Queue[Dict]" = queue.Queue()
        for _ in range(max_inflight):
            self._free.put({})                   # arena slot: name -> buffer
        self._work: "queue.Queue" = queue.Queue()
        self._cv = threading.Condition()
        self._inflight = 0
        self._error: Optional[BaseException] = None
        self._abort = threading.Event()
        self._closed = False
        self._thread = threading.Thread(target=self._writer_loop,
                                        name="ckpt-writer", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------------
    def save_async(self, step: int, state: Dict[str, Any],
                   extra_meta: Optional[Dict] = None) -> None:
        """Snapshot ``state`` to a host staging slot and return; the writer
        thread serializes and publishes.  Blocks only for the device→host
        copy, or when all ``max_inflight`` slots still hold unwritten
        snapshots (backpressure).  Raises a prior writer error, if any."""
        self.check_error()
        if self.staging == "sync" or self._closed:
            self.save(step, state, extra_meta)
            return
        slot = self._free.get()                  # backpressure point
        try:
            snap = self._snapshot_host(state, slot)
        except BaseException:
            self._free.put(slot)
            raise
        with self._cv:
            self._inflight += 1
        self._work.put((step, slot, snap, extra_meta))

    def _writer_loop(self):
        while True:
            item = self._work.get()
            if item is None:
                return
            step, slot, snap, extra_meta = item
            try:
                if not self._abort.is_set():
                    self._write(step, snap, extra_meta,
                                abort_check=self._abort.is_set)
            except _Aborted:
                shutil.rmtree(os.path.join(self.dir, f"step_{step:08d}.tmp"),
                              ignore_errors=True)
            except BaseException as e:           # sticky: surfaced to caller
                if self._error is None:
                    self._error = e
                shutil.rmtree(os.path.join(self.dir, f"step_{step:08d}.tmp"),
                              ignore_errors=True)
            finally:
                self._free.put(slot)
                with self._cv:
                    self._inflight -= 1
                    self._cv.notify_all()

    # ------------------------------------------------------------------
    def wait_until_finished(self):
        """Drain every queued/in-flight save, then surface writer errors."""
        with self._cv:
            while self._inflight > 0:
                self._cv.wait()
        self.check_error()

    def check_error(self):
        """Re-raise the first writer failure (sticky, orbax semantics)."""
        if self._error is not None:
            raise RuntimeError(
                f"async checkpoint writer failed: {self._error!r}"
            ) from self._error

    def abort(self):
        """Discard queued snapshots and interrupt any mid-write publish —
        called by the fault supervisor when this incarnation is dead, so a
        restart can never observe a save issued after the failure point.
        Published checkpoints are untouched; ``.tmp`` debris is swept, and a
        sticky writer error is cleared with it: the dead incarnation's
        persistence failure is fenced exactly like its in-flight saves, so
        the NEXT incarnation starts clean instead of dying at its first
        checkpoint boundary on a stale error (e.g. a recovered ENOSPC)."""
        self._abort.set()
        with self._cv:
            while self._inflight > 0:
                self._cv.wait()
        self._abort.clear()
        self._error = None
        self._clean_stale_tmp()

    def close(self):
        """Drain (without raising) and stop the writer thread."""
        if self._closed:
            return
        with self._cv:
            while self._inflight > 0:
                self._cv.wait()
        self._closed = True
        self._work.put(None)
        self._thread.join(timeout=60)


def make_manager(directory: str, ccfg=None) -> CheckpointManager:
    """Build the manager a :class:`repro.config.CheckpointConfig` describes
    (``None`` → the synchronous default)."""
    if ccfg is None:
        return CheckpointManager(directory)
    if ccfg.async_:
        return AsyncCheckpointManager(directory, keep=ccfg.keep,
                                      max_inflight=ccfg.max_inflight,
                                      staging=ccfg.staging,
                                      durable=ccfg.durable)
    return CheckpointManager(directory, keep=ccfg.keep, durable=ccfg.durable)
