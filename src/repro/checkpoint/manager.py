"""Fault-tolerant sharded checkpointing — multi-writer, quorum-published.

Design (no orbax in this environment — built from scratch, mirroring its
``save / wait_until_finished / check_error`` surface):

  * **Writer group** (the ISSUE 6 tentpole): a save fans out over ``writers``
    logical writers.  Each writer persists only its addressable shards into a
    per-writer subdirectory (``writer_KK/``) and then atomically publishes a
    *partial manifest* (``writer_KK/manifest.json``) recording, per shard, the
    file, shape, logical dtype, byte length, and a crc32 checksum of the
    on-disk bytes, plus a self-checksum over the shard table.  On a real pod
    each writer is one host (for pipeline state: one writer per stage/pod via
    ``parallel/pipeline.stage_writer_map``); here the writers are threads with
    the identical on-disk protocol.  Shards with no explicit writer mapping
    are byte-balanced across the group (:func:`partition_shards`).
  * **Two-phase quorum publish**: a coordinator waits for the writer group,
    re-reads every partial manifest from disk, verifies its self-checksum and
    that every listed shard file is present with the recorded length, and
    only then writes the step's global ``MANIFEST.json`` (via ``.tmp`` +
    ``os.replace``) and atomically publishes the step directory
    (``step_K.tmp/`` → ``step_K/``).  Publication requires at least
    ``quorum`` verified partial manifests AND complete shard coverage; a
    writer that dies between its shard writes and its manifest publish
    (``writer_fault`` injection window, ``FailureInjector.check_writer``)
    therefore leaves torn debris that is swept and never listed by
    :meth:`CheckpointManager.all_steps` — a restart can never resume from a
    half-written step.  ``quorum < writers`` only changes the outcome when
    the dead writers owned zero shards (coverage stays complete), the
    single-filesystem analogue of publishing with a replication quorum.
  * **End-to-end integrity**: restore is *quorum reassembly* — it selects the
    newest step whose global manifest is complete, and (``verify=True``)
    checks every shard's byte length and crc32 against the manifest before
    ``device_put``.  A bit-flipped or truncated shard file raises
    :class:`CheckpointCorruptionError` naming the file, instead of silently
    loading garbage into the optimizer state.
  * **Elastic restore**: leaves are saved UNSHARDED logically (global
    arrays), so a checkpoint written by N writers on one grid restores onto
    any other grid — or writer count — with *target* shardings applied at
    ``device_put`` (the elastic-scaling / node-failure path of
    runtime/fault.py).  The writer partition is a persistence layout, not a
    numerics layout.
  * **Self-describing**: the global manifest records step, tree structure,
    the committed writer set, and the logical dtype of every leaf.  Leaf
    files are numbered per writer (``writer_00/leaf_00000.npy``) and mapped
    through the manifest, so pytree key names can contain any character
    (``__``, ``/``, ``%``) without filename collisions; path segments are
    %-escaped so ``{"a/b": x}`` and ``{"a": {"b": x}}`` stay distinct.
    Dtypes ``.npy`` cannot round-trip (``bfloat16`` and the other ml_dtypes
    extension types load back as raw void) are stored as raw bytes with the
    logical dtype in the manifest.
  * **Tolerant listing**: ``all_steps`` ignores foreign files, ``.tmp``
    debris, and half-deleted step directories (a GC interrupted mid-rmtree,
    a torn multi-writer publish) — these states are reachable with
    concurrent writers and must not crash step listing.  GC renames a step
    out of the namespace (``step_K`` → ``step_K.gc.tmp``) before deleting
    it, so an interrupted GC leaves ``.tmp`` debris, never a listable
    half-step.

Asynchronous path (:class:`AsyncCheckpointManager`): ``save_async`` runs only
the device→host snapshot on the caller (train-loop) thread — a
``jax.device_get`` into a *reusable host staging arena* — and hands the
writer-group fan-out + quorum publish to a background coordinator thread.
The arena copy is required for correctness, not just speed: on the CPU
backend ``device_get`` can alias the device buffer, and with
``donate_argnums`` the next train step reuses that memory; the arena gives
the writers stable storage while the step ahead runs.  The arena is
double-buffered (``max_inflight`` slots): acquiring a slot blocks only when
every slot still has an unwritten snapshot, which bounds host memory and
applies natural backpressure instead of dropping checkpoints.  Writer-group
failures are sticky and surface on the next ``save_async`` / ``check_error``
/ ``wait_until_finished``; ``abort`` (called by
``runtime/fault.run_supervised`` when an incarnation dies) fences the WHOLE
writer group: queued snapshots are discarded, every in-flight writer is
interrupted between shards, ``.tmp`` debris is swept, and the sticky error
is cleared, so the restart sees only fully-published steps.
"""

from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint import wire

_COPY_POOL: Optional[ThreadPoolExecutor] = None
_WRITE_POOL: Optional[ThreadPoolExecutor] = None
_POOL_LOCK = threading.Lock()

_STEP_RE = re.compile(r"^step_(\d{8})$")
MANIFEST = wire.MANIFEST            # global (coordinator-published) manifest
PARTIAL_MANIFEST = wire.PARTIAL_MANIFEST  # per-writer partial manifest
_FLEET_DIR = ".fleet"               # writer-fleet scratch (runtime/procs.py)


def _copy_pool() -> ThreadPoolExecutor:
    """Shared pool for the staging-arena memcpy: ``np.copyto`` releases the
    GIL but is single-threaded, and the boundary snapshot is exactly the
    stall the async path is supposed to minimize — copying the leaves
    concurrently overlaps page faults and uses the full memory bandwidth."""
    global _COPY_POOL
    with _POOL_LOCK:
        if _COPY_POOL is None:
            _COPY_POOL = ThreadPoolExecutor(
                max_workers=min(8, 2 * (os.cpu_count() or 2)),
                thread_name_prefix="ckpt-stage")
        return _COPY_POOL


def _write_pool() -> ThreadPoolExecutor:
    """Shared pool the writer group runs on.  ``np.save`` on a file object,
    the crc read-back, and ``os.write`` all release the GIL, so N writers
    genuinely parallelize the serialize+persist wall time (the
    ``checkpoint_multiwriter`` bench rows assert 4 writers ≤ 1)."""
    global _WRITE_POOL
    with _POOL_LOCK:
        if _WRITE_POOL is None:
            _WRITE_POOL = ThreadPoolExecutor(
                max_workers=min(8, (os.cpu_count() or 2)),
                thread_name_prefix="ckpt-write")
        return _WRITE_POOL


def _fsync_path(path: str):
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _escape(segment: str) -> str:
    """%-escape a pytree path segment so joined names are collision-free
    (a dict key containing "/" must not alias a nested dict path)."""
    return segment.replace("%", "%25").replace("/", "%2F")


def _leaf_paths(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in flat:
        name = "/".join(_escape(str(getattr(k, "key", getattr(k, "idx", k))))
                        for k in kp)
        out[name] = leaf
    return out


# Format primitives live in checkpoint/wire.py (jax-free, shared with the
# cross-process writer fleet so both runtimes emit bit-identical trees);
# the local names are kept for callers and tests.
_npy_safe = wire.npy_safe
_crc = wire.crc
_shards_crc = wire.shards_crc


class CheckpointCorruptionError(RuntimeError):
    """A shard file or manifest failed integrity verification on restore —
    the error message names the offending file so the operator can map it to
    a disk/host (restore refuses to load garbage silently)."""


class QuorumError(RuntimeError):
    """The coordinator could not assemble a publishable step: fewer than
    ``quorum`` partial manifests verified, or shard coverage is incomplete
    (a writer died between shard-write and manifest-publish)."""


class _Aborted(Exception):
    """Internal: a mid-write save was interrupted by :meth:`abort`."""


def partition_shards(sizes: Dict[str, int], n_writers: int,
                     writer_map: Optional[Callable[[str], Optional[int]]]
                     = None) -> Dict[str, int]:
    """Deterministic shard→writer assignment.

    ``writer_map(name)`` pins a shard to a writer (the pipeline stage→writer
    mapping, ``parallel/pipeline.stage_writer_map``); unpinned shards are
    greedily byte-balanced (largest first) so no writer becomes the
    bandwidth ceiling.  Pure function of (names, sizes) — sync and async
    saves of the same state produce identical layouts."""
    assert n_writers >= 1
    owner: Dict[str, int] = {}
    load = [0] * n_writers
    free: List[str] = []
    for name in sorted(sizes):
        w = writer_map(name) if writer_map is not None else None
        if w is not None and 0 <= int(w) < n_writers:
            owner[name] = int(w)
            load[int(w)] += sizes[name]
        else:
            free.append(name)
    for name in sorted(free, key=lambda n: (-sizes[n], n)):
        w = min(range(n_writers), key=lambda i: (load[i], i))
        owner[name] = w
        load[w] += sizes[name]
    return owner


class CheckpointManager:
    """Synchronous multi-writer checkpointing (the blocking baseline path).

    ``writers`` logical writers persist disjoint shard sets in parallel;
    ``quorum`` (default: all writers) partial manifests must verify before
    the coordinator publishes (module docstring).  ``verify=True`` checks
    every shard's length+crc32 on restore.  ``writer_map`` pins shards to
    writers (pipeline stage→writer); ``writer_fault(step, writer)`` is a
    fault-injection hook invoked between a writer's shard writes and its
    partial-manifest publish (``FailureInjector.check_writer``).

    ``durable=True`` fsyncs every shard file, both manifest tiers and the
    directories around the atomic publish (and the parent after), so a
    published step survives power loss, not just process death.  Off by
    default — on network/9p filesystems fsync costs seconds, and the
    tests/examples only need crash-consistency against process kills."""

    def __init__(self, directory: str, keep: int = 3, *,
                 durable: bool = False, writers: int = 1,
                 quorum: Optional[int] = None, verify: bool = True,
                 writer_map: Optional[Callable[[str], Optional[int]]] = None,
                 writer_fault: Optional[Callable[[int, int], None]] = None,
                 writer_procs: bool = False, writer_timeout: float = 5.0,
                 reassign: int = 1,
                 proc_fault: Optional[Callable[[int, int],
                                               Optional[Dict]]] = None):
        assert writers >= 1, f"writers={writers} must be >= 1"
        assert writer_timeout > 0, (
            f"writer_timeout={writer_timeout} must be > 0")
        assert reassign >= 0, f"reassign={reassign} must be >= 0"
        self.dir = directory
        self.keep = keep
        self.durable = durable
        self.writers = writers
        self.quorum = writers if quorum is None else quorum
        assert 1 <= self.quorum <= writers, (
            f"quorum={self.quorum} must be in [1, writers={writers}]")
        self.verify = verify
        self.writer_map = writer_map
        self.writer_fault = writer_fault
        # cross-process writer fleet (runtime/procs.py, docs/DESIGN.md §9):
        # each logical writer is its own OS process with a heartbeat lease;
        # proc_fault(step, writer) -> fault spec dict or None is the
        # process-level injection hook (FailureInjector.proc_fault)
        self.writer_procs = writer_procs
        self.writer_timeout = writer_timeout
        self.reassign = reassign
        self.proc_fault = proc_fault
        self._fleet = None
        os.makedirs(directory, exist_ok=True)
        self._clean_stale_tmp()

    def _clean_stale_tmp(self):
        """Sweep torn debris from a dead incarnation: ``step_K.tmp/``
        (in-flight or crashed writes, interrupted GC renames) and published
        -namespace step directories whose global manifest is absent or
        unparseable (a half-deleted step, a foreign dir squatting on the
        name), plus writer-fleet scratch (``.fleet/`` heartbeats and
        handover spill files from a SIGKILLed coordinator — its orphaned
        writer children self-exit on the ppid check within a heartbeat
        interval, runtime/procs.py).  Safe only when no writer is active
        against this directory (true at construction and after an abort
        drain, which fences the fleet first)."""
        for d in os.listdir(self.dir):
            p = os.path.join(self.dir, d)
            if d.startswith("step_") and d.endswith(".tmp"):
                shutil.rmtree(p, ignore_errors=True)
            elif d == _FLEET_DIR:
                shutil.rmtree(p, ignore_errors=True)
            elif _STEP_RE.match(d) and os.path.isdir(p) \
                    and not self._manifest_complete(p):
                shutil.rmtree(p, ignore_errors=True)

    @staticmethod
    def _manifest_complete(step_dir: str) -> bool:
        """Does ``step_dir`` hold a parseable, complete global manifest?
        Never raises — torn json / missing file / permission errors all mean
        "not a restorable step" (the tolerant-listing contract).  The type
        check matters: a foreign ``MANIFEST.json`` holding a JSON array /
        string / null parses fine but is not a manifest, and must read as
        "not restorable", not crash ``all_steps``."""
        try:
            with open(os.path.join(step_dir, MANIFEST)) as f:
                meta = json.load(f)
            return isinstance(meta, dict) and bool(meta.get("complete"))
        except (OSError, ValueError):
            return False

    # ------------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any],
             extra_meta: Optional[Dict] = None) -> str:
        """Blocking save: snapshot, fan out the writer group and publish on
        this thread (the writers still run on the shared write pool)."""
        return self._write(step, self._snapshot_host(state), extra_meta)

    def _snapshot_host(self, state, slot: Optional[Dict] = None):
        """Device→host snapshot of every leaf, as a flat {name: np.ndarray}.

        With a ``slot`` (the async staging arena), host bytes are copied into
        the slot's reusable buffers so the result owns stable storage even
        when ``device_get`` aliases a soon-to-be-donated device buffer."""
        leaves = _leaf_paths(state)
        host = jax.device_get(leaves)            # one batched transfer
        if slot is None:
            return {k: np.asarray(v) for k, v in host.items()}
        snap = {}
        jobs = []
        for name, arr in host.items():
            arr = np.asarray(arr)
            buf = slot.get(name)
            if (buf is None or buf.shape != arr.shape
                    or buf.dtype != arr.dtype):
                slot[name] = buf = np.empty(arr.shape, arr.dtype)
            jobs.append((buf, arr))
            snap[name] = buf
        # parallel memcpy into the arena (np.copyto releases the GIL)
        list(_copy_pool().map(lambda ba: np.copyto(ba[0], ba[1]), jobs))
        return snap

    # -- writer side (phase 1: shards + partial manifest) ---------------
    def _write_leaf(self, path: str, arr: np.ndarray) -> Dict:
        wire_arr, info = wire.leaf_wire(arr)
        np.save(path, wire_arr)     # module-local np: tests fault-inject here
        with open(path, "rb") as f:    # checksum the on-disk container bytes
            data = f.read()
        info["bytes"] = len(data)
        info["crc32"] = _crc(data)
        if self.durable:
            _fsync_path(path)
        return info

    def _run_writer(self, tmp: str, step: int, writer: int,
                    names: List[str], snap: Dict[str, np.ndarray],
                    abort_check) -> Dict[str, Dict]:
        """One logical writer: persist ``names`` into ``writer_KK/``, then
        atomically publish the partial manifest.  The gap between the last
        shard write and the manifest publish is the torn-step window the
        quorum gate exists for — ``writer_fault`` injects death there."""
        wtag = f"writer_{writer:02d}"
        wdir = os.path.join(tmp, wtag)
        os.makedirs(wdir, exist_ok=True)
        shards: Dict[str, Dict] = {}
        for i, name in enumerate(names):
            if abort_check is not None and abort_check():
                raise _Aborted(step)
            info = self._write_leaf(
                os.path.join(wdir, f"leaf_{i:05d}.npy"), snap[name])
            info["file"] = f"{wtag}/leaf_{i:05d}.npy"
            info["writer"] = writer
            shards[name] = info
        # >>> shards on disk; partial manifest NOT yet published <<<
        if self.writer_fault is not None:
            self.writer_fault(step, writer)
        if abort_check is not None and abort_check():
            raise _Aborted(step)
        partial = {"writer": writer, "step": step, "shards": shards,
                   "crc32": _shards_crc(shards)}
        mtmp = os.path.join(wdir, PARTIAL_MANIFEST + ".tmp")
        with open(mtmp, "w") as f:
            json.dump(partial, f, sort_keys=True)
            if self.durable:
                f.flush()
                os.fsync(f.fileno())
        os.replace(mtmp, os.path.join(wdir, PARTIAL_MANIFEST))
        if self.durable:
            _fsync_path(wdir)
        return shards

    # -- coordinator side (phase 2: verify quorum, publish) --------------
    def _verify_partial(self, tmp: str, step: int,
                        writer: int) -> Dict[str, Dict]:
        """Re-read one partial manifest FROM DISK and verify it: parseable
        json, self-checksum over the shard table, correct (step, writer)
        identity, and every listed shard file present with the recorded
        byte length.  This is the "durably present and checksum-verified"
        gate the global publish waits on; full per-shard crc verification
        is the restore side's job (end-to-end, where it matters)."""
        path = os.path.join(tmp, f"writer_{writer:02d}", PARTIAL_MANIFEST)
        try:
            with open(path) as f:
                partial = json.load(f)
        except (OSError, ValueError) as e:
            raise QuorumError(
                f"writer {writer} partial manifest {path} unreadable: "
                f"{type(e).__name__}: {e}") from e
        shards = partial.get("shards", {})
        if partial.get("crc32") != _shards_crc(shards):
            raise QuorumError(
                f"writer {writer} partial manifest {path} failed its "
                f"self-checksum — torn manifest write")
        if partial.get("step") != step or partial.get("writer") != writer:
            raise QuorumError(
                f"{path} identifies as step {partial.get('step')} writer "
                f"{partial.get('writer')}, expected step {step} writer "
                f"{writer}")
        for name, info in shards.items():
            fpath = os.path.join(tmp, info["file"])
            try:
                size = os.stat(fpath).st_size
            except OSError as e:
                raise QuorumError(
                    f"shard {fpath} (leaf {name!r}) listed by writer "
                    f"{writer} is missing: {e}") from e
            if size != info["bytes"]:
                raise QuorumError(
                    f"shard {fpath} (leaf {name!r}) is {size}B on disk, "
                    f"writer {writer} manifest records {info['bytes']}B")
        return shards

    def _fan_out_threads(self, tmp: str, step: int,
                         groups: List[List[str]],
                         snap: Dict[str, np.ndarray],
                         abort_check) -> Dict[int, BaseException]:
        """Phase 1, thread runtime: run the writer group on the shared write
        pool; returns the per-writer failure map (empty = all committed)."""
        futs = [_write_pool().submit(self._run_writer, tmp, step, w,
                                     groups[w], snap, abort_check)
                for w in range(self.writers)]
        failures: Dict[int, BaseException] = {}
        for w, fut in enumerate(futs):
            try:
                fut.result()
            except BaseException as e:
                failures[w] = e
        return failures

    def _get_fleet(self):
        from repro.runtime.procs import WriterFleet
        if self._fleet is None:
            self._fleet = WriterFleet(self.dir, self.writers,
                                      timeout=self.writer_timeout,
                                      reassign=self.reassign)
        return self._fleet

    def _fan_out_procs(self, tmp: str, step: int, groups: List[List[str]],
                       snap: Dict[str, np.ndarray], abort_check
                       ) -> Tuple[Dict[int, BaseException], Dict[int, str]]:
        """Phase 1, process runtime (docs/DESIGN.md §9): hand the snapshot to
        the writer fleet; heartbeat-lease supervision + orphan-shard
        reassignment happen inside :meth:`WriterFleet.run_save`.  The
        ``verify`` callback makes the fleet's commit criterion the SAME
        disk verification the quorum gate uses — a writer that corrupted a
        shard after checksumming it fails commit and is reassigned exactly
        like a dead one."""
        from repro.runtime.procs import FleetAborted
        fleet = self._get_fleet()
        try:
            failed, reassigned = fleet.run_save(
                tmp, step, groups, snap, durable=self.durable,
                fault_for=self.proc_fault,
                verify=lambda w: self._verify_partial(tmp, step, w),
                abort_check=abort_check)
        except FleetAborted:
            raise _Aborted(step) from None
        return ({w: RuntimeError(why) for w, why in failed.items()},
                reassigned)

    def quorum_gate(self, tmp: str, step: int, names: List[str],
                    failures: Dict[int, BaseException]
                    ) -> Dict[int, Dict[str, Dict]]:
        """Phase 2 gate: re-verify every surviving writer's partial manifest
        FROM DISK, then demand quorum AND full shard coverage.  Raises
        :class:`QuorumError` on a torn step; returns the verified per-writer
        shard tables on success."""
        verified: Dict[int, Dict[str, Dict]] = {}
        for w in range(self.writers):
            if w not in failures:
                verified[w] = self._verify_partial(tmp, step, w)
        covered = set()
        for shards in verified.values():
            covered.update(shards)
        missing = [n for n in names if n not in covered]
        if len(verified) < self.quorum or missing:
            why = "; ".join(
                f"writer {w}: {type(e).__name__}: {e}"
                for w, e in sorted(failures.items())) or "no writer died"
            raise QuorumError(
                f"step {step} torn: {len(verified)}/{self.writers} "
                f"partial manifests verified (quorum {self.quorum}), "
                f"{len(missing)} shards uncovered — {why}")
        return verified

    def _publish(self, tmp: str, final: str, step: int,
                 verified: Dict[int, Dict[str, Dict]],
                 failures: Dict[int, BaseException],
                 reassigned: Dict[int, str],
                 extra_meta: Optional[Dict] = None) -> str:
        """Phase 2 publish: write the global manifest (tmp + ``os.replace``)
        and atomically publish the step directory.  ``reassigned`` writers
        are recorded in the manifest ONLY when non-empty, so a clean
        fleet save is bit-identical to a thread-writer save."""
        manifest: Dict[str, Dict] = {}
        for w in sorted(verified):
            manifest.update(verified[w])
        meta = {"step": step, "writers": self.writers,
                "quorum": self.quorum, "committed": sorted(verified),
                "failed_writers": sorted(failures), "complete": True,
                "manifest": manifest, **(extra_meta or {})}
        if reassigned:
            meta["reassigned"] = {str(w): why
                                  for w, why in sorted(reassigned.items())}
        gtmp = os.path.join(tmp, MANIFEST + ".tmp")
        with open(gtmp, "w") as f:
            json.dump(meta, f, sort_keys=True)
            if self.durable:
                f.flush()
                os.fsync(f.fileno())
        os.replace(gtmp, os.path.join(tmp, MANIFEST))
        if self.durable:               # data durable BEFORE the publish
            _fsync_path(tmp)
        os.replace(tmp, final)                      # atomic publish
        if self.durable:
            _fsync_path(self.dir)        # the rename itself
        return final

    def _write(self, step: int, snap: Dict[str, np.ndarray],
               extra_meta: Optional[Dict] = None, abort_check=None) -> str:
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        try:
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            names = sorted(snap)
            owner = partition_shards({n: snap[n].nbytes for n in names},
                                     self.writers, self.writer_map)
            groups = [[n for n in names if owner[n] == w]
                      for w in range(self.writers)]
            reassigned: Dict[int, str] = {}
            if self.writer_procs:
                failures, reassigned = self._fan_out_procs(
                    tmp, step, groups, snap, abort_check)
            else:
                failures = self._fan_out_threads(tmp, step, groups, snap,
                                                 abort_check)
            if any(isinstance(e, _Aborted) for e in failures.values()):
                raise _Aborted(step)
            verified = self.quorum_gate(tmp, step, names, failures)
            self._publish(tmp, final, step, verified, failures, reassigned,
                          extra_meta)
        except BaseException:
            # any failure — writer death, quorum miss, abort — leaves only
            # swept ground: the torn step must never be observable
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return final

    def _gc(self):
        """Retire steps beyond ``keep``.  The step is renamed OUT of the
        published namespace first (``.gc.tmp`` — invisible to
        :meth:`all_steps`), so a kill mid-rmtree leaves sweepable debris,
        never a half-deleted listable step."""
        for s in self.all_steps()[:-self.keep]:
            src = os.path.join(self.dir, f"step_{s:08d}")
            dst = src + ".gc.tmp"
            try:
                os.replace(src, dst)
            except OSError:        # e.g. a concurrent GC won the rename
                dst = src
            shutil.rmtree(dst, ignore_errors=True)

    def all_steps(self):
        """Restorable steps only: published (never ``.tmp``) AND carrying a
        complete global manifest.  Foreign files, half-deleted directories
        and torn publishes in the checkpoint root are skipped, not fatal."""
        try:
            entries = os.listdir(self.dir)
        except FileNotFoundError:
            return []
        out = []
        for d in entries:
            m = _STEP_RE.match(d)
            if not m:
                continue
            p = os.path.join(self.dir, d)
            if os.path.isdir(p) and self._manifest_complete(p):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def retire_steps_after(self, step: int):
        """Divergence-rollback hook (runtime/guard.py, docs/DESIGN.md §8):
        retire every published step > ``step`` — they were saved from
        already-poisoned state.  A checkpoint labeled K holds the state
        *after* consuming data 0..K-1, so with first poisoned loop step P
        the newest safe checkpoint is the largest K <= P and the caller
        passes ``retire_steps_after(P)``.  Same rename-then-rmtree dance as
        :meth:`_gc`: the step leaves the published namespace atomically
        before deletion.  Returns the retired step list."""
        retired = []
        for s in self.all_steps():
            if s <= step:
                continue
            src = os.path.join(self.dir, f"step_{s:08d}")
            dst = src + ".gc.tmp"
            try:
                os.replace(src, dst)
            except OSError:
                dst = src
            shutil.rmtree(dst, ignore_errors=True)
            retired.append(s)
        return retired

    # ------------------------------------------------------------------
    # orbax-like surface, trivially satisfied on the sync path (so the train
    # loop / supervisor can treat both managers uniformly)
    # ------------------------------------------------------------------
    def save_async(self, step: int, state: Dict[str, Any],
                   extra_meta: Optional[Dict] = None) -> None:
        """On the sync manager this is just a blocking :meth:`save`."""
        self.save(step, state, extra_meta)

    def wait_until_finished(self):
        pass

    def check_error(self):
        pass

    def abort(self):
        """Fence: SIGKILL + reap + sweep the writer fleet (when one runs),
        then sweep torn-step debris.  The next save respawns the fleet."""
        if self._fleet is not None:
            self._fleet.fence()
        self._clean_stale_tmp()

    def close(self):
        if self._fleet is not None:
            self._fleet.close()
            self._fleet = None

    # ------------------------------------------------------------------
    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, int]:
        """Quorum reassembly: restore into the structure of ``template`` (a
        pytree of arrays or ShapeDtypeStructs) from the newest step whose
        global manifest is complete (``all_steps`` already filters torn and
        half-deleted steps out).  With ``verify=True`` every shard's byte
        length and crc32 are checked against the manifest BEFORE the bytes
        reach ``device_put`` — corruption fails loudly, naming the file.
        ``shardings`` (optional matching tree) re-shards for the *current*
        mesh — the elastic-scaling path; the writer partition a step was
        saved with is irrelevant on restore (leaves are global arrays)."""
        import io
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        try:
            with open(os.path.join(d, MANIFEST)) as f:
                meta = json.load(f)
        except FileNotFoundError as e:
            raise FileNotFoundError(
                f"step {step} in {self.dir} has no global manifest — torn "
                f"or half-deleted step") from e
        except ValueError as e:
            raise CheckpointCorruptionError(
                f"global manifest {os.path.join(d, MANIFEST)} is not valid "
                f"JSON: {e}") from e
        if not isinstance(meta, dict) or not meta.get("complete"):
            # non-dict JSON (array/string/null) is a foreign file squatting
            # on the manifest name, not a manifest — same refusal, no
            # AttributeError
            raise CheckpointCorruptionError(
                f"global manifest of step {step} is not marked complete — "
                f"refusing a sub-quorum restore")
        leaves = _leaf_paths(template)
        shard_leaves = _leaf_paths(shardings) if shardings is not None else {}
        out = {}
        for name, leaf in leaves.items():
            info = meta["manifest"][name]
            path = os.path.join(d, info["file"])
            with open(path, "rb") as f:
                data = f.read()
            if self.verify:
                if len(data) != info["bytes"]:
                    raise CheckpointCorruptionError(
                        f"checkpoint shard {path} (leaf {name!r}) is "
                        f"truncated: {len(data)}B on disk, manifest records "
                        f"{info['bytes']}B — refusing to load")
                got = _crc(data)
                if got != info["crc32"]:
                    raise CheckpointCorruptionError(
                        f"checkpoint shard {path} (leaf {name!r}) failed "
                        f"crc32 verification: file 0x{got:08x} != manifest "
                        f"0x{info['crc32']:08x} — refusing to load a "
                        f"corrupted shard")
            arr = np.load(io.BytesIO(data), allow_pickle=False)
            if info.get("raw"):
                arr = np.frombuffer(arr.tobytes(),
                                    dtype=np.dtype(info["dtype"])
                                    ).reshape(info["shape"])
            assert list(arr.shape) == list(leaf.shape), \
                f"{name}: ckpt {arr.shape} vs template {leaf.shape}"
            sh = shard_leaves.get(name)
            out[name] = (jax.device_put(arr, sh) if sh is not None
                         else jax.numpy.asarray(arr))
        # rebuild the tree
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        rebuilt = []
        for kp, _ in flat:
            name = "/".join(_escape(str(getattr(k, "key",
                                                getattr(k, "idx", k))))
                            for k in kp)
            rebuilt.append(out[name])
        return jax.tree_util.tree_unflatten(treedef, rebuilt), step


class AsyncCheckpointManager(CheckpointManager):
    """Non-blocking checkpointing: snapshot on the step boundary, writer-group
    fan-out + quorum publish on a background coordinator thread (module
    docstring)."""

    def __init__(self, directory: str, keep: int = 3, *,
                 max_inflight: int = 2, staging: str = "host",
                 durable: bool = False, writers: int = 1,
                 quorum: Optional[int] = None, verify: bool = True,
                 writer_map: Optional[Callable[[str], Optional[int]]] = None,
                 writer_fault: Optional[Callable[[int, int], None]] = None,
                 writer_procs: bool = False, writer_timeout: float = 5.0,
                 reassign: int = 1,
                 proc_fault: Optional[Callable[[int, int],
                                               Optional[Dict]]] = None):
        super().__init__(directory, keep, durable=durable, writers=writers,
                         quorum=quorum, verify=verify, writer_map=writer_map,
                         writer_fault=writer_fault,
                         writer_procs=writer_procs,
                         writer_timeout=writer_timeout, reassign=reassign,
                         proc_fault=proc_fault)
        assert staging in ("host", "sync"), staging
        assert max_inflight >= 1, max_inflight
        self.staging = staging
        self._free: "queue.Queue[Dict]" = queue.Queue()
        for _ in range(max_inflight):
            self._free.put({})                   # arena slot: name -> buffer
        self._work: "queue.Queue" = queue.Queue()
        self._cv = threading.Condition()
        self._inflight = 0
        self._error: Optional[BaseException] = None
        self._abort = threading.Event()
        self._closed = False
        self._thread = threading.Thread(target=self._writer_loop,
                                        name="ckpt-writer", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------------
    def save_async(self, step: int, state: Dict[str, Any],
                   extra_meta: Optional[Dict] = None) -> None:
        """Snapshot ``state`` to a host staging slot and return; the
        coordinator thread fans out the writer group and publishes.  Blocks
        only for the device→host copy, or when all ``max_inflight`` slots
        still hold unwritten snapshots (backpressure).  Raises a prior
        writer-group error, if any."""
        self.check_error()
        if self.staging == "sync" or self._closed:
            self.save(step, state, extra_meta)
            return
        slot = self._free.get()                  # backpressure point
        try:
            snap = self._snapshot_host(state, slot)
        except BaseException:
            self._free.put(slot)
            raise
        with self._cv:
            self._inflight += 1
        self._work.put((step, slot, snap, extra_meta))

    def _writer_loop(self):
        while True:
            item = self._work.get()
            if item is None:
                return
            step, slot, snap, extra_meta = item
            try:
                if not self._abort.is_set():
                    self._write(step, snap, extra_meta,
                                abort_check=self._abort.is_set)
            except _Aborted:
                pass                             # _write swept its debris
            except BaseException as e:           # sticky: surfaced to caller
                if self._error is None:
                    self._error = e
            finally:
                self._free.put(slot)
                with self._cv:
                    self._inflight -= 1
                    self._cv.notify_all()

    # ------------------------------------------------------------------
    def wait_until_finished(self):
        """Drain every queued/in-flight save, then surface writer errors."""
        with self._cv:
            while self._inflight > 0:
                self._cv.wait()
        self.check_error()

    def check_error(self):
        """Re-raise the first writer-group failure (sticky, orbax
        semantics)."""
        if self._error is not None:
            raise RuntimeError(
                f"async checkpoint writer failed: {self._error!r}"
            ) from self._error

    def abort(self):
        """Fence the whole writer group: discard queued snapshots and
        interrupt every in-flight writer between shards — called by the
        fault supervisor when this incarnation is dead, so a restart can
        never observe a save issued after the failure point.  Published
        checkpoints are untouched; ``.tmp`` debris is swept, and a sticky
        writer error is cleared with it: the dead incarnation's persistence
        failure is fenced exactly like its in-flight saves, so the NEXT
        incarnation starts clean instead of dying at its first checkpoint
        boundary on a stale error (e.g. a recovered ENOSPC).  With
        ``writer_procs`` the fence is physical: every writer PROCESS is
        SIGKILLed and reaped (runtime/procs.py) — an in-flight fleet save
        observes the fence, raises, and its debris is swept below."""
        self._abort.set()
        if self._fleet is not None:
            self._fleet.fence()
        with self._cv:
            while self._inflight > 0:
                self._cv.wait()
        self._abort.clear()
        self._error = None
        self._clean_stale_tmp()

    def close(self):
        """Drain (without raising), stop the coordinator thread, and shut
        down the writer fleet if one is running."""
        if self._closed:
            return
        with self._cv:
            while self._inflight > 0:
                self._cv.wait()
        self._closed = True
        self._work.put(None)
        self._thread.join(timeout=60)
        if self._fleet is not None:
            self._fleet.close()
            self._fleet = None


def make_manager(directory: str, ccfg=None, *,
                 writer_map: Optional[Callable[[str], Optional[int]]] = None,
                 writer_fault: Optional[Callable[[int, int], None]] = None,
                 proc_fault: Optional[Callable[[int, int],
                                               Optional[Dict]]] = None
                 ) -> CheckpointManager:
    """Build the manager a :class:`repro.config.CheckpointConfig` describes
    (``None`` → the synchronous single-writer default).  ``writer_map`` pins
    shards to writers (e.g. ``parallel/pipeline.stage_writer_map``);
    ``writer_fault`` is the thread-writer injection hook
    (``FailureInjector.check_writer``) and ``proc_fault`` its process-fleet
    sibling (``FailureInjector.proc_fault``, runtime/procs.py) — both also
    wired automatically by ``train/loop.py`` when an injector is active."""
    if ccfg is None:
        return CheckpointManager(directory, writer_map=writer_map,
                                 writer_fault=writer_fault,
                                 proc_fault=proc_fault)
    kw = dict(keep=ccfg.keep, durable=ccfg.durable, writers=ccfg.writers,
              quorum=ccfg.quorum, verify=ccfg.verify,
              writer_map=writer_map, writer_fault=writer_fault,
              writer_procs=ccfg.writer_procs,
              writer_timeout=ccfg.writer_timeout, reassign=ccfg.reassign,
              proc_fault=proc_fault)
    if ccfg.async_:
        return AsyncCheckpointManager(directory,
                                      max_inflight=ccfg.max_inflight,
                                      staging=ccfg.staging, **kw)
    return CheckpointManager(directory, **kw)
