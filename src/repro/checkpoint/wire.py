"""Process-agnostic checkpoint wire format (jax-free, importable by spawn).

The multi-writer on-disk protocol (docs/DESIGN.md §7) was designed to be
process-agnostic: a "writer" is whoever writes ``writer_NN/leaf_*.npy``
shards and then atomically publishes ``writer_NN/manifest.json``.  This
module is the format's single source of truth for the pieces BOTH runtimes
share — thread writers inside ``checkpoint/manager.py`` and the
cross-process writer fleet (``runtime/procs.py``, docs/DESIGN.md §9) — so
the two produce bit-identical trees:

  * ``crc`` / ``shards_crc``: the shard checksum and the partial manifest's
    self-checksum over its canonical-json shard table.
  * ``leaf_wire``: the logical→wire lowering of one leaf (ml_dtypes
    extension types like bfloat16 cannot round-trip ``.npy`` and are
    lowered to raw uint8 bytes + the logical dtype string in the manifest).
  * ``write_leaf`` / ``publish_partial``: shard persistence and the atomic
    (tmp + ``os.replace``) partial-manifest publish, with the same
    fsync-when-durable barriers as the thread path.

Writer children import ONLY this module (plus numpy) — never jax — so a
fleet child costs a numpy import to spawn, and the coordinator side is the
only place device buffers or ml_dtypes scalars exist.
"""

from __future__ import annotations

import json
import os
import zlib
from typing import Dict, Tuple

import numpy as np

MANIFEST = "MANIFEST.json"          # global (coordinator-published) manifest
PARTIAL_MANIFEST = "manifest.json"  # per-writer partial manifest


def crc(data: bytes) -> int:
    return zlib.crc32(data) & 0xFFFFFFFF


def shards_crc(shards: Dict[str, Dict]) -> int:
    """Self-checksum of a partial manifest's shard table (canonical json) —
    a torn/garbled manifest write fails this instead of passing coordinator
    verification by accident."""
    return crc(json.dumps(shards, sort_keys=True).encode())


def npy_safe(dtype: np.dtype) -> bool:
    """Can the ``.npy`` format round-trip this dtype?  ml_dtypes extension
    types (bfloat16, float8_*) save fine but LOAD back as raw void."""
    return np.dtype(dtype).isbuiltin == 1


def fsync_path(path: str):
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def leaf_wire(arr: np.ndarray) -> Tuple[np.ndarray, Dict]:
    """Lower one logical leaf to its wire form: the ndarray that is actually
    ``np.save``d and the manifest info stub ({shape, dtype[, raw]}) that
    describes how to lift it back.  The ``raw`` key is present ONLY for
    non-round-trippable dtypes — key *presence* is part of the format, so
    thread and process writers emit identical manifests."""
    info: Dict = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    if not npy_safe(arr.dtype):    # bf16 etc: raw bytes + logical dtype
        info["raw"] = True
        arr = np.frombuffer(arr.tobytes(), np.uint8)
    else:
        # force C order WITHOUT np.ascontiguousarray: its contract is
        # ndim >= 1, which would silently promote 0-d leaves (adamw's
        # ``.step``) to shape (1,) and break restore's shape check
        arr = np.asarray(arr, order="C")
    return arr, info


def write_leaf(path: str, wire_arr: np.ndarray,
               durable: bool = False) -> Tuple[int, int]:
    """Persist one wire-form shard; returns (bytes, crc32) of the on-disk
    ``.npy`` container (the checksum covers container bytes, not payload)."""
    np.save(path, wire_arr)
    with open(path, "rb") as f:
        data = f.read()
    if durable:
        fsync_path(path)
    return len(data), crc(data)


def publish_partial(wdir: str, step: int, writer: int,
                    shards: Dict[str, Dict], durable: bool = False):
    """Atomically publish a writer's partial manifest (tmp + ``os.replace``).
    The gap between the last shard write and this publish is the torn-step
    window the coordinator's quorum gate exists for."""
    partial = {"writer": writer, "step": step, "shards": shards,
               "crc32": shards_crc(shards)}
    mtmp = os.path.join(wdir, PARTIAL_MANIFEST + ".tmp")
    with open(mtmp, "w") as f:
        json.dump(partial, f, sort_keys=True)
        if durable:
            f.flush()
            os.fsync(f.fileno())
    os.replace(mtmp, os.path.join(wdir, PARTIAL_MANIFEST))
    if durable:
        fsync_path(wdir)
