"""Mini HLO-text analyzer for roofline extraction.

XLA's ``compiled.cost_analysis()`` visits every op ONCE — it does not scale loop
bodies by trip count, so a scan-over-layers model reports ~1/L of its real FLOPs.
This module parses the optimized (post-SPMD) HLO text, recovers the computation
call graph (while bodies x trip counts, fusions, calls), and accumulates:

  * flops            — from dot/convolution ops (2 * prod(result) * contracted)
  * hbm_bytes        — fusion-boundary traffic model: operand + result bytes of
                       top-level (unfused) ops — XLA's fusion boundaries are
                       exactly where HBM round-trips happen
  * collective bytes — per collective type, ring-transfer model:
                       AG (g-1)*shard, RS (g-1)/g*operand, AR 2x that, CP 1x
                       (paper eq. (1): ring time ∝ (g-1)/g * S / bw)

Shapes in post-SPMD HLO are per-device, so every number is per-chip.
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shapes_in(s: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(s):
        if m.group(1) in DTYPE_BYTES:
            dims = [int(d) for d in m.group(2).split(",") if d]
            out.append((m.group(1), dims))
    return out


def _bytes_of(shapes: List[Tuple[str, List[int]]]) -> int:
    return sum(DTYPE_BYTES[dt] * math.prod(dims or [1]) for dt, dims in shapes)


@dataclass
class OpCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    s2_bytes: float = 0.0      # S^2-shaped attention intermediates (see below)
    coll_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    coll_count: Dict[str, float] = field(default_factory=lambda: defaultdict(float))

    def add(self, other: "OpCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.s2_bytes += other.s2_bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] += v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] += v * mult

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())

    @property
    def hbm_bytes_kernel_adjusted(self) -> float:
        """HBM bytes assuming attention runs as a fused flash kernel: the
        [*, Sq, Sk] score/prob intermediates the jnp fallback materializes
        never leave VMEM in kernels/flash_attention.py, so they are excluded
        (their Q/K/V/O boundary tensors remain counted)."""
        return self.hbm_bytes - self.s2_bytes


# metadata markers for attention score/prob tensors: the einsum strings from
# models/attention.py (scores 'bhqd,bhdk->bhqk', SV 'bhqk,bhkd->', grouped
# decode 'bcgqs') and the softmax that sits between them.
_ATTN_META = ("bhqk", "bcgqs", "bchqk", "softmax")


def _is_attn_line(line: str) -> bool:
    m = re.search(r'op_name="([^"]*)"', line)
    return bool(m) and any(t in m.group(1) for t in _ATTN_META)


def _is_s2(shapes: List[Tuple[str, List[int]]], line: str = "") -> bool:
    """Attention score/prob tensors: fp32, >=4MB, shaped either
    [*, q_block=1024, Sk>=1024] (models/attention.py chunks q at 1024) or
    square [*, S, S] (direct path, e.g. whisper's 1500 frames).

    Metadata (einsum names) would be the precise signal but XLA strips
    op_name from fused ops in optimized dumps; the fp32 requirement excludes
    bf16 activations, and the exact q-block width excludes norm/rope fp32
    upcasts of [*, S, H] activations."""
    for dt, dims in shapes:
        if dt != "f32" or len(dims) < 2:
            continue
        d1, d2 = dims[-2], dims[-1]
        big = math.prod(dims) * 4 >= 4 * 2 ** 20
        if big and ((d1 == 1024 and d2 >= 1024) or (d1 == d2 >= 1024)):
            return True
    return False


_GROUPS_BRACE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[([0-9,]+)\]<=")
_TRIP = re.compile(r'known_trip_count[\\"]*:\s*\{[\\"]*n[\\"]*:[\\"]*(\d+)')
_NAME = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_WHILE_PARTS = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLEE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_OPRNDS = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# Ops whose standalone appearance in CPU-backend HLO would not round-trip HBM on
# a TPU (layout changes fuse into neighbors; converts fuse into the producer).
# Counting them would bias the memory term by the CPU backend's weaker fusion.
SKIP_BYTES_OPS = ("parameter(", "constant(", "get-tuple-element(", "tuple(",
                  "bitcast(", "copy(", " while(", "after-all(",
                  "opt-barrier(", "transpose(", "convert(", "reshape(",
                  "broadcast(", "iota(")


def group_size(line: str) -> int:
    m = _GROUPS_BRACE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA.search(line)
    if m:
        dims = [int(d) for d in m.group(1).split(",")]
        total = math.prod(dims)
        return total // dims[0] if dims[0] else 1
    return 1


class HLOModule:
    """Parses an optimized HLO dump into computations + a module-wide symbol
    table (op name -> result shapes), then folds costs over the call graph."""

    def __init__(self, text: str):
        self.computations: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        self.symbols: Dict[str, List[Tuple[str, List[int]]]] = {}
        self._parse(text)
        self._cost_cache: Dict[Tuple[str, bool], OpCost] = {}

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            ls = raw.strip()
            if not ls or ls.startswith(("//", "#")):
                continue
            if ls.endswith("{") and "->" in ls and "=" not in ls.split("(")[0]:
                hdr = re.match(r"(ENTRY\s+)?%?([\w.\-]+)\s*\(", ls)
                if hdr:
                    cur = hdr.group(2)
                    self.computations[cur] = []
                    if hdr.group(1):
                        self.entry = cur
                    # header params: "name: f32[...]"
                    for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\))|"
                                          r"(?:[a-z0-9]+\[[0-9,]*\]))", ls):
                        self.symbols[pm.group(1)] = _shapes_in(pm.group(2))
                    continue
            if ls == "}" or ls.startswith("}"):
                cur = None
                continue
            if cur is not None:
                m = _NAME.match(ls)
                if m:
                    self.computations[cur].append(ls)
                    rhs = m.group(2)
                    # result type = everything before the op name token
                    self.symbols[m.group(1)] = _shapes_in(rhs.split(")")[0]
                                                          if rhs.startswith("(")
                                                          else rhs.split(" ")[0])
        if self.entry is None and self.computations:
            self.entry = next((n for n in self.computations if "main" in n),
                              next(iter(self.computations)))

    # -----------------------------------------------------------------
    def _operand_names(self, line: str, op: str) -> List[str]:
        i = line.find(f" {op}(")
        if i < 0:
            return []
        m = _OPRNDS.search(line[i:])
        if not m:
            return []
        return re.findall(r"%([\w.\-]+)", m.group(1))

    def _operand_shapes(self, line: str, op: str):
        return [self.symbols.get(n, []) for n in self._operand_names(line, op)]

    def _result_shapes(self, line: str):
        m = _NAME.match(line)
        return self.symbols.get(m.group(1), []) if m else []

    def _dot_flops(self, line: str) -> float:
        rdims = self._result_shapes(line)
        rsize = sum(math.prod(d or [1]) for _, d in rdims)
        ops = self._operand_shapes(line, "dot")
        lhs = ops[0][0][1] if ops and ops[0] else []
        c = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
        contract = 1
        if c and lhs:
            for d in c.group(1).split(","):
                if d and int(d) < len(lhs):
                    contract *= lhs[int(d)]
        return 2.0 * rsize * contract

    def _trip_count(self, line: str, cond: str) -> int:
        m = _TRIP.search(line)
        if m:
            return int(m.group(1))
        n = 1
        for l in self.computations.get(cond, ()):
            mm = re.search(r"constant\((\d+)\)", l)
            if mm:
                n = max(n, int(mm.group(1)))
        return n

    def _line_cost(self, line: str):
        """Returns (own OpCost, optional (callee, mult, flops_only))."""
        c = OpCost()
        if " while(" in line:
            m = _WHILE_PARTS.search(line)
            if m:
                cond, body = m.group(1), m.group(2)
                trips = self._trip_count(line, cond)
                sub = OpCost()
                sub.add(self.cost(body))
                sub.add(self.cost(cond))
                c.add(sub, trips)
            return c, None
        for coll in COLLECTIVES:
            if f" {coll}(" in line or f" {coll}-start(" in line:
                op = coll if f" {coll}(" in line else f"{coll}-start"
                g = group_size(line)
                ins = self._operand_shapes(line, op)
                in_b = sum(_bytes_of(s) for s in ins)
                out_b = _bytes_of(self._result_shapes(line))
                if coll == "all-gather":
                    t = in_b * (g - 1)
                elif coll == "reduce-scatter":
                    t = in_b * (g - 1) / max(g, 1)
                elif coll == "all-reduce":
                    t = 2 * in_b * (g - 1) / max(g, 1)
                elif coll == "all-to-all":
                    t = in_b * (g - 1) / max(g, 1)
                else:
                    t = in_b
                c.coll_bytes[coll] += t
                c.coll_count[coll] += 1
                c.hbm_bytes += in_b + out_b
                return c, None
        if " dot(" in line:
            c.flops += self._dot_flops(line)
            res = self._result_shapes(line)
            c.hbm_bytes += _bytes_of(res)
            if _is_s2(res, line):
                c.s2_bytes += _bytes_of(res)
            for s in self._operand_shapes(line, "dot"):
                c.hbm_bytes += _bytes_of(s)
                if _is_s2(s, line):      # SV dot reading [Sq,Sk] probs
                    c.s2_bytes += _bytes_of(s)
            return c, None
        if " convolution(" in line:
            rsize = sum(math.prod(d or [1]) for _, d in self._result_shapes(line))
            ops = self._operand_shapes(line, "convolution")
            ker = math.prod(ops[1][0][1][:-1]) if len(ops) > 1 and ops[1] else 1
            c.flops += 2.0 * rsize * max(1, ker)
            return c, None
        m = re.search(r"\b(fusion|call|map)\(", line)
        if m:
            kind = m.group(1)
            callee = _CALLEE.search(line)
            # fusion boundary: count the write (result) once; reads of its
            # operands belong to the producers on a TPU-grade fusion pipeline
            # (counting fan-in here would double-bill every residual edge).
            res = self._result_shapes(line)
            c.hbm_bytes += _bytes_of(res)
            if _is_s2(res, line):
                c.s2_bytes += _bytes_of(res)
            if callee:
                return c, (callee.group(1), 1.0, kind == "fusion")
            return c, None
        if " conditional(" in line:
            br = re.search(r"branch_computations=\{([^}]*)\}", line)
            if br:
                names = re.findall(r"%?([\w.\-]+)", br.group(1))
                if names:
                    return c, (names[0], 1.0, False)
            return c, None
        if " custom-call(" in line:
            callee = _CALLEE.search(line)
            c.hbm_bytes += _bytes_of(self._result_shapes(line))
            if callee:
                return c, (callee.group(1), 1.0, False)
            return c, None
        if not any(k in line for k in SKIP_BYTES_OPS):
            res = self._result_shapes(line)
            c.hbm_bytes += _bytes_of(res)
            if _is_s2(res, line):
                c.s2_bytes += _bytes_of(res)
        return c, None

    def cost(self, name: Optional[str] = None,
             flops_only: bool = False) -> OpCost:
        name = name or self.entry
        key = (name, flops_only)
        if key in self._cost_cache:
            return self._cost_cache[key]
        total = OpCost()
        self._cost_cache[key] = total           # cycle guard
        for line in self.computations.get(name, ()):
            own, callee = self._line_cost(line)
            if flops_only:
                own.hbm_bytes = 0.0
                own.s2_bytes = 0.0
            total.add(own)
            if callee:
                sub, mult, sub_fo = callee
                if sub in self.computations and sub != name:
                    total.add(self.cost(sub, flops_only or sub_fo), mult)
        return total


def analyze(text: str) -> OpCost:
    return HLOModule(text).cost()
