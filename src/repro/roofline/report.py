"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the per-cell
JSONs written by launch/dryrun.py.

    PYTHONPATH=src python -m repro.roofline.report [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from collections import defaultdict

ARCH_ORDER = ["mamba2-130m", "qwen3-0.6b", "nemotron-4-340b", "granite-34b",
              "minicpm3-4b", "paligemma-3b", "whisper-small",
              "granite-moe-3b-a800m", "grok-1-314b", "zamba2-1.2b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
HBM_LIMIT = 16 * 2 ** 30          # v5e per-chip


def load(dir_):
    cells = {}
    for f in glob.glob(os.path.join(dir_, "*.json")):
        d = json.load(open(f))
        cells[(d["arch"], d["shape"], d["strategy"], d["mesh"])] = d
    return cells


def fmt_t(s):
    if s >= 1:
        return f"{s:.2f}s"
    return f"{s*1e3:.1f}ms"


def fmt_b(b):
    return f"{b/2**30:.2f}GiB"


def dominant_note(d):
    """One sentence on what would move the dominant term down."""
    bn = d["bottleneck"]
    coll = d.get("coll_breakdown", {})
    top_coll = max(coll, key=coll.get) if coll else "?"
    if bn == "collective":
        return (f"dominated by {top_coll} "
                f"({coll.get(top_coll,0)/1e9:.1f}GB/chip): reduce via bf16 "
                "gathers / fused loss / EP dispatch")
    if bn == "memory":
        return ("HBM-bound: fuse loss (skip logits round-trips), deepen "
                "remat-free regions, larger microbatch")
    return "compute-bound: already near the useful-flops limit; raise MFU via fusion"


def roofline_table(cells, strategy="hecaton", mesh="single"):
    lines = ["| arch | shape | compute | memory | collective | bottleneck | "
             "6ND/HLO | roofline-MFU | peak mem/chip | fits v5e? |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = cells.get((arch, shape, strategy, mesh))
            if d is None:
                continue
            peak = d["memory_analysis"]["peak_bytes_per_chip"]
            lines.append(
                f"| {arch} | {shape} | {fmt_t(d['compute_s'])} | "
                f"{fmt_t(d['memory_s'])} | {fmt_t(d['collective_s'])} | "
                f"{d['bottleneck']} | {d['flops_ratio']:.2f} | "
                f"{d['mfu']*100:.1f}% | {fmt_b(peak)} | "
                f"{'yes' if peak <= HBM_LIMIT else 'NO'} |")
    return "\n".join(lines)


def dryrun_table(cells):
    lines = ["| arch | shape | mesh | strategy | chips | lower+compile | "
             "args/chip | temp/chip | collectives (count) |",
             "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for strat, mesh in (("hecaton", "single"), ("hecaton", "multi"),
                                ("megatron", "single")):
                d = cells.get((arch, shape, strat, mesh))
                if d is None:
                    continue
                ma = d["memory_analysis"]
                cc = d.get("coll_counts", {})
                ccs = " ".join(f"{k.replace('-','')}:{int(v)}"
                               for k, v in sorted(cc.items()))
                lines.append(
                    f"| {arch} | {shape} | {mesh} | {strat} | {d['chips']} | "
                    f"{d.get('lower_s',0)}+{d.get('compile_s',0)}s | "
                    f"{fmt_b(ma['argument_bytes'])} | "
                    f"{fmt_b(ma['temp_bytes'])} | {ccs} |")
    return "\n".join(lines)


def strategy_comparison(cells):
    """hecaton vs megatron on single-pod train cells — the paper's headline."""
    lines = ["| arch | hecaton coll | megatron coll | ratio | hecaton temp | "
             "megatron temp |", "|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        h = cells.get((arch, "train_4k", "hecaton", "single"))
        m = cells.get((arch, "train_4k", "megatron", "single"))
        if not h or not m:
            continue
        lines.append(
            f"| {arch} | {fmt_t(h['collective_s'])} | "
            f"{fmt_t(m['collective_s'])} | "
            f"{m['collective_s']/max(h['collective_s'],1e-9):.2f}x | "
            f"{fmt_b(h['memory_analysis']['temp_bytes'])} | "
            f"{fmt_b(m['memory_analysis']['temp_bytes'])} |")
    return "\n".join(lines)


def pick_hillclimb(cells):
    """Worst roofline fraction, most collective-bound, most representative."""
    train = [d for (a, s, st, me), d in cells.items()
             if st == "hecaton" and me == "single"]
    worst_mfu = min(train, key=lambda d: d["mfu"])
    coll = max(train, key=lambda d: d["collective_s"] /
               max(d["step_time_s"], 1e-9))
    return worst_mfu, coll


def notes_section(cells, strategy="hecaton", mesh="single"):
    lines = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = cells.get((arch, shape, strategy, mesh))
            if d is None:
                continue
            lines.append(f"* **{arch} / {shape}** — {dominant_note(d)}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    cells = load(args.dir)
    out = []
    out.append("### Roofline (hecaton, single pod 16x16 = 256 chips)\n")
    out.append(roofline_table(cells, "hecaton", "single"))
    out.append("\n### Roofline (hecaton, multi-pod 2x16x16 = 512 chips)\n")
    out.append(roofline_table(cells, "hecaton", "multi"))
    out.append("\n### Baseline comparison (megatron 1D-TP, single pod)\n")
    out.append(roofline_table(cells, "megatron", "single"))
    out.append("\n### Strategy comparison on train_4k\n")
    out.append(strategy_comparison(cells))
    out.append("\n### Dry-run inventory\n")
    out.append(dryrun_table(cells))
    out.append("\n### Per-cell bottleneck notes\n")
    out.append(notes_section(cells))
    text = "\n".join(out)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    else:
        print(text)


if __name__ == "__main__":
    main()
