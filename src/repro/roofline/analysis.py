"""Three-term roofline from a compiled dry-run artifact.

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s          (197 TFLOP/s bf16)
    memory term     = HLO_bytes_per_chip / HBM_bw               (819 GB/s)
    collective term = collective_bytes_per_chip / link_bw       (~50 GB/s/link ICI)

Per-chip numbers come from the post-SPMD HLO via roofline/hlo.py (loop-scaled).
MODEL_FLOPS uses 6*N*D (train) / 2*N_active*D (inference) to expose how much of
the compiled compute is "useful" (catches remat & replication waste).
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

from repro.config import ModelConfig, RunConfig

# TPU v5e-class hardware constants (per chip), per assignment.
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # B/s
LINK_BW = 50e9               # B/s per ICI link


@dataclass
class RooflineResult:
    arch: str
    shape: str
    mesh: str
    strategy: str
    chips: int
    flops_per_chip: float
    hbm_bytes_per_chip: float
    s2_bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: Dict[str, float]
    coll_counts: Dict[str, float]
    model_flops_total: float
    # memory_analysis
    arg_bytes: float = 0.0
    out_bytes: float = 0.0
    temp_bytes: float = 0.0
    note: str = ""

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        """Kernel-adjusted: S^2 attention intermediates excluded (they stay in
        VMEM under kernels/flash_attention.py; the jnp dry-run fallback
        materializes them).  memory_s_raw keeps the unadjusted number."""
        return (self.hbm_bytes_per_chip - self.s2_bytes_per_chip) / HBM_BW

    @property
    def memory_s_raw(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_chip / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound is the sum; perfect overlap is the max.
        We report the max (XLA latency-hiding target) as the roofline time."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_per_chip(self) -> float:
        return self.model_flops_total / max(1, self.chips)

    @property
    def flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (per chip) — fraction of compiled compute
        that is 'useful'."""
        return self.useful_flops_per_chip / max(1.0, self.flops_per_chip)

    @property
    def mfu(self) -> float:
        """Roofline-model FLOP utilization: useful flops / (peak * step_time)."""
        t = self.step_time_s
        return self.useful_flops_per_chip / (PEAK_FLOPS * t) if t else 0.0

    def to_dict(self):
        d = asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 memory_s_raw=self.memory_s_raw,
                 collective_s=self.collective_s, bottleneck=self.bottleneck,
                 step_time_s=self.step_time_s, flops_ratio=self.flops_ratio,
                 mfu=self.mfu)
        return d


def model_flops(cfg: ModelConfig, rc: RunConfig) -> float:
    """6*N*D (train) / 2*N_active*D (prefill) / 2*N_active*B (decode)."""
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    if rc.mode == "train":
        return 6.0 * n_active * rc.global_batch * rc.seq_len
    if rc.mode == "prefill":
        return 2.0 * n_active * rc.global_batch * rc.seq_len
    return 2.0 * n_active * rc.global_batch     # decode: one token


def from_compiled(compiled, *, arch, shape, mesh_name, strategy, chips,
                  cfg: ModelConfig, rc: RunConfig, note="") -> RooflineResult:
    from repro.roofline.hlo import analyze
    cost = analyze(compiled.as_text())
    ma = compiled.memory_analysis()
    return RooflineResult(
        arch=arch, shape=shape, mesh=mesh_name, strategy=strategy, chips=chips,
        flops_per_chip=cost.flops, hbm_bytes_per_chip=cost.hbm_bytes,
        s2_bytes_per_chip=cost.s2_bytes,
        coll_bytes_per_chip=cost.total_coll_bytes,
        coll_breakdown=dict(cost.coll_bytes), coll_counts=dict(cost.coll_count),
        model_flops_total=model_flops(cfg, rc),
        arg_bytes=getattr(ma, "argument_size_in_bytes", 0),
        out_bytes=getattr(ma, "output_size_in_bytes", 0),
        temp_bytes=getattr(ma, "temp_size_in_bytes", 0),
        note=note)


def fmt_row(r: RooflineResult) -> str:
    return (f"| {r.arch} | {r.shape} | {r.strategy}/{r.mesh} | "
            f"{r.compute_s*1e3:.1f} | {r.memory_s*1e3:.1f} | "
            f"{r.collective_s*1e3:.1f} | {r.bottleneck} | "
            f"{r.flops_ratio:.2f} | {r.mfu*100:.1f}% |")


HEADER = ("| arch | shape | strategy/mesh | compute ms | memory ms | "
          "collective ms | bottleneck | useful/HLO | roofline MFU |\n"
          "|---|---|---|---|---|---|---|---|---|")
