"""Flash attention (online softmax) Pallas TPU kernel.

TPU-native rethinking of the standard GPU flash algorithm: instead of warp-level
shuffles, the sequential TPU grid carries running (max, sum, acc) statistics in
VMEM scratch across the KV-block axis; the MXU consumes (q_block x kv_block)
tiles.  Causal masking skips fully-masked KV blocks via pl.when.  GQA is
supported by mapping multiple q-heads onto one kv-head index (no KV repeat —
the memory argument from docs/DESIGN.md §4).

Grid: (batch*q_heads, Sq/bq, Sk/bk), KV axis innermost.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, bq: int, bk: int, n_k: int):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_i = pl.program_id(1)

    def _step():
        q = q_ref[0].astype(jnp.float32)                     # [bq, dh]
        k = k_ref[0].astype(jnp.float32)                     # [bk, dh]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = kv_i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:   # skip fully-masked KV blocks entirely
        pl.when(kv_i * bk <= q_i * bq + bq - 1)(_step)
    else:
        _step()

    @pl.when(kv_i == n_k - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q [B,nh,Sq,dh]; k,v [B,nkv,Sk,dh]; nh % nkv == 0.  Returns [B,nh,Sq,dh]."""
    B, nh, Sq, dh = q.shape
    _, nkv, Sk, _ = k.shape
    assert nh % nkv == 0
    g = nh // nkv
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    qf = q.reshape(B * nh, Sq, dh)
    kf = k.reshape(B * nkv, Sk, dh)
    vf = v.reshape(B * nkv, Sk, dh)
    grid = (B * nh, Sq // bq, Sk // bk)
    scale = dh ** -0.5

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk, n_k=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda h, i, j, g=g: (h // g, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda h, i, j, g=g: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * nh, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max
            pltpu.VMEM((bq, 1), jnp.float32),     # running denom
            pltpu.VMEM((bq, dh), jnp.float32),    # running accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf).reshape(B, nh, Sq, dh)
