"""Mamba2 SSD (state-space duality) chunked-scan Pallas TPU kernel.

TPU-native structure: grid (batch*heads, S/chunk) with the chunk axis innermost
and sequential — the inter-chunk recurrent state lives in a VMEM scratch that
persists across grid steps (the chiplet "weight/state-stationary" idiom; on GPU
this would be a cross-block carry requiring a separate kernel launch or
cooperative groups — the TPU sequential grid makes the carry free).

Per chunk (all MXU matmuls):
  decay  L[i,j] = exp(segsum dA)           (intra-chunk, lower-triangular)
  y_diag = (C B^T ∘ L) (x*dt)
  y_off  = C h_prev ∘ exp(cum dA)
  h_new  = h_prev * exp(sum dA) + B^T ((x*dt) ∘ decay_to_end)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, A_ref, b_ref, c_ref, o_ref, h_ref, *,
                n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)          # [Q, dh]
    dt = dt_ref[0].astype(jnp.float32)        # [Q]
    A = A_ref[0]                              # scalar decay rate (negative)
    B = b_ref[0].astype(jnp.float32)          # [Q, ds]
    C = c_ref[0].astype(jnp.float32)          # [Q, ds]

    dA = dt * A                               # [Q] (negative)
    cum = jnp.cumsum(dA)                      # inclusive
    Q = x.shape[0]
    seg = cum[:, None] - cum[None, :]         # [Q,Q] pairwise
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri, jnp.exp(seg), 0.0)

    xdt = x * dt[:, None]
    scores = jnp.dot(C, B.T, preferred_element_type=jnp.float32) * L
    y = jnp.dot(scores, xdt, preferred_element_type=jnp.float32)

    # off-diagonal: contribution of carried state
    h = h_ref[...]                            # [dh, ds]
    y += jnp.exp(cum)[:, None] * jnp.dot(C, h.T,
                                         preferred_element_type=jnp.float32)

    # state update
    decay_to_end = jnp.exp(cum[-1] - cum) * dt            # [Q]
    h_ref[...] = h * jnp.exp(cum[-1]) + jnp.dot(
        (x * decay_to_end[:, None]).T, B,
        preferred_element_type=jnp.float32)

    o_ref[0] = y.astype(o_ref.dtype)


def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array, C: jax.Array,
        *, chunk: int = 128, interpret: bool = False) -> jax.Array:
    """SSD forward.

    x [b,S,nh,dh]; dt [b,S,nh] (post-softplus); A [nh] (negative);
    B, C [b,S,g,ds] with g groups broadcast over heads.
    Returns y [b,S,nh,dh] (without the D-skip term — caller adds D*x).
    """
    b, S, nh, dh = x.shape
    g, ds = B.shape[2], B.shape[3]
    hpg = nh // g
    assert S % chunk == 0
    nc = S // chunk

    # layout: one grid row per (batch, head)
    xf = x.transpose(0, 2, 1, 3).reshape(b * nh, S, dh)
    dtf = dt.transpose(0, 2, 1).reshape(b * nh, S)
    Af = jnp.broadcast_to(A[None, :], (b, nh)).reshape(b * nh)
    Bh = jnp.repeat(B, hpg, axis=2).transpose(0, 2, 1, 3).reshape(b * nh, S, ds)
    Ch = jnp.repeat(C, hpg, axis=2).transpose(0, 2, 1, 3).reshape(b * nh, S, ds)

    kernel = functools.partial(_ssd_kernel, n_chunks=nc)
    out = pl.pallas_call(
        kernel,
        grid=(b * nh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dh), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk), lambda h, c: (h, c)),
            pl.BlockSpec((1,), lambda h, c: (h,)),
            pl.BlockSpec((1, chunk, ds), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda h, c: (h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dh), lambda h, c: (h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((b * nh, S, dh), x.dtype),
        scratch_shapes=[pltpu.VMEM((dh, ds), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, Af, Bh, Ch)
    return out.reshape(b, nh, S, dh).transpose(0, 2, 1, 3)
