"""Fused matmul (+bias +activation) Pallas TPU kernel.

This is the per-die compute primitive of the paper's architecture: the PE array
consumes operands from on-die SRAM (here: VMEM via BlockSpec tiling) and the
"layer fusion" scheduling keeps bias/activation in the buffers instead of
round-tripping DRAM/HBM (paper §III-B b).

Grid: (M/bm, N/bn, K/bk) with the K axis innermost — TPU grids execute
sequentially per core, so a VMEM f32 scratch accumulates partial products across
K steps and the epilogue (bias + activation) fires on the last K step only.
Block shapes default to MXU-aligned (128x128x512) tiles.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _epilogue(acc, bias, act: str):
    y = acc
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if act == "relu2":
        r = jnp.maximum(y, 0.0)
        y = r * r
    elif act == "gelu":
        y = jax.nn.gelu(y)
    elif act == "silu":
        y = jax.nn.silu(y)
    elif act != "none":
        raise ValueError(act)
    return y


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref, *, act: str, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = _epilogue(acc_ref[...], None, act).astype(o_ref.dtype)


def _mm_bias_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, act: str, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = _epilogue(acc_ref[...], b_ref[...], act).astype(o_ref.dtype)


def matmul(x: jax.Array, w: jax.Array, bias: Optional[jax.Array] = None, *,
           act: str = "none", block_m: int = 128, block_n: int = 128,
           block_k: int = 512, interpret: bool = False) -> jax.Array:
    """y = act(x @ w + bias).  x [M,K], w [K,N]; dims multiples of the blocks."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    grid = (M // bm, N // bn, K // bk)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    if bias is None:
        kernel = functools.partial(_mm_kernel, act=act, n_k=grid[2])
        args = (x, w)
    else:
        kernel = functools.partial(_mm_bias_kernel, act=act, n_k=grid[2])
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        args = (x, w, bias.reshape(1, N))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*args)


def gated_matmul(x: jax.Array, w1: jax.Array, w1b: jax.Array, *,
                 act: str = "silu", block_m: int = 128, block_n: int = 128,
                 block_k: int = 512, interpret: bool = False) -> jax.Array:
    """y = act(x@w1) * (x@w1b) — the fused gated-MLP up-projection.

    Both products read the same x tile from VMEM: the paper's shared-gather
    argument (one load feeds two MACs) expressed at kernel level.
    """
    M, K = x.shape
    _, N = w1.shape
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    grid = (M // bm, N // bn, K // bk)

    def kernel(x_ref, w1_ref, w1b_ref, o_ref, acc_ref, accb_ref):
        @pl.when(pl.program_id(2) == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            accb_ref[...] = jnp.zeros_like(accb_ref)

        xt = x_ref[...]
        acc_ref[...] += jnp.dot(xt, w1_ref[...],
                                preferred_element_type=jnp.float32)
        accb_ref[...] += jnp.dot(xt, w1b_ref[...],
                                 preferred_element_type=jnp.float32)

        @pl.when(pl.program_id(2) == grid[2] - 1)
        def _done():
            g = _epilogue(acc_ref[...], None, act)
            o_ref[...] = (g * accb_ref[...]).astype(o_ref.dtype)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w1, w1b)
