"""Jit'd dispatch wrappers for the Pallas kernels.

``use_pallas`` flips between the TPU kernels and the pure-jnp reference path.
On this CPU container the models default to the reference path (Pallas interpret
mode inside a full model would be impractically slow); on a real TPU set
``REPRO_USE_PALLAS=1`` (read by launch/train.py) to enable the kernels.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import matmul as _mm
from repro.kernels import ref as _ref
from repro.kernels import ssd as _ssd

_INTERPRET = jax.default_backend() != "tpu"


def use_pallas_default() -> bool:
    return os.environ.get("REPRO_USE_PALLAS", "0") == "1"


@functools.partial(jax.jit, static_argnames=("act", "use_pallas"))
def fused_matmul(x, w, bias=None, *, act: str = "none",
                 use_pallas: bool = False):
    if use_pallas:
        return _mm.matmul(x, w, bias, act=act, interpret=_INTERPRET)
    return _ref.matmul_ref(x, w, bias, act=act)


@functools.partial(jax.jit, static_argnames=("act", "use_pallas"))
def fused_gated_matmul(x, w1, w1b, *, act: str = "silu",
                       use_pallas: bool = False):
    if use_pallas:
        return _mm.gated_matmul(x, w1, w1b, act=act, interpret=_INTERPRET)
    return _ref.gated_matmul_ref(x, w1, w1b, act=act)


@functools.partial(jax.jit, static_argnames=("causal", "use_pallas"))
def attention(q, k, v, *, causal: bool = True, use_pallas: bool = False):
    if use_pallas:
        return _fa.flash_attention(q, k, v, causal=causal,
                                   interpret=_INTERPRET)
    return _ref.attention_ref(q, k, v, causal=causal)


@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas"))
def ssd(x, dt, A, B, C, *, chunk: int = 128, use_pallas: bool = False):
    if use_pallas:
        return _ssd.ssd(x, dt, A, B, C, chunk=chunk, interpret=_INTERPRET)
    return _ref.ssd_ref(x, dt, A, B, C, chunk=chunk)
