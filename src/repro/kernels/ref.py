"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x, w, bias=None, *, act: str = "none"):
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if act == "relu2":
        r = jnp.maximum(y, 0.0)
        y = r * r
    elif act == "gelu":
        y = jax.nn.gelu(y)
    elif act == "silu":
        y = jax.nn.silu(y)
    elif act != "none":
        raise ValueError(act)
    return y.astype(x.dtype)


def gated_matmul_ref(x, w1, w1b, *, act: str = "silu"):
    a = matmul_ref(x, w1, act=act).astype(jnp.float32)
    b = jnp.dot(x.astype(jnp.float32), w1b.astype(jnp.float32))
    return (a * b).astype(x.dtype)


def attention_ref(q, k, v, *, causal: bool = True):
    """q [B,nh,Sq,dh]; k,v [B,nkv,Sk,dh].  Naive softmax attention."""
    B, nh, Sq, dh = q.shape
    nkv = k.shape[1]
    if nh != nkv:
        k = jnp.repeat(k, nh // nkv, axis=1)
        v = jnp.repeat(v, nh // nkv, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    if causal:
        Sk = k.shape[2]
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None] + (Sk - Sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x, dt, A, B, C, *, chunk: int = 128):
    """Sequential (non-chunked) SSD recurrence — the strongest oracle.

    Shapes as kernels/ssd.ssd.  h_t = h_{t-1}*exp(dt_t*A) + dt_t * B_t x_t^T;
    y_t = C_t . h_t.
    """
    b, S, nh, dh = x.shape
    g, ds = B.shape[2], B.shape[3]
    hpg = nh // g
    Bh = jnp.repeat(B, hpg, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, hpg, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, t):
        xt, dtt, Bt, Ct = t
        dA = jnp.exp(dtt * Af)[..., None, None]             # [b,nh,1,1]
        h = h * dA + jnp.einsum("bhd,bhn->bhdn", xt * dtt[..., None], Bt)
        y = jnp.einsum("bhdn,bhn->bhd", h, Ct)
        return h, y

    h0 = jnp.zeros((b, nh, dh, ds), jnp.float32)
    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)


# the chunked-but-pure-jnp implementation used inside the models is itself
# property-tested against ssd_ref (tests/test_kernels.py)
from repro.models.ssm import ssd_chunked as ssd_chunked_jnp  # noqa: E402,F401
