"""Fused Pallas ring-matmul kernels — remote DMA double-buffered inside the tile loop.

PR 1 decomposed Hecaton's bulk AG/RS collectives into ``lax.ppermute`` rings
(core/overlap.py), which *exposes* the overlap to the XLA scheduler: each ring
step is still its own dispatch, and the permute for step ``k+1`` only hides
behind the matmul for step ``k`` if the scheduler cooperates.  This module is
the next rung (paper §III-B scheduling): the whole ring runs inside **one**
kernel, where a double-buffered VMEM pair receives the next peer's shard via
``pltpu.make_async_remote_copy`` while the MXU consumes the current shard
through the same MXU-aligned tile loop as ``kernels/matmul.py`` (fp32
accumulator scratch, fused bias/activation epilogue, gated variant reusing the
shared-x-tile trick).  Overlap is then guaranteed by construction — no
kernel-launch or VMEM-refill gap between ring steps.

Three collective-matmul shapes (mirroring core/overlap.py's ring primitives,
all called *inside* shard_map on per-device blocks):

  ``ag_matmul``           AG ⊕ matmul, gathered dim is a batch dim (tokens):
                          step *k*'s tile matmul fills its slot of the output
                          while the DMA for step *k+1* is in flight.
  ``matmul_rs``           matmul ⊕ RS: a per-destination accumulator tile
                          circulates through the VMEM pair; each step folds in
                          the local contribution straight from the MXU.
  ``ag_matmul_contract``  AG ⊕ matmul over the *contracted* dim: per-step
                          partial products accumulate in an fp32 VMEM scratch
                          that spans ring steps (epilogue on the last step).
  ``matmul_rs_pair``      gated variant: two circulating accumulators whose
                          per-step contributions read the SAME x tile from
                          VMEM (the shared-x-tile trick of
                          ``kernels/matmul.gated_matmul`` at ring scope).

Execution modes
---------------
* **TPU** (``compat.remote_dma_supported()``): single ``pallas_call`` per
  collective with ``make_async_remote_copy`` between ring neighbours,
  ``make_async_copy`` for the local prologue, per-slot DMA semaphores, and a
  REGULAR capacity semaphore providing back-pressure so a neighbour never
  lands a shard in a slot the MXU is still reading.
* **everywhere else** (CPU CI, interpret mode): the ppermute-emulation shim
  ``compat.ring_step_permute`` replaces each remote DMA hop with one
  ``lax.ppermute`` of the circulating buffer — identical data movement and
  step count — while per-step compute still runs through the Pallas tile loop
  with ``interpret=True``.  This is what the 4x2/2x2/4x1 grid numerics tests
  cover.

Autodiff: every public op carries a ``jax.custom_vjp`` whose backward is the
*transposed ring* — transpose(AG-matmul) is a matmul-RS over the reversed ring
and vice versa, exactly the pairing JAX derives automatically for the unrolled
ppermute rings in core/overlap.py.  The backward therefore stays fused /
ring-decomposed too.

Communication dtype (``comm_dtype``, docs/DESIGN.md §11): ``"bf16"`` ships
shards as-is; ``"int8"`` carries an ``(int8 payload, fp32 per-row scale)``
pair over every hop.  On the emulated path each ppermute hop routes through
``core/quant.ring_hop``; on the TPU path the double-buffered VMEM pair
becomes a quantized pair — for the AG/contract kernels the circulating shard
is quantized ONCE outside the kernel (the payload is invariant around the
ring) and dequantized per tile at the MXU dot, while the matmul-RS kernel
re-quantizes the circulating *accumulator* at each send (it changes every
hop): folds land in a full-width ``work`` staging buffer, whose whole-buffer
quantize happens right before the paired remote DMAs.  The fp32 accumulator
tiles themselves never quantize — only link traffic does.  Hops whose shard
cannot carry scales (``quant.quant_ok``) degrade per collective to the
full-width pair, mirroring the fused→ring→bulk lattice.

Fallback contract: callers gate on :func:`fused_ok` (MXU-tile-aligned dims and
ring-divisible extents).  Shapes that fail the gate are routed by
``core/overlap.py`` to the plain ``ring`` decomposition — same degradation
contract as ``bidir`` → ``ring`` for un-halvable shards.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.core import quant as Q
from repro.kernels.matmul import _epilogue, _mm_bias_kernel, _mm_kernel

# MXU-aligned tile preferences (same defaults as kernels/matmul.py).
BLOCK_M, BLOCK_N, BLOCK_K = 128, 128, 512

# Per-core VMEM budget for the single-kernel scratch (double-buffered shard /
# accumulator pair + fp32 acc tiles); shapes whose scratch would exceed it are
# routed to the plain ring decomposition by the fused_ok_* gates.
VMEM_BUDGET = 12 * 2 ** 20


# ---------------------------------------------------------------------------
# Block selection / fused-mode gating
# ---------------------------------------------------------------------------


def pick_block(dim: int, pref: int) -> int:
    """Largest tile <= ``pref`` that divides ``dim`` (always succeeds).

    A dim no larger than the preference is its own (single) tile; otherwise
    prefer the MXU-aligned size and degrade to the largest divisor.  The
    degraded tiles keep the emulated path (and transposed backward shapes)
    correct on any extent; :func:`aligned` is the stricter gate the overlap
    dispatcher uses to decide fused vs ring."""
    if dim <= pref:
        return max(dim, 1)
    if dim % pref == 0:
        return pref
    for b in range(pref - 1, 0, -1):
        if dim % b == 0:
            return b
    return 1


def aligned(dim: int, pref: int) -> bool:
    """Tile-aligned in the fused-kernel sense: one tile, or MXU-tiled."""
    return dim <= pref or dim % pref == 0


def _mk(shape3) -> Tuple[int, int]:
    """(M, K) of the flattened per-step matmul for a [b, t, h] block."""
    b, t, h = shape3
    return b * t, h


def _prod(shape) -> int:
    p = 1
    for s in shape:
        p *= s
    return p


def _fits_vmem(*byte_counts) -> bool:
    return sum(byte_counts) <= VMEM_BUDGET


def _tile_bytes(itemsize: int) -> int:
    """fp32 acc tile + double-buffered operand/output tiles (upper bound)."""
    return (BLOCK_M * BLOCK_N * 4
            + 2 * (BLOCK_M * BLOCK_K + BLOCK_K * BLOCK_N
                   + BLOCK_M * BLOCK_N) * itemsize)


def fused_ok_ag(x_shape, w_shape, n: int, dim: int = 1,
                itemsize: int = 4) -> bool:
    """Can ``ag_matmul`` run fused for x [b,t,h] (gather ``dim``), w [h,o]?

    Requires MXU-tile-aligned dims AND the double-buffered shard pair fitting
    the VMEM budget — anything else degrades to the ppermute ring."""
    if n <= 1 or len(x_shape) != 3 or dim != 1:
        return False
    m, k = _mk(x_shape)
    return (x_shape[-1] == w_shape[0] and aligned(m, BLOCK_M)
            and aligned(k, BLOCK_K) and aligned(w_shape[-1], BLOCK_N)
            and _fits_vmem(2 * _prod(x_shape) * itemsize,
                           _tile_bytes(itemsize)))


def fused_ok_rs(x_shape, w_shape, n: int, scatter_dim: int,
                itemsize: int = 4) -> bool:
    """Can ``matmul_rs`` run fused for x [b,t,h] @ w [h,o], scatter ``dim``?"""
    if n <= 1 or len(x_shape) != 3:
        return False
    last = scatter_dim == len(x_shape) - 1
    scattered = w_shape[-1] if last else x_shape[scatter_dim]
    if scattered % n:
        return False
    chunk = scattered // n
    if last:
        m, k, nn = x_shape[0] * x_shape[1], x_shape[-1], chunk
        out_elts = _prod(x_shape[:-1]) * chunk
    else:
        m, k, nn = x_shape[0] * chunk, x_shape[-1], w_shape[-1]
        out_elts = x_shape[0] * chunk * w_shape[-1]
    return (x_shape[-1] == w_shape[0] and aligned(m, BLOCK_M)
            and aligned(k, BLOCK_K) and aligned(nn, BLOCK_N)
            and _fits_vmem(2 * out_elts * itemsize, _tile_bytes(itemsize)))


def fused_ok_contract(x_shape, w_shape, n: int, itemsize: int = 4) -> bool:
    """Can ``ag_matmul_contract`` run fused (gathered dim contracted)?

    The fp32 accumulator spanning ring steps lives in VMEM whole, so it
    counts against the budget alongside the circulating shard pair."""
    if n <= 1 or len(x_shape) != 3 or w_shape[0] != n * x_shape[-1]:
        return False
    m, k = _mk(x_shape)
    return (aligned(m, BLOCK_M) and aligned(k, BLOCK_K)
            and aligned(w_shape[-1], BLOCK_N)
            and _fits_vmem(2 * _prod(x_shape) * itemsize,
                           m * w_shape[-1] * 4, _tile_bytes(itemsize)))


# ---------------------------------------------------------------------------
# Per-step tile matmul (the kernels/matmul.py loop with an out_dtype knob)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=512)
def _tile_mm_call(M: int, K: int, N: int, bm: int, bn: int, bk: int,
                  has_bias: bool, act: str, out_dtype_name: str,
                  interpret: bool):
    """Build (and CACHE) the ``pallas_call`` for one tile-matmul signature.

    The emulated ring loops invoke a tile matmul of the *same* shape once per
    ring step (and again per benchmark iteration); rebuilding the pallas_call
    closure each time re-traced the kernel per step, a pure-overhead cost on
    the interpret path.  Keyed on the full static signature, each distinct
    matmul shape is constructed exactly once per process and every ring step
    reuses the same compiled callable."""
    grid = (M // bm, N // bn, K // bk)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    if has_bias:
        kernel = functools.partial(_mm_bias_kernel, n_k=grid[2], act=act)
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
    else:
        kernel = functools.partial(_mm_kernel, n_k=grid[2], act=act)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.dtype(out_dtype_name)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )


def _tile_mm_raw(x, w, bias=None, *, act: str = "none", out_dtype=None,
                 interpret: Optional[bool] = None):
    """y = act(x @ w + bias) through the Pallas tile loop; x [M,K], w [K,N].

    Blocks come from :func:`pick_block`, so any extent works (degraded tiles
    off the MXU-aligned fast path).  ``out_dtype`` keeps fp32 partials alive
    across ring steps for the contracted-gather accumulation.

    On the interpret path (CPU CI / emulated rings) the grid collapses to a
    SINGLE cell (bm, bn, bk) = (M, N, K): the Pallas interpreter pays a fixed
    overhead per grid cell and has no VMEM capacity to respect, so one cell
    per matmul removes nearly all of the emulation tax while still executing
    the exact kernel body (acc init → dot → epilogue).  Real-TPU tiling is
    unchanged."""
    if interpret is None:
        interpret = not compat.remote_dma_supported()
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    if interpret:
        bm, bn, bk = M, N, K
    else:
        bm, bn, bk = pick_block(M, BLOCK_M), pick_block(N, BLOCK_N), \
            pick_block(K, BLOCK_K)
    out_dtype = out_dtype or x.dtype
    call = _tile_mm_call(M, K, N, bm, bn, bk, bias is not None, act,
                         jnp.dtype(out_dtype).name, interpret)
    if bias is None:
        return call(x, w)
    return call(x, w, bias.reshape(1, N))


@jax.custom_vjp
def tile_matmul(x, w):
    """Differentiable plain tile matmul (no epilogue), y in x.dtype.

    The backward runs through the same Pallas tile loop (dx = g wᵀ, dw = xᵀ g),
    so ring backwards stay on the kernel path too."""
    return _tile_mm_raw(x, w)


def _tile_matmul_f32(x, w):
    return _tile_mm_raw(x, w, out_dtype=jnp.float32)


def _tile_mm_fwd(x, w):
    return tile_matmul(x, w), (x, w)


def _tile_mm_bwd(res, g):
    x, w = res
    dx = _tile_mm_raw(g.astype(x.dtype), w.T.astype(x.dtype),
                      out_dtype=x.dtype)
    dw = _tile_mm_raw(x.T, g.astype(x.dtype), out_dtype=w.dtype)
    return dx, dw


tile_matmul.defvjp(_tile_mm_fwd, _tile_mm_bwd)


# ---------------------------------------------------------------------------
# small local helpers (kept self-contained: core/overlap.py imports this
# module at top level, so we must not import it back at module scope)
# ---------------------------------------------------------------------------


def _put(buf, part, dim: int, start):
    starts = [0] * buf.ndim
    starts[dim] = start
    return lax.dynamic_update_slice(buf, part.astype(buf.dtype), tuple(starts))


def _take(x, dim: int, start, size: int):
    starts = [0] * x.ndim
    starts[dim] = start
    sizes = list(x.shape)
    sizes[dim] = size
    return lax.dynamic_slice(x, tuple(starts), tuple(sizes))


def _flat(x3):
    b, t, h = x3.shape
    return x3.reshape(b * t, h)


def _unflat(x2, b):
    m, o = x2.shape
    return x2.reshape(b, m // b, o)


def _mm3(x3, w, out_dtype=None):
    """Per-step [b,t,h] @ [h,o] through the tile loop (differentiable)."""
    if out_dtype in (None, x3.dtype):
        return _unflat(tile_matmul(_flat(x3), w), x3.shape[0])
    return _unflat(_tile_matmul_f32(_flat(x3), w), x3.shape[0]).astype(
        out_dtype)


def _pure_ag(x, axis_name: str, dim: int, n: int, comm_dtype: str = "bf16"):
    """Plain ppermute ring all-gather (rank order), used by vjp helpers."""
    if n <= 1:
        return x
    idx = lax.axis_index(axis_name)
    chunk = x.shape[dim]
    shape = list(x.shape)
    shape[dim] = chunk * n
    out = jnp.zeros(tuple(shape), x.dtype)
    cur = x
    for s in range(n):
        out = _put(out, cur, dim, ((idx - s) % n) * chunk)
        if s < n - 1:
            cur = Q.ring_hop(cur, axis_name, n, 1, comm_dtype)
    return out


# ---------------------------------------------------------------------------
# Emulated fused loops (ppermute hops between Pallas tile-loop steps)
# ---------------------------------------------------------------------------


def _ag_mm_impl(x, w, axis_name: str, dim: int, n: int, bias, act: str,
                comm_dtype: str = "bf16"):
    """Ring AG-matmul: circulate x shards, tile-matmul each into its slot."""
    if n <= 1:
        return _unflat(_tile_mm_raw(_flat(x), w, bias, act=act), x.shape[0])
    idx = lax.axis_index(axis_name)
    chunk = x.shape[dim]
    shape = list(x.shape)
    shape[dim] = chunk * n
    shape[-1] = w.shape[-1]
    out = jnp.zeros(tuple(shape), x.dtype)
    cur = x
    for s in range(n):
        if bias is None and act == "none":
            y = _mm3(cur, w)
        else:   # fwd-only epilogue path (elementwise ⇒ valid per slot)
            y = _unflat(_tile_mm_raw(_flat(cur), w, bias, act=act),
                        cur.shape[0])
        out = _put(out, y, dim, ((idx - s) % n) * chunk)
        if s < n - 1:
            cur = Q.ring_hop(cur, axis_name, n, 1, comm_dtype)
    return out


def _mm_rs_impl(x, w, axis_name: str, scatter_dim: int, n: int, bias, act,
                comm_dtype: str = "bf16"):
    """Ring matmul-RS: per-destination tile folded into a circulating acc."""
    if n <= 1:
        return _unflat(_tile_mm_raw(_flat(x), w, bias, act=act), x.shape[0])
    idx = lax.axis_index(axis_name)
    last = scatter_dim == x.ndim - 1
    scattered = w.shape[-1] if last else x.shape[scatter_dim]
    assert scattered % n == 0, (
        f"fused matmul-RS: extent {scattered} does not chunk by ring {n}")
    chunk = scattered // n

    if last:                                # chunk w's output columns
        def contrib(d):
            return _mm3(x, _take(w, 1, d * chunk, chunk))
    else:                                   # chunk x's rows along scatter_dim
        def contrib(d):
            return _mm3(_take(x, scatter_dim, d * chunk, chunk), w)

    acc = contrib((idx - 1) % n)
    for s in range(1, n):
        acc = Q.ring_hop(acc, axis_name, n, 1, comm_dtype)
        acc = acc + contrib((idx + n - 1 - s) % n)
    if bias is None and act == "none":
        return acc
    return _epilogue(acc.astype(jnp.float32),
                     None if bias is None else bias, act).astype(acc.dtype)


def _ag_mm_contract_impl(x, w, axis_name: str, n: int, out_dtype, bias, act,
                         comm_dtype: str = "bf16"):
    """Ring AG-matmul over the contracted dim: fp32 acc spans ring steps."""
    dt = out_dtype or x.dtype
    if n <= 1:
        y = _tile_mm_raw(_flat(x), w, bias, act=act, out_dtype=dt)
        return _unflat(y, x.shape[0])
    idx = lax.axis_index(axis_name)
    h_loc = x.shape[-1]
    acc = jnp.zeros(x.shape[:-1] + (w.shape[-1],), jnp.float32)
    cur = x
    for s in range(n):
        src = (idx - s) % n
        acc = acc + _mm3(cur, _take(w, 0, src * h_loc, h_loc), jnp.float32)
        if s < n - 1:
            cur = Q.ring_hop(cur, axis_name, n, 1, comm_dtype)
    if bias is not None or act != "none":
        acc = _epilogue(acc, bias, act)
    return acc.astype(dt)


def _mm_rs_pair_impl(x, w1, w1b, axis_name: str, scatter_dim: int, n: int,
                     comm_dtype: str = "bf16"):
    """Two circulating accumulators; per-step contributions share the x tile
    (one Pallas call on the column-concatenated weights reads each x tile once
    for both products — gated_matmul's trick at ring scope)."""
    wc = jnp.concatenate([w1, w1b], axis=1)
    o1 = w1.shape[-1]
    if n <= 1:
        y = _mm3(x, wc)
        return y[..., :o1], y[..., o1:]
    idx = lax.axis_index(axis_name)
    assert scatter_dim != x.ndim - 1, "pair variant scatters the token dim"
    scattered = x.shape[scatter_dim]
    assert scattered % n == 0
    chunk = scattered // n

    def contrib(d):
        y = _mm3(_take(x, scatter_dim, d * chunk, chunk), wc)
        return y[..., :o1], y[..., o1:]

    acc, accb = contrib((idx - 1) % n)
    for s in range(1, n):
        acc = Q.ring_hop(acc, axis_name, n, 1, comm_dtype)
        accb = Q.ring_hop(accb, axis_name, n, 1, comm_dtype)
        c, cb = contrib((idx + n - 1 - s) % n)
        acc, accb = acc + c, accb + cb
    return acc, accb


# ---------------------------------------------------------------------------
# vjp helper rings (run in backward passes only)
# ---------------------------------------------------------------------------


def _contract_rows_ring(x, dy, axis_name: str, scatter_dim: int, n: int,
                        w_dtype, comm_dtype: str = "bf16"):
    """dw = Σ_d take(x, d·chunk)ᵀ @ dy_d — circulate dy, contract per step."""
    idx = lax.axis_index(axis_name)
    chunk = x.shape[scatter_dim] // n
    dw = None
    cur = dy
    for s in range(n):
        d = (idx - s) % n
        xd = _flat(_take(x, scatter_dim, d * chunk, chunk))
        term = _tile_mm_raw(xd.T, _flat(cur).astype(x.dtype),
                            out_dtype=jnp.float32)
        dw = term if dw is None else dw + term
        if s < n - 1:
            cur = Q.ring_hop(cur, axis_name, n, 1, comm_dtype)
    return dw.astype(w_dtype)


def _place_cols_ring(x, dy, axis_name: str, n: int, w_shape, w_dtype,
                     comm_dtype: str = "bf16"):
    """dw[:, d·chunk] = xᵀ @ dy_d — circulate dy, place column chunks."""
    idx = lax.axis_index(axis_name)
    chunk = w_shape[-1] // n
    dw = jnp.zeros(w_shape, jnp.float32)
    cur = dy
    for s in range(n):
        d = (idx - s) % n
        term = _tile_mm_raw(_flat(x).T, _flat(cur).astype(x.dtype),
                            out_dtype=jnp.float32)
        dw = _put(dw, term, 1, d * chunk)
        if s < n - 1:
            cur = Q.ring_hop(cur, axis_name, n, 1, comm_dtype)
    return dw.astype(w_dtype)


def _place_rows_ring(x, dy, axis_name: str, n: int, w_shape, w_dtype,
                     comm_dtype: str = "bf16"):
    """dw[d·h_loc, :] = x_dᵀ @ dy — circulate x, place row chunks."""
    idx = lax.axis_index(axis_name)
    h_loc = x.shape[-1]
    dw = jnp.zeros(w_shape, jnp.float32)
    cur = x
    for s in range(n):
        src = (idx - s) % n
        term = _tile_mm_raw(_flat(cur).T, _flat(dy).astype(x.dtype),
                            out_dtype=jnp.float32)
        dw = _put(dw, term, 0, src * h_loc)
        if s < n - 1:
            cur = Q.ring_hop(cur, axis_name, n, 1, comm_dtype)
    return dw.astype(w_dtype)


# ---------------------------------------------------------------------------
# Public ops (custom_vjp: the backward is the transposed ring, still fused)
# ---------------------------------------------------------------------------


def _use_tpu(n: int, mesh_axes) -> bool:
    """Take the single-kernel remote-DMA path?  Requires a real TPU backend,
    a non-degenerate ring, AND the caller having supplied the full mesh axis
    list (needed to address ring neighbours by mesh coordinates)."""
    return n > 1 and mesh_axes is not None and compat.remote_dma_supported()


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def _ag_mm(x, w, axis_name: str, dim: int, n: int, mesh_axes, comm_dtype):
    if not _use_tpu(n, mesh_axes):
        return _ag_mm_impl(x, w, axis_name, dim, n, None, "none", comm_dtype)
    return _ag_matmul_tpu(x, w, axis_name=axis_name, dim=dim, n=n,
                          mesh_axes=mesh_axes, comm_dtype=comm_dtype)


def _ag_mm_fwd(x, w, axis_name, dim, n, mesh_axes, comm_dtype):
    return _ag_mm(x, w, axis_name, dim, n, mesh_axes, comm_dtype), (x, w)


def _ag_mm_bwd(axis_name, dim, n, mesh_axes, comm_dtype, res, dy):
    x, w = res
    # transpose(ring AG-matmul) = ring matmul-RS over the reversed ring
    dx = _mm_rs(dy, w.T, axis_name, dim, n, mesh_axes,
                comm_dtype).astype(x.dtype)
    xg = _pure_ag(x, axis_name, dim, n, comm_dtype)
    dw = _tile_mm_raw(_flat(xg).T, _flat(dy).astype(x.dtype),
                      out_dtype=jnp.float32).astype(w.dtype)
    return dx, dw


_ag_mm.defvjp(_ag_mm_fwd, _ag_mm_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def _mm_rs(x, w, axis_name: str, scatter_dim: int, n: int, mesh_axes,
           comm_dtype):
    if not _use_tpu(n, mesh_axes):
        return _mm_rs_impl(x, w, axis_name, scatter_dim, n, None, "none",
                           comm_dtype)
    return _matmul_rs_tpu(x, w, axis_name=axis_name, scatter_dim=scatter_dim,
                          n=n, mesh_axes=mesh_axes, comm_dtype=comm_dtype)


def _mm_rs_fwd(x, w, axis_name, scatter_dim, n, mesh_axes, comm_dtype):
    return (_mm_rs(x, w, axis_name, scatter_dim, n, mesh_axes, comm_dtype),
            (x, w))


def _mm_rs_bwd(axis_name, scatter_dim, n, mesh_axes, comm_dtype, res, dy):
    x, w = res
    if scatter_dim == x.ndim - 1:
        # y_chunk = x @ w[:, dᵢ]: dx = AG_cols(dy) ⊗ wᵀ (contracted ring)
        dx = _ag_mm_contract(dy, w.T, axis_name, n, x.dtype,
                             mesh_axes, comm_dtype).astype(x.dtype)
        dw = _place_cols_ring(x, dy, axis_name, n, w.shape, w.dtype,
                              comm_dtype)
    else:
        # transpose(ring matmul-RS) = ring AG-matmul
        dx = _ag_mm(dy.astype(x.dtype), w.T, axis_name, scatter_dim, n,
                    mesh_axes, comm_dtype)
        dw = _contract_rows_ring(x, dy, axis_name, scatter_dim, n, w.dtype,
                                 comm_dtype)
    return dx, dw


_mm_rs.defvjp(_mm_rs_fwd, _mm_rs_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def _ag_mm_contract(x, w, axis_name: str, n: int, out_dtype, mesh_axes,
                    comm_dtype):
    if not _use_tpu(n, mesh_axes):
        return _ag_mm_contract_impl(x, w, axis_name, n, out_dtype, None,
                                    "none", comm_dtype)
    return _ag_matmul_contract_tpu(x, w, axis_name=axis_name, n=n,
                                   out_dtype=out_dtype, mesh_axes=mesh_axes,
                                   comm_dtype=comm_dtype)


def _ag_mm_contract_fwd(x, w, axis_name, n, out_dtype, mesh_axes, comm_dtype):
    return (_ag_mm_contract(x, w, axis_name, n, out_dtype, mesh_axes,
                            comm_dtype), (x, w))


def _ag_mm_contract_bwd(axis_name, n, out_dtype, mesh_axes, comm_dtype, res,
                        dy):
    x, w = res
    # y = Σ_src x_src @ w[src rows]: dx arrives as a matmul-RS over wᵀ columns
    dx = _mm_rs(dy.astype(x.dtype), w.T, axis_name, dy.ndim - 1, n,
                mesh_axes, comm_dtype).astype(x.dtype)
    dw = _place_rows_ring(x, dy, axis_name, n, w.shape, w.dtype, comm_dtype)
    return dx, dw


_ag_mm_contract.defvjp(_ag_mm_contract_fwd, _ag_mm_contract_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _mm_rs_pair(x, w1, w1b, axis_name: str, scatter_dim: int, n: int,
                mesh_axes, comm_dtype):
    if not _use_tpu(n, mesh_axes):
        return _mm_rs_pair_impl(x, w1, w1b, axis_name, scatter_dim, n,
                                comm_dtype)
    return _matmul_rs_pair_tpu(x, w1, w1b, axis_name=axis_name,
                               scatter_dim=scatter_dim, n=n,
                               mesh_axes=mesh_axes, comm_dtype=comm_dtype)


def _mm_rs_pair_fwd(x, w1, w1b, axis_name, scatter_dim, n, mesh_axes,
                    comm_dtype):
    return (_mm_rs_pair(x, w1, w1b, axis_name, scatter_dim, n, mesh_axes,
                        comm_dtype), (x, w1, w1b))


def _mm_rs_pair_bwd(axis_name, scatter_dim, n, mesh_axes, comm_dtype, res,
                    dys):
    x, w1, w1b = res
    dh, dg = dys
    dx = (_ag_mm(dh.astype(x.dtype), w1.T, axis_name, scatter_dim, n,
                 mesh_axes, comm_dtype)
          + _ag_mm(dg.astype(x.dtype), w1b.T, axis_name, scatter_dim, n,
                   mesh_axes, comm_dtype))
    dw1 = _contract_rows_ring(x, dh, axis_name, scatter_dim, n, w1.dtype,
                              comm_dtype)
    dw1b = _contract_rows_ring(x, dg, axis_name, scatter_dim, n, w1b.dtype,
                               comm_dtype)
    return dx, dw1, dw1b


_mm_rs_pair.defvjp(_mm_rs_pair_fwd, _mm_rs_pair_bwd)


# -- public wrappers --------------------------------------------------------


def ag_matmul(x, w, axis_name: str, *, dim: int = 1, n: int,
              bias=None, act: str = "none", mesh_axes=None,
              comm_dtype: str = "bf16"):
    """Fused all-gather ⊕ matmul; x [b,t,h] (gather ``dim``), w [h,o].

    Differentiable when no epilogue is requested; the bias/activation epilogue
    (fused into the last K step of each tile loop) is forward-only — hecaton's
    training path never uses it, serving and kernel tests do.  ``mesh_axes``
    is the enclosing mesh's full axis-name tuple, required for the TPU
    remote-DMA path to address ring neighbours by mesh coordinates; without
    it the ppermute-emulated path runs.  ``comm_dtype="int8"`` ships each hop
    as an (int8, fp32 per-row scale) pair (docs/DESIGN.md §11)."""
    if bias is None and act == "none":
        return _ag_mm(x, w, axis_name, dim, n, _axes_key(mesh_axes),
                      comm_dtype)
    return _ag_mm_impl(x, w, axis_name, dim, n, bias, act, comm_dtype)


def matmul_rs(x, w, axis_name: str, *, scatter_dim: int, n: int,
              bias=None, act: str = "none", mesh_axes=None,
              comm_dtype: str = "bf16"):
    """Fused matmul ⊕ reduce-scatter; epilogue fires on the final (fully
    reduced) accumulator only, preserving post-reduction semantics."""
    if bias is None and act == "none":
        return _mm_rs(x, w, axis_name, scatter_dim, n, _axes_key(mesh_axes),
                      comm_dtype)
    return _mm_rs_impl(x, w, axis_name, scatter_dim, n, bias, act, comm_dtype)


def ag_matmul_contract(x, w, axis_name: str, *, n: int, out_dtype=None,
                       bias=None, act: str = "none", mesh_axes=None,
                       comm_dtype: str = "bf16"):
    """Fused all-gather ⊕ matmul over the contracted dim (fp32 ring acc)."""
    if bias is None and act == "none":
        return _ag_mm_contract(x, w, axis_name, n, out_dtype,
                               _axes_key(mesh_axes), comm_dtype)
    return _ag_mm_contract_impl(x, w, axis_name, n, out_dtype, bias, act,
                                comm_dtype)


def matmul_rs_pair(x, w1, w1b, axis_name: str, *, scatter_dim: int, n: int,
                   mesh_axes=None, comm_dtype: str = "bf16"):
    """Gated fused matmul ⊕ RS: returns (x·w1, x·w1b) reduce-scattered, both
    per-step contributions reading the same x tile.  The caller applies the
    gate (``act(h) * g``) — keeping the nonlinearity outside lets model code
    pass arbitrary activation callables."""
    return _mm_rs_pair(x, w1, w1b, axis_name, scatter_dim, n,
                       _axes_key(mesh_axes), comm_dtype)


def _axes_key(mesh_axes):
    """Normalize to a hashable tuple (custom_vjp nondiff arg) or None."""
    return tuple(mesh_axes) if mesh_axes else None


# ---------------------------------------------------------------------------
# TPU single-kernel path: the whole ring inside one pallas_call.
#
# Synchronisation scheme (per ring collective):
#   * barrier semaphore handshake with both neighbours at kernel start;
#   * per-slot DMA send/recv semaphores for the double-buffered VMEM pair;
#   * a REGULAR capacity semaphore: the consumer signals its *upstream*
#     neighbour after the MXU finishes a step, and the sender consumes one
#     credit before overwriting that slot — a neighbour running one step
#     ahead can therefore never land a shard in a buffer still being read.
#
# ``device_id`` uses ``DeviceIdType.MESH``: a tuple of mesh coordinates over
# the *full* axis list of the enclosing mesh (``mesh_axes``, plumbed down
# from the hecaton/megatron call sites, which know ``mesh.axis_names``).  All
# coordinates are computed *outside* the kernel with lax.axis_index and
# handed in via scalar prefetch; only the ring axis differs between self and
# neighbours.
# ---------------------------------------------------------------------------


def _ring_ids(axis_name: str, n: int, mesh_axes):
    axes = tuple(mesh_axes)
    assert axis_name in axes, (axis_name, axes)
    coords = {a: lax.axis_index(a) for a in axes}
    me = coords[axis_name]
    right = [coords[a] if a != axis_name else (me + 1) % n for a in axes]
    left = [coords[a] if a != axis_name else (me - 1) % n for a in axes]
    return jnp.stack([me] + right + left).astype(jnp.int32), len(axes)


def _nbr(ids_ref, n_axes: int, which: str):
    off = 1 if which == "right" else 1 + n_axes
    return tuple(ids_ref[off + i] for i in range(n_axes))


def _ag_matmul_tpu(x, w, *, axis_name: str, dim: int, n: int,
                   act: str = "none", mesh_axes=None,
                   collective_id: int = 0, comm_dtype: str = "bf16"):
    """Single-kernel ring AG-matmul: grid (step, batch, m, n, k); the remote
    DMA for step s+1 launches on step s's first tile and the MXU consumes the
    current slot through the tile loop meanwhile.

    ``comm_dtype="int8"``: the shard is quantized ONCE on the host side of
    the call (it circulates unchanged, so a single quantization serves every
    hop — strictly less error than the emulated path's per-hop roundtrip)
    and the double-buffered VMEM pair becomes an (int8 payload, fp32 per-row
    scale) pair moved by paired remote DMAs sharing one capacity credit;
    each MXU tile dequantizes its slice right before the dot."""
    assert dim == 1, "token-dim gather only"
    b, t, h = x.shape
    o = w.shape[-1]
    bm, bn, bk = pick_block(t, BLOCK_M), pick_block(o, BLOCK_N), \
        pick_block(h, BLOCK_K)
    mt, nt, kt = t // bm, o // bn, h // bk
    ids, n_axes = _ring_ids(axis_name, n, mesh_axes)
    quant = comm_dtype == "int8" and Q.quant_ok(x.shape, x.dtype)

    def kernel(ids_ref, *refs):
        if quant:
            (xq_hbm, xs_hbm, w_ref, o_ref, buf, sbuf, acc, copy_sem,
             send_sem, recv_sem, send_s, recv_s, cap_sem) = refs
        else:
            (x_hbm, w_ref, o_ref, buf, acc, copy_sem,
             send_sem, recv_sem, cap_sem) = refs
        s, bi = pl.program_id(0), pl.program_id(1)
        i, j, k = pl.program_id(2), pl.program_id(3), pl.program_id(4)
        first = (bi == 0) & (i == 0) & (j == 0) & (k == 0)
        last = ((bi == b - 1) & (i == mt - 1) & (j == nt - 1)
                & (k == kt - 1))
        slot = lax.rem(s, 2)
        nxt = lax.rem(s + 1, 2)

        @pl.when((s == 0) & first)
        def _prologue():
            barrier = pltpu.get_barrier_semaphore()
            for which in ("left", "right"):
                pltpu.semaphore_signal(
                    barrier, inc=1, device_id=_nbr(ids_ref, n_axes, which),
                    device_id_type=pltpu.DeviceIdType.MESH)
            pltpu.semaphore_wait(barrier, 2)
            if quant:
                cp = pltpu.make_async_copy(xq_hbm, buf.at[0], copy_sem)
                cp.start()
                cp.wait()
                cp = pltpu.make_async_copy(xs_hbm, sbuf.at[0], copy_sem)
            else:
                cp = pltpu.make_async_copy(x_hbm, buf.at[0], copy_sem)
            cp.start()
            cp.wait()

        @pl.when((s > 0) & first)
        def _recv_wait():     # data for this step landed in buf[slot]
            pltpu.make_async_copy(buf.at[slot], buf.at[slot],
                                  recv_sem.at[slot]).wait()
            if quant:
                pltpu.make_async_copy(sbuf.at[slot], sbuf.at[slot],
                                      recv_s.at[slot]).wait()

        @pl.when((s < n - 1) & first)
        def _send():          # forward the current shard to the right
            @pl.when(s > 0)
            def _credit():    # right neighbour freed the destination slot
                pltpu.semaphore_wait(cap_sem, 1)
            rdma = pltpu.make_async_remote_copy(
                src_ref=buf.at[slot], dst_ref=buf.at[nxt],
                send_sem=send_sem.at[slot], recv_sem=recv_sem.at[nxt],
                device_id=_nbr(ids_ref, n_axes, "right"),
                device_id_type=pltpu.DeviceIdType.MESH)
            rdma.start()
            if quant:
                rdma_s = pltpu.make_async_remote_copy(
                    src_ref=sbuf.at[slot], dst_ref=sbuf.at[nxt],
                    send_sem=send_s.at[slot], recv_sem=recv_s.at[nxt],
                    device_id=_nbr(ids_ref, n_axes, "right"),
                    device_id_type=pltpu.DeviceIdType.MESH)
                rdma_s.start()

        @pl.when(k == 0)
        def _init():
            acc[...] = jnp.zeros_like(acc)

        if quant:
            xt = (buf[slot, bi, pl.ds(i * bm, bm),
                      pl.ds(k * bk, bk)].astype(jnp.float32)
                  * sbuf[slot, bi, pl.ds(i * bm, bm), :]).astype(w_ref.dtype)
        else:
            xt = buf[slot, bi, pl.ds(i * bm, bm), pl.ds(k * bk, bk)]
        acc[...] += jnp.dot(xt, w_ref[...],
                            preferred_element_type=jnp.float32)

        @pl.when(k == kt - 1)
        def _done():
            o_ref[...] = _epilogue(acc[...], None, act).astype(o_ref.dtype)

        @pl.when((s < n - 1) & last)
        def _step_done():     # our outbound read of buf[slot] must be done
            pltpu.make_async_copy(buf.at[slot], buf.at[slot],
                                  send_sem.at[slot]).wait()
            if quant:
                pltpu.make_async_copy(sbuf.at[slot], sbuf.at[slot],
                                      send_s.at[slot]).wait()

        # Credit the upstream neighbour: slot s%2 is free for the write its
        # step-(s+1) send performs.  Only sends at steps 1..n-2 consume a
        # credit, so only steps 0..n-3 issue one (the semaphore drains to 0).
        @pl.when((s < n - 2) & last)
        def _free_slot():
            pltpu.semaphore_signal(
                cap_sem, inc=1, device_id=_nbr(ids_ref, n_axes, "left"),
                device_id_type=pltpu.DeviceIdType.MESH)

    grid = (n, b, mt, nt, kt)
    if quant:
        xq, xs = Q.quant_int8(x)
        in_specs = [
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((bk, bn), lambda s, bi, i, j, k, ids: (k, j)),
        ]
        scratch = [
            pltpu.VMEM((2, b, t, h), jnp.int8),
            pltpu.VMEM((2, b, t, 1), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.REGULAR,
        ]
        operands = (ids, xq, xs, w)
    else:
        in_specs = [
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((bk, bn), lambda s, bi, i, j, k, ids: (k, j)),
        ]
        scratch = [
            pltpu.VMEM((2, b, t, h), x.dtype),
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.REGULAR,
        ]
        operands = (ids, x, w)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                (1, bm, bn),
                lambda s, bi, i, j, k, ids:
                    (bi, ((ids[0] - s) % n) * mt + i, j)),
            scratch_shapes=scratch,
        ),
        out_shape=jax.ShapeDtypeStruct((b, n * t, o), x.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",) * len(grid),
            collective_id=collective_id, has_side_effects=True),
    )(*operands)
    return out


def _matmul_rs_tpu(x, w, *, axis_name: str, scatter_dim: int, n: int,
                   mesh_axes=None, collective_id: int = 1,
                   comm_dtype: str = "bf16"):
    """Single-kernel ring matmul-RS: the per-destination accumulator chunk
    circulates through the VMEM pair.

    Overlap structure: the inbound transfer for step *s* (started by the left
    neighbour at the end of its step *s-1*) flies while step *s*'s
    contribution tiles run on the MXU — the recv wait sits immediately before
    the first fold, not at the step boundary; the outbound send is started
    without an inline wait, its completion (and the capacity credit to the
    upstream neighbour) checked at the first tile of the NEXT step.  x and w
    stay in HBM and stream through double-buffered BlockSpec tiles whose
    index maps follow the per-step destination rank (scalar prefetch).

    ``comm_dtype="int8"``: unlike the AG kernels, the circulating object is
    the *accumulator*, which changes every hop — so the quantized pair must
    be rebuilt per send.  Folds land in a full-width ``work`` staging buffer
    (dequantize the received slot + add this step's fp32 tile); at the send
    point the whole ``work`` buffer is quantized into the (int8, fp32 scale)
    VMEM pair and both halves fly as paired remote DMAs under one capacity
    credit.  Only link traffic quantizes — ``work`` and the fp32 acc tiles
    stay full width."""
    b, t, h = x.shape
    o = w.shape[-1]
    last = scatter_dim == x.ndim - 1
    scattered = o if last else x.shape[scatter_dim]
    chunk = scattered // n
    if last:
        bm, bn, bk = pick_block(t, BLOCK_M), pick_block(chunk, BLOCK_N), \
            pick_block(h, BLOCK_K)
        mt, nt, kt = t // bm, chunk // bn, h // bk
        out_shape = (b, t, chunk)
    else:
        bm, bn, bk = pick_block(chunk, BLOCK_M), pick_block(o, BLOCK_N), \
            pick_block(h, BLOCK_K)
        mt, nt, kt = chunk // bm, o // bn, h // bk
        out_shape = (b, chunk, o)
    ids, n_axes = _ring_ids(axis_name, n, mesh_axes)
    quant = comm_dtype == "int8" and Q.quant_ok(out_shape, x.dtype)

    def _dest(s, ids_ref):                   # (me + n-1-s) % n; s=0 → me-1
        return (ids_ref[0] + n - 1 - s) % n

    if last:       # contribution = x @ w[:, dest·chunk + j·bn]
        x_spec = pl.BlockSpec((1, bm, bk),
                              lambda s, bi, i, j, k, ids: (bi, i, k))
        w_spec = pl.BlockSpec(
            (bk, bn),
            lambda s, bi, i, j, k, ids:
                (k, _dest(s, ids) * (chunk // bn) + j))
    else:          # contribution = x[dest·chunk + i·bm] @ w
        x_spec = pl.BlockSpec(
            (1, bm, bk),
            lambda s, bi, i, j, k, ids:
                (bi, _dest(s, ids) * (chunk // bm) + i, k))
        w_spec = pl.BlockSpec((bk, bn),
                              lambda s, bi, i, j, k, ids: (k, j))

    def kernel(ids_ref, *refs):
        if quant:
            (x_ref, w_ref, o_ref, buf, sbuf, work, acc,
             send_sem, recv_sem, send_s, recv_s, cap_sem) = refs
        else:
            (x_ref, w_ref, o_ref, buf, acc,
             send_sem, recv_sem, cap_sem) = refs
        s, bi = pl.program_id(0), pl.program_id(1)
        i, j, k = pl.program_id(2), pl.program_id(3), pl.program_id(4)
        first = (bi == 0) & (i == 0) & (j == 0) & (k == 0)
        lastt = ((bi == b - 1) & (i == mt - 1) & (j == nt - 1)
                 & (k == kt - 1))
        slot = lax.rem(s, 2)
        prev = lax.rem(s + 1, 2)

        @pl.when((s == 0) & first)
        def _prologue():
            barrier = pltpu.get_barrier_semaphore()
            for which in ("left", "right"):
                pltpu.semaphore_signal(
                    barrier, inc=1, device_id=_nbr(ids_ref, n_axes, which),
                    device_id_type=pltpu.DeviceIdType.MESH)
            pltpu.semaphore_wait(barrier, 2)

        @pl.when((s > 0) & first)
        def _prev_send_done():
            # the step-(s-1) send read buf[prev] to completion; the upstream
            # neighbour may now overwrite our slot (its next send lands here)
            pltpu.make_async_copy(buf.at[prev], buf.at[prev],
                                  send_sem.at[prev]).wait()
            if quant:
                pltpu.make_async_copy(sbuf.at[prev], sbuf.at[prev],
                                      send_s.at[prev]).wait()

        @pl.when((s > 0) & (s < n - 1) & first)
        def _free_slot():      # credits sends at steps 1..n-2 (drains to 0)
            pltpu.semaphore_signal(
                cap_sem, inc=1, device_id=_nbr(ids_ref, n_axes, "left"),
                device_id_type=pltpu.DeviceIdType.MESH)

        @pl.when(k == 0)
        def _init():
            acc[...] = jnp.zeros_like(acc)

        acc[...] += jnp.dot(x_ref[0], w_ref[...],
                            preferred_element_type=jnp.float32)

        # the inbound accumulator is needed only at fold time: waiting here —
        # after this step's first contribution tile has already run — lets
        # the transfer hide behind the MXU work above.
        @pl.when((s > 0) & (k == kt - 1) & (bi == 0) & (i == 0) & (j == 0))
        def _recv_wait():
            pltpu.make_async_copy(buf.at[slot], buf.at[slot],
                                  recv_sem.at[slot]).wait()
            if quant:
                pltpu.make_async_copy(sbuf.at[slot], sbuf.at[slot],
                                      recv_s.at[slot]).wait()

        @pl.when(k == kt - 1)
        def _fold():
            if quant:
                tile = acc[...].astype(work.dtype)
                idxs = (bi, pl.ds(i * bm, bm), pl.ds(j * bn, bn))

                @pl.when(s == 0)
                def _set():
                    work[idxs] = tile

                @pl.when(s > 0)
                def _add():   # dequantize the received tile, fold this step's
                    got = (buf[(slot,) + idxs].astype(jnp.float32)
                           * sbuf[slot, bi, pl.ds(i * bm, bm), :])
                    work[idxs] = got.astype(work.dtype) + tile
            else:
                tile = acc[...].astype(buf.dtype)
                idxs = (slot, bi, pl.ds(i * bm, bm), pl.ds(j * bn, bn))

                @pl.when(s == 0)
                def _set():
                    buf[idxs] = tile

                @pl.when(s > 0)
                def _add():
                    buf[idxs] += tile

        if quant:   # the outbound pair is rebuilt from work at every send
            @pl.when((s < n - 1) & lastt)
            def _requant():
                qv, sv = Q.quant_int8(work[...])
                buf[slot] = qv
                sbuf[slot] = sv

        @pl.when((s < n - 1) & lastt)
        def _send():           # start only — completion checked next step
            @pl.when(s > 0)
            def _credit():     # right neighbour's destination slot is free
                pltpu.semaphore_wait(cap_sem, 1)
            rdma = pltpu.make_async_remote_copy(
                src_ref=buf.at[slot], dst_ref=buf.at[lax.rem(s + 1, 2)],
                send_sem=send_sem.at[slot],
                recv_sem=recv_sem.at[lax.rem(s + 1, 2)],
                device_id=_nbr(ids_ref, n_axes, "right"),
                device_id_type=pltpu.DeviceIdType.MESH)
            rdma.start()
            if quant:
                rdma_s = pltpu.make_async_remote_copy(
                    src_ref=sbuf.at[slot], dst_ref=sbuf.at[lax.rem(s + 1, 2)],
                    send_sem=send_s.at[slot],
                    recv_sem=recv_s.at[lax.rem(s + 1, 2)],
                    device_id=_nbr(ids_ref, n_axes, "right"),
                    device_id_type=pltpu.DeviceIdType.MESH)
                rdma_s.start()

        @pl.when((s == n - 1) & (k == kt - 1))
        def _emit():
            if quant:
                o_ref[...] = work[bi, pl.ds(i * bm, bm),
                                  pl.ds(j * bn, bn)].astype(o_ref.dtype)
            else:
                o_ref[...] = buf[slot, bi, pl.ds(i * bm, bm),
                                 pl.ds(j * bn, bn)].astype(o_ref.dtype)

    if quant:
        scratch = [
            pltpu.VMEM((2,) + out_shape, jnp.int8),
            pltpu.VMEM((2,) + out_shape[:-1] + (1,), jnp.float32),
            pltpu.VMEM(out_shape, x.dtype),
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.REGULAR,
        ]
    else:
        scratch = [
            pltpu.VMEM((2,) + out_shape, x.dtype),
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.REGULAR,
        ]
    grid = (n, b, mt, nt, kt)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[x_spec, w_spec],
            out_specs=pl.BlockSpec(
                (1, bm, bn), lambda s, bi, i, j, k, ids: (bi, i, j)),
            scratch_shapes=scratch,
        ),
        out_shape=jax.ShapeDtypeStruct(out_shape, x.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",) * len(grid),
            collective_id=collective_id, has_side_effects=True),
    )(ids, x, w)


def _ag_matmul_contract_tpu(x, w, *, axis_name: str, n: int, out_dtype=None,
                            mesh_axes=None, collective_id: int = 2,
                            comm_dtype: str = "bf16"):
    """Single-kernel contracted-dim ring: x shards circulate while an fp32
    accumulator spanning ring steps lives in VMEM; w row-blocks are indexed by
    the shard's source rank, epilogue/cast on the very last step.

    ``comm_dtype="int8"``: like the AG kernel, the payload is ring-invariant
    — quantized once outside the kernel, the (int8, fp32 scale) pair
    circulates through paired remote DMAs and every tile dequantizes its
    slice at the dot; the fp32 accumulator never quantizes."""
    b, t, h = x.shape
    o = w.shape[-1]
    m = b * t
    dt = out_dtype or x.dtype
    bm, bn, bk = pick_block(m, BLOCK_M), pick_block(o, BLOCK_N), \
        pick_block(h, BLOCK_K)
    mt, nt, kt = m // bm, o // bn, h // bk
    ids, n_axes = _ring_ids(axis_name, n, mesh_axes)
    quant = comm_dtype == "int8" and Q.quant_ok(x.shape, x.dtype)

    def kernel(ids_ref, *refs):
        if quant:
            (xq_hbm, xs_hbm, w_ref, o_ref, buf, sbuf, acc, copy_sem,
             send_sem, recv_sem, send_s, recv_s, cap_sem) = refs
        else:
            (x_hbm, w_ref, o_ref, buf, acc, copy_sem,
             send_sem, recv_sem, cap_sem) = refs
        s = pl.program_id(0)
        i, j, k = pl.program_id(1), pl.program_id(2), pl.program_id(3)
        first = (i == 0) & (j == 0) & (k == 0)
        lastt = (i == mt - 1) & (j == nt - 1) & (k == kt - 1)
        slot = lax.rem(s, 2)
        nxt = lax.rem(s + 1, 2)

        @pl.when((s == 0) & first)
        def _prologue():
            barrier = pltpu.get_barrier_semaphore()
            for which in ("left", "right"):
                pltpu.semaphore_signal(
                    barrier, inc=1, device_id=_nbr(ids_ref, n_axes, which),
                    device_id_type=pltpu.DeviceIdType.MESH)
            pltpu.semaphore_wait(barrier, 2)
            if quant:
                cp = pltpu.make_async_copy(xq_hbm, buf.at[0], copy_sem)
                cp.start()
                cp.wait()
                cp = pltpu.make_async_copy(xs_hbm, sbuf.at[0], copy_sem)
            else:
                cp = pltpu.make_async_copy(x_hbm, buf.at[0], copy_sem)
            cp.start()
            cp.wait()
            acc[...] = jnp.zeros_like(acc)

        @pl.when((s > 0) & first)
        def _recv_wait():
            pltpu.make_async_copy(buf.at[slot], buf.at[slot],
                                  recv_sem.at[slot]).wait()
            if quant:
                pltpu.make_async_copy(sbuf.at[slot], sbuf.at[slot],
                                      recv_s.at[slot]).wait()

        @pl.when((s < n - 1) & first)
        def _send():
            @pl.when(s > 0)
            def _credit():
                pltpu.semaphore_wait(cap_sem, 1)
            rdma = pltpu.make_async_remote_copy(
                src_ref=buf.at[slot], dst_ref=buf.at[nxt],
                send_sem=send_sem.at[slot],
                recv_sem=recv_sem.at[nxt],
                device_id=_nbr(ids_ref, n_axes, "right"),
                device_id_type=pltpu.DeviceIdType.MESH)
            rdma.start()
            if quant:
                rdma_s = pltpu.make_async_remote_copy(
                    src_ref=sbuf.at[slot], dst_ref=sbuf.at[nxt],
                    send_sem=send_s.at[slot], recv_sem=recv_s.at[nxt],
                    device_id=_nbr(ids_ref, n_axes, "right"),
                    device_id_type=pltpu.DeviceIdType.MESH)
                rdma_s.start()

        if quant:
            xt = (buf[slot].reshape(m, h)[pl.ds(i * bm, bm),
                                          pl.ds(k * bk, bk)]
                  .astype(jnp.float32)
                  * sbuf[slot].reshape(m, 1)[pl.ds(i * bm, bm), :]
                  ).astype(w_ref.dtype)
        else:
            xt = buf[slot].reshape(m, h)[pl.ds(i * bm, bm),
                                         pl.ds(k * bk, bk)]
        acc[pl.ds(i * bm, bm), pl.ds(j * bn, bn)] += jnp.dot(
            xt, w_ref[...], preferred_element_type=jnp.float32)

        @pl.when((s == n - 1) & (k == kt - 1))
        def _emit():
            o_ref[...] = acc[pl.ds(i * bm, bm),
                             pl.ds(j * bn, bn)].astype(o_ref.dtype)

        @pl.when((s < n - 1) & lastt)
        def _step_done():     # our outbound read of buf[slot] must be done
            pltpu.make_async_copy(buf.at[slot], buf.at[slot],
                                  send_sem.at[slot]).wait()
            if quant:
                pltpu.make_async_copy(sbuf.at[slot], sbuf.at[slot],
                                      send_s.at[slot]).wait()

        # Only sends at steps 1..n-2 consume a credit, so only steps 0..n-3
        # issue one — the capacity semaphore drains to zero at kernel end.
        @pl.when((s < n - 2) & lastt)
        def _free_slot():
            pltpu.semaphore_signal(
                cap_sem, inc=1, device_id=_nbr(ids_ref, n_axes, "left"),
                device_id_type=pltpu.DeviceIdType.MESH)

    if quant:
        xq, xs = Q.quant_int8(x)
        in_specs = [
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            # w row-block follows the circulating shard's source rank
            pl.BlockSpec((h // kt, o // nt),
                         lambda s, i, j, k, ids:
                             (((ids[0] - s) % n) * kt + k, j)),
        ]
        scratch = [
            pltpu.VMEM((2, b, t, h), jnp.int8),
            pltpu.VMEM((2, b, t, 1), jnp.float32),
            pltpu.VMEM((m, o), jnp.float32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.REGULAR,
        ]
        operands = (ids, xq, xs, w)
    else:
        in_specs = [
            pl.BlockSpec(memory_space=pltpu.ANY),
            # w row-block follows the circulating shard's source rank
            pl.BlockSpec((h // kt, o // nt),
                         lambda s, i, j, k, ids:
                             (((ids[0] - s) % n) * kt + k, j)),
        ]
        scratch = [
            pltpu.VMEM((2, b, t, h), x.dtype),
            pltpu.VMEM((m, o), jnp.float32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.REGULAR,
        ]
        operands = (ids, x, w)
    grid = (n, mt, nt, kt)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                (m // mt, o // nt), lambda s, i, j, k, ids: (i, j)),
            scratch_shapes=scratch,
        ),
        out_shape=jax.ShapeDtypeStruct((m, o), dt),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",) * len(grid),
            collective_id=collective_id, has_side_effects=True),
    )(*operands)
    return out.reshape(b, t, o)


def _matmul_rs_pair_tpu(x, w1, w1b, *, axis_name: str, scatter_dim: int,
                        n: int, mesh_axes=None, collective_id: int = 3,
                        comm_dtype: str = "bf16"):
    """Gated single-kernel ring matmul-RS: the column-concatenated weights run
    through one `_matmul_rs_tpu`-shaped loop, so every x tile is read once for
    both products (shared-x-tile trick); the halves are split on emit."""
    wc = jnp.concatenate([w1, w1b], axis=1)
    y = _matmul_rs_tpu(x, wc, axis_name=axis_name, scatter_dim=scatter_dim,
                       n=n, mesh_axes=mesh_axes, collective_id=collective_id,
                       comm_dtype=comm_dtype)
    o1 = w1.shape[-1]
    return y[..., :o1], y[..., o1:]
