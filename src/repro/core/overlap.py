"""Ring-decomposed collective matmuls — overlap NoP communication with compute.

Hecaton's headline claim (paper §III-B(3), §IV) is that its schedule hides NoP
communication behind on-die compute, keeping the computation-to-communication
ratio constant under weak scaling.  The bulk-synchronous ops in
``core/hecaton.py`` (``lax.all_gather`` → full matmul → ``lax.psum_scatter``)
leave the links idle during the matmul and the MXU idle during the collectives.
This module provides the standard remedy — decomposed collective matmuls over
``lax.ppermute`` rings — selected by ``ParallelConfig.overlap``:

  * ``"none"``   — the bulk path (callers keep using lax.all_gather/psum_scatter).
  * ``"ring"``   — unidirectional ring: at step *k* each device matmuls the
                   shard it holds while the ``ppermute`` for step *k+1* is in
                   flight, so a latency-hiding scheduler (TPU/GPU async
                   collectives) fully overlaps the chain.
  * ``"bidir"``  — bidirectional ring: every shard is split in half and the two
                   halves circulate in opposite directions, halving per-step
                   bytes per link on full-duplex (torus) links.
  * ``"fused"``  — the whole ring inside ONE Pallas kernel
                   (kernels/ring_matmul.py): a double-buffered VMEM pair
                   receives the next peer's shard via remote DMA while the MXU
                   consumes the current shard through the tile loop — overlap
                   guaranteed by construction, no per-step dispatch gap.  On
                   backends without remote-DMA support the kernels emulate
                   each hop with ``lax.ppermute`` (compat.ring_step_permute)
                   and run the tile loops in interpret mode.

The mode lattice degrades left: ``fused`` falls back to ``ring`` per
collective when a shape is not tile-aligned (:func:`fused_ok_*` in
kernels/ring_matmul.py), exactly as ``bidir`` degrades to ``ring`` when a
shard cannot be halved; every mode falls back to the bulk collective for
extents a ring cannot chunk (``rs_ok``).  Numerics are identical across the
lattice (fp32-accumulation tolerance).

Primitives (all called *inside* shard_map, on per-device blocks):

  ``ring_all_gather``        AG as a ppermute chain (no fused compute).
  ``ring_reduce_scatter``    RS as a circulating-accumulator ppermute chain.
  ``ring_ag_matmul``         AG ⊕ matmul: circulate input shards, matmul each
                             on arrival into its slot of the output (the
                             gather dim is *not* contracted).
  ``ring_ag_matmul_contract``AG ⊕ matmul over the *contracted* dim: per-step
                             partial products accumulate in fp32 (one partial
                             per peer shard — same accumulation the MXU does).
  ``ring_matmul_rs``         matmul ⊕ RS: per-destination output tiles are
                             computed one ring step ahead of the accumulator
                             they are folded into.
  ``ring_linear``            RS(matmul(AG(x))) with the matmul fused into
                             whichever side moves more bytes.

Backward/transpose story: every loop is unrolled Python over linear primitives
(``ppermute``, ``dynamic_(update_)slice``, ``dot``), so JAX's transpose rules
yield the overlapped backward for free: the transpose of a ``ppermute`` ring is
the reversed ring, ``dynamic_update_slice`` transposes to ``dynamic_slice``,
and therefore transpose(ring-AG-matmul) *is* a ring-matmul-RS (and vice versa).
Under ``comm_dtype="bf16"`` no custom VJP is needed and grads flow as
collective-permute chains too.  Under ``comm_dtype="int8"`` each hop is
``core/quant.q_hop`` — a custom-VJP hop whose forward permutes the (int8
payload, fp32 scale) pair and whose backward runs the same quantized hop over
the inverse permutation, so cotangent shards cross the links quantized exactly
like activations do (docs/DESIGN.md §11).

Communication dtype (``ParallelConfig.comm_dtype``): every ``ppermute`` in
this module goes through ``core/quant.ring_hop``.  ``"bf16"`` (default) is
bit-identical to a bare ``lax.ppermute`` of the operand; ``"int8"`` quantizes
the shard being sent with per-row symmetric scales and dequantizes into the
existing fp32 accumulation on receipt, cutting per-hop bytes ~2x (bf16
compute) to ~4x (fp32).  Hops whose shard cannot carry scales — integer ids,
trailing extents below ``quant.MIN_QUANT_DIM`` — degrade per hop to the
full-width permute, mirroring the fused→ring→bulk mode lattice.

Shape constraints: ``bidir`` degrades to ``ring`` per collective when a shard
cannot be halved (checked inside each primitive — numerics are identical), and
a degenerate ring (axis size 1) short-circuits to the local op.  A ring
reduce-scatter needs the scattered extent to divide by the ring size — the
same divisibility the bulk ``psum_scatter(tiled=True)`` already enforces, so
the overlapped path never accepts less than the bulk path (``ring_linear``
routes the non-dividing case to the bulk collective, whose error message names
the offending shape).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core import quant as Q
from repro.kernels import ring_matmul as RM

MODES = ("none", "ring", "bidir", "fused")
COMM_DTYPES = Q.COMM_DTYPES
check_comm_dtype = Q.check_comm_dtype
_hop = Q.ring_hop


def _mm_f32(x, w):
    """bf16 matmul with fp32 accumulation (MXU semantics), fp32 result."""
    return jnp.einsum("bth,ho->bto", x, w, preferred_element_type=jnp.float32)


def _mm(x, w):
    return _mm_f32(x, w).astype(x.dtype)


def _shift_perm(n: int, shift: int):
    return [(i, (i + shift) % n) for i in range(n)]


def _put(buf, part, dim: int, start):
    starts = [0] * buf.ndim
    starts[dim] = start
    return lax.dynamic_update_slice(buf, part, tuple(starts))


def _take(x, dim: int, start, size: int):
    starts = [0] * x.ndim
    starts[dim] = start
    sizes = list(x.shape)
    sizes[dim] = size
    return lax.dynamic_slice(x, tuple(starts), tuple(sizes))


def check_mode(overlap: str) -> str:
    """Validate an overlap mode string (a typo must not silently mean ring)."""
    if overlap not in MODES:
        raise ValueError(f"overlap={overlap!r} not in {MODES}")
    return overlap


def rs_ok(extent: int, n: int) -> bool:
    """Can a ring reduce-scatter over an ``n``-ring chunk ``extent``?

    False routes the caller to the bulk collective: for ``n == 1`` that is the
    trivial no-op, and for a non-dividing extent the bulk ``psum_scatter``
    raises the same shape error the bulk path always has."""
    return n > 1 and extent % n == 0


# ---------------------------------------------------------------------------
# Pure ring collectives (ppermute chains, no fused compute)
# ---------------------------------------------------------------------------


def ring_all_gather(x, axis_name: str, *, dim: int, n: int,
                    bidir: bool = False, comm_dtype: str = "bf16"):
    """== lax.all_gather(x, axis_name, axis=dim, tiled=True), rank order."""
    if n <= 1:
        return x
    idx = lax.axis_index(axis_name)
    chunk = x.shape[dim]
    shape = list(x.shape)
    shape[dim] = chunk * n
    out = jnp.zeros(tuple(shape), x.dtype)
    if bidir and chunk % 2 == 0:
        half = chunk // 2
        curf = _take(x, dim, 0, half)
        curb = _take(x, dim, half, half)
        for s in range(n):
            out = _put(out, curf, dim, ((idx - s) % n) * chunk)
            out = _put(out, curb, dim, ((idx + s) % n) * chunk + half)
            if s < n - 1:
                curf = _hop(curf, axis_name, n, 1, comm_dtype)
                curb = _hop(curb, axis_name, n, -1, comm_dtype)
        return out
    cur = x
    for s in range(n):
        out = _put(out, cur, dim, ((idx - s) % n) * chunk)
        if s < n - 1:
            cur = _hop(cur, axis_name, n, 1, comm_dtype)
    return out


def ring_reduce_scatter(y, axis_name: str, *, dim: int, n: int,
                        bidir: bool = False, comm_dtype: str = "bf16"):
    """== lax.psum_scatter(y, axis_name, scatter_dimension=dim, tiled=True).

    A per-destination accumulator circulates the ring; each device folds in its
    local contribution as the accumulator passes through.  Destination of the
    accumulator held at device *i* after *s* hops: ``(i + n-1 - s) % n`` — at
    the final step every device holds its own fully reduced chunk.
    """
    if n <= 1:
        return y
    assert y.shape[dim] % n == 0, (
        f"ring RS: extent {y.shape[dim]} does not chunk by ring size {n}")
    idx = lax.axis_index(axis_name)
    chunk = y.shape[dim] // n
    if bidir and chunk % 2 == 0:
        half = chunk // 2

        def takef(d):
            return _take(y, dim, d * chunk, half)

        def takeb(d):
            return _take(y, dim, d * chunk + half, half)

        accf = takef((idx - 1) % n)
        accb = takeb((idx + 1) % n)
        for s in range(1, n):
            accf = _hop(accf, axis_name, n, 1, comm_dtype)
            accb = _hop(accb, axis_name, n, -1, comm_dtype)
            accf = accf + takef((idx + n - 1 - s) % n)
            accb = accb + takeb((idx - (n - 1) + s) % n)
        return jnp.concatenate([accf, accb], axis=dim)
    acc = _take(y, dim, ((idx - 1) % n) * chunk, chunk)
    for s in range(1, n):
        acc = _hop(acc, axis_name, n, 1, comm_dtype)
        acc = acc + _take(y, dim, ((idx + n - 1 - s) % n) * chunk, chunk)
    return acc


# ---------------------------------------------------------------------------
# Fused collective matmuls
# ---------------------------------------------------------------------------


def ring_ag_matmul(x, w, axis_name: str, *, dim: int, n: int,
                   bidir: bool = False, comm_dtype: str = "bf16"):
    """== _mm(ring_all_gather(x, dim), w) with per-step partial matmuls.

    The gather dim is a *batch* dim of the matmul (tokens), so each arriving
    shard is matmul'd independently into its slot of the output — step *k*'s
    matmul hides step *k+1*'s permute.
    """
    if n <= 1:
        return _mm(x, w)
    idx = lax.axis_index(axis_name)
    chunk = x.shape[dim]
    shape = list(x.shape)
    shape[dim] = chunk * n
    shape[-1] = w.shape[-1]
    out = jnp.zeros(tuple(shape), x.dtype)
    if bidir and chunk % 2 == 0:
        half = chunk // 2
        curf = _take(x, dim, 0, half)
        curb = _take(x, dim, half, half)
        for s in range(n):
            out = _put(out, _mm(curf, w), dim, ((idx - s) % n) * chunk)
            out = _put(out, _mm(curb, w), dim, ((idx + s) % n) * chunk + half)
            if s < n - 1:
                curf = _hop(curf, axis_name, n, 1, comm_dtype)
                curb = _hop(curb, axis_name, n, -1, comm_dtype)
        return out
    cur = x
    for s in range(n):
        out = _put(out, _mm(cur, w), dim, ((idx - s) % n) * chunk)
        if s < n - 1:
            cur = _hop(cur, axis_name, n, 1, comm_dtype)
    return out


def ring_ag_matmul_contract(x, w, axis_name: str, *, n: int,
                            bidir: bool = False, out_dtype=None,
                            comm_dtype: str = "bf16"):
    """== mm(ring_all_gather(x, dim=-1), w) where the gathered dim is the
    matmul's *contraction* dim: w's rows are chunked to match and the per-step
    partial products accumulate in fp32 (the same accumulation a single big
    matmul performs internally, so numerics track the bulk path)."""
    dt = out_dtype or x.dtype
    if n <= 1:
        return _mm_f32(x, w).astype(dt)
    idx = lax.axis_index(axis_name)
    h_loc = x.shape[-1]
    acc = jnp.zeros(x.shape[:-1] + (w.shape[-1],), jnp.float32)
    if bidir and h_loc % 2 == 0:
        half = h_loc // 2
        curf = _take(x, x.ndim - 1, 0, half)
        curb = _take(x, x.ndim - 1, half, half)
        for s in range(n):
            rf = ((idx - s) % n) * h_loc
            rb = ((idx + s) % n) * h_loc + half
            acc = acc + _mm_f32(curf, _take(w, 0, rf, half))
            acc = acc + _mm_f32(curb, _take(w, 0, rb, half))
            if s < n - 1:
                curf = _hop(curf, axis_name, n, 1, comm_dtype)
                curb = _hop(curb, axis_name, n, -1, comm_dtype)
        return acc.astype(dt)
    cur = x
    for s in range(n):
        acc = acc + _mm_f32(cur, _take(w, 0, ((idx - s) % n) * h_loc, h_loc))
        if s < n - 1:
            cur = _hop(cur, axis_name, n, 1, comm_dtype)
    return acc.astype(dt)


def ring_matmul_rs(x, w, axis_name: str, *, scatter_dim: int, n: int,
                   bidir: bool = False, comm_dtype: str = "bf16"):
    """== lax.psum_scatter(_mm(x, w), scatter_dimension=scatter_dim, tiled).

    The per-destination tile is produced by a *chunked* matmul right before it
    is folded into the circulating accumulator: rows of x are chunked when the
    scatter dim is the token dim (1), columns of w when it is the output
    feature dim (2) — either way each ring step has a matmul to hide its
    permute behind.
    """
    if n <= 1:
        return _mm(x, w)
    idx = lax.axis_index(axis_name)
    scattered = w.shape[-1] if scatter_dim == x.ndim - 1 else \
        x.shape[scatter_dim]
    assert scattered % n == 0, (
        f"ring matmul-RS: extent {scattered} does not chunk by ring size {n}")
    if scatter_dim == x.ndim - 1:          # chunk w's output columns
        chunk = w.shape[-1] // n

        def contrib(d, off=0, size=None):
            return _mm(x, _take(w, 1, d * chunk + off, size or chunk))
    else:                                   # chunk x's rows along scatter_dim
        chunk = x.shape[scatter_dim] // n

        def contrib(d, off=0, size=None):
            return _mm(_take(x, scatter_dim, d * chunk + off, size or chunk),
                       w)

    if bidir and chunk % 2 == 0:
        half = chunk // 2
        accf = contrib((idx - 1) % n, 0, half)
        accb = contrib((idx + 1) % n, half, half)
        for s in range(1, n):
            accf = _hop(accf, axis_name, n, 1, comm_dtype)
            accb = _hop(accb, axis_name, n, -1, comm_dtype)
            accf = accf + contrib((idx + n - 1 - s) % n, 0, half)
            accb = accb + contrib((idx - (n - 1) + s) % n, half, half)
        return jnp.concatenate([accf, accb], axis=scatter_dim)
    acc = contrib((idx - 1) % n)
    for s in range(1, n):
        acc = _hop(acc, axis_name, n, 1, comm_dtype)
        acc = acc + contrib((idx + n - 1 - s) % n)
    return acc


# ---------------------------------------------------------------------------
# Mode dispatchers: route one collective matmul to the single-kernel fused
# path (kernels/ring_matmul.py) when overlap="fused" and the shape is
# tile-aligned, else to the ppermute ring above.  These are the only places
# the fused/ring/bidir decision is made, so every hecaton primitive (and the
# MoE / megatron ring paths) inherits the same degradation contract.
# ---------------------------------------------------------------------------


def ag_matmul(x, w, axis_name: str, *, dim: int, n: int, overlap: str,
              mesh_axes=None, comm_dtype: str = "bf16"):
    """AG ⊕ matmul (gathered dim is a batch dim) under the given mode.

    ``mesh_axes`` (the enclosing mesh's full axis-name tuple) lets the TPU
    single-kernel path address ring neighbours by mesh coordinates; without
    it the fused mode still runs, via its ppermute-emulated path."""
    if overlap == "fused" and RM.fused_ok_ag(x.shape, w.shape, n, dim,
                                             x.dtype.itemsize):
        return RM.ag_matmul(x, w, axis_name, dim=dim, n=n,
                            mesh_axes=mesh_axes, comm_dtype=comm_dtype)
    return ring_ag_matmul(x, w, axis_name, dim=dim, n=n,
                          bidir=overlap == "bidir", comm_dtype=comm_dtype)


def matmul_rs(x, w, axis_name: str, *, scatter_dim: int, n: int,
              overlap: str, mesh_axes=None, comm_dtype: str = "bf16"):
    """matmul ⊕ RS under the given mode."""
    if overlap == "fused" and RM.fused_ok_rs(x.shape, w.shape, n,
                                             scatter_dim, x.dtype.itemsize):
        return RM.matmul_rs(x, w, axis_name, scatter_dim=scatter_dim, n=n,
                            mesh_axes=mesh_axes, comm_dtype=comm_dtype)
    return ring_matmul_rs(x, w, axis_name, scatter_dim=scatter_dim, n=n,
                          bidir=overlap == "bidir", comm_dtype=comm_dtype)


def ag_matmul_contract(x, w, axis_name: str, *, n: int, overlap: str,
                       out_dtype=None, mesh_axes=None,
                       comm_dtype: str = "bf16"):
    """AG ⊕ matmul over the contracted dim under the given mode."""
    if overlap == "fused" and RM.fused_ok_contract(x.shape, w.shape, n,
                                                   x.dtype.itemsize):
        return RM.ag_matmul_contract(x, w, axis_name, n=n,
                                     out_dtype=out_dtype,
                                     mesh_axes=mesh_axes,
                                     comm_dtype=comm_dtype)
    return ring_ag_matmul_contract(x, w, axis_name, n=n,
                                   bidir=overlap == "bidir",
                                   out_dtype=out_dtype,
                                   comm_dtype=comm_dtype)


def matmul_rs_pair(x, w1, w1b, axis_name: str, *, scatter_dim: int, n: int,
                   overlap: str, mesh_axes=None, comm_dtype: str = "bf16"):
    """Gated pair: (x·w1, x·w1b) reduce-scattered, sharing the gathered x.

    Fused mode reads each x tile once for both products inside one kernel;
    the ring/bidir path runs two matmul-RS rings over the shared gather."""
    if (overlap == "fused" and scatter_dim != x.ndim - 1
            and RM.fused_ok_rs(x.shape, w1.shape, n, scatter_dim,
                               x.dtype.itemsize)
            and RM.fused_ok_rs(x.shape, w1b.shape, n, scatter_dim,
                               x.dtype.itemsize)):
        return RM.matmul_rs_pair(x, w1, w1b, axis_name,
                                 scatter_dim=scatter_dim, n=n,
                                 mesh_axes=mesh_axes, comm_dtype=comm_dtype)
    bidir = overlap == "bidir"
    return (ring_matmul_rs(x, w1, axis_name, scatter_dim=scatter_dim, n=n,
                           bidir=bidir, comm_dtype=comm_dtype),
            ring_matmul_rs(x, w1b, axis_name, scatter_dim=scatter_dim, n=n,
                           bidir=bidir, comm_dtype=comm_dtype))


# ---------------------------------------------------------------------------
# Composed linear: RS(matmul(AG(x))) with the matmul fused into the heavier side
# ---------------------------------------------------------------------------


def fuse_side(h_loc: int, o_loc: int) -> str:
    """Which collective the single matmul should fuse into.

    The AG moves the input (∝ h_loc per token), the RS moves the output
    (∝ o_loc per token); fusing the heavier side hides more bytes.  Ties go to
    the AG (circulating the smaller operand keeps ring messages small)."""
    return "rs" if o_loc > h_loc else "ag"


def ring_linear(x, w, *, g_ax: str, n_g: int, s_ax: str, n_s: int,
                gather_dim: int = 1, scatter_dim: int = 1, overlap: str,
                mesh_axes=None, comm_dtype: str = "bf16"):
    """Overlapped y = RS_{s_ax}( AG_{g_ax}(x, gather_dim) @ w, scatter_dim).

    One of the two collectives gets the matmul fused into its ring loop
    (``fuse_side``); the other runs as a pure ppermute ring — every NoP
    transfer in the chain is a collective-permute.  Under ``overlap="fused"``
    the matmul-carrying side runs as one Pallas ring kernel when tile-aligned
    (kernels/ring_matmul.py), degrading per collective to the ppermute ring
    otherwise.  A scattered extent the ring cannot chunk goes to the bulk
    ``psum_scatter`` instead (a no-op for a size-1 axis; for a genuinely
    non-dividing extent it raises the same shape error the bulk path always
    has) — the gather side stays overlapped.
    """
    check_mode(overlap)
    bidir = overlap == "bidir"
    scattered = (x.shape[gather_dim] * n_g if scatter_dim == gather_dim
                 else w.shape[-1])
    if fuse_side(x.shape[-1], w.shape[-1]) == "rs" and rs_ok(scattered, n_s):
        xg = ring_all_gather(x, g_ax, dim=gather_dim, n=n_g, bidir=bidir,
                             comm_dtype=comm_dtype)
        return matmul_rs(xg, w, s_ax, scatter_dim=scatter_dim, n=n_s,
                         overlap=overlap, mesh_axes=mesh_axes,
                         comm_dtype=comm_dtype)
    yp = ag_matmul(x, w, g_ax, dim=gather_dim, n=n_g, overlap=overlap,
                   mesh_axes=mesh_axes, comm_dtype=comm_dtype)
    if not rs_ok(scattered, n_s):           # cannot chunk: bulk reduce-scatter
        return lax.psum_scatter(yp, s_ax, scatter_dimension=scatter_dim,
                                tiled=True)
    return ring_reduce_scatter(yp, s_ax, dim=scatter_dim, n=n_s, bidir=bidir,
                               comm_dtype=comm_dtype)
