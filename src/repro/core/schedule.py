"""Hecaton scheduling (paper §III-B) mapped to TPU/XLA idioms.

The paper's three scheduling levers and their TPU equivalents:

1. **Mini-batch decomposition** — a batch is split into mini-batches as minimal
   execution units so fixed hardware trains arbitrary batch sizes.  Here: microbatch
   gradient accumulation via ``lax.scan`` (train/step.py); the microbatch count is
   chosen so the live activation set fits the per-chip memory target, exactly the
   paper's "larger activation buffer => more samples per mini-batch".

2. **Layer fusion** — consecutive layers consume activations where they are produced,
   never round-tripping DRAM.  Here: (a) the hecaton seq-scatter chain already fuses
   linear pairs with zero comm (core/hecaton.ffn_block); (b) the remat policy below
   recomputes the *gathers* in backward instead of saving gathered activations —
   the paper's Step-6/7 re-gather which keeps SRAM (HBM) footprint at the sharded
   size; (c) fused Pallas kernels (kernels/matmul.py) keep bias+activation in VMEM.

3. **On/off-package overlap** — DRAM streaming overlaps on-package execution.  Here:
   the data pipeline prefetches host->device asynchronously (data/synthetic.py) and
   collectives are issued back-to-back with the consuming matmul so XLA's latency
   hiding scheduler overlaps them (flags in launch/train.py).

``remat_policy`` returns a jax.checkpoint policy implementing (2b).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
from jax.ad_checkpoint import checkpoint_policies as cp


def remat_policy(name: str):
    """Named remat policies.

    * ``none``   — save everything (fastest recompute, highest memory).
    * ``fusion`` — paper-faithful: save only matmul outputs that are *sharded*
                   (checkpoint_dots_with_no_batch_dims saves weight-stationary dots);
                   gathers/elementwise are recomputed in backward — Alg. 1 Step 6-7.
    * ``full``   — save only block boundaries (max recompute, min memory).
    """
    if name == "none":
        return None
    if name == "fusion":
        return cp.dots_with_no_batch_dims_saveable
    if name == "full":
        return cp.nothing_saveable
    raise KeyError(f"unknown remat policy {name!r}")


def apply_remat(fn, policy_name: str):
    pol = remat_policy(policy_name)
    if policy_name == "none":
        return fn
    return jax.checkpoint(fn, policy=pol)


# ---------------------------------------------------------------------------
# Mini-batch (microbatch) sizing — paper §III-B(a)
# ---------------------------------------------------------------------------

# live bytes per token per layer (f32-saved dot outputs) under each remat policy,
# as a multiple of d_model elements
_REMAT_FACTOR = {"none": 24.0, "fusion": 10.0, "full": 2.5}


def min_microbatches_for_bubble(n_stages: int, max_bubble: float) -> int:
    """Smallest 1F1B microbatch count with bubble fraction <= ``max_bubble``.

    The non-interleaved 1F1B bubble fraction is ``(p-1)/(m+p-1)``
    (core/theory.pipeline_bubble_fraction, verified against the simulated
    schedule in parallel/pipeline.py): solving for ``m`` gives
    ``m >= (p-1)*(1-f)/f``.
    """
    if n_stages <= 1:
        return 1
    assert 0.0 < max_bubble < 1.0, max_bubble
    return max(1, math.ceil((n_stages - 1) * (1.0 - max_bubble) / max_bubble))


def choose_microbatches(global_batch: int, seq_len: int, d_model: int,
                        n_data_shards: int, n_token_shards: int,
                        *, num_layers: int = 32, vocab: int = 32_000,
                        act_budget_bytes: float = 2e9,
                        bytes_per_elt: int = 2,
                        n_stages: int = 1, max_bubble: float = 0.25):
    """Pick (microbatch count, remat policy) so live activations fit the budget.

    Live set per token ≈ L * d_model * remat_factor (saved residual stack across
    the layer scan)  +  3 * vocab (logits + one-hot + exp in the loss), all
    divided by the model shards.  Mirrors the paper's §III-B rule: the
    mini-batch is whatever the activation buffer holds; deeper recompute
    (= deeper layer fusion) trades compute for buffer space.

    With ``n_stages > 1`` (inter-pod 1F1B pipeline, parallel/pipeline.py)
    the choice is additionally *bubble-aware*: the count is raised until the
    schedule's bubble fraction ``(p-1)/(m+p-1)`` drops to ``max_bubble`` —
    more microbatches cost nothing under 1F1B (per-stage live activations
    stay bounded by ``min(p-s, m)``) while directly shrinking the bubble.
    Returns (n_micro, remat_name).
    """
    per_shard_batch = max(1, global_batch // n_data_shards)
    floor = min(min_microbatches_for_bubble(n_stages, max_bubble),
                per_shard_batch)

    def divisible(n_micro: int) -> int:
        while per_shard_batch % n_micro:
            n_micro += 1
        return min(n_micro, per_shard_batch)

    def per_token(remat):
        layer_term = num_layers * d_model * _REMAT_FACTOR[remat]
        loss_term = 3.0 * vocab
        return (layer_term + loss_term) * bytes_per_elt * 2 / n_token_shards

    for remat in ("fusion", "full"):
        tokens_budget = act_budget_bytes / per_token(remat)
        mb_samples = int(tokens_budget // seq_len)
        if mb_samples >= 1:
            n_micro = max(1, math.ceil(per_shard_batch / mb_samples), floor)
            return divisible(n_micro), remat
    return per_shard_batch, "full"      # 1-sample microbatches, max recompute
