"""Hecaton's distributed training method (paper §IV, Algorithm 1) as JAX ops.

The paper tiles every weight matrix over a 2D die grid (mx × my) and replaces the
global all-reduce of 1D tensor parallelism with two *local* collectives over √N-size
groups — an all-gather of the input along one grid axis and a reduce-scatter of the
output along the other.  Both collectives run at full ring bandwidth on a torus
(TPU ICI is a torus; the paper builds one from bypass links).

Two dataflow patterns from the paper:

* ``linear_seq_scatter``  (§IV-B, FFN blocks / fused linear chains)
    in : x  [B, T/t_ax, H/h_ax]   (tokens sharded over ``t_ax``, hidden over ``h_ax``)
         w  [H/h_ax, O/t_ax]      (paper's transposed tile placement W[j,i] on die (i,j))
    out: y  [B, T/h_ax, O/t_ax]   — tiling is the *transpose* of the input tiling, so
                                    the next (fused) layer runs with swapped axis roles
                                    and needs no extra communication (paper §IV-B).

* ``mixer_in`` / ``mixer_out``  (§IV-C, attention & other token mixers)
    ``mixer_in``  all-gathers the *sequence* (so every die sees all tokens) and
    reduce-scatters the output along *hidden* — each die ends up with a head-slice of
    Q/K/V over the full sequence, exploiting head parallelism with zero comm inside
    the attention itself.  ``mixer_out`` is the inverse: gather hidden, project, and
    reduce-scatter tokens back to the canonical tiling.

Backward faithfulness: we differentiate *through* ``shard_map``.  JAX's transpose
rules give exactly Algorithm 1's backward —
    transpose(all_gather)   = reduce-scatter (paper Step 4 of bwd)
    transpose(psum_scatter) = all-gather     (paper Step 3 of bwd: gather dY once,
                                              reuse for both dX and dW)
and the re-gather of X for dW (paper Steps 6-7, the SRAM-capacity trick) is obtained
by wrapping blocks in a remat policy that saves only the *sharded* activations and
recomputes gathers (core/schedule.py).

All functions are no-ops (plain einsums) when ``mesh is None`` so the same model code
runs single-device smoke tests.

Residual layout: the canonical inter-block activation contract
(``ParallelConfig.residual == "seq"``) is the SEQ-SHARDED residual stream —
for these ops that is simply Alg. 1's native tiling P(data, t_ax, h_ax):
every primitive here already accepts token-scattered inputs without an
up-front gather, which is why no block boundary carries a bulk collective.
The flag exists for the megatron baseline (parallel/megatron.py), whose
replicated layout is kept as the §V-A(b) comparison point.

Communication/compute overlap (``overlap=`` on every op, plumbed from
``ParallelConfig.overlap`` via ``parallel/context.py``):

  * ``"none"``  — bulk-synchronous collectives (lax.all_gather / psum_scatter),
                  the paper's Algorithm 1 verbatim.
  * ``"ring"``  — ring-decomposed collective matmuls (core/overlap.py): the
                  all-gather circulates shards with ``lax.ppermute`` while each
                  arriving shard is matmul'd (AG-matmul), and the
                  reduce-scatter folds per-destination matmul tiles into a
                  circulating accumulator (matmul-RS), so every NoP transfer is
                  a collective-permute hidden behind a partial matmul — the
                  paper's §III-B(3) overlap claim made explicit in the HLO.
  * ``"bidir"`` — same, with half-sized shards circulating in both ring
                  directions (full-duplex torus links).
  * ``"fused"`` — the whole ring inside one Pallas kernel
                  (kernels/ring_matmul.py): remote DMA into a double-buffered
                  VMEM pair overlapped with the MXU tile loop by construction,
                  removing the per-step dispatch gap the ``ring`` modes leave
                  to the XLA scheduler.  CPU/interpret backends emulate each
                  hop with ``lax.ppermute`` (same chain in the HLO).

The mode lattice degrades left (``fused → ring``, ``bidir → ring``, any →
bulk) per collective, decided entirely inside core/overlap.py's dispatchers:
``fused`` requires tile-aligned shapes, ``bidir`` requires halvable shards, a
ring reduce-scatter requires the scattered extent to chunk by the ring size,
and degenerate (size-1) ring axes short-circuit to the bulk op — numerics are
identical everywhere.

The backward pass stays overlapped for free: the ring loops are unrolled linear
primitives, and JAX transposes ring-AG-matmul into ring-matmul-RS (and vice
versa); the fused kernels carry ``custom_vjp``s implementing the same
transposed rings — see core/overlap.py and kernels/ring_matmul.py.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core import overlap as OV

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _shard_map(f, mesh, in_specs, out_specs):
    return compat.shard_map(f, mesh, in_specs, out_specs)


def _ag(x, axis_name: str, dim: int):
    """Tiled all-gather along ``dim`` over mesh axis ``axis_name``."""
    return lax.all_gather(x, axis_name, axis=dim, tiled=True)


def _rs(x, axis_name: str, dim: int):
    """Tiled reduce-scatter (psum_scatter) along ``dim`` over ``axis_name``."""
    return lax.psum_scatter(x, axis_name, scatter_dimension=dim, tiled=True)


def _mm(x, w, precision=None):
    """Local matmul in bf16 with fp32 accumulation (MXU semantics)."""
    return jnp.einsum("bth,ho->bto", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# Pattern 1: fused-linear / FFN dataflow (Algorithm 1, seq-scatter)
# ---------------------------------------------------------------------------


def linear_seq_scatter(x: jax.Array, w: jax.Array, *, mesh: Optional[Mesh],
                       t_ax: str, h_ax: str,
                       data_axes: Tuple[str, ...] = ("data",),
                       overlap: str = "none",
                       comm_dtype: str = "bf16") -> jax.Array:
    """One Hecaton linear layer (paper Alg. 1 forward, steps 2-5).

    x: [B, T_local*t, H_local*h] logically; sharded P(data_axes, t_ax, h_ax).
    w: [H, O] sharded P(h_ax, t_ax)  (the paper's W[j,i] -> die(i,j) placement).
    returns y sharded P(data_axes, h_ax, t_ax)  (transposed tiling).
    """
    OV.check_mode(overlap)
    if mesh is None:
        return _mm(x, w)
    n_t, n_h = mesh.shape[t_ax], mesh.shape[h_ax]

    def f(xl, wl):
        if overlap != "none":
            return OV.ring_linear(xl, wl, g_ax=t_ax, n_g=n_t, s_ax=h_ax,
                                  n_s=n_h, gather_dim=1, scatter_dim=1,
                                  overlap=overlap,
                                  mesh_axes=mesh.axis_names,
                                  comm_dtype=comm_dtype)
        xg = _ag(xl, t_ax, 1)           # Step 3: all-gather tokens within column
        yp = _mm(xg, wl)                # local tile matmul (partial over h_ax)
        return _rs(yp, h_ax, 1)         # Step 4: reduce-scatter tokens within row

    dspec = P(data_axes)
    return _shard_map(
        f, mesh,
        in_specs=(P(dspec[0] if len(data_axes) == 1 else data_axes, t_ax, h_ax),
                  P(h_ax, t_ax)),
        out_specs=P(data_axes if len(data_axes) > 1 else data_axes[0], h_ax, t_ax),
    )(x, w)


# ---------------------------------------------------------------------------
# Pattern 2: token-mixer dataflow (paper §IV-C)
# ---------------------------------------------------------------------------


def mixer_in(x: jax.Array, w: jax.Array, *, mesh: Optional[Mesh],
             t_ax: str, h_ax: str,
             data_axes: Tuple[str, ...] = ("data",),
             overlap: str = "none",
             comm_dtype: str = "bf16") -> jax.Array:
    """Projection *into* a token mixer (QKV / mamba in_proj). Paper Fig. 7(b) steps 1-4+10.

    x: [B, T/t_ax, H/h_ax]  ->  out: [B, T(full), O/(t_ax,h_ax)]
    Sequence is gathered (every die sees all tokens of its data shard); output hidden
    is fully sharded over the whole 2D grid: head-sliced, comm-free attention.
    """
    OV.check_mode(overlap)
    if mesh is None:
        return _mm(x, w)
    n_t, n_h = mesh.shape[t_ax], mesh.shape[h_ax]

    def f(xl, wl):
        if overlap != "none":
            return OV.ring_linear(xl, wl, g_ax=t_ax, n_g=n_t, s_ax=h_ax,
                                  n_s=n_h, gather_dim=1, scatter_dim=2,
                                  overlap=overlap,
                                  mesh_axes=mesh.axis_names,
                                  comm_dtype=comm_dtype)
        xg = _ag(xl, t_ax, 1)           # gather sequence within column
        yp = _mm(xg, wl)                # [b, T, O/t_ax] partial over h_ax
        return _rs(yp, h_ax, 2)         # Step 10: reduce-scatter along *hidden*
    return _shard_map(
        f, mesh,
        in_specs=(P(data_axes if len(data_axes) > 1 else data_axes[0], t_ax, h_ax),
                  P(h_ax, t_ax)),
        out_specs=P(data_axes if len(data_axes) > 1 else data_axes[0], None,
                    (t_ax, h_ax)),
    )(x, w)


def mixer_out(a: jax.Array, w: jax.Array, *, mesh: Optional[Mesh],
              t_ax: str, h_ax: str,
              data_axes: Tuple[str, ...] = ("data",),
              overlap: str = "none",
              comm_dtype: str = "bf16") -> jax.Array:
    """Projection *out of* a token mixer (attention O-proj / mamba out_proj).

    Paper Fig. 7(b) steps 12-14: all-gather hidden within row, project, then
    reduce-scatter the sequence back to the canonical tiling.

    a: [B, T(full), Hm/(t_ax,h_ax)]  ->  out: [B, T/t_ax, O/h_ax]

    Here the gathered dim is the matmul's *contraction* dim, so the overlapped
    gather accumulates per-step partial products (ring_ag_matmul_contract)
    instead of placing tiles.
    """
    OV.check_mode(overlap)
    if mesh is None:
        return _mm(a, w)
    n_t, n_h = mesh.shape[t_ax], mesh.shape[h_ax]

    def f(al, wl):
        if overlap != "none":
            bidir = overlap == "bidir"
            rs_ok = OV.rs_ok(al.shape[1], n_t)
            if OV.fuse_side(al.shape[-1], wl.shape[-1]) == "rs" and rs_ok:
                ag = OV.ring_all_gather(al, h_ax, dim=2, n=n_h, bidir=bidir,
                                        comm_dtype=comm_dtype)
                return OV.matmul_rs(ag, wl, t_ax, scatter_dim=1, n=n_t,
                                    overlap=overlap,
                                    mesh_axes=mesh.axis_names,
                                    comm_dtype=comm_dtype)
            yp = OV.ag_matmul_contract(al, wl, h_ax, n=n_h, overlap=overlap,
                                       mesh_axes=mesh.axis_names,
                                       comm_dtype=comm_dtype)
            if not rs_ok:
                return _rs(yp, t_ax, 1)
            return OV.ring_reduce_scatter(yp, t_ax, dim=1, n=n_t, bidir=bidir,
                                          comm_dtype=comm_dtype)
        ag = _ag(al, h_ax, 2)           # Step 12: gather hidden within row
        yp = _mm(ag, wl)                # [b, T, O/h_ax] partial over t_ax
        return _rs(yp, t_ax, 1)         # Step 14: reduce-scatter sequence
    return _shard_map(
        f, mesh,
        in_specs=(P(data_axes if len(data_axes) > 1 else data_axes[0], None,
                    (t_ax, h_ax)),
                  P(t_ax, h_ax)),
        out_specs=P(data_axes if len(data_axes) > 1 else data_axes[0], t_ax, h_ax),
    )(a, w)


# ---------------------------------------------------------------------------
# Fused FFN block (paper §IV-B "two rounds of transposition")
# ---------------------------------------------------------------------------


def ffn_block(x, w1, w2, *, mesh, act_fn, t_ax: str, h_ax: str,
              data_axes: Tuple[str, ...] = ("data",),
              w1b=None, overlap: str = "none", comm_dtype: str = "bf16"):
    """Fused up/down FFN: two chained seq-scatter linears with swapped axis roles.

    After L1 the activation tiling is transposed (tokens on h_ax); L2 runs with the
    roles swapped and restores the canonical tiling — the paper's zero-communication
    layer fusion.  ``w1b`` is an optional second up-projection for gated MLPs
    (SwiGLU/GeGLU): both up-projections read the *same* gathered input, so gating
    adds zero extra communication (the gather is shared — layer fusion again).

    With ``overlap`` enabled the gated path ring-gathers the input once (both
    up-projections read it) and fuses each projection's reduce-scatter into its
    matmul loop; the ungated path uses the composed ``ring_linear`` twice.
    """
    OV.check_mode(overlap)
    if mesh is None:
        h = _mm(x, w1)
        if w1b is not None:
            h = act_fn(h) * _mm(x, w1b)
        else:
            h = act_fn(h)
        return _mm(h, w2)
    n_t, n_h = mesh.shape[t_ax], mesh.shape[h_ax]

    def f_ring(xl, w1l, w2l, *rest):
        bidir = overlap == "bidir"
        if rest:                                   # gated: share the gathered x
            xg = OV.ring_all_gather(xl, t_ax, dim=1, n=n_t, bidir=bidir,
                                    comm_dtype=comm_dtype)
            if OV.rs_ok(xg.shape[1], n_h):
                h, g = OV.matmul_rs_pair(xg, w1l, rest[0], h_ax,
                                         scatter_dim=1, n=n_h,
                                         overlap=overlap,
                                         mesh_axes=mesh.axis_names,
                                         comm_dtype=comm_dtype)
            else:
                h = _rs(_mm(xg, w1l), h_ax, 1)
                g = _rs(_mm(xg, rest[0]), h_ax, 1)
            h = act_fn(h) * g
        else:
            h = act_fn(OV.ring_linear(xl, w1l, g_ax=t_ax, n_g=n_t, s_ax=h_ax,
                                      n_s=n_h, overlap=overlap,
                                      mesh_axes=mesh.axis_names,
                                      comm_dtype=comm_dtype))
        return OV.ring_linear(h, w2l, g_ax=h_ax, n_g=n_h, s_ax=t_ax, n_s=n_t,
                              overlap=overlap, mesh_axes=mesh.axis_names,
                              comm_dtype=comm_dtype)

    def f(xl, w1l, w2l, *rest):
        if overlap != "none":
            return f_ring(xl, w1l, w2l, *rest)
        xg = _ag(xl, t_ax, 1)                      # gather tokens once
        hp = _mm(xg, w1l)
        h = _rs(hp, h_ax, 1)                       # tokens now tiled over h_ax
        if rest:
            gp = _mm(xg, rest[0])
            g = _rs(gp, h_ax, 1)
            h = act_fn(h) * g
        else:
            h = act_fn(h)
        hg = _ag(h, h_ax, 1)                       # L2 with swapped roles
        yp = _mm(hg, w2l)
        return _rs(yp, t_ax, 1)                    # canonical tiling restored

    dspec = data_axes if len(data_axes) > 1 else data_axes[0]
    in_specs = [P(dspec, t_ax, h_ax), P(h_ax, t_ax), P(t_ax, h_ax)]
    args = [x, w1, w2]
    if w1b is not None:
        in_specs.append(P(h_ax, t_ax))
        args.append(w1b)
    return _shard_map(f, mesh, in_specs=tuple(in_specs),
                      out_specs=P(dspec, t_ax, h_ax))(*args)


# ---------------------------------------------------------------------------
# Vocab-parallel embedding (paper §IV-B Step 2-3: scatter from DRAM, collect
# via NoP).  The table is 2D-tiled [V/t_ax, H/h_ax]; each die gathers its vocab
# slice for ALL tokens (masked) and a reduce-scatter over the token axis both
# sums the vocab partials and restores the canonical activation tiling.
# (Also works around an XLA GSPMD bug partitioning gathers from 2D-sharded
# tables: dynamic-slice verifier failure, observed jax 0.8.2 CPU backend.)
#
# With ``overlap`` != "none" the last bulk collective outside the hot paths
# honours the mode lattice too: the ids gather and the vocab-partial
# reduce-scatter run as ppermute rings, and ``"fused"`` additionally routes
# the collect through the single-kernel matmul-RS (the vocab partial is
# expressed as a one-hot matmul so there is a matmul to fuse the scatter
# into) when the local vocab slice is small enough for that to be a win —
# larger slices degrade to the ring reduce-scatter, per the lattice.
# ---------------------------------------------------------------------------

# local vocab slice above which the one-hot-matmul form of the vocab collect
# (the fused matmul-RS route) costs more MXU time than it hides — degrade to
# the plain ring reduce-scatter beyond it.
EMBED_FUSED_VMAX = 2048


def embed_2d(ids: jax.Array, table: jax.Array, *, mesh: Optional[Mesh],
             t_ax: str, h_ax: str, data_axes: Tuple[str, ...] = ("data",),
             compute_dtype=jnp.bfloat16, seq_sharded: bool = True,
             batch_sharded: bool = True, overlap: str = "none",
             comm_dtype: str = "bf16") -> jax.Array:
    """ids [B,S] -> embeddings.

    seq_sharded=True (train/prefill): ids arrive tokens-over-t_ax, output is
    canonical [B, S/t_ax, H/h_ax] (for megatron callers ``h_ax=None``: the
    seq-sharded residual P(d, model, None)).  seq_sharded=False (decode): ids
    replicated, output [B, S, H/h_ax] with a psum over t_ax instead of the
    scatter.  ``overlap`` != "none" replaces the bulk ids-gather / vocab
    reduce-scatter with the ring forms (fused one-hot matmul-RS when cheap).
    """
    OV.check_mode(overlap)
    if mesh is None:
        return jnp.take(table, ids, axis=0).astype(compute_dtype)
    n_t = mesh.shape[t_ax]
    bidir = overlap == "bidir"

    def f(ids_l, tab_l):
        if seq_sharded and overlap != "none":
            # integer ids: quant_ok degrades these hops to full width
            idg = OV.ring_all_gather(ids_l, t_ax, dim=1, n=n_t, bidir=bidir,
                                     comm_dtype=comm_dtype)
        elif seq_sharded:
            idg = _ag(ids_l, t_ax, 1)
        else:
            idg = ids_l
        v_loc = tab_l.shape[0]
        off = lax.axis_index(t_ax) * v_loc
        lid = idg - off
        ok = (lid >= 0) & (lid < v_loc)
        if (seq_sharded and overlap == "fused" and v_loc <= EMBED_FUSED_VMAX
                and OV.rs_ok(idg.shape[1], n_t)):
            # one-hot form: emb_partial = onehot @ table_slice, which the
            # fused dispatcher can run as a single-kernel matmul ⊕ RS
            onehot = (jnp.where(ok, lid, v_loc)[..., None]
                      == jnp.arange(v_loc)[None, None, :]).astype(compute_dtype)
            tab = tab_l.astype(compute_dtype)
            return OV.matmul_rs(onehot, tab, t_ax, scatter_dim=1, n=n_t,
                                overlap=overlap, mesh_axes=mesh.axis_names,
                                comm_dtype=comm_dtype)
        emb = jnp.take(tab_l, jnp.clip(lid, 0, v_loc - 1), axis=0)
        emb = (emb * ok[..., None]).astype(compute_dtype)
        if seq_sharded:
            if overlap != "none" and OV.rs_ok(emb.shape[1], n_t):
                return OV.ring_reduce_scatter(emb, t_ax, dim=1, n=n_t,
                                              bidir=bidir,
                                              comm_dtype=comm_dtype)
            return _rs(emb, t_ax, 1)        # sums vocab partials + tiles tokens
        return lax.psum(emb, t_ax)

    d = data_axes if len(data_axes) > 1 else data_axes[0]
    bspec = d if batch_sharded else None
    in_ids = P(bspec, t_ax if seq_sharded else None)
    out = P(bspec, t_ax, h_ax) if seq_sharded else P(bspec, None, h_ax)
    return _shard_map(f, mesh, in_specs=(in_ids, P(t_ax, h_ax)),
                      out_specs=out)(ids, table)


# ---------------------------------------------------------------------------
# Fused chunked LM-head + cross-entropy (beyond-paper optimization, §Perf it.2)
#
# The baseline seq-scatter lm_head materializes [all-local-tokens, V/mx]
# partial logits (gigabytes in fp32) and its backward all-gathers fp32
# d-logits — by far the largest memory AND collective contributor for
# small/medium models.  Here the loss is computed inside ONE shard_map,
# scanning over sequence chunks:
#   * tokens stay tiled over t_ax (never gathered);
#   * the head weight is [H, V/h_ax] (vocab over h_ax, H unsharded — stored
#     FSDP-sharded over data);
#   * per chunk: AG x over h_ax (tiny), local [tc,H]@[H,V/h] matmul, stable
#     LSE via pmax/psum of per-token scalars over h_ax;
#   * nothing bigger than [tc, V/h_ax] ever exists, and the only collectives
#     are the tiny x-chunk gather + scalar reductions.
# ---------------------------------------------------------------------------


def fused_lm_loss(x: jax.Array, w: jax.Array, labels: jax.Array,
                  loss_mask: Optional[jax.Array], *, mesh: Optional[Mesh],
                  t_ax: str, h_ax: str, data_axes: Tuple[str, ...] = ("data",),
                  n_chunks: int = 8,
                  overlap: str = "none",
                  comm_dtype: str = "bf16") -> Tuple[jax.Array, jax.Array]:
    """Returns (sum of masked NLL, mask count) — caller divides.

    x [B, S, H] canonical P(d, t_ax, h_ax); w [H, V] P(None, h_ax);
    labels/loss_mask [B, S] P(d, t_ax).
    """
    OV.check_mode(overlap)
    if loss_mask is None:
        loss_mask = jnp.ones(labels.shape, jnp.float32)

    if mesh is None:
        lf = jnp.einsum("bth,hv->btv", x, w.astype(x.dtype),
                        preferred_element_type=jnp.float32)
        m = lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
        lse = jnp.squeeze(m, -1) + jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1))
        gold = jnp.sum(lf * jax.nn.one_hot(labels, w.shape[1],
                                           dtype=jnp.float32), axis=-1)
        wmask = loss_mask.astype(jnp.float32)
        return jnp.sum((lse - gold) * wmask), jnp.sum(wmask)

    def f(xl, wl, ll, ml):
        b, s_loc, _ = xl.shape
        v_loc = wl.shape[1]
        v_off = lax.axis_index(h_ax) * v_loc
        nc = n_chunks
        while s_loc % nc:
            nc -= 1
        tc = s_loc // nc
        xs = (xl.reshape(b, nc, tc, -1).transpose(1, 0, 2, 3),
              ll.reshape(b, nc, tc).transpose(1, 0, 2),
              ml.reshape(b, nc, tc).transpose(1, 0, 2))

        n_h = mesh.shape[h_ax]

        def chunk(carry, inp):
            xc, lc, mc = inp
            if overlap != "none":
                # ring AG-matmul over the contracted hidden dim: the per-chunk
                # x gather circulates as collective-permutes hidden behind the
                # per-shard [tc,H/n]@[H/n,V/n] partial matmuls (fp32 accum);
                # "fused" runs the whole chunk ring inside one Pallas kernel.
                lg = OV.ag_matmul_contract(xc, wl, h_ax, n=n_h,
                                           overlap=overlap,
                                           out_dtype=jnp.float32,
                                           mesh_axes=mesh.axis_names,
                                           comm_dtype=comm_dtype)
            else:
                xg = _ag(xc, h_ax, 2)                 # [b, tc, H] (tiny AG)
                lg = jnp.einsum("bth,hv->btv", xg, wl,
                                preferred_element_type=jnp.float32)
            mloc = jnp.max(lg, axis=-1)
            # pmax has no AD rule: gather the per-shard maxima (tiny) instead
            mall = lax.all_gather(lax.stop_gradient(mloc), h_ax, axis=0)
            mglob = jnp.max(mall, axis=0)
            e = jnp.exp(lg - mglob[..., None])
            lse = mglob + jnp.log(lax.psum(jnp.sum(e, axis=-1), h_ax))
            onehot = ((lc[..., None] - v_off)
                      == jnp.arange(v_loc)[None, None, :])
            gold = lax.psum(jnp.sum(lg * onehot, axis=-1), h_ax)
            wm = mc.astype(jnp.float32)
            # rank-1 carry: scalar carries become scalar residuals under
            # jax.checkpoint, which old shard_map's partial-eval mis-names
            # (jax<=0.4.x _SpecError); a [2]-vector sidesteps the bug.
            return carry + jnp.stack([jnp.sum((lse - gold) * wm),
                                      jnp.sum(wm)]), None

        chunk = jax.checkpoint(chunk)                 # recompute logits in bwd
        acc, _ = lax.scan(chunk, jnp.zeros((2,)), xs)
        nll = lax.psum(acc[0], data_axes + (t_ax,))
        cnt = lax.psum(acc[1], data_axes + (t_ax,))
        return nll, cnt

    d = data_axes if len(data_axes) > 1 else data_axes[0]
    return _shard_map(
        f, mesh,
        in_specs=(P(d, t_ax, h_ax), P(None, h_ax), P(d, t_ax), P(d, t_ax)),
        out_specs=(P(), P()),
    )(x, w.astype(x.dtype), labels, loss_mask)


# ---------------------------------------------------------------------------
# Weight / activation PartitionSpecs implied by the method
# ---------------------------------------------------------------------------


def canonical_act_spec(t_ax="mx", h_ax="my", data_axes=("data",)) -> P:
    """[B, T, H] tiling at block boundaries: tokens over t_ax, hidden over h_ax."""
    d = data_axes if len(data_axes) > 1 else data_axes[0]
    return P(d, t_ax, h_ax)


def mixer_act_spec(t_ax="mx", h_ax="my", data_axes=("data",)) -> P:
    """[B, T, Hm] inside a mixer: full sequence, hidden over the whole grid."""
    d = data_axes if len(data_axes) > 1 else data_axes[0]
    return P(d, None, (t_ax, h_ax))


def w_in_spec(t_ax="mx", h_ax="my") -> P:
    """Weight consumed by a canonical-layout input: W[H/h_ax, O/t_ax]."""
    return P(h_ax, t_ax)


def w_swapped_spec(t_ax="mx", h_ax="my") -> P:
    """Weight of the second fused layer (roles swapped): W[H/t_ax, O/h_ax]."""
    return P(t_ax, h_ax)
