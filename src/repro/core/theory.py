"""Analytical communication model — paper Table III, §V, and the Fig. 8-11 studies.

Implements closed-form NoP/ICI overheads for the four distributed-training methods the
paper compares:

  flat_ring  : 1D-TP + ring all-reduce            (Megatron, "F" in Fig. 8)
  torus_ring : 1D-TP + 2D-torus all-reduce        ("T")
  optimus    : 2D-TP + broadcast/reduce            ("O")
  hecaton    : this paper's 2D-TP + AG/RS          ("A")

Notation follows Table II/III:
  N      — number of devices participating in tensor parallelism
  alpha  — per-hop link latency [s]
  beta   — per-link bandwidth  [bytes/s]
  gamma  — b*s*h * bytes_per_elt / beta   (activation transfer unit, seconds)
  xi     — h^2  * bytes_per_elt / beta    (weight-tile transfer unit, seconds)

All returned times are seconds for ONE transformer layer's Attention or FFN block
(forward or backward), exactly the cells of Table III.  These formulas are the oracle
against which we test the *measured* collective bytes parsed from compiled HLO
(tests/test_roofline.py), closing the loop between the paper's theory and our
implementation.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class CommParams:
    N: int                 # devices in the TP group
    alpha: float = 10e-9   # link latency (paper §VI-E uses 10ns)
    beta: float = 64e9     # D2D/ICI bandwidth per link [B/s]
    b: int = 8             # mini-batch size (samples)
    s: int = 2048          # sequence length
    h: int = 4096          # hidden size
    bytes_per_elt: int = 2

    @property
    def gamma(self) -> float:
        return self.b * self.s * self.h * self.bytes_per_elt / self.beta

    @property
    def xi(self) -> float:
        return self.h * self.h * self.bytes_per_elt / self.beta

    @property
    def rootN(self) -> float:
        r = math.isqrt(self.N)
        assert r * r == self.N, f"N={self.N} must be a perfect square for 2D methods"
        return r


# ---------------------------------------------------------------------------
# Table III rows.  Each function returns dict(link_latency=..., transmission=...).
# ---------------------------------------------------------------------------

def _cell(L, T):
    return {"link_latency": L, "transmission": T, "total": L + T}


def hecaton(p: CommParams, phase: str, block: str) -> Dict[str, float]:
    """Paper's method.  AG/RS over sqrt(N)-size rows/cols; bypass ring: 2*alpha/hop."""
    r = p.rootN
    L_unit = (r - 1) * 2 * p.alpha                       # eq. (2)
    coeff = {("fwd", "atten"): (4, 6), ("fwd", "ffn"): (4, 10),
             ("bwd", "atten"): (6, 8), ("bwd", "ffn"): (6, 15)}[(phase, block)]
    n_colls, t_coeff = coeff
    L = n_colls * L_unit / 2                             # Table III: 8/12 (sqrt(N)-1) a
    # Table III link-latency entries: fwd 8(√N−1)α, bwd 12(√N−1)α
    L = {("fwd"): 8, ("bwd"): 12}[phase] * (r - 1) * p.alpha
    T = t_coeff * (r - 1) / p.N * p.gamma
    return _cell(L, T)


def flat_ring(p: CommParams, phase: str, block: str) -> Dict[str, float]:
    """1D-TP + flat ring all-reduce (Megatron)."""
    n = {"fwd": 2, "bwd": 3}[phase]                      # #all-reduces per block
    L = n * (p.N - 1) * p.alpha
    T = n * (p.N - 1) / p.N * p.gamma
    return _cell(L, T)


def torus_ring(p: CommParams, phase: str, block: str) -> Dict[str, float]:
    """1D-TP + 2D-torus all-reduce: 2x links, 2x hops per step."""
    n = {"fwd": 2, "bwd": 3}[phase]
    L = 2 * n * (p.N - p.rootN) * p.alpha
    T = n * (p.N - 1) / (2 * p.N) * p.gamma
    return _cell(L, T)


def optimus(p: CommParams, phase: str, block: str) -> Dict[str, float]:
    """2D-TP with broadcast/reduce (recursive doubling), per Table III."""
    r = p.rootN
    logN = math.log2(p.N)
    L = {"fwd": 4 * (p.N - r), "bwd": 12 * (p.N - r)}[phase] * p.alpha
    coeff = {("fwd", "atten"): (2, 4), ("fwd", "ffn"): (5, 8),
             ("bwd", "atten"): (4, 8), ("bwd", "ffn"): (10, 16)}[(phase, block)]
    cg, cx = coeff
    T = logN / (2 * r) * (cg * p.gamma + cx * p.xi)
    return _cell(L, T)


METHODS = {"flat_ring": flat_ring, "torus_ring": torus_ring,
           "optimus": optimus, "hecaton": hecaton}


def layer_comm(method: str, p: CommParams) -> Dict[str, float]:
    """Total NoP comm (s) for one full transformer layer fwd+bwd."""
    f = METHODS[method]
    cells = [f(p, ph, bl) for ph in ("fwd", "bwd") for bl in ("atten", "ffn")]
    return {
        "link_latency": sum(c["link_latency"] for c in cells),
        "transmission": sum(c["transmission"] for c in cells),
        "total": sum(c["total"] for c in cells),
    }


# ---------------------------------------------------------------------------
# Compute / DRAM model (for Fig. 8-10 style studies and weak scaling)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SystemParams:
    comm: CommParams
    flops_per_device: float = 197e12 / 256    # per-"die" compute (scaled v5e default)
    dram_bw: float = 51.2e9                   # off-package bandwidth [B/s]
    dram_channels: int = 16
    sram_bytes: int = 8 * 2**20               # per-die activation/weight buffer
    act_stream_mult: float = 24.0             # streamed elements/token/h


def layer_flops(p: CommParams) -> float:
    """FLOPs of one transformer layer fwd+bwd (dense, 4h^2 attn + 8h^2 ffn weights)."""
    tokens = p.b * p.s
    fwd = 2 * tokens * (4 * p.h * p.h + 8 * p.h * p.h)   # matmul MACs*2
    fwd += 2 * tokens * p.s * p.h * 2                    # QK^T and SV
    return 3 * fwd                                       # bwd ~ 2x fwd


def pe_utilization(method: str, p: CommParams, array_dim: int = 64,
                   floor: float = 0.4) -> float:
    """Systolic-array utilization of the local weight tile (paper §VI-B: 1D-TP
    "exhibits increased computation time ... due to reduced PE array
    utilization"; 2D methods keep balanced input/output channel counts).

    1D-TP slices ONE weight dim N ways (tile h x h/N); 2D-TP slices both dims
    sqrt(N) ways.  Dims below the effective array width waste lanes; ``floor``
    models the vector/streaming units that stay busy regardless."""
    if method in ("flat_ring", "torus_ring"):
        tile = p.h / p.N
    else:
        tile = p.h / p.rootN
    return max(floor, min(1.0, max(tile, 1.0) / array_dim))


def layer_time(method: str, sp: SystemParams) -> Dict[str, float]:
    """Per-layer time decomposition {compute, nop, dram, total} with overlap.

    DRAM term models the paper's §III-B scheduling: activations stream on/off
    package overlapped with execution (latency hiding); weights amortized over
    the batch.  Activation stream = fwd save + bwd reload of the ~24*h live
    elements/token (unfused-layer boundaries, Fig. 6).
    """
    p = sp.comm
    comm = layer_comm(method, p)
    util = pe_utilization(method, p)
    compute = layer_flops(p) / (sp.flops_per_device * p.N) / util
    act_bytes = sp.act_stream_mult * p.b * p.s * p.h * p.bytes_per_elt
    dram = act_bytes / (sp.dram_channels * sp.dram_bw)
    on_pkg = compute + comm["total"]
    total = max(on_pkg, dram)                            # overlap (paper Fig. 6)
    return {"compute": compute, "nop": comm["total"], "dram": dram,
            "utilization": util,
            "nop_link": comm["link_latency"], "nop_tx": comm["transmission"],
            "exposed_dram": max(0.0, dram - on_pkg), "total": total}


def weak_scaling_series(method: str, base: CommParams, ks=(1, 2, 4, 8),
                        flops_per_device: float = 197e12 / 256,
                        dram_bw: float = 51.2e9):
    """Scale h by k and N by k^2 (paper §V-B); return normalized latency series."""
    out = []
    for k in ks:
        p = CommParams(N=base.N * k * k, alpha=base.alpha, beta=base.beta,
                       b=base.b, s=base.s, h=base.h * k,
                       bytes_per_elt=base.bytes_per_elt)
        sp = SystemParams(comm=p, dram_channels=int(16 * k),
                          flops_per_device=flops_per_device, dram_bw=dram_bw)
        out.append(layer_time(method, sp))
    norm = out[0]["total"]
    for o in out:
        o["normalized"] = o["total"] / norm
    return out


# ---------------------------------------------------------------------------
# Inter-pod pipeline model (PR 5: pod_axis_role="pipeline")
# ---------------------------------------------------------------------------

def pipeline_bubble_fraction(p: int, m: int) -> float:
    """Idle fraction of the non-interleaved 1F1B schedule: ``(p-1)/(m+p-1)``.

    ``p`` pipeline stages (pods), ``m`` microbatches; F and B take one tick
    each, so the makespan is ``2(m+p-1)`` ticks of which every stage idles
    ``2(p-1)`` — the classic PipeDream-flush / Megatron-LM bubble.  The
    simulated schedule (parallel/pipeline.schedule_1f1b) reproduces this
    exactly; benchmarks/comm_model.pipeline_rows asserts the match in the
    emitted ``theory_pipeline_*`` rows.
    """
    if p <= 1:
        return 0.0
    return (p - 1) / (m + p - 1)


def pipeline_boundary_comm(p: CommParams, n_stages: int, n_micro: int,
                           pod_beta: float, pod_alpha: float = 1e-6
                           ) -> Dict[str, float]:
    """Per-step inter-pod transfer time of the 1F1B stage boundaries.

    Each microbatch crosses each of the ``p-1`` boundaries once forward
    (one [b/m, s, h] activation) and once backward (its cotangent) over the
    slow off-package links (``pod_beta`` bytes/s, ``pod_alpha`` latency).
    The residual stays seq-sharded *within* a pod, but the whole tensor
    must cross the package boundary, so the per-crossing bytes are the full
    microbatch activation.
    """
    bytes_per_mb = p.b / n_micro * p.s * p.h * p.bytes_per_elt
    crossings = 2 * (n_stages - 1) * n_micro
    T = crossings * bytes_per_mb / pod_beta
    L = crossings * pod_alpha
    return _cell(L, T)


def pipeline_step_time(sp: SystemParams, n_stages: int, n_micro: int,
                       layers: int, pod_beta: float) -> Dict[str, float]:
    """Whole-step time decomposition of a ``p``-pod 1F1B pipeline.

    Per-stage compute is ``layers/p`` layer times; the 1F1B bubble inflates
    the critical path by ``1/(1-bubble)``; boundary transfers hide behind
    compute when shorter than one stage's per-microbatch work (1F1B sends
    while the next microbatch computes), otherwise the excess is exposed.
    """
    p = sp.comm
    lt = layer_time("hecaton", sp)
    stage_layers = layers / n_stages
    work = lt["total"] * stage_layers * n_micro      # per-stage, all microbatches
    bubble = pipeline_bubble_fraction(n_stages, n_micro)
    comm = pipeline_boundary_comm(p, n_stages, n_micro, pod_beta)
    per_mb_compute = lt["total"] * stage_layers
    per_crossing = (comm["total"] / max(1, 2 * (n_stages - 1) * n_micro))
    exposed = max(0.0, per_crossing - per_mb_compute) * 2 * (n_stages - 1) \
        * n_micro
    total = work / (1.0 - bubble) + exposed
    return {"compute": work, "bubble_fraction": bubble,
            "boundary_comm": comm["total"], "exposed_boundary": exposed,
            "total": total}


# ---------------------------------------------------------------------------
# SRAM requirement model (paper §V-A b)
# ---------------------------------------------------------------------------

def peak_sram_bytes(method: str, p: CommParams) -> float:
    """Peak per-die activation-buffer bytes for the 4h FFN intermediate."""
    e = p.bytes_per_elt
    if method == "hecaton":
        return 4 * p.b * p.s * p.h / p.rootN * e          # Z gathered within a row
    if method in ("flat_ring", "torus_ring"):
        return 4 * p.b * p.s * p.h * e / 1                # full activations per die
    if method == "optimus":
        return 4 * p.b * p.s * p.h / p.rootN * e + p.h * p.h / p.N * e * 2
    raise KeyError(method)
