"""Per-channel symmetric int8 quantization for communicated shards.

The ring lattice (core/overlap.py, kernels/ring_matmul.py) hides NoP time
behind compute, but every hop still moves full-width shards, so link
bandwidth stays the binding constraint of the weak-scaling argument (paper
§V-B).  This module is the shared quantize/dequantize machinery behind
``ParallelConfig.comm_dtype="int8"``: the shard a device is about to send is
cast to int8 with a per-channel symmetric scale, the *pair* (int8 payload,
fp32 scale) crosses the link, and the receiver dequantizes into the fp32
accumulator the rings already carry — cutting per-hop bytes ~2x vs bf16
shards (~4x vs fp32) at a bounded per-hop error of ``scale/2`` per element.

Scale placement (docs/DESIGN.md §11): scales are **per row** — one fp32
scale per slice of the trailing (feature) axis, i.e. shape ``x.shape[:-1]``.
Per-row wins over per-feature on both axes that matter here:

  * bytes — a row scale amortizes over the feature extent actually moved
    per hop (``h`` payload bytes carry 4 scale bytes), whereas per-feature
    scales are a fixed ``4*h``-byte tensor that dwarfs the small per-device
    shards the smoke grids move;
  * error — the rings contract over features (``x @ w``), so a per-row
    scale keeps the quantization error of each dot product proportional to
    that row's own magnitude, the standard AQT-style channel choice for
    activations.

Zero-safety: an all-zero row would divide by zero; its scale is forced to
1.0, which round-trips zeros bit-exactly (0/1.0 → q=0 → 0*1.0 == +0.0) and
produces no NaN/Inf anywhere (property-tested in tests/test_properties.py).

Degradation (mirrors the fused→ring→bulk lattice): :func:`quant_ok` refuses
integer payloads (token ids must gather exactly) and trailing extents too
small for the scale to pay for itself — such hops silently stay full-width,
per collective, with every other hop in the same ring still quantized.

Autodiff: plain value-level quantization would break both directions —
``jnp.round`` has a zero gradient, and XLA would move the *pre-cast* wide
tensor if the cast got fused away.  :func:`q_hop` is therefore a
``jax.custom_vjp`` whose forward ppermutes the actual int8 payload and the
scales (so compiled HLO moves int8 bytes) and whose backward runs the SAME
quantized hop over the inverse permutation — the transposed ring quantizes
cotangent shards exactly like the forward quantizes activations.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

COMM_DTYPES = ("bf16", "int8")

# Trailing extents below this keep full-width hops: the 4-byte row scale and
# the extra permute op erode the 2x byte cut past usefulness (at h=16 the
# pair still moves only 0.63x of bf16; below that the margin thins fast).
MIN_QUANT_DIM = 16


def check_comm_dtype(comm_dtype: str) -> str:
    """Validate a comm dtype string (a typo must not silently mean bf16)."""
    if comm_dtype not in COMM_DTYPES:
        raise ValueError(f"comm_dtype={comm_dtype!r} not in {COMM_DTYPES}")
    return comm_dtype


def quant_ok(shape, dtype) -> bool:
    """May a shard of this shape/dtype be quantized for a ring hop?

    False degrades that hop (not the whole ring) to the full-width permute:
    integer payloads (embedding ids) must arrive exact, and sub-
    ``MIN_QUANT_DIM`` trailing extents cannot carry their scales profitably.
    """
    return (len(shape) >= 1 and shape[-1] >= MIN_QUANT_DIM
            and jnp.issubdtype(jnp.dtype(dtype), jnp.inexact))


def quant_int8(x):
    """Per-row symmetric int8 quantization.

    Returns ``(q, scale)`` with ``q`` int8 of ``x.shape`` and ``scale`` fp32
    of ``x.shape[:-1] + (1,)`` (one scale per trailing-axis row, kept-dims so
    it broadcasts straight back).  ``scale = max|row| / 127`` so the row
    maximum maps to exactly ±127; all-zero rows get scale 1.0 (zeros
    round-trip bit-exactly, no div-by-zero).  Element-wise roundtrip error is
    ≤ ``scale/2``."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0.0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(xf / scale), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def dequant_int8(q, scale, dtype):
    """Dequantize ``(q, scale)`` back to ``dtype`` (via fp32 product)."""
    return (q.astype(jnp.float32) * scale).astype(dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def q_hop(x, axis_name: str, perm):
    """One quantized ring hop: quantize, permute the (int8, scale) pair,
    dequantize on receipt.  ``perm`` is a tuple of (src, dst) pairs (hashable
    — it is a nondiff argument of the custom VJP)."""
    q, s = quant_int8(x)
    q = lax.ppermute(q, axis_name, list(perm))
    s = lax.ppermute(s, axis_name, list(perm))
    return dequant_int8(q, s, x.dtype)


def _q_hop_fwd(x, axis_name, perm):
    return q_hop(x, axis_name, perm), None


def _q_hop_bwd(axis_name, perm, _res, g):
    # transpose of a permutation is its inverse; the cotangent shard crosses
    # the link quantized exactly like the forward shard did
    inv = tuple((d, s) for s, d in perm)
    return (q_hop(g, axis_name, inv),)


q_hop.defvjp(_q_hop_fwd, _q_hop_bwd)


def ring_hop(x, axis_name: str, n: int, shift: int = 1,
             comm_dtype: str = "bf16"):
    """One ring hop under ``comm_dtype``: shard → (rank + shift) % n.

    ``"bf16"`` is EXACTLY ``lax.ppermute`` of the operand as-is (the
    default path stays bit-identical to the pre-quantization rings);
    ``"int8"`` routes eligible shards through :func:`q_hop` and silently
    degrades ineligible ones (``quant_ok``) to the full-width permute."""
    perm = [(i, (i + shift) % n) for i in range(n)]
    if comm_dtype == "int8" and quant_ok(x.shape, x.dtype):
        return q_hop(x, axis_name, tuple(perm))
    return lax.ppermute(x, axis_name, perm)
