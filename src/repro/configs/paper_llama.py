"""The paper's own evaluation ladder (§VI-A): Llama family with doubling hidden
size, used by benchmarks/scaling.py to reproduce Fig. 9 (weak scaling).

These are registered with a `paper-` prefix; they are NOT part of the 40
assigned cells but drive the paper-faithfulness benchmarks.
"""
from repro.config import ModelConfig, register


def _llama(name, L, h, nh, nkv, ff, vocab=32_000):
    return ModelConfig(name=name, family="dense", num_layers=L, d_model=h,
                       num_heads=nh, num_kv_heads=nkv, d_ff=ff,
                       vocab_size=vocab, mlp_kind="swiglu", norm_kind="rmsnorm")


for cfg in [
    _llama("paper-tinyllama-1.1b", 22, 2048, 32, 4, 5632),
    _llama("paper-llama2-7b", 32, 4096, 32, 32, 11_008),
    _llama("paper-llama2-70b", 80, 8192, 64, 8, 28_672),
    _llama("paper-llama3.1-405b", 126, 16_384, 128, 8, 53_248, vocab=128_256),
]:
    register(cfg, cfg.scaled(num_layers=2, d_model=64, num_heads=4,
                             num_kv_heads=4, head_dim=16, d_ff=128,
                             vocab_size=128))
