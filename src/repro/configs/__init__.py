"""Assigned-architecture registry: importing this package registers all configs."""

from repro.configs import (  # noqa: F401
    mamba2_130m,
    qwen3_0_6b,
    nemotron_4_340b,
    granite_34b,
    minicpm3_4b,
    paligemma_3b,
    whisper_small,
    granite_moe_3b_a800m,
    grok_1_314b,
    zamba2_1_2b,
    paper_llama,
)
