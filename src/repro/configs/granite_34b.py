"""granite-34b — code model, MQA (kv=1). [arXiv:2405.04324]

Non-gated gelu MLP (d_ff = 4*d_model): yields ~34B params matching the name;
a SwiGLU MLP would overcount at ~47B (the HF granite-34b-code is GPTBigCode-
style MQA + gelu, "llama-arch" in the assignment note notwithstanding)."""
from repro.config import ModelConfig, register

FULL = ModelConfig(
    name="granite-34b", family="dense", num_layers=88, d_model=6144,
    num_heads=48, num_kv_heads=1, d_ff=24_576, vocab_size=49_152,
    mlp_kind="gelu", norm_kind="rmsnorm", rope_theta=10_000.0,
)

SMOKE = FULL.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
                    head_dim=16, d_ff=256, vocab_size=128)

register(FULL, SMOKE)
