"""paligemma-3b — SigLIP (stubbed) + gemma decoder, MQA. [arXiv:2407.07726]

The vision frontend is a STUB: input_specs() supplies precomputed patch
embeddings [B, 256, d_model] which replace the first 256 sequence positions.
"""
from repro.config import ModelConfig, register

FULL = ModelConfig(
    name="paligemma-3b", family="vlm", num_layers=18, d_model=2048,
    num_heads=8, num_kv_heads=1, d_ff=16_384, vocab_size=257_216,
    head_dim=256, mlp_kind="geglu", norm_kind="rmsnorm",
    rope_theta=10_000.0, frontend_stub_len=256,
)

SMOKE = FULL.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
                    head_dim=16, d_ff=128, vocab_size=128, frontend_stub_len=8)

register(FULL, SMOKE)
