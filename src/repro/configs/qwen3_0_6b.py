"""qwen3-0.6b — dense, GQA kv=8, qk_norm, SwiGLU. [hf:Qwen/Qwen3-8B family]"""
from repro.config import ModelConfig, register

FULL = ModelConfig(
    name="qwen3-0.6b", family="dense", num_layers=28, d_model=1024,
    num_heads=16, num_kv_heads=8, d_ff=3072, vocab_size=151_936,
    head_dim=128, mlp_kind="swiglu", norm_kind="rmsnorm", qk_norm=True,
    rope_theta=1_000_000.0, tie_embeddings=True,
)

SMOKE = FULL.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                    head_dim=16, d_ff=128, vocab_size=128)

register(FULL, SMOKE)
