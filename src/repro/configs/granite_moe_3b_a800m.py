"""granite-moe-3b-a800m — MoE 40 experts top-8. [hf:ibm-granite family]

The assignment lists "MoE 40e top-8" in the config line and "32 experts" in the
note; we follow the config line (40 experts, top-8).
"""
from repro.config import MoEConfig, ModelConfig, register

FULL = ModelConfig(
    name="granite-moe-3b-a800m", family="moe", num_layers=32, d_model=1536,
    num_heads=24, num_kv_heads=8, d_ff=512, vocab_size=49_155,
    mlp_kind="swiglu", norm_kind="rmsnorm",
    moe=MoEConfig(num_experts=40, top_k=8, capacity_factor=1.25),
)

SMOKE = FULL.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                    head_dim=16, d_ff=32, vocab_size=128,
                    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.5))

register(FULL, SMOKE)
