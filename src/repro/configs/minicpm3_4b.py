"""minicpm3-4b — MLA (multi-head latent attention). [hf:openbmb/MiniCPM3-4B]"""
from repro.config import MLAConfig, ModelConfig, register

FULL = ModelConfig(
    name="minicpm3-4b", family="dense", num_layers=62, d_model=2560,
    num_heads=40, num_kv_heads=40, d_ff=6400, vocab_size=73_448,
    mlp_kind="swiglu", norm_kind="rmsnorm", rope_theta=10_000.0,
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64,
                  qk_rope_head_dim=32, v_head_dim=64),
)

SMOKE = FULL.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                    d_ff=128, vocab_size=128,
                    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                  qk_nope_head_dim=8, qk_rope_head_dim=4,
                                  v_head_dim=8))

register(FULL, SMOKE)
