"""zamba2-1.2b — hybrid: Mamba2 backbone + shared attention blocks. [arXiv:2411.15242]

38 mamba2 blocks; after every 6th block a full attention+MLP block runs whose
parameters come from 2 alternating shared sets (parameter re-use across depth).
"""
from repro.config import ModelConfig, SSMConfig, register

FULL = ModelConfig(
    name="zamba2-1.2b", family="hybrid", num_layers=38, d_model=2048,
    num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=32_000,
    mlp_kind="swiglu", norm_kind="rmsnorm",
    num_shared_attn_sets=2, shared_attn_every=6,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, n_groups=1,
                  conv_kernel=4, chunk_size=128),
)

SMOKE = FULL.scaled(num_layers=5, d_model=64, num_heads=4, num_kv_heads=4,
                    head_dim=16, d_ff=128, vocab_size=128,
                    num_shared_attn_sets=2, shared_attn_every=2,
                    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2,
                                  n_groups=1, conv_kernel=4, chunk_size=8))

register(FULL, SMOKE)
