"""whisper-small — encoder-decoder, conv frontend stubbed. [arXiv:2212.04356]

input_specs() supplies precomputed frame embeddings [B, 1500, d_model] (the
conv1d+log-mel frontend is a stub).  Positional scheme simplified to RoPE
(backbone-only reproduction, noted in docs/DESIGN.md §4).
"""
from repro.config import ModelConfig, register

FULL = ModelConfig(
    name="whisper-small", family="audio", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=51_865,
    mlp_kind="gelu", norm_kind="layernorm", encoder_layers=12,
    frontend_stub_len=1500,
)

SMOKE = FULL.scaled(num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
                    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=128,
                    frontend_stub_len=12)

register(FULL, SMOKE)
