"""mamba2-130m — SSD (state-space duality), attention-free. [arXiv:2405.21060]"""
from repro.config import ModelConfig, SSMConfig, register

FULL = ModelConfig(
    name="mamba2-130m", family="ssm", num_layers=24, d_model=768,
    num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=50_280,
    norm_kind="rmsnorm", tie_embeddings=True,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, n_groups=1,
                  conv_kernel=4, chunk_size=128),
)

SMOKE = FULL.scaled(num_layers=2, d_model=64, vocab_size=128,
                    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2,
                                  n_groups=1, conv_kernel=4, chunk_size=8))

register(FULL, SMOKE)
