"""nemotron-4-340b — dense, GQA kv=8, squared-ReLU MLP. [arXiv:2402.16819]"""
from repro.config import ModelConfig, register

FULL = ModelConfig(
    name="nemotron-4-340b", family="dense", num_layers=96, d_model=18_432,
    num_heads=96, num_kv_heads=8, d_ff=73_728, vocab_size=256_000,
    mlp_kind="relu2", norm_kind="layernorm", rope_theta=10_000.0,
)

SMOKE = FULL.scaled(num_layers=2, d_model=96, num_heads=8, num_kv_heads=2,
                    head_dim=12, d_ff=384, vocab_size=128)

register(FULL, SMOKE)
