"""grok-1-314b — MoE 8 experts top-2, GQA kv=8. [hf:xai-org/grok-1]

Gated MLP (3 matrices): with d_ff=32768 this yields ~316B params, matching the
advertised 314B; a non-gated MLP would undercount at ~213B."""
from repro.config import MoEConfig, ModelConfig, register

FULL = ModelConfig(
    name="grok-1-314b", family="moe", num_layers=64, d_model=6144,
    num_heads=48, num_kv_heads=8, d_ff=32_768, vocab_size=131_072,
    mlp_kind="geglu", norm_kind="rmsnorm",
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25),
)

SMOKE = FULL.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                    head_dim=16, d_ff=128, vocab_size=128,
                    moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=1.5))

register(FULL, SMOKE)
