"""Self-healing training runtime: NaN/divergence guard, loss-spike rollback,
hang watchdog (docs/DESIGN.md §8).

Checkpointing + supervised restart (runtime/fault.py, checkpoint/manager.py)
survive *process death*; this module covers the other failure class that
kills week-long runs — numerical blow-ups and silently hung steps — with
three escalating defenses:

1. **In-graph skip-update guard** (``optim/adamw.guard_predicate``, wired by
   ``train/step.build_train_step(guard=...)`` and
   ``parallel/pipeline.build_pipeline_train_step(guard=...)``): the jitted
   optimizer step computes one scalar ``update_ok`` — all grads finite
   (read off the global-norm reduction the clip already performs) AND no
   norm spike vs the EWMA carried in ``AdamState.gnorm_ewma`` — and applies
   AdamW under a ``jax.lax.cond``.  A poison microbatch costs a no-op step
   (state bit-unchanged, step counter frozen), never a crash or a retrace;
   metrics gain ``update_ok`` / ``update_skipped`` / ``nonfinite``.  The
   paper's mini-batch-as-relocatable-unit framing is what makes "skip the
   poison microbatch and keep going" a legal recovery action.

2. **Loss-spike rollback** (:class:`TrainingGuard`): the train loop feeds
   every synced per-step loss to a pure-Python EWMA tracker; ``patience``
   consecutive spiking losses (or ``skip_cap`` consecutive in-graph skips)
   raise :class:`DivergenceError` carrying the poisoned window.
   ``run_supervised`` (runtime/fault.py) reacts by fencing the writer
   group, *retiring* published checkpoints newer than the first poisoned
   step (``CheckpointManager.retire_steps_after``) and publishing the
   poisoned data indices to a ``blocklist.json`` sidecar — the restarted
   incarnation's iterator (:func:`blocklisted_stream`) then skips those
   batches, so the recovered trajectory is bit-identical to a clean run
   that never saw them (seekable ``data/synthetic.batch_at`` makes this
   exact and testable, tests/_mp/check_guard.py).

3. **Hang watchdog** (:class:`Watchdog`): a daemon thread the loop arms at
   the top of each step and disarms when the step's loss syncs.  A step
   exceeding ``hang_timeout`` trips the watchdog — ``check()`` then raises
   :class:`HangError` (an ordinary supervised incarnation death), and an
   optional ``on_hang`` escalation callback fires *during* the hang (on a
   real fleet: page + kill the pod; in the subprocess test: ``os._exit``).

Blocklist protocol: ``blocklist.json`` lives next to the manager's step
directories and is published atomically (``.tmp`` + ``os.replace``) with
merge-on-write semantics, so repeated incidents accumulate.  Blocklisted
values are DATA indices (``batch_at`` arguments), not loop steps: loop step
``s`` of a blocklist-aware run consumes data index :func:`data_index`\\
``(s, blocklist)`` — the s-th non-blocklisted index.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Callable, Iterable, Iterator, List, Optional, Sequence

BLOCKLIST = "blocklist.json"


class DivergenceError(RuntimeError):
    """Training diverged: a sustained loss spike or too many consecutive
    skipped updates.  Carries everything the supervisor's rollback policy
    needs: ``first_step`` (the first poisoned LOOP step — checkpoints newer
    than it are poisoned and must be retired), ``data_indices`` (the
    poisoned ``batch_at`` indices to blocklist) and ``rollback`` (the
    GuardConfig policy bit)."""

    def __init__(self, msg: str, *, kind: str, first_step: int,
                 data_indices: Sequence[int], rollback: bool = True):
        super().__init__(msg)
        self.kind = kind                          # "loss_spike" | "skip_cap"
        self.first_step = first_step
        self.data_indices = tuple(data_indices)
        self.rollback = rollback


class HangError(RuntimeError):
    """A training step exceeded the watchdog's ``hang_timeout``.  Retryable:
    ``run_supervised`` fences the writer group and restarts from the last
    published checkpoint like any other incarnation death."""

    def __init__(self, step: int, elapsed: float, timeout: float):
        super().__init__(
            f"step {step} hung: {elapsed:.3f}s exceeds hang_timeout="
            f"{timeout:.3f}s")
        self.step = step
        self.elapsed = elapsed
        self.timeout = timeout


# ---------------------------------------------------------------------------
# Loss-spike / skip-cap tracking (pure Python, loop side)
# ---------------------------------------------------------------------------

class TrainingGuard:
    """Escalation layer above the in-graph guard: watches the synced
    per-step loss and the ``update_skipped`` metric, raises
    :class:`DivergenceError` on sustained divergence.

    Mirrors ``StepTimer``'s freeze-while-anomalous EWMA: spiking losses are
    NOT folded into the baseline (a sustained spike must not normalize
    itself), and a healthy step resets the streak.  Non-finite losses count
    as spikes unconditionally — the in-graph guard keeps non-finite grads
    out of the *state*, but the loss metric itself can still be NaN."""

    def __init__(self, gcfg):
        self.gcfg = gcfg
        self.loss_ewma: Optional[float] = None
        self.spike_streak = 0
        self.skip_streak = 0
        self._spike_window: List[tuple] = []      # (loop_step, data_index)
        self._skip_window: List[tuple] = []
        self.events: List[str] = []

    def observe(self, step: int, loss: float, metrics=None,
                data_index: Optional[int] = None):
        """Feed one completed step.  Raises :class:`DivergenceError` when
        the spike streak reaches ``patience`` or the skip streak reaches
        ``skip_cap``."""
        g = self.gcfg
        di = step if data_index is None else data_index
        skipped = bool(metrics is not None
                       and float(metrics.get("update_skipped", 0.0)) >= 0.5)
        if skipped:
            self.skip_streak += 1
            self._skip_window.append((step, di))
            if self.skip_streak >= g.skip_cap:
                self._raise("skip_cap", self._skip_window,
                            f"{self.skip_streak} consecutive updates "
                            f"skipped in-graph (skip_cap={g.skip_cap})")
            # a skipped step's loss is untrusted (often NaN); don't let it
            # touch the loss EWMA or the spike streak either way
            return
        self.skip_streak = 0
        self._skip_window.clear()

        finite = loss == loss and abs(loss) != float("inf")
        if self.loss_ewma is None:
            if finite:
                self.loss_ewma = loss             # first healthy loss seeds
            return
        spiking = (not finite) or loss > g.loss_spike_factor * self.loss_ewma
        if spiking:
            self.spike_streak += 1
            self._spike_window.append((step, di))
            if self.spike_streak >= g.patience:
                self._raise("loss_spike", self._spike_window,
                            f"loss {loss:.4f} spiked >"
                            f"{g.loss_spike_factor}x ewma "
                            f"{self.loss_ewma:.4f} for "
                            f"{self.spike_streak} consecutive steps "
                            f"(patience={g.patience})")
            return                                # EWMA frozen while spiking
        self.spike_streak = 0
        self._spike_window.clear()
        a = g.loss_ewma_alpha
        self.loss_ewma = (1 - a) * self.loss_ewma + a * loss

    def _raise(self, kind: str, window: List[tuple], why: str):
        first_step = window[0][0]
        indices = [di for _, di in window]
        self.events.append(f"{kind} at step {first_step}: {why}")
        raise DivergenceError(
            f"divergence ({kind}) first poisoned step {first_step}, "
            f"data indices {indices}: {why}",
            kind=kind, first_step=first_step, data_indices=indices,
            rollback=self.gcfg.rollback)


# ---------------------------------------------------------------------------
# Hang watchdog
# ---------------------------------------------------------------------------

class Watchdog:
    """Per-step hang detector (the ``hang_timeout`` heartbeat
    runtime/fault.py's contract promises).

    The loop calls :meth:`arm` at the top of a step and :meth:`disarm` +
    :meth:`check` once the step's loss has synced.  A daemon thread wakes
    every ``poll`` seconds; when an armed step's age exceeds ``timeout`` it
    records the trip and fires ``on_hang(step, elapsed)`` — the escalation
    hook for hangs that never return (a real deployment kills the pod; the
    subprocess test ``os._exit``\\ s).  For hangs that DO eventually return
    (stalled collective that times out, GC pause), :meth:`check` raises
    :class:`HangError` on the training thread — an ordinary supervised
    death, fenced and restarted by ``run_supervised``.

    One watchdog serves a whole supervised run: :meth:`check` clears the
    trip, so the next incarnation starts clean."""

    def __init__(self, timeout: float, *,
                 on_hang: Optional[Callable[[int, float], None]] = None,
                 poll: float = 0.02,
                 clock: Callable[[], float] = time.monotonic):
        assert timeout > 0.0, f"hang_timeout={timeout} must be > 0"
        self.timeout = timeout
        self.on_hang = on_hang
        self.poll = poll
        self.clock = clock
        self._lock = threading.Lock()
        self._armed_step: Optional[int] = None
        self._armed_at = 0.0
        self._trip: Optional[HangError] = None
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._watch, name="watchdog",
                                        daemon=True)
        self._thread.start()

    def arm(self, step: int):
        with self._lock:
            self._armed_step = step
            self._armed_at = self.clock()

    def disarm(self):
        with self._lock:
            self._armed_step = None

    def check(self):
        """Raise (and clear) a pending :class:`HangError`."""
        with self._lock:
            trip, self._trip = self._trip, None
        if trip is not None:
            raise trip

    @property
    def tripped(self) -> bool:
        with self._lock:
            return self._trip is not None

    def _watch(self):
        while not self._stop.wait(self.poll):
            fire = None
            with self._lock:
                if (self._armed_step is not None and self._trip is None):
                    elapsed = self.clock() - self._armed_at
                    if elapsed > self.timeout:
                        self._trip = HangError(self._armed_step, elapsed,
                                               self.timeout)
                        fire = (self._armed_step, elapsed)
                        self._armed_step = None   # one trip per arm
            if fire is not None and self.on_hang is not None:
                self.on_hang(*fire)

    def close(self):
        self._stop.set()
        self._thread.join(timeout=5)


# ---------------------------------------------------------------------------
# Blocklist sidecar (published next to the checkpoint manifests)
# ---------------------------------------------------------------------------

def blocklist_path(directory: str) -> str:
    return os.path.join(directory, BLOCKLIST)


def load_blocklist(directory: Optional[str]) -> List[int]:
    """Sorted poisoned data indices, or [] (missing dir/file/torn json all
    mean "nothing blocklisted" — same tolerant-listing stance as
    ``all_steps``)."""
    if not directory:
        return []
    try:
        with open(blocklist_path(directory)) as f:
            return sorted({int(i) for i in json.load(f)["data_indices"]})
    except (OSError, ValueError, KeyError, TypeError):
        return []


def publish_blocklist(directory: str, data_indices: Iterable[int]
                      ) -> List[int]:
    """Merge ``data_indices`` into the sidecar and publish atomically
    (``.tmp`` + ``os.replace``, the manifest-publish idiom) so a reader
    never observes a torn blocklist.  Returns the merged sorted list."""
    merged = sorted(set(load_blocklist(directory)) | {int(i) for i in
                                                      data_indices})
    os.makedirs(directory, exist_ok=True)
    tmp = blocklist_path(directory) + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"data_indices": merged}, f, sort_keys=True)
    os.replace(tmp, blocklist_path(directory))
    return merged


def data_index(step: int, blocklist: Sequence[int]) -> int:
    """Loop step -> data index under a blocklist: step ``s`` consumes the
    s-th NON-blocklisted index.  Identity for an empty blocklist; exact
    inverse of dropping the blocklisted batches from a clean stream, which
    is what makes rollback-resume bit-comparable to a clean filtered run."""
    idx = step
    for b in sorted(set(blocklist)):
        if b <= idx:
            idx += 1
    return idx


def blocklisted_stream(batch_at: Callable[[int], dict], start_step: int,
                       blocklist: Sequence[int]) -> Iterator[dict]:
    """Seekable data stream for a (restarted) blocklist-aware run: yields
    ``batch_at(data_index(s, blocklist))`` for ``s = start_step, ...``."""
    bl = sorted(set(blocklist))
    s = start_step
    while True:
        yield batch_at(data_index(s, bl))
        s += 1
