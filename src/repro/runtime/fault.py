"""Fault tolerance & straggler mitigation runtime.

What "fault tolerant at 1000+ nodes" means for this framework, and what is
implemented (all exercised by tests/test_fault.py and examples/elastic_restart.py):

1. **Checkpoint/restart** — training state is periodically saved atomically
   (checkpoint/manager.py); the loop (train/loop.py) is a pure function of
   (state, step), and the data pipeline is seekable (data/synthetic.batch_at),
   so a restart resumes bit-exact from the last checkpoint.  Saves are
   *asynchronous* by default (AsyncCheckpointManager: host-arena snapshot on
   the step boundary, persistence in the background) and *multi-writer*
   (a writer group of N logical writers — one per pipeline stage/pod —
   persists disjoint shard sets with per-shard checksums; a coordinator
   publishes the step's global manifest only after a quorum of partial
   manifests verified with full shard coverage, docs/DESIGN.md §7).  With
   ``CheckpointConfig.writer_procs`` each logical writer is its own OS
   PROCESS (runtime/procs.py, docs/DESIGN.md §9): heartbeat leases detect
   crashed (``kill -9``), hung (SIGSTOP → SIGKILL fence) and slow writers,
   and a dead writer's shard range is REASSIGNED to a survivor before the
   quorum gate — a single writer death degrades the save instead of
   tearing it, with QuorumError as the backstop.  The supervisor must
   still fence the WHOLE writer group on failure: ``run_supervised(ckpt=
   ...)`` calls ``ckpt.abort()`` when an incarnation dies, which discards
   queued snapshots from the dead incarnation, interrupts every in-flight
   writer between shards (SIGKILL + reap for process writers), and sweeps
   torn-step debris (``step_K.tmp``, sub-quorum step dirs, ``.fleet``
   scratch) — a restart only ever restores a quorum-published step, and
   restore checksum-verifies every shard before ``device_put``
   (``FailureInjector.check_writer`` injects a thread-writer death inside
   the torn window, ``FailureInjector.proc_fault`` injects process-level
   kill9/sigstop/slow/corrupt faults, to prove this).  Restore keeps the
   elastic re-sharding path (point 3) untouched.

2. **Failure detection** — ``runtime/guard.Watchdog`` is the per-step hang
   detector: the train loop arms it at the top of each step and disarms once
   the step's loss syncs; a step exceeding ``GuardConfig.hang_timeout`` trips
   the watchdog thread, whose ``check()`` raises ``HangError`` — an ordinary
   retryable incarnation death the supervisor fences and restarts (an
   optional ``on_hang`` callback escalates hangs that never return).
   Numerical failure is detected one layer deeper: the jitted step's
   ``update_ok`` guard skips non-finite / norm-spiking updates in-graph,
   and ``runtime/guard.TrainingGuard`` raises ``DivergenceError`` on a
   sustained loss spike or skip streak (docs/DESIGN.md §8).  FailureInjector
   simulates chip/host failures deterministically for tests.

3. **Elastic rescale** — on restart with a different device count (node lost /
   replaced), checkpoints restore with *target-mesh* shardings (global arrays
   re-sharded at device_put).  The data axis shrinks/grows; microbatching is
   re-planned (core/schedule.choose_microbatches) so the global batch and thus
   the training trajectory semantics are preserved.

4. **Straggler mitigation & divergence rollback** — StepTimer keeps an EWMA
   of step latency per incarnation (the first ``warmup_steps`` samples are
   discarded: a JIT-compile step is ~100x steady state and would poison the
   baseline); sustained outliers (> ``straggler_factor`` x EWMA) trigger a
   rebalance callback that remaps data shards away from the slow host
   (simulated + unit-tested policy) — the TPU analogue of the paper's
   mini-batch re-scheduling freedom: mini-batches are the minimal execution
   units and can be reassigned between dies/hosts.  The same relocatability
   powers the rollback policy: when an incarnation dies of
   ``DivergenceError``, ``run_supervised`` retires published checkpoints
   newer than the first poisoned step and publishes the poisoned data
   indices to ``blocklist.json`` (runtime/guard.py), so the restarted
   incarnation's iterator drops those mini-batches and the recovered
   trajectory is bit-identical to a clean run that never saw them
   (docs/DESIGN.md §8).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.runtime.guard import DivergenceError, publish_blocklist


class FailureInjector:
    """Deterministically fail at given steps (simulated node failures).

    ``fail_at`` maps step -> failure kind and kills the whole incarnation at
    the top of that step (:meth:`check`, called by the train loop).

    ``writer_fail_at`` maps step -> writer index and kills ONE logical
    checkpoint writer (:meth:`check_writer`) — the train loop wires this as
    the manager's ``writer_fault`` hook, which fires between a writer's
    shard writes and its partial-manifest publish: the torn-step window the
    quorum publish protocol exists for (checkpoint/manager.py).

    ``proc_fail_at`` maps step -> (writer, kind) and injects a PROCESS-level
    fault into that writer of the cross-process fleet (:meth:`proc_fault`,
    wired as the manager's ``proc_fault`` hook; runtime/procs.py executes
    the spec in the child, inside the same torn window).  Kinds:
    ``kill9`` (SIGKILL self), ``sigstop`` (hang until the lease fences it),
    ``slow`` (sleep with heartbeats flowing — must NOT be killed) and
    ``corrupt`` (truncate a shard after checksumming — the disk-verified
    gate must reject it).  A third tuple element, if given, is a dict of
    extra spec fields (e.g. ``{"seconds": 2.0}`` for ``slow``).
    """

    PROC_KINDS = ("kill9", "sigstop", "slow", "corrupt")

    def __init__(self, fail_at: Optional[Dict[int, str]] = None,
                 writer_fail_at: Optional[Dict[int, int]] = None,
                 proc_fail_at: Optional[Dict[int, tuple]] = None):
        self.fail_at = dict(fail_at or {})
        self.writer_fail_at = dict(writer_fail_at or {})
        self.proc_fail_at = dict(proc_fail_at or {})
        for spec in self.proc_fail_at.values():
            assert spec[1] in self.PROC_KINDS, (
                f"proc fault kind {spec[1]!r} not in {self.PROC_KINDS}")
        self.log: List[str] = []

    def check(self, step: int):
        if step in self.fail_at:
            kind = self.fail_at.pop(step)
            self.log.append(f"step {step}: injected {kind}")
            raise RuntimeError(f"injected failure: {kind} at step {step}")

    def check_writer(self, step: int, writer: int):
        """Writer-fault hook: raises inside writer ``writer`` of the save of
        ``step``, after its shards are on disk but before its partial
        manifest publishes.  One-shot per step (like :meth:`check`)."""
        if self.writer_fail_at.get(step) == writer:
            del self.writer_fail_at[step]
            self.log.append(f"step {step}: injected writer {writer} death")
            raise RuntimeError(
                f"injected failure: checkpoint writer {writer} died at step "
                f"{step} (post shard-write, pre manifest-publish)")

    def proc_fault(self, step: int, writer: int) -> Optional[Dict]:
        """Process-fleet fault hook: returns the fault SPEC (dict) for the
        fleet to execute inside writer ``writer``'s child process during the
        save of ``step`` — the coordinator cannot raise on the child's
        behalf, it can only ship instructions (runtime/procs.inject_fault).
        One-shot per step, mirroring :meth:`check_writer`."""
        spec = self.proc_fail_at.get(step)
        if spec is None or spec[0] != writer:
            return None
        del self.proc_fail_at[step]
        kind = spec[1]
        extra = dict(spec[2]) if len(spec) > 2 else {}
        self.log.append(
            f"step {step}: injected proc fault {kind} into writer {writer}")
        return {"kind": kind, **extra}


@dataclass
class StepTimer:
    """EWMA step-latency tracker with straggler detection.

    The first ``warmup_steps`` samples are DISCARDED, not folded: step 0 is
    JIT-compile dominated (often 100x steady state), and seeding the EWMA
    with it would mask real stragglers for a long decay window (a genuinely
    2.5x-slow step compares against a ~100x baseline).  The EWMA seeds from
    the first post-warmup sample."""
    alpha: float = 0.1
    straggler_factor: float = 2.5
    patience: int = 3
    warmup_steps: int = 1
    ewma: Optional[float] = None
    slow_streak: int = 0
    _seen: int = 0
    events: List[str] = field(default_factory=list)

    def record(self, dt: float) -> bool:
        """Returns True when a sustained straggler is detected."""
        if self._seen < self.warmup_steps:
            self._seen += 1
            return False                # compile-dominated: discard
        if self.ewma is None:
            self.ewma = dt
            return False
        is_slow = dt > self.straggler_factor * self.ewma
        self.slow_streak = self.slow_streak + 1 if is_slow else 0
        if not is_slow:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        if self.slow_streak >= self.patience:
            self.events.append(
                f"straggler: {dt:.3f}s vs ewma {self.ewma:.3f}s "
                f"x{self.slow_streak}")
            self.slow_streak = 0
            return True
        return False


@dataclass
class Incarnation:
    """One supervised attempt; killed and replaced on failure."""
    index: int
    start_step: int


NON_RETRYABLE = (KeyboardInterrupt, AssertionError)


def run_supervised(make_state: Callable[[Optional[int]], tuple],
                   run_steps: Callable,
                   *, max_restarts: int = 5,
                   on_restart: Optional[Callable[[Incarnation], None]] = None,
                   ckpt=None,
                   backoff_base: float = 0.5, backoff_cap: float = 30.0,
                   sleep_fn: Callable[[float], None] = time.sleep):
    """Supervisor loop: (re)build state from the latest checkpoint and run.

    ``make_state(step|None) -> (state, start_step)`` restores or cold-starts.
    ``run_steps(state, start_step, incarnation) -> final_state`` raises on
    failure (real or injected).  Returns (final_state, incarnations_used).

    **What is supervised**: any ``Exception`` — not just ``RuntimeError``
    (injected/jax runtime faults) but also ``OSError`` from a dead
    filesystem under the checkpoint directory; at 1000-node scale those are
    routine incarnation deaths, not operator bugs.  ``KeyboardInterrupt``
    and ``AssertionError`` (:data:`NON_RETRYABLE`) propagate immediately:
    the first is the operator, the second is an invariant violation that a
    restart would just re-trip.

    **Backoff**: restarts wait ``min(backoff_cap, backoff_base * 2**k)``
    (k = prior failures) instead of hot-looping — a crash loop against a
    recovering filesystem or a flapping host must not burn the cluster.
    ``sleep_fn`` is injectable for tests.

    ``ckpt`` (optional, the run's CheckpointManager) lets the supervisor
    fence asynchronous persistence: when an incarnation dies, ``ckpt.abort()``
    runs BEFORE ``make_state`` rebuilds — the WHOLE writer group is fenced
    (queued snapshots dropped, every in-flight writer interrupted between
    shards, torn-step debris swept), so the restart restores only a
    quorum-published step and never a half-written one.

    **Rollback policy** (docs/DESIGN.md §8): a ``DivergenceError`` with
    ``rollback=True`` additionally *retires* published checkpoints newer
    than the first poisoned step (``ckpt.retire_steps_after``) — they were
    saved from already-poisoned state — and publishes the poisoned data
    indices to the ``blocklist.json`` sidecar next to the manifests, so the
    restarted incarnation's data iterator (``guard.blocklisted_stream``)
    skips those batches.  Both hooks are looked up dynamically so fakes and
    managers without a directory still supervise cleanly.

    **Resume-step pinning**: after the fence (and the rollback retire, when
    one ran), the supervisor reads ``ckpt.latest_step()`` ONCE and passes
    that exact step to ``make_state`` — the restore target is decided at
    fence time, under the post-abort/post-retire view of the directory,
    so a concurrent lister/GC between fence and restore cannot move the
    resume point.  The first (cold-start) incarnation, and managers/fakes
    without ``latest_step``, still get ``None`` (restore-latest-or-init).
    """
    restarts = 0
    resume_step = None
    while True:
        state, start = make_state(resume_step)
        inc = Incarnation(index=restarts, start_step=start)
        if on_restart and restarts:
            on_restart(inc)
        try:
            return run_steps(state, start, inc), restarts + 1
        except BaseException as e:
            if isinstance(e, NON_RETRYABLE) or not isinstance(e, Exception):
                raise                 # operator interrupt / invariant bug
            restarts += 1
            if ckpt is not None:
                ckpt.abort()          # dead incarnation: fence writer group
                if (isinstance(e, DivergenceError)
                        and getattr(e, "rollback", False)):
                    # fence first, THEN retire: an in-flight save of a
                    # poisoned step must not land after the rollback
                    retire = getattr(ckpt, "retire_steps_after", None)
                    if retire is not None:
                        retire(e.first_step)
                    d = getattr(ckpt, "dir", None)
                    if d:
                        publish_blocklist(d, e.data_indices)
                # pin the restore target now, post-fence/post-retire
                latest = getattr(ckpt, "latest_step", None)
                resume_step = latest() if callable(latest) else None
            if restarts > max_restarts:
                raise RuntimeError(
                    f"exceeded {max_restarts} restarts; last error: {e}")
            sleep_fn(min(backoff_cap, backoff_base * 2 ** (restarts - 1)))


def rebalance_data_shards(num_hosts: int, slow_hosts: List[int],
                          shards_per_host: Optional[List[int]] = None
                          ) -> List[int]:
    """Straggler-mitigation policy: move one data shard from each sustained
    straggler to the currently least-loaded healthy host.  Pure + unit-tested;
    the launcher applies the returned assignment on the next step boundary
    (mini-batches are the paper's relocatable execution units)."""
    shards = list(shards_per_host or [1] * num_hosts)
    for s in slow_hosts:
        if shards[s] <= 0:
            continue
        healthy = [h for h in range(num_hosts) if h not in slow_hosts]
        if not healthy:
            break
        tgt = min(healthy, key=lambda h: shards[h])
        shards[s] -= 1
        shards[tgt] += 1
    return shards
