"""Cross-process checkpoint writer fleet (docs/DESIGN.md §9).

PR 6 made every checkpoint save a *writer group*: N logical writers persist
disjoint shard sets and a coordinator publishes only after a disk-verified
quorum with full coverage.  But those writers were threads — one ``kill -9``
took out the whole group, which is exactly the failure model Hecaton's
per-pod controllers must survive.  This module runs each logical writer as
its own OS process against shared storage, with the liveness + work-
reassignment layer that turns "one writer died → torn step → restart" into
"one writer died → degraded save still publishes with full coverage".

The on-disk protocol is UNCHANGED (``writer_NN/`` shards + partial
manifests, ``checkpoint/wire.py`` is the shared format module): a tree
published by the fleet is bit-identical to one published by the thread
writers, and the coordinator's quorum gate / restore verification
(``checkpoint/manager.py``) stay the single authority on what publishes.

Protocol (docs/DESIGN.md §9 for the proof obligations):

  * **Spawn**: ``WriterFleet`` forks one child per writer slot via the
    ``spawn`` context (no inherited jax/runtime state; children import only
    numpy + ``checkpoint/wire``).  The fleet is persistent across saves —
    spawn cost is paid once, not per boundary.
  * **Handover**: per save, the coordinator packs every leaf's wire bytes
    into one contiguous arena — a ``multiprocessing.shared_memory`` segment
    when available, a spill file under ``<ckpt_dir>/.fleet/`` otherwise
    (``REPRO_CKPT_HANDOVER=spill`` forces the fallback) — and sends each
    child its task: writer identity, shard names, and (offset, nbytes,
    wire dtype/shape) views into the arena.  Children never see pytrees,
    device buffers, or ml_dtypes values.  The arena is PERSISTENT and
    grow-only: allocated on the first save, reused (never unlinked)
    across saves, so the steady-state handover is one warm memcpy —
    first-touch page faults on a fresh segment cost ~100x the copy
    itself and are paid once, not per boundary (the
    ``ckpt_multiwriter_procs_*`` bench rows gate this at <= 1.3x the
    thread-writer save).
  * **Heartbeat leases**: each child runs a daemon thread that bumps a
    sequence token into ``.fleet/hb_NN`` (tmp + ``os.replace``) every
    ``hb_interval``; the coordinator-side :class:`LeaseTable` treats a
    *token change* as progress, timed against the COORDINATOR's monotonic
    clock — no cross-process clock comparison.  A slot whose token does not
    advance within ``timeout`` is hung (``SIGSTOP``, a wedged filesystem
    call): the coordinator SIGKILL-fences it and treats its work as failed.
    A slot whose process has exited (nonzero exit, ``kill -9``) fails
    immediately; a slot that heartbeats but exceeds ``timeout`` without
    replying is merely *slow* — recorded in ``events``, never killed.
  * **Orphan-shard reassignment**: a failed writer's shard range is wiped
    (``writer_NN/`` may hold torn shards) and re-dispatched to a surviving
    child, which rewrites it UNDER THE ORIGINAL writer identity — the
    published tree is indistinguishable from one where that writer lived
    (modulo the global manifest's ``reassigned`` record).  Reassignment is
    bounded by the ``reassign`` budget per save; when the budget or the
    fleet is exhausted, the writer stays failed and the quorum gate decides
    (QuorumError is the backstop, exactly as before).  A writer's partial
    manifest must pass the coordinator's disk verification (the ``verify``
    callback) to count as committed — a writer that *corrupts* a shard
    after checksumming it is detected and reassigned like a dead one.
  * **Fence**: :meth:`WriterFleet.fence` SIGKILLs every child (SIGKILL
    lands on SIGSTOPped processes too), reaps them, and removes heartbeat
    + arena scratch; an in-flight :meth:`run_save` observes the fence and
    raises :class:`FleetAborted`.  ``CheckpointManager.abort`` fences the
    fleet before sweeping ``.tmp`` debris, so a restart never races a
    half-dead fleet.  Children detect a SIGKILLed *coordinator* themselves:
    the heartbeat thread exits the process when ``os.getppid`` changes, so
    orphans stop writing within one heartbeat interval and the next
    incarnation's ``_clean_stale_tmp`` sweeps ``.fleet`` and ``step_*.tmp``
    debris before restoring.
"""

from __future__ import annotations

import multiprocessing as mp
import os
from multiprocessing import connection as mp_connection
import shutil
import signal
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint import wire

FLEET_DIR = ".fleet"                 # scratch under the checkpoint root
_SPAWN_WAIT = 60.0                   # cap on waiting for a child's 1st beat
_ORPHAN_EXIT = 3                     # child exit code: coordinator vanished


class FleetAborted(Exception):
    """An in-flight fleet save was interrupted by a fence/abort."""


class FleetError(RuntimeError):
    """The fleet itself is unusable (spawn failed, every child dead)."""


# ---------------------------------------------------------------------------
# heartbeat files (child writes, coordinator reads)
# ---------------------------------------------------------------------------

def _beat(path: str, pid: int, seq: int):
    tmp = f"{path}.{pid}.tmp"
    with open(tmp, "w") as f:
        f.write(f"{pid} {seq}")
    os.replace(tmp, path)


def read_heartbeat(path: str) -> Optional[Tuple[int, int]]:
    """(pid, seq) or None — unreadable/garbled means "no beat yet" (the
    lease, not the parser, decides liveness)."""
    try:
        with open(path) as f:
            pid_s, seq_s = f.read().split()
        return int(pid_s), int(seq_s)
    except (OSError, ValueError):
        return None


class LeaseTable:
    """Coordinator-side liveness ledger: token-change-as-progress.

    ``observe(slot, token, now)`` records the current heartbeat token for a
    slot; the lease clock for that slot resets only when the token CHANGES.
    ``expired(slot, now)`` is True once ``timeout`` of coordinator-monotonic
    time passes without a token change — no cross-process clock is ever
    compared, so coordinator/child clock skew cannot forge or break a lease.
    ``start`` opens a lease at dispatch time (a child that never beats at
    all must still expire).  Pure (callers supply ``now``) so the property
    tests drive arbitrary schedules through it (tests/test_properties.py).
    """

    def __init__(self, timeout: float):
        assert timeout > 0, f"lease timeout={timeout} must be > 0"
        self.timeout = timeout
        self._last: Dict[int, Tuple[Any, float]] = {}

    def start(self, slot: int, now: float):
        self._last.setdefault(slot, (None, now))

    def observe(self, slot: int, token: Any, now: float):
        cur = self._last.get(slot)
        if cur is None or cur[0] != token:
            self._last[slot] = (token, now)

    def expired(self, slot: int, now: float) -> bool:
        cur = self._last.get(slot)
        return cur is not None and (now - cur[1]) > self.timeout

    def drop(self, slot: int):
        self._last.pop(slot, None)


# ---------------------------------------------------------------------------
# snapshot handover arena (coordinator packs, children attach read-only)
# ---------------------------------------------------------------------------

class _Arena:
    """One contiguous byte region both sides can map.  Owned by the fleet
    and reused across saves (grow-only) — fresh segments pay first-touch
    page faults worth ~100x the warm memcpy."""

    def __init__(self, kind: str, ref: str, buf, owner):
        self.kind = kind          # "shm" | "spill"
        self.ref = ref            # shm name | spill file path
        self.buf = buf            # writable memoryview (coordinator side)
        self.capacity = len(buf)
        self._owner = owner       # SharedMemory | file descriptor int

    def handle(self) -> Tuple[str, str]:
        return (self.kind, self.ref)

    def close(self):
        try:
            if self.kind == "shm":
                self.buf.release()
                self._owner.close()
                self._owner.unlink()
            else:
                self.buf.release()
                os.close(self._owner)
                os.unlink(self.ref)
        except (OSError, BufferError, ValueError):
            pass                  # already fenced/swept


def make_arena(total: int, scratch: str, handover: str) -> _Arena:
    """Create an arena: shared memory preferred, spill file under
    ``scratch`` when shm is unavailable or ``handover="spill"``."""
    if handover != "spill":
        try:
            from multiprocessing import shared_memory
            seg = shared_memory.SharedMemory(create=True, size=max(1, total))
            return _Arena("shm", seg.name, seg.buf, seg)
        except (ImportError, OSError):
            pass                  # no /dev/shm etc — spill below
    path = os.path.join(scratch, f"handover_{os.getpid()}_{time.time_ns()}")
    fd = os.open(path, os.O_CREAT | os.O_RDWR | os.O_EXCL, 0o600)
    os.ftruncate(fd, max(1, total))
    import mmap
    m = mmap.mmap(fd, max(1, total))
    return _Arena("spill", path, memoryview(m), fd)


def attach_arena(handle: Tuple[str, str]):
    """Child side: map the arena read-only; returns (closer, buffer)."""
    kind, ref = handle
    if kind == "shm":
        from multiprocessing import shared_memory
        # NOTE: attach re-registers the segment with the resource tracker,
        # but spawn children share the coordinator's tracker process and its
        # cache is a set — the duplicate collapses, and the coordinator's
        # unlink clears it.  An explicit child-side unregister would double-
        # remove and make the tracker log KeyErrors.
        seg = shared_memory.SharedMemory(name=ref)
        return seg.close, seg.buf
    mm = np.memmap(ref, dtype=np.uint8, mode="r")
    return (lambda: None), memoryview(mm)


# ---------------------------------------------------------------------------
# child process
# ---------------------------------------------------------------------------

def inject_fault(spec: Dict[str, Any], wdir: str, shards: Dict[str, Dict]):
    """Execute an injected process-level fault inside the torn window (shards
    on disk, partial manifest unpublished) — ``runtime/fault.FailureInjector``
    builds the spec on the coordinator, this runs it in the child:

      kill9    SIGKILL self: the crashed-writer path (no exit handlers run).
      sigstop  SIGSTOP self: the hung-writer path — the heartbeat thread
               freezes with the process, the lease expires, the coordinator
               SIGKILL-fences us.
      slow     sleep ``seconds`` with heartbeats still flowing: must NOT be
               killed, only logged as slow.
      corrupt  truncate the last shard by one byte AFTER its checksum was
               recorded, then publish normally: the coordinator's disk
               verification must reject the partial (the shard's on-disk
               length no longer matches) and reassign.
    """
    kind = spec.get("kind")
    if kind == "kill9":
        os.kill(os.getpid(), signal.SIGKILL)
    elif kind == "sigstop":
        os.kill(os.getpid(), signal.SIGSTOP)
    elif kind == "slow":
        time.sleep(float(spec.get("seconds", 1.0)))
    elif kind == "corrupt":
        if shards:
            last = sorted(shards)[-1]
            path = os.path.join(os.path.dirname(wdir), shards[last]["file"])
            with open(path, "r+b") as f:
                f.truncate(max(0, os.path.getsize(path) - 1))
    else:
        raise ValueError(f"unknown injected fault kind {kind!r}")


def run_writer_task(task: Dict[str, Any]) -> int:
    """Execute one writer assignment: materialize each arena view, persist
    the shards, run the fault hook in the torn window, publish the partial
    manifest.  Returns the shard count.  Identical bytes to the thread
    writer path — both sides lower through ``checkpoint/wire``."""
    closer, buf = attach_arena(task["arena"])
    try:
        wtag = f"writer_{task['writer']:02d}"
        wdir = os.path.join(task["tmp"], wtag)
        os.makedirs(wdir, exist_ok=True)
        shards: Dict[str, Dict] = {}
        for i, ent in enumerate(task["entries"]):
            view = buf[ent["offset"]:ent["offset"] + ent["nbytes"]]
            arr = np.frombuffer(view, dtype=np.dtype(ent["wire_dtype"])
                                ).reshape(ent["wire_shape"])
            nbytes, c = wire.write_leaf(
                os.path.join(wdir, f"leaf_{i:05d}.npy"), arr,
                durable=task["durable"])
            info = dict(ent["info"])
            info["bytes"] = nbytes
            info["crc32"] = c
            info["file"] = f"{wtag}/leaf_{i:05d}.npy"
            info["writer"] = task["writer"]
            shards[ent["name"]] = info
            del arr, view          # release arena refs before closer()
        # >>> shards on disk; partial manifest NOT yet published <<<
        if task.get("fault"):
            inject_fault(task["fault"], wdir, shards)
        wire.publish_partial(wdir, task["step"], task["writer"], shards,
                             durable=task["durable"])
        return len(shards)
    finally:
        closer()


def _writer_child_main(conn, parent_pid: int, hb_path: str,
                       hb_interval: float):
    """Child entrypoint: heartbeat daemon + serial task loop on the pipe.

    The heartbeat thread is also the orphan detector: when ``os.getppid()``
    stops matching the coordinator (it was SIGKILLed — no fence ran), the
    child hard-exits instead of writing into a directory the next
    incarnation is about to sweep."""
    def beat_loop():
        pid, seq = os.getpid(), 0
        while True:
            if os.getppid() != parent_pid:
                os._exit(_ORPHAN_EXIT)
            seq += 1
            try:
                _beat(hb_path, pid, seq)
            except OSError:
                pass               # scratch swept mid-beat: orphaned soon
            time.sleep(hb_interval)

    threading.Thread(target=beat_loop, daemon=True,
                     name="ckpt-heartbeat").start()
    while True:
        try:
            task = conn.recv()
        except (EOFError, OSError):
            os._exit(0)            # coordinator closed the pipe
        if task is None:
            os._exit(0)            # graceful shutdown
        try:
            n = run_writer_task(task)
            reply = ("ok", task["writer"], n)
        except BaseException as e:  # noqa: BLE001 — child must report, not die
            reply = ("err", task["writer"], f"{type(e).__name__}: {e}")
        try:
            conn.send(reply)
        except (OSError, BrokenPipeError):
            os._exit(0)


# ---------------------------------------------------------------------------
# coordinator
# ---------------------------------------------------------------------------

class WriterFleet:
    """Supervisor for one checkpoint directory's writer processes.

    One slot per logical writer; slots are respawned between saves, never
    during one (a mid-save respawn would race the step's arena lifetime —
    reassignment to a *surviving* slot covers the work instead)."""

    def __init__(self, directory: str, writers: int, *,
                 timeout: float = 5.0, reassign: int = 1,
                 hb_interval: Optional[float] = None,
                 handover: Optional[str] = None):
        assert writers >= 1, writers
        assert timeout > 0, timeout
        assert reassign >= 0, reassign
        self.dir = directory
        self.writers = writers
        self.timeout = timeout
        self.reassign = reassign
        self.hb_interval = (hb_interval if hb_interval is not None
                            else min(0.5, max(0.02, timeout / 10.0)))
        self.handover = (handover if handover is not None
                         else os.environ.get("REPRO_CKPT_HANDOVER", "shm"))
        self.events: List[str] = []
        self._ctx = mp.get_context("spawn")
        self._procs: Dict[int, Any] = {}
        self._conns: Dict[int, Any] = {}
        self._fenced = threading.Event()
        self._lock = threading.Lock()
        self._arena: Optional[_Arena] = None   # persistent, grow-only
        self._saving = False

    # -- lifecycle -----------------------------------------------------
    def _scratch(self) -> str:
        return os.path.join(self.dir, FLEET_DIR)

    def _hb_path(self, slot: int) -> str:
        return os.path.join(self._scratch(), f"hb_{slot:02d}")

    def _spawn_slot(self, slot: int):
        parent_conn, child_conn = self._ctx.Pipe()
        hb = self._hb_path(slot)
        try:
            os.remove(hb)
        except OSError:
            pass
        p = self._ctx.Process(
            target=_writer_child_main,
            args=(child_conn, os.getpid(), hb, self.hb_interval),
            name=f"ckpt-writer-{slot:02d}", daemon=True)
        p.start()
        child_conn.close()
        self._procs[slot] = p
        self._conns[slot] = parent_conn

    def _reap_slot(self, slot: int):
        p = self._procs.pop(slot, None)
        conn = self._conns.pop(slot, None)
        if conn is not None:
            try:
                conn.close()
            except OSError:
                pass
        if p is not None:
            if p.exitcode is None:
                try:
                    os.kill(p.pid, signal.SIGKILL)
                except OSError:
                    pass
            p.join(timeout=10)
        try:
            os.remove(self._hb_path(slot))
        except OSError:
            pass

    def ensure_spawned(self):
        """Bring the fleet to full strength (full slots, first beat seen) —
        called at the top of every save, so a save after a fence or a slot
        death starts with a fresh fleet."""
        with self._lock:
            self._fenced.clear()
            for slot in range(self.writers):
                p = self._procs.get(slot)
                if p is None or p.exitcode is not None:
                    if p is not None:
                        self._reap_slot(slot)
                    os.makedirs(self._scratch(), exist_ok=True)
                    self._spawn_slot(slot)
            deadline = time.monotonic() + _SPAWN_WAIT
            for slot in range(self.writers):
                while read_heartbeat(self._hb_path(slot)) is None:
                    if self._procs[slot].exitcode is not None:
                        raise FleetError(
                            f"writer slot {slot} died during spawn "
                            f"(exit {self._procs[slot].exitcode})")
                    if time.monotonic() > deadline:
                        raise FleetError(
                            f"writer slot {slot} produced no heartbeat "
                            f"within {_SPAWN_WAIT}s of spawn")
                    time.sleep(0.01)

    def fence(self):
        """SIGKILL + reap every child and remove fleet scratch.  Safe from
        any thread; an in-flight :meth:`run_save` raises
        :class:`FleetAborted` at its next poll."""
        self._fenced.set()
        with self._lock:
            for slot in list(self._procs):
                self._reap_slot(slot)
            # a mid-save fence leaves the arena to run_save's own
            # exception path (its views may still be live in _pack)
            if not self._saving and self._arena is not None:
                self._arena.close()
                self._arena = None
            shutil.rmtree(self._scratch(), ignore_errors=True)

    def close(self):
        """Graceful shutdown: ask children to exit, then fence stragglers."""
        with self._lock:
            for slot, conn in list(self._conns.items()):
                try:
                    conn.send(None)
                except (OSError, BrokenPipeError):
                    pass
            for slot, p in list(self._procs.items()):
                p.join(timeout=10)
        self.fence()

    def alive_slots(self) -> List[int]:
        return [s for s, p in self._procs.items() if p.exitcode is None]

    # -- the save ------------------------------------------------------
    def _ensure_arena(self, total: int) -> _Arena:
        """Persistent handover arena: reuse while capacity suffices, grow
        by replacement otherwise.  Reuse is the whole perf story — the
        warm memcpy into mapped pages is ~100x cheaper than first-touch
        faulting a fresh segment every save."""
        a = self._arena
        if a is not None and a.capacity >= total:
            return a
        if a is not None:
            a.close()
            self._arena = None
        self._arena = make_arena(total, self._scratch(), self.handover)
        return self._arena

    def _drop_arena(self):
        if self._arena is not None:
            self._arena.close()
            self._arena = None

    def _pack(self, groups: List[List[str]],
              snap: Dict[str, np.ndarray],
              entries: List[List[Dict]],
              on_group: Optional[Callable[[int], None]] = None) -> _Arena:
        """Lower every leaf to wire form into the persistent arena.

        Appends writer ``w``'s entry list (carrying (offset, nbytes) arena
        views) into ``entries`` and calls ``on_group(w)`` the moment that
        slice is fully packed — the caller dispatches ``w`` while later
        groups are still copying, so the pack overlaps child I/O instead
        of preceding all of it."""
        wire_arrs: Dict[str, Tuple[np.ndarray, Dict]] = {}
        total = 0
        for g in groups:
            for name in g:
                wa, info = wire.leaf_wire(snap[name])
                wire_arrs[name] = (wa, info)
                total += wa.nbytes
        arena = self._ensure_arena(total)
        try:
            offset = 0
            for wi, g in enumerate(groups):
                ents = []
                for name in g:
                    wa, info = wire_arrs[name]
                    nb = wa.nbytes
                    if nb:
                        dst = np.frombuffer(arena.buf, dtype=np.uint8,
                                            count=nb, offset=offset)
                        # reshape BEFORE view: a 0-d leaf cannot change
                        # itemsize via .view, but its (1,) reshape can
                        np.copyto(dst, wa.reshape(-1).view(np.uint8))
                        del dst
                    ents.append({"name": name, "offset": offset,
                                 "nbytes": nb,
                                 "wire_dtype": str(wa.dtype),
                                 "wire_shape": list(wa.shape),
                                 "info": info})
                    offset += nb
                entries.append(ents)
                if on_group is not None:
                    on_group(wi)
        except BaseException:
            self._drop_arena()
            raise
        return arena

    def run_save(self, tmp: str, step: int, groups: List[List[str]],
                 snap: Dict[str, np.ndarray], *, durable: bool = False,
                 fault_for: Optional[Callable[[int, int],
                                              Optional[Dict]]] = None,
                 verify: Optional[Callable[[int], Any]] = None,
                 abort_check: Optional[Callable[[], bool]] = None,
                 ) -> Tuple[Dict[int, str], Dict[int, str]]:
        """Fan one save out over the fleet; supervise to completion.

        Returns ``(failures, reassigned)``: writers with no verified partial
        after the reassignment budget, and writers whose range WAS recovered
        (value = why the original owner failed).  Raises
        :class:`FleetAborted` on fence/abort, :class:`FleetError` when the
        whole fleet is gone mid-save."""
        self.ensure_spawned()
        self._saving = True       # fence defers arena teardown to us
        lease = LeaseTable(self.timeout)
        now = time.monotonic()
        pending: Dict[int, int] = {}        # writer -> slot running it
        dispatched_at: Dict[int, float] = {}
        failures: Dict[int, str] = {}
        reassigned: Dict[int, str] = {}
        slow_logged: set = set()
        budget = self.reassign
        entries: List[List[Dict]] = []      # filled group-by-group by _pack

        def dispatch(writer: int, slot: int, fault: Optional[Dict]):
            if self._fenced.is_set():
                raise FleetAborted(step)
            task = {"step": step, "tmp": tmp, "writer": writer,
                    "durable": durable, "arena": self._arena.handle(),
                    "entries": entries[writer], "fault": fault}
            self._conns[slot].send(task)
            pending[writer] = slot
            dispatched_at[writer] = time.monotonic()
            lease.start(slot, time.monotonic())

        def fail_writer(writer: int, why: str):
            """Reassign within budget, else record the failure."""
            nonlocal budget
            self.events.append(f"step {step}: writer {writer} failed: {why}")
            alive = self.alive_slots()
            if budget > 0 and alive:
                budget -= 1
                # the dead owner may have left torn shards — wipe the range
                shutil.rmtree(os.path.join(tmp, f"writer_{writer:02d}"),
                              ignore_errors=True)
                tgt = min(alive,
                          key=lambda s: sum(1 for sl in pending.values()
                                            if sl == s))
                reassigned[writer] = why
                self.events.append(
                    f"step {step}: writer {writer} range reassigned to "
                    f"slot {tgt}")
                dispatch(writer, tgt, None)
            else:
                failures[writer] = why
                reassigned.pop(writer, None)

        try:
            # pack + dispatch interleaved: writer 0 is writing its shards
            # while later groups are still being copied into the arena
            self._pack(groups, snap, entries,
                       on_group=lambda w: dispatch(
                           w, w, fault_for(step, w)
                           if fault_for is not None else None))
            while pending:
                if self._fenced.is_set() or (abort_check is not None
                                             and abort_check()):
                    raise FleetAborted(step)
                try:
                    conns = {self._conns[s]: s
                             for s in set(pending.values())
                             if s in self._conns}
                    ready = mp_connection.wait(
                        list(conns), timeout=min(0.05, self.hb_interval / 2))
                except (OSError, KeyError):
                    # a concurrent fence closed handles under us — the
                    # _fenced check at the top of the loop exits next pass
                    continue
                now = time.monotonic()
                for conn in ready:
                    slot = conns[conn]
                    try:
                        msg = conn.recv()
                    except (EOFError, OSError):
                        continue        # exit handled by the liveness scan
                    kind, writer, detail = msg
                    if pending.get(writer) != slot:
                        continue        # stale reply from a superseded task
                    del pending[writer]
                    if kind == "ok" and verify is not None:
                        try:
                            verify(writer)
                        except Exception as e:
                            kind, detail = "err", (
                                f"partial failed disk verification: {e}")
                    if kind != "ok":
                        fail_writer(writer, str(detail))
                # liveness scan (per slot; a slot may carry several writers)
                for slot in set(pending.values()):
                    hb = read_heartbeat(self._hb_path(slot))
                    if hb is not None:
                        lease.observe(slot, hb, now)
                    p = self._procs.get(slot)
                    dead_why = None
                    if p is None or p.exitcode is not None:
                        code = p.exitcode if p is not None else "?"
                        dead_why = f"writer process exited ({code})"
                    elif lease.expired(slot, now):
                        dead_why = (f"heartbeat lease expired "
                                    f"(>{self.timeout}s): SIGKILL fence")
                    if dead_why is not None:
                        self._reap_slot(slot)
                        lease.drop(slot)
                        for w in [w for w, s in pending.items()
                                  if s == slot]:
                            del pending[w]
                            fail_writer(w, dead_why)
                # slow writers: alive + leased, just late — log once
                for w, t0 in dispatched_at.items():
                    if (w in pending and w not in slow_logged
                            and now - t0 > self.timeout):
                        slow_logged.add(w)
                        self.events.append(
                            f"step {step}: writer {w} slow "
                            f"(>{self.timeout}s, heartbeats healthy)")
        except BaseException:
            # abort/fence/fleet-death: the arena may be scheduled for
            # sweeping with the scratch dir — drop it rather than reuse
            self._drop_arena()
            raise
        finally:
            self._saving = False
            if self._fenced.is_set():
                # a fence landed while we were saving and deferred the
                # arena teardown to us (its scratch was swept under it)
                self._drop_arena()
        return failures, reassigned
