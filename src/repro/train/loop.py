"""Training loop: data prefetch, jit'd step, checkpointing, fault hooks.

Pure-state design: the loop is a fold of ``train_step`` over a seekable data
stream, so (checkpoint, step) fully determines the future — the property the
supervisor (runtime/fault.py) relies on for restart-exactness.

Checkpointing is non-blocking when the manager supports it
(checkpoint/manager.AsyncCheckpointManager): the boundary step only snapshots
state into the host staging arena via ``save_async`` — serialization and the
atomic publish happen on the manager's writer thread while the next steps
run.  The snapshot must happen here, synchronously at the boundary, because
the step function donates its buffers: by the next ``train_step`` call the
device memory behind ``params``/``opt_state`` may be reused.  On normal exit
the loop drains in-flight saves (``wait_until_finished``), which also
surfaces any writer error; on failure the supervisor aborts them instead
(``run_supervised(ckpt=...)``) so a restart never resumes from a
half-published step.

The loop is agnostic to HOW the step runs: the single-program jitted step
(train/step.py) and the 1F1B pipeline orchestrator
(parallel/pipeline.build_pipeline_train_step) both fold ``(params,
opt_state, batch) -> (params, opt_state, metrics)``; under the pipeline the
state leaves are *lists of per-stage trees* (one per pod), which checkpoint
and restore like any other pytree.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.fault import FailureInjector, StepTimer


def train(train_step: Callable, state: Dict, data_iter, *,
          start_step: int = 0, num_steps: int = 100,
          ckpt: Optional[CheckpointManager] = None, ckpt_every: int = 50,
          log_every: int = 10, injector: Optional[FailureInjector] = None,
          timer: Optional[StepTimer] = None,
          on_straggler: Optional[Callable] = None,
          guard=None, watchdog=None,
          data_index_fn: Optional[Callable[[int], int]] = None,
          log_fn: Callable = print) -> Dict:
    """``guard`` is a :class:`repro.runtime.guard.TrainingGuard` — fed every
    synced per-step loss (+ the in-graph ``update_skipped`` metric), it
    raises ``DivergenceError`` on sustained divergence, BEFORE the boundary
    save that would persist the poisoned state.  ``watchdog`` is a
    :class:`repro.runtime.guard.Watchdog`, armed at the top of each step and
    checked once the loss syncs — a step that outlives ``hang_timeout``
    raises ``HangError``.  ``data_index_fn`` maps loop step -> data index
    (identity when None) so a blocklist-aware run reports the true poisoned
    ``batch_at`` indices (docs/DESIGN.md §8)."""
    params, opt_state = state["params"], state["opt_state"]
    history = state.setdefault("history", [])
    if (ckpt is not None and injector is not None
            and hasattr(injector, "check_writer")
            and getattr(ckpt, "writer_fault", None) is None):
        # wire the writer-fault dimension: the injector can now kill one
        # logical writer inside the torn window (post shard-write, pre
        # partial-manifest publish) — checkpoint/manager.py quorum protocol
        ckpt.writer_fault = injector.check_writer
    if (ckpt is not None and injector is not None
            and hasattr(injector, "proc_fault")
            and getattr(ckpt, "writer_procs", False)
            and getattr(ckpt, "proc_fault", None) is None):
        # process-fleet sibling: the injector ships kill9/sigstop/slow/
        # corrupt specs into writer CHILD PROCESSES (runtime/procs.py) —
        # same torn window, process-level failure modes
        ckpt.proc_fault = injector.proc_fault
    for step in range(start_step, num_steps):
        batch = next(data_iter)
        if injector is not None:
            injector.check(step)
        if watchdog is not None:
            watchdog.arm(step)
        t0 = time.time()
        params, opt_state, metrics = train_step(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.time() - t0
        if watchdog is not None:
            watchdog.disarm()
            watchdog.check()                # raises HangError if tripped
        if timer is not None and timer.record(dt) and on_straggler:
            on_straggler(step, timer)
        # per-step history: the loss is already a synced scalar (the
        # block_until_ready above), so recording every step costs one float
        # append — and restart-exactness tests / the guard see the full
        # trajectory, not a log_every subsample
        loss = float(metrics["loss"])
        history.append((step, loss))
        if step % log_every == 0 or step == num_steps - 1:
            log_fn(f"step {step:5d} loss {loss:.4f} "
                   f"gnorm {float(metrics.get('grad_norm', 0)):.3f} "
                   f"{dt*1e3:.0f}ms")
        if guard is not None:
            # before the boundary save: a DivergenceError here must not
            # let the poisoned state publish
            guard.observe(step, loss, metrics,
                          data_index=(data_index_fn(step)
                                      if data_index_fn else step))
        if ckpt is not None and (step + 1) % ckpt_every == 0:
            # non-blocking on AsyncCheckpointManager; = save() on the sync one
            ckpt.save_async(step + 1, {"params": params,
                                       "opt_state": opt_state})
    if ckpt is not None:
        ckpt.wait_until_finished()          # drain async writes; raise errors
    state.update(params=params, opt_state=opt_state)
    return state
