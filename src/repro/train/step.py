"""Training step builder: microbatch gradient accumulation (the paper's
mini-batch scheduling, §III-B a), remat policy, grad clipping, AdamW + ZeRO-1.

``build_train_step`` returns a pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
suitable for ``jax.jit`` with the sharding trees from parallel/specs.py.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig, ParallelConfig, RunConfig
from repro.models import lm
from repro.optim import adamw
from repro.parallel import zero
from repro.parallel.context import PCtx


def microbatch_split(batch: Dict[str, jax.Array], n_micro: int):
    """[B, ...] -> [n_micro, B/n_micro, ...] for every array in the batch.

    A ``dropout_rng`` key is not batch-shaped: it is *split* into one
    independent PRNG key per microbatch instead (so every microbatch draws a
    distinct dropout mask), which keeps every leaf scannable over the leading
    microbatch dim."""
    def split(a):
        B = a.shape[0]
        assert B % n_micro == 0, f"batch {B} % microbatches {n_micro}"
        return a.reshape(n_micro, B // n_micro, *a.shape[1:])
    return {k: (jax.random.split(v, n_micro) if k == "dropout_rng"
                else split(v))
            for k, v in batch.items() if hasattr(v, "shape")}


def build_train_step(cfg: ModelConfig, pcfg: ParallelConfig, rc: RunConfig,
                     mesh, *, total_steps: int = 10_000,
                     compute_dtype=jnp.bfloat16, guard=None):
    """Single-program train step (grad-accumulation scan over microbatches).

    ``guard`` (a :class:`repro.config.GuardConfig`) arms the in-graph
    skip-update guard (docs/DESIGN.md §8): the AdamW update is applied under
    a ``jax.lax.cond`` on ``update_ok`` (all grads finite, no norm spike vs
    the EWMA in ``opt_state``), and metrics gain ``update_ok`` /
    ``update_skipped`` / ``nonfinite``.

    With ``pcfg.pipeline_enabled`` (pod_axis_role="pipeline") the step is
    instead the 1F1B orchestrator over per-pod stage sub-meshes — build it
    with ``parallel/pipeline.build_pipeline_train_step(...)`` (it takes the
    multi-pod mesh and returns (runner, step_fn); the step_fn must NOT be
    wrapped in ``jax.jit`` — it is a host-side schedule executor whose
    per-stage closures are jitted individually).
    """
    if pcfg.pipeline_enabled:
        raise ValueError(
            "pcfg.pipeline_enabled: use parallel/pipeline."
            "build_pipeline_train_step for the 1F1B pipeline step "
            "(state is per-stage; this single-program builder cannot "
            "express it)")
    pctx = PCtx(mesh, pcfg, "train")
    n_micro = pcfg.microbatches

    def loss_fn(params, mb):
        mb = dict(mb)
        mb["_dtype"] = compute_dtype
        return lm.train_loss(pctx, cfg, params, mb, remat=pcfg.remat)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        mbs = microbatch_split(batch, n_micro)

        def mb_body(carry, mb):
            gsum, lsum, asum = carry
            (loss, metrics), g = grad_fn(params, mb)
            g = zero.compress_grads(g, pcfg.grad_reduce_dtype)
            gsum = jax.tree.map(lambda a, b: a + b.astype(a.dtype), gsum, g)
            return (gsum, lsum + metrics["loss"], asum + metrics["aux"]), None

        gzero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum, asum), _ = lax.scan(
            mb_body, (gzero, jnp.zeros(()), jnp.zeros(())), mbs)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        new_params, new_opt, om = adamw.update(params, grads, opt_state, rc,
                                               total_steps, guard=guard)
        metrics = {"loss": lsum / n_micro, "aux": asum / n_micro, **om}
        return new_params, new_opt, metrics

    return train_step


def init_train_state(cfg: ModelConfig, key):
    params = lm.init_params(cfg, key)
    return params, adamw.init(params)
