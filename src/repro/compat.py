"""Small jax version-compatibility shims.

The repo targets the ``jax.shard_map`` API (jax >= 0.6, ``check_vma=``) but must
also run on the 0.4.x series the container ships, where shard_map lives in
``jax.experimental.shard_map`` and the flag is spelled ``check_rep=``.  Same
story for ``Compiled.cost_analysis()``, which returns a list of per-program
dicts on old jaxlibs and a plain dict on new ones.
"""

from __future__ import annotations

import jax

try:                                    # jax >= 0.6: public API, check_vma flag
    _new_shard_map = jax.shard_map
except AttributeError:
    _new_shard_map = None

if _new_shard_map is None:
    from jax.experimental.shard_map import shard_map as _old_shard_map


def shard_map(f, mesh, in_specs, out_specs, check=False):
    """Uniform shard_map with replication checking disabled by default."""
    if _new_shard_map is not None:
        return _new_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=check)
    return _old_shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check)


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized to a single flat dict."""
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        return dict(ca[0]) if ca else {}
    return dict(ca)
