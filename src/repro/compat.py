"""Small jax version/backend-compatibility shims.

The repo targets the ``jax.shard_map`` API (jax >= 0.6, ``check_vma=``) but must
also run on the 0.4.x series the container ships, where shard_map lives in
``jax.experimental.shard_map`` and the flag is spelled ``check_rep=``.  Same
story for ``Compiled.cost_analysis()``, which returns a list of per-program
dicts on old jaxlibs and a plain dict on new ones.

This module also hosts the *remote-DMA emulation shim* for the fused Pallas
ring kernels (kernels/ring_matmul.py): only a real TPU backend can execute
``pltpu.make_async_remote_copy`` between ring neighbours, so on every other
backend (CPU CI, interpret mode) the kernels replace each inter-chip hop with
a ``lax.ppermute`` ring step — identical data movement, same step count, local
compute still running through the Pallas tile loop in interpret mode."""

from __future__ import annotations

import jax
from jax import lax

try:                                    # jax >= 0.6: public API, check_vma flag
    _new_shard_map = jax.shard_map
except AttributeError:
    _new_shard_map = None

if _new_shard_map is None:
    from jax.experimental.shard_map import shard_map as _old_shard_map


def shard_map(f, mesh, in_specs, out_specs, check=False):
    """Uniform shard_map with replication checking disabled by default."""
    if _new_shard_map is not None:
        return _new_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=check)
    return _old_shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check)


def remote_dma_supported() -> bool:
    """Can this runtime execute ``pltpu.make_async_remote_copy`` for real?

    True only on an actual TPU backend — the Pallas interpreter and the CPU/GPU
    backends have no inter-chip DMA engine.  The fused ring kernels use this to
    pick between the single-kernel remote-DMA path and the ppermute-emulated
    path (``ring_step_permute``)."""
    try:
        return jax.default_backend() == "tpu"
    except Exception:          # no backend initialized / headless analysis
        return False


def ring_step_permute(x, axis_name: str, n: int, shift: int = 1):
    """One emulated fused-kernel ring hop: shard -> (rank + shift) % n.

    This is the ppermute-emulation shim for ``kernels/ring_matmul.py``: on
    backends without remote-DMA support, each ``make_async_remote_copy`` of the
    circulating VMEM buffer becomes one ``lax.ppermute`` step with the exact
    same ring permutation, so CPU CI covers the fused kernels' numerics (and
    their HLO stays a collective-permute chain)."""
    return lax.ppermute(x, axis_name, [(i, (i + shift) % n) for i in range(n)])


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized to a single flat dict."""
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        return dict(ca[0]) if ca else {}
    return dict(ca)
