"""Sharding layout rules + a small layout solver.

Centralizes every PartitionSpec decision so models never hardcode axis names.
The solver picks attention-head/batch layouts subject to divisibility — e.g.
minicpm3's 40 heads cannot shard over a 16-way grid, so heads go on ``my`` (4) and
the batch dimension absorbs ``mx`` when divisible (paper §VI-F's layout-flexibility
point: Hecaton accommodates non-square/non-dividing layouts by re-mapping work).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

from jax.sharding import Mesh, PartitionSpec as P


def divides(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


@dataclass(frozen=True)
class AxisInfo:
    """Mesh axis bookkeeping for one strategy/mode."""
    data_axes: Tuple[str, ...]      # batch-sharding axes, e.g. ("pod", "data")
    t_ax: Optional[str]             # hecaton token axis ("mx"), None for megatron
    h_ax: Optional[str]             # hecaton hidden axis ("my")
    model_axes: Tuple[str, ...]     # combined model axes, e.g. ("mx","my") or ("model",)
    sizes: dict                     # axis -> size

    @property
    def n_data(self) -> int:
        return int(_prod(self.sizes[a] for a in self.data_axes))

    @property
    def n_model(self) -> int:
        return int(_prod(self.sizes[a] for a in self.model_axes))

    def size(self, ax) -> int:
        if ax is None:
            return 1
        if isinstance(ax, tuple):
            return int(_prod(self.sizes[a] for a in ax))
        return self.sizes[ax]


def _prod(it):
    r = 1
    for v in it:
        r *= v
    return r


def axis_info(mesh: Optional[Mesh], strategy: str) -> Optional[AxisInfo]:
    if mesh is None:
        return None
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    if strategy == "hecaton":
        return AxisInfo(data_axes, "mx", "my", ("mx", "my"), sizes)
    return AxisInfo(data_axes, None, None, ("model",), sizes)


# ---------------------------------------------------------------------------
# Attention layout solver
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AttnLayout:
    """How to shard [B, S, n_heads, head_dim] inside the mixer."""
    batch_axes: Tuple[str, ...]     # axes sharding B
    head_axes: Tuple[str, ...]      # axes sharding n_heads
    note: str = ""

    def q_spec(self) -> P:
        b = self.batch_axes if len(self.batch_axes) != 1 else self.batch_axes[0]
        h = self.head_axes if len(self.head_axes) != 1 else (
            self.head_axes[0] if self.head_axes else None)
        return P(b if self.batch_axes else None, None, h if self.head_axes else None,
                 None)


def solve_attn_layout(ax: AxisInfo, n_heads: int, batch_per_data: int,
                      prefer: str = "auto") -> AttnLayout:
    """Choose head/batch sharding over the model axes.

    Preference order (most parallel first):
      1. heads over all model axes;
      2. heads over h_ax, batch over t_ax;
      3. heads over h_ax only (t_ax replicated — flagged in note);
      4. batch over all model axes (head-replicated);
      5. fully replicated over model axes (flagged).
    ``prefer='heads'`` skips the batch-absorbing options (2): batch-over-mx
    layouts force per-layer collective-permute reshards between the mixer
    projections (hidden over the full grid) and the attention view.
    """
    m_axes, sz = ax.model_axes, ax.size
    if divides(n_heads, ax.n_model):
        return AttnLayout(ax.data_axes, m_axes, "heads fully sharded")
    if ax.t_ax is not None:
        if (prefer != "heads" and divides(n_heads, sz(ax.h_ax))
                and divides(batch_per_data, sz(ax.t_ax))):
            return AttnLayout(ax.data_axes + (ax.t_ax,), (ax.h_ax,),
                              "heads on my, batch on mx")
        if (prefer != "heads" and divides(n_heads, sz(ax.t_ax))
                and divides(batch_per_data, sz(ax.h_ax))):
            return AttnLayout(ax.data_axes + (ax.h_ax,), (ax.t_ax,),
                              "heads on mx, batch on my")
        if divides(n_heads, sz(ax.h_ax)):
            return AttnLayout(ax.data_axes, (ax.h_ax,),
                              f"heads on my only; {ax.t_ax} replicated (compute x{sz(ax.t_ax)})")
    if divides(batch_per_data, ax.n_model):
        return AttnLayout(ax.data_axes + m_axes, (),
                          "batch over model axes, heads replicated-per-shard")
    return AttnLayout(ax.data_axes, (), "WARNING: attention replicated over model axes")


# ---------------------------------------------------------------------------
# Canonical activation / param spec helpers
# ---------------------------------------------------------------------------

# Inter-block residual-stream layouts (ParallelConfig.residual):
#   "seq"        — tokens sharded over the model axes between blocks.  The
#                  hecaton canonical tiling P(d, mx, my) is natively
#                  sequence-sharded; for megatron this is the Korthikanti
#                  sequence-parallel layout P(d, model, None).
#   "replicated" — classic 1D-TP model-replicated residual P(d, None, None)
#                  (kept as the comparison baseline and the decode layout).
RESIDUAL_LAYOUTS = ("seq", "replicated")


def check_residual(layout: str) -> str:
    if layout not in RESIDUAL_LAYOUTS:
        raise ValueError(f"residual={layout!r} not in {RESIDUAL_LAYOUTS}")
    return layout


def act_canonical(ax: Optional[AxisInfo], layout: str = "seq") -> Optional[P]:
    """[B, S, H] spec at block boundaries for the given residual layout.

    hecaton's 2D tiling is sequence-sharded by construction (tokens over
    ``t_ax``, hidden over ``h_ax``) regardless of ``layout``; megatron
    switches between the seq-sharded P(d, model, None) and the
    model-replicated P(d, None, None) residual."""
    if ax is None:
        return None
    check_residual(layout)
    d = _one(ax.data_axes)
    if ax.t_ax is not None:
        return P(d, ax.t_ax, ax.h_ax)
    if layout == "seq":
        return P(d, _one(ax.model_axes), None)
    return P(d, None, None)            # megatron: activations model-replicated


def act_mixer(ax: Optional[AxisInfo]) -> Optional[P]:
    """[B, S, Hm] spec inside a mixer: full seq, hidden over all model axes."""
    if ax is None:
        return None
    d = _one(ax.data_axes)
    return P(d, None, _one(ax.model_axes))


def seq_shardable(ax: Optional[AxisInfo], seq_len: int) -> bool:
    """Can a megatron residual of this sequence extent shard over the model
    axes?  Requires a single non-degenerate model axis that divides the
    sequence; anything else (decode's S=1 included) falls back to the
    replicated residual at the call site."""
    if ax is None or ax.t_ax is not None:
        return False                    # hecaton: handled by its own tiling
    if len(ax.model_axes) != 1:
        return False
    n = ax.size(ax.model_axes[0])
    return n > 1 and seq_len > 1 and seq_len % n == 0


def vocab_spec(ax: Optional[AxisInfo]) -> Optional[P]:
    """Embedding table [V, H]."""
    if ax is None:
        return None
    if ax.t_ax is not None:
        return P(ax.t_ax, ax.h_ax)
    return P("model", None)


def _one(axes: Sequence[str]):
    axes = tuple(axes)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes
