"""Inter-pod 1F1B pipeline parallelism (``ParallelConfig.pod_axis_role ==
"pipeline"``, docs/DESIGN.md §5).

The paper's weak-scaling argument (§V-B) holds *within* a package: the 2D
AG/RS collectives ride the on-package bypass rings.  Across packages the
off-package links are the slow tier, and the canonical strategy there is
pipeline parallelism — each pod owns a contiguous *stage* of the block stack
and microbatches stream through the stages under a 1F1B (one-forward-
one-backward) schedule, so the only inter-pod traffic is one boundary
activation (and its cotangent) per microbatch per stage boundary.

Two layers live here:

1. **The schedule itself** (:func:`schedule_1f1b`) — a pure-Python,
   tick-synchronous 1F1B table (warmup / steady 1F1B / cooldown per stage,
   Megatron-LM's non-interleaved PipeDream-flush).  It is data-free, so its
   properties (op order, dependency sanity, makespan ``2*(m+p-1)``, bubble
   ticks ``2*(p-1)`` per stage, peak in-flight ``min(p-s, m)``) are unit
   tested without devices, and ``core/theory.py``'s bubble-fraction
   prediction ``(p-1)/(m+p-1)`` is checked against the simulated table
   (``theory_pipeline_*`` rows in benchmarks/comm_model.py).

2. **The runner** (:class:`PipelineRunner`) — executes the table on a
   multi-pod mesh.  Each stage runs on its pod's sub-mesh
   (``launch/mesh.pod_submeshes``) with the FULL existing intra-pod
   machinery — hecaton 2D tiling or the megatron baseline, the
   ``overlap`` lattice, and the seq-sharded residual — composing unchanged,
   because inside a stage the world looks exactly like a single-pod run.
   Stage-boundary transfers move the canonical (seq-sharded) [B,S,H]
   residual shard-to-shard between neighbouring pods' sub-meshes via
   ``jax.device_put`` — the point-to-point off-package hop.  (The jax 0.4.x
   series cannot nest a pod-axis ``shard_map``/``ppermute`` around the
   hecaton ops' own shard_maps, so the transfer is expressed as an explicit
   reshard instead of a pod-axis collective-permute; on one global mesh the
   two lower to the same device-to-device copies.)

Backward runs per-stage VJPs in the 1F1B order: a stage's backward
*recomputes* its forward from the stashed boundary input (stage-granular
remat — the stash per stage is bounded by the schedule's in-flight bound
``min(p-s, m)``, the 1F1B memory advantage over GPipe's ``m``).  Gradients
accumulate per stage exactly as train/step.py's microbatch scan does
(compress to ``grad_reduce_dtype``, accumulate fp32, divide by ``m``), and
the optimizer step stays bit-comparable to the single-program step: the
global-norm clip couples the stages, so per-stage square-sums are combined
into ONE global norm which every stage's AdamW update consumes
(``optim/adamw.update(grad_norm=...)``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ParallelConfig, RunConfig
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.parallel import specs as SP
from repro.parallel import zero
from repro.parallel.context import PCtx

# ---------------------------------------------------------------------------
# 1F1B schedule (pure Python — no jax below this line until the runner)
# ---------------------------------------------------------------------------

F = "F"
B = "B"


@dataclass(frozen=True)
class PipeTask:
    """One unit of stage work: forward or backward of one microbatch."""
    kind: str          # "F" | "B"
    mb: int            # microbatch index


def stage_order(stage: int, n_stages: int, n_micro: int) -> List[PipeTask]:
    """Per-stage 1F1B op order: warmup forwards, steady 1F1B, cooldown.

    Stage ``s`` warms up with ``min(p-1-s, m)`` forwards (the last stage
    warms up with zero and immediately alternates), then strictly
    alternates F, B until its forwards run out, then drains the remaining
    backwards — Megatron-LM's non-interleaved 1F1B.
    """
    p, m = n_stages, n_micro
    warmup = min(p - 1 - stage, m)
    order = [PipeTask(F, i) for i in range(warmup)]
    for i in range(m - warmup):
        order.append(PipeTask(F, warmup + i))
        order.append(PipeTask(B, i))
    for i in range(m - warmup, m):
        order.append(PipeTask(B, i))
    return order


@dataclass(frozen=True)
class PipeSchedule:
    """Tick-synchronous 1F1B table: ``ticks[t][s]`` is stage ``s``'s task at
    tick ``t`` (or None for a bubble).  F and B each take one tick; a task
    may only run when its dependency completed at a strictly earlier tick."""
    n_stages: int
    n_micro: int
    ticks: Tuple[Tuple[Optional[PipeTask], ...], ...]

    @property
    def makespan(self) -> int:
        return len(self.ticks)

    def bubble_ticks(self, stage: int) -> int:
        """Idle ticks of ``stage`` within the makespan."""
        return sum(1 for t in self.ticks if t[stage] is None)

    @property
    def bubble_fraction(self) -> float:
        """Simulated bubble fraction = idle/total of any stage (uniform in
        1F1B); theory predicts ``(p-1)/(m+p-1)`` (core/theory.py)."""
        return self.bubble_ticks(0) / self.makespan

    def peak_in_flight(self, stage: int) -> int:
        """Max simultaneously-stashed microbatches at ``stage`` (the
        activation-memory bound: ``min(p - stage, m)`` under 1F1B)."""
        peak = cur = 0
        for t in self.ticks:
            task = t[stage]
            if task is None:
                continue
            cur += 1 if task.kind == F else -1
            peak = max(peak, cur)
        return peak


def schedule_1f1b(n_stages: int, n_micro: int) -> PipeSchedule:
    """Simulate the 1F1B orders into a tick table.

    Dependencies: F(s, i) needs F(s-1, i); B(s, i) needs B(s+1, i) (and its
    own F(s, i), implied by the per-stage order).  Each stage executes its
    next op as soon as the dependency completed at an earlier tick.
    """
    p, m = n_stages, n_micro
    assert p >= 1 and m >= 1, (p, m)
    orders = [stage_order(s, p, m) for s in range(p)]
    pos = [0] * p                       # next-op index per stage
    done: Dict[Tuple[str, int, int], int] = {}   # (kind, stage, mb) -> tick
    ticks: List[Tuple[Optional[PipeTask], ...]] = []
    t = 0
    while any(pos[s] < len(orders[s]) for s in range(p)):
        row: List[Optional[PipeTask]] = []
        fired = []
        for s in range(p):
            if pos[s] >= len(orders[s]):
                row.append(None)
                continue
            task = orders[s][pos[s]]
            if task.kind == F:
                dep = None if s == 0 else (F, s - 1, task.mb)
            else:
                dep = None if s == p - 1 else (B, s + 1, task.mb)
            if dep is None or done.get(dep, t) < t:
                row.append(task)
                fired.append((task.kind, s, task.mb))
                pos[s] += 1
            else:
                row.append(None)
        assert fired, f"1F1B deadlock at tick {t} (p={p}, m={m})"
        for key in fired:
            done[key] = t
        ticks.append(tuple(row))
        t += 1
    return PipeSchedule(p, m, tuple(ticks))


# ---------------------------------------------------------------------------
# Stage partitioning of the model
# ---------------------------------------------------------------------------

def split_stage_layers(num_layers: int, n_stages: int) -> List[range]:
    """Contiguous per-stage layer ranges; the stack must divide evenly."""
    if num_layers % n_stages:
        raise ValueError(
            f"num_layers={num_layers} must divide evenly into "
            f"{n_stages} pipeline stages")
    lps = num_layers // n_stages
    return [range(s * lps, (s + 1) * lps) for s in range(n_stages)]


def validate_pipeline(cfg: ModelConfig, pcfg: ParallelConfig) -> None:
    """Raise on model/parallel combinations the 1F1B runner does not support."""
    if not pcfg.pipeline_enabled:
        raise ValueError("pod_axis_role='pipeline' requires pods > 1 "
                         f"(got pods={pcfg.pods})")
    if (cfg.family not in ("dense", "moe") or cfg.is_encdec
            or set(cfg.pattern()) != {"attn"} or cfg.frontend_stub_len):
        raise ValueError(
            f"pipeline stages support uniform token-only attention stacks "
            f"(dense/moe) only; {cfg.name!r} is family={cfg.family!r} with "
            f"pattern {sorted(set(cfg.pattern()))} (encdec={cfg.is_encdec}, "
            f"frontend_stub_len={cfg.frontend_stub_len}) — vlm patch "
            f"injection / audio frames / mamba states are not staged")
    if cfg.tie_embeddings:
        raise ValueError(
            "pipeline does not support tie_embeddings: the table would need "
            "to live on both the first and last stage with summed grads")
    split_stage_layers(cfg.num_layers, pcfg.pipeline_stages)


def stage_params(params, cfg: ModelConfig, stage: int, n_stages: int):
    """Slice the stacked param tree down to one stage's subtree.

    Stage 0 owns the embedding; the last stage owns the final norm and the
    LM head; every stage owns ``num_layers / n_stages`` contiguous blocks.
    """
    rng = split_stage_layers(cfg.num_layers, n_stages)[stage]
    sp: Dict[str, Any] = {
        "blocks": jax.tree.map(lambda a: a[rng.start:rng.stop],
                               params["blocks"]),
    }
    if stage == 0:
        sp["embed"] = params["embed"]
    if stage == n_stages - 1:
        sp["final_norm"] = params["final_norm"]
        if "lm_head" in params:
            sp["lm_head"] = params["lm_head"]
    return sp


def merge_stage_grads(stage_trees: Sequence[Any], cfg: ModelConfig):
    """Reassemble per-stage trees into one full-model tree (for tests /
    checkpoints of the combined view).  Inverse of :func:`stage_params`."""
    blocks = jax.tree.map(
        lambda *leaves: np.concatenate([np.asarray(l) for l in leaves], 0),
        *[t["blocks"] for t in stage_trees])
    out = {"blocks": blocks,
           "embed": jax.tree.map(np.asarray, stage_trees[0]["embed"]),
           "final_norm": jax.tree.map(np.asarray,
                                      stage_trees[-1]["final_norm"])}
    if "lm_head" in stage_trees[-1]:
        out["lm_head"] = jax.tree.map(np.asarray, stage_trees[-1]["lm_head"])
    return out


def stage_writer_map(n_writers: int):
    """Checkpoint shard→writer mapping for pipeline state (ISSUE 6).

    Pipeline train state is ``{"params": [per-stage trees], "opt_state":
    [...]}``, so a checkpoint leaf path's second segment is the stage index
    — the pod that already holds those shards in HBM.  Mapping ``stage %
    n_writers`` makes each pod persist its own stage (the natural failure
    domain: a pod death costs one writer, not the whole save), with the
    modulo covering ``n_writers < stages``.  Returns ``None`` for non-stage
    leaves (e.g. scalars at the tree root), which fall back to the
    manager's byte-balanced partition (checkpoint/manager.partition_shards).
    """
    def _map(name: str):
        parts = name.split("/")
        if len(parts) >= 2:
            try:
                return int(parts[1]) % n_writers
            except ValueError:
                return None
        return None
    return _map


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

class PipelineRunner:
    """Executes the 1F1B table over per-pod sub-meshes.

    ``mesh`` is the global multi-pod mesh (leading ``"pod"`` axis,
    ``launch/mesh.make_small_mesh(..., pods=p)``).  Each stage gets the
    pod's sub-mesh and an inner single-pod ``ParallelConfig`` (same
    strategy / grid / overlap / residual), so hecaton's 2D collectives and
    the overlap lattice run inside the stage exactly as on a single pod.
    """

    def __init__(self, cfg: ModelConfig, pcfg: ParallelConfig, rc: RunConfig,
                 mesh: Mesh, *, total_steps: int = 10_000,
                 compute_dtype=jnp.bfloat16, guard=None):
        from repro.launch import mesh as M
        validate_pipeline(cfg, pcfg)
        if "pod" not in mesh.axis_names:
            raise ValueError(
                f"pipeline needs a mesh with a 'pod' axis; got "
                f"{mesh.axis_names} (use launch.mesh.make_small_mesh(..., "
                f"pods=n) or make_hecaton_mesh(multi_pod=True))")
        self.cfg, self.pcfg, self.rc = cfg, pcfg, rc
        self.total_steps = total_steps
        self.compute_dtype = compute_dtype
        self.n_stages = pcfg.pipeline_stages
        self.n_micro = pcfg.microbatches
        self.sched = schedule_1f1b(self.n_stages, self.n_micro)
        self.submeshes = M.pod_submeshes(mesh)
        assert len(self.submeshes) == self.n_stages, (
            len(self.submeshes), self.n_stages)
        inner = pcfg.with_(pods=1, pod_axis_role="data")
        self.pctxs = [PCtx(sm, inner, "train") for sm in self.submeshes]
        self.aux_coef = cfg.moe.aux_loss if cfg.moe else 0.0
        # per-stage canonical residual / token shardings for the boundary
        # transfers — with the same non-dividing-sequence fallback that
        # PCtx.canon / specs.batch_specs apply inside the stage
        self._canon = [NamedSharding(
            sm, shd.act_canonical(px.ax, self._residual_layout(px)))
            for sm, px in zip(self.submeshes, self.pctxs)]
        self._tok = [NamedSharding(sm, SP.batch_specs(
            sm, inner, microbatched=False, seq_len=rc.seq_len)["tokens"])
            for sm in self.submeshes]
        self.guard = guard
        self._build_stage_fns()
        self._gnorm_sq = jax.jit(adamw.global_norm_sq)
        # one jitted optimizer update serves every stage: jit re-traces per
        # stage tree structure/sharding and caches each specialization.
        # With a guard, every stage folds the SAME cross-stage scalar norm
        # into its update, so per-stage guard predicates and EWMAs stay
        # bitwise in sync — stages skip (or accept) a step in lockstep.
        self._upd = jax.jit(lambda q, g, st, gn: adamw.update(
            q, g, st, self.rc, self.total_steps, grad_norm=gn,
            guard=self.guard))
        # executed-op log (schedule-conformance assertions in tests)
        self.executed: List[List[PipeTask]] = []

    def _residual_layout(self, pctx: PCtx) -> str:
        ax = pctx.ax
        if ax.t_ax is not None:
            return "seq"               # hecaton tiling is seq-sharded natively
        if (pctx.pcfg.residual == "seq"
                and shd.seq_shardable(ax, self.rc.seq_len)):
            return "seq"
        return "replicated"

    # -- stage cores -------------------------------------------------------

    def _blocks(self, s: int, sparams, x):
        from repro.models import lm
        pctx = self.pctxs[s]
        Bsz, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (Bsz, S))
        layout = pctx.attn_layout(self.cfg.num_heads, Bsz)
        x, aux, _ = lm._scan_attn_stack(
            pctx, self.cfg, sparams["blocks"], x, positions=positions,
            layout=layout, causal=True, caches=None, memory=None,
            remat=self.pcfg.remat)
        return x, aux

    def _first_core(self, sparams, tokens, rng):
        pctx, cfg = self.pctxs[0], self.cfg
        x = pctx.embed(sparams["embed"]["table"], tokens, self.compute_dtype)
        x = pctx.canon(x)
        if cfg.embed_dropout and rng is not None:
            x = pctx.dropout(x, cfg.embed_dropout, rng)
        return self._blocks(0, sparams, x)

    def _mid_core(self, s: int, sparams, x):
        return self._blocks(s, sparams, self.pctxs[s].canon(x))

    def _last_core(self, sparams, x, labels, mask):
        from repro.models import lm
        s = self.n_stages - 1
        pctx, cfg = self.pctxs[s], self.cfg
        x, aux = self._blocks(s, sparams, pctx.canon(x))
        hidden = pctx.norm(cfg.norm_kind, sparams["final_norm"], x)
        loss = lm.head_loss(pctx, cfg, sparams, hidden, labels, mask=mask,
                            compute_dtype=self.compute_dtype)
        return loss, aux

    # -- jitted stage entry points ----------------------------------------

    def _build_stage_fns(self):
        coef = jnp.float32(self.aux_coef)
        p = self.n_stages

        def first_fwd(sp, tokens, rng):
            return self._first_core(sp, tokens, rng)

        def first_bwd(sp, tokens, rng, dy):
            _, pull = jax.vjp(lambda q: self._first_core(q, tokens, rng), sp)
            (dsp,) = pull((dy, coef))
            return dsp

        self.first_fwd = jax.jit(first_fwd)
        self.first_bwd = jax.jit(first_bwd)

        self.mid_fwd, self.mid_bwd = {}, {}
        for s in range(1, p - 1):
            def mid_fwd(sp, x, _s=s):
                return self._mid_core(_s, sp, x)

            def mid_bwd(sp, x, dy, _s=s):
                _, pull = jax.vjp(lambda q, xx: self._mid_core(_s, q, xx),
                                  sp, x)
                return pull((dy, coef))

            self.mid_fwd[s] = jax.jit(mid_fwd)
            self.mid_bwd[s] = jax.jit(mid_bwd)

        def last_total(sp, x, labels, mask):
            loss, aux = self._last_core(sp, x, labels, mask)
            return loss + self.aux_coef * aux, (loss, aux)

        def last_bwd(sp, x, labels, mask):
            grads, aux = jax.grad(last_total, argnums=(0, 1),
                                  has_aux=True)(sp, x, labels, mask)
            return grads, aux

        self.last_bwd = jax.jit(last_bwd)

    # -- state placement ---------------------------------------------------

    def place_params(self, params) -> List[Any]:
        """Full-model param tree -> per-stage trees sharded on the sub-meshes."""
        out = []
        for s in range(self.n_stages):
            sp = stage_params(params, self.cfg, s, self.n_stages)
            pspecs = SP.param_specs(sp, self.submeshes[s],
                                    self.pctxs[s].pcfg)
            out.append(jax.device_put(sp, SP.sharding_tree(
                pspecs, self.submeshes[s])))
        return out

    def init_opt(self, sparams: List[Any]) -> List[adamw.AdamState]:
        out = []
        for s, sp in enumerate(sparams):
            st = adamw.init(sp)
            pspecs = SP.param_specs(sp, self.submeshes[s], self.pctxs[s].pcfg)
            ospecs = SP.opt_state_specs(pspecs, sp, self.submeshes[s],
                                        self.pctxs[s].pcfg)
            out.append(jax.device_put(st, SP.sharding_tree(
                ospecs, self.submeshes[s])))
        return out

    # -- 1F1B execution ----------------------------------------------------

    _BATCH_KEYS = ("tokens", "labels", "loss_mask", "dropout_rng")

    def _split_batch(self, batch):
        from repro.train.step import microbatch_split
        unknown = [k for k in batch
                   if k not in self._BATCH_KEYS and hasattr(batch[k],
                                                            "shape")]
        if unknown:
            # e.g. custom "positions": the stages rebuild arange positions,
            # so silently dropping a caller-supplied key would mistrain
            raise ValueError(f"pipeline runner does not support batch keys "
                             f"{unknown}; supported: {self._BATCH_KEYS}")
        mbs = microbatch_split(batch, self.n_micro)
        tokens = [jax.device_put(mbs["tokens"][i], self._tok[0])
                  for i in range(self.n_micro)]
        rngs = ([mbs["dropout_rng"][i] for i in range(self.n_micro)]
                if "dropout_rng" in mbs else [None] * self.n_micro)
        last = self._tok[-1]
        labels = [jax.device_put(mbs["labels"][i], last)
                  for i in range(self.n_micro)]
        masks = ([jax.device_put(mbs["loss_mask"][i], last)
                  for i in range(self.n_micro)]
                 if "loss_mask" in mbs else [None] * self.n_micro)
        return tokens, rngs, labels, masks

    def loss_and_grads(self, sparams: List[Any], batch):
        """Run the full 1F1B table once: mean loss + per-stage mean grads.

        Mirrors train/step.py's accumulation bit-for-bit: per-microbatch
        grads are compressed to ``grad_reduce_dtype``, accumulated into an
        fp32 sum, and divided by the microbatch count at the end.
        """
        p, m = self.n_stages, self.n_micro
        tokens, rngs, labels, masks = self._split_batch(batch)
        # accumulators are seeded by the first backward's (compressed) grad,
        # so they inherit the stage sharding — no zero tree ever
        # materializes on the default device
        gsum: List[Any] = [None] * p
        acts: List[Dict[int, Any]] = [dict() for _ in range(p)]
        cots: List[Dict[int, Any]] = [dict() for _ in range(p)]
        inflight = [set() for _ in range(p)]
        losses, auxes = [], [[] for _ in range(p)]
        executed: List[List[PipeTask]] = [[] for _ in range(p)]
        self.max_stash = [0] * p

        def accumulate(s, dp):
            dp = zero.compress_grads(dp, self.pcfg.grad_reduce_dtype)
            if gsum[s] is None:
                gsum[s] = jax.tree.map(lambda b: b.astype(jnp.float32), dp)
            else:
                gsum[s] = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                       gsum[s], dp)

        for row in self.sched.ticks:
            for s, task in enumerate(row):
                if task is None:
                    continue
                executed[s].append(task)
                i = task.mb
                if task.kind == F:
                    if s == 0:
                        y, aux = self.first_fwd(sparams[0], tokens[i],
                                                rngs[i])
                    elif s < p - 1:
                        y, aux = self.mid_fwd[s](sparams[s], acts[s][i])
                    # the last stage's fwd happens inside the fused bwd at
                    # its B tick (stage-granular remat): the F tick only
                    # admits the microbatch into the stash.
                    if s < p - 1:
                        acts[s + 1][i] = jax.device_put(y,
                                                        self._canon[s + 1])
                        auxes[s].append(aux)
                    inflight[s].add(i)
                    self.max_stash[s] = max(self.max_stash[s],
                                            len(inflight[s]))
                else:
                    if s == p - 1:
                        (dp, dx), (loss_i, aux_i) = self.last_bwd(
                            sparams[s], acts[s][i], labels[i], masks[i])
                        losses.append(loss_i)
                        auxes[s].append(aux_i)
                    elif s > 0:
                        dp, dx = self.mid_bwd[s](sparams[s], acts[s][i],
                                                 cots[s].pop(i))
                    else:
                        dp = self.first_bwd(sparams[0], tokens[i], rngs[i],
                                            cots[0].pop(i))
                        dx = None
                    if s > 0:
                        cots[s - 1][i] = jax.device_put(dx,
                                                        self._canon[s - 1])
                        acts[s].pop(i)
                    inflight[s].discard(i)
                    accumulate(s, dp)
        self.executed = executed
        grads = [jax.tree.map(lambda g: g / m, gs) for gs in gsum]
        loss = sum(losses[1:], losses[0]) / m
        aux_terms = [sum(a[1:], a[0]) / m for a in auxes if a]
        metrics = {"loss": loss,
                   "aux": float(np.sum([np.asarray(a) for a in aux_terms]))}
        return loss, grads, metrics

    # -- full train step ---------------------------------------------------

    def train_step(self, sparams: List[Any], sopt: List[Any], batch):
        """(stage params, stage opt states, batch) -> updated state + metrics.

        Bit-comparable to the single-program optimizer step: the global-norm
        clip consumes ONE norm combined across all stages.
        """
        loss, grads, metrics = self.loss_and_grads(sparams, batch)
        sq = [float(np.asarray(self._gnorm_sq(g))) for g in grads]
        gnorm = float(np.sqrt(np.sum(np.asarray(sq, np.float64))))
        new_p, new_o = [], []
        for s in range(self.n_stages):
            gn = jax.device_put(jnp.float32(gnorm),
                                NamedSharding(self.submeshes[s], P()))
            np_, no_, om = self._upd(sparams[s], grads[s], sopt[s], gn)
            new_p.append(np_)
            new_o.append(no_)
        metrics.update({"grad_norm": jnp.float32(gnorm), "lr": om["lr"]})
        if self.guard is not None:
            # identical across stages (same scalar norm, synced EWMAs);
            # surface the last stage's copy
            for k in ("update_ok", "update_skipped", "nonfinite"):
                metrics[k] = om[k]
        metrics["aux"] = jnp.float32(metrics["aux"])
        return new_p, new_o, metrics


def build_pipeline_train_step(cfg: ModelConfig, pcfg: ParallelConfig,
                              rc: RunConfig, mesh, *,
                              total_steps: int = 10_000,
                              compute_dtype=jnp.bfloat16, guard=None):
    """Pipeline counterpart of ``train/step.build_train_step``.

    Returns ``(runner, step_fn)``: the step takes (stage_params,
    stage_opt_states, batch) like the single-program step takes (params,
    opt_state, batch), so ``train/loop.train`` drives either one.  The step
    is a host-side 1F1B orchestrator — do NOT wrap it in ``jax.jit``.
    """
    runner = PipelineRunner(cfg, pcfg, rc, mesh, total_steps=total_steps,
                            compute_dtype=compute_dtype, guard=guard)
    return runner, runner.train_step
