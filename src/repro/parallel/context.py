"""Parallel execution context — the dispatch hub between model code and strategies.

Model code never touches axis names or collectives directly; it calls the methods
here.  ``PCtx`` binds (mesh, ParallelConfig, mode) and routes every projection to:

  * ``hecaton``  — paper Alg. 1 shard_map ops (core/hecaton.py) for train/prefill;
  * ``megatron`` — 1D-TP column/row-parallel with GSPMD-inserted all-reduce
                   (the paper's baseline, parallel/megatron.py);
  * plain einsum when ``mesh is None`` (smoke tests) .

``ParallelConfig.overlap`` (none → ring → bidir → fused, core/overlap.py) is
plumbed through unchanged: the hecaton ops AND the megatron baseline both
ring-decompose their collectives per mode, ``fused`` additionally routing
tile-aligned collective matmuls through the single-kernel Pallas ring path
(kernels/ring_matmul.py) with automatic fallback to ``ring`` otherwise.

``ParallelConfig.residual`` ("seq" | "replicated") selects the canonical
inter-block activation layout.  The default "seq" keeps the residual stream
token-sharded over the model axes for the whole layer scan — hecaton's 2D
tiling natively, the Korthikanti sequence-parallel layout P(d, model, None)
for megatron — so the shard-local entry points here (:meth:`norm`,
:meth:`dropout`, residual adds via :meth:`canon`) run on 1/n_t of the tokens
and no block boundary carries a bulk collective: megatron's entry gathers /
exit scatters ride the same overlap lattice as the hecaton ops.

Decode mode always uses the 1D layout over the *combined* model axes: Alg. 1's
token-scatter needs >= sqrt(N) tokens per step, and the paper targets training /
finetuning (docs/DESIGN.md §4).  Decode therefore also forces the replicated
residual (S=1 cannot token-scatter).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.config import ParallelConfig
from repro.core import hecaton as hec
from repro.models import layers as _L
from repro.parallel import megatron as meg
from repro.parallel import sharding as shd


def _einsum(x, w):
    return jnp.einsum("...h,ho->...o", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


@dataclass(frozen=True)
class PCtx:
    mesh: Optional[Mesh]
    pcfg: ParallelConfig
    mode: str = "train"                    # train | prefill | decode

    # ------------------------------------------------------------------
    @property
    def ax(self) -> Optional[shd.AxisInfo]:
        return shd.axis_info(self.mesh, self.pcfg.strategy)

    @property
    def use_hecaton(self) -> bool:
        return (self.mesh is not None and self.pcfg.strategy == "hecaton"
                and self.mode in ("train", "prefill"))

    @property
    def data_axes(self) -> Tuple[str, ...]:
        a = self.ax
        return a.data_axes if a else ()

    @property
    def overlap(self) -> str:
        """NoP comm/compute overlap mode (core/overlap.py MODES lattice):
        none | ring | bidir | fused — consumed by the hecaton ops, the MoE
        EP/TP collectives, and the megatron ring paths alike."""
        return self.pcfg.overlap

    @property
    def comm_dtype(self) -> str:
        """Ring-collective wire dtype (core/quant.py): "bf16" | "int8".
        Every ring hop the overlap lattice issues goes through
        ``quant.ring_hop`` under this dtype; "bf16" is bit-identical to the
        bare ``lax.ppermute`` the rings always did."""
        return self.pcfg.comm_dtype

    @property
    def residual(self) -> str:
        """Effective residual-stream layout (sharding.RESIDUAL_LAYOUTS).

        ``pcfg.residual`` except in decode, which forces "replicated" (S=1
        cannot token-scatter).  hecaton's canonical tiling is seq-sharded by
        construction, so the flag only changes the megatron baseline."""
        if self.mode == "decode":
            return "replicated"
        return self.pcfg.residual

    def constraint(self, x, spec: Optional[P]):
        if self.mesh is None or spec is None:
            return x
        return lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec))

    # ------------------------------------------------------------------
    # canonical layouts
    # ------------------------------------------------------------------
    def canon(self, x):
        """Constrain [B,S,H] to the canonical block-boundary layout.

        Decode (S=1) cannot token-scatter: canonical is batch-over-data only,
        hidden replicated (1D-TP residual layout).  A megatron sequence the
        model ring cannot divide likewise stays replicated."""
        a = self.ax
        if a is None:
            return x
        if self.mode == "decode":
            d = a.data_axes[0] if len(a.data_axes) == 1 else a.data_axes
            return self.constraint(x, P(d, None, None))
        layout = self.residual
        if (layout == "seq" and a.t_ax is None
                and not shd.seq_shardable(a, x.shape[1])):
            layout = "replicated"
        return self.constraint(x, shd.act_canonical(a, layout))

    def mixer_spec(self) -> Optional[P]:
        return shd.act_mixer(self.ax)

    # ------------------------------------------------------------------
    # shard-local residual-stream ops (norm / dropout run on 1/n_t tokens)
    # ------------------------------------------------------------------
    def norm(self, kind: str, params, x, eps: float = 1e-6):
        """Pre-norm on the canonical residual layout.

        Norm statistics are over the (unsharded) hidden dim, so the whole op
        is computed on the local token shard — zero communication, and under
        the seq layout per-die norm work and activation bytes shrink by
        1/n_t (the redundancy sequence parallelism removes)."""
        return _L.apply_norm(kind, params, self.canon(x), eps=eps)

    def dropout(self, x, rate: float, rng=None):
        """Dropout on the local token shard of the canonical layout.

        ``rng=None`` (or rate 0) is the deterministic path.  The mask is
        generated under GSPMD on the sharded operand, so no replicated
        [B,S,H] mask ever materializes.  The seq layout reproduces the
        single-device mask bit-for-bit; on the 0.4.x jax series the
        replicated megatron layout can draw a different (equally valid) mask
        for the same key — old GSPMD's non-partitionable threefry lowering is
        not bit-stable across program structure.  Keep rate and values are
        exact in every layout."""
        if rate <= 0.0 or rng is None:
            return x
        return _L.dropout(self.canon(x), rate, rng)

    # ------------------------------------------------------------------
    # projections
    # ------------------------------------------------------------------
    def _cast(self, x, *ws):
        """Cast weights to the activation dtype BEFORE any gather/shard_map —
        fp32 weights entering collectives double the FSDP/ZeRO gather bytes and
        silently promote the matmuls to fp32 (Perf iteration 1, EXPERIMENTS.md)."""
        return tuple(w if w is None else w.astype(x.dtype) for w in ws)

    def ffn(self, x, w1, w2, act_fn: Callable, w1b=None):
        """Fused FFN (paper §IV-B)."""
        w1, w2, w1b = self._cast(x, w1, w2, w1b)
        if self.use_hecaton:
            a = self.ax
            return hec.ffn_block(x, w1, w2, mesh=self.mesh, act_fn=act_fn,
                                 t_ax=a.t_ax, h_ax=a.h_ax, data_axes=a.data_axes,
                                 w1b=w1b, overlap=self.overlap,
                                 comm_dtype=self.comm_dtype)
        if self.mesh is not None:
            return meg.ffn(self, x, w1, w2, act_fn, w1b)
        h = _einsum(x, w1)
        h = act_fn(h) * _einsum(x, w1b) if w1b is not None else act_fn(h)
        return _einsum(h, w2)

    def mixer_in(self, x, w, interior: bool = False):
        """Projection into a token mixer: out has full sequence, hidden over grid.

        ``interior=True`` marks inputs that are already mixer-interior
        (full-sequence, hidden-sharded — e.g. MLA's second q projection) so
        the megatron seq-sharded path does not re-gather an entry that never
        scattered."""
        (w,) = self._cast(x, w)
        if self.use_hecaton:
            a = self.ax
            return hec.mixer_in(x, w, mesh=self.mesh, t_ax=a.t_ax, h_ax=a.h_ax,
                                data_axes=a.data_axes, overlap=self.overlap,
                                comm_dtype=self.comm_dtype)
        if self.mesh is not None:
            return meg.col_parallel(self, x, w, interior=interior)
        return _einsum(x, w)

    def mixer_in_many(self, x, *ws):
        """Several mixer-in projections of the SAME residual entry (QKV and
        friends) sharing one entry gather where the layout allows it.

        megatron seq layout: routes through ``col_parallel_shared`` — the
        sequence is ring-gathered ONCE and every projection reads the shared
        gather (1x entry NoP bytes instead of len(ws)x; one reduce-scatter in
        the backward).  Everything else falls back to per-weight
        :meth:`mixer_in` (hecaton's identical per-op gathers CSE in XLA)."""
        ws = self._cast(x, *ws)
        if (self.mesh is not None and not self.use_hecaton
                and self.mode != "decode"):
            return meg.col_parallel_shared(self, x, ws)
        return tuple(self.mixer_in(x, w) for w in ws)

    def mixer_out(self, y, w):
        """Projection out of a token mixer back to canonical layout."""
        (w,) = self._cast(y, w)
        if self.use_hecaton:
            a = self.ax
            return hec.mixer_out(y, w, mesh=self.mesh, t_ax=a.t_ax, h_ax=a.h_ax,
                                 data_axes=a.data_axes, overlap=self.overlap,
                                 comm_dtype=self.comm_dtype)
        if self.mesh is not None:
            return meg.row_parallel(self, y, w)
        return _einsum(y, w)

    def embed(self, table, ids, compute_dtype):
        """Vocab-parallel embedding lookup (core/hecaton.embed_2d).

        The vocab-partial collect rides the overlap lattice too (satellite of
        the seq-residual PR): ring ids-gather + ring reduce-scatter of the
        embedding partials.  Under the megatron seq layout the scatter lands
        the output directly in the canonical token-sharded residual."""
        if self.mesh is None:
            return jnp.take(table, ids, axis=0).astype(compute_dtype)
        a = self.ax
        B, S = ids.shape
        batch_ok = B % a.n_data == 0
        if self.pcfg.strategy == "hecaton":
            seq_ok = (self.mode != "decode" and S % a.size(a.t_ax) == 0
                      and S > 1)
            return hec.embed_2d(ids, table, mesh=self.mesh, t_ax=a.t_ax,
                                h_ax=a.h_ax, data_axes=a.data_axes,
                                compute_dtype=compute_dtype,
                                seq_sharded=seq_ok, batch_sharded=batch_ok,
                                overlap=self.overlap,
                                comm_dtype=self.comm_dtype)
        seq_ok = self.residual == "seq" and shd.seq_shardable(a, S)
        return hec.embed_2d(ids, table, mesh=self.mesh, t_ax="model",
                            h_ax=None, data_axes=a.data_axes,
                            compute_dtype=compute_dtype, seq_sharded=seq_ok,
                            batch_sharded=batch_ok, overlap=self.overlap,
                            comm_dtype=self.comm_dtype)

    def small_proj(self, x, w):
        """Tiny projection (mamba dt/B/C, routers) whose output dim is too small
        to 2D-tile: plain einsum from canonical layout; GSPMD sums the h_ax
        partials; output replicated over model axes (it is broadcast anyway)."""
        (w,) = self._cast(x, w)
        y = _einsum(x, w)
        return self.constraint(y, self.replicated_bsh())

    def lm_head(self, x, w):
        """Final projection to (sharded) vocab logits.

        hecaton: one seq-scatter linear — logits come out tokens-over-h_ax,
        vocab-over-t_ax; the fused loss consumes that layout directly.
        """
        (w,) = self._cast(x, w)
        if self.use_hecaton:
            a = self.ax
            return hec.linear_seq_scatter(x, w, mesh=self.mesh, t_ax=a.t_ax,
                                          h_ax=a.h_ax, data_axes=a.data_axes,
                                          overlap=self.overlap,
                                          comm_dtype=self.comm_dtype)
        if self.mesh is not None:
            return meg.col_parallel(self, x, w)   # vocab over model axis
        return _einsum(x, w)

    def logits_spec(self) -> Optional[P]:
        a = self.ax
        if a is None:
            return None
        d = shd._one(a.data_axes)
        if self.use_hecaton:
            return P(d, a.h_ax, a.t_ax)
        return P(d, None, shd._one(a.model_axes))

    # ------------------------------------------------------------------
    # attention layout
    # ------------------------------------------------------------------
    def attn_layout(self, n_heads: int, global_batch: int) -> shd.AttnLayout:
        a = self.ax
        if a is None:
            return shd.AttnLayout((), (), "single device")
        return shd.solve_attn_layout(a, n_heads,
                                     max(1, global_batch // a.n_data),
                                     prefer=self.pcfg.attn_layout)

    def heads_spec(self, layout: shd.AttnLayout) -> Optional[P]:
        """Spec for [B, S, n_heads, head_dim]."""
        if self.mesh is None:
            return None
        return layout.q_spec()

    # ------------------------------------------------------------------
    # param specs
    # ------------------------------------------------------------------
    def w_in_spec(self) -> Optional[P]:
        """Weight [H, O] consumed from canonical layout (QKV, up-proj, lm head)."""
        a = self.ax
        if a is None:
            return None
        if self.pcfg.strategy == "hecaton":
            return P(a.h_ax, a.t_ax)
        return P(None, "model")

    def w_out_spec(self) -> Optional[P]:
        """Weight of a mixer-out / second fused linear (swapped roles)."""
        a = self.ax
        if a is None:
            return None
        if self.pcfg.strategy == "hecaton":
            return P(a.t_ax, a.h_ax)
        return P("model", None)

    def vocab_spec(self) -> Optional[P]:
        return shd.vocab_spec(self.ax)

    def replicated(self) -> Optional[P]:
        return None if self.mesh is None else P()

    def replicated_bsh(self) -> Optional[P]:
        """[B,S,*] with only batch sharded (small broadcast tensors: B/C/dt)."""
        a = self.ax
        if a is None:
            return None
        d = a.data_axes[0] if len(a.data_axes) == 1 else a.data_axes
        return P(d, None, None)
