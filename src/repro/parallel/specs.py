"""PartitionSpec trees for params / optimizer states / batches / caches.

Specs are derived by walking the parameter tree (from ``jax.eval_shape``) and
pattern-matching leaf names — the single place where the paper's weight-tiling
rules (W[j,i] on die (i,j), transposed second fused layer, EPxTP expert tiling)
are spelled out.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ParallelConfig
from repro.parallel import sharding as shd
from repro.parallel import zero

# leaf-name -> role
W_IN = {"wq", "wk", "wv", "wq_a", "wq_b", "wkv_a", "wkv_b", "wz", "wx",
        "w1", "w1b", "w"}
W_MOE = {"we1", "we1b", "we2"}
W_OUT = {"wo", "w2"}
REPL = {"scale", "bias", "norm", "q_norm", "k_norm", "kv_norm", "A_log", "D",
        "dt_bias", "conv_w", "wB", "wC", "wdt", "router"}


def _leaf_spec(path: Tuple[str, ...], shape, ax: shd.AxisInfo,
               strategy: str, fused_loss: bool = False) -> P:
    name = path[-1]
    rank = len(shape)
    under_moe = name in W_MOE
    lead = rank - 2                                   # stacked layer dims
    if strategy == "hecaton":
        t, h = ax.t_ax, ax.h_ax
        if name == "table":
            return P(t, h)
        if fused_loss and len(path) >= 2 and path[-2] == "lm_head":
            return P(None, h)      # fused loss: vocab over h_ax, H unsharded
        if under_moe:
            # [*, E, H, F] or [*, E, F, H]: experts over t(mx), ffn width over h(my)
            if name in ("we1", "we1b"):
                return P(*([None] * (rank - 3)), t, None, h)
            return P(*([None] * (rank - 3)), t, h, None)
        if name in REPL:
            return P()
        if name in W_IN:
            return P(*([None] * lead), h, t)
        if name in W_OUT:
            return P(*([None] * lead), t, h)
        return P()
    # megatron 1D
    m = "model"
    if name == "table":
        return P(m, None)
    if under_moe:
        if name in ("we1", "we1b"):
            return P(*([None] * (rank - 3)), None, None, m)
        return P(*([None] * (rank - 3)), None, m, None)
    if name in REPL:
        return P()
    if name in W_IN:
        return P(*([None] * lead), None, m)
    if name in W_OUT:
        return P(*([None] * lead), m, None)
    return P()


def _path_names(kp) -> Tuple[str, ...]:
    out = []
    for k in kp:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return tuple(out)


def param_specs(params_shape, mesh: Optional[Mesh], pcfg: ParallelConfig):
    """Spec tree matching a params (or eval_shape) tree."""
    if mesh is None:
        return jax.tree.map(lambda _: None, params_shape)
    ax = shd.axis_info(mesh, pcfg.strategy)

    def f(kp, leaf):
        spec = _leaf_spec(_path_names(kp), leaf.shape, ax, pcfg.strategy,
                          fused_loss=getattr(pcfg, "fused_loss", False))
        if pcfg.fsdp:
            spec = zero.state_spec(spec, leaf.shape, ax.data_axes, mesh, True)
        return spec

    return jax.tree_util.tree_map_with_path(f, params_shape)


def opt_state_specs(pspecs, params_shape, mesh: Optional[Mesh],
                    pcfg: ParallelConfig):
    """AdamState specs: step + guard EWMA replicated scalars; mu/nu = param
    spec + data axis (ZeRO-1)."""
    if mesh is None:
        return None
    ax = shd.axis_info(mesh, pcfg.strategy)

    def f(spec, leaf):
        return zero.state_spec(spec, leaf.shape, ax.data_axes, mesh, pcfg.zero1)

    moment = jax.tree.map(f, pspecs, params_shape)
    from repro.optim.adamw import AdamState
    return AdamState(P(), moment, moment, P())


def batch_specs(mesh: Optional[Mesh], pcfg: ParallelConfig, *, microbatched: bool,
                keys=("tokens", "labels"), seq_len: Optional[int] = None):
    """Input batch specs: batch over data axes; sequence over the token axis.

    hecaton always token-scatters over ``t_ax``; megatron scatters over the
    ``model`` axis when the seq-sharded residual layout is active (and, when
    ``seq_len`` is given, divides the model ring) so inputs arrive already in
    the canonical block-boundary layout — no entry reshard."""
    if mesh is None:
        return {k: None for k in keys}
    ax = shd.axis_info(mesh, pcfg.strategy)
    d = shd._one(ax.data_axes)
    if pcfg.strategy == "hecaton":
        seq_ax = ax.t_ax
    elif pcfg.residual == "seq" and (seq_len is None
                                     or shd.seq_shardable(ax, seq_len)):
        seq_ax = shd._one(ax.model_axes)
    else:
        seq_ax = None
    lead = (None,) if microbatched else ()
    out = {}
    for k in keys:
        if k == "dropout_rng":
            out[k] = P(*lead)         # PRNG key(s): replicated, never sharded
        elif k in ("tokens", "labels", "loss_mask", "positions"):
            out[k] = P(*lead, d, seq_ax)
        elif k in ("patches", "frames"):
            out[k] = P(*lead, d, seq_ax, ax.h_ax if ax.h_ax else None)
        else:
            out[k] = P(*lead)
    return out


def sharding_tree(spec_tree, mesh: Optional[Mesh]):
    if mesh is None:
        return None
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s if s is not None else P()), spec_tree,
        is_leaf=lambda x: isinstance(x, P) or x is None)
