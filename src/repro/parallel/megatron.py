"""Megatron-style 1D tensor parallelism — the paper's baseline ("F" in Fig. 8).

Column-parallel then row-parallel linears over a single ``model`` axis.  The
CANONICAL inter-block activation layout is the *sequence-sharded* residual
stream (``ParallelConfig.residual == "seq"``, Korthikanti et al.): between
blocks the [B, S, H] residual lives at P(data, model, None) — tokens sharded
over the model ring — so pre-norm, dropout and the residual add all run on the
local 1/n token shard, and per-die activation memory for the layer scan
shrinks by 1/n.  Column-parallel becomes *gather-at-entry* (the sequence
all-gather fuses into the matmul as a ring AG-matmul under ``overlap``) and
row-parallel becomes *scatter-at-exit* (the output all-reduce is replaced by a
matmul ⊕ reduce-scatter of the sequence dim) — same byte volume as the flat
all-reduce, 2·(n-1)/n per element, but no model-replicated activation ever
materializes between blocks.

``residual == "replicated"`` restores the classic layout (activations
replicated over the model axis between blocks; the row output is all-reduced)
— exactly the property the paper criticizes in §V-A(b): per-device activation
memory does NOT shrink with N, which our memory_analysis dry-runs surface.
Decode (S=1) and sequence extents the model ring cannot divide fall back to
the replicated layout per call.

Overlap (``ParallelConfig.overlap`` != "none"): the baseline's collectives are
ring-decomposed too, so per-mode comparisons against hecaton stay apples to
apples.  In the seq layout the entry gather runs as a ring AG-matmul and the
exit reduce as a ring matmul-RS (core/overlap.py dispatchers — ``"fused"``
routes tile-aligned collective matmuls through the single-kernel Pallas
path); the backwards are the transposed rings, derived automatically by
differentiating through the unrolled ring loops.  In the replicated layout
the row-parallel all-reduce becomes matmul-RS ⊕ ring-AG over the 1D ``model``
ring, and the column-parallel backward's dx all-reduce becomes the transposed
ring via a ``custom_vjp`` (needed there because the replicated operands leave
the model axis unmentioned in the shard_map specs).  Shapes the ring cannot
chunk (hidden extent not divisible by the ring size, multi-axis ``model``
meshes, decode) fall back to the bulk path — the same degradation contract as
the hecaton ops.

The LM loss is fused over sequence shards too (:func:`fused_lm_loss_seq`):
instead of gathering the sequence at the lm_head and bulk-gathering the
sharded labels for a replicated xent, the head's vocab chunks ring over the
model axis while each device online-softmaxes its LOCAL token shard — labels
stay sharded end to end, closing the last block-boundary bulk collective of
the seq residual layout (the ROADMAP megatron leftover).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import overlap as OV
from repro.core import quant as Q
from repro.parallel import sharding as shd


def _einsum(x, w):
    return jnp.einsum("...h,ho->...o", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def _model_axes(pctx):
    a = pctx.ax
    return a.model_axes if len(a.model_axes) > 1 else a.model_axes[0]


def _dax(pctx):
    a = pctx.ax
    return a.data_axes[0] if len(a.data_axes) == 1 else a.data_axes


def _ring_info(pctx, h_total: int):
    """(axis_name, n) when the 1D model ring can decompose this linear's
    all-reduce (single model axis, ring size > 1, hidden chunks evenly);
    None routes the caller to the bulk path."""
    a = pctx.ax
    if pctx.overlap == "none" or a is None or len(a.model_axes) != 1:
        return None
    ax = a.model_axes[0]
    n = a.size(ax)
    if not OV.rs_ok(h_total, n):
        return None
    return ax, n


def _seq_ring(pctx, seq_len: int):
    """(axis_name, n) when the seq-sharded residual layout applies to this
    projection's sequence extent; None keeps the replicated-residual path
    (decode, non-dividing S, multi-axis model meshes)."""
    a = pctx.ax
    if pctx.residual != "seq" or a is None:
        return None
    if not shd.seq_shardable(a, seq_len):
        return None
    ax = a.model_axes[0]
    return ax, a.size(ax)


def col_parallel(pctx, x, w, interior: bool = False):
    """y = x @ W with W's output dim sharded over the model axes.

    Seq-sharded residual layout (the canonical): x arrives token-sharded
    P(d, model, None) and the sequence is gathered AT ENTRY, fused into the
    matmul as a ring AG-matmul under ``overlap`` (bulk all-gather otherwise);
    the backward's dx reduce-scatter is the transposed ring, for free.

    Replicated layout (or ``interior=True`` for projections that consume a
    mixer-interior full-sequence tensor, e.g. MLA's second q projection):
    forward is communication-free (x model-replicated, W column-sharded);
    under overlap the backward's dx all-reduce runs as the transposed ring
    (matmul-RS ⊕ ring-AG over hidden chunks) instead of a bulk collective.
    """
    if not interior:
        seq = _seq_ring(pctx, x.shape[1])
        if seq is not None:
            return _col_seq(pctx, x, w, seq)
    m, d = _model_axes(pctx), _dax(pctx)
    ring = _ring_info(pctx, x.shape[-1])
    if ring is not None:
        return _col_ring(pctx, x, w, ring)
    x = pctx.constraint(x, P(d, None, None))
    w = pctx.constraint(w, P(None, m))
    y = _einsum(x, w)
    return pctx.constraint(y, P(d, None, m))


def _col_seq(pctx, x, w, ring):
    """Gather-at-entry column parallel: AG the token shard over the model
    ring, fused into the matmul (``overlap`` != none) or bulk (none).

    Unlike the replicated-layout ring, every operand mentions the model axis
    in its shard_map spec (x on the sequence dim, w on the output dim), so
    differentiating straight through the shard_map yields the correct
    transposed ring — transpose(AG-matmul) = matmul-RS — with no custom_vjp.
    """
    ax, n = ring
    d = _dax(pctx)
    mesh, ov = pctx.mesh, pctx.overlap
    cd = pctx.comm_dtype
    x_spec, w_spec, y_spec = P(d, ax, None), P(None, ax), P(d, None, ax)

    def f(xl, wl):
        if ov != "none":
            return OV.ag_matmul(xl, wl, ax, dim=1, n=n, overlap=ov,
                                mesh_axes=mesh.axis_names, comm_dtype=cd)
        xg = lax.all_gather(xl, ax, axis=1, tiled=True)
        return _einsum(xg, wl)

    x = pctx.constraint(x, x_spec)
    return compat.shard_map(f, mesh, (x_spec, w_spec), y_spec)(
        x, w.astype(x.dtype))


def _col_ring(pctx, x, w, ring):
    # The custom_vjp wraps the shard_map calls from OUTSIDE: shard_map's own
    # transpose would conservatively psum cotangents over the unmentioned
    # model axis (check_rep=False), double-counting the ring-reduced dx.
    ax, n = ring
    d = _dax(pctx)
    a = pctx.ax
    mesh = pctx.mesh
    ov = pctx.overlap
    cd = pctx.comm_dtype
    x_spec, w_spec, y_spec = P(d, None, None), P(None, ax), P(d, None, ax)

    @jax.custom_vjp
    def col(xg, wg):
        return compat.shard_map(_einsum, mesh, (x_spec, w_spec),
                                y_spec)(xg, wg)

    def col_fwd(xg, wg):
        return col(xg, wg), (xg, wg)

    def col_bwd(res, dy):
        xg, wg = res

        def fx(dyl, wl):
            # dx = Σ_j dy_j · w_jᵀ: ring reduce over hidden chunks, then ring
            # AG back to the model-replicated layout — the bulk all-reduce's
            # bytes moved entirely as collective-permutes (fused kernel when
            # tile-aligned).
            part = OV.matmul_rs(dyl.astype(wl.dtype), wl.T, ax,
                                scatter_dim=2, n=n, overlap=ov,
                                mesh_axes=mesh.axis_names, comm_dtype=cd)
            return OV.ring_all_gather(part, ax, dim=2, n=n,
                                      bidir=ov == "bidir", comm_dtype=cd)

        def fw(xl, dyl):
            dw = jnp.einsum("bsh,bso->ho", xl, dyl.astype(xl.dtype),
                            preferred_element_type=jnp.float32)
            return lax.psum(dw, a.data_axes) if a.data_axes else dw

        dx = compat.shard_map(fx, mesh, (y_spec, w_spec), x_spec)(dy, wg)
        dw = compat.shard_map(fw, mesh, (x_spec, y_spec), w_spec)(xg, dy)
        return dx.astype(xg.dtype), dw.astype(wg.dtype)

    col.defvjp(col_fwd, col_bwd)
    x = pctx.constraint(x, P(d, None, None))
    return col(x, w.astype(x.dtype))


def col_parallel_shared(pctx, x, ws):
    """Several column-parallel projections of the SAME residual entry (QKV,
    MLA's q/kv down-projections, mamba's z/x), sharing ONE sequence gather.

    Seq layout: one shard_map ring-gathers the token shard once (pure
    ppermute ring under overlap, bulk AG otherwise) and every projection
    reads the gathered xg — entry NoP bytes are 1x instead of len(ws)x.  The
    backward needs only a single reduce-scatter: each dy_i @ w_iᵀ is local
    (w is sharded on its *output* dim), the per-device contributions sum at
    xg, and transpose(ring-AG) reduce-scatters them back to the token shard.
    Other layouts fall back to per-weight :func:`col_parallel`."""
    seq = _seq_ring(pctx, x.shape[1])
    if seq is None or len(ws) == 1:
        return tuple(col_parallel(pctx, x, w) for w in ws)
    ax, n = seq
    d = _dax(pctx)
    mesh, ov = pctx.mesh, pctx.overlap
    cd = pctx.comm_dtype
    x_spec, w_spec, y_spec = P(d, ax, None), P(None, ax), P(d, None, ax)

    def f(xl, *wls):
        if ov != "none":
            xg = OV.ring_all_gather(xl, ax, dim=1, n=n, bidir=ov == "bidir",
                                    comm_dtype=cd)
        else:
            xg = lax.all_gather(xl, ax, axis=1, tiled=True)
        return tuple(_einsum(xg, wl) for wl in wls)

    x = pctx.constraint(x, x_spec)
    return compat.shard_map(f, mesh, (x_spec,) + (w_spec,) * len(ws),
                            (y_spec,) * len(ws))(
        x, *[w.astype(x.dtype) for w in ws])


def row_parallel(pctx, y, w):
    """out = y @ W with W's input dim sharded; partial outputs reduced.

    Seq-sharded residual layout (the canonical): the model-axis reduction is a
    *scatter-at-exit* — matmul ⊕ reduce-scatter of the sequence dim (ring
    matmul-RS under ``overlap``), returning the residual token-sharded
    P(d, model, None).  Half the bulk all-reduce's exit bytes, and no
    model-replicated [B, S, H] is ever materialized.

    Replicated layout: output all-reduced to replicated.  Under overlap the
    all-reduce is decomposed into matmul-RS (contribution tiles folded into a
    circulating accumulator) followed by a ring all-gather of the reduced
    hidden chunks; the backward is local."""
    seq = _seq_ring(pctx, y.shape[1])
    if seq is not None:
        return _row_seq(pctx, y, w, seq)
    m, d = _model_axes(pctx), _dax(pctx)
    ring = _ring_info(pctx, w.shape[-1])
    if ring is not None:
        return _row_ring(pctx, y, w, ring)
    y = pctx.constraint(y, P(d, None, m))
    w = pctx.constraint(w, P(m, None))
    out = _einsum(y, w)
    # constraining to model-replicated forces GSPMD's all-reduce (flat ring on ICI)
    return pctx.constraint(out, P(d, None, None))


def _row_seq(pctx, y, w, ring):
    """Scatter-at-exit row parallel: the partial-sum reduction over the model
    ring reduce-scatters the SEQUENCE dim, restoring the token-sharded
    residual.  transpose(matmul-RS) = AG-matmul, so the backward re-gathers
    the cotangent sequence as a ring too — all differentiate-through."""
    ax, n = ring
    d = _dax(pctx)
    mesh, ov = pctx.mesh, pctx.overlap
    cd = pctx.comm_dtype
    y_spec, w_spec, o_spec = P(d, None, ax), P(ax, None), P(d, ax, None)

    def f(yl, wl):
        if ov != "none" and OV.rs_ok(yl.shape[1], n):
            return OV.matmul_rs(yl, wl, ax, scatter_dim=1, n=n, overlap=ov,
                                mesh_axes=mesh.axis_names, comm_dtype=cd)
        return lax.psum_scatter(_einsum(yl, wl), ax, scatter_dimension=1,
                                tiled=True)

    y = pctx.constraint(y, y_spec)
    return compat.shard_map(f, mesh, (y_spec, w_spec), o_spec)(
        y, w.astype(y.dtype))


def _row_ring(pctx, y, w, ring):
    ax, n = ring
    d = _dax(pctx)
    a = pctx.ax
    mesh = pctx.mesh
    ov = pctx.overlap
    cd = pctx.comm_dtype
    y_spec, w_spec, o_spec = P(d, None, ax), P(ax, None), P(d, None, None)

    @jax.custom_vjp
    def row(yg, wg):
        def f(yl, wl):
            part = OV.matmul_rs(yl, wl, ax, scatter_dim=2, n=n, overlap=ov,
                                mesh_axes=mesh.axis_names, comm_dtype=cd)
            return OV.ring_all_gather(part, ax, dim=2, n=n,
                                      bidir=ov == "bidir", comm_dtype=cd)
        return compat.shard_map(f, mesh, (y_spec, w_spec), o_spec)(yg, wg)

    def row_fwd(yg, wg):
        return row(yg, wg), (yg, wg)

    def row_bwd(res, dout):
        # dout is model-replicated and w row-sharded ⇒ backward is comm-free
        # on the model axis (the bulk path pays nothing here either).
        yg, wg = res

        def fy(doutl, wl):
            return jnp.einsum("bsh,fh->bsf", doutl.astype(wl.dtype), wl,
                              preferred_element_type=jnp.float32)

        def fw(yl, doutl):
            dw = jnp.einsum("bsf,bsh->fh", yl, doutl.astype(yl.dtype),
                            preferred_element_type=jnp.float32)
            return lax.psum(dw, a.data_axes) if a.data_axes else dw

        dy = compat.shard_map(fy, mesh, (o_spec, w_spec), y_spec)(dout, wg)
        dw = compat.shard_map(fw, mesh, (y_spec, o_spec), w_spec)(yg, dout)
        return dy.astype(yg.dtype), dw.astype(wg.dtype)

    row.defvjp(row_fwd, row_bwd)
    y = pctx.constraint(y, P(d, None, ax))
    return row(y, w.astype(y.dtype))


def seq_loss_ok(pctx, seq_len: int, vocab: int) -> bool:
    """Gate for :func:`fused_lm_loss_seq`: the seq-sharded residual layout
    must apply to this sequence extent AND the (padded) vocab must chunk
    evenly over the model ring so the circulating head-weight shards stay
    equal-sized."""
    seq = _seq_ring(pctx, seq_len)
    if seq is None:
        return False
    _, n = seq
    return n > 1 and vocab % n == 0


def fused_lm_loss_seq(pctx, x, w, labels, loss_mask):
    """Sequence-sharded fused LM loss for the megatron baseline — labels (and
    the final-norm hidden) never leave their token shard.

    The classic path gathers the sequence at the lm_head (col_parallel) and
    bulk-gathers the sharded int32 labels for the replicated xent — the last
    block-boundary bulk collective left in the seq residual layout (ROADMAP
    megatron leftover).  Here each device keeps its LOCAL token shard
    x [B, S/n, H] and its LOCAL vocab shard of the head W [H, V/n], and the
    ring circulates the *weight* chunks instead: at step k a device holds
    vocab chunk (i+k) mod n, folds the partial logits into an online-softmax
    accumulator (running max / sum-exp, hecaton's fused_lm_loss trick), picks
    up the gold logit when the label lands in the current chunk's vocab
    range, and ppermutes the chunk onward.  After n steps every token has its
    full-vocab lse and gold without any [tokens, V] logits, sequence gather,
    or label gather materializing — the HLO carries only collective-permutes
    (asserted by tests/test_overlap.py + the CI residual smoke check).  The
    backward differentiates through the unrolled ring (operands all mention
    the model axis, as in ``_col_seq``), so transpose(w-ring) is the reversed
    w-ring and dx stays token-sharded.

    Returns (masked NLL sum, mask count) as replicated scalars — the caller
    divides.  Callers must check :func:`seq_loss_ok` first.
    """
    ax, n = _seq_ring(pctx, x.shape[1])
    d = _dax(pctx)
    mesh = pctx.mesh
    cd = pctx.comm_dtype
    if loss_mask is None:
        loss_mask = jnp.ones(labels.shape, jnp.float32)
    data_axes = pctx.ax.data_axes

    def f(xl, wl, ll, ml):
        v_loc = wl.shape[1]
        b, s_loc, _ = xl.shape
        i = lax.axis_index(ax)

        def body(carry, k):
            m_run, s_run, gold, wk = carry
            lg = jnp.einsum("bth,hv->btv", xl, wk,
                            preferred_element_type=jnp.float32)
            v_off = ((i + k) % n) * v_loc
            mloc = lax.stop_gradient(jnp.max(lg, axis=-1))
            new_m = jnp.maximum(m_run, mloc)
            s_run = (s_run * jnp.exp(m_run - new_m)
                     + jnp.sum(jnp.exp(lg - new_m[..., None]), axis=-1))
            onehot = ((ll[..., None] - v_off)
                      == jnp.arange(v_loc)[None, None, :])
            gold = gold + jnp.sum(lg * onehot, axis=-1)
            # the circulating head-weight chunk rides the same quantized
            # wire as the activation rings (trailing dim is V/n >= 16)
            wk = Q.ring_hop(wk, ax, n, shift=-1, comm_dtype=cd)
            return (new_m, s_run, gold, wk), None

        body = jax.checkpoint(body)          # recompute the logits in bwd
        # -1e30 (not -inf): new_m at step 0 equals mloc, and a finite floor
        # keeps exp(m_run - new_m) free of inf-inf NaNs under AD
        init = (jnp.full((b, s_loc), -1e30, jnp.float32),
                jnp.zeros((b, s_loc), jnp.float32),
                jnp.zeros((b, s_loc), jnp.float32),
                wl)
        (m_run, s_run, gold, _), _ = lax.scan(body, init, jnp.arange(n))
        lse = m_run + jnp.log(s_run)
        wm = ml.astype(jnp.float32)
        axes = data_axes + (ax,)
        return (lax.psum(jnp.sum((lse - gold) * wm), axes),
                lax.psum(jnp.sum(wm), axes))

    x_spec = P(d, ax, None)
    l_spec = P(d, ax)
    return compat.shard_map(
        f, mesh, (x_spec, P(None, ax), l_spec, l_spec), (P(), P()))(
        pctx.constraint(x, x_spec), w.astype(x.dtype),
        pctx.constraint(labels, l_spec),
        pctx.constraint(loss_mask.astype(jnp.float32), l_spec))


def ffn(pctx, x, w1, w2, act_fn, w1b=None):
    """Column→row FFN.  Seq layout runs the whole block in ONE shard_map so
    the gated variant's two up-projections share a single entry gather of the
    token shard (zero extra communication for the gate — the same layer-fusion
    property hecaton's ffn_block has)."""
    seq = _seq_ring(pctx, x.shape[1])
    if seq is not None:
        return _ffn_seq(pctx, x, w1, w2, act_fn, w1b, seq)
    h = col_parallel(pctx, x, w1)
    if w1b is not None:
        h = act_fn(h) * col_parallel(pctx, x, w1b)
    else:
        h = act_fn(h)
    return row_parallel(pctx, h, w2)


def _ffn_seq(pctx, x, w1, w2, act_fn, w1b, ring):
    """Seq-sharded FFN: entry AG (ring, shared by the gated pair) → local
    column matmuls → exit matmul-RS of the sequence dim.  One gather + one
    scatter per block, both collective-permute chains under overlap."""
    ax, n = ring
    d = _dax(pctx)
    mesh, ov = pctx.mesh, pctx.overlap
    cd = pctx.comm_dtype

    def f(xl, w1l, w2l, *rest):
        bidir = ov == "bidir"
        if rest:                                   # gated: share the gathered x
            if ov != "none":
                xg = OV.ring_all_gather(xl, ax, dim=1, n=n, bidir=bidir,
                                        comm_dtype=cd)
            else:
                xg = lax.all_gather(xl, ax, axis=1, tiled=True)
            h = act_fn(_einsum(xg, w1l)) * _einsum(xg, rest[0])
        elif ov != "none":
            h = act_fn(OV.ag_matmul(xl, w1l, ax, dim=1, n=n, overlap=ov,
                                    mesh_axes=mesh.axis_names, comm_dtype=cd))
        else:
            xg = lax.all_gather(xl, ax, axis=1, tiled=True)
            h = act_fn(_einsum(xg, w1l))
        if ov != "none" and OV.rs_ok(h.shape[1], n):
            return OV.matmul_rs(h, w2l, ax, scatter_dim=1, n=n, overlap=ov,
                                mesh_axes=mesh.axis_names, comm_dtype=cd)
        return lax.psum_scatter(_einsum(h, w2l), ax, scatter_dimension=1,
                                tiled=True)

    x_spec = P(d, ax, None)
    in_specs = [x_spec, P(None, ax), P(ax, None)]
    args = [pctx.constraint(x, x_spec), w1.astype(x.dtype), w2.astype(x.dtype)]
    if w1b is not None:
        in_specs.append(P(None, ax))
        args.append(w1b.astype(x.dtype))
    return compat.shard_map(f, mesh, tuple(in_specs), x_spec)(*args)
