"""Megatron-style 1D tensor parallelism — the paper's baseline ("F" in Fig. 8).

Column-parallel then row-parallel linears over a single ``model`` axis; the row
output is all-reduced (GSPMD inserts the flat-ring all-reduce when we constrain the
output back to the model-replicated layout).  Activations are replicated over the
model axis — exactly the property the paper criticizes in §V-A(b): per-device
activation memory does NOT shrink with N, which our memory_analysis dry-runs surface.

An optional *sequence-parallel* variant (Korthikanti et al.) is provided as a
beyond-paper optimization knob for the baseline: activations outside matmuls are
sharded over the sequence dim, turning each all-reduce into AG+RS (same volume as
flat-ring all-reduce, lower memory).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P


def _einsum(x, w):
    return jnp.einsum("...h,ho->...o", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def _model_axes(pctx):
    a = pctx.ax
    return a.model_axes if len(a.model_axes) > 1 else a.model_axes[0]


def _dax(pctx):
    a = pctx.ax
    return a.data_axes[0] if len(a.data_axes) == 1 else a.data_axes


def col_parallel(pctx, x, w):
    """y = x @ W with W's output dim sharded over the model axes."""
    m, d = _model_axes(pctx), _dax(pctx)
    x = pctx.constraint(x, P(d, None, None))
    w = pctx.constraint(w, P(None, m))
    y = _einsum(x, w)
    return pctx.constraint(y, P(d, None, m))


def row_parallel(pctx, y, w):
    """out = y @ W with W's input dim sharded; output all-reduced to replicated."""
    m, d = _model_axes(pctx), _dax(pctx)
    y = pctx.constraint(y, P(d, None, m))
    w = pctx.constraint(w, P(m, None))
    out = _einsum(y, w)
    # constraining to model-replicated forces GSPMD's all-reduce (flat ring on ICI)
    return pctx.constraint(out, P(d, None, None))


def ffn(pctx, x, w1, w2, act_fn, w1b=None):
    h = col_parallel(pctx, x, w1)
    if w1b is not None:
        h = act_fn(h) * col_parallel(pctx, x, w1b)
    else:
        h = act_fn(h)
    return row_parallel(pctx, h, w2)
