"""Megatron-style 1D tensor parallelism — the paper's baseline ("F" in Fig. 8).

Column-parallel then row-parallel linears over a single ``model`` axis; the row
output is all-reduced (GSPMD inserts the flat-ring all-reduce when we constrain the
output back to the model-replicated layout).  Activations are replicated over the
model axis — exactly the property the paper criticizes in §V-A(b): per-device
activation memory does NOT shrink with N, which our memory_analysis dry-runs surface.

An optional *sequence-parallel* variant (Korthikanti et al.) is provided as a
beyond-paper optimization knob for the baseline: activations outside matmuls are
sharded over the sequence dim, turning each all-reduce into AG+RS (same volume as
flat-ring all-reduce, lower memory).

Overlap (``ParallelConfig.overlap`` != "none"): the baseline's collectives are
ring-decomposed too, so per-mode comparisons against hecaton stay apples to
apples.  The row-parallel all-reduce becomes matmul-RS ⊕ ring-AG over the
1D ``model`` ring (core/overlap.py dispatchers — ``"fused"`` routes the
matmul-RS through the single-kernel Pallas path when tile-aligned), and the
column-parallel backward's dx all-reduce becomes the transposed ring via a
``custom_vjp``.  Byte volume is identical to the bulk all-reduce
(2·(n-1)/n per element); every transfer is a collective-permute.  Shapes the
ring cannot chunk (hidden extent not divisible by the ring size, multi-axis
``model`` meshes, decode) fall back to the bulk path — the same degradation
contract as the hecaton ops.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import overlap as OV


def _einsum(x, w):
    return jnp.einsum("...h,ho->...o", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def _model_axes(pctx):
    a = pctx.ax
    return a.model_axes if len(a.model_axes) > 1 else a.model_axes[0]


def _dax(pctx):
    a = pctx.ax
    return a.data_axes[0] if len(a.data_axes) == 1 else a.data_axes


def _ring_info(pctx, h_total: int):
    """(axis_name, n) when the 1D model ring can decompose this linear's
    all-reduce (single model axis, ring size > 1, hidden chunks evenly);
    None routes the caller to the bulk path."""
    a = pctx.ax
    if pctx.overlap == "none" or a is None or len(a.model_axes) != 1:
        return None
    ax = a.model_axes[0]
    n = a.size(ax)
    if not OV.rs_ok(h_total, n):
        return None
    return ax, n


def col_parallel(pctx, x, w):
    """y = x @ W with W's output dim sharded over the model axes.

    Forward is communication-free (x model-replicated, W column-sharded);
    under overlap the backward's dx all-reduce runs as the transposed ring
    (matmul-RS ⊕ ring-AG over hidden chunks) instead of a bulk collective.
    """
    m, d = _model_axes(pctx), _dax(pctx)
    ring = _ring_info(pctx, x.shape[-1])
    if ring is not None:
        return _col_ring(pctx, x, w, ring)
    x = pctx.constraint(x, P(d, None, None))
    w = pctx.constraint(w, P(None, m))
    y = _einsum(x, w)
    return pctx.constraint(y, P(d, None, m))


def _col_ring(pctx, x, w, ring):
    # The custom_vjp wraps the shard_map calls from OUTSIDE: shard_map's own
    # transpose would conservatively psum cotangents over the unmentioned
    # model axis (check_rep=False), double-counting the ring-reduced dx.
    ax, n = ring
    d = _dax(pctx)
    a = pctx.ax
    mesh = pctx.mesh
    ov = pctx.overlap
    x_spec, w_spec, y_spec = P(d, None, None), P(None, ax), P(d, None, ax)

    @jax.custom_vjp
    def col(xg, wg):
        return compat.shard_map(_einsum, mesh, (x_spec, w_spec),
                                y_spec)(xg, wg)

    def col_fwd(xg, wg):
        return col(xg, wg), (xg, wg)

    def col_bwd(res, dy):
        xg, wg = res

        def fx(dyl, wl):
            # dx = Σ_j dy_j · w_jᵀ: ring reduce over hidden chunks, then ring
            # AG back to the model-replicated layout — the bulk all-reduce's
            # bytes moved entirely as collective-permutes (fused kernel when
            # tile-aligned).
            part = OV.matmul_rs(dyl.astype(wl.dtype), wl.T, ax,
                                scatter_dim=2, n=n, overlap=ov,
                                mesh_axes=mesh.axis_names)
            return OV.ring_all_gather(part, ax, dim=2, n=n,
                                      bidir=ov == "bidir")

        def fw(xl, dyl):
            dw = jnp.einsum("bsh,bso->ho", xl, dyl.astype(xl.dtype),
                            preferred_element_type=jnp.float32)
            return lax.psum(dw, a.data_axes) if a.data_axes else dw

        dx = compat.shard_map(fx, mesh, (y_spec, w_spec), x_spec)(dy, wg)
        dw = compat.shard_map(fw, mesh, (x_spec, y_spec), w_spec)(xg, dy)
        return dx.astype(xg.dtype), dw.astype(wg.dtype)

    col.defvjp(col_fwd, col_bwd)
    x = pctx.constraint(x, P(d, None, None))
    return col(x, w.astype(x.dtype))


def row_parallel(pctx, y, w):
    """out = y @ W with W's input dim sharded; output all-reduced to replicated.

    Under overlap the all-reduce is decomposed into matmul-RS (contribution
    tiles folded into a circulating accumulator) followed by a ring
    all-gather of the reduced hidden chunks; the backward is local."""
    m, d = _model_axes(pctx), _dax(pctx)
    ring = _ring_info(pctx, w.shape[-1])
    if ring is not None:
        return _row_ring(pctx, y, w, ring)
    y = pctx.constraint(y, P(d, None, m))
    w = pctx.constraint(w, P(m, None))
    out = _einsum(y, w)
    # constraining to model-replicated forces GSPMD's all-reduce (flat ring on ICI)
    return pctx.constraint(out, P(d, None, None))


def _row_ring(pctx, y, w, ring):
    ax, n = ring
    d = _dax(pctx)
    a = pctx.ax
    mesh = pctx.mesh
    ov = pctx.overlap
    y_spec, w_spec, o_spec = P(d, None, ax), P(ax, None), P(d, None, None)

    @jax.custom_vjp
    def row(yg, wg):
        def f(yl, wl):
            part = OV.matmul_rs(yl, wl, ax, scatter_dim=2, n=n, overlap=ov,
                                mesh_axes=mesh.axis_names)
            return OV.ring_all_gather(part, ax, dim=2, n=n,
                                      bidir=ov == "bidir")
        return compat.shard_map(f, mesh, (y_spec, w_spec), o_spec)(yg, wg)

    def row_fwd(yg, wg):
        return row(yg, wg), (yg, wg)

    def row_bwd(res, dout):
        # dout is model-replicated and w row-sharded ⇒ backward is comm-free
        # on the model axis (the bulk path pays nothing here either).
        yg, wg = res

        def fy(doutl, wl):
            return jnp.einsum("bsh,fh->bsf", doutl.astype(wl.dtype), wl,
                              preferred_element_type=jnp.float32)

        def fw(yl, doutl):
            dw = jnp.einsum("bsf,bsh->fh", yl, doutl.astype(yl.dtype),
                            preferred_element_type=jnp.float32)
            return lax.psum(dw, a.data_axes) if a.data_axes else dw

        dy = compat.shard_map(fy, mesh, (o_spec, w_spec), y_spec)(dout, wg)
        dw = compat.shard_map(fw, mesh, (y_spec, o_spec), w_spec)(yg, dout)
        return dy.astype(yg.dtype), dw.astype(wg.dtype)

    row.defvjp(row_fwd, row_bwd)
    y = pctx.constraint(y, P(d, None, ax))
    return row(y, w.astype(y.dtype))


def ffn(pctx, x, w1, w2, act_fn, w1b=None):
    h = col_parallel(pctx, x, w1)
    if w1b is not None:
        h = act_fn(h) * col_parallel(pctx, x, w1b)
    else:
        h = act_fn(h)
    return row_parallel(pctx, h, w2)
