"""ZeRO-1 optimizer-state partitioning + gradient-compression hooks.

The paper's off-package-bandwidth argument (§III-A c: DRAM channels scale with the
package perimeter) maps on TPU to per-chip state sharding: optimizer moments are
sharded over the *data* axis on top of the model-parallel sharding, so per-chip
optimizer bytes shrink with the full device count.

``state_spec`` derives the moment PartitionSpec from the parameter spec by adding
the data axis to the largest still-divisible unsharded dim.  With pjit, assigning
these shardings makes GSPMD reduce-scatter gradients and all-gather updated params
— classic ZeRO-1 with zero hand-written collectives.

``compress_grads``/``decompress_grads`` optionally cast the cross-data-axis
gradient reduction payload to bf16 (2x comm) — the "gradient compression" lever.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def state_spec(param_spec: Optional[P], shape, data_axes, mesh: Mesh,
               zero1: bool) -> Optional[P]:
    """Moment spec = param spec (+ data axis on the first shardable dim)."""
    if param_spec is None:
        param_spec = P()
    if not zero1 or not data_axes:
        return param_spec
    used = set()
    for e in param_spec:
        if e is None:
            continue
        used.update(e if isinstance(e, tuple) else (e,))
    if any(a in used for a in data_axes):
        return param_spec          # already data-sharded (FSDP params)
    entries = list(param_spec) + [None] * (len(shape) - len(param_spec))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dsize = 1
    for a in data_axes:
        dsize *= sizes[a]
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if e is None and dim % dsize == 0 and dim >= dsize:
            entries[i] = data_axes[0] if len(data_axes) == 1 else tuple(data_axes)
            return P(*entries)
        if e is not None:
            cur = e if isinstance(e, tuple) else (e,)
            csize = 1
            for a in cur:
                csize *= sizes[a]
            if dim % (csize * dsize) == 0:
                entries[i] = tuple(cur) + tuple(data_axes)
                return P(*entries)
    return param_spec     # nothing divisible: fall back to param sharding


def compress_grads(grads, dtype_name: str):
    if dtype_name == "fp32":
        return grads
    if dtype_name == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    raise KeyError(dtype_name)


def decompress_grads(grads):
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)
