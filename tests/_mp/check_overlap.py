"""Subprocess numerics check: ring/bidir/fused overlapped hecaton ops == bulk
path == dense reference, forward AND gradient, on a fake 8-device topology.

Covers an asymmetric 4x2 hecaton grid (different ring sizes per axis), odd
shard extents (bidir must degrade to the unidirectional ring per collective;
fused handles them via degraded tile sizes), the fused LM loss's per-chunk
contraction gather, and — for "fused" — the Pallas ring kernels running their
interpret/ppermute-emulated path (kernels/ring_matmul.py).

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import hecaton as H

TOL = dict(rtol=2e-5, atol=2e-5)


def _close(a, b, name):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), err_msg=name,
                               **TOL)


def check_ops(mesh, B, T, Hd, O, tag):
    key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (B, T, Hd), jnp.float32)
    w = jax.random.normal(k2, (Hd, O), jnp.float32) / np.sqrt(Hd)
    w2 = jax.random.normal(k3, (O, Hd), jnp.float32) / np.sqrt(O)
    wb = jax.random.normal(k4, (Hd, O), jnp.float32) / np.sqrt(Hd)
    xs = jax.device_put(x, NamedSharding(mesh, P("data", "mx", "my")))
    ws = jax.device_put(w, NamedSharding(mesh, P("my", "mx")))
    w2s = jax.device_put(w2, NamedSharding(mesh, P("mx", "my")))
    wbs = jax.device_put(wb, NamedSharding(mesh, P("my", "mx")))

    for ov in ("ring", "bidir", "fused"):
        kw = dict(mesh=mesh, t_ax="mx", h_ax="my", overlap=ov)

        def lin(x, w, _kw=kw):
            return H.linear_seq_scatter(x, w, **_kw)

        _close(jax.jit(lin)(xs, ws), x @ w, f"{tag}/{ov} linear fwd")
        gh = jax.jit(jax.grad(lambda a, b: lin(a, b).sum(),
                              argnums=(0, 1)))(xs, ws)
        gr = jax.grad(lambda a, b: (a @ b).sum(), argnums=(0, 1))(x, w)
        for got, want in zip(gh, gr):
            _close(got, want, f"{tag}/{ov} linear grad")

        def mix(x, w, w2, _kw=kw):
            a = H.mixer_in(x, w, **_kw)
            return H.mixer_out(jnp.tanh(a), w2, **_kw)

        def mix_ref(x, w, w2):
            return jnp.tanh(x @ w) @ w2

        _close(jax.jit(mix)(xs, ws, w2s), mix_ref(x, w, w2),
               f"{tag}/{ov} mixer fwd")
        gm = jax.jit(jax.grad(lambda *a: mix(*a).sum(),
                              argnums=(0, 1, 2)))(xs, ws, w2s)
        gmr = jax.grad(lambda *a: mix_ref(*a).sum(),
                       argnums=(0, 1, 2))(x, w, w2)
        for got, want in zip(gm, gmr):
            _close(got, want, f"{tag}/{ov} mixer grad")

        def ffn(x, w1, w2, wb, _kw=kw):
            return H.ffn_block(x, w1, w2, act_fn=jax.nn.silu, w1b=wb, **_kw)

        def ffn_ref(x, w1, w2, wb):
            return (jax.nn.silu(x @ w1) * (x @ wb)) @ w2

        _close(jax.jit(ffn)(xs, ws, w2s, wbs), ffn_ref(x, w, w2, wb),
               f"{tag}/{ov} ffn fwd")
        gf = jax.jit(jax.grad(lambda *a: ffn(*a).sum(),
                              argnums=(0, 1, 2, 3)))(xs, ws, w2s, wbs)
        gfr = jax.grad(lambda *a: ffn_ref(*a).sum(),
                       argnums=(0, 1, 2, 3))(x, w, w2, wb)
        for got, want in zip(gf, gfr):
            _close(got, want, f"{tag}/{ov} ffn grad")
        print(f"{tag}: {ov} linear/mixer/ffn fwd+grad OK")


def check_fused_loss(mesh):
    key = jax.random.PRNGKey(1)
    B, S, Hd, V = 4, 8, 16, 32
    x = jax.random.normal(key, (B, S, Hd), jnp.float32)
    w = jax.random.normal(key, (Hd, V), jnp.float32)
    lab = jax.random.randint(key, (B, S), 0, V)
    xs = jax.device_put(x, NamedSharding(mesh, P("data", "mx", "my")))
    ws = jax.device_put(w, NamedSharding(mesh, P(None, "my")))
    ls = jax.device_put(lab, NamedSharding(mesh, P("data", "mx")))

    def mkloss(ov):
        def loss(x, w):
            nll, cnt = H.fused_lm_loss(x, w, ls, None, mesh=mesh, t_ax="mx",
                                       h_ax="my", overlap=ov)
            return nll / cnt
        return loss

    ref = jax.jit(mkloss("none"))(xs, ws)
    gref = jax.jit(jax.grad(mkloss("none"), argnums=(0, 1)))(xs, ws)
    for ov in ("ring", "bidir", "fused"):
        np.testing.assert_allclose(float(jax.jit(mkloss(ov))(xs, ws)),
                                   float(ref), rtol=1e-6)
        g = jax.jit(jax.grad(mkloss(ov), argnums=(0, 1)))(xs, ws)
        for got, want in zip(g, gref):
            _close(got, want, f"fused_lm_loss/{ov} grad")
        print(f"fused_lm_loss: {ov} fwd+grad OK")


def main():
    devs = np.array(jax.devices())
    # asymmetric grid: mx ring of 4, my ring of 2; even shard extents
    mesh_a = Mesh(devs.reshape(1, 4, 2), ("data", "mx", "my"))
    check_ops(mesh_a, B=2, T=16, Hd=24, O=32, tag="grid4x2")
    # odd shard extents: t_loc = 12/4 = 3 — bidir cannot halve the circulating
    # token shard and must degrade to the unidirectional ring (same numerics)
    check_ops(mesh_a, B=2, T=12, Hd=24, O=16, tag="grid4x2-oddshard")
    # square grid + fused loss (contract-dim ring gather inside scan+remat)
    mesh_b = Mesh(devs.reshape(2, 2, 2), ("data", "mx", "my"))
    check_ops(mesh_b, B=4, T=8, Hd=16, O=24, tag="grid2x2")
    check_fused_loss(mesh_b)
    # degenerate my=1 ring: RS side falls back to the (singleton) bulk path
    mesh_c = Mesh(devs.reshape(2, 4, 1), ("data", "mx", "my"))
    check_ops(mesh_c, B=4, T=8, Hd=16, O=8, tag="grid4x1")
    print("ALL OVERLAP NUMERICS CHECKS PASSED")


if __name__ == "__main__":
    main()
