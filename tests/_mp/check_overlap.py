"""Subprocess numerics check: ring/bidir/fused overlapped hecaton ops == bulk
path == dense reference, forward AND gradient, on a fake 8-device topology.

Covers an asymmetric 4x2 hecaton grid (different ring sizes per axis), odd
shard extents (bidir must degrade to the unidirectional ring per collective;
fused handles them via degraded tile sizes), the fused LM loss's per-chunk
contraction gather, and — for "fused" — the Pallas ring kernels running their
interpret/ppermute-emulated path (kernels/ring_matmul.py).

Also checks the residual-stream layouts: the megatron baseline under
``ParallelConfig.residual`` seq vs replicated (gather-at-entry col /
scatter-at-exit row, all overlap modes) on 1x8 / 2x4 / 4x2 model rings,
embed_2d's overlapped vocab scatter, and a full-model train loss+grad on a
megatron mesh in both layouts — everything against the single-device dense
reference.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import hecaton as H

TOL = dict(rtol=2e-5, atol=2e-5)


def _close(a, b, name):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), err_msg=name,
                               **TOL)


def check_ops(mesh, B, T, Hd, O, tag):
    key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (B, T, Hd), jnp.float32)
    w = jax.random.normal(k2, (Hd, O), jnp.float32) / np.sqrt(Hd)
    w2 = jax.random.normal(k3, (O, Hd), jnp.float32) / np.sqrt(O)
    wb = jax.random.normal(k4, (Hd, O), jnp.float32) / np.sqrt(Hd)
    xs = jax.device_put(x, NamedSharding(mesh, P("data", "mx", "my")))
    ws = jax.device_put(w, NamedSharding(mesh, P("my", "mx")))
    w2s = jax.device_put(w2, NamedSharding(mesh, P("mx", "my")))
    wbs = jax.device_put(wb, NamedSharding(mesh, P("my", "mx")))

    for ov in ("ring", "bidir", "fused"):
        kw = dict(mesh=mesh, t_ax="mx", h_ax="my", overlap=ov)

        def lin(x, w, _kw=kw):
            return H.linear_seq_scatter(x, w, **_kw)

        _close(jax.jit(lin)(xs, ws), x @ w, f"{tag}/{ov} linear fwd")
        gh = jax.jit(jax.grad(lambda a, b: lin(a, b).sum(),
                              argnums=(0, 1)))(xs, ws)
        gr = jax.grad(lambda a, b: (a @ b).sum(), argnums=(0, 1))(x, w)
        for got, want in zip(gh, gr):
            _close(got, want, f"{tag}/{ov} linear grad")

        def mix(x, w, w2, _kw=kw):
            a = H.mixer_in(x, w, **_kw)
            return H.mixer_out(jnp.tanh(a), w2, **_kw)

        def mix_ref(x, w, w2):
            return jnp.tanh(x @ w) @ w2

        _close(jax.jit(mix)(xs, ws, w2s), mix_ref(x, w, w2),
               f"{tag}/{ov} mixer fwd")
        gm = jax.jit(jax.grad(lambda *a: mix(*a).sum(),
                              argnums=(0, 1, 2)))(xs, ws, w2s)
        gmr = jax.grad(lambda *a: mix_ref(*a).sum(),
                       argnums=(0, 1, 2))(x, w, w2)
        for got, want in zip(gm, gmr):
            _close(got, want, f"{tag}/{ov} mixer grad")

        def ffn(x, w1, w2, wb, _kw=kw):
            return H.ffn_block(x, w1, w2, act_fn=jax.nn.silu, w1b=wb, **_kw)

        def ffn_ref(x, w1, w2, wb):
            return (jax.nn.silu(x @ w1) * (x @ wb)) @ w2

        _close(jax.jit(ffn)(xs, ws, w2s, wbs), ffn_ref(x, w, w2, wb),
               f"{tag}/{ov} ffn fwd")
        gf = jax.jit(jax.grad(lambda *a: ffn(*a).sum(),
                              argnums=(0, 1, 2, 3)))(xs, ws, w2s, wbs)
        gfr = jax.grad(lambda *a: ffn_ref(*a).sum(),
                       argnums=(0, 1, 2, 3))(x, w, w2, wb)
        for got, want in zip(gf, gfr):
            _close(got, want, f"{tag}/{ov} ffn grad")
        print(f"{tag}: {ov} linear/mixer/ffn fwd+grad OK")


def check_fused_loss(mesh):
    key = jax.random.PRNGKey(1)
    B, S, Hd, V = 4, 8, 16, 32
    x = jax.random.normal(key, (B, S, Hd), jnp.float32)
    w = jax.random.normal(key, (Hd, V), jnp.float32)
    lab = jax.random.randint(key, (B, S), 0, V)
    xs = jax.device_put(x, NamedSharding(mesh, P("data", "mx", "my")))
    ws = jax.device_put(w, NamedSharding(mesh, P(None, "my")))
    ls = jax.device_put(lab, NamedSharding(mesh, P("data", "mx")))

    def mkloss(ov):
        def loss(x, w):
            nll, cnt = H.fused_lm_loss(x, w, ls, None, mesh=mesh, t_ax="mx",
                                       h_ax="my", overlap=ov)
            return nll / cnt
        return loss

    ref = jax.jit(mkloss("none"))(xs, ws)
    gref = jax.jit(jax.grad(mkloss("none"), argnums=(0, 1)))(xs, ws)
    for ov in ("ring", "bidir", "fused"):
        np.testing.assert_allclose(float(jax.jit(mkloss(ov))(xs, ws)),
                                   float(ref), rtol=1e-6)
        g = jax.jit(jax.grad(mkloss(ov), argnums=(0, 1)))(xs, ws)
        for got, want in zip(g, gref):
            _close(got, want, f"fused_lm_loss/{ov} grad")
        print(f"fused_lm_loss: {ov} fwd+grad OK")


def check_megatron_residual(mesh, tag):
    """meg col→row mixer + gated ffn, seq vs replicated residual, all modes."""
    from repro.config import ParallelConfig
    from repro.parallel import megatron as MEG
    from repro.parallel.context import PCtx

    n_d = mesh.shape["data"]
    n_m = mesh.shape["model"]
    B, S, Hd, F = 2 * n_d, 16, 24, 48     # S divides every model ring tested
    key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (B, S, Hd), jnp.float32)
    w1 = jax.random.normal(k2, (Hd, F), jnp.float32) / np.sqrt(Hd)
    w2 = jax.random.normal(k3, (F, Hd), jnp.float32) / np.sqrt(F)
    wb = jax.random.normal(k4, (Hd, F), jnp.float32) / np.sqrt(Hd)

    def ffn_ref(x, w1, w2, wb):
        return (jax.nn.silu(x @ w1) * (x @ wb)) @ w2

    def mix_ref(x, w1, w2):
        return jnp.tanh(x @ w1) @ w2

    gfr = jax.grad(lambda *a: ffn_ref(*a).sum(), argnums=(0, 1, 2, 3))(
        x, w1, w2, wb)
    gmr = jax.grad(lambda *a: mix_ref(*a).sum(), argnums=(0, 1, 2))(x, w1, w2)

    for residual in ("replicated", "seq"):
        for ov in ("none", "ring", "bidir", "fused"):
            pctx = PCtx(mesh=mesh, pcfg=ParallelConfig(
                strategy="megatron", data=n_d, model=n_m, overlap=ov,
                residual=residual, zero1=False), mode="train")

            def ffn(x, w1, w2, wb, _p=pctx):
                return MEG.ffn(_p, x, w1, w2, jax.nn.silu, wb)

            def mix(x, w1, w2, _p=pctx):
                a = MEG.col_parallel(_p, x, w1)
                return MEG.row_parallel(_p, jnp.tanh(a), w2)

            _close(jax.jit(ffn)(x, w1, w2, wb), ffn_ref(x, w1, w2, wb),
                   f"{tag}/{residual}/{ov} ffn fwd")
            gf = jax.jit(jax.grad(lambda *a: ffn(*a).sum(),
                                  argnums=(0, 1, 2, 3)))(x, w1, w2, wb)
            for got, want in zip(gf, gfr):
                _close(got, want, f"{tag}/{residual}/{ov} ffn grad")
            _close(jax.jit(mix)(x, w1, w2), mix_ref(x, w1, w2),
                   f"{tag}/{residual}/{ov} mixer fwd")
            gm = jax.jit(jax.grad(lambda *a: mix(*a).sum(),
                                  argnums=(0, 1, 2)))(x, w1, w2)
            for got, want in zip(gm, gmr):
                _close(got, want, f"{tag}/{residual}/{ov} mixer grad")
        print(f"{tag}: megatron {residual} residual fwd+grad OK")


def check_megatron_fused_seq_loss(mesh, tag):
    """fused_lm_loss_seq (labels stay sharded; head vocab chunks ring over
    the model axis) == dense masked-xent reference, fwd+grad, all modes."""
    from repro.config import ParallelConfig
    from repro.parallel import megatron as MEG
    from repro.parallel.context import PCtx

    n_d, n_m = mesh.shape["data"], mesh.shape["model"]
    B, S, Hd, V = 2 * n_d, 16, 32, 64 * n_m
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, Hd), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (Hd, V),
                          jnp.float32) / np.sqrt(Hd)
    lab = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, V)
    mask = (jax.random.uniform(jax.random.PRNGKey(3), (B, S))
            > 0.3).astype(jnp.float32)

    def ref(x, w):
        lf = jnp.einsum("bth,hv->btv", x, w,
                        preferred_element_type=jnp.float32)
        m = jax.lax.stop_gradient(jnp.max(lf, -1, keepdims=True))
        lse = jnp.squeeze(m, -1) + jnp.log(jnp.sum(jnp.exp(lf - m), -1))
        gold = jnp.sum(lf * jax.nn.one_hot(lab, V, dtype=jnp.float32), -1)
        return jnp.sum((lse - gold) * mask) / jnp.sum(mask)

    gr = jax.grad(ref, argnums=(0, 1))(x, w)
    for ov in ("none", "ring", "bidir", "fused"):
        pctx = PCtx(mesh, ParallelConfig(
            strategy="megatron", data=n_d, model=n_m, residual="seq",
            overlap=ov, zero1=False), "train")
        assert MEG.seq_loss_ok(pctx, S, V), (tag, ov)

        def loss(x, w, _p=pctx):
            nll, cnt = MEG.fused_lm_loss_seq(_p, x, w, lab, mask)
            return nll / cnt

        np.testing.assert_allclose(float(jax.jit(loss)(x, w)),
                                   float(ref(x, w)), rtol=1e-6,
                                   err_msg=f"{tag}/{ov} seq loss")
        g = jax.jit(jax.grad(loss, argnums=(0, 1)))(x, w)
        for got, want in zip(g, gr):
            _close(got, want, f"{tag}/{ov} seq loss grad")
    print(f"{tag}: fused_lm_loss_seq fwd+grad all modes OK")


def check_megatron_model(mesh):
    """Full-model train loss + grads, seq vs replicated residual, vs ref."""
    from repro.config import ModelConfig, ParallelConfig
    from repro.models import lm
    from repro.parallel import specs as SP
    from repro.parallel.context import PCtx

    cfg = ModelConfig(name="res-test", family="dense", num_layers=2,
                      d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                      vocab_size=64, mlp_kind="swiglu", qk_norm=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1),
             "_dtype": jnp.float32}
    pctx1 = PCtx(None, ParallelConfig(data=1, model=1, mx=1, my=1))
    ref, _ = lm.train_loss(pctx1, cfg, params, batch, remat="none")
    gref = jax.grad(lambda p: lm.train_loss(pctx1, cfg, p, batch,
                                            remat="none")[0])(params)

    n_d, n_m = mesh.shape["data"], mesh.shape["model"]
    for residual in ("replicated", "seq"):
        for ov in ("none", "ring", "fused"):
            pcfg = ParallelConfig(strategy="megatron", data=n_d, model=n_m,
                                  overlap=ov, residual=residual, zero1=False)
            pspecs = SP.param_specs(params, mesh, pcfg)
            params_s = jax.device_put(params, SP.sharding_tree(pspecs, mesh))
            bsp = SP.batch_specs(mesh, pcfg, microbatched=False, seq_len=16)
            batch_s = {k: jax.device_put(batch[k],
                                         NamedSharding(mesh, bsp[k]))
                       for k in ("tokens", "labels")}
            pctx = PCtx(mesh, pcfg, "train")

            def loss(p, b, _pctx=pctx):
                return lm.train_loss(_pctx, cfg, p,
                                     {**b, "_dtype": jnp.float32},
                                     remat="none")[0]

            got = jax.jit(loss)(params_s, batch_s)
            np.testing.assert_allclose(float(got), float(ref), rtol=1e-4,
                                       err_msg=f"model {residual}/{ov}")
            g = jax.jit(jax.grad(loss))(params_s, batch_s)
            for gg, gw in zip(jax.tree_util.tree_leaves(g),
                              jax.tree_util.tree_leaves(gref)):
                np.testing.assert_allclose(np.asarray(gg), np.asarray(gw),
                                           rtol=2e-3, atol=2e-4,
                                           err_msg=f"model {residual}/{ov}")
        print(f"megatron full model {residual} residual loss+grad OK")


def check_embed_overlap(mesh):
    """embed_2d overlapped ids gather + vocab scatter == take, fwd+grad."""
    table = jax.random.normal(jax.random.PRNGKey(2), (64, 32), jnp.float32)
    ids = jax.random.randint(jax.random.PRNGKey(3), (4, 16), 0, 64)
    table_s = jax.device_put(table, NamedSharding(mesh, P("mx", "my")))
    ids_s = jax.device_put(ids, NamedSharding(mesh, P("data", "mx")))
    gr = jax.grad(lambda t: jnp.take(t, ids, axis=0).sum())(table)
    for ov in ("none", "ring", "bidir", "fused"):
        emb = jax.jit(lambda i, t, _ov=ov: H.embed_2d(
            i, t, mesh=mesh, t_ax="mx", h_ax="my",
            compute_dtype=jnp.float32, overlap=_ov))(ids_s, table_s)
        np.testing.assert_allclose(np.asarray(emb), np.asarray(table[ids]),
                                   rtol=1e-6, err_msg=f"embed {ov}")
        g = jax.jit(jax.grad(lambda t, _ov=ov: H.embed_2d(
            ids_s, t, mesh=mesh, t_ax="mx", h_ax="my",
            compute_dtype=jnp.float32, overlap=_ov).sum()))(table_s)
        np.testing.assert_allclose(np.asarray(g), np.asarray(gr), rtol=1e-6,
                                   err_msg=f"embed {ov} grad")
    print("embed_2d overlap modes fwd+grad OK")


def check_quant_parity(mesh, tag):
    """Loss-parity gate for ``comm_dtype="int8"`` (docs/DESIGN.md §11).

    Two SGD steps of the full 2-layer LM on a megatron grid, ring/bidir/fused:
    the int8-comm loss curve must track the bf16-comm curve within QUANT_RTOL,
    and the step-0 grads within the (documented, looser) relative-L2 bound
    QUANT_GRAD_REL.  bf16 comm is itself asserted BIT-IDENTICAL to the
    pre-quantization rings implicitly: ``comm_dtype="bf16"`` lowers to the
    very same ``lax.ppermute`` calls, and the dense-reference checks above run
    the default config.  Tolerances are deliberately loose — per-hop error is
    ≤ scale/2 per element (core/quant.py) and compounds over hops and layers —
    but tight enough to catch a broken scale or a dropped hop, which shows up
    as O(1) loss divergence, not O(1e-2)."""
    from repro.config import ModelConfig, ParallelConfig
    from repro.models import lm
    from repro.parallel import specs as SP
    from repro.parallel.context import PCtx

    QUANT_RTOL = 0.05       # |loss_int8 - loss_bf16| / loss_bf16, each step
    QUANT_GRAD_REL = 0.25   # ||g_int8 - g_bf16|| / ||g_bf16||, whole tree
    LR = 0.05

    cfg = ModelConfig(name="quant-test", family="dense", num_layers=2,
                      d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                      vocab_size=64, mlp_kind="swiglu", qk_norm=True)
    params0 = lm.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}

    n_d, n_m = mesh.shape["data"], mesh.shape["model"]

    def run(ov, comm_dtype):
        """Two SGD steps; returns ([loss0, loss1], grad0 tree)."""
        pcfg = ParallelConfig(strategy="megatron", data=n_d, model=n_m,
                              overlap=ov, residual="seq", zero1=False,
                              comm_dtype=comm_dtype)
        pspecs = SP.param_specs(params0, mesh, pcfg)
        params = jax.device_put(params0, SP.sharding_tree(pspecs, mesh))
        bsp = SP.batch_specs(mesh, pcfg, microbatched=False, seq_len=16)
        batch_s = {k: jax.device_put(batch[k], NamedSharding(mesh, bsp[k]))
                   for k in ("tokens", "labels")}
        pctx = PCtx(mesh, pcfg, "train")

        def loss(p, b, _pctx=pctx):
            return lm.train_loss(_pctx, cfg, p,
                                 {**b, "_dtype": jnp.float32},
                                 remat="none")[0]

        vg = jax.jit(jax.value_and_grad(loss))
        losses, grad0 = [], None
        for step in range(2):
            l, g = vg(params, batch_s)
            losses.append(float(l))
            if step == 0:
                grad0 = g
            params = jax.tree_util.tree_map(
                lambda p, gg: p - LR * gg.astype(p.dtype), params, g)
        return losses, grad0

    for ov in ("ring", "bidir", "fused"):
        ref_losses, ref_g = run(ov, "bf16")
        q_losses, q_g = run(ov, "int8")
        for step, (lr_, lq) in enumerate(zip(ref_losses, q_losses)):
            rel = abs(lq - lr_) / max(abs(lr_), 1e-9)
            assert rel <= QUANT_RTOL, (
                f"{tag}/{ov} step{step}: int8 loss {lq:.6f} vs bf16 "
                f"{lr_:.6f} (rel {rel:.4f} > {QUANT_RTOL})")
        diff = jnp.sqrt(sum(
            jnp.sum((jnp.asarray(a, jnp.float32)
                     - jnp.asarray(b, jnp.float32)) ** 2)
            for a, b in zip(jax.tree_util.tree_leaves(q_g),
                            jax.tree_util.tree_leaves(ref_g))))
        norm = jnp.sqrt(sum(jnp.sum(jnp.asarray(b, jnp.float32) ** 2)
                            for b in jax.tree_util.tree_leaves(ref_g)))
        rel_g = float(diff / jnp.maximum(norm, 1e-9))
        assert rel_g <= QUANT_GRAD_REL, (
            f"{tag}/{ov}: grad rel-L2 {rel_g:.4f} > {QUANT_GRAD_REL}")
        print(f"{tag}: quant parity {ov} OK "
              f"(loss rel {abs(q_losses[-1] - ref_losses[-1]) / abs(ref_losses[-1]):.2e}, "
              f"grad rel {rel_g:.2e})")


def quant_parity_main():
    devs = np.array(jax.devices())
    check_quant_parity(Mesh(devs.reshape(1, 8), ("data", "model")),
                       "ring1x8")
    check_quant_parity(Mesh(devs.reshape(2, 4), ("data", "model")),
                       "ring2x4")
    print("ALL QUANT PARITY CHECKS PASSED")


def main():
    devs = np.array(jax.devices())
    # asymmetric grid: mx ring of 4, my ring of 2; even shard extents
    mesh_a = Mesh(devs.reshape(1, 4, 2), ("data", "mx", "my"))
    check_ops(mesh_a, B=2, T=16, Hd=24, O=32, tag="grid4x2")
    # odd shard extents: t_loc = 12/4 = 3 — bidir cannot halve the circulating
    # token shard and must degrade to the unidirectional ring (same numerics)
    check_ops(mesh_a, B=2, T=12, Hd=24, O=16, tag="grid4x2-oddshard")
    # square grid + fused loss (contract-dim ring gather inside scan+remat)
    mesh_b = Mesh(devs.reshape(2, 2, 2), ("data", "mx", "my"))
    check_ops(mesh_b, B=4, T=8, Hd=16, O=24, tag="grid2x2")
    check_fused_loss(mesh_b)
    # degenerate my=1 ring: RS side falls back to the (singleton) bulk path
    mesh_c = Mesh(devs.reshape(2, 4, 1), ("data", "mx", "my"))
    check_ops(mesh_c, B=4, T=8, Hd=16, O=8, tag="grid4x1")
    check_embed_overlap(mesh_b)
    print("ALL OVERLAP NUMERICS CHECKS PASSED")
    # megatron residual layouts: 1x8 / 2x4 / 4x2 (data x model) rings
    check_megatron_residual(Mesh(devs.reshape(1, 8), ("data", "model")),
                            "ring1x8")
    check_megatron_residual(Mesh(devs.reshape(2, 4), ("data", "model")),
                            "ring2x4")
    check_megatron_residual(Mesh(devs.reshape(4, 2), ("data", "model")),
                            "ring4x2")
    check_megatron_model(Mesh(devs.reshape(2, 4), ("data", "model")))
    print("ALL RESIDUAL LAYOUT CHECKS PASSED")
    # sharded-label fused loss (ISSUE 4 satellite): every grid, every mode
    check_megatron_fused_seq_loss(Mesh(devs.reshape(1, 8),
                                       ("data", "model")), "ring1x8")
    check_megatron_fused_seq_loss(Mesh(devs.reshape(2, 4),
                                       ("data", "model")), "ring2x4")
    check_megatron_fused_seq_loss(Mesh(devs.reshape(4, 2),
                                       ("data", "model")), "ring4x2")
    print("ALL FUSED SEQ LOSS CHECKS PASSED")


if __name__ == "__main__":
    import sys
    if "--quant-parity" in sys.argv:
        quant_parity_main()
    else:
        main()
