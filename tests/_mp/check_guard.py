"""End-to-end checks for the self-healing training runtime (ISSUE 7
acceptance, runtime/guard.py, docs/DESIGN.md §8).

Scenario A — in-graph NaN skip: a poison batch (NaN in ``loss_mask`` ->
NaN loss -> NaN grads) at step k of a guarded single-program run is skipped
IN-GRAPH: no retrace (a trace counter stays at 1 — the predicate is a traced
select, not Python control flow), ``update_skipped == 1`` at exactly step k,
and the final params/opt-state and every non-poisoned loss are bit-exact
against a clean run over the same stream with batch k dropped (a skipped
step passes state through bit-unchanged, so the two folds are the same
fold).

Scenario B1 — genuine loss-spike rollback (single-program + ASYNC
checkpointing): after enough pretraining that the model is confident,
label-shifted poison batches produce a real, finite loss spike
(ratio asserted >= SPIKE_MARGIN so calibration drift fails loudly).
TrainingGuard raises DivergenceError at patience; run_supervised fences the
async writer group, retires the published checkpoint saved mid-spike,
publishes ``blocklist.json``, and the restarted incarnation — streaming
``batch_at(data_index(s, blocklist))`` — produces a loss history and final
params bit-exact vs an uninterrupted run over the same filtered stream.

Scenario B2 — skip-cap rollback on the 2-pod 1F1B pipeline grid: NaN poison
batches are skipped in-graph (per-stage guards stay in lockstep off ONE
cross-stage norm), the skip streak hits ``skip_cap``, and the same
rollback/blocklist/bit-exact-resume contract holds with the stage-pinned
2-writer checkpoint group.

Scenario B3 — loss-spike rollback on the pipeline path: the in-graph guard
disarmed, NaN poison reaches the loss (non-finite counts as a spike), state
is genuinely corrupted and the mid-spike checkpoint holds NaN params —
retirement + blocklist + restart recover a trajectory bit-exact vs the
filtered clean run.

Scenario C — hang watchdog, in-process: a step that sleeps past
``hang_timeout`` trips the Watchdog; ``check()`` raises HangError — a
retryable supervised death — and the restart resumes bit-exact.

Scenario C2 — hang watchdog, subprocess (``--child-hang DIR``): the child's
hung step never returns; the ``on_hang`` escalation callback ``os._exit``\\ s
the process DURING the hang (rc 57 proves detection fired while hung), and
the parent's next incarnation sweeps and resumes from the published step
bit-exact.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import subprocess
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import (AsyncCheckpointManager,
                                      CheckpointManager)
from repro.config import GuardConfig, ModelConfig, ParallelConfig, RunConfig
from repro.data.synthetic import SyntheticLM
from repro.models import lm
from repro.optim import adamw
from repro.runtime import guard as G
from repro.runtime.fault import run_supervised
from repro.train import loop as train_loop
from repro.train import step as TS

CFG = ModelConfig(name="guard-test", family="dense", num_layers=2,
                  d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                  vocab_size=64, mlp_kind="swiglu")
RC = RunConfig("t", "train", 16, 8, lr=2e-3)
DS = SyntheticLM(CFG.vocab_size, RC.seq_len, RC.global_batch, seed=7)
PCFG1 = ParallelConfig(strategy="hecaton", data=1, model=1, mx=1, my=1,
                       microbatches=1, zero1=False)

# B1 calibration: at lr=1e-2 the model is confident enough by PRETRAIN that
# label-shifted batches spike the loss ~1.20x over its EWMA; the detector
# runs at 1.1x and the measured ratio is asserted >= SPIKE_MARGIN so any
# drift (jax version, platform) fails loudly instead of silently not firing
RC_HOT = RunConfig("t", "train", 16, 8, lr=1e-2)
PRETRAIN_TOTAL = 240
POISON = (233, 234)
SPIKE_MARGIN = 1.15


def _batch(s, poison=()):
    b = {k: jnp.asarray(v) for k, v in DS.batch_at(s).items()}
    # loss_mask is optional to the step fn; carry it on EVERY batch so the
    # poison batch (NaN mask) has the same pytree structure — the no-retrace
    # assertion in scenario A depends on poison being data-only
    b["loss_mask"] = jnp.ones((RC.global_batch, RC.seq_len), jnp.float32)
    if s in poison:
        # label shift: on a confident model, NLL of the wrong token is well
        # above the EWMA — a *finite* loss spike (mask scaling can't spike:
        # xent_loss is loss_mask-normalized)
        b["labels"] = (b["labels"] + CFG.vocab_size // 2) % CFG.vocab_size
    return b


def _nan_batch(s):
    b = _batch(s)
    b["loss_mask"] = jnp.full((RC.global_batch, RC.seq_len), jnp.nan,
                              jnp.float32)
    return b


def _leaves_equal(t1, t2, what):
    for a, b in zip(jax.tree_util.tree_leaves(t1),
                    jax.tree_util.tree_leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=what)


# ---------------------------------------------------------------------------
# Scenario A: in-graph NaN skip, no retrace, bit-exact vs dropped batch
# ---------------------------------------------------------------------------

def check_nan_skip_in_graph():
    gc = GuardConfig(grad_spike_factor=1e9)       # isolate the finite test
    inner = TS.build_train_step(CFG, PCFG1, RC, None,
                                compute_dtype=jnp.float32, guard=gc)
    traces = {"n": 0}

    def counted(p, o, b):
        traces["n"] += 1
        return inner(p, o, b)

    ts = jax.jit(counted)
    p0 = lm.init_params(CFG, jax.random.PRNGKey(0))
    o0 = adamw.init(p0)
    K, TOTAL = 4, 10

    # guarded run over the poisoned stream
    pa, oa = p0, o0
    skipped, losses_a = [], []
    for s in range(TOTAL):
        b = _nan_batch(s) if s == K else _batch(s)
        pa, oa, m = ts(pa, oa, b)
        skipped.append(float(m["update_skipped"]))
        losses_a.append(float(m["loss"]))
    assert traces["n"] == 1, f"retraced: {traces['n']} traces"
    assert skipped == [1.0 if s == K else 0.0 for s in range(TOTAL)], skipped
    assert np.isnan(losses_a[K])                  # poison loss surfaced...

    # ...but the fold is the clean fold with batch K dropped: same step fn,
    # one fewer step
    pb, ob = p0, o0
    losses_b = []
    for s in [x for x in range(TOTAL) if x != K]:
        pb, ob, m = ts(pb, ob, _batch(s))
        losses_b.append(float(m["loss"]))
    assert [l for i, l in enumerate(losses_a) if i != K] == losses_b
    _leaves_equal(pa, pb, "params after NaN-skip vs dropped-batch run")
    _leaves_equal(oa, ob, "opt state after NaN-skip vs dropped-batch run")
    assert int(oa.step) == TOTAL - 1              # counter froze at the skip
    print(f"A: NaN batch at step {K} skipped in-graph (1 trace, "
          f"update_skipped==1), trajectory bit-exact vs dropped-batch run")


# ---------------------------------------------------------------------------
# Scenario B1: finite loss spike -> rollback -> blocklist -> bit-exact resume
# (single-program, ASYNC multi-writer checkpointing)
# ---------------------------------------------------------------------------

def check_loss_spike_rollback_single(tmp_root):
    gc = GuardConfig(grad_spike_factor=1e6, loss_spike_factor=1.1,
                     patience=2, skip_cap=999)
    ts = jax.jit(TS.build_train_step(CFG, PCFG1, RC_HOT, None,
                                     compute_dtype=jnp.float32, guard=gc))
    p0 = lm.init_params(CFG, jax.random.PRNGKey(0))
    TOTAL = PRETRAIN_TOTAL

    # ---- measured spike margin (loud calibration guard) ------------------
    pa, oa = p0, adamw.init(p0)
    ew = None
    for s in range(POISON[0]):
        pa, oa, m = ts(pa, oa, _batch(s))
        l = float(m["loss"])
        ew = l if ew is None else 0.9 * ew + 0.1 * l
    _, _, m = ts(pa, oa, _batch(POISON[0], poison=POISON))
    ratio = float(m["loss"]) / ew
    assert ratio >= SPIKE_MARGIN, (
        f"calibration drift: poison/ewma ratio {ratio:.3f} < "
        f"{SPIKE_MARGIN} — retune PRETRAIN_TOTAL/lr")

    # ---- uninterrupted reference over the FILTERED stream ----------------
    bl = list(POISON)
    pr, orr = p0, adamw.init(p0)
    ref_hist = []
    for s in range(TOTAL):
        pr, orr, m = ts(pr, orr, _batch(G.data_index(s, bl)))
        ref_hist.append((s, float(m["loss"])))

    # ---- supervised run: poison stream, async 2-writer checkpointing -----
    ckpt_dir = os.path.join(tmp_root, "spike_single")
    mgr = AsyncCheckpointManager(ckpt_dir, keep=4, writers=2)
    restored_at = []

    def make_state(_):
        state = {"params": p0, "opt_state": adamw.init(p0)}
        start = 0
        if mgr.latest_step() is not None:
            state, start = mgr.restore(state)
            restored_at.append(start)
        return state, start

    def run_steps(state, start, inc):
        blist = G.load_blocklist(ckpt_dir)
        stream = G.blocklisted_stream(
            lambda i: _batch(i, poison=POISON), start, blist)
        return train_loop.train(
            ts, state, stream, start_step=start, num_steps=TOTAL,
            ckpt=mgr, ckpt_every=2, log_every=1000,
            guard=G.TrainingGuard(gc),
            data_index_fn=lambda s: G.data_index(s, blist),
            log_fn=lambda *a: None)

    state, incarnations = run_supervised(make_state, run_steps, ckpt=mgr,
                                         sleep_fn=lambda _: None)
    mgr.close()
    assert incarnations == 2, incarnations
    assert G.load_blocklist(ckpt_dir) == list(POISON)
    # the restart restored a pre-spike boundary (async: the last published
    # save at divergence time; the poisoned boundary was retired)
    assert len(restored_at) == 1 and restored_at[0] <= POISON[0] \
        and restored_at[0] % 2 == 0, restored_at
    start = restored_at[0]
    # resumed trajectory bit-exact vs the uninterrupted filtered run
    resumed = dict(state["history"])
    for s, want in ref_hist:
        if s >= start:
            assert resumed[s] == want, (s, resumed[s], want)
    _leaves_equal(state["params"], pr, "params after rollback-resume")
    _leaves_equal(state["opt_state"], orr, "opt state after rollback-resume")
    print(f"B1: finite loss spike ({ratio:.2f}x) at {POISON} -> rollback to "
          f"step {start}, blocklist published, resume bit-exact vs filtered "
          f"clean run (async 2-writer ckpt)")


# ---------------------------------------------------------------------------
# Scenarios B2/B3: rollback on the 2-pod 1F1B pipeline grid
# ---------------------------------------------------------------------------

def _pipeline_runner(guard):
    from repro.launch import mesh as MM
    from repro.parallel import pipeline as PP
    pcfg = ParallelConfig(strategy="hecaton", data=1, model=2, mx=1, my=2,
                          pods=2, pod_axis_role="pipeline", microbatches=2,
                          grad_reduce_dtype="fp32", remat="none",
                          zero1=False)
    mesh = MM.make_small_mesh("hecaton", 1, 1, 2, pods=2)
    cfg = CFG.scaled(num_layers=2)
    runner, pstep = PP.build_pipeline_train_step(cfg, pcfg, RC, mesh,
                                                 compute_dtype=jnp.float32,
                                                 guard=guard)
    return runner, pstep, cfg


def _pipeline_rollback(tmp_root, tag, guard_cfg, runner_guard, expect_kind):
    """Shared driver for B2 (in-graph skip -> skip_cap) and B3 (in-graph
    guard off -> NaN loss counts as spike): poison data 7,8 of a 12-step
    2-stage pipeline run, supervise, and require the rollback contract."""
    from repro.parallel import pipeline as PP
    runner, pstep, cfg = _pipeline_runner(runner_guard)
    p0 = lm.init_params(cfg, jax.random.PRNGKey(0))
    TOTAL, PBAD = 12, (7, 8)

    def fresh_state():
        sparams = runner.place_params(p0)
        return {"params": sparams, "opt_state": runner.init_opt(sparams)}

    def poisoned(i):
        return _nan_batch(i) if i in PBAD else _batch(i)

    # uninterrupted reference over the filtered stream
    ref = train_loop.train(
        pstep, fresh_state(),
        (_batch(G.data_index(s, list(PBAD))) for s in range(TOTAL)),
        num_steps=TOTAL, log_every=1000, log_fn=lambda *a: None)
    ref_hist = dict(ref["history"])

    ckpt_dir = os.path.join(tmp_root, f"pipe_{tag}")
    mgr = CheckpointManager(ckpt_dir, keep=5, writers=2,
                            writer_map=PP.stage_writer_map(2))
    restored_at, steps_seen = [], []

    def make_state(_):
        state, start = fresh_state(), 0
        if mgr.latest_step() is not None:
            steps_seen.append(list(mgr.all_steps()))
            state, start = mgr.restore(state)
            restored_at.append(start)
        return state, start

    def run_steps(state, start, inc):
        blist = G.load_blocklist(ckpt_dir)
        stream = G.blocklisted_stream(poisoned, start, blist)
        return train_loop.train(
            pstep, state, stream, start_step=start, num_steps=TOTAL,
            ckpt=mgr, ckpt_every=2, log_every=1000,
            guard=G.TrainingGuard(guard_cfg),
            data_index_fn=lambda s: G.data_index(s, blist),
            log_fn=lambda *a: None)

    state, incarnations = run_supervised(make_state, run_steps, ckpt=mgr,
                                         sleep_fn=lambda _: None)
    assert incarnations == 2, incarnations
    assert G.load_blocklist(ckpt_dir) == list(PBAD)
    # the boundary checkpoint saved inside the poison window (step 8) was
    # retired before the restart could see it
    assert restored_at == [6], restored_at
    assert 8 not in steps_seen[0], steps_seen
    resumed = dict(state["history"])
    for s in range(6, TOTAL):
        assert resumed[s] == ref_hist[s], (s, resumed[s], ref_hist[s])
    _leaves_equal(state["params"], ref["params"],
                  f"pipeline params after {expect_kind} rollback")
    _leaves_equal(state["opt_state"], ref["opt_state"],
                  f"pipeline opt state after {expect_kind} rollback")
    print(f"{tag}: {expect_kind} rollback on 2-pod 1F1B grid -> retire(8), "
          "blocklist [7, 8], resume from 6 bit-exact vs filtered clean run")


def check_skip_cap_rollback_pipeline(tmp_root):
    # in-graph guard armed: NaN batches skip (state bit-unchanged per
    # stage, predicates in lockstep off the one cross-stage norm), the skip
    # streak hits skip_cap=2
    gc = GuardConfig(grad_spike_factor=1e9, skip_cap=2, patience=99)
    _pipeline_rollback(tmp_root, "B2", gc, gc, "skip_cap")


def check_loss_spike_rollback_pipeline(tmp_root):
    # in-graph guard OFF: NaN reaches loss AND params (the mid-spike
    # checkpoint genuinely holds poisoned state — retirement is load-
    # bearing); non-finite loss counts as a spike
    gc = GuardConfig(loss_spike_factor=2.0, patience=2, skip_cap=999)
    _pipeline_rollback(tmp_root, "B3", gc, None, "loss_spike")


# ---------------------------------------------------------------------------
# Scenario C: hang watchdog, in-process supervised recovery
# ---------------------------------------------------------------------------

def check_watchdog_supervised(tmp_root):
    ts = jax.jit(TS.build_train_step(CFG, PCFG1, RC, None,
                                     compute_dtype=jnp.float32))
    p0 = lm.init_params(CFG, jax.random.PRNGKey(0))
    TOTAL, HANG_AT = 10, 5
    hung = {"done": False}

    def hang_once(p, o, b, _step=[0]):
        s = _step[0]
        _step[0] += 1
        out = ts(p, o, b)
        if s == HANG_AT and not hung["done"]:
            hung["done"] = True
            jax.block_until_ready(out[2]["loss"])
            time.sleep(0.6)                       # the "hang" (returns)
        return out

    # uninterrupted baseline
    base = train_loop.train(ts, {"params": p0, "opt_state": adamw.init(p0)},
                            (_batch(s) for s in range(TOTAL)),
                            num_steps=TOTAL, log_every=1000,
                            log_fn=lambda *a: None)
    base_hist = dict(base["history"])

    ckpt_dir = os.path.join(tmp_root, "hang")
    mgr = CheckpointManager(ckpt_dir)
    wd = G.Watchdog(0.25, poll=0.02)
    errors = []

    def make_state(_):
        state = {"params": p0, "opt_state": adamw.init(p0)}
        start = 0
        if mgr.latest_step() is not None:
            state, start = mgr.restore(state)
        return state, start

    def run_steps(state, start, inc):
        try:
            return train_loop.train(
                hang_once, state, (_batch(s) for s in range(start, TOTAL)),
                start_step=start, num_steps=TOTAL, ckpt=mgr, ckpt_every=2,
                log_every=1000, watchdog=wd, log_fn=lambda *a: None)
        except G.HangError as e:
            errors.append(e)
            raise

    try:
        state, incarnations = run_supervised(make_state, run_steps,
                                             ckpt=mgr,
                                             sleep_fn=lambda _: None)
    finally:
        wd.close()
    assert incarnations == 2, incarnations
    assert len(errors) == 1 and errors[0].step == HANG_AT
    assert errors[0].elapsed > errors[0].timeout == 0.25
    resumed = dict(state["history"])
    for s, want in base_hist.items():
        if s >= 4:                                # steps re-run after restore
            assert resumed[s] == want, (s, resumed[s], want)
    _leaves_equal(state["params"], base["params"],
                  "params after hang-restart")
    print(f"C: step {HANG_AT} hung past hang_timeout=0.25s -> HangError, "
          "supervised restart from step 4, resume bit-exact")


# ---------------------------------------------------------------------------
# Scenario C2: hung step never returns; on_hang kills the process mid-hang
# ---------------------------------------------------------------------------

def child_hang(ckpt_dir):
    ts = jax.jit(TS.build_train_step(CFG, PCFG1, RC, None,
                                     compute_dtype=jnp.float32))
    p0 = lm.init_params(CFG, jax.random.PRNGKey(0))
    # warm the compile cache before arming a 0.3s watchdog — the compile
    # step is ~100x steady state and would itself read as a hang (the same
    # reason StepTimer discards warmup_steps samples)
    jax.block_until_ready(ts(p0, adamw.init(p0), _batch(0))[2]["loss"])
    mgr = CheckpointManager(ckpt_dir)

    def hang_forever(p, o, b, _step=[0]):
        s = _step[0]
        _step[0] += 1
        out = ts(p, o, b)
        if s == 5:
            jax.block_until_ready(out[2]["loss"])
            time.sleep(600)                       # a real hang: never returns
        return out

    # rc 57 (not 1) so the parent can tell the watchdog escalation from an
    # uncaught child exception; _exit fires DURING the sleep above
    wd = G.Watchdog(0.3, poll=0.02, on_hang=lambda s, el: os._exit(57))
    train_loop.train(hang_forever, {"params": p0, "opt_state": adamw.init(p0)},
                     (_batch(s) for s in range(10)), num_steps=10,
                     ckpt=mgr, ckpt_every=2, log_every=1000, watchdog=wd,
                     log_fn=lambda *a: None)
    os._exit(3)                                   # unreachable


def check_hang_kill(ckpt_dir):
    t0 = time.time()
    r = subprocess.run([sys.executable, os.path.abspath(__file__),
                        "--child-hang", ckpt_dir],
                       capture_output=True, text=True,
                       env=dict(os.environ), timeout=300)
    wall = time.time() - t0
    assert r.returncode == 57, (r.returncode, r.stdout, r.stderr[-2000:])
    assert wall < 120, f"watchdog escalation took {wall:.0f}s"

    # next incarnation: sweep, restore the published step, resume bit-exact
    mgr = CheckpointManager(ckpt_dir)
    mgr.abort()
    assert mgr.all_steps() == [2, 4], mgr.all_steps()
    ts = jax.jit(TS.build_train_step(CFG, PCFG1, RC, None,
                                     compute_dtype=jnp.float32))
    p0 = lm.init_params(CFG, jax.random.PRNGKey(0))
    o0 = adamw.init(p0)
    pa, oa = p0, o0
    ref = []
    for s in range(8):
        pa, oa, m = ts(pa, oa, _batch(s))
        ref.append(float(m["loss"]))
    restored, step = mgr.restore({"params": p0, "opt_state": o0})
    assert step == 4
    pb, ob = restored["params"], restored["opt_state"]
    got = []
    for s in range(4, 8):
        pb, ob, m = ts(pb, ob, _batch(s))
        got.append(float(m["loss"]))
    assert ref[4:] == got, (ref[4:], got)
    _leaves_equal(pa, pb, "params after hang-kill resume")
    print("C2: on_hang escalation fired DURING the 600s hang (rc 57, "
          f"{wall:.0f}s wall), restart resumed from step 4 bit-exact")


def main():
    import tempfile
    root = tempfile.mkdtemp(prefix="guard_check_")
    check_nan_skip_in_graph()
    check_loss_spike_rollback_single(root)
    check_skip_cap_rollback_pipeline(root)
    check_loss_spike_rollback_pipeline(root)
    check_watchdog_supervised(root)
    check_hang_kill(os.path.join(root, "hang_kill"))
    print("ALL GUARD CHECKS PASSED")


if __name__ == "__main__":
    if len(sys.argv) > 2 and sys.argv[1] == "--child-hang":
        child_hang(sys.argv[2])
    else:
        main()
