"""Subprocess check: full-model forward/loss on an 8-device hecaton mesh ==
single-device reference; embed_2d == take; MoE shard_map == local MoE.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import MoEConfig, ModelConfig, ParallelConfig, RunConfig
from repro.core import hecaton as H
from repro.models import lm
from repro.optim import adamw
from repro.parallel import specs as SP
from repro.parallel.context import PCtx
from repro.train import step as TS


def main():
    devs = np.array(jax.devices()).reshape(2, 2, 2)
    mesh = Mesh(devs, ("data", "mx", "my"))
    pcfg = ParallelConfig(strategy="hecaton", data=2, model=4, mx=2, my=2,
                          microbatches=2, zero1=True)

    cfg = ModelConfig(name="mp-test", family="dense", num_layers=2,
                      d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                      vocab_size=64, mlp_kind="swiglu", qk_norm=True)
    rc = RunConfig("t", "train", 16, 4, lr=1e-3)

    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}

    # single-device reference
    pctx1 = PCtx(None, ParallelConfig(data=1, model=1, mx=1, my=1))
    ref_loss, _ = lm.train_loss(pctx1, cfg, params,
                                {**batch, "_dtype": jnp.float32}, remat="none")

    # sharded
    pspecs = SP.param_specs(params, mesh, pcfg)
    pshard = SP.sharding_tree(pspecs, mesh)
    params_s = jax.device_put(params, pshard)
    bshard = {k: NamedSharding(mesh, P("data", "mx")) for k in batch}
    batch_s = {k: jax.device_put(v, bshard[k]) for k, v in batch.items()}
    pctx8 = PCtx(mesh, pcfg, "train")
    loss8, _ = jax.jit(lambda p, b: lm.train_loss(
        pctx8, cfg, p, {**b, "_dtype": jnp.float32}, remat="none"))(
            params_s, batch_s)
    np.testing.assert_allclose(float(loss8), float(ref_loss), rtol=1e-4)
    print("dense model sharded-vs-single loss OK", float(loss8))

    # full train step runs sharded (grad + adam + zero1)
    ts = TS.build_train_step(cfg, pcfg, rc, mesh, compute_dtype=jnp.float32)
    oshape = adamw.init(params)
    ospecs = SP.opt_state_specs(pspecs, params, mesh, pcfg)
    opt_s = jax.device_put(oshape, SP.sharding_tree(ospecs, mesh))
    p2, o2, m = jax.jit(ts)(params_s, opt_s, batch_s)
    assert np.isfinite(float(m["loss"]))
    print("sharded train step OK; loss", float(m["loss"]))

    # embedding: shard_map path == take
    table = jax.random.normal(jax.random.PRNGKey(2), (64, 32), jnp.float32)
    ids = jax.random.randint(jax.random.PRNGKey(3), (4, 16), 0, 64)
    table_s = jax.device_put(table, NamedSharding(mesh, P("mx", "my")))
    ids_s = jax.device_put(ids, NamedSharding(mesh, P("data", "mx")))
    emb = jax.jit(lambda i, t: H.embed_2d(
        i, t, mesh=mesh, t_ax="mx", h_ax="my", compute_dtype=jnp.float32))(
            ids_s, table_s)
    np.testing.assert_allclose(np.asarray(emb), np.asarray(table[ids]),
                               rtol=1e-6)
    print("embed_2d OK")

    # MoE: sharded EPxTP == local
    from repro.models import mlp as MLP
    mcfg = ModelConfig(name="moe-test", family="moe", num_layers=1,
                       d_model=32, num_heads=4, num_kv_heads=2, d_ff=16,
                       vocab_size=64, mlp_kind="swiglu",
                       moe=MoEConfig(num_experts=4, top_k=2,
                                     capacity_factor=4.0))
    mp = MLP.init_moe(mcfg, jax.random.PRNGKey(4))
    # make routing decisive: top-k tie-breaks on near-boundary tokens would
    # otherwise flip between the gathered and local paths (legit numerics)
    mp["router"] = mp["router"] * 50.0
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 16, 32), jnp.float32)
    y_ref, aux_ref = MLP.apply_moe(pctx1, mcfg, mp, x)
    xs = jax.device_put(x, NamedSharding(mesh, P("data", "mx", "my")))
    mps = jax.device_put(mp, SP.sharding_tree(
        SP.param_specs(mp, mesh, pcfg), mesh))
    y8, aux8 = jax.jit(lambda p, xx: MLP.apply_moe(pctx8, mcfg, p, xx))(mps, xs)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y_ref), rtol=2e-3,
                               atol=2e-4)
    # aux is a per-data-group load-balance loss (nonlinear in mean probs), so
    # group-mean != global value exactly; they agree to ~group-size effects.
    np.testing.assert_allclose(float(aux8), float(aux_ref), rtol=0.1)
    print("MoE EPxTP shard_map OK")
    print("ALL MODEL-PARALLEL CHECKS PASSED")


if __name__ == "__main__":
    main()
