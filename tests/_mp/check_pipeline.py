"""Subprocess numerics check: inter-pod 1F1B pipeline == single-pod baseline.

Acceptance for ISSUE 5's tentpole: on 2-pod CPU grids — 2x(1x4) and
2x(2x2), i.e. a "pod" axis of 2 in front of the hecaton (mx, my) grid —
``pod_axis_role="pipeline"`` must train with 1F1B microbatch scheduling and
produce loss + grads matching the single-pod baseline (same inner grid, no
pod axis) to fp32 tolerance, under ``overlap in {none, ring}`` with the
seq-sharded residual composing inside each stage.

Also checks:
  * the executed op order per stage matches the pure-Python 1F1B table
    (warmup/steady/cooldown) and the per-stage activation stash never
    exceeds the schedule's in-flight bound min(p-s, m);
  * a full optimizer step (global-norm clip coupled across stages) stays
    within fp32 tolerance of the single-program train step, for two steps;
  * grads also match the dense single-device reference;
  * a 4-stage 4x(1x2) pipeline (mid-stage fwd/bwd paths) with m=4 AND the
    m=2 < p warmup-clamped schedule.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.config import ModelConfig, ParallelConfig, RunConfig
from repro.launch import mesh as M
from repro.models import lm
from repro.parallel import pipeline as PP
from repro.parallel import specs as SP
from repro.parallel import zero
from repro.parallel.context import PCtx
from repro.train import step as TS

CFG = ModelConfig(name="pipe-test", family="dense", num_layers=4,
                  d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                  vocab_size=64, qk_norm=True)
RC = RunConfig("pipe", "train", seq_len=16, global_batch=8, lr=1e-3,
               warmup_steps=2)
N_MICRO = 4
TOL = dict(rtol=2e-4, atol=2e-5)


def make_batch():
    k = jax.random.PRNGKey(7)
    tokens = jax.random.randint(k, (RC.global_batch, RC.seq_len), 0,
                                CFG.vocab_size)
    return {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}


def pcfg_for(mx, my, *, pods=1, overlap="none", n_micro=N_MICRO):
    role = "pipeline" if pods > 1 else "data"
    return ParallelConfig(strategy="hecaton", data=1, model=mx * my,
                          mx=mx, my=my, pods=pods, pod_axis_role=role,
                          overlap=overlap, microbatches=n_micro,
                          grad_reduce_dtype="fp32", remat="none")


def accumulated_loss_grads(pctx, pcfg, params, batch):
    """Replicate train/step.py's microbatch accumulation (python loop)."""
    mbs = TS.microbatch_split(batch, N_MICRO)

    def loss_fn(p, mb):
        mb = dict(mb)
        mb["_dtype"] = jnp.float32
        return lm.train_loss(pctx, CFG, p, mb, remat=pcfg.remat)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))
    gsum = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    lsum = 0.0
    for i in range(N_MICRO):
        mb = {k: v[i] for k, v in mbs.items()}
        (_, metrics), g = grad_fn(params, mb)
        g = zero.compress_grads(g, pcfg.grad_reduce_dtype)
        gsum = jax.tree.map(lambda a, b: a + b.astype(a.dtype), gsum, g)
        lsum += float(metrics["loss"])
    grads = jax.tree.map(lambda g: g / N_MICRO, gsum)
    return lsum / N_MICRO, grads


def check_grid(mx, my, overlap, params, batch, ref_loss, ref_grads):
    tag = f"2x({mx}x{my})/{overlap}"
    # ---- single-pod baseline on the inner grid --------------------------
    bmesh = M.make_small_mesh("hecaton", 1, mx, my)
    bpcfg = pcfg_for(mx, my, overlap=overlap)
    bspecs = SP.param_specs(params, bmesh, bpcfg)
    bparams = jax.device_put(params, SP.sharding_tree(bspecs, bmesh))
    bsp = SP.batch_specs(bmesh, bpcfg, microbatched=False,
                         seq_len=RC.seq_len)
    bbatch = {k: jax.device_put(batch[k], NamedSharding(bmesh, bsp[k]))
              for k in batch}
    base_loss, base_grads = accumulated_loss_grads(
        PCtx(bmesh, bpcfg, "train"), bpcfg, bparams, bbatch)
    np.testing.assert_allclose(base_loss, ref_loss, rtol=1e-4,
                               err_msg=f"{tag} baseline vs dense ref")

    # ---- 2-pod 1F1B pipeline -------------------------------------------
    pmesh = M.make_small_mesh("hecaton", 1, mx, my, pods=2)
    ppcfg = pcfg_for(mx, my, pods=2, overlap=overlap)
    runner = PP.PipelineRunner(CFG, ppcfg, RC, pmesh,
                               compute_dtype=jnp.float32)
    sparams = runner.place_params(params)
    loss, sgrads, metrics = runner.loss_and_grads(sparams, batch)

    np.testing.assert_allclose(float(loss), base_loss, rtol=1e-5,
                               err_msg=f"{tag} pipeline loss")
    merged = PP.merge_stage_grads(sgrads, CFG)
    flat_base = dict(jax.tree_util.tree_flatten_with_path(base_grads)[0])
    flat_pipe = dict(jax.tree_util.tree_flatten_with_path(merged)[0])
    assert flat_base.keys() == flat_pipe.keys()
    for kp, want in flat_base.items():
        np.testing.assert_allclose(np.asarray(flat_pipe[kp]),
                                   np.asarray(want),
                                   err_msg=f"{tag} grad {kp}", **TOL)
    for kp, want in dict(
            jax.tree_util.tree_flatten_with_path(ref_grads)[0]).items():
        np.testing.assert_allclose(np.asarray(flat_pipe[kp]),
                                   np.asarray(want),
                                   err_msg=f"{tag} grad-vs-dense {kp}", **TOL)

    # ---- schedule conformance ------------------------------------------
    p = runner.n_stages
    for s in range(p):
        want_order = PP.stage_order(s, p, N_MICRO)
        assert runner.executed[s] == want_order, (tag, s)
        bound = min(p - s, N_MICRO)
        assert runner.max_stash[s] <= bound, (tag, s, runner.max_stash)
    print(f"{tag}: 1F1B loss+grads match baseline + dense ref, "
          f"schedule conformant")
    return bmesh, bpcfg, pmesh, ppcfg, runner, sparams


def check_four_stage(params, batch, ref_loss, ref_grads):
    """4 pods x (1x2) grid (one layer per stage — mid-stage fwd/bwd paths),
    with m=4 (steady 1F1B) AND m=2 < p (warmup-clamped schedule)."""
    for n_micro in (4, 2):
        tag = f"4x(1x2)/m{n_micro}"
        pmesh = M.make_small_mesh("hecaton", 1, 1, 2, pods=4)
        ppcfg = pcfg_for(1, 2, pods=4, n_micro=n_micro)
        runner = PP.PipelineRunner(CFG, ppcfg, RC, pmesh,
                                   compute_dtype=jnp.float32)
        sparams = runner.place_params(params)
        loss, sgrads, _ = runner.loss_and_grads(sparams, batch)
        np.testing.assert_allclose(float(loss), ref_loss, rtol=1e-4,
                                   err_msg=f"{tag} loss")
        merged = PP.merge_stage_grads(sgrads, CFG)
        for kp, want in dict(
                jax.tree_util.tree_flatten_with_path(ref_grads)[0]).items():
            got = dict(jax.tree_util.tree_flatten_with_path(merged)[0])[kp]
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       err_msg=f"{tag} grad {kp}", **TOL)
        for s in range(4):
            assert runner.executed[s] == PP.stage_order(s, 4, n_micro), \
                (tag, s)
            assert runner.max_stash[s] <= min(4 - s, n_micro), \
                (tag, s, runner.max_stash)
        print(f"{tag}: 4-stage 1F1B loss+grads match dense ref, "
              f"schedule conformant")


def check_train_step_parity(mx, my, params, batch):
    """Two full optimizer steps: pipeline == single-program, fp32 tol."""
    tag = f"2x({mx}x{my})/train-step"
    from repro.optim import adamw
    bmesh = M.make_small_mesh("hecaton", 1, mx, my)
    bpcfg = pcfg_for(mx, my)
    bspecs = SP.param_specs(params, bmesh, bpcfg)
    bparams = jax.device_put(params, SP.sharding_tree(bspecs, bmesh))
    bopt = adamw.init(bparams)
    ospecs = SP.opt_state_specs(bspecs, bparams, bmesh, bpcfg)
    bopt = jax.device_put(bopt, SP.sharding_tree(ospecs, bmesh))
    bstep = jax.jit(TS.build_train_step(CFG, bpcfg, RC, bmesh,
                                        compute_dtype=jnp.float32))
    bsp = SP.batch_specs(bmesh, bpcfg, microbatched=False,
                         seq_len=RC.seq_len)
    bbatch = {k: jax.device_put(batch[k], NamedSharding(bmesh, bsp[k]))
              for k in batch}

    pmesh = M.make_small_mesh("hecaton", 1, mx, my, pods=2)
    ppcfg = pcfg_for(mx, my, pods=2)
    runner, pstep = PP.build_pipeline_train_step(CFG, ppcfg, RC, pmesh,
                                                 compute_dtype=jnp.float32)
    sparams = runner.place_params(params)
    sopt = runner.init_opt(sparams)

    for step in range(2):
        bparams, bopt, bm = bstep(bparams, bopt, bbatch)
        sparams, sopt, pm = pstep(sparams, sopt, batch)
        np.testing.assert_allclose(float(pm["loss"]), float(bm["loss"]),
                                   rtol=1e-5, err_msg=f"{tag} step{step}")
        np.testing.assert_allclose(float(pm["grad_norm"]),
                                   float(bm["grad_norm"]), rtol=1e-4,
                                   err_msg=f"{tag} gnorm step{step}")
    merged = PP.merge_stage_grads(sparams, CFG)
    for kp, want in dict(
            jax.tree_util.tree_flatten_with_path(bparams)[0]).items():
        got = dict(jax.tree_util.tree_flatten_with_path(merged)[0])[kp]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   err_msg=f"{tag} params {kp}", **TOL)
    print(f"{tag}: 2 optimizer steps bit-comparable (fp32 tol) OK")


def main():
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    batch = make_batch()
    # dense single-device reference
    dense_pctx = PCtx(None, ParallelConfig(data=1, model=1, mx=1, my=1,
                                           microbatches=N_MICRO,
                                           grad_reduce_dtype="fp32",
                                           remat="none"))
    ref_loss, ref_grads = accumulated_loss_grads(
        dense_pctx, dense_pctx.pcfg, params, batch)

    for mx, my in ((1, 4), (2, 2)):
        for overlap in ("none", "ring"):
            check_grid(mx, my, overlap, params, batch, ref_loss, ref_grads)
    check_four_stage(params, batch, ref_loss, ref_grads)
    check_train_step_parity(1, 4, params, batch)
    print("ALL PIPELINE CHECKS PASSED")


if __name__ == "__main__":
    main()
