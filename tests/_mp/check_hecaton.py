"""Subprocess numerics check: hecaton shard_map ops == dense reference (fwd + grad).

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P, NamedSharding

from repro.core import hecaton as H


def main():
    devs = np.array(jax.devices()).reshape(2, 2, 2)
    mesh = Mesh(devs, ("data", "mx", "my"))
    key = jax.random.PRNGKey(0)
    B, T, Hd, O = 4, 8, 16, 24
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (B, T, Hd), jnp.float32)
    w = jax.random.normal(k2, (Hd, O), jnp.float32) / np.sqrt(Hd)
    w2 = jax.random.normal(k3, (O, Hd), jnp.float32) / np.sqrt(O)
    wb = jax.random.normal(k4, (Hd, O), jnp.float32) / np.sqrt(Hd)

    xs = jax.device_put(x, NamedSharding(mesh, P("data", "mx", "my")))
    ws = jax.device_put(w, NamedSharding(mesh, P("my", "mx")))

    # ---- linear_seq_scatter fwd ----
    def f_hec(x, w):
        return H.linear_seq_scatter(x, w, mesh=mesh, t_ax="mx", h_ax="my").sum()

    def f_ref(x, w):
        return (x @ w).sum()

    y = jax.jit(lambda x, w: H.linear_seq_scatter(x, w, mesh=mesh, t_ax="mx", h_ax="my"))(xs, ws)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=2e-5, atol=2e-5)
    print("fwd linear_seq_scatter OK; out sharding:", y.sharding.spec)

    # ---- grads ----
    gh = jax.jit(jax.grad(f_hec, argnums=(0, 1)))(xs, ws)
    gr = jax.grad(f_ref, argnums=(0, 1))(x, w)
    for a, b, nm in zip(gh, gr, ("dx", "dw")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)
    print("grad linear_seq_scatter OK")

    # ---- mixer_in / mixer_out ----
    def f_mix(x, w, w2):
        a = H.mixer_in(x, w, mesh=mesh, t_ax="mx", h_ax="my")
        a = jnp.tanh(a)
        return H.mixer_out(a, w2, mesh=mesh, t_ax="mx", h_ax="my")

    def f_mix_ref(x, w, w2):
        return jnp.tanh(x @ w) @ w2

    w2s = jax.device_put(w2, NamedSharding(mesh, P("mx", "my")))
    ym = jax.jit(f_mix)(xs, ws, w2s)
    np.testing.assert_allclose(np.asarray(ym), np.asarray(f_mix_ref(x, w, w2)),
                               rtol=2e-5, atol=2e-5)
    print("fwd mixer OK; out sharding:", ym.sharding.spec)

    gm = jax.jit(jax.grad(lambda *a: f_mix(*a).sum(), argnums=(0, 1, 2)))(xs, ws, w2s)
    gmr = jax.grad(lambda *a: f_mix_ref(*a).sum(), argnums=(0, 1, 2))(x, w, w2)
    for a, b, nm in zip(gm, gmr, ("dx", "dw", "dw2")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)
    print("grad mixer OK")

    # ---- fused ffn_block (gated) ----
    def f_ffn(x, w1, w2, wb):
        return H.ffn_block(x, w1, w2, mesh=mesh, act_fn=jax.nn.silu,
                           t_ax="mx", h_ax="my", w1b=wb)

    def f_ffn_ref(x, w1, w2, wb):
        return (jax.nn.silu(x @ w1) * (x @ wb)) @ w2

    wbs = jax.device_put(wb, NamedSharding(mesh, P("my", "mx")))
    yf = jax.jit(f_ffn)(xs, ws, w2s, wbs)
    np.testing.assert_allclose(np.asarray(yf), np.asarray(f_ffn_ref(x, w, w2, wb)),
                               rtol=2e-5, atol=2e-5)
    gf = jax.jit(jax.grad(lambda *a: f_ffn(*a).sum(), argnums=(0, 1, 2, 3)))(xs, ws, w2s, wbs)
    gfr = jax.grad(lambda *a: f_ffn_ref(*a).sum(), argnums=(0, 1, 2, 3))(x, w, w2, wb)
    for a, b in zip(gf, gfr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)
    print("ffn_block fwd+grad OK")

    # ---- HLO contains only AG/RS collectives (the paper's claim) ----
    txt = jax.jit(f_ffn).lower(xs, ws, w2s, wbs).compile().as_text()
    assert "all-gather" in txt and "reduce-scatter" in txt, "expected AG+RS in HLO"
    assert "all-to-all" not in txt
    print("HLO collective check OK")
    print("ALL HECATON NUMERICS CHECKS PASSED")


if __name__ == "__main__":
    main()
