"""Serve-smoke subprocess check (docs/DESIGN.md §10).

1. **GQA/MQA cache_specs regression** on an 8-device (2 data, 2 mx, 2 my)
   mesh: for qwen3 (GQA nkv=2), granite (MQA nkv=1), minicpm3 (MLA — no
   nkv axis at all) and zamba2 (hybrid), the spec tree returned by
   ``serve.step.cache_specs`` must lay out every cache leaf so each
   sharded dim is divisible by its mesh-axes product — the old
   ``cfg.num_kv_heads if cfg.num_kv_heads else 1`` fallback could hand
   the layout solver a head count that disagrees with the nkv axis
   ``ATT.init_kv_cache`` actually built.  The dense cache tree is
   device_put against the specs and a jitted dense decode step runs on
   the sharded caches to prove the layout is executable, not just
   well-formed.

2. **Continuous-batching engine trace**: 6 arrivals > 2 slots with mixed
   prompt lengths and one forced EOS early-exit; every sequence's tokens
   must be bit-identical to running that sequence ALONE through the
   dense-cache greedy path, and the paged pool's high-water mark must
   stay strictly below the dense [slots, max_seq] arena equivalent.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ParallelConfig, RunConfig, get_smoke_config
from repro.models import lm
from repro.serve import step as SRV
from repro.serve.cache import PoolConfig, blocks_for
from repro.serve.engine import DecodeEngine, Request

PCFG1 = ParallelConfig(strategy="hecaton", data=1, model=1, mx=1, my=1)
MAXSEQ = 24
GEN = 6


def check_cache_specs():
    devs = np.array(jax.devices()).reshape(2, 2, 2)
    mesh = Mesh(devs, ("data", "mx", "my"))
    pcfg = ParallelConfig(strategy="hecaton", data=2, model=4, mx=2, my=2)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    B = 4                                   # divides n_data=2
    for arch in ("qwen3-0.6b", "granite-34b", "minicpm3-4b", "zamba2-1.2b"):
        cfg = get_smoke_config(arch)
        specs = SRV.cache_specs(cfg, pcfg, mesh, batch=B)
        caches = lm.init_caches(cfg, B, MAXSEQ, jnp.float32)
        flat_s = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        flat_l = jax.tree.leaves(caches)
        assert len(flat_s) == len(flat_l), arch
        for spec, leaf in zip(flat_s, flat_l):
            for dim, entry in enumerate(spec):
                if entry is None:
                    continue
                axes = (entry,) if isinstance(entry, str) else tuple(entry)
                prod = int(np.prod([sizes[a] for a in axes]))
                assert leaf.shape[dim] % prod == 0, \
                    (arch, spec, leaf.shape, dim)
        # the layout must be executable: shard the tree, run one decode step
        leaves, treedef = jax.tree.flatten(caches)
        sharded = treedef.unflatten(
            [jax.device_put(l, NamedSharding(mesh, s))
             for l, s in zip(leaves, flat_s)])
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        rc = RunConfig("serve", "decode", MAXSEQ, B)
        dec = jax.jit(SRV.build_decode_step(cfg, pcfg, rc, mesh,
                                            compute_dtype=jnp.float32))
        tok = jnp.zeros((B, 1), jnp.int32)
        pos = jnp.zeros((B, 1), jnp.int32)
        logits, _ = dec(params, sharded, tok, pos)
        assert bool(jnp.isfinite(logits).all()), arch
        print(f"  cache_specs {arch}: OK ({len(flat_l)} leaves)")
    print("PASS: GQA/MQA/MLA cache_specs regression")


def dense_greedy(cfg, params, prompt, gen, rc, eos=None):
    prefill = jax.jit(SRV.build_prefill(cfg, PCFG1, rc, None,
                                        compute_dtype=jnp.float32))
    decode = jax.jit(SRV.build_decode_step(cfg, PCFG1, rc, None,
                                           compute_dtype=jnp.float32))
    logits, caches = prefill(params, {"tokens": jnp.asarray(prompt)[None, :]})
    tok = SRV.greedy_sample(logits)
    toks = [int(tok[0, 0])]
    for i in range(gen - 1):
        if eos is not None and toks[-1] == eos:
            break
        pos = jnp.full((1, 1), len(prompt) + i, jnp.int32)
        logits, caches = decode(params, caches, tok, pos)
        tok = SRV.greedy_sample(logits)
        toks.append(int(tok[0, 0]))
    return toks


def check_engine_trace():
    cfg = get_smoke_config("qwen3-0.6b")
    rc = RunConfig("serve", "decode", MAXSEQ, 1)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    plens = (5, 11, 7, 14, 3, 9)            # 6 arrivals > 2 slots, mixed
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in plens]
    base = [dense_greedy(cfg, params, p, GEN, rc) for p in prompts]
    # force one EOS early-exit: a token sequence 0 emits mid-stream
    eos = base[0][2]
    want = [dense_greedy(cfg, params, p, GEN, rc, eos=eos) for p in prompts]
    assert len(want[0]) < GEN, "EOS choice did not shorten sequence 0"

    pool = PoolConfig(slots=2, block=4,
                      num_blocks=2 * blocks_for(MAXSEQ, 4) + 1, max_seq=MAXSEQ)
    eng = DecodeEngine(cfg, PCFG1, rc, params, pool,
                       compute_dtype=jnp.float32, eos_id=eos)
    eng.warmup(prompt_lens=plens)
    fin = eng.run([Request(rid=i, prompt=p, max_new=GEN, arrival=i // 2)
                   for i, p in enumerate(prompts)])
    assert len(fin) == len(prompts)
    for i in range(len(prompts)):
        assert fin[i].tokens == want[i], \
            f"seq {i}: paged {fin[i].tokens} != dense {want[i]}"
    assert any(f.reason == "eos" for f in fin.values()), "no EOS early-exit"
    assert eng.pool.peak_blocks_in_use < pool.dense_equiv_blocks, \
        (eng.pool.peak_blocks_in_use, pool.dense_equiv_blocks)
    assert eng.pool.blocks_in_use == 0
    print(f"PASS: engine trace bit-exact ({len(prompts)} seqs, "
          f"peak {eng.pool.peak_blocks_in_use}/{pool.dense_equiv_blocks} "
          f"blocks, {sum(f.reason == 'eos' for f in fin.values())} eos)")


if __name__ == "__main__":
    assert len(jax.devices()) == 8, "need 8 fake CPU devices"
    check_cache_specs()
    check_engine_trace()
    print("ALL SERVE CHECKS PASSED")
