"""Subprocess numerics check for the fused Pallas ring-matmul kernels
(kernels/ring_matmul.py) on a fake 8-device topology.

Interpret-mode equivalence of each fused kernel against BOTH references:
the core/overlap.py ppermute-ring primitives and the bulk collectives —
forward and gradient — plus the bias/activation epilogues, the gated
shared-x-tile pair, and the non-tile-aligned fallback through the
core/overlap.py dispatchers.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import overlap as OV
from repro.kernels import ring_matmul as RM
from repro.kernels.matmul import _epilogue

TOL = dict(rtol=2e-5, atol=2e-5)


def _close(a, b, name):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), err_msg=name,
                               **TOL)


def _sm(f, mesh, in_specs, out_specs):
    return jax.jit(compat.shard_map(f, mesh, in_specs, out_specs))


def _grads(fn, *args):
    return jax.jit(jax.grad(lambda *a: fn(*a).sum(),
                            argnums=tuple(range(len(args)))))(*args)


def check_ag_matmul(mesh):
    B, T, H, O = 2, 16, 24, 32
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (B, T, H), jnp.float32)
    w = jax.random.normal(k2, (H, O), jnp.float32) / np.sqrt(H)
    xs = jax.device_put(x, NamedSharding(mesh, P("data", "mx", "my")))
    ws = jax.device_put(w, NamedSharding(mesh, P("my", "mx")))
    specs = ((P("data", "mx", "my"), P("my", "mx")),
             P("data", None, ("my", "mx")))

    fused = _sm(lambda xl, wl: RM.ag_matmul(xl, wl, "mx", dim=1, n=4),
                mesh, *specs)
    ring = _sm(lambda xl, wl: OV.ring_ag_matmul(xl, wl, "mx", dim=1, n=4),
               mesh, *specs)
    bulk = _sm(lambda xl, wl: jnp.einsum(
        "bth,ho->bto", lax.all_gather(xl, "mx", axis=1, tiled=True), wl,
        preferred_element_type=jnp.float32).astype(xl.dtype), mesh, *specs)
    yf, yr, yb = fused(xs, ws), ring(xs, ws), bulk(xs, ws)
    _close(yf, yr, "ag_matmul vs ring")
    _close(yf, yb, "ag_matmul vs bulk")
    for gf, gr in zip(_grads(fused, xs, ws), _grads(ring, xs, ws)):
        _close(gf, gr, "ag_matmul grad vs ring")
    print("ag_matmul: fused == ring == bulk (fwd+grad) OK")

    # bias + activation epilogue (forward path): per-slot epilogue == bulk
    b1 = jax.random.normal(jax.random.PRNGKey(3), (O,), jnp.float32)
    bs = jax.device_put(b1, NamedSharding(mesh, P("mx")))  # bias over columns
    ep = _sm(lambda xl, wl, bl: RM.ag_matmul(xl, wl, "mx", dim=1, n=4,
                                             bias=bl, act="gelu"),
             mesh, (P("data", "mx", "my"), P("my", "mx"), P("mx")),
             P("data", None, ("my", "mx")))
    epb = _sm(lambda xl, wl, bl: _epilogue(jnp.einsum(
        "bth,ho->bto", lax.all_gather(xl, "mx", axis=1, tiled=True), wl,
        preferred_element_type=jnp.float32), bl, "gelu").astype(xl.dtype),
        mesh, (P("data", "mx", "my"), P("my", "mx"), P("mx")),
        P("data", None, ("my", "mx")))
    _close(ep(xs, ws, bs), epb(xs, ws, bs), "ag_matmul bias+gelu epilogue")
    print("ag_matmul: bias+activation epilogue OK")


def check_matmul_rs(mesh):
    B, T, H, O = 2, 16, 24, 32
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(k1, (B, T, H), jnp.float32)
    w = jax.random.normal(k2, (H, O), jnp.float32) / np.sqrt(H)
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, "my")))
    ws = jax.device_put(w, NamedSharding(mesh, P("my", None)))

    for sdim, out_spec in ((1, P("data", "my", None)),
                           (2, P("data", None, "my"))):
        specs = ((P("data", None, "my"), P("my", None)), out_spec)
        fused = _sm(lambda xl, wl, _d=sdim:
                    RM.matmul_rs(xl, wl, "my", scatter_dim=_d, n=2),
                    mesh, *specs)
        ring = _sm(lambda xl, wl, _d=sdim:
                   OV.ring_matmul_rs(xl, wl, "my", scatter_dim=_d, n=2),
                   mesh, *specs)
        bulk = _sm(lambda xl, wl, _d=sdim: lax.psum_scatter(
            jnp.einsum("bth,ho->bto", xl, wl,
                       preferred_element_type=jnp.float32).astype(xl.dtype),
            "my", scatter_dimension=_d, tiled=True), mesh, *specs)
        _close(fused(xs, ws), ring(xs, ws), f"matmul_rs[{sdim}] vs ring")
        _close(fused(xs, ws), bulk(xs, ws), f"matmul_rs[{sdim}] vs bulk")
        for gf, gr in zip(_grads(fused, xs, ws), _grads(ring, xs, ws)):
            _close(gf, gr, f"matmul_rs[{sdim}] grad vs ring")
    # post-reduction activation epilogue
    act = _sm(lambda xl, wl: RM.matmul_rs(xl, wl, "my", scatter_dim=1, n=2,
                                          act="relu2"),
              mesh, (P("data", None, "my"), P("my", None)),
              P("data", "my", None))
    actb = _sm(lambda xl, wl: _epilogue(lax.psum_scatter(
        jnp.einsum("bth,ho->bto", xl, wl,
                   preferred_element_type=jnp.float32).astype(xl.dtype),
        "my", scatter_dimension=1, tiled=True).astype(jnp.float32),
        None, "relu2").astype(xl.dtype),
        mesh, (P("data", None, "my"), P("my", None)), P("data", "my", None))
    _close(act(xs, ws), actb(xs, ws), "matmul_rs relu2 epilogue")
    print("matmul_rs: rows/cols fused == ring == bulk (fwd+grad) + "
          "epilogue OK")


def check_contract(mesh):
    B, T, H, O = 2, 16, 24, 32
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(k1, (B, T, H), jnp.float32)
    w = jax.random.normal(k2, (H, O), jnp.float32) / np.sqrt(H)
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, "my")))
    ws = jax.device_put(w, NamedSharding(mesh, P(None, None)))
    specs = ((P("data", None, "my"), P(None, None)), P("data", None, None))
    fused = _sm(lambda xl, wl: RM.ag_matmul_contract(xl, wl, "my", n=2),
                mesh, *specs)
    ring = _sm(lambda xl, wl: OV.ring_ag_matmul_contract(xl, wl, "my", n=2),
               mesh, *specs)
    bulk = _sm(lambda xl, wl: jnp.einsum(
        "bth,ho->bto", lax.all_gather(xl, "my", axis=2, tiled=True), wl,
        preferred_element_type=jnp.float32).astype(xl.dtype), mesh, *specs)
    _close(fused(xs, ws), ring(xs, ws), "contract vs ring")
    _close(fused(xs, ws), bulk(xs, ws), "contract vs bulk")
    for gf, gr in zip(_grads(fused, xs, ws), _grads(ring, xs, ws)):
        _close(gf, gr, "contract grad vs ring")
    print("ag_matmul_contract: fused == ring == bulk (fwd+grad) OK")


def check_pair(mesh):
    B, T, H, O = 2, 16, 24, 32
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(4), 3)
    x = jax.random.normal(k1, (B, T, H), jnp.float32)
    w1 = jax.random.normal(k2, (H, O), jnp.float32) / np.sqrt(H)
    w1b = jax.random.normal(k3, (H, O), jnp.float32) / np.sqrt(H)
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, "my")))
    ws = jax.device_put(w1, NamedSharding(mesh, P("my", None)))
    wbs = jax.device_put(w1b, NamedSharding(mesh, P("my", None)))
    in_specs = (P("data", None, "my"), P("my", None), P("my", None))
    out_spec = P("data", "my", None)

    def gated(h, g):
        return jax.nn.silu(h) * g

    fused = _sm(lambda xl, al, bl: gated(*RM.matmul_rs_pair(
        xl, al, bl, "my", scatter_dim=1, n=2)), mesh, in_specs, out_spec)
    ring = _sm(lambda xl, al, bl: gated(
        OV.ring_matmul_rs(xl, al, "my", scatter_dim=1, n=2),
        OV.ring_matmul_rs(xl, bl, "my", scatter_dim=1, n=2)),
        mesh, in_specs, out_spec)
    _close(fused(xs, ws, wbs), ring(xs, ws, wbs), "pair vs two-ring")
    for gf, gr in zip(_grads(fused, xs, ws, wbs),
                      _grads(ring, xs, ws, wbs)):
        _close(gf, gr, "pair grad vs two-ring")
    print("matmul_rs_pair: gated shared-x-tile == two rings (fwd+grad) OK")


def check_fallback(mesh):
    """Non-tile-aligned shapes: the overlap dispatcher must route fused →
    ring silently with identical numerics."""
    # M = b·t_loc = 2·160 = 320 > 128 and 320 % 128 != 0 → not tile-aligned
    B, T, H, O = 2, 640, 24, 32
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    x = jax.random.normal(k1, (B, T, H), jnp.float32)
    w = jax.random.normal(k2, (H, O), jnp.float32) / np.sqrt(H)
    assert not RM.fused_ok_ag((B, T // 4, H // 2), (H // 2, O // 4), 4)
    xs = jax.device_put(x, NamedSharding(mesh, P("data", "mx", "my")))
    ws = jax.device_put(w, NamedSharding(mesh, P("my", "mx")))
    specs = ((P("data", "mx", "my"), P("my", "mx")),
             P("data", None, ("my", "mx")))
    disp = _sm(lambda xl, wl: OV.ag_matmul(xl, wl, "mx", dim=1, n=4,
                                           overlap="fused"), mesh, *specs)
    ring = _sm(lambda xl, wl: OV.ring_ag_matmul(xl, wl, "mx", dim=1, n=4),
               mesh, *specs)
    _close(disp(xs, ws), ring(xs, ws), "fused fallback == ring")
    # non-chunking scattered extent → matmul_rs dispatcher refuses fused
    assert not RM.fused_ok_rs((2, 10, 12), (12, 8), 4, 1)
    print("fallback: non-tile-aligned fused → ring OK")


def main():
    devs = np.array(jax.devices())
    mesh = Mesh(devs.reshape(1, 4, 2), ("data", "mx", "my"))
    check_ag_matmul(mesh)
    check_matmul_rs(mesh)
    check_contract(mesh)
    check_pair(mesh)
    check_fallback(mesh)
    print("ALL RING KERNEL CHECKS PASSED")


if __name__ == "__main__":
    main()
