"""Chaos harness for the cross-process writer fleet (ISSUE 8 acceptance).

Every scenario drives REAL ``runtime/procs.py`` children (spawn context,
shared-memory handover, heartbeat leases) through the public manager API and
asserts the ISSUE 8 invariant: a save either publishes a VERIFIED, complete
step — full shard coverage, every crc32 re-checked from disk — or leaves
only debris the next incarnation sweeps before a bit-exact resume.

Scenarios (``--scenario NAME [--writers N]``; ``--scenario all`` runs the
full matrix):

  bit-identity  clean procs saves (sync + async) are byte-for-byte identical
                to the thread-writer trees — same files, same bytes.
  kill9         writer N-1 SIGKILLs itself inside the torn window (shards on
                disk, partial manifest unpublished); the coordinator sees
                the exit, wipes the orphan range, reassigns it to a
                surviving child, and the step still publishes verified.
  sigstop       writer N-1 SIGSTOPs itself: heartbeats freeze, the lease
                expires, the coordinator SIGKILL-fences the slot and
                reassigns.  Publishes verified.
  slow          writer N-1 sleeps past ``writer_timeout`` with heartbeats
                flowing: logged as slow, NEVER killed, no reassignment, the
                step publishes clean (no ``reassigned`` record).
  corrupt       writer N-1 truncates a shard AFTER checksumming it, then
                publishes its partial normally: the coordinator's disk
                verification rejects the partial and reassigns.
  coordinator   a CHILD process (``--child-coord-kill DIR``) publishes step
                4 in procs mode, starts save 8 with one writer parked slow,
                and SIGKILLs ITSELF mid-save.  The parent verifies the
                orphaned writer processes self-exit (ppid watch in the
                heartbeat thread), the debris (``step_*.tmp`` + ``.fleet``)
                is swept by the next incarnation, and restore(4) is
                bit-exact.
  supervised    run_supervised with a procs-mode sync manager, reassign=0
                and an injected kill9: the QuorumError kills incarnation 1
                at the boundary, abort() fences the fleet, incarnation 2 is
                handed the latest PUBLISHED step (the run_supervised
                resume-step pin) and resumes bit-exact vs an uninterrupted
                baseline.
  spill         the kill9 scenario with ``REPRO_CKPT_HANDOVER=spill`` — the
                file-backed arena fallback behaves identically.

Module top stays import-light on purpose: spawn children re-import this
file as ``__main__``, so jax (and anything that pulls it) is imported only
inside functions.
"""
import argparse
import json
import os
import signal
import subprocess
import sys
import time
import zlib

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

TIMEOUT = 1.0          # writer lease — short so sigstop fences fast


# ---------------------------------------------------------------------------
# deterministic fixtures
# ---------------------------------------------------------------------------

def _np_state(seed=0):
    """Deterministic numpy pytree (~200 KB), mixed dtypes incl. a raw-path
    bf16 leaf — everything the wire format has to carry, no jax needed."""
    rng = np.random.default_rng(seed)
    state = {
        "params": {
            "embed": rng.standard_normal((64, 96)).astype(np.float32),
            "w_qkv": rng.standard_normal((96, 192)).astype(np.float32),
            "w_out": rng.standard_normal((96, 96)).astype(np.float32),
            "scale": rng.standard_normal((96,)).astype(np.float32) * 0.1,
        },
        "opt_state": {
            "mu": rng.standard_normal((96, 192)).astype(np.float32),
            "nu": rng.standard_normal((96, 192)).astype(np.float32),
            "count": np.full((3,), seed * 100 + 7, dtype=np.int32),
            # 0-d on purpose: adamw's ``.step`` is 0-d, and the wire format
            # must NOT promote it to (1,) (restore checks template shapes)
            "step": np.asarray(seed * 10 + 1, dtype=np.int32),
        },
    }
    try:
        import ml_dtypes
        state["params"]["ln_bf16"] = rng.standard_normal(
            (96,)).astype(ml_dtypes.bfloat16)
    except ImportError:
        pass
    return state


def _assert_tree_equal(a, b):
    import jax
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), (len(la), len(lb))
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype, (x.dtype, y.dtype)
        np.testing.assert_array_equal(x, y)


def _verify_published_step(ckpt_dir, step):
    """The publish-side half of the invariant, checked from raw disk: the
    global manifest is complete, covers every shard exactly once, and every
    shard file's bytes re-hash to the recorded crc32.  Returns the meta."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    assert os.path.isdir(d), os.listdir(ckpt_dir)
    with open(os.path.join(d, "MANIFEST.json")) as f:
        meta = json.load(f)
    assert meta.get("complete") is True, meta
    manifest = meta["manifest"]
    assert manifest, "empty manifest"
    for name, info in manifest.items():
        path = os.path.join(d, info["file"])
        blob = open(path, "rb").read()
        assert len(blob) == info["bytes"], (name, len(blob), info["bytes"])
        assert zlib.crc32(blob) == info["crc32"], name
    return meta


def _assert_no_debris(ckpt_dir):
    names = os.listdir(ckpt_dir)
    assert not [n for n in names if n.endswith(".tmp")], names
    assert ".fleet" not in names, names


# ---------------------------------------------------------------------------
# bit-identity: procs trees == thread trees, byte for byte
# ---------------------------------------------------------------------------

def _tree_files(root):
    out = {}
    for dirpath, _, files in os.walk(root):
        for f in files:
            p = os.path.join(dirpath, f)
            out[os.path.relpath(p, root)] = open(p, "rb").read()
    return out


def scenario_bit_identity(root, n_writers):
    from repro.checkpoint.manager import (AsyncCheckpointManager,
                                          CheckpointManager)
    state = _np_state(seed=1)
    td = os.path.join(root, f"bid_thr{n_writers}")
    pd = os.path.join(root, f"bid_prc{n_writers}")
    ad = os.path.join(root, f"bid_async{n_writers}")
    mt = CheckpointManager(td, writers=n_writers)
    mt.save(3, state)
    mp_ = CheckpointManager(pd, writers=n_writers, writer_procs=True,
                            writer_timeout=TIMEOUT)
    mp_.save(3, state)
    ma = AsyncCheckpointManager(ad, writers=n_writers, writer_procs=True,
                                writer_timeout=TIMEOUT)
    ma.save_async(3, state)
    ma.wait_until_finished()
    mp_.close()
    ma.close()
    ft = _tree_files(os.path.join(td, "step_00000003"))
    fp = _tree_files(os.path.join(pd, "step_00000003"))
    fa = _tree_files(os.path.join(ad, "step_00000003"))
    assert set(ft) == set(fp) == set(fa), (sorted(ft), sorted(fp))
    for name in ft:
        assert ft[name] == fp[name], f"sync procs differs at {name}"
        assert ft[name] == fa[name], f"async procs differs at {name}"
    restored, step = CheckpointManager(pd, writers=n_writers).restore(
        _np_state(seed=1))
    assert step == 3
    _assert_tree_equal(restored, state)
    _assert_no_debris(pd)
    _assert_no_debris(ad)
    print(f"bit-identity w={n_writers}: {len(ft)} files byte-identical "
          "across thread / procs-sync / procs-async")


# ---------------------------------------------------------------------------
# in-fleet faults: kill9 / sigstop / slow / corrupt
# ---------------------------------------------------------------------------

_FAULT_WHY = {
    "kill9": "writer process exited (-9)",
    "sigstop": "heartbeat lease expired",
    "corrupt": "partial failed disk verification",
}


def scenario_fault(root, kind, n_writers):
    from repro.checkpoint.manager import CheckpointManager
    from repro.runtime.fault import FailureInjector
    victim = n_writers - 1
    spec = ((victim, "slow", {"seconds": 2.5}) if kind == "slow"
            else (victim, kind))
    inj = FailureInjector(proc_fail_at={2: spec})
    d = os.path.join(root, f"fault_{kind}{n_writers}")
    mgr = CheckpointManager(d, writers=n_writers, writer_procs=True,
                            writer_timeout=TIMEOUT,
                            proc_fault=inj.proc_fault)
    s1, s2 = _np_state(seed=1), _np_state(seed=2)
    mgr.save(1, s1)                       # clean save — fleet healthy
    mgr.save(2, s2)                       # fault lands in this save
    assert inj.log == [f"step 2: injected proc fault {kind} "
                       f"into writer {victim}"], inj.log
    meta = _verify_published_step(d, 2)
    events = mgr._fleet.events
    if kind == "slow":
        # heartbeats stayed healthy: logged, never killed, no reassignment
        assert "reassigned" not in meta, meta
        assert any("slow" in e and f"writer {victim}" in e
                   for e in events), events
        assert not any("reassigned" in e for e in events), events
    else:
        why = meta["reassigned"][str(victim)]
        assert _FAULT_WHY[kind] in why, (kind, why)
        assert any(f"writer {victim} range reassigned" in e
                   for e in events), events
    restored, step = mgr.restore(_np_state(seed=2))
    assert step == 2
    _assert_tree_equal(restored, s2)
    mgr.close()
    _assert_no_debris(d)
    print(f"fault {kind} w={n_writers}: step 2 published verified"
          + ("" if kind == "slow"
             else f" via reassignment ({_FAULT_WHY[kind]!r})"))


# ---------------------------------------------------------------------------
# coordinator kill -9 mid-save: orphans self-exit, debris swept, bit-exact
# ---------------------------------------------------------------------------

def child_coord_kill(ckpt_dir):
    from repro.checkpoint.manager import AsyncCheckpointManager
    mgr = AsyncCheckpointManager(ckpt_dir, writers=2, writer_procs=True,
                                 writer_timeout=5.0)
    mgr.save_async(4, _np_state(seed=4))
    mgr.wait_until_finished()             # step 4 is PUBLISHED
    # park writer 1 in a long sleep so save 8 is mid-flight when we die
    mgr.proc_fault = (lambda step, w:
                      {"kind": "slow", "seconds": 120.0}
                      if (step == 8 and w == 1) else None)
    mgr.save_async(8, _np_state(seed=8))
    w0 = os.path.join(ckpt_dir, "step_00000008.tmp", "writer_00",
                      "manifest.json")
    deadline = time.monotonic() + 30
    while not os.path.exists(w0):         # writer 0's partial is on disk
        assert time.monotonic() < deadline, "writer 0 never published partial"
        time.sleep(0.05)
    os.kill(os.getpid(), signal.SIGKILL)  # coordinator dies, no fence runs


def scenario_coordinator(root):
    from repro.checkpoint.manager import CheckpointManager
    from repro.runtime.procs import read_heartbeat
    d = os.path.join(root, "coord")
    r = subprocess.run([sys.executable, os.path.abspath(__file__),
                       "--child-coord-kill", d],
                      capture_output=True, text=True,
                      env=dict(os.environ), timeout=600)
    assert r.returncode == -signal.SIGKILL, \
        (r.returncode, r.stdout, r.stderr[-2000:])
    # the dead coordinator left a half-written step AND fleet scratch behind
    names = os.listdir(d)
    assert "step_00000008.tmp" in names, names
    assert ".fleet" in names, names
    # the orphaned writer children notice the vanished parent (ppid watch in
    # the heartbeat thread) and self-exit — no fence ever ran
    pids = []
    for slot in range(2):
        hb = read_heartbeat(os.path.join(d, ".fleet", f"hb_{slot:02d}"))
        if hb is not None:
            pids.append(hb[0])
    assert pids, "no heartbeat files — fleet never spawned?"
    deadline = time.monotonic() + 15
    while time.monotonic() < deadline:
        alive = []
        for pid in pids:
            try:
                os.kill(pid, 0)
                alive.append(pid)
            except OSError:
                pass
        if not alive:
            break
        time.sleep(0.1)
    assert not alive, f"orphan writers {alive} still alive 15s after kill"
    # next incarnation: torn step invisible, ALL debris swept before restore
    mgr = CheckpointManager(d, writers=2, writer_procs=True,
                            writer_timeout=TIMEOUT)
    assert mgr.all_steps() == [4], mgr.all_steps()
    _assert_no_debris(d)
    restored, step = mgr.restore(_np_state(seed=4))
    assert step == 4
    _assert_tree_equal(restored, _np_state(seed=4))   # bit-exact resume
    mgr.close()
    print(f"coordinator kill -9: orphans {pids} self-exited, debris swept, "
          "restore(4) bit-exact")


# ---------------------------------------------------------------------------
# supervised restart: QuorumError at the boundary, resume bit-exact
# ---------------------------------------------------------------------------

def scenario_supervised(root):
    import jax
    import jax.numpy as jnp
    from repro.checkpoint.manager import CheckpointManager
    from repro.config import ModelConfig, ParallelConfig, RunConfig
    from repro.data.synthetic import SyntheticLM
    from repro.models import lm
    from repro.optim import adamw
    from repro.runtime.fault import FailureInjector, run_supervised
    from repro.train import loop as train_loop
    from repro.train import step as TS

    cfg = ModelConfig(name="procs-test", family="dense", num_layers=2,
                      d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                      vocab_size=64, mlp_kind="swiglu")
    rc = RunConfig("t", "train", 16, 8, lr=2e-3)
    ds = SyntheticLM(cfg.vocab_size, rc.seq_len, rc.global_batch, seed=7)
    pcfg = ParallelConfig(strategy="hecaton", data=1, model=1, mx=1, my=1,
                          microbatches=1, zero1=False)
    ts = jax.jit(TS.build_train_step(cfg, pcfg, rc, None,
                                     compute_dtype=jnp.float32))
    TOTAL = 8

    def fresh():
        p = lm.init_params(cfg, jax.random.PRNGKey(0))
        return {"params": p, "opt_state": adamw.init(p)}

    def batches(lo, hi):
        return ({k: jnp.asarray(v) for k, v in ds.batch_at(s).items()}
                for s in range(lo, hi))

    # uninterrupted baseline
    base = train_loop.train(ts, fresh(), batches(0, TOTAL), num_steps=TOTAL,
                            log_every=1, log_fn=lambda *a: None)
    base_hist = dict(base["history"])

    # supervised run: procs-mode sync manager, NO reassignment budget, so
    # the injected kill9 at step 4's save becomes a QuorumError that kills
    # incarnation 1 at the boundary (abort() must fence the fleet)
    d = os.path.join(root, "supervised")
    mgr = CheckpointManager(d, writers=2, writer_procs=True,
                            writer_timeout=TIMEOUT, reassign=0)
    inj = FailureInjector(proc_fail_at={4: (1, "kill9")})
    resume_args = []

    def make_state(resume_step):
        resume_args.append(resume_step)
        state, start = fresh(), 0
        if resume_step is not None:
            state, start = mgr.restore(state)
        return state, start

    def run_steps(state, start, inc):
        return train_loop.train(ts, state, batches(start, TOTAL),
                                start_step=start, num_steps=TOTAL,
                                ckpt=mgr, ckpt_every=2, log_every=1,
                                injector=inj, log_fn=lambda *a: None)

    state, incarnations = run_supervised(make_state, run_steps, ckpt=mgr,
                                         sleep_fn=lambda _: None)
    assert incarnations == 2, incarnations
    assert inj.log == ["step 4: injected proc fault kill9 into writer 1"], \
        inj.log
    # the resume-step pin: incarnation 2 was handed the latest PUBLISHED
    # step (2 — the torn 4 was fenced), not None
    assert resume_args == [None, 2], resume_args
    steps = mgr.all_steps()
    assert steps[-1] == 8 and 4 in steps, steps
    _verify_published_step(d, 8)
    # crash-resume bit-exact vs the uninterrupted baseline
    hist = dict(state["history"])
    for s, want in base_hist.items():
        if s >= 2:                        # steps re-run by incarnation 2
            assert hist[s] == want, (s, hist[s], want)
    restored, step = mgr.restore(fresh())
    assert step == 8
    _assert_tree_equal(restored, {"params": state["params"],
                                  "opt_state": state["opt_state"]})
    mgr.close()
    _assert_no_debris(d)
    print("supervised: kill9 -> QuorumError fenced incarnation 1, "
          f"resume pinned to step {resume_args[1]}, history bit-exact")


# ---------------------------------------------------------------------------
# spill handover fallback
# ---------------------------------------------------------------------------

def scenario_spill(root):
    from repro.checkpoint.manager import CheckpointManager
    d = os.path.join(root, "spill")
    prev = os.environ.get("REPRO_CKPT_HANDOVER")
    os.environ["REPRO_CKPT_HANDOVER"] = "spill"
    try:
        mgr = CheckpointManager(d, writers=2, writer_procs=True,
                                writer_timeout=TIMEOUT,
                                proc_fault=lambda s, w:
                                    {"kind": "kill9"}
                                    if (s == 2 and w == 1) else None)
        s2 = _np_state(seed=2)
        mgr.save(2, s2)
        assert mgr._fleet.handover == "spill"
        meta = _verify_published_step(d, 2)
        assert "1" in meta.get("reassigned", {}), meta
        restored, step = mgr.restore(_np_state(seed=2))
        assert step == 2
        _assert_tree_equal(restored, s2)
        mgr.close()
        _assert_no_debris(d)
    finally:
        if prev is None:
            os.environ.pop("REPRO_CKPT_HANDOVER", None)
        else:
            os.environ["REPRO_CKPT_HANDOVER"] = prev
    print("spill: file-backed arena handover published verified "
          "via reassignment")


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def run(scenario, root, n_writers):
    if scenario == "bit-identity":
        scenario_bit_identity(root, n_writers)
    elif scenario in ("kill9", "sigstop", "slow", "corrupt"):
        scenario_fault(root, scenario, n_writers)
    elif scenario == "coordinator":
        scenario_coordinator(root)
    elif scenario == "supervised":
        scenario_supervised(root)
    elif scenario == "spill":
        scenario_spill(root)
    elif scenario == "all":
        scenario_bit_identity(root, 3)
        for n in (2, 4):
            for kind in ("kill9", "sigstop", "slow", "corrupt"):
                scenario_fault(root, kind, n)
        scenario_coordinator(root)
        scenario_supervised(root)
        scenario_spill(root)
        print("ALL WRITER-PROCS CHAOS CHECKS PASSED")
    else:
        raise SystemExit(f"unknown scenario {scenario!r}")


def main(argv):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="all")
    ap.add_argument("--writers", type=int, default=2)
    ap.add_argument("--child-coord-kill", metavar="DIR", default=None)
    args = ap.parse_args(argv)
    if args.child_coord_kill:
        child_coord_kill(args.child_coord_kill)
        return
    import tempfile
    root = tempfile.mkdtemp(prefix="procs_chaos_")
    run(args.scenario, root, args.writers)


if __name__ == "__main__":
    main(sys.argv[1:])
