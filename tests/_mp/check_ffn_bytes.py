"""Paper-faithfulness check: the per-device collective bytes of one Hecaton FFN
forward, parsed from compiled HLO, match the Table III / eq.(2) ring model.

fwd FFN = AG_x(t_ax) + RS_h(h_ax) + AG_h(h_ax) + RS_y(t_ax):
  AG bytes  = (g-1) * local_shard_bytes        (per device, ring)
  RS bytes  = (g-1)/g * operand_bytes
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import hecaton as H
from repro.roofline.hlo import analyze


def main():
    devs = np.array(jax.devices()).reshape(2, 2, 2)
    mesh = Mesh(devs, ("data", "mx", "my"))
    Bb, T, Hd, F = 4, 64, 32, 128
    elt = 4  # f32

    def ffn(x, w1, w2):
        return H.ffn_block(x, w1, w2, mesh=mesh, act_fn=jax.nn.silu,
                           t_ax="mx", h_ax="my")

    c = jax.jit(ffn, in_shardings=(
        NamedSharding(mesh, P("data", "mx", "my")),
        NamedSharding(mesh, P("my", "mx")),
        NamedSharding(mesh, P("mx", "my")))).lower(
            jax.ShapeDtypeStruct((Bb, T, Hd), jnp.float32),
            jax.ShapeDtypeStruct((Hd, F), jnp.float32),
            jax.ShapeDtypeStruct((F, Hd), jnp.float32)).compile()
    r = analyze(c.as_text())

    b_loc = Bb // 2
    g = 2   # mx == my == 2
    # AG_x: local [b_loc, T/2, Hd/2]; AG_h: local [b_loc, T/2, F/2]
    ag = (g - 1) * b_loc * (T // 2) * (Hd // 2) * elt \
        + (g - 1) * b_loc * (T // 2) * (F // 2) * elt
    # RS_h: operand [b_loc, T, F/2]; RS_y: operand [b_loc, T, Hd/2]
    rs = (g - 1) / g * b_loc * T * (F // 2) * elt \
        + (g - 1) / g * b_loc * T * (Hd // 2) * elt
    np.testing.assert_allclose(r.coll_bytes["all-gather"], ag, rtol=1e-6)
    np.testing.assert_allclose(r.coll_bytes["reduce-scatter"], rs, rtol=1e-6)
    assert r.coll_count["all-gather"] == 2 and \
        r.coll_count["reduce-scatter"] == 2
    print("AG", r.coll_bytes["all-gather"], "==", ag,
          "| RS", r.coll_bytes["reduce-scatter"], "==", rs)
    print("BYTES MATCH THEORY")


if __name__ == "__main__":
    main()
