"""Subprocess checks for the async checkpoint subsystem (ISSUE 4 acceptance).

Part A — kill-mid-write atomicity: a CHILD process (``--child-kill DIR``)
trains a tiny model, publishes step 4, then issues ``save_async(8)`` with a
deliberately slowed writer and ``os._exit(1)``s between ``save_async`` and
writer completion — the acceptance criterion's kill.  The parent verifies the
half-written step is never published nor listed, its ``.tmp`` debris is swept
by the next incarnation's manager, and the restore from the previous
PUBLISHED step (4) resumes bit-exact against an uninterrupted run.

Part B — elastic restore: a checkpoint saved from a single-device run is
restored with *target-mesh* shardings onto 1x8 / 2x4 / 4x2 (data x model)
megatron grids; resuming through the checkpoint roundtrip must be bit-exact
(loss history AND final params) against resuming from the same state
device_put directly — the fold-of-train_step property train/loop.py
documents.  The resumed sharded state is then saved *asynchronously* from
the mesh and restored again, proving sharded→global snapshots are lossless.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import subprocess
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.checkpoint.manager import (AsyncCheckpointManager,
                                      CheckpointManager)
from repro.config import ModelConfig, ParallelConfig, RunConfig
from repro.data.synthetic import SyntheticLM
from repro.models import lm
from repro.optim import adamw
from repro.train import step as TS

CFG = ModelConfig(name="ckpt-test", family="dense", num_layers=2, d_model=32,
                  num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                  mlp_kind="swiglu")
RC = RunConfig("t", "train", 16, 8, lr=2e-3)
DS = SyntheticLM(CFG.vocab_size, RC.seq_len, RC.global_batch, seed=7)
PCFG1 = ParallelConfig(strategy="hecaton", data=1, model=1, mx=1, my=1,
                       microbatches=1, zero1=False)


def _ts1():
    return jax.jit(TS.build_train_step(CFG, PCFG1, RC, None,
                                       compute_dtype=jnp.float32))


def _fold(ts, params, opt, lo, hi, batch_fn=None):
    losses = []
    for s in range(lo, hi):
        b = batch_fn(s) if batch_fn else {
            k: jnp.asarray(v) for k, v in DS.batch_at(s).items()}
        params, opt, m = ts(params, opt, b)
        losses.append(float(m["loss"]))
    return params, opt, losses


# ---------------------------------------------------------------------------
# Part A: kill between save_async and writer completion
# ---------------------------------------------------------------------------

def child_kill(ckpt_dir):
    ts = _ts1()
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    mgr = AsyncCheckpointManager(ckpt_dir)
    params, opt, _ = _fold(ts, params, opt, 0, 4)
    mgr.save_async(4, {"params": params, "opt_state": opt})
    mgr.wait_until_finished()                 # step 4 is PUBLISHED
    params, opt, _ = _fold(ts, params, opt, 4, 8)
    # slow the writer so the kill reliably lands mid-write
    import repro.checkpoint.manager as M
    orig = M.np.save

    def slow_save(*a, **k):
        time.sleep(0.25)
        return orig(*a, **k)

    M.np.save = slow_save
    mgr.save_async(8, {"params": params, "opt_state": opt})
    time.sleep(0.1)                           # let the writer open step_8.tmp
    os._exit(42)                              # hard kill, writer mid-write —
    # 42 (not 1) so the parent can tell the deliberate kill from an uncaught
    # child exception, which exits 1


def check_kill_mid_write(ckpt_dir):
    env = dict(os.environ)
    r = subprocess.run([sys.executable, os.path.abspath(__file__),
                        "--child-kill", ckpt_dir],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 42, (r.returncode, r.stdout, r.stderr[-2000:])
    names = os.listdir(ckpt_dir)
    assert "step_00000008" not in names, names   # half-write never published
    assert "step_00000004" in names, names
    # next incarnation: debris invisible and swept, restore = step 4
    mgr = CheckpointManager(ckpt_dir)
    assert mgr.all_steps() == [4], mgr.all_steps()
    assert not [n for n in os.listdir(ckpt_dir) if n.endswith(".tmp")]

    ts = _ts1()
    p0 = lm.init_params(CFG, jax.random.PRNGKey(0))
    o0 = adamw.init(p0)
    pa, oa, la = _fold(ts, p0, o0, 0, 8)      # uninterrupted reference
    restored, step = mgr.restore({"params": p0, "opt_state": o0})
    assert step == 4
    pb, ob, lb = _fold(ts, restored["params"], restored["opt_state"], 4, 8)
    assert la[4:] == lb, (la[4:], lb)         # bit-exact resumed losses
    for a, b in zip(jax.tree_util.tree_leaves(pa),
                    jax.tree_util.tree_leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("kill-mid-write: step 8 never published, debris swept, "
          "restore(4) resumed bit-exact")


# ---------------------------------------------------------------------------
# Part B: elastic restore onto 1x8 / 2x4 / 4x2 grids
# ---------------------------------------------------------------------------

def check_elastic_grids(tmp_root):
    from repro.parallel import specs as SP

    ts1 = _ts1()
    p0 = lm.init_params(CFG, jax.random.PRNGKey(0))
    o0 = adamw.init(p0)
    p3, o3, _ = _fold(ts1, p0, o0, 0, 3)
    ckpt_dir = os.path.join(tmp_root, "elastic")
    mgr = CheckpointManager(ckpt_dir)
    mgr.save(3, {"params": p3, "opt_state": o3})

    devs = np.array(jax.devices())
    from jax.sharding import Mesh
    for n_d, n_m in ((1, 8), (2, 4), (4, 2)):
        mesh = Mesh(devs.reshape(n_d, n_m), ("data", "model"))
        pcfg = ParallelConfig(strategy="megatron", data=n_d, model=n_m,
                              microbatches=1, zero1=False)
        pspecs = SP.param_specs(p3, mesh, pcfg)
        pshard = SP.sharding_tree(pspecs, mesh)
        oshard = SP.sharding_tree(
            SP.opt_state_specs(pspecs, p3, mesh, pcfg), mesh)
        bsp = SP.batch_specs(mesh, pcfg, microbatched=False,
                             seq_len=RC.seq_len)
        ts = jax.jit(TS.build_train_step(CFG, pcfg, RC, mesh,
                                         compute_dtype=jnp.float32))

        def batch_fn(s, _mesh=mesh, _bsp=bsp):
            return {k: jax.device_put(jnp.asarray(v),
                                      NamedSharding(_mesh, _bsp[k]))
                    for k, v in DS.batch_at(s).items()}

        # resume THROUGH the checkpoint, re-sharded for this grid
        restored, step = mgr.restore({"params": p3, "opt_state": o3},
                                     shardings={"params": pshard,
                                                "opt_state": oshard})
        assert step == 3
        pa, oa, la = _fold(ts, restored["params"], restored["opt_state"],
                           3, 6, batch_fn)
        # resume from the SAME state device_put directly (no checkpoint)
        pb, ob, lb = _fold(ts, jax.device_put(p3, pshard),
                           jax.device_put(o3, oshard), 3, 6, batch_fn)
        assert la == lb, (n_d, n_m, la, lb)   # bit-exact loss resume
        for a, b in zip(jax.tree_util.tree_leaves(pa),
                        jax.tree_util.tree_leaves(pb)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        # async save FROM the sharded state; restore = device_get bit-exact
        amgr = AsyncCheckpointManager(os.path.join(tmp_root,
                                                   f"grid{n_d}x{n_m}"))
        amgr.save_async(6, {"params": pa, "opt_state": oa})
        amgr.wait_until_finished()
        rt, _ = amgr.restore({"params": p3, "opt_state": o3})
        for a, b in zip(jax.tree_util.tree_leaves(rt),
                        jax.tree_util.tree_leaves(
                            {"params": pa, "opt_state": oa})):
            np.testing.assert_array_equal(np.asarray(a),
                                          np.asarray(jax.device_get(b)))
        amgr.close()
        print(f"elastic {n_d}x{n_m}: ckpt-roundtrip resume bit-exact, "
              "sharded async snapshot lossless")


def main():
    import tempfile
    root = tempfile.mkdtemp(prefix="ckpt_check_")
    check_kill_mid_write(os.path.join(root, "kill"))
    check_elastic_grids(root)
    print("ALL CHECKPOINT CHECKS PASSED")


if __name__ == "__main__":
    if len(sys.argv) > 2 and sys.argv[1] == "--child-kill":
        child_kill(sys.argv[2])
    else:
        main()
