"""Subprocess checks for the async checkpoint subsystem (ISSUE 4 acceptance).

Part A — kill-mid-write atomicity: a CHILD process (``--child-kill DIR``)
trains a tiny model, publishes step 4, then issues ``save_async(8)`` with a
deliberately slowed writer and ``os._exit(1)``s between ``save_async`` and
writer completion — the acceptance criterion's kill.  The parent verifies the
half-written step is never published nor listed, its ``.tmp`` debris is swept
by the next incarnation's manager, and the restore from the previous
PUBLISHED step (4) resumes bit-exact against an uninterrupted run.

Part B — elastic restore: a checkpoint saved from a single-device run is
restored with *target-mesh* shardings onto 1x8 / 2x4 / 4x2 (data x model)
megatron grids; resuming through the checkpoint roundtrip must be bit-exact
(loss history AND final params) against resuming from the same state
device_put directly — the fold-of-train_step property train/loop.py
documents.  The resumed sharded state is then saved *asynchronously* from
the mesh and restored again, proving sharded→global snapshots are lossless.

Part C — writer-kill quorum (ISSUE 6 acceptance, N=2 and N=4): a child
(``--child-writer-kill DIR N``) publishes step 4 with an N-writer group,
then issues ``save_async(8)`` with writer N-1 hung INSIDE the torn window
(shards written, partial manifest not yet published) and hard-kills itself.
The parent inspects the torn debris (N-1 partial manifests present, the
dead writer's shards present but unmanifested, no global manifest), then
verifies the torn step is never restorable, the debris is swept, and
restore(4) resumes bit-exact against an uninterrupted run.

``--pipeline-quorum`` (the CI ckpt-quorum job) runs the full crash-resume
story on a 2-pod 1F1B pipeline grid: one checkpoint writer per stage
(stage_writer_map), an injected single-writer death at a save boundary
killing the incarnation at the quorum gate, run_supervised fencing +
restart, loss history bit-exact against an uninterrupted baseline, an async
multi-writer save/restore roundtrip of the pipeline state, and a corrupted
shard failing restore with the file named.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import subprocess
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.checkpoint.manager import (AsyncCheckpointManager,
                                      CheckpointManager)
from repro.config import ModelConfig, ParallelConfig, RunConfig
from repro.data.synthetic import SyntheticLM
from repro.models import lm
from repro.optim import adamw
from repro.train import step as TS

CFG = ModelConfig(name="ckpt-test", family="dense", num_layers=2, d_model=32,
                  num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                  mlp_kind="swiglu")
RC = RunConfig("t", "train", 16, 8, lr=2e-3)
DS = SyntheticLM(CFG.vocab_size, RC.seq_len, RC.global_batch, seed=7)
PCFG1 = ParallelConfig(strategy="hecaton", data=1, model=1, mx=1, my=1,
                       microbatches=1, zero1=False)


def _ts1():
    return jax.jit(TS.build_train_step(CFG, PCFG1, RC, None,
                                       compute_dtype=jnp.float32))


def _fold(ts, params, opt, lo, hi, batch_fn=None):
    losses = []
    for s in range(lo, hi):
        b = batch_fn(s) if batch_fn else {
            k: jnp.asarray(v) for k, v in DS.batch_at(s).items()}
        params, opt, m = ts(params, opt, b)
        losses.append(float(m["loss"]))
    return params, opt, losses


# ---------------------------------------------------------------------------
# Part A: kill between save_async and writer completion
# ---------------------------------------------------------------------------

def child_kill(ckpt_dir):
    ts = _ts1()
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    mgr = AsyncCheckpointManager(ckpt_dir)
    params, opt, _ = _fold(ts, params, opt, 0, 4)
    mgr.save_async(4, {"params": params, "opt_state": opt})
    mgr.wait_until_finished()                 # step 4 is PUBLISHED
    params, opt, _ = _fold(ts, params, opt, 4, 8)
    # slow the writer so the kill reliably lands mid-write
    import repro.checkpoint.manager as M
    orig = M.np.save

    def slow_save(*a, **k):
        time.sleep(0.25)
        return orig(*a, **k)

    M.np.save = slow_save
    mgr.save_async(8, {"params": params, "opt_state": opt})
    time.sleep(0.1)                           # let the writer open step_8.tmp
    os._exit(42)                              # hard kill, writer mid-write —
    # 42 (not 1) so the parent can tell the deliberate kill from an uncaught
    # child exception, which exits 1


def check_kill_mid_write(ckpt_dir):
    env = dict(os.environ)
    r = subprocess.run([sys.executable, os.path.abspath(__file__),
                        "--child-kill", ckpt_dir],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 42, (r.returncode, r.stdout, r.stderr[-2000:])
    names = os.listdir(ckpt_dir)
    assert "step_00000008" not in names, names   # half-write never published
    assert "step_00000004" in names, names
    # next incarnation: debris invisible and swept, restore = step 4
    mgr = CheckpointManager(ckpt_dir)
    assert mgr.all_steps() == [4], mgr.all_steps()
    assert not [n for n in os.listdir(ckpt_dir) if n.endswith(".tmp")]

    ts = _ts1()
    p0 = lm.init_params(CFG, jax.random.PRNGKey(0))
    o0 = adamw.init(p0)
    pa, oa, la = _fold(ts, p0, o0, 0, 8)      # uninterrupted reference
    restored, step = mgr.restore({"params": p0, "opt_state": o0})
    assert step == 4
    pb, ob, lb = _fold(ts, restored["params"], restored["opt_state"], 4, 8)
    assert la[4:] == lb, (la[4:], lb)         # bit-exact resumed losses
    for a, b in zip(jax.tree_util.tree_leaves(pa),
                    jax.tree_util.tree_leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("kill-mid-write: step 8 never published, debris swept, "
          "restore(4) resumed bit-exact")


# ---------------------------------------------------------------------------
# Part B: elastic restore onto 1x8 / 2x4 / 4x2 grids
# ---------------------------------------------------------------------------

def check_elastic_grids(tmp_root):
    from repro.parallel import specs as SP

    ts1 = _ts1()
    p0 = lm.init_params(CFG, jax.random.PRNGKey(0))
    o0 = adamw.init(p0)
    p3, o3, _ = _fold(ts1, p0, o0, 0, 3)
    ckpt_dir = os.path.join(tmp_root, "elastic")
    mgr = CheckpointManager(ckpt_dir)
    mgr.save(3, {"params": p3, "opt_state": o3})

    devs = np.array(jax.devices())
    from jax.sharding import Mesh
    for n_d, n_m in ((1, 8), (2, 4), (4, 2)):
        mesh = Mesh(devs.reshape(n_d, n_m), ("data", "model"))
        pcfg = ParallelConfig(strategy="megatron", data=n_d, model=n_m,
                              microbatches=1, zero1=False)
        pspecs = SP.param_specs(p3, mesh, pcfg)
        pshard = SP.sharding_tree(pspecs, mesh)
        oshard = SP.sharding_tree(
            SP.opt_state_specs(pspecs, p3, mesh, pcfg), mesh)
        bsp = SP.batch_specs(mesh, pcfg, microbatched=False,
                             seq_len=RC.seq_len)
        ts = jax.jit(TS.build_train_step(CFG, pcfg, RC, mesh,
                                         compute_dtype=jnp.float32))

        def batch_fn(s, _mesh=mesh, _bsp=bsp):
            return {k: jax.device_put(jnp.asarray(v),
                                      NamedSharding(_mesh, _bsp[k]))
                    for k, v in DS.batch_at(s).items()}

        # resume THROUGH the checkpoint, re-sharded for this grid
        restored, step = mgr.restore({"params": p3, "opt_state": o3},
                                     shardings={"params": pshard,
                                                "opt_state": oshard})
        assert step == 3
        pa, oa, la = _fold(ts, restored["params"], restored["opt_state"],
                           3, 6, batch_fn)
        # resume from the SAME state device_put directly (no checkpoint)
        pb, ob, lb = _fold(ts, jax.device_put(p3, pshard),
                           jax.device_put(o3, oshard), 3, 6, batch_fn)
        assert la == lb, (n_d, n_m, la, lb)   # bit-exact loss resume
        for a, b in zip(jax.tree_util.tree_leaves(pa),
                        jax.tree_util.tree_leaves(pb)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        # async save FROM the sharded state; restore = device_get bit-exact
        amgr = AsyncCheckpointManager(os.path.join(tmp_root,
                                                   f"grid{n_d}x{n_m}"))
        amgr.save_async(6, {"params": pa, "opt_state": oa})
        amgr.wait_until_finished()
        rt, _ = amgr.restore({"params": p3, "opt_state": o3})
        for a, b in zip(jax.tree_util.tree_leaves(rt),
                        jax.tree_util.tree_leaves(
                            {"params": pa, "opt_state": oa})):
            np.testing.assert_array_equal(np.asarray(a),
                                          np.asarray(jax.device_get(b)))
        amgr.close()
        print(f"elastic {n_d}x{n_m}: ckpt-roundtrip resume bit-exact, "
              "sharded async snapshot lossless")


# ---------------------------------------------------------------------------
# Part C: kill writer k of N inside the torn window (ISSUE 6)
# ---------------------------------------------------------------------------

def child_writer_kill(ckpt_dir, n_writers):
    ts = _ts1()
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    mgr = AsyncCheckpointManager(ckpt_dir, writers=n_writers)
    params, opt, _ = _fold(ts, params, opt, 0, 4)
    mgr.save_async(4, {"params": params, "opt_state": opt})
    mgr.wait_until_finished()                 # step 4 is PUBLISHED
    params, opt, _ = _fold(ts, params, opt, 4, 8)

    def hang_last_writer(step, writer):
        # park writer N-1 in the torn window: its shards are on disk, its
        # partial manifest is not — the exact state a host crash leaves
        if writer == n_writers - 1:
            time.sleep(60)

    mgr.writer_fault = hang_last_writer
    mgr.save_async(8, {"params": params, "opt_state": opt})
    time.sleep(1.0)            # healthy writers publish partials; victim hangs
    os._exit(42)               # host dies with the group sub-quorum


def check_writer_kill(ckpt_dir, n_writers):
    env = dict(os.environ)
    r = subprocess.run([sys.executable, os.path.abspath(__file__),
                        "--child-writer-kill", ckpt_dir, str(n_writers)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 42, (r.returncode, r.stdout, r.stderr[-2000:])
    # torn debris: quorum was never met, so the step must still be a .tmp
    # dir with N-1 partial manifests, the victim's shards unmanifested, and
    # no global manifest
    tmp = os.path.join(ckpt_dir, "step_00000008.tmp")
    assert os.path.isdir(tmp), os.listdir(ckpt_dir)
    assert not os.path.exists(os.path.join(tmp, "MANIFEST.json"))
    for w in range(n_writers - 1):
        assert os.path.exists(os.path.join(tmp, f"writer_{w:02d}",
                                           "manifest.json")), (n_writers, w)
    victim = os.path.join(tmp, f"writer_{n_writers - 1:02d}")
    assert not os.path.exists(os.path.join(victim, "manifest.json"))
    assert [f for f in os.listdir(victim) if f.endswith(".npy")], \
        "victim writer should have written shards before hanging"
    assert "step_00000008" not in os.listdir(ckpt_dir)

    # next incarnation: torn step never restorable, debris swept
    mgr = CheckpointManager(ckpt_dir, writers=n_writers)
    assert mgr.all_steps() == [4], mgr.all_steps()
    assert not [n for n in os.listdir(ckpt_dir) if n.endswith(".tmp")]

    ts = _ts1()
    p0 = lm.init_params(CFG, jax.random.PRNGKey(0))
    o0 = adamw.init(p0)
    pa, oa, la = _fold(ts, p0, o0, 0, 8)      # uninterrupted reference
    restored, step = mgr.restore({"params": p0, "opt_state": o0})
    assert step == 4
    pb, ob, lb = _fold(ts, restored["params"], restored["opt_state"], 4, 8)
    assert la[4:] == lb, (la[4:], lb)
    for a, b in zip(jax.tree_util.tree_leaves(pa),
                    jax.tree_util.tree_leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print(f"writer-kill {n_writers - 1} of {n_writers}: torn step never "
          "restorable, debris swept, restore(4) resumed bit-exact")


# ---------------------------------------------------------------------------
# --pipeline-quorum: crash-resume on a 2-pod 1F1B grid, one writer per stage
# ---------------------------------------------------------------------------

def check_pipeline_quorum(tmp_root):
    from repro.launch import mesh as MM
    from repro.parallel import pipeline as PP
    from repro.runtime.fault import FailureInjector, run_supervised
    from repro.train import loop as train_loop

    pcfg = ParallelConfig(strategy="hecaton", data=1, model=2, mx=1, my=2,
                          pods=2, pod_axis_role="pipeline", microbatches=2,
                          grad_reduce_dtype="fp32", remat="none",
                          zero1=False)
    mesh = MM.make_small_mesh("hecaton", 1, 1, 2, pods=2)
    cfg = CFG.scaled(num_layers=2)
    runner, pstep = PP.build_pipeline_train_step(cfg, pcfg, RC, mesh,
                                                 compute_dtype=jnp.float32)
    p0 = lm.init_params(cfg, jax.random.PRNGKey(0))
    TOTAL = 8

    def fresh_state():
        sparams = runner.place_params(p0)
        return {"params": sparams, "opt_state": runner.init_opt(sparams)}

    def batches():
        return iter([{k: jnp.asarray(v) for k, v in DS.batch_at(s).items()}
                     for s in range(TOTAL)])

    # ---- uninterrupted baseline ----------------------------------------
    base = train_loop.train(pstep, fresh_state(), batches(),
                            num_steps=TOTAL, log_every=1,
                            log_fn=lambda *a: None)
    base_hist = list(base["history"])

    # ---- supervised run with an injected writer death at step 4's save --
    # one writer per stage: stage-pinned shards, sync manager so the
    # QuorumError lands at the boundary (the incarnation-killing path)
    ckpt_dir = os.path.join(tmp_root, "pipe_quorum")
    mgr = CheckpointManager(ckpt_dir, writers=2,
                            writer_map=PP.stage_writer_map(2))
    inj = FailureInjector(writer_fail_at={4: 1})
    seen_after_crash = []

    def make_state(_):
        state, start = fresh_state(), 0
        if mgr.latest_step() is not None:
            seen_after_crash.append(list(mgr.all_steps()))
            state, start = mgr.restore(state)
        return state, start

    def run_steps(state, start, inc):
        it = ({k: jnp.asarray(v) for k, v in DS.batch_at(s).items()}
              for s in range(start, TOTAL))
        return train_loop.train(pstep, state, it, start_step=start,
                                num_steps=TOTAL, ckpt=mgr, ckpt_every=2,
                                log_every=1, injector=inj,
                                log_fn=lambda *a: None)

    state, incarnations = run_supervised(make_state, run_steps, ckpt=mgr,
                                         sleep_fn=lambda _: None)
    assert incarnations == 2, incarnations
    assert inj.log == ["step 4: injected writer 1 death"], inj.log
    # the torn step 4 was never visible to the restart
    assert seen_after_crash == [[2]], seen_after_crash
    assert mgr.all_steps() == [4, 6, 8], mgr.all_steps()
    # stage pinning held: every stage-s shard sits with writer s
    import json
    with open(os.path.join(ckpt_dir, "step_00000008",
                           "MANIFEST.json")) as f:
        manifest = json.load(f)["manifest"]
    for name, info in manifest.items():
        assert info["writer"] == int(name.split("/")[1]), (name, info)
    # crash-resume is bit-exact against the uninterrupted baseline
    hist = state["history"]
    tail = {s: l for s, l in hist}
    for s, want in base_hist:
        if s >= 4:                     # steps re-run by incarnation 2
            assert tail[s] == want, (s, tail[s], want)
    print("pipeline-quorum: stage-pinned 2-writer crash-resume bit-exact, "
          f"torn step fenced (saw {seen_after_crash[0]} after crash)")

    # ---- async multi-writer roundtrip of the pipeline state -------------
    amgr = AsyncCheckpointManager(os.path.join(tmp_root, "pipe_async"),
                                  writers=2,
                                  writer_map=PP.stage_writer_map(2))
    amgr.save_async(TOTAL, {"params": state["params"],
                            "opt_state": state["opt_state"]})
    amgr.wait_until_finished()
    rt, _ = amgr.restore({"params": state["params"],
                          "opt_state": state["opt_state"]})
    for a, b in zip(jax.tree_util.tree_leaves(rt),
                    jax.tree_util.tree_leaves({"params": state["params"],
                                               "opt_state":
                                                   state["opt_state"]})):
        np.testing.assert_array_equal(np.asarray(a),
                                      np.asarray(jax.device_get(b)))
    amgr.close()
    print("pipeline-quorum: async 2-writer pipeline snapshot lossless")

    # ---- a corrupted shard fails restore naming the file ----------------
    from repro.checkpoint.manager import CheckpointCorruptionError
    name, info = sorted(manifest.items())[0]
    victim = os.path.join(ckpt_dir, "step_00000008", info["file"])
    blob = bytearray(open(victim, "rb").read())
    blob[-1] ^= 0x40
    with open(victim, "wb") as f:
        f.write(blob)
    try:
        mgr.restore(fresh_state())
    except CheckpointCorruptionError as e:
        assert info["file"] in str(e), (info["file"], str(e))
        print(f"pipeline-quorum: corrupted {info['file']} refused by name")
    else:
        raise AssertionError("corrupted shard restored silently")


def main():
    import tempfile
    root = tempfile.mkdtemp(prefix="ckpt_check_")
    check_kill_mid_write(os.path.join(root, "kill"))
    check_elastic_grids(root)
    for n in (2, 4):
        check_writer_kill(os.path.join(root, f"wkill{n}"), n)
    print("ALL CHECKPOINT CHECKS PASSED")


if __name__ == "__main__":
    if len(sys.argv) > 2 and sys.argv[1] == "--child-kill":
        child_kill(sys.argv[2])
    elif len(sys.argv) > 3 and sys.argv[1] == "--child-writer-kill":
        child_writer_kill(sys.argv[2], int(sys.argv[3]))
    elif len(sys.argv) > 1 and sys.argv[1] == "--pipeline-quorum":
        import tempfile
        check_pipeline_quorum(tempfile.mkdtemp(prefix="ckpt_pq_"))
        print("ALL PIPELINE-QUORUM CHECKS PASSED")
    else:
        main()
