"""Multi-device numerics: run the tests/_mp/ scripts in subprocesses with a
fake 8-device CPU topology (jax locks the device count at first init, so these
cannot share the main pytest process)."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, os.path.join(ROOT, "tests", "_mp",
                                                     script)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_hecaton_ops_numerics():
    out = _run("check_hecaton.py")
    assert "ALL HECATON NUMERICS CHECKS PASSED" in out


def test_model_parallel_numerics():
    out = _run("check_model_parallel.py")
    assert "ALL MODEL-PARALLEL CHECKS PASSED" in out
