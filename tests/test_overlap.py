"""Ring-overlapped collective matmuls (core/overlap.py).

Numerics run in a subprocess on a fake 8-device topology (tests/_mp style);
the HLO assertion uses the extended benchmarks/hlo_compare.py counter to prove
that overlap="ring"/"bidir" replaces every bulk all-gather/reduce-scatter in
the FFN hot path (forward AND backward) with collective-permute chains.
In-process tests cover the pure dispatch/fallback logic and config plumbing.
"""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, ROOT)            # for `benchmarks` imports


def _run(script):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, os.path.join(ROOT, "tests", "_mp",
                                                     script)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_overlap_numerics():
    """ring/bidir/fused fwd+grad == bulk == dense ref on 4x2 / 2x2 / 4x1
    grids, including odd-shard bidir fallback, the fused-loss contraction
    ring, the Pallas ring kernels' interpret path, the overlapped embed_2d
    vocab scatter, AND the megatron residual layouts (seq vs replicated,
    gather-at-entry / scatter-at-exit, 1x8 / 2x4 / 4x2 model rings plus a
    full-model loss+grad) against the dense reference, plus the
    sharded-label fused_lm_loss_seq on 1x8 / 2x4 / 4x2 grids."""
    out = _run("check_overlap.py")
    assert "ALL OVERLAP NUMERICS CHECKS PASSED" in out
    assert "ALL RESIDUAL LAYOUT CHECKS PASSED" in out
    assert "ALL FUSED SEQ LOSS CHECKS PASSED" in out


def test_overlap_hlo_collective_permute_replaces_bulk():
    """Acceptance: with overlap enabled, the compiled hot paths (hecaton FFN
    fwd AND bwd, MoE EP/TP gathers+scatters, megatron column/row FFN) have a
    collective-permute chain and ZERO bulk all-gather/reduce-scatter — while
    the bulk mode has the inverse on the FFN path."""
    from benchmarks import hlo_compare
    out = hlo_compare.run_overlap()
    assert "error" not in out, out.get("error")
    for tag in ("fwd", "fwd_bwd"):
        none_b = out["none"][tag]["bytes"]
        assert none_b.get("all-gather", 0) > 0
        assert none_b.get("reduce-scatter", 0) > 0
        assert none_b.get("collective-permute", 0) == 0
        for mode in ("ring", "bidir", "fused"):
            b = out[mode][tag]["bytes"]
            assert b.get("all-gather", 0) == 0, (mode, tag, b)
            assert b.get("reduce-scatter", 0) == 0, (mode, tag, b)
            assert b.get("collective-permute", 0) > 0, (mode, tag, b)
    # MoE and megatron paths: the bulk mode has AG/RS, the ring modes none
    for path in ("moe", "megatron"):
        for mode in ("ring", "bidir", "fused"):
            b = out[mode][path]["bytes"]
            assert b.get("all-gather", 0) == 0, (mode, path, b)
            assert b.get("reduce-scatter", 0) == 0, (mode, path, b)
            assert b.get("collective-permute", 0) > 0, (mode, path, b)
    assert out["none"]["moe"]["bytes"].get("all-gather", 0) > 0
    # bidir halves per-step messages but doubles the permute count
    n_ring = out["ring"]["fwd"]["count"]["collective-permute"]
    n_bidir = out["bidir"]["fwd"]["count"]["collective-permute"]
    assert n_bidir == 2 * n_ring


def test_seq_residual_hlo_no_block_boundary_gather():
    """Acceptance (ISSUE 3 + ISSUE 4 label satellite): under the seq-sharded
    residual layout with overlap ∈ {ring, bidir, fused}, a full megatron LM
    train step (fwd+bwd) has ZERO bulk collectives — no reduce-scatter and
    ZERO all-gather bytes: since fused_lm_loss_seq rings the head's vocab
    chunks with the labels kept sharded, even the old sub-KB int32 label
    gather is gone — while the replicated layout keeps residual-sized bulk
    gathers in EVERY mode.  Per-die residual-stream bytes shrink by exactly
    1/n_model, and the seq layout never moves more bulk bytes (AG+RS+AR)
    than the replicated one."""
    from benchmarks import hlo_compare
    out = hlo_compare.run_residual()
    assert "error" not in out, out.get("error")
    n = out["n_model"]

    def bulk(row):
        b = row["bytes"]
        return (b.get("all-gather", 0.0) + b.get("reduce-scatter", 0.0)
                + b.get("all-reduce", 0.0))

    for mode in ("ring", "bidir", "fused"):
        b = out["seq"][mode]["bytes"]
        assert b.get("reduce-scatter", 0) == 0, (mode, b)
        # zero label bulk-gather bytes: labels stay sharded through the
        # fused seq loss, so NO all-gather of any size survives
        assert b.get("all-gather", 0) == 0, (mode, b)
        assert b.get("collective-permute", 0) > 0, (mode, b)
        # the replicated layout pays residual-sized bulk gathers in all modes
        rb = out["replicated"][mode]["bytes"]
        assert rb.get("all-gather", 0) > 1e5, (mode, rb)
    for mode in ("none", "ring", "bidir", "fused"):
        assert bulk(out["seq"][mode]) <= bulk(out["replicated"][mode]), mode
        # per-die activation bytes for the layer scan shrink by 1/n_model
        assert (out["seq"][mode]["residual_bytes_per_die"] * n
                == out["replicated"][mode]["residual_bytes_per_die"])


# ---------------------------------------------------------------------------
# In-process: dispatch/fallback logic + config plumbing (no multi-device mesh)
# ---------------------------------------------------------------------------


def test_mode_fallback_logic():
    from repro.core.overlap import MODES, check_mode, rs_ok

    assert MODES == ("none", "ring", "bidir", "fused")
    for m in MODES:
        assert check_mode(m) == m
    with pytest.raises(ValueError):
        check_mode("diagonal")               # a typo must not mean "ring"
    assert rs_ok(12, 4)                      # chunks evenly: ring RS
    assert not rs_ok(10, 4)                  # cannot chunk: bulk collective
    assert not rs_ok(12, 1)                  # degenerate axis: bulk no-op


def test_hecaton_ops_reject_bad_overlap():
    import jax.numpy as jnp
    from repro.core import hecaton as H

    x = jnp.ones((2, 4, 8), jnp.float32)
    w = jnp.ones((8, 6), jnp.float32)
    with pytest.raises(ValueError):
        H.linear_seq_scatter(x, w, mesh=None, t_ax="mx", h_ax="my",
                             overlap="sprial")


def test_fuse_side_picks_heavier_collective():
    from repro.core.overlap import fuse_side

    assert fuse_side(h_loc=64, o_loc=256) == "rs"    # output heavier: fuse RS
    assert fuse_side(h_loc=256, o_loc=64) == "ag"    # input heavier: fuse AG
    assert fuse_side(h_loc=64, o_loc=64) == "ag"     # tie: circulate input


def test_shift_perm_is_a_ring():
    from repro.core.overlap import _shift_perm

    assert _shift_perm(4, 1) == [(0, 1), (1, 2), (2, 3), (3, 0)]
    assert _shift_perm(4, -1) == [(0, 3), (1, 0), (2, 1), (3, 2)]
    srcs, dsts = zip(*_shift_perm(8, 1))
    assert sorted(srcs) == sorted(dsts) == list(range(8))


def test_parallel_config_overlap_validation():
    from repro.config import ParallelConfig

    assert ParallelConfig().overlap == "none"
    assert ParallelConfig(overlap="ring").overlap == "ring"
    pc = ParallelConfig(overlap="ring").with_(overlap="bidir")
    assert pc.overlap == "bidir"
    with pytest.raises(AssertionError):
        ParallelConfig(overlap="spiral")


def test_pctx_plumbs_overlap():
    from repro.config import ParallelConfig
    from repro.parallel.context import PCtx

    pctx = PCtx(mesh=None, pcfg=ParallelConfig(overlap="ring"))
    assert pctx.overlap == "ring"


def test_residual_layout_config_plumbing():
    from repro.config import ParallelConfig
    from repro.parallel.context import PCtx

    assert ParallelConfig().residual == "seq"        # seq is the canonical
    assert ParallelConfig(residual="replicated").residual == "replicated"
    with pytest.raises(AssertionError):
        ParallelConfig(residual="diagonal")
    # decode forces the replicated residual (S=1 cannot token-scatter)
    pcfg = ParallelConfig(residual="seq")
    assert PCtx(mesh=None, pcfg=pcfg, mode="train").residual == "seq"
    assert PCtx(mesh=None, pcfg=pcfg, mode="decode").residual == "replicated"


def test_seq_shardable_gate():
    from repro.parallel import sharding as shd

    ax = shd.AxisInfo(("data",), None, None, ("model",),
                      {"data": 2, "model": 4})
    assert shd.seq_shardable(ax, 16)
    assert not shd.seq_shardable(ax, 15)     # does not divide the ring
    assert not shd.seq_shardable(ax, 1)      # decode
    hec = shd.AxisInfo(("data",), "mx", "my", ("mx", "my"),
                       {"data": 2, "mx": 2, "my": 2})
    assert not shd.seq_shardable(hec, 16)    # hecaton: own tiling handles it
    from jax.sharding import PartitionSpec as P
    assert shd.act_canonical(ax, "seq") == P("data", "model", None)
    assert shd.act_canonical(ax, "replicated") == P("data", None, None)
    assert shd.act_canonical(hec, "seq") == shd.act_canonical(hec, "replicated")
    with pytest.raises(ValueError):
        shd.act_canonical(ax, "spiral")


def test_shard_local_norm_and_dropout_entry_points():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.config import ParallelConfig
    from repro.models import layers as L
    from repro.parallel.context import PCtx

    pctx = PCtx(mesh=None, pcfg=ParallelConfig(data=1, model=1, mx=1, my=1))
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16), jnp.float32)
    p = L.init_norm("rmsnorm", 16)
    np.testing.assert_allclose(np.asarray(pctx.norm("rmsnorm", p, x)),
                               np.asarray(L.apply_norm("rmsnorm", p, x)))
    # rate 0 / missing rng are deterministic no-ops
    assert pctx.dropout(x, 0.0, jax.random.PRNGKey(1)) is x
    assert pctx.dropout(x, 0.5, None) is x
    y = pctx.dropout(x, 0.5, jax.random.PRNGKey(1))
    kept = np.asarray(y) != 0
    np.testing.assert_allclose(np.asarray(y)[kept],
                               (np.asarray(x) / 0.5)[kept], rtol=1e-6)
    assert 0.2 < kept.mean() < 0.8           # ~half the entries survive


def test_embed_dropout_microbatched_train_step():
    """embed_dropout end to end: the train step splits dropout_rng into one
    key per microbatch (distinct masks) and the loss stays finite."""
    import jax
    import jax.numpy as jnp
    from repro.config import ModelConfig, ParallelConfig, RunConfig
    from repro.train import step as TS

    cfg = ModelConfig(name="do-test", family="dense", num_layers=1,
                      d_model=16, num_heads=2, num_kv_heads=2, d_ff=32,
                      vocab_size=32, mlp_kind="gelu", embed_dropout=0.25)
    rc = RunConfig("t", "train", 8, 4, lr=1e-3)
    pcfg = ParallelConfig(data=1, model=1, mx=1, my=1, microbatches=2,
                          zero1=False)
    params, opt = TS.init_train_state(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 32)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1),
             "dropout_rng": jax.random.PRNGKey(2)}
    ts = TS.build_train_step(cfg, pcfg, rc, None, compute_dtype=jnp.float32)
    _, _, m = jax.jit(ts)(params, opt, batch)
    assert jnp.isfinite(m["loss"])
    mbs = TS.microbatch_split(batch, 2)
    assert mbs["dropout_rng"].shape == (2, 2)        # one key per microbatch
    assert not bool((mbs["dropout_rng"][0] == mbs["dropout_rng"][1]).all())
    # spec builders treat the rng as replicated, never sharded
    from jax.sharding import PartitionSpec as P
    from repro.parallel import specs as SP
    assert SP.batch_specs(None, pcfg, microbatched=True,
                          keys=("tokens", "dropout_rng")) is not None


def test_mixer_in_many_matches_per_weight():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.config import ParallelConfig
    from repro.parallel.context import PCtx

    pctx = PCtx(mesh=None, pcfg=ParallelConfig(data=1, model=1, mx=1, my=1))
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16), jnp.float32)
    ws = [jax.random.normal(jax.random.PRNGKey(i), (16, 24), jnp.float32)
          for i in (1, 2, 3)]
    outs = pctx.mixer_in_many(x, *ws)
    assert len(outs) == 3
    for got, w in zip(outs, ws):
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(pctx.mixer_in(x, w)), rtol=1e-6)


def test_fit_overlap_eff():
    from benchmarks.comm_model import OVERLAP_EFF, fit_overlap_eff

    # synthetic: compute 70us, comm 30us, ring hides 2/3, fused hides all
    times = {"none": {"ffn_us": 100.0, "linear_us": 200.0},
             "ring": {"ffn_us": 80.0, "linear_us": 160.0},
             "fused": {"ffn_us": 70.0, "linear_us": 140.0}}
    fit = fit_overlap_eff(times)
    assert fit is not None
    assert fit["eff"]["none"] == 0.0
    # exact recovery requires the true rho=0.3 to be on the search grid;
    # the prior pulls toward it since eff_fused(0.3)=1.0 ≈ prior 0.95
    assert 0.5 < fit["eff"]["ring"] < 0.9
    assert fit["eff"]["fused"] > 0.85
    assert fit["eff"]["ring"] < fit["eff"]["fused"]
    assert 0.0 < fit["comm_fraction"] < 1.0
    # CPU-style regression (ring modes slower than bulk) clips to 0
    slow = {"none": {"ffn_us": 100.0}, "ring": {"ffn_us": 150.0}}
    fit2 = fit_overlap_eff(slow)
    assert fit2["eff"]["ring"] == 0.0 and "ring" in fit2["clipped"]
    # garbage in → None, not a crash
    assert fit_overlap_eff(None) is None
    assert fit_overlap_eff({"ring": {"ffn_us": 1.0}}) is None
    assert set(OVERLAP_EFF) == {"none", "ring", "bidir", "fused"}


def test_mesh_none_paths_ignore_overlap():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import hecaton as H

    x = jnp.ones((2, 4, 8), jnp.float32)
    w = jnp.ones((8, 6), jnp.float32)
    for ov in ("none", "ring", "bidir"):
        y = H.linear_seq_scatter(x, w, mesh=None, t_ax="mx", h_ax="my",
                                 overlap=ov)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                   rtol=1e-6)
    ffn = H.ffn_block(x, w, jnp.ones((6, 8), jnp.float32), mesh=None,
                      act_fn=jax.nn.silu, t_ax="mx", h_ax="my", overlap="ring")
    assert ffn.shape == (2, 4, 8)
