"""Ring-overlapped collective matmuls (core/overlap.py).

Numerics run in a subprocess on a fake 8-device topology (tests/_mp style);
the HLO assertion uses the extended benchmarks/hlo_compare.py counter to prove
that overlap="ring"/"bidir" replaces every bulk all-gather/reduce-scatter in
the FFN hot path (forward AND backward) with collective-permute chains.
In-process tests cover the pure dispatch/fallback logic and config plumbing.
"""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, ROOT)            # for `benchmarks` imports


def _run(script):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, os.path.join(ROOT, "tests", "_mp",
                                                     script)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_overlap_numerics():
    """ring/bidir/fused fwd+grad == bulk == dense ref on 4x2 / 2x2 / 4x1
    grids, including odd-shard bidir fallback, the fused-loss contraction
    ring, and the Pallas ring kernels' interpret path."""
    out = _run("check_overlap.py")
    assert "ALL OVERLAP NUMERICS CHECKS PASSED" in out


def test_overlap_hlo_collective_permute_replaces_bulk():
    """Acceptance: with overlap enabled, the compiled hot paths (hecaton FFN
    fwd AND bwd, MoE EP/TP gathers+scatters, megatron column/row FFN) have a
    collective-permute chain and ZERO bulk all-gather/reduce-scatter — while
    the bulk mode has the inverse on the FFN path."""
    from benchmarks import hlo_compare
    out = hlo_compare.run_overlap()
    assert "error" not in out, out.get("error")
    for tag in ("fwd", "fwd_bwd"):
        none_b = out["none"][tag]["bytes"]
        assert none_b.get("all-gather", 0) > 0
        assert none_b.get("reduce-scatter", 0) > 0
        assert none_b.get("collective-permute", 0) == 0
        for mode in ("ring", "bidir", "fused"):
            b = out[mode][tag]["bytes"]
            assert b.get("all-gather", 0) == 0, (mode, tag, b)
            assert b.get("reduce-scatter", 0) == 0, (mode, tag, b)
            assert b.get("collective-permute", 0) > 0, (mode, tag, b)
    # MoE and megatron paths: the bulk mode has AG/RS, the ring modes none
    for path in ("moe", "megatron"):
        for mode in ("ring", "bidir", "fused"):
            b = out[mode][path]["bytes"]
            assert b.get("all-gather", 0) == 0, (mode, path, b)
            assert b.get("reduce-scatter", 0) == 0, (mode, path, b)
            assert b.get("collective-permute", 0) > 0, (mode, path, b)
    assert out["none"]["moe"]["bytes"].get("all-gather", 0) > 0
    # bidir halves per-step messages but doubles the permute count
    n_ring = out["ring"]["fwd"]["count"]["collective-permute"]
    n_bidir = out["bidir"]["fwd"]["count"]["collective-permute"]
    assert n_bidir == 2 * n_ring


# ---------------------------------------------------------------------------
# In-process: dispatch/fallback logic + config plumbing (no multi-device mesh)
# ---------------------------------------------------------------------------


def test_mode_fallback_logic():
    from repro.core.overlap import MODES, check_mode, rs_ok

    assert MODES == ("none", "ring", "bidir", "fused")
    for m in MODES:
        assert check_mode(m) == m
    with pytest.raises(ValueError):
        check_mode("diagonal")               # a typo must not mean "ring"
    assert rs_ok(12, 4)                      # chunks evenly: ring RS
    assert not rs_ok(10, 4)                  # cannot chunk: bulk collective
    assert not rs_ok(12, 1)                  # degenerate axis: bulk no-op


def test_hecaton_ops_reject_bad_overlap():
    import jax.numpy as jnp
    from repro.core import hecaton as H

    x = jnp.ones((2, 4, 8), jnp.float32)
    w = jnp.ones((8, 6), jnp.float32)
    with pytest.raises(ValueError):
        H.linear_seq_scatter(x, w, mesh=None, t_ax="mx", h_ax="my",
                             overlap="sprial")


def test_fuse_side_picks_heavier_collective():
    from repro.core.overlap import fuse_side

    assert fuse_side(h_loc=64, o_loc=256) == "rs"    # output heavier: fuse RS
    assert fuse_side(h_loc=256, o_loc=64) == "ag"    # input heavier: fuse AG
    assert fuse_side(h_loc=64, o_loc=64) == "ag"     # tie: circulate input


def test_shift_perm_is_a_ring():
    from repro.core.overlap import _shift_perm

    assert _shift_perm(4, 1) == [(0, 1), (1, 2), (2, 3), (3, 0)]
    assert _shift_perm(4, -1) == [(0, 3), (1, 0), (2, 1), (3, 2)]
    srcs, dsts = zip(*_shift_perm(8, 1))
    assert sorted(srcs) == sorted(dsts) == list(range(8))


def test_parallel_config_overlap_validation():
    from repro.config import ParallelConfig

    assert ParallelConfig().overlap == "none"
    assert ParallelConfig(overlap="ring").overlap == "ring"
    pc = ParallelConfig(overlap="ring").with_(overlap="bidir")
    assert pc.overlap == "bidir"
    with pytest.raises(AssertionError):
        ParallelConfig(overlap="spiral")


def test_pctx_plumbs_overlap():
    from repro.config import ParallelConfig
    from repro.parallel.context import PCtx

    pctx = PCtx(mesh=None, pcfg=ParallelConfig(overlap="ring"))
    assert pctx.overlap == "ring"


def test_mesh_none_paths_ignore_overlap():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import hecaton as H

    x = jnp.ones((2, 4, 8), jnp.float32)
    w = jnp.ones((8, 6), jnp.float32)
    for ov in ("none", "ring", "bidir"):
        y = H.linear_seq_scatter(x, w, mesh=None, t_ax="mx", h_ax="my",
                                 overlap=ov)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                   rtol=1e-6)
    ffn = H.ffn_block(x, w, jnp.ones((6, 8), jnp.float32), mesh=None,
                      act_fn=jax.nn.silu, t_ax="mx", h_ax="my", overlap="ring")
    assert ffn.shape == (2, 4, 8)
