"""Ring-overlapped collective matmuls (core/overlap.py).

Numerics run in a subprocess on a fake 8-device topology (tests/_mp style);
the HLO assertion uses the extended benchmarks/hlo_compare.py counter to prove
that overlap="ring"/"bidir" replaces every bulk all-gather/reduce-scatter in
the FFN hot path (forward AND backward) with collective-permute chains.
In-process tests cover the pure dispatch/fallback logic and config plumbing.
"""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, ROOT)            # for `benchmarks` imports


def _run(script, *args):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, os.path.join(ROOT, "tests", "_mp",
                                                     script), *args],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_overlap_numerics():
    """ring/bidir/fused fwd+grad == bulk == dense ref on 4x2 / 2x2 / 4x1
    grids, including odd-shard bidir fallback, the fused-loss contraction
    ring, the Pallas ring kernels' interpret path, the overlapped embed_2d
    vocab scatter, AND the megatron residual layouts (seq vs replicated,
    gather-at-entry / scatter-at-exit, 1x8 / 2x4 / 4x2 model rings plus a
    full-model loss+grad) against the dense reference, plus the
    sharded-label fused_lm_loss_seq on 1x8 / 2x4 / 4x2 grids."""
    out = _run("check_overlap.py")
    assert "ALL OVERLAP NUMERICS CHECKS PASSED" in out
    assert "ALL RESIDUAL LAYOUT CHECKS PASSED" in out
    assert "ALL FUSED SEQ LOSS CHECKS PASSED" in out


def test_overlap_hlo_collective_permute_replaces_bulk():
    """Acceptance: with overlap enabled, the compiled hot paths (hecaton FFN
    fwd AND bwd, MoE EP/TP gathers+scatters, megatron column/row FFN) have a
    collective-permute chain and ZERO bulk all-gather/reduce-scatter — while
    the bulk mode has the inverse on the FFN path."""
    from benchmarks import hlo_compare
    out = hlo_compare.run_overlap()
    assert "error" not in out, out.get("error")
    for tag in ("fwd", "fwd_bwd"):
        none_b = out["none"][tag]["bytes"]
        assert none_b.get("all-gather", 0) > 0
        assert none_b.get("reduce-scatter", 0) > 0
        assert none_b.get("collective-permute", 0) == 0
        for mode in ("ring", "bidir", "fused"):
            b = out[mode][tag]["bytes"]
            assert b.get("all-gather", 0) == 0, (mode, tag, b)
            assert b.get("reduce-scatter", 0) == 0, (mode, tag, b)
            assert b.get("collective-permute", 0) > 0, (mode, tag, b)
    # MoE and megatron paths: the bulk mode has AG/RS, the ring modes none
    for path in ("moe", "megatron"):
        for mode in ("ring", "bidir", "fused"):
            b = out[mode][path]["bytes"]
            assert b.get("all-gather", 0) == 0, (mode, path, b)
            assert b.get("reduce-scatter", 0) == 0, (mode, path, b)
            assert b.get("collective-permute", 0) > 0, (mode, path, b)
    assert out["none"]["moe"]["bytes"].get("all-gather", 0) > 0
    # bidir halves per-step messages but doubles the permute count
    n_ring = out["ring"]["fwd"]["count"]["collective-permute"]
    n_bidir = out["bidir"]["fwd"]["count"]["collective-permute"]
    assert n_bidir == 2 * n_ring


def test_seq_residual_hlo_no_block_boundary_gather():
    """Acceptance (ISSUE 3 + ISSUE 4 label satellite): under the seq-sharded
    residual layout with overlap ∈ {ring, bidir, fused}, a full megatron LM
    train step (fwd+bwd) has ZERO bulk collectives — no reduce-scatter and
    ZERO all-gather bytes: since fused_lm_loss_seq rings the head's vocab
    chunks with the labels kept sharded, even the old sub-KB int32 label
    gather is gone — while the replicated layout keeps residual-sized bulk
    gathers in EVERY mode.  Per-die residual-stream bytes shrink by exactly
    1/n_model, and the seq layout never moves more bulk bytes (AG+RS+AR)
    than the replicated one."""
    from benchmarks import hlo_compare
    out = hlo_compare.run_residual()
    assert "error" not in out, out.get("error")
    n = out["n_model"]

    def bulk(row):
        b = row["bytes"]
        return (b.get("all-gather", 0.0) + b.get("reduce-scatter", 0.0)
                + b.get("all-reduce", 0.0))

    for mode in ("ring", "bidir", "fused"):
        b = out["seq"][mode]["bytes"]
        assert b.get("reduce-scatter", 0) == 0, (mode, b)
        # zero label bulk-gather bytes: labels stay sharded through the
        # fused seq loss, so NO all-gather of any size survives
        assert b.get("all-gather", 0) == 0, (mode, b)
        assert b.get("collective-permute", 0) > 0, (mode, b)
        # the replicated layout pays residual-sized bulk gathers in all modes
        rb = out["replicated"][mode]["bytes"]
        assert rb.get("all-gather", 0) > 1e5, (mode, rb)
    for mode in ("none", "ring", "bidir", "fused"):
        assert bulk(out["seq"][mode]) <= bulk(out["replicated"][mode]), mode
        # per-die activation bytes for the layer scan shrink by 1/n_model
        assert (out["seq"][mode]["residual_bytes_per_die"] * n
                == out["replicated"][mode]["residual_bytes_per_die"])


# ---------------------------------------------------------------------------
# In-process: dispatch/fallback logic + config plumbing (no multi-device mesh)
# ---------------------------------------------------------------------------


def test_mode_fallback_logic():
    from repro.core.overlap import MODES, check_mode, rs_ok

    assert MODES == ("none", "ring", "bidir", "fused")
    for m in MODES:
        assert check_mode(m) == m
    with pytest.raises(ValueError):
        check_mode("diagonal")               # a typo must not mean "ring"
    assert rs_ok(12, 4)                      # chunks evenly: ring RS
    assert not rs_ok(10, 4)                  # cannot chunk: bulk collective
    assert not rs_ok(12, 1)                  # degenerate axis: bulk no-op


def test_hecaton_ops_reject_bad_overlap():
    import jax.numpy as jnp
    from repro.core import hecaton as H

    x = jnp.ones((2, 4, 8), jnp.float32)
    w = jnp.ones((8, 6), jnp.float32)
    with pytest.raises(ValueError):
        H.linear_seq_scatter(x, w, mesh=None, t_ax="mx", h_ax="my",
                             overlap="sprial")


def test_fuse_side_picks_heavier_collective():
    from repro.core.overlap import fuse_side

    assert fuse_side(h_loc=64, o_loc=256) == "rs"    # output heavier: fuse RS
    assert fuse_side(h_loc=256, o_loc=64) == "ag"    # input heavier: fuse AG
    assert fuse_side(h_loc=64, o_loc=64) == "ag"     # tie: circulate input


def test_shift_perm_is_a_ring():
    from repro.core.overlap import _shift_perm

    assert _shift_perm(4, 1) == [(0, 1), (1, 2), (2, 3), (3, 0)]
    assert _shift_perm(4, -1) == [(0, 3), (1, 0), (2, 1), (3, 2)]
    srcs, dsts = zip(*_shift_perm(8, 1))
    assert sorted(srcs) == sorted(dsts) == list(range(8))


def test_parallel_config_overlap_validation():
    from repro.config import ParallelConfig

    assert ParallelConfig().overlap == "none"
    assert ParallelConfig(overlap="ring").overlap == "ring"
    pc = ParallelConfig(overlap="ring").with_(overlap="bidir")
    assert pc.overlap == "bidir"
    with pytest.raises(AssertionError):
        ParallelConfig(overlap="spiral")


def test_pctx_plumbs_overlap():
    from repro.config import ParallelConfig
    from repro.parallel.context import PCtx

    pctx = PCtx(mesh=None, pcfg=ParallelConfig(overlap="ring"))
    assert pctx.overlap == "ring"


def test_residual_layout_config_plumbing():
    from repro.config import ParallelConfig
    from repro.parallel.context import PCtx

    assert ParallelConfig().residual == "seq"        # seq is the canonical
    assert ParallelConfig(residual="replicated").residual == "replicated"
    with pytest.raises(AssertionError):
        ParallelConfig(residual="diagonal")
    # decode forces the replicated residual (S=1 cannot token-scatter)
    pcfg = ParallelConfig(residual="seq")
    assert PCtx(mesh=None, pcfg=pcfg, mode="train").residual == "seq"
    assert PCtx(mesh=None, pcfg=pcfg, mode="decode").residual == "replicated"


def test_seq_shardable_gate():
    from repro.parallel import sharding as shd

    ax = shd.AxisInfo(("data",), None, None, ("model",),
                      {"data": 2, "model": 4})
    assert shd.seq_shardable(ax, 16)
    assert not shd.seq_shardable(ax, 15)     # does not divide the ring
    assert not shd.seq_shardable(ax, 1)      # decode
    hec = shd.AxisInfo(("data",), "mx", "my", ("mx", "my"),
                       {"data": 2, "mx": 2, "my": 2})
    assert not shd.seq_shardable(hec, 16)    # hecaton: own tiling handles it
    from jax.sharding import PartitionSpec as P
    assert shd.act_canonical(ax, "seq") == P("data", "model", None)
    assert shd.act_canonical(ax, "replicated") == P("data", None, None)
    assert shd.act_canonical(hec, "seq") == shd.act_canonical(hec, "replicated")
    with pytest.raises(ValueError):
        shd.act_canonical(ax, "spiral")


def test_shard_local_norm_and_dropout_entry_points():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.config import ParallelConfig
    from repro.models import layers as L
    from repro.parallel.context import PCtx

    pctx = PCtx(mesh=None, pcfg=ParallelConfig(data=1, model=1, mx=1, my=1))
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16), jnp.float32)
    p = L.init_norm("rmsnorm", 16)
    np.testing.assert_allclose(np.asarray(pctx.norm("rmsnorm", p, x)),
                               np.asarray(L.apply_norm("rmsnorm", p, x)))
    # rate 0 / missing rng are deterministic no-ops
    assert pctx.dropout(x, 0.0, jax.random.PRNGKey(1)) is x
    assert pctx.dropout(x, 0.5, None) is x
    y = pctx.dropout(x, 0.5, jax.random.PRNGKey(1))
    kept = np.asarray(y) != 0
    np.testing.assert_allclose(np.asarray(y)[kept],
                               (np.asarray(x) / 0.5)[kept], rtol=1e-6)
    assert 0.2 < kept.mean() < 0.8           # ~half the entries survive


def test_embed_dropout_microbatched_train_step():
    """embed_dropout end to end: the train step splits dropout_rng into one
    key per microbatch (distinct masks) and the loss stays finite."""
    import jax
    import jax.numpy as jnp
    from repro.config import ModelConfig, ParallelConfig, RunConfig
    from repro.train import step as TS

    cfg = ModelConfig(name="do-test", family="dense", num_layers=1,
                      d_model=16, num_heads=2, num_kv_heads=2, d_ff=32,
                      vocab_size=32, mlp_kind="gelu", embed_dropout=0.25)
    rc = RunConfig("t", "train", 8, 4, lr=1e-3)
    pcfg = ParallelConfig(data=1, model=1, mx=1, my=1, microbatches=2,
                          zero1=False)
    params, opt = TS.init_train_state(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 32)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1),
             "dropout_rng": jax.random.PRNGKey(2)}
    ts = TS.build_train_step(cfg, pcfg, rc, None, compute_dtype=jnp.float32)
    _, _, m = jax.jit(ts)(params, opt, batch)
    assert jnp.isfinite(m["loss"])
    mbs = TS.microbatch_split(batch, 2)
    assert mbs["dropout_rng"].shape == (2, 2)        # one key per microbatch
    assert not bool((mbs["dropout_rng"][0] == mbs["dropout_rng"][1]).all())
    # spec builders treat the rng as replicated, never sharded
    from jax.sharding import PartitionSpec as P
    from repro.parallel import specs as SP
    assert SP.batch_specs(None, pcfg, microbatched=True,
                          keys=("tokens", "dropout_rng")) is not None


def test_mixer_in_many_matches_per_weight():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.config import ParallelConfig
    from repro.parallel.context import PCtx

    pctx = PCtx(mesh=None, pcfg=ParallelConfig(data=1, model=1, mx=1, my=1))
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16), jnp.float32)
    ws = [jax.random.normal(jax.random.PRNGKey(i), (16, 24), jnp.float32)
          for i in (1, 2, 3)]
    outs = pctx.mixer_in_many(x, *ws)
    assert len(outs) == 3
    for got, w in zip(outs, ws):
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(pctx.mixer_in(x, w)), rtol=1e-6)


def test_fit_overlap_eff():
    from benchmarks.comm_model import OVERLAP_EFF, fit_overlap_eff

    # synthetic: compute 70us, comm 30us, ring hides 2/3, fused hides all
    times = {"none": {"ffn_us": 100.0, "linear_us": 200.0},
             "ring": {"ffn_us": 80.0, "linear_us": 160.0},
             "fused": {"ffn_us": 70.0, "linear_us": 140.0}}
    fit = fit_overlap_eff(times)
    assert fit is not None
    assert fit["eff"]["none"] == 0.0
    # exact recovery requires the true rho=0.3 to be on the search grid;
    # the prior pulls toward it since eff_fused(0.3)=1.0 ≈ prior 0.95
    assert 0.5 < fit["eff"]["ring"] < 0.9
    assert fit["eff"]["fused"] > 0.85
    assert fit["eff"]["ring"] < fit["eff"]["fused"]
    assert 0.0 < fit["comm_fraction"] < 1.0
    # CPU-style regression (ring modes slower than bulk) clips to 0
    slow = {"none": {"ffn_us": 100.0}, "ring": {"ffn_us": 150.0}}
    fit2 = fit_overlap_eff(slow)
    assert fit2["eff"]["ring"] == 0.0 and "ring" in fit2["clipped"]
    # garbage in → None, not a crash
    assert fit_overlap_eff(None) is None
    assert fit_overlap_eff({"ring": {"ffn_us": 1.0}}) is None
    assert set(OVERLAP_EFF) == {"none", "ring", "bidir", "fused"}


def test_mesh_none_paths_ignore_overlap():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import hecaton as H

    x = jnp.ones((2, 4, 8), jnp.float32)
    w = jnp.ones((8, 6), jnp.float32)
    for ov in ("none", "ring", "bidir"):
        y = H.linear_seq_scatter(x, w, mesh=None, t_ax="mx", h_ax="my",
                                 overlap=ov)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                   rtol=1e-6)
    ffn = H.ffn_block(x, w, jnp.ones((6, 8), jnp.float32), mesh=None,
                      act_fn=jax.nn.silu, t_ax="mx", h_ax="my", overlap="ring")
    assert ffn.shape == (2, 4, 8)


# ---------------------------------------------------------------------------
# Int8-quantized ring collectives (core/quant.py, docs/DESIGN.md §11)
# ---------------------------------------------------------------------------


def test_quant_parity_gate():
    """Loss-parity gate: 2 optimizer steps of the 2-layer LM on 1x8 and 2x4
    megatron grids, ring/bidir/fused — the int8-comm loss curve tracks the
    bf16-comm curve within rtol and the grads within the documented looser
    relative-L2 bound (tests/_mp/check_overlap.py --quant-parity)."""
    out = _run("check_overlap.py", "--quant-parity")
    assert "ALL QUANT PARITY CHECKS PASSED" in out


def test_quant_hlo_byte_cut():
    """Acceptance: int8 rings move ≤ 0.55x the collective-permute bytes of
    the bf16 wire on the 2-layer megatron LM train step (fwd+bwd), on every
    overlap mode — and the bulk AG/RS total stays zero for BOTH wire dtypes
    (the wire dtype must never re-bulk a ring)."""
    from benchmarks import hlo_compare
    out = hlo_compare.run_quant()
    assert "error" not in out, out.get("error")
    for mode in ("ring", "bidir", "fused"):
        row = out[mode]
        cp = {cd: row[cd]["bytes"].get("collective-permute", 0.0)
              for cd in ("bf16", "int8")}
        assert cp["bf16"] > 0, (mode, row)
        assert cp["int8"] <= 0.55 * cp["bf16"], (mode, cp)
        for cd in ("bf16", "int8"):
            b = row[cd]["bytes"]
            assert b.get("all-gather", 0) == 0, (mode, cd, b)
            assert b.get("reduce-scatter", 0) == 0, (mode, cd, b)
        # the scales ride as extra (small) permutes: more ops, fewer bytes
        assert (row["int8"]["count"]["collective-permute"]
                > row["bf16"]["count"]["collective-permute"]), mode


def test_comm_dtype_config_plumbing():
    from repro.config import ParallelConfig
    from repro.core import quant as Q
    from repro.core.overlap import COMM_DTYPES, check_comm_dtype
    from repro.parallel.context import PCtx

    assert COMM_DTYPES == ("bf16", "int8")
    assert ParallelConfig().comm_dtype == "bf16"     # default: today's wire
    assert ParallelConfig(comm_dtype="int8").comm_dtype == "int8"
    with pytest.raises(AssertionError):
        ParallelConfig(comm_dtype="int4")            # typo must not mean bf16
    with pytest.raises(ValueError):
        check_comm_dtype("fp8")
    pctx = PCtx(mesh=None, pcfg=ParallelConfig(comm_dtype="int8"))
    assert pctx.comm_dtype == "int8"
    # per-hop degradation gate: integer payloads and tiny trailing extents
    # stay full width; everything else quantizes
    import jax.numpy as jnp
    assert Q.quant_ok((4, 64), jnp.float32)
    assert Q.quant_ok((4, Q.MIN_QUANT_DIM), jnp.bfloat16)
    assert not Q.quant_ok((4, Q.MIN_QUANT_DIM - 1), jnp.float32)
    assert not Q.quant_ok((4, 64), jnp.int32)        # embedding ids
    assert not Q.quant_ok((), jnp.float32)


def test_quant_single_device_smoke():
    """Tier-1 single-device smoke: hecaton ops accept comm_dtype on the
    mesh=None path (no rings → bit-exact), and a 1-device mesh runs the
    int8 ring end to end (the self-hop is a quantize/dequantize roundtrip,
    bounded by scale/2 per element)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import hecaton as H
    from repro.core import quant as Q

    x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 32), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16), jnp.float32)
    y = H.linear_seq_scatter(x, w, mesh=None, t_ax="mx", h_ax="my",
                             overlap="ring", comm_dtype="int8")
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-6)
    q, s = Q.quant_int8(x)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    assert s.shape == x.shape[:-1] + (1,)
    err = np.abs(np.asarray(Q.dequant_int8(q, s, x.dtype) - x))
    assert (err <= np.asarray(s) / 2 * (1 + 1e-6) + 1e-7).all()


def test_comm_model_wire_dtype_rows():
    """Regression (satellite bugfix): the theory rows' bytes-per-element now
    flows from the comm dtype — comm_bytes_per_elt is the single source, the
    SRAM minimal-unit check uses the ladder's element width instead of the
    hardcoded fp32 (=4), and the int8 wire shows up as a NoP-only cut."""
    from benchmarks.comm_model import (comm_bytes_per_elt, fit_overlap_eff,
                                       overlap_rows, run)

    assert comm_bytes_per_elt("bf16", 4096) == 2.0
    assert comm_bytes_per_elt("int8", 4096) == pytest.approx(1 + 4 / 4096)
    # below MIN_QUANT_DIM the hop degrades to full width
    assert comm_bytes_per_elt("int8", 8) == 2.0
    with pytest.raises(ValueError):
        comm_bytes_per_elt("fp8", 4096)
    big_b = {r["mode"]: r for r in overlap_rows()
             if r["workload"] == "llama3.1-405b"}
    big_i = {r["mode"]: r for r in overlap_rows(comm_dtype="int8")
             if r["workload"] == "llama3.1-405b"}
    # pinned: the corrected rows (bulk bf16 ≡ 1.0 by normalization; int8
    # halves the exposed-NoP share of the bulk critical path)
    assert big_b["none"]["latency_norm"] == pytest.approx(1.0)
    assert big_i["none"]["latency_norm"] == pytest.approx(0.772, rel=0.02)
    for m in ("none", "ring", "bidir", "fused"):
        assert big_i[m]["latency"] <= big_b[m]["latency"], m
        assert big_i[m]["wire_bytes_per_elt"] < big_b[m]["wire_bytes_per_elt"]
    # the SRAM check is consistent with the ladder's own element width: the
    # paper's verdict rows (flat/torus overflow, optimus+hecaton fit) hold
    verdict = {(r["package"], r["method"]): r["sram_ok"] for r in run()
               if r["workload"] == "llama3.1-405b"}
    assert verdict[("standard", "hecaton")] and verdict[("standard", "optimus")]
    assert not verdict[("standard", "flat_ring")]
    # calibrated fit: attributing a byte cut to the wire lowers the comm term
    # the efficiencies have to explain — the wire kwarg must change the fit
    times = {"none": {"ffn_us": 100.0}, "ring": {"ffn_us": 80.0}}
    assert (fit_overlap_eff(times, wire={"ring": 0.5})["eff"]["ring"]
            != fit_overlap_eff(times)["eff"]["ring"])
