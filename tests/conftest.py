import os
import sys

# Tests run single-device (the dry-run sets its own 512-device XLA_FLAGS in a
# separate process; multi-device numerics tests spawn subprocesses).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
