"""Docs layer (ISSUE 5 satellites): README/DESIGN exist, zero dangling
intra-repo links or DESIGN.md § citations, and the checker itself catches
rot (so the CI step is not a tautology)."""

import os
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "tools"))

import check_links  # noqa: E402


def test_readme_and_design_exist():
    assert os.path.exists(os.path.join(ROOT, "README.md"))
    assert os.path.exists(os.path.join(ROOT, "docs", "DESIGN.md"))


def test_repo_has_no_dangling_links():
    errors = check_links.check(ROOT)
    assert not errors, "\n".join(errors)


def test_design_has_cited_sections():
    """Every section number cited anywhere must be a real ## N. heading —
    in particular the §4 serve/step.py cited while DESIGN.md didn't exist."""
    sections = check_links.design_sections(
        os.path.join(ROOT, "docs", "DESIGN.md"))
    assert sections is not None and {1, 2, 3, 4, 5} <= sections


def test_readme_covers_required_topics():
    with open(os.path.join(ROOT, "README.md")) as f:
        text = f.read()
    for required in ("Quickstart", "Repo map", "BENCH_overlap.json",
                     "pytest", "examples/quickstart.py",
                     "`none`", "`ring`", "`bidir`", "`fused`"):
        assert required in text, f"README.md missing {required!r}"


@pytest.mark.parametrize("bad,msg", [
    ("see [x](no/such/file.md)", "no such file"),
    ("see [x](other.md#missing-anchor)", "dangling anchor"),
    # assembled at scan time so THIS file doesn't trip the repo-wide scan
    ("per DESIGN" + ".md §99", "sections"),
])
def test_checker_catches_rot(tmp_path, bad, msg):
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "DESIGN.md").write_text("## 1. Real section\n")
    (tmp_path / "other.md").write_text("## Present\n")
    (tmp_path / "doc.md").write_text(f"hello\n{bad}\n")
    errors = check_links.check(str(tmp_path))
    assert errors and any(msg in e for e in errors), (bad, errors)


def test_checker_requires_design_to_exist(tmp_path):
    (tmp_path / "mod.py").write_text("# cited in docs/DESIGN" + ".md §4\n")
    errors = check_links.check(str(tmp_path))
    assert errors and "does not exist" in errors[0]


def test_checker_ignores_code_fences_and_external(tmp_path):
    (tmp_path / "doc.md").write_text(
        "```\n[fake](not/a/file.md)\n```\n"
        "[ext](https://example.com/x) [mail](mailto:a@b.c)\n")
    assert check_links.check(str(tmp_path)) == []
