"""Serving tier: paged cache pool accounting, sampling entry points,
incremental-decode parity (dense AND paged vs teacher-forced full forward),
and the continuous-batching engine's bit-exactness + memory contract
(docs/DESIGN.md §10).

The multi-device GQA cache_specs regression and the larger engine trace run
in tests/_mp/check_serve.py (subprocess — jax locks the device count)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.config import ParallelConfig, RunConfig, get_smoke_config
from repro.models import lm
from repro.parallel.context import PCtx
from repro.serve import step as SRV
from repro.serve.cache import CachePool, PoolConfig, blocks_for, init_dense
from repro.serve.engine import DecodeEngine, Request

PCFG = ParallelConfig(strategy="hecaton", data=1, model=1, mx=1, my=1)
MAXSEQ = 24
GEN = 6


# ---------------------------------------------------------------------------
# pool accounting (host-side, no model)
# ---------------------------------------------------------------------------

def _pool(slots=2, block=4, num_blocks=9, max_seq=MAXSEQ):
    cfg = get_smoke_config("qwen3-0.6b")
    return CachePool(cfg, PoolConfig(slots, block, num_blocks, max_seq),
                     dtype=jnp.float32)


def test_pool_admission_gate():
    p = _pool(slots=2, block=4, num_blocks=9)      # 8 leasable
    assert p.can_admit(9)                          # 3 blocks
    s0 = p.admit(9)
    assert s0 is not None and p.blocks_in_use == 3
    assert p.admit(25) is None                     # > max_seq
    s1 = p.admit(17)                               # 5 blocks -> 8 total
    assert s1 is not None and p.blocks_in_use == 8
    assert not p.can_admit(1)                      # slots exhausted too
    p.free_slot(s0)
    assert p.blocks_in_use == 5 and p.can_admit(4)
    # freed slot's table rows are back on the null block
    assert (p.table[s0] == 0).all()


def test_pool_append_and_peak():
    p = _pool(slots=2, block=4, num_blocks=9)
    s = p.admit(4)                                 # exactly one block
    p.commit_prefill(s, 4)
    assert p.blocks_in_use == 1
    assert p.ensure_append(s)                      # position 4 -> block 2
    assert p.blocks_in_use == 2
    p.advance(s)
    assert p.ensure_append(s) and p.blocks_in_use == 2   # 5 fits block 2
    assert p.peak_blocks_in_use == 2
    # exhaust the free list: appends must start failing, not corrupt
    other = p.admit(24)                            # 6 blocks -> 8 in use
    p.commit_prefill(other, 20)
    for _ in range(3):
        p.advance(s)
    assert not p.ensure_append(s)                  # position 8 needs block 3
    p.free_slot(other)
    assert p.ensure_append(s)


def test_pool_table_null_block_invariant():
    p = _pool()
    s = p.admit(5)
    # entries beyond the lease stay on the null block
    owned = blocks_for(5, p.pool.block)
    assert (p.table[s, owned:] == 0).all()
    assert (p.table[s, :owned] > 0).all()


def test_pool_config_validation():
    with pytest.raises(AssertionError):
        PoolConfig(slots=1, block=4, num_blocks=1, max_seq=8)
    pc = PoolConfig(slots=3, block=4, num_blocks=10, max_seq=10)
    assert pc.max_blocks_per_slot == 3
    assert pc.leasable_blocks == 9
    assert pc.dense_equiv_blocks == 9


def test_engine_submit_rejects_unservable():
    cfg = get_smoke_config("qwen3-0.6b")
    rc = RunConfig("serve", "decode", 8, 1)
    params = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    eng = DecodeEngine(cfg, PCFG, rc, params,
                       PoolConfig(1, 4, 2, 8), compute_dtype=jnp.float32)
    with pytest.raises(ValueError):
        eng.submit(Request(0, np.zeros(7, np.int32), max_new=4))  # > max_seq
    with pytest.raises(ValueError):
        eng.submit(Request(1, np.zeros(6, np.int32), max_new=2))  # 2 blocks > 1


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def test_sampling_entry_point():
    key = jax.random.PRNGKey(0)
    lg = jax.random.normal(key, (3, 64))
    g = SRV.sample(lg, method="greedy")
    assert (np.asarray(g) == np.asarray(jnp.argmax(lg, -1))).all()
    for m in ("temperature", "top_p"):
        a = SRV.sample(lg, method=m, key=key, temperature=0.7, top_p=0.8)
        b = SRV.sample(lg, method=m, key=key, temperature=0.7, top_p=0.8)
        assert a.shape == (3,) and a.dtype == jnp.int32
        assert (np.asarray(a) == np.asarray(b)).all()      # same key -> same
        assert ((np.asarray(a) >= 0) & (np.asarray(a) < 64)).all()
    # nucleus with a tiny mass keeps only the argmax
    t = SRV.sample(lg, method="top_p", key=key, top_p=1e-6)
    assert (np.asarray(t) == np.asarray(g)).all()
    with pytest.raises(ValueError):
        SRV.sample(lg, method="temperature")               # needs a key
    with pytest.raises(ValueError):
        SRV.sample(lg, method="beam", key=key)


def test_top_p_restricts_support():
    # one dominant logit -> top_p=0.5 must always return it
    lg = jnp.zeros((1, 16)).at[0, 3].set(10.0)
    for i in range(8):
        k = jax.random.PRNGKey(i)
        assert int(SRV.sample(lg, method="top_p", key=k, top_p=0.5)[0]) == 3


# ---------------------------------------------------------------------------
# cache_specs (GQA/MQA audit — single-device mesh; 8-device in _mp)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen3-0.6b", "granite-34b", "minicpm3-4b",
                                  "zamba2-1.2b"])
def test_cache_specs_head_axes_divide_leaf(arch):
    from jax.sharding import Mesh
    cfg = get_smoke_config(arch)
    dev = np.array(jax.devices()[:1]).reshape(1, 1, 1)
    mesh = Mesh(dev, ("data", "mx", "my"))
    specs = SRV.cache_specs(cfg, PCFG, mesh, batch=2)
    caches = jax.eval_shape(lambda: init_dense(cfg, 2, 8, jnp.float32))

    # spec and cache trees have the same structure by construction
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(
        x, jax.sharding.PartitionSpec))
    flat_l = jax.tree.leaves(caches)
    assert len(flat_s) == len(flat_l)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for spec, leaf in zip(flat_s, flat_l):
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = (entry,) if isinstance(entry, str) else tuple(entry)
            prod = int(np.prod([sizes[a] for a in axes]))
            assert leaf.shape[dim] % prod == 0, (arch, spec, leaf.shape, dim)


def test_cache_specs_none_mesh():
    cfg = get_smoke_config("qwen3-0.6b")
    assert SRV.cache_specs(cfg, PCFG, None, batch=2) is None


# ---------------------------------------------------------------------------
# incremental-decode parity: dense AND paged vs teacher-forced argmax
# ---------------------------------------------------------------------------

def _dense_greedy(cfg, params, prompt, gen, rc):
    prefill = jax.jit(SRV.build_prefill(cfg, PCFG, rc, None,
                                        compute_dtype=jnp.float32))
    decode = jax.jit(SRV.build_decode_step(cfg, PCFG, rc, None,
                                           compute_dtype=jnp.float32))
    logits, caches = prefill(params, {"tokens": jnp.asarray(prompt)[None, :]})
    tok = SRV.greedy_sample(logits)
    toks = [int(tok[0, 0])]
    for i in range(gen - 1):
        pos = jnp.full((1, 1), len(prompt) + i, jnp.int32)
        logits, caches = decode(params, caches, tok, pos)
        tok = SRV.greedy_sample(logits)
        toks.append(int(tok[0, 0]))
    return toks


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "minicpm3-4b", "zamba2-1.2b"])
def test_decode_parity_dense_paged_teacher(arch):
    cfg = get_smoke_config(arch)
    rc = RunConfig("serve", "decode", MAXSEQ, 1)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (7,), 0,
                                           cfg.vocab_size), np.int32)
    dense = _dense_greedy(cfg, params, prompt, GEN, rc)

    # teacher-forced: one full forward over prompt + generated prefix must
    # reproduce the same argmax tokens position by position
    full = np.concatenate([prompt, np.asarray(dense[:-1], np.int64)])
    out = lm.forward(PCtx(None, PCFG), cfg, params,
                     {"tokens": jnp.asarray(full)[None, :],
                      "_dtype": jnp.float32})
    teacher = np.asarray(jnp.argmax(out.logits[0, len(prompt) - 1:], -1))
    assert teacher[:GEN].tolist() == dense, arch

    # paged: single request through the engine
    pool = PoolConfig(slots=2, block=4,
                      num_blocks=2 * blocks_for(MAXSEQ, 4) + 1, max_seq=MAXSEQ)
    eng = DecodeEngine(cfg, PCFG, rc, params, pool, compute_dtype=jnp.float32)
    eng.warmup()
    fin = eng.run([Request(rid=0, prompt=prompt, max_new=GEN)])
    assert fin[0].tokens == dense, arch


# ---------------------------------------------------------------------------
# engine: over-subscribed trace, bit-exact + pool high-water mark
# ---------------------------------------------------------------------------

def test_engine_trace_bit_exact_and_paged_memory_win():
    cfg = get_smoke_config("qwen3-0.6b")
    rc = RunConfig("serve", "decode", MAXSEQ, 1)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    plens = (5, 11, 7, 14, 3)                       # mixed lengths
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in plens]
    want = [_dense_greedy(cfg, params, p, GEN, rc) for p in prompts]

    pool = PoolConfig(slots=2, block=4,
                      num_blocks=2 * blocks_for(MAXSEQ, 4) + 1, max_seq=MAXSEQ)
    eng = DecodeEngine(cfg, PCFG, rc, params, pool, compute_dtype=jnp.float32)
    eng.warmup(prompt_lens=plens)
    fin = eng.run([Request(rid=i, prompt=p, max_new=GEN, arrival=i // 2)
                   for i, p in enumerate(prompts)])   # 5 arrivals > 2 slots
    for i in range(len(prompts)):
        assert fin[i].tokens == want[i], i
    # mixed-length trace: the pool's high-water mark stays strictly below
    # the dense [slots, max_seq] arena equivalent
    assert eng.pool.peak_blocks_in_use < pool.dense_equiv_blocks
    assert eng.pool.blocks_in_use == 0              # everything freed


def test_engine_eviction_restores_tokens():
    cfg = get_smoke_config("qwen3-0.6b")
    rc = RunConfig("serve", "decode", MAXSEQ, 1)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (14, 11)]
    want = [_dense_greedy(cfg, params, p, 8, rc) for p in prompts]
    # 8 leasable blocks admit both prompts (4+3) but can't hold both
    # sequences to completion (6+5): the youngest must be preempted and
    # replayed from its prompt, tokens unchanged
    pool = PoolConfig(slots=2, block=4, num_blocks=9, max_seq=MAXSEQ)
    eng = DecodeEngine(cfg, PCFG, rc, params, pool, compute_dtype=jnp.float32)
    eng.warmup(prompt_lens=(14, 11))
    fin = eng.run([Request(rid=i, prompt=p, max_new=8)
                   for i, p in enumerate(prompts)])
    assert eng.stats["preemptions"] >= 1
    for i in range(2):
        assert fin[i].tokens == want[i], i


# ---------------------------------------------------------------------------
# int8 paged K/V arena: token parity with the fp arena (docs/DESIGN.md §11)
# ---------------------------------------------------------------------------

# Per-arch trace shapes.  qwen3's random-init greedy trajectories keep
# healthy argmax margins for 64+ straight steps, so two sequences decode
# 64 tokens each.  Random-init minicpm3 (MLA) converges within ~15 steps
# to a near-cyclic attractor whose top-2 logit gap collapses to ~1e-4 —
# below even fp32 op-reordering noise, so token parity over that tail is
# meaningless for ANY lossy cache.  Its >= 64 decode steps come instead
# from six sequences generating inside the healthy-margin window (floor
# >= 0.011 vs a measured int8 logit perturbation of ~0.007), which also
# over-subscribes the pool harder (6 arrivals onto 2 slots).
_QUANT_TRACES = {
    "qwen3-0.6b": dict(seeds=(11,), fixed_lens=(9, 6), gen=64,
                       maxseq=80, num_blocks=21),
    "minicpm3-4b": dict(seeds=(46, 29, 37, 17, 3, 10), fixed_lens=None,
                        gen=11, maxseq=32, num_blocks=10),
}


@pytest.mark.parametrize("arch", sorted(_QUANT_TRACES))
def test_quant_kv_decode_parity_eviction_replay(arch):
    """Greedy decode through the int8 paged K/V arena must agree with the
    fp paged arena token-for-token over >= 64 total decode steps, on a
    pool sized so the running sequences can't all finish together — the
    eviction/replay protocol runs under quantized K/V too.  minicpm3
    covers the MLA latent arena (c_kv quantized; its 4-wide rope rows
    degrade to dense per the MIN_QUANT_DIM rule, docs/DESIGN.md §11)."""
    cfg = get_smoke_config(arch)
    t = _QUANT_TRACES[arch]
    rc = RunConfig("serve", "decode", t["maxseq"], 1)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    if t["fixed_lens"] is not None:
        rng = np.random.default_rng(t["seeds"][0])
        prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
                   for n in t["fixed_lens"]]
    else:
        prompts = []
        for qs in t["seeds"]:
            rng = np.random.default_rng(qs)
            n = int(rng.integers(6, 15))
            prompts.append(rng.integers(0, cfg.vocab_size,
                                        size=n).astype(np.int32))
    assert len(prompts) * t["gen"] >= 64          # the step-count gate
    plens = tuple(len(p) for p in prompts)
    # leasable blocks cover any single sequence to completion but not two
    # concurrently -> the youngest is preempted and replayed from its
    # prompt (asserted below for both arena dtypes)
    pool = PoolConfig(slots=2, block=4, num_blocks=t["num_blocks"],
                      max_seq=t["maxseq"])
    runs = {}
    for quant in (False, True):
        eng = DecodeEngine(cfg, PCFG, rc, params, pool,
                           compute_dtype=jnp.float32, quant_kv=quant)
        eng.warmup(prompt_lens=plens)
        fin = eng.run([Request(rid=i, prompt=p, max_new=t["gen"])
                       for i, p in enumerate(prompts)])
        assert eng.stats["preemptions"] >= 1, \
            f"trace not over-subscribed (quant_kv={quant})"
        runs[quant] = [fin[i].tokens for i in range(len(prompts))]
    for i in range(len(prompts)):
        assert len(runs[False][i]) == t["gen"], (arch, i)
        # token-level agreement over the whole generation
        assert runs[True][i] == runs[False][i], (arch, i)


def test_quant_kv_pool_arena_layout():
    """Quant pool: int8 payload + fp32 trailing-1 scale arenas; the dense
    fp pool is untouched by the flag's default."""
    cfg = get_smoke_config("qwen3-0.6b")
    pc = PoolConfig(slots=2, block=4, num_blocks=9, max_seq=MAXSEQ)
    q = CachePool(cfg, pc, dtype=jnp.float32, quant_kv=True)
    k, ks, v, vs = q.arenas["attn"]
    assert k.dtype == jnp.int8 and v.dtype == jnp.int8
    assert ks.dtype == jnp.float32 and vs.dtype == jnp.float32
    assert ks.shape == k.shape[:-1] + (1,)
    assert vs.shape == v.shape[:-1] + (1,)
    # untouched blocks dequantize to exact zeros (scales init to 1.0)
    assert np.asarray(ks).min() == 1.0
    tree = q.decode_tree()["attn"]
    from repro.models import attention as ATT
    assert isinstance(tree, ATT.QuantPagedKVCache)
    # int8 arena + scales still undercut the fp32 arena per block
    d = CachePool(cfg, pc, dtype=jnp.float32)
    assert not d.quant_kv
    assert isinstance(d.decode_tree()["attn"], ATT.PagedKVCache)
    assert q.block_bytes < d.block_bytes


def test_engine_eos_early_exit():
    cfg = get_smoke_config("qwen3-0.6b")
    rc = RunConfig("serve", "decode", MAXSEQ, 1)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (7,), 0,
                                           cfg.vocab_size), np.int32)
    base = _dense_greedy(cfg, params, prompt, GEN, rc)
    eos = base[2]                       # make the 3rd generated token the EOS
    pool = PoolConfig(slots=2, block=4,
                      num_blocks=2 * blocks_for(MAXSEQ, 4) + 1, max_seq=MAXSEQ)
    eng = DecodeEngine(cfg, PCFG, rc, params, pool, compute_dtype=jnp.float32,
                       eos_id=eos)
    eng.warmup()
    fin = eng.run([Request(rid=0, prompt=prompt, max_new=GEN)])
    assert fin[0].reason == "eos"
    assert fin[0].tokens == base[:3]
