"""Hypothesis property tests on system invariants."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import theory as T
from repro.models import layers as L
from repro.models.ssm import ssd_chunked
from repro.kernels import ref as R

SET = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# SSD: chunked == sequential for arbitrary shapes/chunk splits
# ---------------------------------------------------------------------------
@settings(**SET)
@given(st.integers(1, 3), st.sampled_from([16, 32, 48, 64]),
       st.sampled_from([1, 2, 4]), st.sampled_from([4, 8, 16]),
       st.sampled_from([8, 16]), st.integers(0, 10_000))
def test_ssd_chunk_invariance(b, S, nh, dh, chunk, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    g = 1
    ds = 4
    x = jax.random.normal(ks[0], (b, S, nh, dh), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, nh), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,), jnp.float32) * 0.3)
    B = jax.random.normal(ks[3], (b, S, g, ds), jnp.float32)
    C = jax.random.normal(ks[4], (b, S, g, ds), jnp.float32)
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S
    y, _ = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    ref = R.ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-3,
                               atol=1e-3)


# ---------------------------------------------------------------------------
# RoPE is an isometry per 2D plane and composes additively in position
# ---------------------------------------------------------------------------
@settings(**SET)
@given(st.integers(0, 500), st.sampled_from([16, 32, 64]),
       st.integers(0, 10_000))
def test_rope_preserves_norm(pos, dh, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 1, 2, dh), jnp.float32)
    p = jnp.full((1, 1), pos, jnp.int32)
    cos, sin = L.rope_cos_sin(p, dh, 10_000.0)
    y = L.apply_rope(x, cos, sin)
    np.testing.assert_allclose(float(jnp.linalg.norm(y)),
                               float(jnp.linalg.norm(x)), rtol=1e-5)


@settings(**SET)
@given(st.integers(0, 200), st.integers(0, 200), st.integers(0, 10_000))
def test_rope_relative_position(p1, p2, seed):
    """<rope(q,p1), rope(k,p2)> depends only on p1-p2."""
    dh = 32
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    q = jax.random.normal(k1, (1, 1, 1, dh), jnp.float32)
    k = jax.random.normal(k2, (1, 1, 1, dh), jnp.float32)

    def dot_at(a, b):
        ca, sa = L.rope_cos_sin(jnp.full((1, 1), a, jnp.int32), dh, 1e4)
        cb, sb = L.rope_cos_sin(jnp.full((1, 1), b, jnp.int32), dh, 1e4)
        return float(jnp.sum(L.apply_rope(q, ca, sa) * L.apply_rope(k, cb, sb)))

    shift = 13
    np.testing.assert_allclose(dot_at(p1, p2), dot_at(p1 + shift, p2 + shift),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# MoE dispatch: with enough capacity, combined output == dense gated mixture
# ---------------------------------------------------------------------------
@settings(**SET)
@given(st.integers(4, 32), st.sampled_from([4, 8]), st.sampled_from([1, 2]),
       st.integers(0, 10_000))
def test_moe_dispatch_exactness(T_, E, k, seed):
    from repro.config import MoEConfig, ModelConfig
    from repro.models import mlp as MLP
    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=8, vocab_size=32,
                      mlp_kind="gelu",
                      moe=MoEConfig(num_experts=E, top_k=k,
                                    capacity_factor=float(E)))  # no drops
    p = MLP.init_moe(cfg, jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (T_, 16), jnp.float32)
    y, probs = MLP._moe_local(p, x, cfg=cfg, n_local_experts=E, e_offset=0,
                              compute_dtype=jnp.float32)
    # dense reference: full softmax-top-k mixture
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / gate.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x)
    for e in range(E):
        h = jax.nn.gelu(x @ p["we1"][e])
        o = h @ p["we2"][e]
        w = jnp.sum(jnp.where(idx == e, gate, 0.0), axis=-1)
        ref += o * w[:, None]
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-3,
                               atol=2e-3)


# ---------------------------------------------------------------------------
# fused xent == naive log_softmax gather
# ---------------------------------------------------------------------------
@settings(**SET)
@given(st.integers(2, 16), st.sampled_from([8, 33, 128]),
       st.integers(0, 10_000))
def test_xent_matches_naive(T_, V, seed):
    from repro.models.lm import xent_loss
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    logits = jax.random.normal(k1, (1, T_, V), jnp.float32) * 5
    labels = jax.random.randint(k2, (1, T_), 0, V)
    got = float(xent_loss(None, logits, labels))
    naive = -jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                 labels[..., None], -1).mean()
    np.testing.assert_allclose(got, float(naive), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Theory (Table III): hecaton's asymptotic advantage + weak scaling
# ---------------------------------------------------------------------------
@settings(**SET)
@given(st.sampled_from([16, 64, 256, 1024]))
def test_hecaton_beats_1dtp_transmission(N):
    """Table III: hecaton <= flat-ring always (exact tie on FFN rows at N=16,
    where 10(sqrt(N)-1)/N == 2(N-1)/N), strictly better beyond, with the gap
    growing ~sqrt(N)."""
    p = T.CommParams(N=N)
    for phase in ("fwd", "bwd"):
        for blk in ("atten", "ffn"):
            h = T.hecaton(p, phase, blk)["transmission"]
            f = T.flat_ring(p, phase, blk)["transmission"]
            assert h <= f * (1 + 1e-9), (N, phase, blk)
            if N > 16:
                assert h < f, (N, phase, blk)
    # asymptotics: ratio ~ sqrt(N)
    h = T.layer_comm("hecaton", p)["transmission"]
    f = T.layer_comm("flat_ring", p)["transmission"]
    assert f / h > 0.2 * (N ** 0.5)


def test_weak_scaling_flat_vs_hecaton():
    # paper regime (standard package): D2D bandwidth low enough that NoP
    # matters relative to per-die compute
    base = T.CommParams(N=16, h=2048, beta=8e9)
    hec = T.weak_scaling_series("hecaton", base, ks=(1, 2, 4, 8))
    flat = T.weak_scaling_series("flat_ring", base, ks=(1, 2, 4, 8))
    assert hec[-1]["normalized"] < 1.6          # ~constant (paper Fig. 9)
    assert flat[-1]["normalized"] > 1.8          # 1D-TP blows up
    assert flat[-1]["normalized"] > 2 * hec[-1]["normalized"]


@settings(**SET)
@given(st.sampled_from([4, 16, 64, 256]))
def test_sram_requirement_shrinks(N):
    p = T.CommParams(N=N)
    assert T.peak_sram_bytes("hecaton", p) <= \
        T.peak_sram_bytes("flat_ring", p)


# ---------------------------------------------------------------------------
# optimizer: adamw matches a hand-rolled reference on scalars
# ---------------------------------------------------------------------------
@settings(**SET)
@given(st.floats(-2, 2, allow_nan=False), st.floats(-1, 1, allow_nan=False),
       st.integers(0, 10_000))
def test_adamw_matches_reference(p0, g0, seed):
    from repro.config import RunConfig
    from repro.optim import adamw
    rc = RunConfig("t", "train", 8, 2, lr=1e-2, weight_decay=0.0,
                   grad_clip=1e9, warmup_steps=1)
    params = {"w": jnp.array([p0], jnp.float32)}
    g = {"w": jnp.array([g0], jnp.float32)}
    st_ = adamw.init(params)
    p1, st1, _ = adamw.update(params, g, st_, rc, total_steps=10_000)
    # reference
    lr = float(adamw.lr_schedule(rc, 0, 10_000))
    m = 0.1 * g0
    v = 0.05 * g0 * g0
    mh, vh = m / 0.1, v / 0.05
    ref = p0 - lr * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(float(p1["w"][0]), ref, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# in-graph skip-update guard (runtime/guard.py, docs/DESIGN.md §8)
# ---------------------------------------------------------------------------

def _rand_grad_tree(seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return {"a": jax.random.normal(ks[0], (3, 4), jnp.float32),
            "b": {"w": jax.random.normal(ks[1], (5,), jnp.float32),
                  "v": jax.random.normal(ks[2], (2, 2, 2), jnp.float32)}}


@settings(**SET)
@given(st.integers(0, 10_000), st.integers(0, 2), st.integers(0, 3),
       st.sampled_from([np.nan, np.inf, -np.inf]))
def test_guard_any_nonfinite_anywhere_skips_bit_unchanged(seed, leaf_i,
                                                          elem_i, bad):
    """A single non-finite element in ANY leaf forces update_ok=False, and a
    skipped step passes params and every optimizer leaf through
    bit-unchanged (the select must be where(), never multiply)."""
    from repro.config import GuardConfig, RunConfig
    from repro.optim import adamw
    rc = RunConfig("t", "train", 8, 2, lr=1e-2)
    gc = GuardConfig()
    params = _rand_grad_tree(seed + 1)
    st_ = adamw.init(params)
    # one healthy step so the EWMA/moments are non-trivial state to preserve
    params, st_, _ = adamw.update(params, _rand_grad_tree(seed + 2), st_, rc,
                                  guard=gc)
    grads = _rand_grad_tree(seed)
    flat, treedef = jax.tree_util.tree_flatten(grads)
    leaf = flat[leaf_i % len(flat)]
    pos = np.unravel_index(elem_i % leaf.size, leaf.shape)
    flat[leaf_i % len(flat)] = leaf.at[pos].set(bad)
    grads = jax.tree_util.tree_unflatten(treedef, flat)
    p2, s2, m = adamw.update(params, grads, st_, rc, guard=gc)
    assert float(m["update_ok"]) == 0.0
    assert float(m["update_skipped"]) == 1.0
    assert float(m["nonfinite"]) == 1.0
    for a, b in zip(jax.tree.leaves((p2, s2)), jax.tree.leaves((params, st_))):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@settings(**SET)
@given(st.integers(0, 10_000), st.floats(1.5, 50.0, allow_nan=False))
def test_guard_spike_detection_monotone_in_factor(seed, ratio):
    """If a grad norm is accepted at spike factor f, it is accepted at every
    f' > f; if skipped, skipped at every f' < f — the predicate is monotone
    in the factor, so tightening the guard never lets more through."""
    from repro.config import GuardConfig
    from repro.optim import adamw
    ewma = jnp.float32(1.0)
    gnorm = jnp.float32(ratio)
    oks = []
    for f in (1.01, 2.0, 5.0, 10.0, 100.0):
        ok, finite = adamw.guard_predicate(gnorm, ewma,
                                           GuardConfig(grad_spike_factor=f))
        assert bool(finite)
        oks.append(bool(ok))
    assert oks == sorted(oks)          # False ... False True ... True
    assert oks[-1]                     # factor 100 > max ratio 50: accepted


# ---------------------------------------------------------------------------
# checkpoint roundtrip over random pytrees is lossless + manifest-complete
# ---------------------------------------------------------------------------

# keys deliberately include the characters the manifest encoding must keep
# collision-free: "__" (the old flattening separator), "/" (the path join
# itself) and "%" (the escape character)
_CKPT_KEYS = st.sampled_from(
    ["a", "b", "a__b", "a_", "_b", "w/x", "a/b", "%", "%2F", "deep__/key"])
_CKPT_DTYPES = st.sampled_from(
    ["float32", "int32", "bfloat16", "float16", "bool"])


@st.composite
def _ckpt_leaf(draw):
    shape = draw(st.sampled_from([(), (3,), (2, 4), (1, 2, 2)]))
    dtype = draw(_CKPT_DTYPES)
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    if dtype == "bool":
        arr = rng.integers(0, 2, size=shape).astype(bool)
    elif dtype == "int32":
        arr = rng.integers(-1000, 1000, size=shape).astype(np.int32)
    else:
        arr = rng.standard_normal(size=shape).astype(np.float32)
    return jnp.asarray(arr).astype(dtype)


@st.composite
def _ckpt_tree(draw, depth=0):
    if depth >= 2 or draw(st.booleans()):
        return draw(_ckpt_leaf())
    if draw(st.booleans()):
        keys = draw(st.lists(_CKPT_KEYS, min_size=1, max_size=3,
                             unique=True))
        return {k: draw(_ckpt_tree(depth=depth + 1)) for k in keys}
    n = draw(st.integers(1, 3))
    return [draw(_ckpt_tree(depth=depth + 1)) for _ in range(n)]


@settings(max_examples=15, deadline=None)
@given(_ckpt_tree(), st.integers(1, 4), st.booleans())
def test_checkpoint_roundtrip_lossless_and_manifest_complete(tree, writers,
                                                             pin_even):
    """Any pytree of nested dicts/lists with mixed dtypes (incl. bf16, which
    the .npy format cannot round-trip natively, and keys containing "__",
    "/", "%") survives save→restore bit-exact under ANY writer-group size
    1..4 (with and without a writer_map pinning), the global MANIFEST.json
    has exactly one entry per leaf with no file collisions, and the
    per-writer partition covers every leaf exactly once with every shard
    landing in its owner's subdirectory."""
    import json
    import shutil
    import tempfile

    from repro.checkpoint.manager import MANIFEST, CheckpointManager

    # optional pinning: half the leaves forced onto writer 0 by name hash
    wmap = ((lambda n: 0 if len(n) % 2 == 0 else None) if pin_even
            else None)
    d = tempfile.mkdtemp(prefix="ckpt_prop_")
    try:
        mgr = CheckpointManager(d, writers=writers, writer_map=wmap)
        mgr.save(1, tree)
        restored, step = mgr.restore(tree)
        assert step == 1
        got = jax.tree_util.tree_leaves(restored)
        want = jax.tree_util.tree_leaves(tree)
        assert len(got) == len(want)
        for g, w in zip(got, want):
            wn = np.asarray(w)
            gn = np.asarray(g)
            assert gn.dtype == wn.dtype
            assert gn.shape == wn.shape
            # bit-exact: compare raw bytes (works for bf16/NaN alike)
            assert gn.tobytes() == wn.tobytes()
        with open(os.path.join(d, "step_00000001", MANIFEST)) as f:
            meta = json.load(f)
        assert meta["complete"] is True
        assert meta["committed"] == list(range(writers))
        assert len(meta["manifest"]) == len(want)      # complete, no merges
        files = [v["file"] for v in meta["manifest"].values()]
        assert len(set(files)) == len(want)            # no file collisions
        for info in meta["manifest"].values():
            # each shard sits in its owning writer's subdirectory and is
            # accounted for in that writer's partial manifest
            assert info["file"].startswith(f"writer_{info['writer']:02d}/")
            assert 0 <= info["writer"] < writers
        for w in range(writers):
            with open(os.path.join(d, "step_00000001", f"writer_{w:02d}",
                                   "manifest.json")) as f:
                partial = json.load(f)
            assert set(partial["shards"]) == {
                k for k, v in meta["manifest"].items() if v["writer"] == w}
    finally:
        shutil.rmtree(d, ignore_errors=True)


# ---------------------------------------------------------------------------
# straggler rebalancer conserves shards and unloads slow hosts
# ---------------------------------------------------------------------------
@settings(**SET)
@given(st.integers(2, 16), st.data())
def test_rebalance_conserves(n, data):
    from repro.runtime.fault import rebalance_data_shards
    slow = data.draw(st.lists(st.integers(0, n - 1), max_size=n // 2,
                              unique=True))
    out = rebalance_data_shards(n, slow)
    assert sum(out) == n
    for s in slow:
        if len(slow) < n:
            assert out[s] <= 1


# ---------------------------------------------------------------------------
# ISSUE 8: heartbeat leases + fleet fates through the real quorum gate
# ---------------------------------------------------------------------------

@settings(**SET)
@given(st.integers(1, 4),
       st.lists(st.tuples(st.integers(0, 3),          # slot (mod n_slots)
                          st.floats(0.01, 3.0),       # dt since last event
                          st.integers(0, 5)),         # heartbeat token
                min_size=1, max_size=40),
       st.floats(0.5, 2.0))
def test_lease_table_matches_reference_model(n_slots, events, timeout):
    """LeaseTable (the coordinator's liveness ledger) against a reference
    model over arbitrary heartbeat-deadline schedules: a lease expires
    exactly when ``timeout`` of coordinator time passes without the token
    CHANGING — repeated tokens (a frozen child re-observed) never refresh
    it, new tokens always do, and no cross-process clock is involved."""
    from repro.runtime.procs import LeaseTable

    lt = LeaseTable(timeout)
    ref_last = {}
    now = 0.0
    for s in range(n_slots):
        lt.start(s, now)
        ref_last[s] = (None, now)
    for slot, dt, token in events:
        slot %= n_slots
        now += dt
        lt.observe(slot, token, now)
        if ref_last[slot][0] != token:
            ref_last[slot] = (token, now)
        for s in range(n_slots):
            want = (now - ref_last[s][1]) > timeout
            assert lt.expired(s, now) == want, (s, now, ref_last[s])
    victim = events[0][0] % n_slots
    lt.drop(victim)
    assert not lt.expired(victim, now + 10 * timeout)   # dropped = no lease


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.data())
def test_fleet_fates_full_verified_coverage_or_nothing(n_writers, data):
    """Writer-fate simulation through the REAL quorum gate + publish +
    on-disk verification: for any writer count 1..4 and any subset of
    writers killed (torn shards, no partial), stalled (same) or corrupting
    (bad bytes after checksumming), a save either publishes a step whose
    manifest covers EVERY shard with crc32s that verify from disk, or
    publishes nothing at all — never a partial step."""
    import json
    import shutil
    import tempfile
    import zlib

    from repro.checkpoint import wire
    from repro.checkpoint.manager import (CheckpointManager, QuorumError,
                                          partition_shards)

    n_leaves = data.draw(st.integers(1, 6), label="n_leaves")
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1),
                                          label="seed"))
    snap = {f"leaf{i:02d}": rng.standard_normal(
                data.draw(st.sampled_from([(2,), (3, 4), (1, 5)]),
                          label=f"shape{i}")).astype(np.float32)
            for i in range(n_leaves)}
    fates = [data.draw(st.sampled_from(["ok", "dead", "stall", "corrupt"]),
                       label=f"fate{w}") for w in range(n_writers)]

    d = tempfile.mkdtemp(prefix="fleet_prop_")
    try:
        mgr = CheckpointManager(d, writers=n_writers)
        owner = partition_shards({k: v.nbytes for k, v in snap.items()},
                                 n_writers)
        names = sorted(snap)
        tmp = os.path.join(d, "step_00000001.tmp")
        failures = {}
        # virtual writers: same wire calls the fleet children make
        for w, fate in enumerate(fates):
            wtag = f"writer_{w:02d}"
            wdir = os.path.join(tmp, wtag)
            os.makedirs(wdir, exist_ok=True)
            mine = [n for n in names if owner[n] == w]
            shards = {}
            for i, name in enumerate(mine):
                wa, info = wire.leaf_wire(snap[name])
                nbytes, c = wire.write_leaf(
                    os.path.join(wdir, f"leaf_{i:05d}.npy"), wa)
                info.update(bytes=nbytes, crc32=c,
                            file=f"{wtag}/leaf_{i:05d}.npy", writer=w)
                shards[name] = info
                if fate in ("dead", "stall") and i == len(mine) // 2:
                    break              # torn mid-range, rest never written
            if fate in ("dead", "stall"):
                failures[w] = RuntimeError(f"writer {fate}")
                continue               # no partial manifest — the torn state
            if fate == "corrupt" and mine:
                victim = os.path.join(tmp, shards[mine[-1]]["file"])
                with open(victim, "r+b") as f:
                    f.truncate(max(0, os.path.getsize(victim) - 1))
            wire.publish_partial(wdir, 1, w, shards)
        final = os.path.join(d, "step_00000001")
        try:
            verified = mgr.quorum_gate(tmp, 1, names, failures)
            mgr._publish(tmp, final, 1, verified, failures, {})
            published = True
        except QuorumError:
            shutil.rmtree(tmp, ignore_errors=True)   # what _write does
            published = False
        if published:
            with open(os.path.join(final, "MANIFEST.json")) as f:
                meta = json.load(f)
            assert meta["complete"] is True
            assert set(meta["manifest"]) == set(names)   # FULL coverage
            for name, info in meta["manifest"].items():
                blob = open(os.path.join(final, info["file"]), "rb").read()
                assert len(blob) == info["bytes"], name
                assert zlib.crc32(blob) == info["crc32"], name
            assert mgr.all_steps() == [1]
        else:
            assert mgr.all_steps() == []                 # NOTHING published
            assert not os.path.exists(final)
    finally:
        shutil.rmtree(d, ignore_errors=True)


# ---------------------------------------------------------------------------
# Int8 wire quantization (core/quant.py): roundtrip bound, zero-safety, and
# the accumulated per-hop bound of a quantized ring AG-matmul
# ---------------------------------------------------------------------------
@settings(**SET)
@given(st.integers(1, 4), st.sampled_from([16, 24, 64, 129]),
       st.sampled_from(["float32", "bfloat16"]), st.integers(0, 10_000),
       st.floats(1e-3, 1e3))
def test_quant_roundtrip_bounded(rows, h, dtype, seed, amp):
    """Element-wise |dequant(quant(x)) - x| ≤ scale/2 for arbitrary shapes,
    dtypes and magnitudes; scales are fp32 keepdims over the trailing axis."""
    from repro.core import quant as Q

    x = (jax.random.normal(jax.random.PRNGKey(seed), (rows, h), jnp.float32)
         * amp).astype(dtype)
    q, s = Q.quant_int8(x)
    assert q.dtype == jnp.int8
    assert s.dtype == jnp.float32 and s.shape == (rows, 1)
    # the ≤ scale/2 bound holds on the fp32 dequant (the value the rings
    # fold into their fp32 accumulators); casting to a narrower output
    # dtype afterwards adds only that dtype's own half-ULP rounding
    rt32 = Q.dequant_int8(q, s, jnp.float32)
    err = np.abs(np.asarray(rt32) - np.asarray(x, np.float32))
    # 1e-5 relative slack: an exactly-half quantum (x/scale = k + 0.5)
    # makes the error land ON the bound, where fp32 slop in scale and the
    # q*scale product can tip a few ULPs past it
    bound = np.asarray(s) / 2 * (1 + 1e-5) + 1e-30
    assert (err <= bound).all(), (err.max(), float(s.max()))
    rt = Q.dequant_int8(q, s, x.dtype)
    np.testing.assert_array_equal(np.asarray(rt),
                                  np.asarray(rt32.astype(x.dtype)))
    assert np.isfinite(np.asarray(rt, np.float32)).all()


@settings(**SET)
@given(st.integers(1, 4), st.sampled_from([16, 32]), st.integers(0, 10_000))
def test_quant_zero_rows_exact_no_nan(rows, h, seed):
    """All-zero rows get scale 1.0: zeros round-trip bit-exactly and no
    NaN/Inf appears anywhere (the div-by-zero hazard of max|row|=0)."""
    from repro.core import quant as Q

    x = jax.random.normal(jax.random.PRNGKey(seed), (rows + 1, h),
                          jnp.float32).at[0].set(0.0)
    q, s = Q.quant_int8(x)
    assert float(s[0, 0]) == 1.0
    rt = np.asarray(Q.dequant_int8(q, s, x.dtype))
    assert (rt[0] == 0.0).all()                       # bit-exact zeros
    assert np.isfinite(rt).all() and np.isfinite(np.asarray(s)).all()
    z = jnp.zeros((2, h), jnp.float32)
    qz, sz = Q.quant_int8(z)
    assert (np.asarray(Q.dequant_int8(qz, sz, z.dtype)) == 0.0).all()


@settings(**SET)
@given(st.sampled_from([2, 4, 8]), st.sampled_from([16, 32]),
       st.sampled_from([8, 24]), st.integers(0, 10_000))
def test_quant_ring_ag_matmul_accumulated_bound(n, h, o, seed):
    """Quantized ring AG-matmul error vs the exact product is bounded by the
    accumulated per-hop bound: shard k of the gathered result crossed k hops,
    each adding ≤ scale_i/2 per element before the dot — so the error of
    ``roundtrip^k(x_j) @ w`` is ≤ (Σ_i scale_i/2) · Σ|w| column-wise.

    Simulated hop-wise single-process (one shard per ring rank, k successive
    quantize/dequantize roundtrips = k quantized hops of core/quant.ring_hop
    — same arithmetic, no mesh needed), for n ∈ {2, 4, 8}."""
    from repro.core import quant as Q

    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    shards = jax.random.normal(ks[0], (n, 4, h), jnp.float32)
    w = jax.random.normal(ks[1], (h, o), jnp.float32)
    for j in range(n):
        x = shards[j]
        scale_sum = jnp.zeros((4, 1), jnp.float32)
        for k in range(n):                    # k hops away from the source
            got = np.asarray(x @ w)
            want = np.asarray(shards[j] @ w)
            # per-row accumulated bound, contracted through |w|
            bound = (np.asarray(scale_sum) / 2 * (1 + 1e-6)
                     @ np.abs(np.asarray(w)).max(axis=0, keepdims=True) * h
                     + 1e-4)
            assert (np.abs(got - want) <= bound + 1e-5).all(), (j, k)
            q, s = Q.quant_int8(x)            # one more quantized hop
            x = Q.dequant_int8(q, s, x.dtype)
            scale_sum = scale_sum + s
