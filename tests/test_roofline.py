"""HLO analyzer correctness: loop scaling, dot flops, collective byte model —
and the key paper-faithfulness check that measured collective bytes match the
Table III analytical model."""

import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.roofline.hlo import HLOModule, analyze
from repro.core import theory as T

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_scan_flops_scaled_by_trip_count():
    def f(x, w):
        y, _ = lax.scan(lambda c, _: (c @ w, None), x, None, length=8)
        return y

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((128, 128), jnp.float32),
                         jax.ShapeDtypeStruct((128, 128), jnp.float32)
                         ).compile()
    r = analyze(c.as_text())
    expected = 8 * 2 * 128 ** 3
    assert abs(r.flops - expected) / expected < 0.01
    # XLA's own cost_analysis undercounts exactly 8x (documents why hlo.py exists)
    from repro.compat import cost_analysis_dict
    xla = cost_analysis_dict(c)["flops"]
    assert xla < expected / 4


def test_nested_scan_scaling():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None
            y, _ = lax.scan(inner, c, None, length=3)
            return y, None
        y, _ = lax.scan(outer, x, None, length=5)
        return y

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    r = analyze(c.as_text())
    expected = 15 * 2 * 64 ** 3
    assert abs(r.flops - expected) / expected < 0.02


def test_dot_flops_with_batch_dims():
    def f(x, w):
        return jnp.einsum("bij,bjk->bik", x, w)

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((4, 32, 64), jnp.float32),
                         jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
                         ).compile()
    r = analyze(c.as_text())
    expected = 2 * 4 * 32 * 64 * 16
    assert abs(r.flops - expected) / expected < 0.01


def test_collective_byte_model_vs_table3():
    """Measured per-device AG/RS bytes of one hecaton FFN == paper eq.(2)."""
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "_mp",
                                      "check_ffn_bytes.py")],
        capture_output=True, text=True, timeout=600,
        env={**os.environ,
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PYTHONPATH": os.path.join(ROOT, "src")})
    assert out.returncode == 0, out.stderr[-2000:]
    assert "BYTES MATCH THEORY" in out.stdout, out.stdout


def test_memory_bytes_positive_and_flops_ratio():
    def f(x, w):
        return jax.nn.gelu(x @ w)

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((256, 256), jnp.float32),
                         jax.ShapeDtypeStruct((256, 256), jnp.float32)
                         ).compile()
    r = analyze(c.as_text())
    assert r.flops >= 2 * 256 ** 3 * 0.99
    assert r.hbm_bytes >= 3 * 256 * 256 * 4 * 0.9   # >= in+w+out


def test_group_size_parsing():
    from repro.roofline.hlo import group_size
    assert group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
    assert group_size("replica_groups=[4,4]<=[16]") == 4
    assert group_size("replica_groups=[2,8]<=[16]") == 8
