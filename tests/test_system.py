"""End-to-end behaviour tests: training convergence, serve==train consistency,
checkpoint resume exactness, fault-supervised restart, data pipeline."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.config import ModelConfig, ParallelConfig, RunConfig, \
    get_smoke_config
from repro.data.synthetic import Prefetcher, SyntheticLM
from repro.models import lm
from repro.optim import adamw
from repro.parallel.context import PCtx
from repro.runtime.fault import FailureInjector, run_supervised
from repro.serve import step as SS
from repro.train import loop as train_loop
from repro.train import step as TS

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                   num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                   mlp_kind="swiglu", qk_norm=True)
PCFG1 = ParallelConfig(strategy="hecaton", data=1, model=1, mx=1, my=1,
                       microbatches=1)
PCTX1 = PCtx(None, PCFG1)


def _train(cfg, steps=60, seed=0, microbatches=1, lr=2e-3, seq=32, batch=8):
    rc = RunConfig("t", "train", seq, batch, lr=lr, warmup_steps=10)
    pcfg = PCFG1.with_(microbatches=microbatches)
    ts = jax.jit(TS.build_train_step(cfg, pcfg, rc, None,
                                     compute_dtype=jnp.float32))
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw.init(params)
    ds = SyntheticLM(cfg.vocab_size, seq, batch, seed=seed)
    losses = []
    for i in range(steps):
        batch_i = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        params, opt, m = ts(params, opt, batch_i)
        losses.append(float(m["loss"]))
    return params, losses


def test_training_reduces_loss():
    _, losses = _train(TINY, steps=120, lr=5e-3)
    assert min(losses[-10:]) < losses[0] - 0.15, (losses[0], losses[-5:])
    assert all(np.isfinite(losses))


def test_microbatching_equivalence():
    """1 vs 4 microbatches: same global batch => same loss and same
    accumulated gradient (compared via Adam's first moment, which is linear
    in the gradient — raw params after Adam amplify fp noise through the
    sign-like step-1 update)."""
    rc = RunConfig("t", "train", 16, 8, lr=1e-3)
    params = lm.init_params(TINY, jax.random.PRNGKey(0))
    ds = SyntheticLM(TINY.vocab_size, 16, 8)
    b = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
    outs = []
    for n in (1, 4):
        ts = jax.jit(TS.build_train_step(TINY, PCFG1.with_(microbatches=n),
                                         rc, None,
                                         compute_dtype=jnp.float32))
        _, o2, m = ts(params, adamw.init(params), b)
        outs.append((o2.mu, float(m["loss"])))
    np.testing.assert_allclose(outs[0][1], outs[1][1], rtol=1e-5)
    for a, b_ in zip(jax.tree.leaves(outs[0][0]), jax.tree.leaves(outs[1][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-3,
                                   atol=5e-5)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-130m", "zamba2-1.2b",
                                  "minicpm3-4b", "whisper-small"])
def test_prefill_decode_matches_forward(arch):
    """KV/SSM-cache decode produces the same logits as the full forward —
    the strongest cache-correctness check, per arch family."""
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 12
    ds = SyntheticLM(cfg.vocab_size, S, B, seed=3)
    batch = {"tokens": jnp.asarray(ds.batch_at(0)["tokens"]),
             "_dtype": jnp.float32}
    if cfg.family == "audio":
        batch["frames"] = jnp.full((B, cfg.frontend_stub_len, cfg.d_model),
                                   0.01, jnp.float32)
    full = lm.forward(PCTX1, cfg, params, batch)

    rc = RunConfig("s", "decode", S, B)
    prefill = jax.jit(SS.build_prefill(cfg, PCFG1, rc, None,
                                       compute_dtype=jnp.float32))
    decode = jax.jit(SS.build_decode_step(cfg, PCFG1, rc, None,
                                          compute_dtype=jnp.float32))
    pre_batch = {k: v for k, v in batch.items() if k != "_dtype"}
    pre_batch["tokens"] = batch["tokens"][:, :S - 2]
    logits_p, caches = prefill(params, pre_batch)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(full.logits[:, S - 3]),
                               rtol=2e-3, atol=2e-3)
    # decode the next 2 tokens
    for i in range(2):
        tok = batch["tokens"][:, S - 2 + i:S - 1 + i]
        pos = jnp.full((B, 1), S - 2 + i, jnp.int32)
        logits_d, caches = decode(params, caches, tok, pos)
        np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                                   np.asarray(full.logits[:, S - 2 + i]),
                                   rtol=2e-3, atol=2e-3)


def test_checkpoint_resume_bit_exact(tmp_path):
    """train 20 straight == train 10, checkpoint, restore, train 10 more."""
    rc = RunConfig("t", "train", 16, 4, lr=1e-3)
    ts = jax.jit(TS.build_train_step(TINY, PCFG1, rc, None,
                                     compute_dtype=jnp.float32))
    ds = SyntheticLM(TINY.vocab_size, 16, 4)

    def run(params, opt, lo, hi):
        for i in range(lo, hi):
            b = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
            params, opt, _ = ts(params, opt, b)
        return params, opt

    p0 = lm.init_params(TINY, jax.random.PRNGKey(0))
    pa, oa = run(p0, adamw.init(p0), 0, 20)

    pb, ob = run(p0, adamw.init(p0), 0, 10)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(10, {"params": pb, "opt_state": ob})
    restored, step = mgr.restore({"params": pb, "opt_state": ob})
    assert step == 10
    pc, oc = run(restored["params"], restored["opt_state"], 10, 20)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"params": {"w": jnp.ones((4,))}}
    for s in (5, 10, 15):
        mgr.save(s, state)
    assert mgr.all_steps() == [10, 15]          # keep=2 gc'd step 5
    # a stale .tmp dir never shadows a real checkpoint
    os.makedirs(os.path.join(str(tmp_path), "step_00000099.tmp"))
    assert mgr.latest_step() == 15


def test_supervised_restart_with_injected_failures(tmp_path):
    rc = RunConfig("t", "train", 16, 4, lr=1e-3)
    ts = jax.jit(TS.build_train_step(TINY, PCFG1, rc, None,
                                     compute_dtype=jnp.float32))
    ds = SyntheticLM(TINY.vocab_size, 16, 4)
    mgr = CheckpointManager(str(tmp_path))
    injector = FailureInjector({7: "chip", 13: "host"})
    TOTAL = 20

    def make_state(_):
        params = lm.init_params(TINY, jax.random.PRNGKey(0))
        opt = adamw.init(params)
        start = 0
        if mgr.latest_step() is not None:
            restored, start = mgr.restore({"params": params,
                                           "opt_state": opt})
            params, opt = restored["params"], restored["opt_state"]
        return {"params": params, "opt_state": opt}, start

    def run_steps(state, start, inc):
        it = ({k: jnp.asarray(v) for k, v in ds.batch_at(s).items()}
              for s in range(start, TOTAL))
        return train_loop.train(ts, state, it, start_step=start,
                                num_steps=TOTAL, ckpt=mgr, ckpt_every=5,
                                log_every=100, injector=injector,
                                log_fn=lambda *a: None)

    state, incarnations = run_supervised(make_state, run_steps)
    assert incarnations == 3
    assert len(injector.log) == 2


def test_data_pipeline_determinism_and_sharding():
    a = SyntheticLM(100, 16, 8, seed=1).batch_at(5)
    b = SyntheticLM(100, 16, 8, seed=1).batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])
    # host sharding partitions the global batch deterministically
    h0 = SyntheticLM(100, 16, 8, seed=1, host_id=0, num_hosts=2).batch_at(5)
    h1 = SyntheticLM(100, 16, 8, seed=1, host_id=1, num_hosts=2).batch_at(5)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_prefetcher_overlap():
    ds = SyntheticLM(64, 8, 2)
    it = Prefetcher(iter(ds), depth=2)
    batches = [next(it) for _ in range(3)]
    it.close()
    assert all(b["tokens"].shape == (2, 8) for b in batches)


def test_vlm_prefix_influences_logits():
    cfg = get_smoke_config("paligemma-3b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S, P = 2, 16, cfg.frontend_stub_len
    batch = {"tokens": jnp.zeros((B, S), jnp.int32),
             "patches": jnp.ones((B, P, cfg.d_model)) * 0.02,
             "_dtype": jnp.float32}
    out = lm.forward(PCTX1, cfg, params, batch)
    batch2 = dict(batch, patches=batch["patches"] * -1)
    out2 = lm.forward(PCTX1, cfg, params, batch2)
    assert float(jnp.abs(out.logits - out2.logits).max()) > 1e-6
