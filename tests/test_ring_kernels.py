"""Fused Pallas ring-matmul kernels (kernels/ring_matmul.py).

Numerics (fused kernels vs the core/overlap.py ring reference vs bulk
collectives, fwd+grad, epilogues, gated pair, non-tile-aligned fallback) run
in a subprocess on a fake 8-device topology (tests/_mp style).  In-process
tests cover the block/gating logic, the degenerate single-device ring, the
``"fused"`` mode plumbing, and the overlap-aware comm-model extension.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, ROOT)            # for `benchmarks` imports


def _run(script):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, os.path.join(ROOT, "tests", "_mp",
                                                     script)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_ring_kernel_numerics():
    """Each fused kernel == ring reference == bulk (fwd+grad), epilogues,
    gated pair, and the non-tile-aligned fused→ring fallback."""
    out = _run("check_ring_kernels.py")
    assert "ALL RING KERNEL CHECKS PASSED" in out


# ---------------------------------------------------------------------------
# In-process: block selection / gating logic
# ---------------------------------------------------------------------------


def test_pick_block_and_aligned():
    from repro.kernels.ring_matmul import aligned, pick_block

    assert pick_block(64, 128) == 64          # dim fits: one tile
    assert pick_block(256, 128) == 128        # MXU-aligned fast path
    assert pick_block(320, 128) == 80         # degraded: largest divisor
    assert 320 % pick_block(320, 128) == 0
    for dim in (1, 7, 96, 128, 129, 512, 1000):
        assert dim % pick_block(dim, 128) == 0
    assert aligned(64, 128) and aligned(256, 128)
    assert not aligned(320, 128)              # fused gate refuses this


def test_fused_ok_gates():
    from repro.kernels import ring_matmul as RM

    assert RM.fused_ok_ag((2, 4, 12), (12, 8), 4)
    assert not RM.fused_ok_ag((2, 4, 12), (12, 8), 1)       # degenerate ring
    assert not RM.fused_ok_ag((2, 160, 24), (24, 8), 4)     # M=320 unaligned
    assert RM.fused_ok_rs((2, 16, 12), (12, 8), 4, 1)
    assert not RM.fused_ok_rs((2, 10, 12), (12, 8), 4, 1)   # 10 % 4 != 0
    assert RM.fused_ok_rs((2, 16, 12), (12, 8), 4, 2)       # cols: 8 % 4 == 0
    assert not RM.fused_ok_rs((2, 16, 12), (12, 6), 4, 2)   # 6 % 4 != 0
    assert RM.fused_ok_contract((2, 16, 3), (12, 8), 4)
    assert not RM.fused_ok_contract((2, 16, 3), (13, 8), 4)  # w rows mismatch


def test_single_device_ring_matches_matmul_kernel():
    """n=1 short-circuits to the local Pallas tile loop — epilogue parity
    with kernels/matmul.py."""
    from repro.kernels import matmul as MM
    from repro.kernels import ring_matmul as RM

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(k1, (2, 8, 16), jnp.float32)
    w = jax.random.normal(k2, (16, 24), jnp.float32) / 4
    b = jax.random.normal(k3, (24,), jnp.float32)
    y = RM.ag_matmul(x, w, "none_axis", dim=1, n=1, bias=b, act="gelu")
    ref = MM.matmul(x.reshape(16, 16), w, b, act="gelu", block_m=16,
                    block_n=24, block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y).reshape(16, 24),
                               np.asarray(ref), rtol=2e-5, atol=2e-5)
    h, g = RM.matmul_rs_pair(x, w, w, "none_axis", scatter_dim=1, n=1)
    np.testing.assert_allclose(np.asarray(h), np.asarray(g), rtol=1e-6)


def test_tile_matmul_grad_matches_einsum():
    from repro.kernels.ring_matmul import tile_matmul

    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(k1, (8, 12), jnp.float32)
    w = jax.random.normal(k2, (12, 16), jnp.float32)
    g = jax.grad(lambda a, b: tile_matmul(a, b).sum(), argnums=(0, 1))(x, w)
    gr = jax.grad(lambda a, b: (a @ b).sum(), argnums=(0, 1))(x, w)
    for got, want in zip(g, gr):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# In-process: "fused" mode plumbing
# ---------------------------------------------------------------------------


def test_fused_mode_in_lattice():
    from repro.core.overlap import MODES, check_mode

    assert MODES == ("none", "ring", "bidir", "fused")
    assert check_mode("fused") == "fused"


def test_parallel_config_accepts_fused():
    from repro.config import ParallelConfig
    from repro.parallel.context import PCtx

    assert ParallelConfig(overlap="fused").overlap == "fused"
    pctx = PCtx(mesh=None, pcfg=ParallelConfig(overlap="fused"))
    assert pctx.overlap == "fused"


def test_mesh_none_ignores_fused():
    from repro.core import hecaton as H

    x = jnp.ones((2, 4, 8), jnp.float32)
    w = jnp.ones((8, 6), jnp.float32)
    y = H.linear_seq_scatter(x, w, mesh=None, t_ax="mx", h_ax="my",
                             overlap="fused")
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-6)


def test_remote_dma_shim():
    from repro import compat

    # the container has no TPU: the fused kernels must pick the
    # ppermute-emulated interpret path
    assert compat.remote_dma_supported() is False


# ---------------------------------------------------------------------------
# In-process: overlap-aware comm model (Table III extension)
# ---------------------------------------------------------------------------


def test_overlap_comm_model_monotone():
    from benchmarks.comm_model import (OVERLAP_EFF, effective_bandwidth,
                                       exposed_comm, overlap_rows)

    assert set(OVERLAP_EFF) == {"none", "ring", "bidir", "fused"}
    comm, compute = 1.0, 10.0
    exp = [exposed_comm(comm, compute, m)
           for m in ("none", "ring", "bidir", "fused")]
    assert exp[0] == comm                       # bulk: fully exposed
    assert exp[0] > exp[1] > exp[2] > exp[3] > 0
    # compute-bound hiding saturates: tiny compute exposes almost everything
    assert exposed_comm(1.0, 0.01, "fused") == pytest.approx(0.99)
    assert effective_bandwidth(64e9, comm, compute, "fused") > 64e9
    assert effective_bandwidth(64e9, comm, compute, "none") == 64e9
    rows = overlap_rows()
    by_mode = {r["mode"]: r for r in rows if r["workload"] == "llama3.1-405b"}
    assert by_mode["fused"]["latency"] <= by_mode["ring"]["latency"] \
        <= by_mode["none"]["latency"]
