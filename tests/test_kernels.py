"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU).

Per assignment: for each kernel, sweep shapes/dtypes and assert_allclose
against the ref.py oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention as FA
from repro.kernels import matmul as MM
from repro.kernels import ref as R
from repro.kernels import ssd as SSD

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (256, 512, 128),
                                   (128, 256, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["none", "gelu", "relu2"])
def test_matmul_sweep(M, K, N, dtype, act):
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = jax.random.normal(k1, (M, K), dtype)
    w = (jax.random.normal(k2, (K, N), jnp.float32) / np.sqrt(K)).astype(dtype)
    b = jax.random.normal(k3, (N,), dtype)
    y = MM.matmul(x, w, b, act=act, block_m=128, block_n=128, block_k=128,
                  interpret=True)
    ref = R.matmul_ref(x, w, b, act=act)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_gated_matmul():
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = jax.random.normal(k1, (256, 256), jnp.float32)
    w1 = jax.random.normal(k2, (256, 128), jnp.float32) / 16
    w1b = jax.random.normal(k3, (256, 128), jnp.float32) / 16
    y = MM.gated_matmul(x, w1, w1b, act="silu", block_k=128, interpret=True)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(R.gated_matmul_ref(x, w1, w1b)),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,nh,nkv,S,dh", [(1, 4, 4, 128, 64),
                                           (2, 4, 2, 256, 64),
                                           (1, 8, 1, 256, 32)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, nh, nkv, S, dh, causal, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, nh, S, dh), dtype)
    k = jax.random.normal(k2, (B, nkv, S, dh), dtype)
    v = jax.random.normal(k3, (B, nkv, S, dh), dtype)
    o = FA.flash_attention(q, k, v, causal=causal, block_q=128, block_k=128,
                           interpret=True)
    ref = R.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref, np.float32),
                               **(_tol(dtype) if dtype == jnp.bfloat16
                                  else dict(rtol=2e-3, atol=2e-3)))


@pytest.mark.parametrize("b,S,nh,dh,g,ds,chunk", [
    (1, 64, 2, 16, 1, 8, 16), (2, 128, 4, 32, 2, 16, 32),
    (1, 256, 2, 64, 1, 64, 64)])
def test_ssd_kernel_sweep(b, S, nh, dh, g, ds, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, S, nh, dh), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, nh), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,), jnp.float32) * 0.5)
    B = jax.random.normal(ks[3], (b, S, g, ds), jnp.float32)
    C = jax.random.normal(ks[4], (b, S, g, ds), jnp.float32)
    y = SSD.ssd(x, dt, A, B, C, chunk=chunk, interpret=True)
    ref = R.ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=5e-4, atol=5e-4)


def test_ssd_chunked_jnp_matches_sequential():
    """The model's chunked-scan path == sequential recurrence oracle."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(KEY, 5)
    b, S, nh, dh, g, ds = 2, 96, 4, 16, 2, 8
    x = jax.random.normal(ks[0], (b, S, nh, dh), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, nh), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,), jnp.float32) * 0.5)
    B = jax.random.normal(ks[3], (b, S, g, ds), jnp.float32)
    C = jax.random.normal(ks[4], (b, S, g, ds), jnp.float32)
    y, fin = ssd_chunked(x, dt, A, B, C, chunk=32)
    ref = R.ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=5e-4,
                               atol=5e-4)
    assert fin.shape == (b, nh, dh, ds)


def test_ssd_decode_matches_chunked():
    """Streaming decode over the same tokens == chunked forward."""
    from repro.models.ssm import ssd_chunked, ssd_decode_step
    ks = jax.random.split(KEY, 5)
    b, S, nh, dh, g, ds = 1, 16, 2, 8, 1, 4
    x = jax.random.normal(ks[0], (b, S, nh, dh), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, nh), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,), jnp.float32) * 0.5)
    B = jax.random.normal(ks[3], (b, S, g, ds), jnp.float32)
    C = jax.random.normal(ks[4], (b, S, g, ds), jnp.float32)
    y_ref, _ = ssd_chunked(x, dt, A, B, C, chunk=8)
    h = jnp.zeros((b, nh, dh, ds), jnp.float32)
    ys = []
    for t in range(S):
        y, h = ssd_decode_step(h, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        ys.append(y)
    y_dec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_ref),
                               rtol=5e-4, atol=5e-4)
