"""Per-architecture smoke tests: every assigned arch instantiates its REDUCED
config and runs one forward + one train step on CPU, asserting output shapes
and finite values (assignment requirement)."""

import jax
import jax.numpy as jnp
import pytest

from repro.config import (ParallelConfig, RunConfig, get_config,
                          get_smoke_config, list_archs, shape_cells_for)
from repro.models import lm
from repro.optim import adamw
from repro.parallel.context import PCtx
from repro.train import step as TS

ARCHS = [a for a in list_archs() if not a.startswith("paper-")]
PCTX = PCtx(mesh=None, pcfg=ParallelConfig(data=1, model=1, mx=1, my=1))


def _batch(cfg, B=2, S=16, with_dtype=True):
    b = {"tokens": jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % 7,
         "labels": jnp.ones((B, S), jnp.int32)}
    if with_dtype:
        b["_dtype"] = jnp.float32
    if cfg.family == "vlm":
        b["patches"] = jnp.full((B, cfg.frontend_stub_len, cfg.d_model), 0.01,
                                jnp.float32)
    if cfg.family == "audio":
        b["frames"] = jnp.full((B, cfg.frontend_stub_len, cfg.d_model), 0.01,
                               jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    out = lm.forward(PCTX, cfg, params, _batch(cfg, B, S))
    assert out.logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(out.logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_smoke_config(arch)
    rc = RunConfig("t", "train", 16, 2, lr=1e-3)
    pcfg = ParallelConfig(strategy="hecaton", data=1, model=1, mx=1, my=1,
                          microbatches=2)
    ts = TS.build_train_step(cfg, pcfg, rc, None, compute_dtype=jnp.float32)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    p2, o2, m = jax.jit(ts)(params, opt, _batch(cfg, with_dtype=False))
    assert bool(jnp.isfinite(m["loss"]))
    assert int(o2.step) == 1
    # params actually changed
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), params, p2))
    assert max(moved) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """Exact assigned hyper-parameters (see assignment table)."""
    expect = {
        "mamba2-130m": dict(num_layers=24, d_model=768, vocab_size=50_280),
        "qwen3-0.6b": dict(num_layers=28, d_model=1024, num_heads=16,
                           num_kv_heads=8, d_ff=3072, vocab_size=151_936),
        "nemotron-4-340b": dict(num_layers=96, d_model=18_432, num_heads=96,
                                num_kv_heads=8, d_ff=73_728,
                                vocab_size=256_000),
        "granite-34b": dict(num_layers=88, d_model=6144, num_heads=48,
                            num_kv_heads=1, d_ff=24_576, vocab_size=49_152),
        "minicpm3-4b": dict(num_layers=62, d_model=2560, num_heads=40,
                            d_ff=6400, vocab_size=73_448),
        "paligemma-3b": dict(num_layers=18, d_model=2048, num_heads=8,
                             num_kv_heads=1, d_ff=16_384, vocab_size=257_216),
        "whisper-small": dict(num_layers=12, d_model=768, num_heads=12,
                              d_ff=3072, vocab_size=51_865, encoder_layers=12),
        "granite-moe-3b-a800m": dict(num_layers=32, d_model=1536,
                                     num_heads=24, num_kv_heads=8, d_ff=512,
                                     vocab_size=49_155),
        "grok-1-314b": dict(num_layers=64, d_model=6144, num_heads=48,
                            num_kv_heads=8, d_ff=32_768, vocab_size=131_072),
        "zamba2-1.2b": dict(num_layers=38, d_model=2048, num_heads=32,
                            num_kv_heads=32, d_ff=8192, vocab_size=32_000),
    }[arch]
    cfg = get_config(arch)
    for k, v in expect.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    if arch == "granite-moe-3b-a800m":
        assert cfg.moe.num_experts == 40 and cfg.moe.top_k == 8
    if arch == "grok-1-314b":
        assert cfg.moe.num_experts == 8 and cfg.moe.top_k == 2
    if arch == "mamba2-130m":
        assert cfg.ssm.state_dim == 128
    if arch == "zamba2-1.2b":
        assert cfg.ssm.state_dim == 64 and cfg.num_shared_attn_sets == 2
    if arch == "minicpm3-4b":
        assert cfg.mla is not None


def test_long_500k_skip_policy():
    """long_500k runs only for sub-quadratic archs (docs/DESIGN.md §4)."""
    for arch in ARCHS:
        cfg = get_config(arch)
        cells = shape_cells_for(cfg)
        if cfg.family in ("ssm", "hybrid"):
            assert "long_500k" in cells, arch
        else:
            assert "long_500k" not in cells, arch


def test_param_counts_plausible():
    """Analytic parameter counts are within the advertised ballpark."""
    expect_range = {
        "mamba2-130m": (0.09e9, 0.2e9),
        "qwen3-0.6b": (0.4e9, 0.9e9),
        "nemotron-4-340b": (300e9, 380e9),
        "granite-34b": (30e9, 40e9),
        "minicpm3-4b": (3e9, 5e9),
        "paligemma-3b": (2e9, 4e9),          # decoder backbone only
        "grok-1-314b": (290e9, 340e9),
        "zamba2-1.2b": (0.9e9, 1.6e9),
    }
    for arch, (lo, hi) in expect_range.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
