"""Checkpoint subsystem tests (ISSUE 4): async/sync equivalence, crash
atomicity, GC under in-flight saves, error propagation, abort fencing, and
the manifest encoding (keys with ``__`` / ``/``, bf16 leaves).  The
kill-mid-write and elastic-grid acceptance checks run in a subprocess
(tests/_mp/check_checkpoint.py)."""

import os
import subprocess
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.checkpoint.manager as M
from repro.checkpoint.manager import (AsyncCheckpointManager,
                                      CheckpointManager, make_manager)
from repro.config import CheckpointConfig

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

STATE = {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                    "scale": jnp.float32(2.5)},
         "opt_state": [jnp.zeros((4,), jnp.int32),
                       {"mu": jnp.ones((3, 4)) * 0.25}]}


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.asarray(x).dtype == np.asarray(y).dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# subprocess acceptance (kill-mid-write + elastic grids)
# ---------------------------------------------------------------------------

def test_checkpoint_mp_acceptance():
    """Kill between save_async and writer completion never publishes the
    half-written step and resumes bit-exact from the previous published one;
    elastic restore onto 1x8/2x4/4x2 grids is a bit-exact fold resume."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable,
                        os.path.join(ROOT, "tests", "_mp",
                                     "check_checkpoint.py")],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, \
        f"\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "ALL CHECKPOINT CHECKS PASSED" in r.stdout


# ---------------------------------------------------------------------------
# async == sync, non-blocking, backpressure
# ---------------------------------------------------------------------------

def test_async_save_equals_sync_save_bit_for_bit(tmp_path):
    sync = CheckpointManager(str(tmp_path / "sync"))
    asyn = AsyncCheckpointManager(str(tmp_path / "async"))
    sync.save(7, STATE, extra_meta={"tag": "x"})
    asyn.save_async(7, STATE, extra_meta={"tag": "x"})
    asyn.wait_until_finished()
    d1, d2 = (os.path.join(m.dir, "step_00000007") for m in (sync, asyn))
    assert sorted(os.listdir(d1)) == sorted(os.listdir(d2))
    for fn in os.listdir(d1):
        with open(os.path.join(d1, fn), "rb") as f1, \
                open(os.path.join(d2, fn), "rb") as f2:
            assert f1.read() == f2.read(), fn
    _leaves_equal(asyn.restore(STATE)[0], STATE)
    asyn.close()


def test_save_async_does_not_block_on_serialization(tmp_path, monkeypatch):
    """The step boundary pays only the host snapshot: with serialization
    gated on an event, save_async must return while the writer is stuck."""
    gate = threading.Event()
    orig = M.np.save

    def gated_save(*a, **k):
        gate.wait(timeout=30)
        return orig(*a, **k)

    monkeypatch.setattr(M.np, "save", gated_save)
    mgr = AsyncCheckpointManager(str(tmp_path), max_inflight=1)
    t0 = time.time()
    mgr.save_async(1, STATE)
    assert time.time() - t0 < 5           # returned with the writer gated
    assert mgr.all_steps() == []          # nothing published yet
    gate.set()
    mgr.wait_until_finished()
    assert mgr.all_steps() == [1]
    mgr.close()


def test_save_async_backpressure_bounds_inflight(tmp_path, monkeypatch):
    """With max_inflight=1 and the writer gated, a second save_async must
    block (bounded staging arena) instead of queueing unboundedly."""
    gate = threading.Event()
    orig = M.np.save
    monkeypatch.setattr(M.np, "save",
                        lambda *a, **k: (gate.wait(timeout=30),
                                         orig(*a, **k))[1])
    mgr = AsyncCheckpointManager(str(tmp_path), max_inflight=1)
    mgr.save_async(1, STATE)
    blocked = threading.Event()

    def second():
        mgr.save_async(2, STATE)      # must block on the arena slot
        blocked.set()

    t = threading.Thread(target=second, daemon=True)
    t.start()
    assert not blocked.wait(timeout=0.3)  # still waiting while gated
    gate.set()
    assert blocked.wait(timeout=30)
    mgr.wait_until_finished()
    assert mgr.all_steps() == [1, 2]
    mgr.close()


def test_async_snapshot_is_decoupled_from_later_mutation(tmp_path,
                                                         monkeypatch):
    """The staging arena owns the bytes: mutating the source array after
    save_async (stand-in for a donated buffer being reused by the next step)
    must not corrupt the checkpoint."""
    gate = threading.Event()
    orig = M.np.save
    monkeypatch.setattr(M.np, "save",
                        lambda *a, **k: (gate.wait(timeout=30),
                                         orig(*a, **k))[1])
    src = np.arange(8.0)
    mgr = AsyncCheckpointManager(str(tmp_path))
    mgr.save_async(1, {"w": src})
    src[:] = -1.0                         # "donated" memory reused
    gate.set()
    mgr.wait_until_finished()
    restored, _ = mgr.restore({"w": jnp.zeros(8)})
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(8.0))
    mgr.close()


# ---------------------------------------------------------------------------
# GC, atomicity debris, abort, errors
# ---------------------------------------------------------------------------

def test_gc_honors_keep_with_inflight_async_saves(tmp_path):
    mgr = AsyncCheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4, 5):
        mgr.save_async(s, STATE)
    mgr.wait_until_finished()
    assert mgr.all_steps() == [4, 5]
    mgr.close()


def test_stale_tmp_never_listed_and_swept_on_init(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, STATE)
    debris = tmp_path / "step_00000009.tmp"
    debris.mkdir()
    (debris / "leaf_00000.npy").write_bytes(b"garbage")
    assert mgr.all_steps() == [5]         # never listed
    assert mgr.latest_step() == 5
    mgr2 = CheckpointManager(str(tmp_path))   # next incarnation sweeps
    assert not debris.exists()
    assert mgr2.all_steps() == [5]


def test_abort_discards_queued_saves_keeps_published(tmp_path, monkeypatch):
    gate = threading.Event()
    orig = M.np.save
    monkeypatch.setattr(M.np, "save",
                        lambda *a, **k: (gate.wait(timeout=30),
                                         orig(*a, **k))[1])
    mgr = AsyncCheckpointManager(str(tmp_path), max_inflight=2)
    monkeypatch.undo()
    mgr.save_async(1, STATE)
    mgr.wait_until_finished()             # step 1 published
    monkeypatch.setattr(M.np, "save",
                        lambda *a, **k: (gate.wait(timeout=30),
                                         orig(*a, **k))[1])
    mgr.save_async(2, STATE)              # stuck mid-write
    mgr.save_async(3, STATE)              # queued behind it
    threading.Timer(0.2, gate.set).start()
    mgr.abort()
    assert mgr.all_steps() == [1]         # nothing half-published
    assert not [n for n in os.listdir(str(tmp_path)) if n.endswith(".tmp")]
    monkeypatch.undo()
    mgr.save_async(4, STATE)              # manager survives the abort
    mgr.wait_until_finished()
    assert mgr.all_steps() == [1, 4]
    mgr.close()


def test_abort_clears_sticky_writer_error(tmp_path, monkeypatch):
    """The supervisor's abort fence must clear a dead incarnation's writer
    error along with its in-flight saves — otherwise every restarted
    incarnation re-raises the stale error at its first checkpoint boundary
    and the restart budget burns down on a long-recovered fault."""
    mgr = AsyncCheckpointManager(str(tmp_path))
    monkeypatch.setattr(M.np, "save",
                        lambda *a, **k: (_ for _ in ()).throw(
                            IOError("transient ENOSPC")))
    mgr.save_async(1, STATE)
    with pytest.raises(RuntimeError):
        mgr.wait_until_finished()
    monkeypatch.undo()                    # the "disk" recovered
    mgr.abort()                           # supervisor fences the incarnation
    mgr.save_async(2, STATE)              # next incarnation starts clean
    mgr.wait_until_finished()
    assert mgr.all_steps() == [2]
    mgr.close()


def test_writer_error_is_sticky_and_surfaces(tmp_path, monkeypatch):
    mgr = AsyncCheckpointManager(str(tmp_path))

    def boom(*a, **k):
        raise IOError("disk full")

    monkeypatch.setattr(M.np, "save", boom)
    mgr.save_async(1, STATE)
    with pytest.raises(RuntimeError, match="disk full"):
        mgr.wait_until_finished()
    monkeypatch.undo()
    with pytest.raises(RuntimeError, match="disk full"):
        mgr.save_async(2, STATE)          # sticky until acknowledged
    with pytest.raises(RuntimeError):
        mgr.check_error()
    assert mgr.all_steps() == []          # the failed write left no debris
    assert not [n for n in os.listdir(str(tmp_path)) if n.endswith(".tmp")]


# ---------------------------------------------------------------------------
# manifest encoding: tricky keys, exotic dtypes (deterministic version of the
# hypothesis property in test_properties.py)
# ---------------------------------------------------------------------------

def test_roundtrip_tricky_keys_and_dtypes(tmp_path):
    tree = {
        "a__b": jnp.float32(1.0),              # "__" must not alias a/b
        "a": {"b": jnp.float32(2.0),
              "c%d": jnp.arange(3, dtype=jnp.int32)},
        "a/b": jnp.float32(3.0),               # "/" must not alias nesting
        "bf16": jnp.asarray([1.5, -2.25], jnp.bfloat16),
        "f16": jnp.asarray([0.5], jnp.float16),
        "bool": jnp.asarray([True, False]),
        "list": [jnp.zeros((2, 2)), {"nested": jnp.ones((1,), jnp.int32)}],
    }
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    restored, step = mgr.restore(tree)
    assert step == 1
    _leaves_equal(restored, tree)
    # manifest is complete: one entry per leaf, distinct files
    import json
    with open(os.path.join(str(tmp_path), "step_00000001",
                           "meta.json")) as f:
        meta = json.load(f)
    n_leaves = len(jax.tree_util.tree_leaves(tree))
    assert len(meta["manifest"]) == n_leaves
    files = [v["file"] for v in meta["manifest"].values()]
    assert len(set(files)) == n_leaves


def test_checkpoint_config_validation_and_make_manager(tmp_path):
    ccfg = CheckpointConfig()
    assert ccfg.every == 50 and ccfg.keep == 3 and ccfg.async_
    with pytest.raises(AssertionError):
        CheckpointConfig(every=0)
    with pytest.raises(AssertionError):
        CheckpointConfig(keep=0)
    with pytest.raises(AssertionError):
        CheckpointConfig(staging="device")
    with pytest.raises(AssertionError):
        CheckpointConfig(max_inflight=0)

    m1 = make_manager(str(tmp_path / "a"), CheckpointConfig(async_=False,
                                                            keep=7))
    assert type(m1) is CheckpointManager and m1.keep == 7
    m2 = make_manager(str(tmp_path / "b"), CheckpointConfig(keep=4))
    assert isinstance(m2, AsyncCheckpointManager) and m2.keep == 4
    m3 = make_manager(str(tmp_path / "c"))
    assert type(m3) is CheckpointManager
    m2.close()


def test_staging_sync_degrades_to_blocking_save(tmp_path):
    mgr = AsyncCheckpointManager(str(tmp_path), staging="sync")
    mgr.save_async(3, STATE)              # blocking: published on return
    assert mgr.all_steps() == [3]
    mgr.close()


def test_train_loop_uses_async_path_and_drains(tmp_path):
    """train() must route boundary saves through save_async and drain on
    exit — a gated writer would otherwise leave steps unpublished."""
    from repro.train import loop as train_loop

    calls = []

    class Probe(AsyncCheckpointManager):
        def save_async(self, step, state, extra_meta=None):
            calls.append(step)
            return super().save_async(step, state, extra_meta)

    mgr = Probe(str(tmp_path))

    def ts(params, opt, batch):
        return params, opt, {"loss": jnp.float32(1.0)}

    state = {"params": {"w": jnp.zeros(2)}, "opt_state": {}}
    train_loop.train(ts, state, iter([{}] * 6), num_steps=6, ckpt=mgr,
                     ckpt_every=2, log_every=100, log_fn=lambda *a: None)
    assert calls == [2, 4, 6]
    assert mgr.all_steps() == [2, 4, 6]   # drained before returning
    mgr.close()
