"""Checkpoint subsystem tests (ISSUE 4 + ISSUE 6): async/sync equivalence,
crash atomicity, GC under in-flight saves, error propagation, abort fencing,
the manifest encoding (keys with ``__`` / ``/``, bf16 leaves), and the
multi-writer quorum protocol — per-writer partitioning, torn-step sweeping,
writer-fault injection, and end-to-end corruption detection on restore.  The
kill-mid-write, writer-kill and elastic-grid acceptance checks run in a
subprocess (tests/_mp/check_checkpoint.py)."""

import json
import os
import subprocess
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.checkpoint.manager as M
from repro.checkpoint.manager import (MANIFEST, AsyncCheckpointManager,
                                      CheckpointCorruptionError,
                                      CheckpointManager, QuorumError,
                                      make_manager, partition_shards)
from repro.config import CheckpointConfig

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

STATE = {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                    "scale": jnp.float32(2.5)},
         "opt_state": [jnp.zeros((4,), jnp.int32),
                       {"mu": jnp.ones((3, 4)) * 0.25}]}


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.asarray(x).dtype == np.asarray(y).dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _files_under(d):
    """{relative path: absolute path} for every file under ``d`` (steps are
    directories of per-writer subdirectories now)."""
    out = {}
    for root, _, files in os.walk(d):
        for fn in files:
            p = os.path.join(root, fn)
            out[os.path.relpath(p, d)] = p
    return out


def _manifest_of(mgr, step):
    with open(os.path.join(mgr.dir, f"step_{step:08d}", MANIFEST)) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# subprocess acceptance (kill-mid-write + elastic grids)
# ---------------------------------------------------------------------------

def test_checkpoint_mp_acceptance():
    """Kill between save_async and writer completion never publishes the
    half-written step and resumes bit-exact from the previous published one;
    elastic restore onto 1x8/2x4/4x2 grids is a bit-exact fold resume."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable,
                        os.path.join(ROOT, "tests", "_mp",
                                     "check_checkpoint.py")],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, \
        f"\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "ALL CHECKPOINT CHECKS PASSED" in r.stdout


# ---------------------------------------------------------------------------
# async == sync, non-blocking, backpressure
# ---------------------------------------------------------------------------

def test_async_save_equals_sync_save_bit_for_bit(tmp_path):
    sync = CheckpointManager(str(tmp_path / "sync"))
    asyn = AsyncCheckpointManager(str(tmp_path / "async"))
    sync.save(7, STATE, extra_meta={"tag": "x"})
    asyn.save_async(7, STATE, extra_meta={"tag": "x"})
    asyn.wait_until_finished()
    d1, d2 = (os.path.join(m.dir, "step_00000007") for m in (sync, asyn))
    fa, fb = _files_under(d1), _files_under(d2)
    assert sorted(fa) == sorted(fb)
    for rel in fa:
        with open(fa[rel], "rb") as f1, open(fb[rel], "rb") as f2:
            assert f1.read() == f2.read(), rel
    _leaves_equal(asyn.restore(STATE)[0], STATE)
    asyn.close()


def test_save_async_does_not_block_on_serialization(tmp_path, monkeypatch):
    """The step boundary pays only the host snapshot: with serialization
    gated on an event, save_async must return while the writer is stuck."""
    gate = threading.Event()
    orig = M.np.save

    def gated_save(*a, **k):
        gate.wait(timeout=30)
        return orig(*a, **k)

    monkeypatch.setattr(M.np, "save", gated_save)
    mgr = AsyncCheckpointManager(str(tmp_path), max_inflight=1)
    t0 = time.time()
    mgr.save_async(1, STATE)
    assert time.time() - t0 < 5           # returned with the writer gated
    assert mgr.all_steps() == []          # nothing published yet
    gate.set()
    mgr.wait_until_finished()
    assert mgr.all_steps() == [1]
    mgr.close()


def test_save_async_backpressure_bounds_inflight(tmp_path, monkeypatch):
    """With max_inflight=1 and the writer gated, a second save_async must
    block (bounded staging arena) instead of queueing unboundedly."""
    gate = threading.Event()
    orig = M.np.save
    monkeypatch.setattr(M.np, "save",
                        lambda *a, **k: (gate.wait(timeout=30),
                                         orig(*a, **k))[1])
    mgr = AsyncCheckpointManager(str(tmp_path), max_inflight=1)
    mgr.save_async(1, STATE)
    blocked = threading.Event()

    def second():
        mgr.save_async(2, STATE)      # must block on the arena slot
        blocked.set()

    t = threading.Thread(target=second, daemon=True)
    t.start()
    assert not blocked.wait(timeout=0.3)  # still waiting while gated
    gate.set()
    assert blocked.wait(timeout=30)
    mgr.wait_until_finished()
    assert mgr.all_steps() == [1, 2]
    mgr.close()


def test_async_snapshot_is_decoupled_from_later_mutation(tmp_path,
                                                         monkeypatch):
    """The staging arena owns the bytes: mutating the source array after
    save_async (stand-in for a donated buffer being reused by the next step)
    must not corrupt the checkpoint."""
    gate = threading.Event()
    orig = M.np.save
    monkeypatch.setattr(M.np, "save",
                        lambda *a, **k: (gate.wait(timeout=30),
                                         orig(*a, **k))[1])
    src = np.arange(8.0)
    mgr = AsyncCheckpointManager(str(tmp_path))
    mgr.save_async(1, {"w": src})
    src[:] = -1.0                         # "donated" memory reused
    gate.set()
    mgr.wait_until_finished()
    restored, _ = mgr.restore({"w": jnp.zeros(8)})
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(8.0))
    mgr.close()


# ---------------------------------------------------------------------------
# GC, atomicity debris, abort, errors
# ---------------------------------------------------------------------------

def test_gc_honors_keep_with_inflight_async_saves(tmp_path):
    mgr = AsyncCheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4, 5):
        mgr.save_async(s, STATE)
    mgr.wait_until_finished()
    assert mgr.all_steps() == [4, 5]
    mgr.close()


def test_stale_tmp_never_listed_and_swept_on_init(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, STATE)
    debris = tmp_path / "step_00000009.tmp"
    debris.mkdir()
    (debris / "leaf_00000.npy").write_bytes(b"garbage")
    assert mgr.all_steps() == [5]         # never listed
    assert mgr.latest_step() == 5
    mgr2 = CheckpointManager(str(tmp_path))   # next incarnation sweeps
    assert not debris.exists()
    assert mgr2.all_steps() == [5]


def test_abort_discards_queued_saves_keeps_published(tmp_path, monkeypatch):
    gate = threading.Event()
    orig = M.np.save
    monkeypatch.setattr(M.np, "save",
                        lambda *a, **k: (gate.wait(timeout=30),
                                         orig(*a, **k))[1])
    mgr = AsyncCheckpointManager(str(tmp_path), max_inflight=2)
    monkeypatch.undo()
    mgr.save_async(1, STATE)
    mgr.wait_until_finished()             # step 1 published
    monkeypatch.setattr(M.np, "save",
                        lambda *a, **k: (gate.wait(timeout=30),
                                         orig(*a, **k))[1])
    mgr.save_async(2, STATE)              # stuck mid-write
    mgr.save_async(3, STATE)              # queued behind it
    threading.Timer(0.2, gate.set).start()
    mgr.abort()
    assert mgr.all_steps() == [1]         # nothing half-published
    assert not [n for n in os.listdir(str(tmp_path)) if n.endswith(".tmp")]
    monkeypatch.undo()
    mgr.save_async(4, STATE)              # manager survives the abort
    mgr.wait_until_finished()
    assert mgr.all_steps() == [1, 4]
    mgr.close()


def test_abort_clears_sticky_writer_error(tmp_path, monkeypatch):
    """The supervisor's abort fence must clear a dead incarnation's writer
    error along with its in-flight saves — otherwise every restarted
    incarnation re-raises the stale error at its first checkpoint boundary
    and the restart budget burns down on a long-recovered fault."""
    mgr = AsyncCheckpointManager(str(tmp_path))
    monkeypatch.setattr(M.np, "save",
                        lambda *a, **k: (_ for _ in ()).throw(
                            IOError("transient ENOSPC")))
    mgr.save_async(1, STATE)
    with pytest.raises(RuntimeError):
        mgr.wait_until_finished()
    monkeypatch.undo()                    # the "disk" recovered
    mgr.abort()                           # supervisor fences the incarnation
    mgr.save_async(2, STATE)              # next incarnation starts clean
    mgr.wait_until_finished()
    assert mgr.all_steps() == [2]
    mgr.close()


def test_writer_error_is_sticky_and_surfaces(tmp_path, monkeypatch):
    mgr = AsyncCheckpointManager(str(tmp_path))

    def boom(*a, **k):
        raise IOError("disk full")

    monkeypatch.setattr(M.np, "save", boom)
    mgr.save_async(1, STATE)
    with pytest.raises(RuntimeError, match="disk full"):
        mgr.wait_until_finished()
    monkeypatch.undo()
    with pytest.raises(RuntimeError, match="disk full"):
        mgr.save_async(2, STATE)          # sticky until acknowledged
    with pytest.raises(RuntimeError):
        mgr.check_error()
    assert mgr.all_steps() == []          # the failed write left no debris
    assert not [n for n in os.listdir(str(tmp_path)) if n.endswith(".tmp")]


# ---------------------------------------------------------------------------
# manifest encoding: tricky keys, exotic dtypes (deterministic version of the
# hypothesis property in test_properties.py)
# ---------------------------------------------------------------------------

def test_roundtrip_tricky_keys_and_dtypes(tmp_path):
    tree = {
        "a__b": jnp.float32(1.0),              # "__" must not alias a/b
        "a": {"b": jnp.float32(2.0),
              "c%d": jnp.arange(3, dtype=jnp.int32)},
        "a/b": jnp.float32(3.0),               # "/" must not alias nesting
        "bf16": jnp.asarray([1.5, -2.25], jnp.bfloat16),
        "f16": jnp.asarray([0.5], jnp.float16),
        "bool": jnp.asarray([True, False]),
        "list": [jnp.zeros((2, 2)), {"nested": jnp.ones((1,), jnp.int32)}],
    }
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    restored, step = mgr.restore(tree)
    assert step == 1
    _leaves_equal(restored, tree)
    # global manifest is complete: one entry per leaf, distinct files, and
    # every entry carries the integrity fields the restore verifier needs
    meta = _manifest_of(mgr, 1)
    assert meta["complete"] is True
    n_leaves = len(jax.tree_util.tree_leaves(tree))
    assert len(meta["manifest"]) == n_leaves
    files = [v["file"] for v in meta["manifest"].values()]
    assert len(set(files)) == n_leaves
    for info in meta["manifest"].values():
        assert info["bytes"] > 0 and 0 <= info["crc32"] <= 0xFFFFFFFF


def test_checkpoint_config_validation_and_make_manager(tmp_path):
    ccfg = CheckpointConfig()
    assert ccfg.every == 50 and ccfg.keep == 3 and ccfg.async_
    with pytest.raises(AssertionError):
        CheckpointConfig(every=0)
    with pytest.raises(AssertionError):
        CheckpointConfig(keep=0)
    with pytest.raises(AssertionError):
        CheckpointConfig(staging="device")
    with pytest.raises(AssertionError):
        CheckpointConfig(max_inflight=0)
    with pytest.raises(AssertionError):
        CheckpointConfig(writers=0)
    with pytest.raises(AssertionError):
        CheckpointConfig(writers=2, quorum=3)
    with pytest.raises(AssertionError):
        CheckpointConfig(writers=2, quorum=0)

    m1 = make_manager(str(tmp_path / "a"), CheckpointConfig(async_=False,
                                                            keep=7))
    assert type(m1) is CheckpointManager and m1.keep == 7
    m2 = make_manager(str(tmp_path / "b"), CheckpointConfig(keep=4))
    assert isinstance(m2, AsyncCheckpointManager) and m2.keep == 4
    m3 = make_manager(str(tmp_path / "c"))
    assert type(m3) is CheckpointManager
    m4 = make_manager(str(tmp_path / "d"),
                      CheckpointConfig(async_=False, writers=4, quorum=3,
                                       verify=False))
    assert (m4.writers, m4.quorum, m4.verify) == (4, 3, False)
    m2.close()


def test_staging_sync_degrades_to_blocking_save(tmp_path):
    mgr = AsyncCheckpointManager(str(tmp_path), staging="sync")
    mgr.save_async(3, STATE)              # blocking: published on return
    assert mgr.all_steps() == [3]
    mgr.close()


def test_train_loop_uses_async_path_and_drains(tmp_path):
    """train() must route boundary saves through save_async and drain on
    exit — a gated writer would otherwise leave steps unpublished."""
    from repro.train import loop as train_loop

    calls = []

    class Probe(AsyncCheckpointManager):
        def save_async(self, step, state, extra_meta=None):
            calls.append(step)
            return super().save_async(step, state, extra_meta)

    mgr = Probe(str(tmp_path))

    def ts(params, opt, batch):
        return params, opt, {"loss": jnp.float32(1.0)}

    state = {"params": {"w": jnp.zeros(2)}, "opt_state": {}}
    train_loop.train(ts, state, iter([{}] * 6), num_steps=6, ckpt=mgr,
                     ckpt_every=2, log_every=100, log_fn=lambda *a: None)
    assert calls == [2, 4, 6]
    assert mgr.all_steps() == [2, 4, 6]   # drained before returning
    mgr.close()


# ---------------------------------------------------------------------------
# ISSUE 6: writer-group partitioning, quorum publish, integrity verification
# ---------------------------------------------------------------------------

def test_partition_shards_balanced_deterministic_and_pinned():
    sizes = {"a": 100, "b": 90, "c": 10, "d": 10, "e": 5}
    p1 = partition_shards(sizes, 2)
    p2 = partition_shards(dict(reversed(list(sizes.items()))), 2)
    assert p1 == p2                       # pure function of contents
    assert set(p1) == set(sizes) and set(p1.values()) <= {0, 1}
    loads = [sum(sizes[n] for n, w in p1.items() if w == i) for i in (0, 1)]
    assert max(loads) <= 2 * min(loads)   # greedy byte-balance
    # writer_map pins; out-of-range / None falls back to balancing
    pinned = partition_shards(sizes, 3,
                              writer_map=lambda n: 2 if n == "a" else None)
    assert pinned["a"] == 2
    assert set(pinned.values()) <= {0, 1, 2}


@pytest.mark.parametrize("writers,quorum", [(1, None), (3, None), (4, 2)])
def test_multiwriter_roundtrip_and_layout(tmp_path, writers, quorum):
    """N writers persist disjoint shard sets into per-writer subdirs with
    partial manifests; restore reassembles bit-exact regardless of N."""
    mgr = CheckpointManager(str(tmp_path), writers=writers, quorum=quorum)
    mgr.save(3, STATE, extra_meta={"tag": "x"})
    meta = _manifest_of(mgr, 3)
    assert meta["writers"] == writers
    assert meta["committed"] == list(range(writers))
    owners = {info["writer"] for info in meta["manifest"].values()}
    n_leaves = len(jax.tree_util.tree_leaves(STATE))
    assert owners == set(range(min(writers, n_leaves)))
    for w in range(writers):              # every writer published a partial
        assert os.path.exists(os.path.join(
            mgr.dir, "step_00000003", f"writer_{w:02d}", "manifest.json"))
    restored, step = mgr.restore(STATE)
    assert step == 3
    _leaves_equal(restored, STATE)


def test_multiwriter_more_writers_than_leaves(tmp_path):
    """Zero-shard writers still commit (empty partial manifests): coverage
    comes from the populated ones."""
    mgr = CheckpointManager(str(tmp_path), writers=4)
    mgr.save(1, {"w": jnp.arange(4.0)})
    meta = _manifest_of(mgr, 1)
    assert meta["committed"] == [0, 1, 2, 3]
    _leaves_equal(mgr.restore({"w": jnp.zeros(4)})[0],
                  {"w": jnp.arange(4.0)})


def test_writer_death_in_torn_window_never_publishes(tmp_path):
    """A writer killed after its shard writes but before its partial
    manifest publishes (the writer_fault window) fails the quorum gate:
    the save raises, the torn step is swept, all_steps never lists it."""
    def kill_w1(step, writer):
        if writer == 1:
            raise RuntimeError("injected writer death")

    mgr = CheckpointManager(str(tmp_path), writers=2, writer_fault=kill_w1)
    with pytest.raises(QuorumError, match="injected writer death"):
        mgr.save(5, STATE)
    assert mgr.all_steps() == []
    assert os.listdir(str(tmp_path)) == []    # torn debris swept
    mgr.writer_fault = None                   # writer "replaced"
    mgr.save(6, STATE)
    assert mgr.all_steps() == [6]
    _leaves_equal(mgr.restore(STATE)[0], STATE)


def test_quorum_tolerates_dead_zero_shard_writer_only(tmp_path):
    """quorum < writers publishes through a dead writer IF coverage is
    complete (the dead writer owned no shards); a dead shard-owning writer
    still fails — there is no replication to cover its shards."""
    state = {"w": jnp.arange(4.0)}            # 1 leaf -> writers 1..3 empty

    def kill(step, writer):
        if writer == 3:
            raise RuntimeError("empty writer died")

    mgr = CheckpointManager(str(tmp_path / "a"), writers=4, quorum=3,
                            writer_fault=kill)
    mgr.save(1, state)                        # publishes: coverage intact
    meta = _manifest_of(mgr, 1)
    assert meta["committed"] == [0, 1, 2] and meta["failed_writers"] == [3]
    _leaves_equal(mgr.restore(state)[0], state)

    def kill0(step, writer):
        if writer == 0:
            raise RuntimeError("shard owner died")

    mgr2 = CheckpointManager(str(tmp_path / "b"), writers=4, quorum=3,
                             writer_fault=kill0)
    with pytest.raises(QuorumError, match="shards uncovered"):
        mgr2.save(1, state)
    assert mgr2.all_steps() == []


def test_async_writer_death_sticky_then_fenced(tmp_path):
    """On the async manager a torn save surfaces as the usual sticky error
    and abort() fences it like any other writer failure."""
    boom = {"on": True}

    def kill(step, writer):
        if boom["on"] and writer == 1:
            raise RuntimeError("injected writer death")

    mgr = AsyncCheckpointManager(str(tmp_path), writers=2, writer_fault=kill)
    mgr.save_async(1, STATE)
    with pytest.raises(RuntimeError, match="injected writer death"):
        mgr.wait_until_finished()
    boom["on"] = False
    mgr.abort()
    mgr.save_async(2, STATE)
    mgr.wait_until_finished()
    assert mgr.all_steps() == [2]
    mgr.close()


def test_bitflip_corruption_fails_restore_naming_file(tmp_path):
    """End-to-end integrity: a single flipped bit in one shard file makes
    restore raise CheckpointCorruptionError naming that file; verify=False
    (explicit opt-out) loads the garbage silently."""
    mgr = CheckpointManager(str(tmp_path), writers=2)
    mgr.save(1, STATE)
    meta = _manifest_of(mgr, 1)
    # pick the shard holding params/w and flip one payload bit
    info = meta["manifest"]["params/w"]
    victim = os.path.join(mgr.dir, "step_00000001", info["file"])
    blob = bytearray(open(victim, "rb").read())
    blob[-1] ^= 0x01
    with open(victim, "wb") as f:
        f.write(blob)
    with pytest.raises(CheckpointCorruptionError) as ei:
        mgr.restore(STATE)
    assert info["file"] in str(ei.value) and "crc32" in str(ei.value)
    lax = CheckpointManager(str(tmp_path), writers=2, verify=False)
    lax.restore(STATE)                        # opt-out: no integrity check


def test_truncated_shard_fails_restore_naming_file(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, STATE)
    info = _manifest_of(mgr, 1)["manifest"]["params/w"]
    victim = os.path.join(mgr.dir, "step_00000001", info["file"])
    blob = open(victim, "rb").read()
    with open(victim, "wb") as f:
        f.write(blob[:len(blob) // 2])
    with pytest.raises(CheckpointCorruptionError, match="truncated") as ei:
        mgr.restore(STATE)
    assert info["file"] in str(ei.value)


def test_torn_or_truncated_manifests_exclude_step(tmp_path):
    """Tolerant listing: a step with a truncated global manifest, a step
    caught before its global publish (partial manifests only), and foreign
    files in the root are all skipped by all_steps — and swept (where torn)
    by the next incarnation — without crashing."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, STATE)
    mgr.save(2, STATE)

    # (a) truncate step 2's global manifest mid-"write"
    g2 = os.path.join(mgr.dir, "step_00000002", MANIFEST)
    blob = open(g2, "rb").read()
    with open(g2, "wb") as f:
        f.write(blob[:len(blob) // 3])
    # (b) a torn multi-writer publish: shards + truncated partial manifest,
    # global manifest never written
    torn = tmp_path / "step_00000007" / "writer_00"
    torn.mkdir(parents=True)
    (torn / "leaf_00000.npy").write_bytes(b"\x93NUMPY...")
    (torn / "manifest.json").write_text('{"writer": 0, "shards": {"x"')
    # (c) foreign junk in the checkpoint root
    (tmp_path / "README.txt").write_text("not a checkpoint")
    (tmp_path / "step_junk").mkdir()
    (tmp_path / "step_00000042").write_text("a FILE squatting on the name")

    assert mgr.all_steps() == [1]
    assert mgr.latest_step() == 1
    restored, step = mgr.restore(STATE)       # newest COMPLETE step
    assert step == 1
    _leaves_equal(restored, STATE)

    mgr2 = CheckpointManager(str(tmp_path))   # next incarnation sweeps torn
    assert mgr2.all_steps() == [1]
    assert not (tmp_path / "step_00000007").exists()
    assert not (tmp_path / "step_00000002").exists()
    assert (tmp_path / "README.txt").exists()     # foreign files untouched
    assert (tmp_path / "step_junk").exists()


def test_gc_survives_foreign_files_and_leaves_no_half_steps(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=1)
    (tmp_path / "notes.md").write_text("x")
    for s in (1, 2, 3):
        mgr.save(s, STATE)
    assert mgr.all_steps() == [3]
    leftover = [d for d in os.listdir(str(tmp_path))
                if d.startswith("step_") and not d.endswith(".tmp")]
    assert leftover == ["step_00000003"]      # retired steps fully gone


# ---------------------------------------------------------------------------
# ISSUE 8: manifest type hardening + the wire format
# ---------------------------------------------------------------------------

def test_manifest_complete_tolerates_non_dict_json_bodies(tmp_path):
    """A foreign MANIFEST.json holding a JSON array / string / null parses
    fine but is not a manifest: ``_manifest_complete`` must answer False
    (it used to crash with AttributeError on ``list.get``), ``all_steps``
    must stay tolerant, and the torn dirs must sweep like any debris."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, STATE)
    for step, body in ((2, "[1, 2, 3]"), (3, '"complete"'), (4, "null")):
        d = tmp_path / f"step_{step:08d}"
        d.mkdir()
        (d / MANIFEST).write_text(body)
        assert CheckpointManager._manifest_complete(str(d)) is False, body
    assert mgr.all_steps() == [1]             # no crash, garbage filtered
    restored, step = mgr.restore(STATE)       # newest COMPLETE step wins
    assert step == 1
    _leaves_equal(restored, STATE)
    mgr2 = CheckpointManager(str(tmp_path))   # next incarnation sweeps them
    assert mgr2.all_steps() == [1]
    assert sorted(d for d in os.listdir(str(tmp_path))
                  if d.startswith("step_")) == ["step_00000001"]


def test_restore_non_dict_manifest_is_corruption_not_crash(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, STATE)
    d = tmp_path / "step_00000002"
    d.mkdir()
    (d / MANIFEST).write_text("[]")
    with pytest.raises(CheckpointCorruptionError):
        mgr.restore(STATE, step=2)            # explicit step: typed error


def test_leaf_wire_preserves_zero_dim_and_forces_c_order():
    """The wire lowering must NOT promote 0-d leaves (adamw's ``.step``) —
    ``np.ascontiguousarray`` silently would — and must emit C-order bytes
    for the handover arena."""
    from repro.checkpoint import wire
    wa, info = wire.leaf_wire(np.float32(2.5))
    assert wa.shape == () and info["shape"] == [] and "raw" not in info
    f_arr = np.asfortranarray(np.arange(12.0, dtype=np.float32).reshape(3, 4))
    wa, info = wire.leaf_wire(f_arr)
    assert wa.flags.c_contiguous and info["shape"] == [3, 4]
    np.testing.assert_array_equal(wa, f_arr)


# ---------------------------------------------------------------------------
# ISSUE 8: cross-process writer fleet through the manager API
# ---------------------------------------------------------------------------

def _procs_mgr(d, **kw):
    kw.setdefault("writers", 2)
    kw.setdefault("writer_timeout", 2.0)
    return CheckpointManager(str(d), writer_procs=True, **kw)


def test_procs_tree_bit_identical_to_threads(tmp_path):
    """Same state, same step, writers=2: the fleet's published tree must be
    byte-for-byte the thread writers' tree — same files, same bytes."""
    mt = CheckpointManager(str(tmp_path / "thr"), writers=2)
    mt.save(5, STATE, extra_meta={"tag": "x"})
    mp_ = _procs_mgr(tmp_path / "prc")
    mp_.save(5, STATE, extra_meta={"tag": "x"})
    mp_.close()
    fa = _files_under(os.path.join(mt.dir, "step_00000005"))
    fb = _files_under(os.path.join(mp_.dir, "step_00000005"))
    assert sorted(fa) == sorted(fb)
    for rel in fa:
        with open(fa[rel], "rb") as f1, open(fb[rel], "rb") as f2:
            assert f1.read() == f2.read(), rel
    restored, step = CheckpointManager(mp_.dir, writers=2).restore(STATE)
    assert step == 5
    _leaves_equal(restored, STATE)
    assert ".fleet" not in os.listdir(mp_.dir)     # close() swept scratch


def test_procs_kill9_reassigns_and_publishes_verified(tmp_path):
    """SIGKILL of writer 1's process inside the torn window: the coordinator
    reassigns its range to the survivor and the step still publishes with
    full coverage — the manifest records who was recovered and why."""
    from repro.runtime.fault import FailureInjector
    inj = FailureInjector(proc_fail_at={2: (1, "kill9")})
    mgr = _procs_mgr(tmp_path, proc_fault=inj.proc_fault)
    mgr.save(2, STATE)
    assert inj.log == ["step 2: injected proc fault kill9 into writer 1"]
    meta = _manifest_of(mgr, 2)
    assert meta["complete"] and "-9" in meta["reassigned"]["1"]
    assert set(meta["manifest"]) == set(M._leaf_paths(STATE))  # full coverage
    restored, step = mgr.restore(STATE)
    assert step == 2
    _leaves_equal(restored, STATE)
    mgr.close()


def test_procs_reassign_budget_zero_is_quorum_error(tmp_path):
    """With no reassignment budget a killed writer is a writer failure and
    the quorum gate stays the backstop: nothing publishes, debris sweeps."""
    mgr = _procs_mgr(tmp_path, reassign=0,
                     proc_fault=lambda s, w: ({"kind": "kill9"}
                                              if (s == 2 and w == 1)
                                              else None))
    with pytest.raises(QuorumError):
        mgr.save(2, STATE)
    assert mgr.all_steps() == []
    assert not [n for n in os.listdir(str(tmp_path)) if n.endswith(".tmp")]
    mgr.save(3, STATE)                 # fleet respawns the dead slot
    assert mgr.all_steps() == [3]
    mgr.close()


def test_procs_async_abort_fences_fleet_fast(tmp_path):
    """abort() on the async manager mid-save SIGKILL-fences the fleet in
    bounded time (never waits out a slow child), keeps published steps, and
    leaves a reusable manager."""
    mgr = AsyncCheckpointManager(str(tmp_path), writers=2, writer_procs=True,
                                 writer_timeout=2.0,
                                 proc_fault=lambda s, w:
                                     {"kind": "slow", "seconds": 60.0}
                                     if (s == 2 and w == 1) else None)
    mgr.save_async(1, STATE)
    mgr.wait_until_finished()
    mgr.save_async(2, STATE)           # writer 1 parked for 60s
    deadline = time.monotonic() + 20
    while not os.path.exists(os.path.join(mgr.dir, "step_00000002.tmp")):
        assert time.monotonic() < deadline
        time.sleep(0.01)
    t0 = time.monotonic()
    mgr.abort()
    assert time.monotonic() - t0 < 5.0, "abort must not wait out the child"
    assert mgr.all_steps() == [1]
    names = os.listdir(str(tmp_path))
    assert not [n for n in names if n.endswith(".tmp")], names
    assert ".fleet" not in names, names
    mgr.save_async(3, STATE)           # manager survives its own abort
    mgr.wait_until_finished()
    assert mgr.all_steps() == [1, 3]
    mgr.close()


def test_procs_spill_handover_fallback(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CKPT_HANDOVER", "spill")
    mgr = _procs_mgr(tmp_path)
    mgr.save(4, STATE)
    assert mgr._fleet.handover == "spill"
    restored, step = mgr.restore(STATE)
    assert step == 4
    _leaves_equal(restored, STATE)
    mgr.close()
    assert ".fleet" not in os.listdir(str(tmp_path))


def test_checkpoint_config_procs_flags_and_make_manager(tmp_path):
    ccfg = CheckpointConfig(async_=False, writers=2, writer_procs=True,
                            writer_timeout=1.5, reassign=2)
    mgr = make_manager(str(tmp_path), ccfg)
    assert (mgr.writer_procs, mgr.writer_timeout, mgr.reassign) \
        == (True, 1.5, 2)
    mgr.save(1, STATE)
    _leaves_equal(mgr.restore(STATE)[0], STATE)
    mgr.close()
    with pytest.raises(AssertionError):
        CheckpointConfig(writer_timeout=0.0)
    with pytest.raises(AssertionError):
        CheckpointConfig(reassign=-1)
