"""Inter-pod 1F1B pipeline (ISSUE 5): pure-Python schedule properties,
config validation, bubble-aware microbatch choice, stage partitioning.

Device numerics (2-pod CPU grids vs single-pod baseline) run in a
subprocess: tests/_mp/check_pipeline.py.
"""

import os
import subprocess
import sys

import pytest

from repro.config import ModelConfig, ParallelConfig
from repro.core import schedule as SCH
from repro.core import theory as TH
from repro.parallel import pipeline as PP

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# schedule properties (no devices)
# ---------------------------------------------------------------------------

GRID = [(2, 2), (2, 4), (2, 8), (3, 3), (4, 2), (4, 8), (4, 16), (8, 8)]


@pytest.mark.parametrize("p,m", GRID)
def test_makespan_and_bubble_count(p, m):
    s = PP.schedule_1f1b(p, m)
    assert s.makespan == 2 * (m + p - 1)
    for stage in range(p):
        assert s.bubble_ticks(stage) == 2 * (p - 1)


@pytest.mark.parametrize("p,m", GRID)
def test_bubble_fraction_matches_theory(p, m):
    """Acceptance: simulated bubble == (p-1)/(m+p-1) (core/theory.py)."""
    s = PP.schedule_1f1b(p, m)
    assert abs(s.bubble_fraction - TH.pipeline_bubble_fraction(p, m)) < 1e-12


@pytest.mark.parametrize("p,m", GRID)
def test_stage_order_warmup_steady_cooldown(p, m):
    for stage in range(p):
        order = PP.stage_order(stage, p, m)
        kinds = [t.kind for t in order]
        assert len(order) == 2 * m
        w = min(p - 1 - stage, m)
        # warmup: w forwards
        assert kinds[:w] == ["F"] * w
        # steady: strict F,B alternation
        steady = kinds[w:w + 2 * (m - w)]
        assert steady == ["F", "B"] * (m - w)
        # cooldown: drain the warmed-up backwards
        assert kinds[w + 2 * (m - w):] == ["B"] * w
        # each microbatch exactly once per direction, F before its B
        fs = [t.mb for t in order if t.kind == "F"]
        bs = [t.mb for t in order if t.kind == "B"]
        assert fs == list(range(m)) and bs == list(range(m))
        for i in range(m):
            assert order.index(PP.PipeTask("F", i)) < \
                order.index(PP.PipeTask("B", i))


@pytest.mark.parametrize("p,m", GRID)
def test_schedule_dependencies_and_in_flight(p, m):
    s = PP.schedule_1f1b(p, m)
    done = {}
    for t, row in enumerate(s.ticks):
        for stage, task in enumerate(row):
            if task is None:
                continue
            if task.kind == "F" and stage > 0:
                assert done[("F", stage - 1, task.mb)] < t
            if task.kind == "B" and stage < p - 1:
                assert done[("B", stage + 1, task.mb)] < t
            if task.kind == "B":
                assert done[("F", stage, task.mb)] < t or p == 1
            done[(task.kind, stage, task.mb)] = t
    # every op executed exactly once
    assert len(done) == 2 * p * m
    # 1F1B memory bound: min(p - s, m) in-flight microbatches at stage s
    for stage in range(p):
        assert s.peak_in_flight(stage) == min(p - stage, m)


def test_schedule_degenerate():
    s = PP.schedule_1f1b(1, 3)
    assert s.makespan == 6 and s.bubble_fraction == 0.0
    assert PP.schedule_1f1b(1, 1).makespan == 2


# ---------------------------------------------------------------------------
# config validation (the old silent no-op)
# ---------------------------------------------------------------------------

def test_pipeline_role_requires_multiple_pods():
    with pytest.raises(ValueError, match="pods > 1"):
        ParallelConfig(data=1, model=1, mx=1, my=1,
                       pod_axis_role="pipeline", pods=1)


def test_bad_pod_axis_role_rejected():
    with pytest.raises(ValueError, match="pod_axis_role"):
        ParallelConfig(data=1, model=1, mx=1, my=1, pod_axis_role="bogus")


def test_pipeline_enabled_properties():
    p = ParallelConfig(data=1, model=1, mx=1, my=1, pods=2,
                       pod_axis_role="pipeline")
    assert p.pipeline_enabled and p.pipeline_stages == 2
    d = ParallelConfig(data=1, model=1, mx=1, my=1, pods=2)
    assert not d.pipeline_enabled and d.pipeline_stages == 1


def test_build_train_step_rejects_pipeline_config():
    from repro.config import RunConfig
    from repro.train import step as TS
    pcfg = ParallelConfig(data=1, model=1, mx=1, my=1, pods=2,
                          pod_axis_role="pipeline")
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=8,
                      num_heads=2, num_kv_heads=2, d_ff=16, vocab_size=32)
    rc = RunConfig("t", "train", 8, 4)
    with pytest.raises(ValueError, match="pipeline"):
        TS.build_train_step(cfg, pcfg, rc, None)


def test_validate_pipeline_unsupported_models():
    pcfg = ParallelConfig(data=1, model=1, mx=1, my=1, pods=2,
                          pod_axis_role="pipeline")
    tied = ModelConfig(name="t", family="dense", num_layers=4, d_model=8,
                       num_heads=2, num_kv_heads=2, d_ff=16, vocab_size=32,
                       tie_embeddings=True)
    with pytest.raises(ValueError, match="tie_embeddings"):
        PP.validate_pipeline(tied, pcfg)
    ssm = ModelConfig(name="t", family="ssm", num_layers=4, d_model=8,
                      num_heads=2, num_kv_heads=2, d_ff=16, vocab_size=32,
                      block_pattern=("mamba",) * 4)
    with pytest.raises(ValueError, match="attention"):
        PP.validate_pipeline(ssm, pcfg)
    odd = ModelConfig(name="t", family="dense", num_layers=5, d_model=8,
                      num_heads=2, num_kv_heads=2, d_ff=16, vocab_size=32)
    with pytest.raises(ValueError, match="divide"):
        PP.validate_pipeline(odd, pcfg)
    # vlm passes the pattern check but needs patch injection + prefix loss
    # mask the stage runner doesn't do — must raise, not silently mistrain
    vlm = ModelConfig(name="t", family="vlm", num_layers=4, d_model=8,
                      num_heads=2, num_kv_heads=2, d_ff=16, vocab_size=32,
                      frontend_stub_len=4)
    with pytest.raises(ValueError, match="token-only"):
        PP.validate_pipeline(vlm, pcfg)


def test_split_stage_layers():
    assert [list(r) for r in PP.split_stage_layers(8, 2)] == \
        [[0, 1, 2, 3], [4, 5, 6, 7]]
    with pytest.raises(ValueError):
        PP.split_stage_layers(6, 4)


# ---------------------------------------------------------------------------
# bubble-aware microbatch choice
# ---------------------------------------------------------------------------

def test_min_microbatches_for_bubble():
    # (p-1)/(m+p-1) <= f  <=>  m >= (p-1)(1-f)/f
    assert SCH.min_microbatches_for_bubble(1, 0.25) == 1
    for p in (2, 4, 8):
        m = SCH.min_microbatches_for_bubble(p, 0.25)
        assert TH.pipeline_bubble_fraction(p, m) <= 0.25
        assert TH.pipeline_bubble_fraction(p, m - 1) > 0.25 or m == 1


def test_choose_microbatches_bubble_aware():
    kw = dict(seq_len=128, d_model=256, n_data_shards=1, n_token_shards=4,
              num_layers=4, vocab=1024, act_budget_bytes=1e9)
    n1, r1 = SCH.choose_microbatches(64, n_stages=1, **kw)
    n4, r4 = SCH.choose_microbatches(64, n_stages=4, max_bubble=0.2, **kw)
    assert r1 == r4
    assert n4 >= n1
    assert TH.pipeline_bubble_fraction(4, n4) <= 0.2
    assert 64 % n4 == 0          # still divides the per-shard batch
    # the floor cannot exceed the per-shard batch
    n_small, _ = SCH.choose_microbatches(2, n_stages=8, max_bubble=0.05,
                                         **kw)
    assert n_small <= 2


# ---------------------------------------------------------------------------
# stage partitioning
# ---------------------------------------------------------------------------

def test_stage_params_roundtrip():
    import jax
    import numpy as np
    from repro.models import lm
    cfg = ModelConfig(name="t", family="dense", num_layers=4, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=32)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    stages = [PP.stage_params(params, cfg, s, 2) for s in range(2)]
    assert "embed" in stages[0] and "embed" not in stages[1]
    assert "lm_head" in stages[1] and "lm_head" not in stages[0]
    assert "final_norm" in stages[1]
    merged = PP.merge_stage_grads(stages, cfg)
    for (kp, want), (kp2, got) in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_flatten_with_path(merged)[0]):
        assert kp == kp2
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


# ---------------------------------------------------------------------------
# device numerics (subprocess, fake 8-device topology)
# ---------------------------------------------------------------------------

def test_pipeline_numerics():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, os.path.join(ROOT, "tests", "_mp",
                                                     "check_pipeline.py")],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, \
        f"\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "ALL PIPELINE CHECKS PASSED" in r.stdout
