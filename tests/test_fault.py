"""Unit tests for the fault-tolerance runtime (runtime/fault.py) — the test
file its docstring has always advertised: FailureInjector determinism,
StepTimer straggler detection (EWMA freeze while slow, streak reset),
rebalance_data_shards edge cases, and run_supervised restart accounting
(including the async-checkpoint abort fence).  End-to-end restart behaviour
lives in tests/test_system.py and examples/elastic_restart.py."""

import pytest

from repro.runtime.fault import (FailureInjector, Incarnation, StepTimer,
                                 rebalance_data_shards, run_supervised)


# ---------------------------------------------------------------------------
# FailureInjector
# ---------------------------------------------------------------------------

def test_injector_fails_each_step_exactly_once():
    inj = FailureInjector({3: "chip down", 7: "host unreachable"})
    inj.check(0)
    inj.check(2)
    with pytest.raises(RuntimeError, match="chip down"):
        inj.check(3)
    inj.check(3)                      # popped: a restart re-runs step 3 fine
    with pytest.raises(RuntimeError, match="host unreachable"):
        inj.check(7)
    assert inj.log == ["step 3: injected chip down",
                       "step 7: injected host unreachable"]
    assert inj.fail_at == {}


# ---------------------------------------------------------------------------
# StepTimer
# ---------------------------------------------------------------------------

def test_steptimer_first_sample_seeds_ewma():
    t = StepTimer()
    assert t.record(1.0) is False
    assert t.ewma == 1.0


def test_steptimer_ewma_freezes_while_slow():
    """Outlier steps must NOT be folded into the EWMA — otherwise a sustained
    straggler drags the baseline up and masks itself."""
    t = StepTimer(alpha=0.5, straggler_factor=2.0, patience=3)
    t.record(1.0)
    for _ in range(2):
        assert t.record(10.0) is False
    assert t.ewma == 1.0              # frozen through the slow streak
    assert t.record(10.0) is True     # patience reached
    assert t.ewma == 1.0
    assert t.slow_streak == 0         # reset after the event fires
    assert len(t.events) == 1


def test_steptimer_fast_step_resets_streak_and_updates_ewma():
    t = StepTimer(alpha=0.5, straggler_factor=2.0, patience=3)
    t.record(1.0)
    t.record(10.0)
    t.record(10.0)                    # streak = 2, one short of patience
    assert t.record(1.2) is False     # healthy step: streak resets
    assert t.slow_streak == 0
    assert t.ewma == pytest.approx(1.1)   # 0.5*1.0 + 0.5*1.2
    assert t.record(10.0) is False    # streak restarts from scratch
    assert t.slow_streak == 1
    assert t.events == []


def test_steptimer_borderline_step_is_not_slow():
    t = StepTimer(alpha=0.5, straggler_factor=2.5, patience=1)
    t.record(1.0)
    assert t.record(2.5) is False     # exactly at factor*ewma: not an outlier
    assert t.ewma == pytest.approx(1.75)


# ---------------------------------------------------------------------------
# rebalance_data_shards
# ---------------------------------------------------------------------------

def test_rebalance_moves_one_shard_to_least_loaded_healthy_host():
    out = rebalance_data_shards(4, [1], shards_per_host=[2, 2, 1, 2])
    assert out == [2, 1, 2, 2]        # host 2 was least loaded
    assert sum(out) == 7


def test_rebalance_all_hosts_slow_is_a_noop():
    shards = [1, 2, 3]
    out = rebalance_data_shards(3, [0, 1, 2], shards_per_host=shards)
    assert out == shards              # nowhere healthy to move work
    assert out is not shards          # but never aliases the input


def test_rebalance_zero_shard_straggler_is_skipped():
    out = rebalance_data_shards(3, [0], shards_per_host=[0, 2, 2])
    assert out == [0, 2, 2]           # nothing to take from an empty host


def test_rebalance_multiple_stragglers_conserve_shards():
    out = rebalance_data_shards(5, [0, 1])
    assert sum(out) == 5
    assert out[0] == 0 and out[1] == 0
    assert sorted(out[2:]) == [1, 2, 2]


# ---------------------------------------------------------------------------
# run_supervised
# ---------------------------------------------------------------------------

class _FlakyRun:
    """Raises on the first ``fails`` invocations, then succeeds."""

    def __init__(self, fails):
        self.fails = fails
        self.calls = 0

    def __call__(self, state, start, inc):
        self.calls += 1
        if self.calls <= self.fails:
            raise RuntimeError(f"boom {self.calls}")
        return {"done": True, "inc": inc}


def test_run_supervised_counts_incarnations_and_restarts():
    restarts = []
    run = _FlakyRun(fails=2)
    state, incarnations = run_supervised(
        lambda _: ({}, 0), run, max_restarts=5,
        on_restart=restarts.append)
    assert state["done"] and incarnations == 3
    assert [i.index for i in restarts] == [1, 2]
    assert all(isinstance(i, Incarnation) for i in restarts)


def test_run_supervised_exhaustion_raises():
    run = _FlakyRun(fails=100)
    with pytest.raises(RuntimeError, match="exceeded 2 restarts"):
        run_supervised(lambda _: ({}, 0), run, max_restarts=2)
    assert run.calls == 3             # initial attempt + 2 restarts


def test_run_supervised_zero_restarts_budget():
    with pytest.raises(RuntimeError, match="exceeded 0 restarts"):
        run_supervised(lambda _: ({}, 0), _FlakyRun(fails=1), max_restarts=0)


def test_run_supervised_non_runtime_errors_propagate():
    """Only RuntimeError (real/injected chip+host failures) is supervised;
    programming errors must surface immediately, not burn restarts."""
    def run(state, start, inc):
        raise ValueError("bug, not a fault")
    with pytest.raises(ValueError):
        run_supervised(lambda _: ({}, 0), run)


class _FakeAsyncCkpt:
    def __init__(self):
        self.aborts = 0

    def abort(self):
        self.aborts += 1


def test_run_supervised_aborts_inflight_saves_per_failure():
    """The supervisor fences async persistence: every dead incarnation gets
    its in-flight saves aborted BEFORE the next make_state restores."""
    ckpt = _FakeAsyncCkpt()
    order = []

    def make_state(_):
        order.append(("make", ckpt.aborts))
        return {}, 0

    state, incarnations = run_supervised(
        make_state, _FlakyRun(fails=2), max_restarts=5, ckpt=ckpt)
    assert incarnations == 3
    assert ckpt.aborts == 2
    # each restore happened only after the preceding failure was fenced
    assert order == [("make", 0), ("make", 1), ("make", 2)]


def test_run_supervised_aborts_on_exhaustion_too():
    ckpt = _FakeAsyncCkpt()
    with pytest.raises(RuntimeError, match="exceeded"):
        run_supervised(lambda _: ({}, 0), _FlakyRun(fails=100),
                       max_restarts=1, ckpt=ckpt)
    assert ckpt.aborts == 2           # fenced even when giving up
