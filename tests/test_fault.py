"""Unit tests for the fault-tolerance runtime (runtime/fault.py) — the test
file its docstring has always advertised: FailureInjector determinism
(whole-incarnation and per-writer), StepTimer straggler detection (EWMA
freeze while slow, streak reset), rebalance_data_shards edge cases, and
run_supervised restart accounting (exception supervision classes, capped
exponential backoff, the async-checkpoint abort fence).  End-to-end restart
behaviour lives in tests/test_system.py and examples/elastic_restart.py."""

import pytest

from repro.runtime.fault import (FailureInjector, Incarnation, StepTimer,
                                 rebalance_data_shards, run_supervised)

NO_SLEEP = {"sleep_fn": lambda _: None}    # keep unit tests instant


# ---------------------------------------------------------------------------
# FailureInjector
# ---------------------------------------------------------------------------

def test_injector_fails_each_step_exactly_once():
    inj = FailureInjector({3: "chip down", 7: "host unreachable"})
    inj.check(0)
    inj.check(2)
    with pytest.raises(RuntimeError, match="chip down"):
        inj.check(3)
    inj.check(3)                      # popped: a restart re-runs step 3 fine
    with pytest.raises(RuntimeError, match="host unreachable"):
        inj.check(7)
    assert inj.log == ["step 3: injected chip down",
                       "step 7: injected host unreachable"]
    assert inj.fail_at == {}


def test_injector_writer_kill_is_one_shot_and_targeted():
    """check_writer (the manager's writer_fault hook) kills exactly the
    configured writer of the configured step's save, exactly once — the
    retried save after a restart must go through."""
    inj = FailureInjector(writer_fail_at={4: 1})
    inj.check(4)                      # whole-incarnation path is untouched
    inj.check_writer(4, 0)            # other writers of the group survive
    with pytest.raises(RuntimeError, match="writer 1 died at step 4"):
        inj.check_writer(4, 1)
    inj.check_writer(4, 1)            # popped: the retry publishes
    inj.check_writer(5, 1)            # other steps never fail
    assert inj.writer_fail_at == {}
    assert inj.log == ["step 4: injected writer 1 death"]


# ---------------------------------------------------------------------------
# StepTimer
# ---------------------------------------------------------------------------

def test_steptimer_first_sample_seeds_ewma():
    t = StepTimer(warmup_steps=0)
    assert t.record(1.0) is False
    assert t.ewma == 1.0


def test_steptimer_warmup_discards_compile_spike():
    """Default warmup discards the first sample entirely: a 100x JIT-compile
    step 0 must not seed the EWMA (it would mask real stragglers for a long
    decay window — the cold-start regression)."""
    t = StepTimer(alpha=0.5, straggler_factor=2.0, patience=1)
    assert t.record(100.0) is False   # compile-dominated: discarded
    assert t.ewma is None
    assert t.record(1.0) is False     # first post-warmup sample seeds
    assert t.ewma == 1.0
    assert t.record(3.0) is True      # 3x a sane baseline fires immediately
    assert len(t.events) == 1


def test_steptimer_no_warmup_compile_spike_masks_stragglers():
    """The regression the warmup exists for: seeding with the compile step
    makes a genuinely 3x-slow step invisible."""
    t = StepTimer(alpha=0.5, straggler_factor=2.0, patience=1,
                  warmup_steps=0)
    t.record(100.0)                   # poisons the baseline
    assert t.record(3.0) is False     # straggler hides under the 100s EWMA
    assert t.events == []


def test_steptimer_warmup_discards_exactly_n_samples():
    t = StepTimer(warmup_steps=3)
    for dt in (50.0, 40.0, 30.0):
        assert t.record(dt) is False
        assert t.ewma is None
    t.record(1.0)
    assert t.ewma == 1.0


def test_steptimer_ewma_freezes_while_slow():
    """Outlier steps must NOT be folded into the EWMA — otherwise a sustained
    straggler drags the baseline up and masks itself."""
    t = StepTimer(alpha=0.5, straggler_factor=2.0, patience=3,
                  warmup_steps=0)
    t.record(1.0)
    for _ in range(2):
        assert t.record(10.0) is False
    assert t.ewma == 1.0              # frozen through the slow streak
    assert t.record(10.0) is True     # patience reached
    assert t.ewma == 1.0
    assert t.slow_streak == 0         # reset after the event fires
    assert len(t.events) == 1


def test_steptimer_fast_step_resets_streak_and_updates_ewma():
    t = StepTimer(alpha=0.5, straggler_factor=2.0, patience=3,
                  warmup_steps=0)
    t.record(1.0)
    t.record(10.0)
    t.record(10.0)                    # streak = 2, one short of patience
    assert t.record(1.2) is False     # healthy step: streak resets
    assert t.slow_streak == 0
    assert t.ewma == pytest.approx(1.1)   # 0.5*1.0 + 0.5*1.2
    assert t.record(10.0) is False    # streak restarts from scratch
    assert t.slow_streak == 1
    assert t.events == []


def test_steptimer_borderline_step_is_not_slow():
    t = StepTimer(alpha=0.5, straggler_factor=2.5, patience=1,
                  warmup_steps=0)
    t.record(1.0)
    assert t.record(2.5) is False     # exactly at factor*ewma: not an outlier
    assert t.ewma == pytest.approx(1.75)


# ---------------------------------------------------------------------------
# rebalance_data_shards
# ---------------------------------------------------------------------------

def test_rebalance_moves_one_shard_to_least_loaded_healthy_host():
    out = rebalance_data_shards(4, [1], shards_per_host=[2, 2, 1, 2])
    assert out == [2, 1, 2, 2]        # host 2 was least loaded
    assert sum(out) == 7


def test_rebalance_all_hosts_slow_is_a_noop():
    shards = [1, 2, 3]
    out = rebalance_data_shards(3, [0, 1, 2], shards_per_host=shards)
    assert out == shards              # nowhere healthy to move work
    assert out is not shards          # but never aliases the input


def test_rebalance_zero_shard_straggler_is_skipped():
    out = rebalance_data_shards(3, [0], shards_per_host=[0, 2, 2])
    assert out == [0, 2, 2]           # nothing to take from an empty host


def test_rebalance_multiple_stragglers_conserve_shards():
    out = rebalance_data_shards(5, [0, 1])
    assert sum(out) == 5
    assert out[0] == 0 and out[1] == 0
    assert sorted(out[2:]) == [1, 2, 2]


# ---------------------------------------------------------------------------
# run_supervised
# ---------------------------------------------------------------------------

class _FlakyRun:
    """Raises on the first ``fails`` invocations, then succeeds."""

    def __init__(self, fails):
        self.fails = fails
        self.calls = 0

    def __call__(self, state, start, inc):
        self.calls += 1
        if self.calls <= self.fails:
            raise RuntimeError(f"boom {self.calls}")
        return {"done": True, "inc": inc}


def test_run_supervised_counts_incarnations_and_restarts():
    restarts = []
    run = _FlakyRun(fails=2)
    state, incarnations = run_supervised(
        lambda _: ({}, 0), run, max_restarts=5,
        on_restart=restarts.append, **NO_SLEEP)
    assert state["done"] and incarnations == 3
    assert [i.index for i in restarts] == [1, 2]
    assert all(isinstance(i, Incarnation) for i in restarts)


def test_run_supervised_exhaustion_raises():
    run = _FlakyRun(fails=100)
    with pytest.raises(RuntimeError, match="exceeded 2 restarts"):
        run_supervised(lambda _: ({}, 0), run, max_restarts=2, **NO_SLEEP)
    assert run.calls == 3             # initial attempt + 2 restarts


def test_run_supervised_zero_restarts_budget():
    with pytest.raises(RuntimeError, match="exceeded 0 restarts"):
        run_supervised(lambda _: ({}, 0), _FlakyRun(fails=1), max_restarts=0,
                       **NO_SLEEP)


def test_run_supervised_supervises_any_exception():
    """A dead filesystem raises OSError, jax raises ValueError-ish runtime
    errors — at cluster scale those are incarnation deaths, and the
    supervisor must restart through them, not die on the first one."""
    for exc in (OSError("EIO: checkpoint fs gone"),
                ValueError("jax runtime broke")):
        calls = {"n": 0}

        def run(state, start, inc):
            calls["n"] += 1
            if calls["n"] == 1:
                raise exc
            return {"done": True}

        state, incarnations = run_supervised(lambda _: ({}, 0), run,
                                             **NO_SLEEP)
        assert state["done"] and incarnations == 2


def test_run_supervised_non_retryable_errors_propagate():
    """KeyboardInterrupt is the operator; AssertionError is an invariant
    violation a restart would just re-trip.  Both escape immediately with
    zero restarts burned (and zero backoff slept)."""
    for exc_type in (KeyboardInterrupt, AssertionError):
        calls = {"n": 0}
        slept = []

        def run(state, start, inc):
            calls["n"] += 1
            raise exc_type("stop")

        with pytest.raises(exc_type):
            run_supervised(lambda _: ({}, 0), run, sleep_fn=slept.append)
        assert calls["n"] == 1 and slept == []


def test_run_supervised_backoff_is_exponential_and_capped():
    """Restart delays follow base * 2^k, clamped at the cap — never a
    hot-loop against a recovering filesystem."""
    slept = []
    with pytest.raises(RuntimeError, match="exceeded 5 restarts"):
        run_supervised(lambda _: ({}, 0), _FlakyRun(fails=100),
                       max_restarts=5, backoff_base=0.5, backoff_cap=3.0,
                       sleep_fn=slept.append)
    assert slept == [0.5, 1.0, 2.0, 3.0, 3.0]   # capped at 3.0
    # no sleep after the final (budget-exhausting) failure
    assert len(slept) == 5


class _FakeAsyncCkpt:
    def __init__(self):
        self.aborts = 0

    def abort(self):
        self.aborts += 1


def test_run_supervised_aborts_inflight_saves_per_failure():
    """The supervisor fences async persistence: every dead incarnation gets
    its in-flight saves aborted BEFORE the next make_state restores."""
    ckpt = _FakeAsyncCkpt()
    order = []

    def make_state(_):
        order.append(("make", ckpt.aborts))
        return {}, 0

    state, incarnations = run_supervised(
        make_state, _FlakyRun(fails=2), max_restarts=5, ckpt=ckpt,
        **NO_SLEEP)
    assert incarnations == 3
    assert ckpt.aborts == 2
    # each restore happened only after the preceding failure was fenced
    assert order == [("make", 0), ("make", 1), ("make", 2)]


def test_run_supervised_aborts_on_exhaustion_too():
    ckpt = _FakeAsyncCkpt()
    with pytest.raises(RuntimeError, match="exceeded"):
        run_supervised(lambda _: ({}, 0), _FlakyRun(fails=100),
                       max_restarts=1, ckpt=ckpt, **NO_SLEEP)
    assert ckpt.aborts == 2           # fenced even when giving up


def test_run_supervised_divergence_rollback_policy(tmp_path):
    """A DivergenceError(rollback=True) triggers the full rollback policy in
    order: fence the writer group, retire checkpoints newer than the first
    poisoned step, publish the poison window to blocklist.json — then the
    next incarnation restores.  A plain RuntimeError must NOT roll back."""
    from repro.runtime.guard import DivergenceError, load_blocklist

    class _RollbackCkpt(_FakeAsyncCkpt):
        def __init__(self, d):
            super().__init__()
            self.dir = str(d)
            self.retired = []

        def retire_steps_after(self, step):
            self.retired.append(("after-abort" if self.aborts else "early",
                                 step))

    ckpt = _RollbackCkpt(tmp_path)
    calls = {"n": 0}

    def run_steps(state, start, inc):
        calls["n"] += 1
        if calls["n"] == 1:
            raise DivergenceError("poison", kind="loss_spike", first_step=17,
                                  data_indices=(17, 18))
        if calls["n"] == 2:
            raise RuntimeError("ordinary death")    # no rollback for this
        return state

    _, incarnations = run_supervised(lambda _: ({}, 0), run_steps,
                                     max_restarts=4, ckpt=ckpt, **NO_SLEEP)
    assert incarnations == 3
    assert ckpt.aborts == 2                   # both deaths fenced
    assert ckpt.retired == [("after-abort", 17)]   # only the divergence
    assert load_blocklist(str(tmp_path)) == [17, 18]


def test_run_supervised_divergence_no_rollback_flag(tmp_path):
    """rollback=False (the --no-rollback policy) restarts WITHOUT retiring
    or blocklisting."""
    from repro.runtime.guard import DivergenceError, load_blocklist

    class _RollbackCkpt(_FakeAsyncCkpt):
        dir = str(tmp_path)

        def retire_steps_after(self, step):
            raise AssertionError("must not retire with rollback=False")

    fails = {"n": 0}

    def run_steps(state, start, inc):
        if not fails["n"]:
            fails["n"] = 1
            raise DivergenceError("poison", kind="skip_cap", first_step=3,
                                  data_indices=(3,), rollback=False)
        return state

    _, incarnations = run_supervised(lambda _: ({}, 0), run_steps,
                                     max_restarts=2, ckpt=_RollbackCkpt(),
                                     **NO_SLEEP)
    assert incarnations == 2
    assert load_blocklist(str(tmp_path)) == []


def test_supervised_writer_kill_end_to_end(tmp_path):
    """The full ISSUE 6 story in-process: an injected single-writer death
    fails the save at the quorum gate (QuorumError is a RuntimeError — a
    supervised fault), the incarnation dies at that boundary, the
    supervisor fences the writer group, and the restart resumes from the
    last quorum step and republishes the torn one.  (The async-manager
    variant of this scenario runs in the subprocess harness,
    tests/_mp/check_checkpoint.py.)"""
    import jax.numpy as jnp

    from repro.checkpoint.manager import CheckpointManager
    from repro.train import loop as train_loop

    inj = FailureInjector(writer_fail_at={4: 1})   # kill writer 1 of step 4
    mgr = CheckpointManager(str(tmp_path), writers=2)

    def ts(params, opt, batch):
        return {"w": params["w"] + 1.0}, opt, {"loss": jnp.float32(0.0)}

    def make_state(_):
        state = {"params": {"w": jnp.zeros(3)}, "opt_state": {}}
        start = 0
        if mgr.latest_step() is not None:
            state, start = mgr.restore(state)
        return state, start

    def run_steps(state, start, inc):
        return train_loop.train(ts, state, iter([{}] * 8), start_step=start,
                                num_steps=8, ckpt=mgr, ckpt_every=2,
                                log_every=100, injector=inj,
                                log_fn=lambda *a: None)

    state, incarnations = run_supervised(make_state, run_steps, ckpt=mgr,
                                         **NO_SLEEP)
    assert incarnations == 2
    assert inj.log == ["step 4: injected writer 1 death"]
    # torn step 4 republished by the restart; GC (keep=3) retired step 2
    assert mgr.all_steps() == [4, 6, 8]
    import numpy as np
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  np.full(3, 8.0))
    mgr.close()


# ---------------------------------------------------------------------------
# ISSUE 8: process-level fault specs + resume-step pinning
# ---------------------------------------------------------------------------

def test_injector_proc_fault_is_one_shot_and_targeted():
    """proc_fault (the manager's process-fleet hook) ships the spec dict to
    exactly the configured writer of the configured step, exactly once —
    the retried save after a restart must run clean."""
    inj = FailureInjector(proc_fail_at={4: (1, "slow", {"seconds": 2.0}),
                                        6: (0, "kill9")})
    assert inj.proc_fault(4, 0) is None         # other writers untouched
    assert inj.proc_fault(3, 1) is None         # other steps untouched
    assert inj.proc_fault(4, 1) == {"kind": "slow", "seconds": 2.0}
    assert inj.proc_fault(4, 1) is None         # popped: the retry is clean
    assert inj.proc_fault(6, 0) == {"kind": "kill9"}
    assert inj.proc_fail_at == {}
    assert inj.log == [
        "step 4: injected proc fault slow into writer 1",
        "step 6: injected proc fault kill9 into writer 0",
    ]


def test_injector_proc_fault_rejects_unknown_kind():
    with pytest.raises(AssertionError, match="nuke"):
        FailureInjector(proc_fail_at={1: (0, "nuke")})


def test_run_supervised_pins_resume_step_to_post_fence_view():
    """make_state must receive the step published BEFORE the crash, read
    once after the fence — not None (the old drift: the supervisor never
    passed anything but None, so restores raced concurrent listers)."""
    class _Ckpt(_FakeAsyncCkpt):
        def __init__(self):
            super().__init__()
            self.published = [2]

        def latest_step(self):
            return self.published[-1] if self.published else None

    ckpt = _Ckpt()
    seen, calls = [], {"n": 0}

    def make_state(resume_step):
        seen.append(resume_step)
        return {}, 0

    def run(state, start, inc):
        calls["n"] += 1
        if calls["n"] == 1:
            ckpt.published.append(4)   # publish, then die
            raise RuntimeError("dead after publishing 4")
        return {"done": True}

    state, incarnations = run_supervised(make_state, run, ckpt=ckpt,
                                         **NO_SLEEP)
    assert state["done"] and incarnations == 2
    assert seen == [None, 4]           # cold start, then the pinned step


def test_run_supervised_rollback_resume_step_is_post_retire(tmp_path):
    """With a DivergenceError rollback, the pin is read AFTER
    retire_steps_after ran: the restart resumes from the newest SURVIVING
    step, never a retired (poisoned) one."""
    from repro.runtime.guard import DivergenceError

    class _Ckpt(_FakeAsyncCkpt):
        def __init__(self, d):
            super().__init__()
            self.dir = str(d)
            self.published = [2, 4, 6]

        def retire_steps_after(self, step):
            self.published = [s for s in self.published if s <= step]

        def latest_step(self):
            return self.published[-1] if self.published else None

    ckpt = _Ckpt(tmp_path)
    seen, calls = [], {"n": 0}

    def make_state(resume_step):
        seen.append(resume_step)
        return {}, 0

    def run(state, start, inc):
        calls["n"] += 1
        if calls["n"] == 1:
            raise DivergenceError("poison", kind="loss_spike", first_step=5,
                                  data_indices=(5,))
        return {"done": True}

    state, incarnations = run_supervised(make_state, run, ckpt=ckpt,
                                         max_restarts=2, **NO_SLEEP)
    assert state["done"] and incarnations == 2
    assert ckpt.published == [2, 4]    # 6 was saved from poisoned state
    assert seen == [None, 4]           # pinned to the post-retire survivor
