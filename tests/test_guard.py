"""Unit tests for the self-healing runtime (runtime/guard.py + the guarded
optimizer path in optim/adamw.py, docs/DESIGN.md §8): GuardConfig
validation, the in-graph skip-update predicate (NaN/Inf anywhere -> skip,
skipped state bit-unchanged, spike vs EWMA), TrainingGuard loss-spike /
skip-cap streaks, the Watchdog, blocklist sidecar helpers + the step->data
index mapping, and CheckpointManager.retire_steps_after.  End-to-end
injected-failure scenarios live in tests/_mp/check_guard.py."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import GuardConfig, RunConfig
from repro.optim import adamw
from repro.runtime import guard as G

RC = RunConfig("t", "train", 16, 8, lr=2e-3)
GC = GuardConfig()


def _tree():
    k = jax.random.PRNGKey(0)
    return {"a": jax.random.normal(k, (4, 8), jnp.float32),
            "b": {"w": jax.random.normal(jax.random.PRNGKey(1), (3,),
                                         jnp.float32)}}


def _grads(scale=0.1):
    return jax.tree.map(lambda p: jnp.full_like(p, scale), _tree())


def _bits_equal(t1, t2):
    return all(np.array_equal(np.asarray(a), np.asarray(b), equal_nan=True)
               for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)))


# ---------------------------------------------------------------------------
# GuardConfig validation
# ---------------------------------------------------------------------------

def test_guardconfig_defaults_valid():
    g = GuardConfig()
    assert g.grad_spike_factor > 1 and g.loss_spike_factor > 1
    assert g.rollback


@pytest.mark.parametrize("kw", [
    {"grad_spike_factor": 1.0}, {"loss_spike_factor": 0.5},
    {"grad_ewma_alpha": 0.0}, {"loss_ewma_alpha": 1.5},
    {"patience": 0}, {"skip_cap": 0}, {"hang_timeout": -1.0},
])
def test_guardconfig_rejects_bad_values(kw):
    with pytest.raises(AssertionError):
        GuardConfig(**kw)


# ---------------------------------------------------------------------------
# Guarded optimizer update (in-graph defense)
# ---------------------------------------------------------------------------

def test_guarded_update_matches_unguarded_when_ok():
    params, grads = _tree(), _grads()
    st = adamw.init(params)
    p1, s1, m1 = adamw.update(params, grads, st, RC)
    p2, s2, m2 = adamw.update(params, grads, st, RC, guard=GC)
    assert _bits_equal(p1, p2)
    assert _bits_equal(s1.mu, s2.mu) and _bits_equal(s1.nu, s2.nu)
    assert int(s2.step) == 1
    assert float(m2["update_ok"]) == 1.0
    assert float(m2["update_skipped"]) == 0.0


@pytest.mark.parametrize("bad", [jnp.nan, jnp.inf, -jnp.inf])
def test_nonfinite_grad_skips_bit_unchanged(bad):
    params = _tree()
    st = adamw.init(params)
    # seed the EWMA with one healthy step first
    params, st, _ = adamw.update(params, _grads(), st, RC, guard=GC)
    grads = _grads()
    grads["b"]["w"] = grads["b"]["w"].at[1].set(bad)   # one poison element
    p2, s2, m = adamw.update(params, grads, st, RC, guard=GC)
    assert float(m["update_skipped"]) == 1.0
    assert float(m["nonfinite"]) == 1.0
    assert _bits_equal(p2, params)
    assert _bits_equal(s2.mu, st.mu) and _bits_equal(s2.nu, st.nu)
    assert int(s2.step) == int(st.step)                # counter frozen
    assert float(s2.gnorm_ewma) == float(st.gnorm_ewma)  # baseline frozen


def test_norm_spike_skips_but_finite():
    params = _tree()
    st = adamw.init(params)
    params, st, _ = adamw.update(params, _grads(0.1), st, RC, guard=GC)
    # 1000x the seeded norm blows past grad_spike_factor=10
    p2, s2, m = adamw.update(params, _grads(100.0), st, RC, guard=GC)
    assert float(m["update_skipped"]) == 1.0
    assert float(m["nonfinite"]) == 0.0               # finite, just spiking
    assert _bits_equal(p2, params)


def test_unseeded_ewma_accepts_any_norm():
    """First step after init (ewma=0 sentinel) must accept — there is no
    baseline to spike against."""
    params = _tree()
    st = adamw.init(params)
    _, s2, m = adamw.update(params, _grads(100.0), st, RC, guard=GC)
    assert float(m["update_ok"]) == 1.0
    assert float(s2.gnorm_ewma) > 0.0                 # norm seeded it


def test_ewma_folds_only_accepted_norms():
    params = _tree()
    st = adamw.init(params)
    _, s1, _ = adamw.update(params, _grads(0.1), st, RC, guard=GC)
    seeded = float(s1.gnorm_ewma)
    _, s2, _ = adamw.update(params, _grads(100.0), s1, RC, guard=GC)
    assert float(s2.gnorm_ewma) == seeded             # skip froze the EWMA
    _, s3, m3 = adamw.update(params, _grads(0.11), s2, RC, guard=GC)
    assert float(m3["update_ok"]) == 1.0
    assert float(s3.gnorm_ewma) != seeded             # accepted step folds


def test_guard_predicate_jits_without_retrace():
    """Data-only poison must not retrace the jitted step — the predicate is
    a traced select, not Python control flow."""
    params = _tree()
    st = adamw.init(params)
    traces = {"n": 0}

    @jax.jit
    def step(p, s, g):
        traces["n"] += 1
        return adamw.update(p, g, s, RC, guard=GC)

    p, s, _ = step(params, st, _grads(0.1))
    p, s, m = step(p, s, _grads(jnp.nan))
    p, s, m2 = step(p, s, _grads(0.1))
    assert traces["n"] == 1
    assert float(m["update_skipped"]) == 1.0
    assert float(m2["update_skipped"]) == 0.0


# ---------------------------------------------------------------------------
# TrainingGuard (loop-side escalation)
# ---------------------------------------------------------------------------

def _tg(**kw):
    base = dict(loss_spike_factor=1.5, patience=2, skip_cap=3)
    base.update(kw)
    return G.TrainingGuard(GuardConfig(**base))


def test_training_guard_healthy_run_never_raises():
    tg = _tg()
    for s in range(50):
        tg.observe(s, 1.0 - s * 0.01)
    assert tg.spike_streak == 0 and tg.events == []


def test_training_guard_loss_spike_raises_with_window():
    tg = _tg()
    tg.observe(0, 1.0)
    tg.observe(1, 1.0)
    tg.observe(2, 9.0)                       # streak 1
    with pytest.raises(G.DivergenceError) as ei:
        tg.observe(3, 9.5)                   # streak 2 = patience
    e = ei.value
    assert e.kind == "loss_spike"
    assert e.first_step == 2
    assert e.data_indices == (2, 3)
    assert e.rollback


def test_training_guard_ewma_frozen_while_spiking():
    """A spike must not normalize itself into the baseline."""
    tg = _tg(patience=5)
    tg.observe(0, 1.0)
    tg.observe(1, 9.0)
    assert tg.loss_ewma == 1.0               # frozen
    tg.observe(2, 1.0)                       # healthy: streak resets, folds
    assert tg.spike_streak == 0
    assert tg.loss_ewma == pytest.approx(1.0)


def test_training_guard_nonfinite_loss_counts_as_spike():
    tg = _tg(patience=1)
    tg.observe(0, 1.0)
    with pytest.raises(G.DivergenceError):
        tg.observe(1, float("nan"))


def test_training_guard_skip_cap():
    tg = _tg(skip_cap=2, patience=99)
    tg.observe(0, 1.0)
    tg.observe(1, float("nan"), {"update_skipped": 1.0})
    with pytest.raises(G.DivergenceError) as ei:
        tg.observe(2, float("nan"), {"update_skipped": 1.0})
    assert ei.value.kind == "skip_cap"
    assert ei.value.data_indices == (1, 2)
    assert tg.loss_ewma == 1.0               # skipped losses never folded


def test_training_guard_reports_data_indices_not_steps():
    """Under a blocklist the loop step != data index; the poison window must
    carry batch_at indices."""
    tg = _tg()
    tg.observe(0, 1.0, data_index=0)
    tg.observe(16, 9.0, data_index=19)
    with pytest.raises(G.DivergenceError) as ei:
        tg.observe(17, 9.0, data_index=20)
    assert ei.value.first_step == 16
    assert ei.value.data_indices == (19, 20)


def test_training_guard_spike_detection_monotone_in_factor():
    """A loss flagged at factor f is flagged at every f' < f."""
    losses = [1.0, 1.2, 2.9, 3.1]
    fired = []
    for f in (1.2, 2.0, 2.8):
        tg = _tg(loss_spike_factor=f, patience=1)
        try:
            for s, l in enumerate(losses):
                tg.observe(s, l)
            fired.append(None)
        except G.DivergenceError as e:
            fired.append(e.first_step)
    assert fired == sorted(fired, key=lambda x: (x is None, x))
    assert fired[0] is not None              # tightest factor fires first


# ---------------------------------------------------------------------------
# Watchdog
# ---------------------------------------------------------------------------

def test_watchdog_fast_steps_never_trip():
    wd = G.Watchdog(0.5, poll=0.01)
    try:
        for s in range(5):
            wd.arm(s)
            time.sleep(0.01)
            wd.disarm()
            wd.check()
    finally:
        wd.close()


def test_watchdog_trips_on_hung_step_and_clears():
    wd = G.Watchdog(0.05, poll=0.01)
    try:
        wd.arm(7)
        time.sleep(0.2)                      # the "hang"
        wd.disarm()
        assert wd.tripped
        with pytest.raises(G.HangError) as ei:
            wd.check()
        assert ei.value.step == 7
        assert ei.value.elapsed > ei.value.timeout == 0.05
        wd.check()                           # trip cleared: next arm is clean
        wd.arm(8)
        time.sleep(0.01)
        wd.disarm()
        wd.check()
    finally:
        wd.close()


def test_watchdog_on_hang_fires_during_the_hang():
    """The escalation callback must fire while the step is STILL hung — that
    is the only defense against a step that never returns."""
    fired = []
    wd = G.Watchdog(0.05, poll=0.01, on_hang=lambda s, el: fired.append(s))
    try:
        wd.arm(3)
        deadline = time.time() + 2.0
        while not fired and time.time() < deadline:
            time.sleep(0.01)                 # "hung": never disarms
        assert fired == [3]
    finally:
        wd.close()


def test_watchdog_disarmed_never_trips():
    wd = G.Watchdog(0.02, poll=0.01)
    try:
        time.sleep(0.1)                      # idle (between steps): no arm
        assert not wd.tripped
    finally:
        wd.close()


# ---------------------------------------------------------------------------
# Blocklist sidecar + index mapping
# ---------------------------------------------------------------------------

def test_blocklist_roundtrip_and_merge(tmp_path):
    d = str(tmp_path)
    assert G.load_blocklist(d) == []
    assert G.publish_blocklist(d, [18, 17]) == [17, 18]
    assert G.load_blocklist(d) == [17, 18]
    # second incident merges, deduped
    assert G.publish_blocklist(d, [18, 40]) == [17, 18, 40]
    assert G.load_blocklist(d) == [17, 18, 40]


def test_blocklist_missing_and_torn_are_empty(tmp_path):
    assert G.load_blocklist(None) == []
    assert G.load_blocklist(str(tmp_path / "nope")) == []
    p = tmp_path / G.BLOCKLIST
    p.write_text("{torn")
    assert G.load_blocklist(str(tmp_path)) == []


def test_data_index_mapping():
    assert [G.data_index(s, []) for s in range(5)] == [0, 1, 2, 3, 4]
    bl = [17, 18]
    assert [G.data_index(s, bl) for s in (16, 17, 18, 19)] == [16, 19, 20, 21]
    assert G.data_index(0, [0]) == 1         # blocklisted head shifts all
    # unsorted input handled: non-blocklisted = [0, 3, 4, 6, ...], s=3 -> 6
    assert G.data_index(3, [1, 5, 2]) == 6


def test_data_index_skips_exactly_the_blocklist():
    """The mapped stream is the clean stream with blocklisted indices
    dropped — the identity the bit-exactness tests rely on."""
    bl = [2, 5, 6, 11]
    mapped = [G.data_index(s, bl) for s in range(10)]
    expect = [i for i in range(20) if i not in bl][:10]
    assert mapped == expect


def test_blocklisted_stream_yields_filtered_batches():
    got = list()
    stream = G.blocklisted_stream(lambda i: i * 10, 1, [2, 3])
    for _ in range(4):
        got.append(next(stream))
    assert got == [10, 40, 50, 60]


# ---------------------------------------------------------------------------
# Checkpoint retirement (rollback's first half)
# ---------------------------------------------------------------------------

def test_retire_steps_after(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep=10)
    state = {"w": np.arange(4.0, dtype=np.float32)}
    for s in (2, 4, 6, 8):
        mgr.save(s, state)
    assert mgr.all_steps() == [2, 4, 6, 8]
    assert mgr.retire_steps_after(4) == [6, 8]
    assert mgr.all_steps() == [2, 4]
    # idempotent; no-op when nothing newer
    assert mgr.retire_steps_after(4) == []
    restored, step = mgr.restore({"w": state["w"]})
    assert step == 4
    assert mgr.retire_steps_after(0) == [2, 4]
    assert mgr.all_steps() == []
