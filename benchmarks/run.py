"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract).

  comm_model    — Fig. 8 / Table III latency+energy comparison (4 methods)
                  + per-overlap-mode exposed-NoP theory (effective bandwidth)
                  + inter-pod 1F1B pipeline theory (``theory_pipeline_*``
                  rows: bubble fraction vs the simulated schedule, boundary
                  transfer exposure)
  scaling       — Fig. 9 weak scaling
  dram          — Fig. 10 DRAM-bandwidth sweep
  layout        — Fig. 11 die-layout study
  link_latency  — Table IV link-latency proportion
  micro         — kernel reference micro-benchmarks (host wall time)
  hlo_compare   — measured collective bytes hecaton vs megatron (compiled HLO)
                  + per-overlap-mode collective-permute vs bulk AG/RS bytes
                  for the hecaton FFN, MoE and megatron paths
  overlap       — wall time bulk vs ring vs bidir vs fused collective matmuls
                  (CPU mesh; fused runs the interpret-emulated kernel path)
  ckpt_stall    — checkpoint-boundary step-time stall, blocking vs async
                  double-buffered saves (ISSUE 4 acceptance rows), plus the
                  multi-writer save-time sweep over writers in {1, 2, 4}
                  (``ckpt_multiwriter_*`` rows, ISSUE 6)

Besides the CSV, the harness persists ``BENCH_overlap.json`` next to the repo
root: per-mode step times from ``benchmarks/overlap.py``, the micro matmul
rows, the overlap-aware comm-model theory (bf16 and int8 wire), the
per-residual-layout HLO bulk bytes (``hlo_compare.run_residual``), the
int8-vs-bf16 wire byte counts (``quant_bytes``, ``hlo_compare.run_quant``),
and the OVERLAP_EFF table *calibrated*
from the measured step times (``comm_model.fit_overlap_eff``) — one file per
run so the perf trajectory is tracked across PRs (CI uploads it as an
artifact and smoke-checks the residual-layout section).

``--calibrate BENCH_overlap.json`` skips the benchmarks and only (re)fits the
per-mode overlap efficiencies from the step times already recorded in the
given file, persisting ``calibrated_overlap_eff`` + the recomputed
``theory_overlap_calibrated`` rows in place.
"""
import argparse
import json
import os

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_overlap.json")


def _calibrate_payload(payload, rows) -> None:
    """Fit OVERLAP_EFF from the payload's step times; record in place."""
    from benchmarks import comm_model
    fit = comm_model.fit_overlap_eff(payload.get("overlap_step_times_us"))
    if fit is None:
        rows.append("calibrated_overlap_eff,0.00,SKIP:no-usable-step-times")
        return
    payload["calibrated_overlap_eff"] = fit
    # seed missing modes (e.g. a bench row that errored) with the prior so
    # the calibrated theory table stays parallel to theory_overlap's 4 modes
    eff_full = {**comm_model.OVERLAP_EFF, **fit["eff"]}
    payload["theory_overlap_calibrated"] = comm_model.overlap_rows(eff_full)
    for mode, e in sorted(fit["eff"].items()):
        default = comm_model.OVERLAP_EFF.get(mode, 0.0)
        rows.append(f"calibrated_eff_{mode},0.00,{e:.3f}(default={default:.2f})")
    rows.append(f"calibrated_comm_fraction,0.00,{fit['comm_fraction']:.3f}")
    if fit["clipped"]:
        rows.append("calibrated_eff_clipped,0.00,"
                    + "|".join(fit["clipped"]) + "(cpu-emulated-ring-overhead)")


def calibrate(path: str) -> None:
    """--calibrate entry: refit efficiencies from an existing bench file."""
    rows = []
    try:
        with open(path) as f:
            payload = json.load(f)
        _calibrate_payload(payload, rows)
        if "calibrated_overlap_eff" in payload:
            with open(path, "w") as f:
                json.dump(payload, f, indent=2, default=str)
            rows.append(f"bench_overlap_json,0.00,{path}")
    except Exception as e:
        rows.append(f"calibrate,0.00,ERROR:{type(e).__name__}:{e}")
    print("name,us_per_call,derived")
    for r in rows:
        print(r)


def main() -> None:
    rows = []

    def emit(name, us, derived):
        rows.append(f"{name},{us:.2f},{derived}")

    from benchmarks import (ckpt_stall, comm_model, dram, hlo_compare,
                            layout, link_latency, micro, overlap, scaling,
                            serve_bench)
    results = {}
    for mod in (comm_model, scaling, dram, layout, link_latency, micro,
                hlo_compare, overlap, ckpt_stall, serve_bench):
        try:
            results[mod.__name__.split(".")[-1]] = mod.main(emit)
        except Exception as e:  # keep the harness robust; surface the failure
            rows.append(f"{mod.__name__},0.00,ERROR:{type(e).__name__}:{e}")

    try:
        payload = {
            "overlap_step_times_us": results.get("overlap"),
            "micro_rows": results.get("micro"),
            "theory_overlap": None,
            "hlo_overlap": (results.get("hlo_compare") or {}).get("overlap"),
            "residual_layouts": (results.get("hlo_compare")
                                 or {}).get("residual"),
            "quant_bytes": (results.get("hlo_compare") or {}).get("quant"),
            "checkpoint_stall": results.get("ckpt_stall"),
            "checkpoint_multiwriter": (results.get("ckpt_stall")
                                       or {}).get("multiwriter"),
            "guard_overhead": (results.get("ckpt_stall") or {}).get("guard"),
            "theory_pipeline": (results.get("comm_model")
                                or {}).get("pipeline"),
            "serving": results.get("serve_bench"),
        }
        from benchmarks import comm_model as _cm
        payload["theory_overlap"] = _cm.overlap_rows()
        payload["theory_overlap_int8"] = _cm.overlap_rows(comm_dtype="int8")
        _calibrate_payload(payload, rows)
        with open(BENCH_JSON, "w") as f:
            json.dump(payload, f, indent=2, default=str)
        rows.append(f"bench_overlap_json,0.00,{BENCH_JSON}")
    except Exception as e:
        rows.append(f"bench_overlap_json,0.00,ERROR:{type(e).__name__}:{e}")

    print("name,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == '__main__':
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--calibrate", metavar="BENCH_JSON", default=None,
                    help="skip benchmarks; refit OVERLAP_EFF from the step "
                         "times recorded in this BENCH_overlap.json")
    args = ap.parse_args()
    if args.calibrate:
        calibrate(args.calibrate)
    else:
        main()
