"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract).

  comm_model    — Fig. 8 / Table III latency+energy comparison (4 methods)
  scaling       — Fig. 9 weak scaling
  dram          — Fig. 10 DRAM-bandwidth sweep
  layout        — Fig. 11 die-layout study
  link_latency  — Table IV link-latency proportion
  micro         — kernel reference micro-benchmarks (host wall time)
  hlo_compare   — measured collective bytes hecaton vs megatron (compiled HLO)
                  + per-overlap-mode collective-permute vs bulk AG/RS bytes
  overlap       — wall time bulk vs ring vs bidir collective matmuls (CPU mesh)
"""
import sys


def main() -> None:
    rows = []

    def emit(name, us, derived):
        rows.append(f"{name},{us:.2f},{derived}")

    from benchmarks import (comm_model, dram, hlo_compare, layout,
                            link_latency, micro, overlap, scaling)
    for mod in (comm_model, scaling, dram, layout, link_latency, micro,
                hlo_compare, overlap):
        try:
            mod.main(emit)
        except Exception as e:  # keep the harness robust; surface the failure
            rows.append(f"{mod.__name__},0.00,ERROR:{type(e).__name__}:{e}")
    print("name,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == '__main__':
    main()
