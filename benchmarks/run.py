"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract).

  comm_model    — Fig. 8 / Table III latency+energy comparison (4 methods)
                  + per-overlap-mode exposed-NoP theory (effective bandwidth)
  scaling       — Fig. 9 weak scaling
  dram          — Fig. 10 DRAM-bandwidth sweep
  layout        — Fig. 11 die-layout study
  link_latency  — Table IV link-latency proportion
  micro         — kernel reference micro-benchmarks (host wall time)
  hlo_compare   — measured collective bytes hecaton vs megatron (compiled HLO)
                  + per-overlap-mode collective-permute vs bulk AG/RS bytes
                  for the hecaton FFN, MoE and megatron paths
  overlap       — wall time bulk vs ring vs bidir vs fused collective matmuls
                  (CPU mesh; fused runs the interpret-emulated kernel path)

Besides the CSV, the harness persists ``BENCH_overlap.json`` next to the repo
root: per-mode step times from ``benchmarks/overlap.py``, the micro matmul
rows, and the overlap-aware comm-model theory — one file per run so the perf
trajectory is tracked across PRs (CI uploads it as an artifact).
"""
import json
import os

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_overlap.json")


def main() -> None:
    rows = []

    def emit(name, us, derived):
        rows.append(f"{name},{us:.2f},{derived}")

    from benchmarks import (comm_model, dram, hlo_compare, layout,
                            link_latency, micro, overlap, scaling)
    results = {}
    for mod in (comm_model, scaling, dram, layout, link_latency, micro,
                hlo_compare, overlap):
        try:
            results[mod.__name__.split(".")[-1]] = mod.main(emit)
        except Exception as e:  # keep the harness robust; surface the failure
            rows.append(f"{mod.__name__},0.00,ERROR:{type(e).__name__}:{e}")

    try:
        payload = {
            "overlap_step_times_us": results.get("overlap"),
            "micro_rows": results.get("micro"),
            "theory_overlap": None,
            "hlo_overlap": (results.get("hlo_compare") or {}).get("overlap"),
        }
        from benchmarks import comm_model as _cm
        payload["theory_overlap"] = _cm.overlap_rows()
        with open(BENCH_JSON, "w") as f:
            json.dump(payload, f, indent=2, default=str)
        rows.append(f"bench_overlap_json,0.00,{BENCH_JSON}")
    except Exception as e:
        rows.append(f"bench_overlap_json,0.00,ERROR:{type(e).__name__}:{e}")

    print("name,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == '__main__':
    main()
