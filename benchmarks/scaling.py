"""Paper Fig. 9 — weak scaling study.

Scales (h, N) along the paper's ladder and reports normalized per-layer
latency for each method, in both package regimes.  Verifies the §V-B theory:
Hecaton stays ~flat; 1D-TP methods grow.
"""
from repro.core import theory as T

DIE_FLOPS = 5e12


def run():
    rows = []
    for pkg, beta in (("standard", 12e9), ("advanced", 48e9)):
        base = T.CommParams(N=16, beta=beta, b=8, s=2048, h=2048)
        for m in T.METHODS:
            series = T.weak_scaling_series(m, base, ks=(1, 2, 4, 8),
                                           flops_per_device=DIE_FLOPS)
            for k, o in zip((1, 2, 4, 8), series):
                rows.append({"package": pkg, "method": m, "k": k,
                             "h": 2048 * k, "N": 16 * k * k,
                             "normalized_latency": o["normalized"],
                             "nop_fraction": o["nop"] / o["total"]})
    return rows


def main(emit):
    rows = run()
    for pkg in ("standard", "advanced"):
        hec = [r for r in rows if r["package"] == pkg
               and r["method"] == "hecaton"][-1]
        flat = [r for r in rows if r["package"] == pkg
                and r["method"] == "flat_ring"][-1]
        emit(f"fig9_weakscale_hecaton_{pkg}_k8", 0.0,
             f"{hec['normalized_latency']:.2f}x")
        emit(f"fig9_weakscale_flatring_{pkg}_k8", 0.0,
             f"{flat['normalized_latency']:.2f}x")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
