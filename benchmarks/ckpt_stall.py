"""Checkpoint-induced step-time stall: sync vs async (ISSUE 4 acceptance).

Trains an embedding-heavy tiny LM (large vocab, shallow stack — cheap per-step
compute, a state big enough that serializing it costs real wall time) and
measures the wall time of steps that land on a checkpoint boundary vs steps
that don't, once with the blocking CheckpointManager (device_get + serialize +
write on the training thread) and once with AsyncCheckpointManager (host
staging-arena snapshot at the boundary; serialization + atomic publish on the
writer thread overlap the following steps).

Rows (also persisted as ``checkpoint_stall`` in BENCH_overlap.json):

  ckpt_stall_base_us        median non-boundary step (sync run — the async
                            run's base steps absorb writer-thread contention
                            and would bias the denominator)
  ckpt_stall_async_base_us  median non-boundary step of the async run, for
                            reference (includes writer contention)
  ckpt_stall_sync_us        median boundary step, blocking saves
  ckpt_stall_async_us       median boundary step, async saves
  ckpt_stall_sync_x         sync boundary / base   (the stall being hidden)
  ckpt_stall_async_x        async boundary / base  (acceptance: <= 1.5x)
  ckpt_stall_state_mb       bytes snapshotted per checkpoint

The step function donates its buffers, so the async boundary still pays the
device→host snapshot (it must — the next step reuses the device memory); what
the writer thread hides is everything after it.

Multi-writer sweep (ISSUE 6; persisted as ``checkpoint_multiwriter``):
blocking wall-clock write time of the same ~65MB state under a writer group
of 1 / 2 / 4 writers (``ckpt_multiwriter_wN_us``, median of several saves,
non-durable so the measurement is serialize+write parallelism rather than
fsync latency).  Acceptance (CI): the 4-writer save is no slower than the
1-writer save — the writer group removes the single-writer bandwidth
ceiling, it must not add a coordination penalty.

Process-fleet sweep (ISSUE 8; same ``checkpoint_multiwriter`` record): the
same saves with the writers as supervised OS processes (runtime/procs.py —
spawn context, shared-memory snapshot handover, heartbeat leases).  A
warmup save absorbs the one-time fleet spawn + cold handover arena;
``ckpt_multiwriter_procs_wN_us`` is then the steady-state save, and
``ckpt_multiwriter_procs_xN`` the median of per-pair ratios against
thread-writer saves interleaved rep by rep (pairing cancels the
writeback-load drift a ratio of separately-taken medians would inhale).
Acceptance (CI): <= 1.3x — crash isolation may cost IPC + a warm shm
memcpy, it must not cost a multiple.

Guard overhead (ISSUE 7; persisted as ``guard_overhead``): median steady-
state step time of the guarded jitted step (the in-graph NaN/spike update
guard, optim/adamw.update + runtime/guard.py, docs/DESIGN.md §8) over the
unguarded step — ``guard_overhead_base_us`` / ``guard_overhead_guarded_us``
/ ``guard_overhead_x``.  Acceptance (CI): <= 1.05x.
"""
import time

STEPS = 14
EVERY = 4          # boundaries at local steps 3, 7, 11 (published 4, 8, 12)
WARMUP = 2
WRITER_SWEEP = (1, 2, 4)
PROC_SWEEP = (2, 4)
MW_REPS = 5
PROC_REPS = 9      # pairs; per-pair ratios swing ±0.4 on a loaded 2-core
                   # box, so the median needs more samples than MW_REPS
GUARD_PAIRS = 30


def _build(guard=None):
    import jax
    import jax.numpy as jnp
    from repro.config import ModelConfig, ParallelConfig, RunConfig
    from repro.data.synthetic import SyntheticLM
    from repro.train import step as TS

    # ~65MB state behind a step with enough token compute that the arena
    # snapshot (a parallel memcpy of the state) stays well under the step
    # time, while the DURABLE serialize+fsync publish costs a multiple of it
    cfg = ModelConfig(name="stall", family="dense", num_layers=2,
                      d_model=256, num_heads=8, num_kv_heads=4, d_ff=512,
                      vocab_size=8_192, mlp_kind="swiglu")
    rc = RunConfig("t", "train", 128, 4, lr=1e-3)
    pcfg = ParallelConfig(data=1, model=1, mx=1, my=1, microbatches=1,
                          zero1=False)
    ts = jax.jit(TS.build_train_step(cfg, pcfg, rc, None,
                                     compute_dtype=jnp.float32, guard=guard),
                 donate_argnums=(0, 1))
    ds = SyntheticLM(cfg.vocab_size, rc.seq_len, rc.global_batch)
    batches = [{k: jnp.asarray(v) for k, v in ds.batch_at(s).items()}
               for s in range(STEPS + WARMUP)]
    return cfg, ts, batches


def _run(mgr, ts, batches, init_state):
    """Fold ts over the batches; boundary steps include the save call (the
    stall under test).  Returns (boundary_times, base_times) in seconds."""
    import jax

    params, opt = init_state()
    for b in batches[:WARMUP]:
        params, opt, m = ts(params, opt, b)
    jax.block_until_ready(m["loss"])
    boundary, base = [], []
    for step, b in enumerate(batches[WARMUP:]):
        t0 = time.perf_counter()
        params, opt, m = ts(params, opt, b)
        jax.block_until_ready(m["loss"])
        is_boundary = (step + 1) % EVERY == 0
        if is_boundary:
            mgr.save_async(step + 1, {"params": params, "opt_state": opt})
        dt = time.perf_counter() - t0
        (boundary if is_boundary else base).append(dt)
    mgr.wait_until_finished()
    mgr.close()
    return boundary, base


def _multiwriter(emit, state, state_mb):
    """Blocking save wall time vs writer-group size, same state each time."""
    import tempfile

    import numpy as np
    from repro.checkpoint.manager import make_manager
    from repro.config import CheckpointConfig

    rows = {}
    for w in WRITER_SWEEP:
        mgr = make_manager(tempfile.mkdtemp(),
                           CheckpointConfig(async_=False, keep=2, writers=w))
        times = []
        for rep in range(MW_REPS):
            t0 = time.perf_counter()
            mgr.save(rep + 1, state)
            times.append(time.perf_counter() - t0)
        rows[f"w{w}_us"] = float(np.median(times)) * 1e6
        emit(f"ckpt_multiwriter_w{w}_us", rows[f"w{w}_us"],
             f"{w}-writers-{state_mb:.0f}MB")
    rows["x4v1"] = rows["w4_us"] / rows["w1_us"]
    emit("ckpt_multiwriter_x4v1", 0.0,
         f"{rows['x4v1']:.2f}(acceptance<=1)")
    # process-fleet sweep (ISSUE 8): same state, writers as OS processes
    # (runtime/procs.py — spawn + shm handover + heartbeat supervision).
    # One warmup save absorbs the one-time fleet spawn + cold handover
    # arena (both persist across saves, so training boundaries never pay
    # them); the timed reps then measure the steady-state process
    # overhead: warm arena pack + IPC + cross-process writes vs
    # same-address-space threads.  Sampling is PAIRED like
    # _guard_overhead: each rep times a thread-group save and a fleet
    # save back to back on the same state, and the acceptance ratio is
    # the median of per-pair ratios — dirty-page writeback from earlier
    # bench phases drifts absolute save times over the run, hitting both
    # pair members equally and cancelling, where a ratio against the
    # earlier thread sweep's median compares different load conditions.
    for w in PROC_SWEEP:
        tmgr = make_manager(tempfile.mkdtemp(),
                            CheckpointConfig(async_=False, keep=2,
                                             writers=w))
        pmgr = make_manager(tempfile.mkdtemp(),
                            CheckpointConfig(async_=False, keep=2,
                                             writers=w, writer_procs=True))
        tmgr.save(1, state)
        pmgr.save(1, state)                    # warmup: fleet spawn
        ptimes, pairs = [], []
        for rep in range(PROC_REPS):
            t0 = time.perf_counter()
            tmgr.save(rep + 2, state)
            t_thr = time.perf_counter() - t0
            t0 = time.perf_counter()
            pmgr.save(rep + 2, state)
            t_proc = time.perf_counter() - t0
            ptimes.append(t_proc)
            pairs.append(t_proc / t_thr)
        tmgr.close()
        pmgr.close()
        rows[f"procs_w{w}_us"] = float(np.median(ptimes)) * 1e6
        emit(f"ckpt_multiwriter_procs_w{w}_us", rows[f"procs_w{w}_us"],
             f"{w}-proc-writers-{state_mb:.0f}MB")
        rows[f"procs_x{w}"] = float(np.median(pairs))
        emit(f"ckpt_multiwriter_procs_x{w}", 0.0,
             f"{rows[f'procs_x{w}']:.2f}(acceptance<=1.3)")
    return rows


def _guard_overhead(emit):
    """In-graph update-guard cost (ISSUE 7; persisted as ``guard_overhead``):
    median step time of the guarded step (isfinite + EWMA-spike predicate +
    where-selected AdamW, optim/adamw.update) over the unguarded step, same
    model/batches.  Acceptance (CI): <= 1.05x — the guard is a handful of
    scalar ops + selects XLA fuses into the update, it must be ~free.

    Sampling is PAIRED and interleaved (base step then guarded step on the
    same batch, back to back): both pair members see the same machine-load
    conditions, so the reported ratio is the MEDIAN OF PER-PAIR RATIOS —
    slow drift and load spikes hit both members and cancel, where a ratio
    of independent block medians over a handful of samples wobbles ~±5% on
    a shared CI box, swamping the effect under test."""
    import itertools

    import jax

    import numpy as np
    from repro.config import GuardConfig
    from repro.models import lm
    from repro.optim import adamw

    cfg, ts_base, batches = _build(guard=None)
    _, ts_guard, _ = _build(guard=GuardConfig())

    def init_state():
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        return params, adamw.init(params)

    # separate fold chains — the jitted steps donate their buffers
    chains = {"base": init_state(), "guarded": init_state()}
    steps = {"base": ts_base, "guarded": ts_guard}
    times = {"base": [], "guarded": []}
    for b in batches[:WARMUP]:
        for key in chains:
            p, o, m = steps[key](*chains[key], b)
            jax.block_until_ready(m["loss"])
            chains[key] = (p, o)
    # data repeats across pairs (cycle) — only the wall time is under test
    for b in itertools.islice(itertools.cycle(batches[WARMUP:]), GUARD_PAIRS):
        for key in ("base", "guarded"):
            t0 = time.perf_counter()
            p, o, m = steps[key](*chains[key], b)
            jax.block_until_ready(m["loss"])
            times[key].append(time.perf_counter() - t0)
            chains[key] = (p, o)
    rows = {}
    for key in ("base", "guarded"):
        rows[f"{key}_us"] = float(np.median(times[key])) * 1e6
        emit(f"guard_overhead_{key}_us", rows[f"{key}_us"],
             f"{'guarded' if key == 'guarded' else 'unguarded'}-step")
    ratios = np.array(times["guarded"]) / np.array(times["base"])
    rows["x"] = float(np.median(ratios))
    emit("guard_overhead_x", 0.0, f"{rows['x']:.3f}(acceptance<=1.05)")
    return rows


def main(emit):
    import tempfile

    import jax
    import numpy as np
    from repro.checkpoint.manager import make_manager
    from repro.config import CheckpointConfig
    from repro.models import lm
    from repro.optim import adamw

    cfg, ts, batches = _build()

    def init_state():
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        return params, adamw.init(params)

    p, o = init_state()
    state_mb = sum(np.asarray(x).nbytes for x in
                   jax.tree_util.tree_leaves({"p": p, "o": o})) / 1e6
    # host-side copy for the multi-writer sweep: the snapshot cost is then a
    # no-op memcpy and the sweep isolates the serialize+write fan-out
    host_state = jax.device_get({"params": p, "opt_state": o})
    del p, o

    # durable=True on BOTH paths: the comparison is fair (identical bytes,
    # identical fsync barrier) and realistic — a checkpoint you cannot
    # trust after power loss hides its cost by not paying it
    sync_b, sync_base = _run(
        make_manager(tempfile.mkdtemp(),
                     CheckpointConfig(async_=False, durable=True)),
        ts, batches, init_state)
    async_b, async_base = _run(
        make_manager(tempfile.mkdtemp(), CheckpointConfig(durable=True)),
        ts, batches, init_state)
    # baseline from the SYNC run only: in the async run the writer thread
    # serializes during the non-boundary steps and inflates them — pooling
    # those samples would bias the denominator the acceptance ratio divides
    # by (the async run's base median is reported separately instead)
    base = float(np.median(sync_base))
    sync_us = float(np.median(sync_b)) * 1e6
    async_us = float(np.median(async_b)) * 1e6
    base_us = base * 1e6
    rows = {
        "base_us": base_us, "sync_us": sync_us, "async_us": async_us,
        "sync_x": sync_us / base_us, "async_x": async_us / base_us,
        "async_base_us": float(np.median(async_base)) * 1e6,
        "state_mb": state_mb,
    }
    emit("ckpt_stall_base_us", base_us, f"{state_mb:.0f}MB-state")
    emit("ckpt_stall_async_base_us", rows["async_base_us"],
         "non-boundary-steps-while-writer-runs")
    emit("ckpt_stall_sync_us", sync_us, f"{rows['sync_x']:.2f}x-base")
    emit("ckpt_stall_async_us", async_us, f"{rows['async_x']:.2f}x-base")
    emit("ckpt_stall_sync_x", 0.0, f"{rows['sync_x']:.2f}")
    emit("ckpt_stall_async_x", 0.0,
         f"{rows['async_x']:.2f}(acceptance<=1.5)")
    rows["multiwriter"] = _multiwriter(emit, host_state, state_mb)
    rows["guard"] = _guard_overhead(emit)
    return rows


if __name__ == "__main__":
    def emit(name, us, derived):
        print(f"{name},{us:.2f},{derived}")
    main(emit)
