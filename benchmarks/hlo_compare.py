"""Measured (compiled-HLO) per-step collective bytes: hecaton vs megatron on a
fake 8-device mesh — the empirical companion to comm_model.py's theory.
Runs in a subprocess (needs its own XLA device-count flag)."""
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=32"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.config import ModelConfig, ParallelConfig, RunConfig
from repro.models import lm
from repro.optim import adamw
from repro.parallel import specs as SP
from repro.roofline.hlo import analyze
from repro.train import step as TS
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

cfg = ModelConfig(name="cmp", family="dense", num_layers=4, d_model=512,
                  num_heads=16, num_kv_heads=8, d_ff=2048, vocab_size=512,
                  mlp_kind="swiglu")
rc = RunConfig("t", "train", 256, 8, lr=1e-3)
out = {}
for strat, mesh in (("hecaton", Mesh(np.array(jax.devices()).reshape(2, 4, 4),
                                     ("data", "mx", "my"))),
                    ("megatron", Mesh(np.array(jax.devices()).reshape(2, 16),
                                      ("data", "model")))):
    pcfg = ParallelConfig(strategy=strat, data=2, model=16, mx=4, my=4,
                          microbatches=1, zero1=False)
    params = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = SP.param_specs(params, mesh, pcfg)
    pshard = SP.sharding_tree(pspecs, mesh)
    opt = jax.eval_shape(adamw.init, params)
    oshard = SP.sharding_tree(SP.opt_state_specs(pspecs, params, mesh, pcfg),
                              mesh)
    seq_ax = "mx" if strat == "hecaton" else None
    bshard = {k: NamedSharding(mesh, P("data", seq_ax))
              for k in ("tokens", "labels")}
    bstruct = {k: jax.ShapeDtypeStruct((8, 256), jnp.int32)
               for k in ("tokens", "labels")}
    ts = TS.build_train_step(cfg, pcfg, rc, mesh)
    c = jax.jit(ts, in_shardings=(pshard, oshard, bshard)).lower(
        params, opt, bstruct).compile()
    r = analyze(c.as_text())
    out[strat] = {"coll_bytes": r.total_coll_bytes,
                  "breakdown": dict(r.coll_bytes), "flops": r.flops}
print("RESULT " + json.dumps(out))
'''


def run():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=900)
    if r.returncode != 0:
        return {"error": r.stderr[-500:]}
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def main(emit):
    out = run()
    if "error" in out:
        emit("hlo_compare", 0.0, "ERROR")
        return out
    h, m = out["hecaton"]["coll_bytes"], out["megatron"]["coll_bytes"]
    emit("hlo_measured_bytes_hecaton", 0.0, f"{h/1e6:.1f}MB")
    emit("hlo_measured_bytes_megatron", 0.0, f"{m/1e6:.1f}MB")
    emit("hlo_measured_ratio_meg_over_hec", 0.0, f"{m/h:.2f}x")
    return out
