"""Measured (compiled-HLO) per-step collective bytes: hecaton vs megatron on a
fake 8-device mesh — the empirical companion to comm_model.py's theory — plus
the overlap counter: per-mode (none/ring/bidir/fused) collective-permute vs
bulk all-gather/reduce-scatter bytes of one Hecaton FFN block (forward and
backward), one MoE block (EP/TP gathers + scatters), and one megatron
column/row FFN, proving the ring decomposition replaces every bulk AG/RS in
every hot path with a ppermute chain (the fused mode additionally runs its
matmuls through the Pallas ring kernels' emulated path on CPU).  Runs in
subprocesses (each needs its own XLA device-count flag)."""
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=32"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.config import ModelConfig, ParallelConfig, RunConfig
from repro.models import lm
from repro.optim import adamw
from repro.parallel import specs as SP
from repro.roofline.hlo import analyze
from repro.train import step as TS
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

cfg = ModelConfig(name="cmp", family="dense", num_layers=4, d_model=512,
                  num_heads=16, num_kv_heads=8, d_ff=2048, vocab_size=512,
                  mlp_kind="swiglu")
rc = RunConfig("t", "train", 256, 8, lr=1e-3)
out = {}
for strat, mesh in (("hecaton", Mesh(np.array(jax.devices()).reshape(2, 4, 4),
                                     ("data", "mx", "my"))),
                    ("megatron", Mesh(np.array(jax.devices()).reshape(2, 16),
                                      ("data", "model")))):
    pcfg = ParallelConfig(strategy=strat, data=2, model=16, mx=4, my=4,
                          microbatches=1, zero1=False)
    params = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = SP.param_specs(params, mesh, pcfg)
    pshard = SP.sharding_tree(pspecs, mesh)
    opt = jax.eval_shape(adamw.init, params)
    oshard = SP.sharding_tree(SP.opt_state_specs(pspecs, params, mesh, pcfg),
                              mesh)
    seq_ax = "mx" if strat == "hecaton" else None
    bshard = {k: NamedSharding(mesh, P("data", seq_ax))
              for k in ("tokens", "labels")}
    bstruct = {k: jax.ShapeDtypeStruct((8, 256), jnp.int32)
               for k in ("tokens", "labels")}
    ts = TS.build_train_step(cfg, pcfg, rc, mesh)
    c = jax.jit(ts, in_shardings=(pshard, oshard, bshard)).lower(
        params, opt, bstruct).compile()
    r = analyze(c.as_text())
    out[strat] = {"coll_bytes": r.total_coll_bytes,
                  "breakdown": dict(r.coll_bytes), "flops": r.flops}
print("RESULT " + json.dumps(out))
'''


# Overlap counter: one Hecaton FFN block (fwd + grad), one MoE block, and one
# megatron column/row FFN compiled per overlap mode on fake 8-device meshes;
# reports per-collective bytes and op counts for each path.
SCRIPT_OVERLAP = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.config import ModelConfig, MoEConfig, ParallelConfig
from repro.core import hecaton as H
from repro.models import mlp as MLP
from repro.parallel import megatron as MEG
from repro.parallel.context import PCtx
from repro.roofline.hlo import analyze

mesh = Mesh(np.array(jax.devices()).reshape(2, 2, 2), ("data", "mx", "my"))
B, T, Hd, F = 4, 64, 128, 512
sh = lambda s: jax.ShapeDtypeStruct(s, jnp.float32)
shards = (NamedSharding(mesh, P("data", "mx", "my")),
          NamedSharding(mesh, P("my", "mx")), NamedSharding(mesh, P("mx", "my")))

# MoE: experts over a 4-ring, FFN width over a 2-ring (data axis degenerate so
# only the EP/TP collectives are counted).
mesh_moe = Mesh(np.array(jax.devices()).reshape(1, 4, 2), ("data", "mx", "my"))
moe_cfg = ModelConfig(name="cmp-moe", family="moe", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=64,
                      mlp_kind="swiglu", moe=MoEConfig(num_experts=8, top_k=2))
moe_p = MLP.init_moe(moe_cfg, jax.random.PRNGKey(0))
moe_x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32), jnp.float32)

# Megatron 1D-TP: 8-way model ring, H=32 chunks evenly.
mesh_meg = Mesh(np.array(jax.devices()).reshape(1, 8), ("data", "model"))
Hm, Fm = 32, 64

out = {}
for ov in ("none", "ring", "bidir", "fused"):
    def ffn(x, w1, w2, _ov=ov):
        return H.ffn_block(x, w1, w2, mesh=mesh, act_fn=jax.nn.silu,
                           t_ax="mx", h_ax="my", overlap=_ov)
    def step(x, w1, w2, _f=ffn):
        return jax.grad(lambda *a: _f(*a).sum(), argnums=(0, 1, 2))(x, w1, w2)
    res = {}
    for tag, fn in (("fwd", ffn), ("fwd_bwd", step)):
        c = jax.jit(fn, in_shardings=shards).lower(
            sh((B, T, Hd)), sh((Hd, F)), sh((F, Hd))).compile()
        r = analyze(c.as_text())
        res[tag] = {"bytes": dict(r.coll_bytes), "count": dict(r.coll_count)}

    moe_pctx = PCtx(mesh=mesh_moe, pcfg=ParallelConfig(
        strategy="hecaton", data=1, model=8, mx=4, my=2, overlap=ov,
        zero1=False))
    def moe_step(p, x, _pctx=moe_pctx):
        def loss(p, x):
            y, aux = MLP.apply_moe(_pctx, moe_cfg, p, x)
            return y.sum() + aux
        return jax.grad(loss, argnums=(0, 1))(p, x)
    c = jax.jit(moe_step).lower(
        jax.tree_util.tree_map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                               moe_p),
        sh(moe_x.shape)).compile()
    r = analyze(c.as_text())
    res["moe"] = {"bytes": dict(r.coll_bytes), "count": dict(r.coll_count)}

    meg_pctx = PCtx(mesh=mesh_meg, pcfg=ParallelConfig(
        strategy="megatron", data=1, model=8, overlap=ov, zero1=False))
    def meg_step(x, w1, w2, _pctx=meg_pctx):
        def loss(x, w1, w2):
            return MEG.ffn(_pctx, x, w1, w2, jax.nn.silu).sum()
        return jax.grad(loss, argnums=(0, 1, 2))(x, w1, w2)
    c = jax.jit(meg_step).lower(
        sh((2, 8, Hm)), sh((Hm, Fm)), sh((Fm, Hm))).compile()
    r = analyze(c.as_text())
    res["megatron"] = {"bytes": dict(r.coll_bytes), "count": dict(r.coll_count)}
    out[ov] = res
print("RESULT " + json.dumps(out))
'''


# Residual-layout counter: a 2-layer dense LM (train fwd+bwd) compiled on a
# megatron 1D-TP ring under BOTH residual layouts (replicated vs seq-sharded)
# per overlap mode.  Proves the seq layout removes every bulk AG/RS from the
# block boundaries under ring/bidir/fused (entry gathers / exit scatters ride
# the collective-permute lattice) and that the per-die residual-stream bytes
# carried across the layer scan shrink by 1/n_model.
SCRIPT_RESIDUAL = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.config import ModelConfig, ParallelConfig
from repro.models import lm
from repro.parallel import specs as SP
from repro.parallel.context import PCtx
from repro.roofline.hlo import analyze

cfg = ModelConfig(name="res", family="dense", num_layers=2, d_model=64,
                  num_heads=8, num_kv_heads=8, d_ff=128, vocab_size=256,
                  mlp_kind="swiglu")
B, S, n_model = 4, 64, 8
mesh = Mesh(np.array(jax.devices()).reshape(1, n_model), ("data", "model"))
params = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
out = {"n_model": n_model}
for residual in ("replicated", "seq"):
    res_l = {}
    for ov in ("none", "ring", "bidir", "fused"):
        pcfg = ParallelConfig(strategy="megatron", data=1, model=n_model,
                              overlap=ov, residual=residual, zero1=False)
        pctx = PCtx(mesh, pcfg, "train")
        pshard = SP.sharding_tree(SP.param_specs(params, mesh, pcfg), mesh)
        bspec = SP.batch_specs(mesh, pcfg, microbatched=False, seq_len=S)
        bshard = {k: NamedSharding(mesh, bspec[k])
                  for k in ("tokens", "labels")}
        bstruct = {k: jax.ShapeDtypeStruct((B, S), jnp.int32)
                   for k in ("tokens", "labels")}
        def loss(p, b, _pctx=pctx):
            return lm.train_loss(_pctx, cfg, p, {**b, "_dtype": jnp.float32},
                                 remat="none")[0]
        c = jax.jit(jax.grad(loss), in_shardings=(pshard, bshard)).lower(
            params, bstruct).compile()
        r = analyze(c.as_text())
        row = {"bytes": dict(r.coll_bytes), "count": dict(r.coll_count)}
        try:                      # measured per-device temp memory (may be
            ma = c.memory_analysis()          # unavailable on some backends)
            row["temp_bytes"] = int(getattr(ma, "temp_size_in_bytes", 0))
        except Exception:
            row["temp_bytes"] = None
        # analytic per-die residual-stream bytes carried across the layer scan
        row["residual_bytes_per_die"] = (B * S * cfg.d_model * 4
                                         // (n_model if residual == "seq"
                                             else 1))
        res_l[ov] = row
    out[residual] = res_l
print("RESULT " + json.dumps(out))
'''


# Quantized-wire counter: the same 2-layer megatron LM (train fwd+bwd, seq
# residual) compiled under comm_dtype "bf16" vs "int8" per overlap mode.
# Proves the int8 rings actually move int8 bytes in compiled HLO — the
# collective-permute byte total must drop well below the 0.55x gate (payload
# shrinks 4x from the fp32 compute dtype; the per-row fp32 scales ride along
# as separate small permutes) — while the bulk AG/RS total stays zero (the
# wire dtype must not break the overlap lattice's degradation decisions).
SCRIPT_QUANT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.config import ModelConfig, ParallelConfig
from repro.models import lm
from repro.parallel import specs as SP
from repro.parallel.context import PCtx
from repro.roofline.hlo import analyze

cfg = ModelConfig(name="quant", family="dense", num_layers=2, d_model=64,
                  num_heads=8, num_kv_heads=8, d_ff=128, vocab_size=256,
                  mlp_kind="swiglu")
B, S, n_model = 4, 64, 8
mesh = Mesh(np.array(jax.devices()).reshape(1, n_model), ("data", "model"))
params = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
out = {"n_model": n_model}
for ov in ("ring", "bidir", "fused"):
    row = {}
    for cd in ("bf16", "int8"):
        pcfg = ParallelConfig(strategy="megatron", data=1, model=n_model,
                              overlap=ov, residual="seq", zero1=False,
                              comm_dtype=cd)
        pctx = PCtx(mesh, pcfg, "train")
        pshard = SP.sharding_tree(SP.param_specs(params, mesh, pcfg), mesh)
        bspec = SP.batch_specs(mesh, pcfg, microbatched=False, seq_len=S)
        bshard = {k: NamedSharding(mesh, bspec[k])
                  for k in ("tokens", "labels")}
        bstruct = {k: jax.ShapeDtypeStruct((B, S), jnp.int32)
                   for k in ("tokens", "labels")}
        def loss(p, b, _pctx=pctx):
            return lm.train_loss(_pctx, cfg, p, {**b, "_dtype": jnp.float32},
                                 remat="none")[0]
        c = jax.jit(jax.grad(loss), in_shardings=(pshard, bshard)).lower(
            params, bstruct).compile()
        r = analyze(c.as_text())
        row[cd] = {"bytes": dict(r.coll_bytes), "count": dict(r.coll_count)}
    out[ov] = row
print("RESULT " + json.dumps(out))
'''


def _run_script(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=900)
    if r.returncode != 0:
        return {"error": r.stderr[-500:]}
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def run():
    return _run_script(SCRIPT)


def run_overlap():
    """Per-overlap-mode collective bytes/counts of one hecaton FFN block
    (fwd, fwd+bwd), one MoE block (fwd+bwd) and one megatron FFN (fwd+bwd).

    Returns {mode: {path: {"bytes": {coll: B}, "count": {coll: n}}}} with
    paths "fwd" / "fwd_bwd" (hecaton FFN), "moe", "megatron".  Every
    ring/bidir/fused mode must show zero bulk all-gather/reduce-scatter and a
    collective-permute chain instead (asserted by tests/test_overlap.py)."""
    return _run_script(SCRIPT_OVERLAP)


def run_residual():
    """Per-residual-layout (replicated vs seq) × per-overlap-mode collective
    bytes of a full 2-layer megatron LM train step (fwd+bwd).

    Returns {"n_model": n, layout: {mode: {"bytes", "count", "temp_bytes",
    "residual_bytes_per_die"}}}.  Acceptance (asserted by
    tests/test_overlap.py and the CI smoke check): the seq layout has ZERO
    bulk all-gather/reduce-scatter under overlap ∈ {ring, bidir, fused}, no
    more bulk bytes than the replicated layout anywhere, and its per-die
    residual bytes are 1/n_model of the replicated layout's."""
    return _run_script(SCRIPT_RESIDUAL)


def run_quant():
    """Per-overlap-mode (ring/bidir/fused) collective bytes of the 2-layer
    megatron LM train step under ``comm_dtype`` "bf16" vs "int8".

    Returns {"n_model": n, mode: {comm_dtype: {"bytes", "count"}}}.
    Acceptance (asserted by tests/test_overlap.py and the CI grep): int8's
    collective-permute bytes ≤ 0.55x the bf16 wire's on every mode, with the
    bulk all-gather/reduce-scatter total still zero — the byte cut comes from
    the wire dtype, never from silently re-bulking a ring."""
    return _run_script(SCRIPT_QUANT)


def main(emit):
    out = run()
    if "error" in out:
        emit("hlo_compare", 0.0, "ERROR")
    else:
        h, m = out["hecaton"]["coll_bytes"], out["megatron"]["coll_bytes"]
        emit("hlo_measured_bytes_hecaton", 0.0, f"{h/1e6:.1f}MB")
        emit("hlo_measured_bytes_megatron", 0.0, f"{m/1e6:.1f}MB")
        emit("hlo_measured_ratio_meg_over_hec", 0.0, f"{m/h:.2f}x")
    ov = run_overlap()
    if "error" in ov:
        emit("hlo_overlap", 0.0, "ERROR")
        return {"compare": out, "overlap": ov}
    for mode, res in ov.items():
        b = res["fwd_bwd"]["bytes"]
        cp = b.get("collective-permute", 0.0)
        bulk = b.get("all-gather", 0.0) + b.get("reduce-scatter", 0.0)
        n_cp = res["fwd_bwd"]["count"].get("collective-permute", 0)
        emit(f"hlo_overlap_{mode}_cp_bytes", 0.0,
             f"{cp/1e3:.1f}KB/{int(n_cp)}ops")
        emit(f"hlo_overlap_{mode}_bulk_bytes", 0.0, f"{bulk/1e3:.1f}KB")
        for path in ("moe", "megatron"):
            pb = res.get(path, {}).get("bytes", {})
            bulk_p = pb.get("all-gather", 0.0) + pb.get("reduce-scatter", 0.0)
            emit(f"hlo_overlap_{path}_{mode}_bulk_bytes", 0.0,
                 f"{bulk_p/1e3:.1f}KB")
    res_l = run_residual()
    if "error" in res_l:
        emit("hlo_residual", 0.0, "ERROR")
    else:
        for layout in ("replicated", "seq"):
            for mode, row in res_l[layout].items():
                b = row["bytes"]
                bulk = b.get("all-gather", 0.0) + b.get("reduce-scatter", 0.0)
                emit(f"hlo_residual_{layout}_{mode}_bulk_bytes", 0.0,
                     f"{bulk/1e3:.1f}KB")
            emit(f"hlo_residual_{layout}_act_bytes", 0.0,
                 f"{res_l[layout]['ring']['residual_bytes_per_die']/1e3:.1f}"
                 "KB/die")
    qt = run_quant()
    if "error" in qt:
        emit("hlo_quant", 0.0, "ERROR")
    else:
        for mode in ("ring", "bidir", "fused"):
            row = qt[mode]
            cp = {cd: row[cd]["bytes"].get("collective-permute", 0.0)
                  for cd in ("bf16", "int8")}
            ratio = cp["int8"] / max(cp["bf16"], 1.0)
            emit(f"hlo_quant_{mode}_cp_ratio", 0.0,
                 f"{ratio:.3f}x({cp['int8']/1e3:.1f}KB/{cp['bf16']/1e3:.1f}KB)")
    return {"compare": out, "overlap": ov, "residual": res_l, "quant": qt}
