"""Paper Fig. 10 — impact of DRAM (here: off-package/host) bandwidth.

Sweeps DDR4-3200 / DDR5-6400 / HBM2 per-channel bandwidth and reports system
latency normalized to DDR5-6400, per package, on llama2-70b.  Shows the
paper's two observations: saturation once DRAM access is hidden by on-package
execution, and higher sensitivity for the faster (advanced) package.
"""
from repro.core import theory as T

DRAMS = {"ddr4-3200": 25.6e9, "ddr5-6400": 51.2e9, "hbm2": 300e9}
DIE_FLOPS = 5e12


def run():
    rows = []
    for pkg, beta in (("standard", 12e9), ("advanced", 48e9)):
        p = T.CommParams(N=256, beta=beta, b=8, s=2048, h=8192)
        base = None
        for name, bw in DRAMS.items():
            # channels sized so DDR5 ~ on-package execution: the paper's
            # design point (Fig. 6 alternates exec-bound / DRAM-bound layers);
            # stream = f32 saves + reloads + unfused 4h intermediates
            sp = T.SystemParams(comm=p, flops_per_device=DIE_FLOPS,
                                dram_bw=bw, dram_channels=12,
                                act_stream_mult=96.0)
            t = T.layer_time("hecaton", sp)
            rows.append({"package": pkg, "dram": name,
                         "total": t["total"],
                         "exposed_dram": t["exposed_dram"]})
        ddr5 = next(r for r in rows if r["package"] == pkg
                    and r["dram"] == "ddr5-6400")["total"]
        for r in rows:
            if r["package"] == pkg:
                r["speedup_vs_ddr5"] = ddr5 / r["total"]
    return rows


def main(emit):
    rows = run()
    for r in rows:
        emit(f"fig10_{r['package']}_{r['dram']}", r["total"] * 1e6,
             f"speedup={r['speedup_vs_ddr5']:.3f}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
