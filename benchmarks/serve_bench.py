"""Serving throughput: decode tokens/s vs concurrent streams on the
continuous-batching engine + paged KV pool (docs/DESIGN.md §10).

For each stream count ``n`` the engine runs ``2n`` requests (arrivals
outpace slots, mixed prompt lengths) through ``n`` decode slots on the
qwen3 smoke config and reports steady-state decode throughput (both
jitted functions warmed first — compile time is excluded by
construction) and mean prefill latency:

  serving_tokps_s{n}      decode tokens/s with n concurrent streams
  serving_prefill_ms_s{n} mean single-sequence prefill latency
  serving_peak_blocks     peak pool blocks-in-use on the widest run vs the
                          dense arena equivalent (slots*ceil(max_seq/block))
  serving_paged_bytes     bytes actually leased at peak vs the dense
                          [slots, max_seq] cache arena bytes

Persisted into BENCH_overlap.json as the ``serving`` section (via
``benchmarks/run.py``, or in place with ``python -m benchmarks.serve_bench``).
"""
import time

STREAMS = (1, 2, 4)
BLOCK = 8
GEN = 16
PROMPT_LENS = (8, 20, 12)
ARCH = "qwen3-0.6b"


def main(emit):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.config import ParallelConfig, RunConfig, get_smoke_config
    from repro.models import lm
    from repro.serve.cache import PoolConfig, blocks_for, dense_cache_bytes
    from repro.serve.engine import DecodeEngine, Request

    cfg = get_smoke_config(ARCH)
    pcfg = ParallelConfig(strategy="hecaton", data=1, model=1, mx=1, my=1)
    max_seq = max(PROMPT_LENS) + GEN
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=p).astype(np.int32)
               for p in PROMPT_LENS]

    out = {"streams": {}, "arch": ARCH, "block": BLOCK, "gen": GEN,
           "prompt_lens": list(PROMPT_LENS)}
    for n in STREAMS:
        pool = PoolConfig(slots=n, block=BLOCK,
                          num_blocks=n * blocks_for(max_seq, BLOCK) + 1,
                          max_seq=max_seq)
        rc = RunConfig("serve", "decode", max_seq, n)
        eng = DecodeEngine(cfg, pcfg, rc, params, pool,
                           compute_dtype=jnp.float32)
        eng.warmup(prompt_lens=PROMPT_LENS)
        reqs = [Request(rid=i, prompt=prompts[i % len(prompts)], max_new=GEN,
                        arrival=0) for i in range(2 * n)]
        eng.run(reqs)
        toks = eng.stats["decode_tokens"]
        dec_s = max(eng.stats["decode_s"], 1e-9)
        pf = eng.stats["prefill_s"]
        pf_ms = 1e3 * sum(pf) / max(1, len(pf))
        dense_b = dense_cache_bytes(cfg, n, max_seq, jnp.float32)
        rec = {"slots": n, "tokps": toks / dec_s, "prefill_ms": pf_ms,
               "decode_tokens": toks, "peak_blocks": eng.pool.peak_blocks_in_use,
               "dense_equiv_blocks": pool.dense_equiv_blocks,
               "paged_bytes": eng.pool.paged_bytes_peak(),
               "dense_bytes": dense_b,
               "preemptions": eng.stats["preemptions"]}
        out["streams"][str(n)] = rec
        emit(f"serving_tokps_s{n}", 1e6 * dec_s / max(1, toks),
             f"{rec['tokps']:.1f}tok/s")
        emit(f"serving_prefill_ms_s{n}", 1e3 * pf_ms, f"{pf_ms:.1f}ms")
    wide = out["streams"][str(STREAMS[-1])]
    emit("serving_peak_blocks", 0.0,
         f"{wide['peak_blocks']}vs{wide['dense_equiv_blocks']}dense")
    emit("serving_paged_bytes", 0.0,
         f"{wide['paged_bytes']}vs{wide['dense_bytes']}dense")
    return out


if __name__ == "__main__":
    # standalone: update the `serving` section of BENCH_overlap.json in place
    import json
    from benchmarks.run import BENCH_JSON
    rows = []

    def emit(name, us, derived):
        rows.append(f"{name},{us:.2f},{derived}")

    res = main(emit)
    try:
        with open(BENCH_JSON) as f:
            payload = json.load(f)
    except Exception:
        payload = {}
    payload["serving"] = res
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=2, default=str)
    rows.append(f"bench_overlap_json,0.00,{BENCH_JSON}")
    print("name,us_per_call,derived")
    for r in rows:
        print(r)
