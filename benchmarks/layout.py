"""Paper Fig. 11 — impact of die layout (16 dies as (length,width) grids).

Generalizes the Table III hecaton coefficients to rectangular (mx, my) grids:
  fwd FFN   : gamma/N * [2(mx-1) + 8(my-1)]
  fwd Atten : gamma/N * [2(mx-1) + 4(my-1)]
  bwd adds the re-gather terms analogously.
plus an MXU/PE-utilization factor for thin local tiles (the paper's observed
square-favoring effect: extreme aspect ratios starve the PE array).
"""
from repro.core import theory as T

LAYOUTS = [(1, 16), (2, 8), (4, 4), (8, 2), (16, 1)]
DIE_FLOPS = 5e12


def rect_comm(mx, my, p):
    """Per-layer (fwd+bwd attn+ffn) transmission seconds on an (mx,my) grid."""
    N = mx * my
    g = p.gamma
    fwd = (2 * (mx - 1) + 8 * (my - 1)) + (2 * (mx - 1) + 4 * (my - 1))
    bwd = (3 * (mx - 1) + 12 * (my - 1)) + (3 * (mx - 1) + 5 * (my - 1))
    return (fwd + bwd) * g / N


def util(mx, my, p):
    """PE-array utilization of the local tile [bs/mx x h/my] @ [h/my x 4h/mx]:
    dims below the 128-wide systolic array waste lanes."""
    rows = p.b * p.s / mx
    cols = p.h / my
    eff = min(1.0, rows / 128) * min(1.0, cols / 128)
    return max(eff, 1e-3)


def run():
    rows = []
    p = T.CommParams(N=16, beta=16e9, b=8, s=512, h=2048)
    flops = T.layer_flops(p)
    for mx, my in LAYOUTS:
        comm = rect_comm(mx, my, p)
        compute = flops / (DIE_FLOPS * 16) / util(mx, my, p)
        rows.append({"layout": f"{mx}x{my}", "comm_s": comm,
                     "compute_s": compute, "total": comm + compute})
    base = next(r for r in rows if r["layout"] == "4x4")["total"]
    for r in rows:
        r["normalized"] = r["total"] / base
    return rows


def main(emit):
    rows = run()
    for r in rows:
        emit(f"fig11_layout_{r['layout']}", r["total"] * 1e6,
             f"norm={r['normalized']:.3f}")
    best = min(rows, key=lambda r: r["total"])
    emit("fig11_best_layout", 0.0, best["layout"])
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
